"""Atomic, elastic checkpointing.

Layout per step: ``<dir>/step_<n>.tmp/`` → fsync → rename to
``<dir>/step_<n>/`` (atomic publish; a crash mid-write never corrupts the
latest checkpoint). Arrays are saved host-gathered as one ``.npz`` per
top-level key plus a json manifest (tree structure, shapes, dtypes, step).

Restore is *elastic*: arrays are loaded on host and ``device_put`` with
the sharding derived for the *current* mesh — restoring a 256-chip
checkpoint onto a 512-chip (or 64-chip) mesh just reshards. Optional
background-thread saving keeps the train loop running (async checkpoint).
"""
from __future__ import annotations

import json
import os
import shutil
import threading

import jax
import numpy as np


def _flatten(tree, prefix=""):
    out = {}
    if isinstance(tree, dict):
        for k, v in tree.items():
            out.update(_flatten(v, f"{prefix}{k}/"))
    else:
        out[prefix[:-1]] = tree
    return out


def _unflatten(flat: dict):
    tree: dict = {}
    for k, v in flat.items():
        parts = k.split("/")
        node = tree
        for p in parts[:-1]:
            node = node.setdefault(p, {})
        node[parts[-1]] = v
    return tree


class Checkpointer:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._thread: threading.Thread | None = None

    # ------------------------------------------------------------- save
    def save(self, state, step: int, *, background: bool = False):
        self.wait()   # never two writers in flight (incl. fg after bg)
        flat = _flatten(state)
        host = {k: np.asarray(jax.device_get(v)) for k, v in flat.items()}
        if background:
            self._thread = threading.Thread(
                target=self._write, args=(host, step), daemon=True)
            self._thread.start()
        else:
            self._write(host, step)

    def _write(self, host: dict, step: int):
        tmp = os.path.join(self.dir,
                           f"step_{step}.tmp{os.getpid()}.{id(host)}")
        final = os.path.join(self.dir, f"step_{step}")
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        np.savez(os.path.join(tmp, "arrays.npz"),
                 **{k.replace("/", "|"): v for k, v in host.items()})
        manifest = {
            "step": step,
            "keys": {k: {"shape": list(v.shape), "dtype": str(v.dtype)}
                     for k, v in host.items()},
        }
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        if os.path.exists(final):
            shutil.rmtree(tmp, ignore_errors=True)
        else:
            os.replace(tmp, final)
        self._gc()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self):
        steps = sorted(self.steps())
        for s in steps[: -self.keep]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s}"),
                          ignore_errors=True)

    # ---------------------------------------------------------- restore
    def steps(self) -> list[int]:
        out = []
        for d in os.listdir(self.dir):
            if d.startswith("step_") and ".tmp" not in d:
                try:
                    out.append(int(d.split("_")[1]))
                except ValueError:
                    pass
        return sorted(out)

    def latest(self) -> int | None:
        s = self.steps()
        return s[-1] if s else None

    def restore(self, step: int | None = None, shardings=None):
        """Load checkpoint; if ``shardings`` (a pytree of NamedSharding
        matching the state) is given, device_put each leaf accordingly —
        this is the elastic-rescale path."""
        step = step if step is not None else self.latest()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.dir}")
        path = os.path.join(self.dir, f"step_{step}")
        data = np.load(os.path.join(path, "arrays.npz"))
        flat = {k.replace("|", "/"): data[k] for k in data.files}
        tree = _unflatten(flat)
        if shardings is not None:
            flat_s = _flatten(shardings)
            tree = _unflatten({
                k: jax.device_put(v, flat_s[k]) if k in flat_s else v
                for k, v in _flatten(tree).items()})
        return tree, step
