"""Paper workload graphs + serving engine + roofline analysis unit
tests."""
import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.graphs import (WORKLOADS, alexnet_task, hydranet_task,
                          vision_mamba_task, vit_task)
from repro.models import init_model
from repro.serve import ServeEngine


def test_alexnet_structure():
    t = alexnet_task(batch=1)
    assert len(t) == 8
    # fully chained after the first conv (the paper's headline property)
    assert all(op.chained for op in t.ops[1:])
    # conv1 GEMM dims: 55*55 x (11*11*3) x 96
    assert (t.ops[0].M, t.ops[0].K, t.ops[0].N) == (3025, 363, 96)
    assert t.ops[-1].N == 1000


def test_vit_grouped_attention_breaks_chain():
    t = vit_task(batch=1)
    scores = [op for op in t.ops if "scores" in op.name]
    assert len(scores) == 12
    for op in scores:
        assert op.n_groups == 12      # heads → grouped GEMM
        assert not op.chained         # breaks redistribution (paper §7.1)
        assert op.sync                # softmax
    fc1 = [op for op in t.ops if "fc1" in op.name]
    assert all(op.chained for op in fc1)  # MLPs keep the chain


def test_batch_scales_m():
    t1, t4 = alexnet_task(1), alexnet_task(4)
    assert t4.ops[0].M == 4 * t1.ops[0].M
    assert t4.ops[0].K == t1.ops[0].K


def test_all_workloads_buildable():
    for name, fn in WORKLOADS.items():
        t = fn(batch=2)
        assert len(t) > 5
        assert t.total_flops > 0


def test_vim_and_hydranet_shapes():
    t = vision_mamba_task(batch=1)
    assert any("in_proj" in op.name for op in t.ops)
    h = hydranet_task(batch=1)
    heads = [op for op in h.ops if "det_" in op.name or "lane_" in op.name]
    assert len(heads) >= 4


# ---------------------------------------------------------------- serve
def test_serve_engine_generates():
    cfg = get_config("smollm-360m", reduced=True)
    params = init_model(cfg, jax.random.PRNGKey(0))
    eng = ServeEngine(cfg, params, batch_size=2, capacity=64)
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab_size, n).astype(np.int32)
               for n in (5, 9, 3)]
    outs = eng.generate(prompts, max_new_tokens=6)
    assert len(outs) == 3
    assert all(len(o) == 6 for o in outs)
    assert all(0 <= t < cfg.vocab_size for o in outs for t in o)


def test_serve_greedy_deterministic():
    cfg = get_config("smollm-360m", reduced=True)
    params = init_model(cfg, jax.random.PRNGKey(0))
    eng1 = ServeEngine(cfg, params, batch_size=2, capacity=64)
    eng2 = ServeEngine(cfg, params, batch_size=2, capacity=64)
    p = [np.arange(5, dtype=np.int32)]
    assert eng1.generate(p, 5) == eng2.generate(p, 5)


def test_serve_rejects_encoder():
    cfg = get_config("hubert-xlarge", reduced=True)
    params = init_model(cfg, jax.random.PRNGKey(0))
    with pytest.raises(ValueError):
        ServeEngine(cfg, params)


# -------------------------------------------------------------- roofline
def test_roofline_terms():
    from repro.roofline import analyze_record
    rec = {
        "arch": "smollm-360m", "shape": "train_4k",
        "mesh": "single_pod_16x16", "kind": "train", "n_devices": 256,
        "flops_per_device": 1e12, "bytes_per_device": 1e11,
        "collective_bytes_per_device": {"all-reduce": 5e9},
    }
    t = analyze_record(rec)
    assert t.compute_s == pytest.approx(1e12 / 197e12)
    # memory_s is the fusion-aware analytic estimate; the raw HLO byte
    # term is preserved separately as an upper bound
    assert t.hlo_bytes_s == pytest.approx(1e11 / 819e9)
    assert t.memory_s > 0
    assert t.collective_s == pytest.approx(5e9 / 50e9)
    assert t.dominant in ("memory", "collective")
    assert 0 < t.roofline_fraction < 1


def test_model_flops_train_vs_decode():
    from repro.roofline.analysis import model_flops_for
    tr = model_flops_for("smollm-360m", "train_4k")
    de = model_flops_for("smollm-360m", "decode_32k")
    assert tr > de * 1e4
    # MoE active < total
    moe = model_flops_for("mixtral-8x22b", "train_4k")
    dense_equiv = 6 * 141e9 * 4096 * 256
    assert moe < dense_equiv * 0.5
