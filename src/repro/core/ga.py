"""Genetic Algorithm scheduler — paper Sec. 6.2.

Genome (per candidate):
  * ``Px`` [n_ops, X], ``Py`` [n_ops, Y] — workload partitions, constrained
    to multiples of R (C) inside the Sec-6.2 window around uniform (±slack),
    with exact per-op sums.
  * ``collectors`` [n_ops] — collection-chiplet column for on-package
    redistribution (the second GA variable set named in the paper).
  * ``redist`` [n_ops] — whether to redistribute after op i (masked to
    semantically valid chain pairs).

Constraint-preserving operators:
  * crossover swaps whole per-op rows between parents (sums stay exact);
  * partition mutation moves one R-unit between two chiplet rows of the
    same op (sum invariant);
  * collector / redist mutations are uniform resamples.

Fitness is the vectorized evaluator over the whole population at once.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from .evaluator import EvalOptions, Evaluator
from .hw import HWConfig
from .workload import (Partition, Task, clamp_partition_to_domain,
                       partition_domain, uniform_partition)

__all__ = ["GAConfig", "GAResult", "run_ga"]


@dataclasses.dataclass(frozen=True)
class GAConfig:
    population: int = 96
    generations: int = 200
    elite: int = 4
    tournament: int = 3
    p_crossover: float = 0.85
    p_mutate_partition: float = 0.5
    p_mutate_collector: float = 0.2
    p_mutate_redist: float = 0.15
    slack: int = 2
    patience: int = 40          # early stop after this many flat generations
    seed: int = 0
    freeze_redist: bool = False  # force redistribution on all valid pairs
                                 # (TPU bridge: no shared-memory path exists)
    backend: str = "numpy"       # fitness backend: "numpy" | "jax"
                                 # (jit+vmap path, DESIGN.md §8; identical
                                 # trajectories under a fixed seed)


@dataclasses.dataclass
class GAResult:
    partition: Partition
    redist_mask: np.ndarray
    objective: float
    history: np.ndarray         # best objective per generation
    evaluations: int


def _random_population(rng, task, hw, cfg, pop):
    """Seed: uniform partition + random unit moves (keeps diversity while
    starting near the feasible center, as the paper's window implies)."""
    n = len(task)
    X, Y = hw.X, hw.Y
    base = uniform_partition(task, X, Y)
    base = clamp_partition_to_domain(base, task, X, Y, hw.R, hw.C, cfg.slack)
    Px = np.repeat(base.Px[None], pop, axis=0).astype(np.int64)
    Py = np.repeat(base.Py[None], pop, axis=0).astype(np.int64)
    lo, hi = partition_domain(task, X, Y, hw.R, hw.C, cfg.slack)
    # Random unit moves per candidate (individual 0 stays uniform — elitism
    # guarantees GA can never be worse than the LS baseline partition).
    for p in range(1, pop):
        for i in range(n):
            for _ in range(rng.integers(0, X + Y)):
                _move_unit(rng, Px[p, i], hw.R, lo[i, 0], hi[i, 0])
                _move_unit(rng, Py[p, i], hw.C, lo[i, 1], hi[i, 1])
    coll = rng.integers(0, Y, size=(pop, n))
    coll[0] = Y // 2
    if cfg.freeze_redist:
        redist = np.ones((pop, n), dtype=bool)
    else:
        redist = rng.random((pop, n)) < 0.5
        redist[0] = True
    return Px, Py, coll.astype(np.int64), redist


def _move_unit(rng, row: np.ndarray, unit: int, lo: int, hi: int) -> None:
    """Move one ``unit`` from a donor entry to a receiver, in place,
    respecting the window — sum-preserving mutation. Rejection-samples a
    few times rather than materializing candidate sets (hot path)."""
    n = len(row)
    if n < 2:
        return
    for _ in range(4):
        d = int(rng.integers(n))
        r = int(rng.integers(n))
        if d == r:
            continue
        if row[d] - unit >= lo * unit and row[r] + unit <= hi * unit:
            row[d] -= unit
            row[r] += unit
            return


def run_ga(
    task: Task,
    hw: HWConfig,
    objective: str = "latency",
    options: EvalOptions | None = None,
    cfg: GAConfig = GAConfig(),
    backend: str | None = None,
) -> GAResult:
    if options is None:
        options = EvalOptions(redistribution=True, async_exec=True)
    ev = Evaluator(task, hw, options, backend=backend or cfg.backend)
    rng = np.random.default_rng(cfg.seed)
    n = len(task)
    X, Y = hw.X, hw.Y
    pop = cfg.population
    lo, hi = partition_domain(task, X, Y, hw.R, hw.C, cfg.slack)

    Px, Py, coll, redist = _random_population(rng, task, hw, cfg, pop)
    n_eval = 0
    history = []
    best = None  # (obj, genome)
    flat = 0

    for gen in range(cfg.generations):
        fit = ev.objective_batch(
            Px.astype(np.float64), Py.astype(np.float64), coll,
            redist.astype(np.float64), objective)
        n_eval += pop
        order = np.argsort(fit)
        gen_best = float(fit[order[0]])
        if best is None or gen_best < best[0] * (1.0 - 1e-4):
            flat = 0
        else:
            flat += 1
        if best is None or gen_best < best[0]:
            best = (gen_best, (Px[order[0]].copy(), Py[order[0]].copy(),
                               coll[order[0]].copy(), redist[order[0]].copy()))
        history.append(best[0])
        if flat >= cfg.patience:
            break

        # ---------------------------------------------------- next epoch
        nPx = np.empty_like(Px)
        nPy = np.empty_like(Py)
        nco = np.empty_like(coll)
        nrd = np.empty_like(redist)
        # elites
        for e in range(cfg.elite):
            j = order[e]
            nPx[e], nPy[e], nco[e], nrd[e] = Px[j], Py[j], coll[j], redist[j]
        # offspring
        for p in range(cfg.elite, pop):
            a = _tournament(rng, fit, cfg.tournament)
            b = _tournament(rng, fit, cfg.tournament)
            cPx, cPy = Px[a].copy(), Py[a].copy()
            cco, crd = coll[a].copy(), redist[a].copy()
            if rng.random() < cfg.p_crossover:
                mask = rng.random(n) < 0.5   # per-op uniform crossover
                cPx[mask] = Px[b][mask]
                cPy[mask] = Py[b][mask]
                cco[mask] = coll[b][mask]
                crd[mask] = redist[b][mask]
            # mutations
            for i in range(n):
                if rng.random() < cfg.p_mutate_partition:
                    _move_unit(rng, cPx[i], hw.R, lo[i, 0], hi[i, 0])
                if rng.random() < cfg.p_mutate_partition:
                    _move_unit(rng, cPy[i], hw.C, lo[i, 1], hi[i, 1])
                if rng.random() < cfg.p_mutate_collector:
                    cco[i] = rng.integers(0, Y)
                if not cfg.freeze_redist and \
                        rng.random() < cfg.p_mutate_redist:
                    crd[i] = not crd[i]
            nPx[p], nPy[p], nco[p], nrd[p] = cPx, cPy, cco, crd
        Px, Py, coll, redist = nPx, nPy, nco, nrd

    obj, (bPx, bPy, bco, brd) = best
    part = Partition(bPx, bPy, bco)
    part.validate(task)
    return GAResult(
        partition=part,
        redist_mask=brd & ev.chain_valid,
        objective=obj,
        history=np.array(history),
        evaluations=n_eval,
    )


def _tournament(rng, fit: np.ndarray, k: int) -> int:
    idx = rng.integers(0, len(fit), size=k)
    return int(idx[np.argmin(fit[idx])])
