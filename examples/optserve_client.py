"""Optimization-server client walkthrough (DESIGN.md §14): submit mixed
co-optimization traffic — evaluations, a GA solve, pipelining — to an
in-process :class:`OptServer`, stream the futures back, then restart
against the same store to show warm-cache serving. Includes the asyncio
submission path.

    PYTHONPATH=src python examples/optserve_client.py
"""
import asyncio
import tempfile

from repro.core import EvalOptions, make_hw, sweep
from repro.core.ga import GAConfig
from repro.core.workload import uniform_partition
from repro.graphs import alexnet_task, vit_task
from repro.serve import OptRequest, OptServer


def build_requests():
    hw = make_hw("A", grid=4, memory="hbm")
    reqs = []
    for task in (alexnet_task(batch=1), vit_task(batch=1)):
        part = uniform_partition(task, hw.X, hw.Y)
        for cong in ("regime", "flow"):
            reqs.append(OptRequest(
                "eval",
                sweep.EvalPoint(task, hw, EvalOptions(congestion=cong),
                                part)))
    reqs.append(OptRequest(
        "solve", sweep.EvalPoint(alexnet_task(batch=1), hw),
        objective="latency", method="ga",
        cfg=GAConfig(generations=10, population=32, seed=0)))
    reqs.append(OptRequest(
        "pipeline",
        sweep.PipelinePoint([("conv", 0.4, 1.2, 0.4),
                             ("mlp", 0.2, 0.9, 0.3),
                             ("head", 0.1, 0.5, 0.2)], batch=8)))
    return reqs


def show(req, res):
    if req.kind == "eval":
        print(f"  eval     {req.point.task.name:<10} "
              f"congestion={req.point.options.congestion:<7} "
              f"latency={res['latency'] * 1e6:9.1f} us")
    elif req.kind == "solve":
        print(f"  solve/ga {req.point.task.name:<10} "
              f"objective={res.objective:.4e} "
              f"({res.evaluations} evaluations)")
    else:
        print(f"  pipeline batch={res.batch} "
              f"sequential={res.sequential:.2f} "
              f"pipelined={res.pipelined:.2f} "
              f"({res.sequential / res.pipelined:.2f}x)")


def main():
    store = tempfile.mktemp(suffix=".bin", prefix="optserve-cache-")
    reqs = build_requests()

    # ---- cold server: everything is computed, coalesced by CallKey
    srv = OptServer(store_path=store)
    futs = [srv.submit(r) for r in reqs]       # returns immediately
    for r, f in zip(reqs, futs):
        show(r, f.result())                    # stream results back
    st = srv.stats()
    print(f"cold:  {st['completed']} requests, "
          f"coalesce {st['coalesce_factor']:.1f}x over "
          f"{st['batches']} sweep calls, cache hit-rate "
          f"{st['cache_hit_rate'] * 100:.0f}%")
    srv.close()                                # full-save (atomic) store

    # ---- warm restart: same requests served from the persisted cache
    sweep.clear_cache()                        # simulate a new process
    srv = OptServer(store_path=store)
    print(f"store: restored {srv.store_info['loaded']} entries")

    async def client():
        outs = await asyncio.gather(
            *(srv.submit_async(r) for r in build_requests()))
        return outs

    asyncio.run(client())
    st = srv.stats()
    print(f"warm:  {st['completed']} requests, cache hit-rate "
          f"{st['cache_hit_rate'] * 100:.0f}%, p99 {st['p99_ms']:.1f}ms")
    srv.close()


if __name__ == "__main__":
    main()
