"""Fig. 13 reproduction: ablation of the co-design features.

Paper claims: partition-only optimization gives a relatively small
speedup; adding diagonal links unlocks most of the gain (bypassing
collection congestion + flattening memory-latency non-uniformity);
pipelining adds further latency gains on top.

Grid driving (benchmarks/README.md): LS references come from the batched
sweep; the (workload × ablation-variant) GA searches run island-batched
through ``sweep.solve_grid`` (plain-mesh and diagonal-link variants share
a shape signature, so both land in one compiled call per workload shape;
DESIGN.md §10); the same ablation grid is solved by the batched lattice
MIQP engine through ``sweep.solve_grid(method="miqp")`` (DESIGN.md §12 —
the same shape sharing applies); pipelining is layered on the
diagonal-link GA result through the batched ``sweep.pipeline_sweep``
(DESIGN.md §13).
"""
from __future__ import annotations

import time

from repro.core import EvalOptions, Evaluator, make_hw, refine_schedule, sweep
from repro.core.ga import GAConfig
from repro.core.miqp import MIQPConfig
from repro.core.sweep import PipelinePoint
from repro.graphs import WORKLOADS

from .common import emit, save_json

GA_CFG = GAConfig(generations=60, population=64)
MIQP_CFG = MIQPConfig()        # engine="auto" → batched lattice solves
MIQP_SOLVE_OPTS = EvalOptions(redistribution=True, async_exec=False)


def main(fast: bool = False, backend: str = "jax"):
    results = {}
    wnames = ("alexnet", "hydranet") if fast else ("alexnet", "vit",
                                                   "hydranet")
    tasks = {w: WORKLOADS[w](batch=1) for w in wnames}
    hw_plain = make_hw("A", 4, "hbm")
    hw_diag = make_hw("A", 4, "hbm", diagonal_links=True)
    opts = EvalOptions(redistribution=True, async_exec=True)

    base_recs = sweep.eval_sweep(
        [sweep.EvalPoint(tasks[w], hw_plain) for w in wnames],
        backend=backend)
    base = {w: r["latency"] for w, r in zip(wnames, base_recs)}

    # variant axis: partitioning only (plain mesh) vs + diagonal links —
    # same shapes, so the GA searches batch as islands per workload.
    variants = ("partition_only", "plus_diagonal")
    pts_grid = sweep.grid(wname=wnames, variant=variants)
    pts = [sweep.EvalPoint(
               tasks[p["wname"]],
               hw_plain if p["variant"] == "partition_only" else hw_diag,
               opts)
           for p in pts_grid]
    t0 = time.perf_counter()
    recs = sweep.solve_grid(pts, "latency", GA_CFG, backend=backend)
    us = (time.perf_counter() - t0) * 1e6
    # one batched solve call for the whole variant grid — the wall time
    # belongs to the call, not to any single point.
    emit("fig13/ga/solve_grid_total", us, f"{len(pts)} points")
    ga_out = {}
    for p, r in zip(pts_grid, recs):
        w, v = p["wname"], p["variant"]
        ga_out[(w, v)] = r
        emit(f"fig13/{w}/{v}", 0.0, f"{base[w] / r.objective:.3f}x")

    # ---- MIQP on the same ablation grid (DESIGN.md §12): batched
    # lattice solves (plain + diagonal variants share shape signatures,
    # exactly like the GA islands), then polish + one batched scoring
    # sweep — the optimize(method="miqp") pipeline.
    mi_pts = [sweep.EvalPoint(
                  tasks[p["wname"]],
                  hw_plain if p["variant"] == "partition_only" else hw_diag,
                  MIQP_SOLVE_OPTS)
              for p in pts_grid]
    t0 = time.perf_counter()
    mi_recs = sweep.solve_grid(mi_pts, "latency", MIQP_CFG,
                               backend=backend, method="miqp")
    us = (time.perf_counter() - t0) * 1e6
    emit("fig13/miqp/solve_grid_total", us, f"{len(mi_pts)} points")
    polished = [refine_schedule(pt.task, pt.hw, opts, r.partition,
                                r.redist_mask, "latency", backend=backend)
                for pt, r in zip(mi_pts, mi_recs)]
    mi_score = sweep.eval_sweep(
        [sweep.EvalPoint(pt.task, pt.hw, opts, partition=part,
                         redist_mask=rd)
         for pt, (part, rd) in zip(mi_pts, polished)],
        backend=backend)
    mi_out = {}
    for p, rec in zip(pts_grid, mi_score):
        w, v = p["wname"], p["variant"]
        mi_out[(w, v)] = base[w] / rec["latency"]
        emit(f"fig13/{w}/{v}/miqp", 0.0, f"{mi_out[(w, v)]:.3f}x")

    # Pipelining on top of the diagonal-link GA result: all workloads'
    # batch-4 instances through one batched pipeline_sweep (§13).
    segs = {}
    for wname in wnames:
        ga2 = ga_out[(wname, "plus_diagonal")]
        ev = Evaluator(tasks[wname], hw_diag, opts, backend=backend)
        segs[wname] = ev.evaluate(ga2.partition, ga2.redist_mask).segments()
    pipes = sweep.pipeline_sweep(
        [PipelinePoint(segs[w], 4) for w in wnames], backend=backend)
    for wname, pipe in zip(wnames, pipes):
        ga2 = ga_out[(wname, "plus_diagonal")]
        part_sp = base[wname] / ga_out[(wname, "partition_only")].objective
        diag_sp = base[wname] / ga2.objective
        pipe_sp = base[wname] / (pipe.pipelined / 4)
        results[wname] = {"partition": part_sp, "diag": diag_sp,
                          "pipe": pipe_sp,
                          "miqp_partition": mi_out[(wname,
                                                    "partition_only")],
                          "miqp_diag": mi_out[(wname, "plus_diagonal")]}
        emit(f"fig13/{wname}/plus_pipelining", 0.0, f"{pipe_sp:.3f}x")
    save_json("fig13", results)


if __name__ == "__main__":
    main()
