"""Vision Mamba (Vim-S) as a GEMM sequence.

Vim-S: 24 bidirectional SSM blocks, d_model=384, expand=2 (d_inner=768),
d_state=16, dt_rank=24. Projections are GEMMs; the selective scan itself
is a sequential SIMD-class op (folded as epilogue cycles on dt_proj, like
the paper folds softmax). Bidirectionality doubles the x/dt projections
(``weight_bytes_scale=2`` for the shared-weight double pass).

The paper notes Vim uses linear attention — like ViT, only the projection
chains (not the scan) benefit from redistribution.
"""
from __future__ import annotations

from ..core.workload import GemmOp, Task


def vision_mamba_task(batch: int = 1, *, depth: int = 24, d: int = 384,
                      expand: int = 2, d_state: int = 16, dt_rank: int = 24,
                      tokens: int = 197) -> Task:
    m = tokens * batch
    di = expand * d
    ops = [GemmOp("patch_embed", M=m, K=768, N=d)]
    for b in range(depth):
        p = f"blk{b}."
        ops.append(GemmOp(p + "in_proj", M=m, K=d, N=2 * di, chained=True,
                          sync=True))               # RMSNorm before
        # bidirectional x-projection (fwd+bwd share structure): B, C, dt
        ops.append(GemmOp(p + "x_proj", M=m, K=di,
                          N=dt_rank + 2 * d_state, chained=True,
                          weight_bytes_scale=2.0))
        # dt_proj + the selective scan as SIMD epilogue on its output
        ops.append(GemmOp(p + "dt_proj", M=m, K=dt_rank, N=di,
                          chained=True, weight_bytes_scale=2.0,
                          epilogue_flops_per_elem=9 * d_state // 8,
                          sync=True))               # scan = sequential
        ops.append(GemmOp(p + "out_proj", M=m, K=di, N=d, chained=True))
    ops.append(GemmOp("head", M=batch, K=d, N=1000))
    return Task(f"vim_s_b{batch}", ops)
