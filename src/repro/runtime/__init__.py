from .fault_tolerance import FaultTolerantLoop, StragglerMonitor  # noqa: F401
