"""Fig. 3 reproduction: DRAM vs HBM congestion and memory placement on a
4×4 mesh (flow-level simulator standing in for ASTRA-sim).

Grid driving (benchmarks/README.md): the (memory × placement × NoP-BW)
grid is a generic ``sweep.grid`` product whose cells all share the 4×4
link space, so the whole figure runs through ONE compiled call of the
batched netsim backend (``sweep.netsim_sweep`` →
``netsim_jax.simulate_pull_batch``, DESIGN.md §11) with records cached
process-wide — the same contract as the fig8/fig9 evaluator sweeps.
"""
from __future__ import annotations

import time

from repro.core import sweep
from repro.core.netsim import fig3_net

from .common import emit, save_json

GB = 1e9
MESSAGE = 1 * GB


def main(backend: str = "jax"):
    results = {}
    cases = sweep.grid(memory=("dram", "hbm"),
                       placement=("peripheral", "central"),
                       bw_nop=(60 * GB, 120 * GB))
    prev = sweep.cache_stats()
    nets = [fig3_net(p["memory"], p["placement"], p["bw_nop"])
            for p in cases]
    t0 = time.perf_counter()
    recs = sweep.netsim_sweep(nets, MESSAGE, backend=backend)
    us = (time.perf_counter() - t0) * 1e6
    emit("fig3/netsim_sweep_total", us,
         f"{len(cases)} cells, backend={backend}")
    for pt, rec in zip(cases, recs):
        key = f"{pt['memory']}_{pt['placement']}_nop{int(pt['bw_nop'] / GB)}"
        results[key] = rec["latency"]
        emit(f"fig3/{key}", 0.0, f"latency_ms={rec['latency']*1e3:.2f}")

    # headline claims
    nop_scale = results["hbm_peripheral_nop60"] / \
        results["hbm_peripheral_nop120"]
    dram_scale = results["dram_peripheral_nop60"] / \
        results["dram_peripheral_nop120"]
    placement = results["hbm_peripheral_nop60"] / \
        results["hbm_central_nop60"]
    emit("fig3/hbm_nop_scaling", 0.0,
         f"{nop_scale:.2f}x (paper: linear, 2.00x)")
    emit("fig3/dram_nop_scaling", 0.0,
         f"{dram_scale:.2f}x (paper: none, 1.00x)")
    emit("fig3/central_vs_peripheral", 0.0,
         f"{placement:.2f}x (paper: 1.53x)")
    cur = sweep.cache_stats()
    print(f"# fig3: sweep cache +{cur['hits'] - prev['hits']} hits "
          f"/ +{cur['misses'] - prev['misses']} misses")
    save_json("fig3", results)


if __name__ == "__main__":
    main()
