"""zamba2-2.7b [hybrid]: 54L d_model=2560 32H d_ff=10240 vocab=32000,
ssm_state=64 — Mamba2 backbone + shared attention block every 6 blocks
(concat(hidden, embed0), 2*d wide) [arXiv:2411.15242; hf].
Shared attention uses a 4096 sliding window so the 500k-context decode
state stays bounded (DESIGN.md §4; per-invocation LoRAs omitted)."""
from ..models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="zamba2-2.7b", family="hybrid",
        n_layers=54, d_model=2560, d_ff=10240, vocab_size=32000,
        n_heads=32, n_kv_heads=32, d_head=160,
        ssm_state=64, ssm_conv=4, ssm_expand=2, ssm_head_dim=64,
        hybrid_attn_period=6, window=4096,
        act="gelu", tie_embeddings=True,
    )


def reduced_config() -> ModelConfig:
    return config().replace(
        name="zamba2-smoke", n_layers=4, d_model=48, d_ff=96,
        vocab_size=256, n_heads=4, n_kv_heads=4, d_head=24,
        ssm_state=8, ssm_head_dim=16, hybrid_attn_period=2, window=32,
        attn_chunk=32, ssm_chunk=16, remat=False)
