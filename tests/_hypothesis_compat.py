"""Optional-`hypothesis` shim for property-based tests.

``hypothesis`` is a dev-only dependency (requirements-dev.txt). When it is
absent, the property-based tests are skipped instead of breaking collection
of the whole module: ``given`` becomes a skip-marking decorator, ``settings``
a no-op, and ``st`` a stub whose strategy constructors return ``None`` so
module-level strategy expressions still evaluate.

Usage (instead of ``from hypothesis import ...``)::

    from _hypothesis_compat import given, settings, st
"""
import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - exercised when hypothesis absent
    HAVE_HYPOTHESIS = False

    def given(*_a, **_kw):
        def deco(fn):
            return pytest.mark.skip(reason="hypothesis not installed")(fn)
        return deco

    def settings(*_a, **_kw):
        def deco(fn):
            return fn
        return deco

    class _St:
        """Stand-in for ``hypothesis.strategies``: any attribute is a
        callable returning ``None`` (never executed — tests are skipped)."""

        def __getattr__(self, name):
            return lambda *a, **k: None

    st = _St()

__all__ = ["given", "settings", "st", "HAVE_HYPOTHESIS"]
