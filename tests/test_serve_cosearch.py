"""Serving the fused co-search (DESIGN.md §14 × §16): solo == served
bitwise through the coalescing ``OptServer``, CallKey grouping for
``method="cosearch"``, and the BadRequest firewall for malformed
multi-objective requests."""
import dataclasses

import numpy as np
import pytest

from repro.core import CoSearchConfig, EvalOptions, Task, make_hw, sweep
from repro.core.ga import GAConfig
from repro.graphs import WORKLOADS
from repro.serve import BadRequest, OptRequest, OptServer
from repro.serve.coalesce import group_requests

HW = make_hw("A", 2, "hbm")
OPTS = EvalOptions(redistribution=True, async_exec=True)
CFG = CoSearchConfig(population=16, generations=10, patience=10,
                     batch=3, seed=0, seed_steps=4, seed_starts=2,
                     archive_size=8)


def _task(name="alex4", lo=0, hi=4):
    full = WORKLOADS["alexnet"](batch=1)
    ops = list(full.ops[lo:hi])
    ops[0] = dataclasses.replace(ops[0], chained=False)
    return Task(name, ops)


@pytest.fixture(autouse=True)
def _fresh_cache():
    sweep.clear_cache()
    yield
    sweep.clear_cache()


def test_solo_equals_served_bitwise():
    tasks = [_task("alex4a", 0, 4), _task("alex4b", 1, 5)]
    pts = [sweep.EvalPoint(t, HW, OPTS) for t in tasks]
    solo = [sweep.cosearch_sweep([p], "edp", CFG, cache=False)[0]
            for p in pts]
    sweep.clear_cache()
    reqs = [OptRequest(kind="solve", point=p, method="cosearch",
                       objective="edp", cfg=CFG, backend="jax")
            for p in pts]
    # both requests share one CallKey → ONE coalesced sweep call
    assert len(group_requests(reqs)) == 1
    srv = OptServer(autostart=False)
    futs = [srv.submit(r) for r in reqs]
    srv.start()
    recs = [f.result(timeout=300) for f in futs]
    srv.kill()
    for s, r in zip(solo, recs):
        assert s.objective == r.objective
        assert s.diagonal == r.diagonal
        np.testing.assert_array_equal(s.partition.Px, r.partition.Px)
        np.testing.assert_array_equal(s.partition.Py, r.partition.Py)
        np.testing.assert_array_equal(s.seg_mask, r.seg_mask)
        for k in s.front:
            np.testing.assert_array_equal(s.front[k], r.front[k])


def test_callkey_separates_objectives_and_cfgs():
    p = sweep.EvalPoint(_task(), HW, OPTS)
    r1 = OptRequest(kind="solve", point=p, method="cosearch",
                    objective="edp", cfg=CFG)
    r2 = OptRequest(kind="solve", point=p, method="cosearch",
                    objective="latency", cfg=CFG)
    r3 = OptRequest(kind="solve", point=p, method="cosearch",
                    objective="edp",
                    cfg=dataclasses.replace(CFG, population=32))
    assert len(group_requests([r1, r2, r3])) == 3


@pytest.mark.parametrize("kw,msg", [
    (dict(backend="numpy"), "backend"),
    (dict(cfg=GAConfig()), "CoSearchConfig"),
    (dict(objective="throughput"), "objective"),
    (dict(method="anneal"), "method"),
])
def test_bad_requests_rejected(kw, msg):
    base = dict(kind="solve", point=sweep.EvalPoint(_task(), HW, OPTS),
                method="cosearch", objective="edp", cfg=CFG,
                backend="jax")
    base.update(kw)
    with pytest.raises(BadRequest, match=msg):
        OptRequest(**base).validate()


def test_bad_request_isolated_from_cohort():
    """A malformed co-search request is rejected per-request; the valid
    request in the same submission batch still serves."""
    good = OptRequest(kind="solve",
                      point=sweep.EvalPoint(_task(), HW, OPTS),
                      method="cosearch", objective="edp", cfg=CFG)
    bad = OptRequest(kind="solve",
                     point=sweep.EvalPoint(_task(), HW, OPTS),
                     method="cosearch", objective="edp", cfg=CFG,
                     backend="numpy")
    srv = OptServer(autostart=False)
    fg, fb = srv.submit(good), srv.submit(bad)
    srv.start()
    r = fg.result(timeout=300)
    assert np.isfinite(r.objective)
    with pytest.raises(BadRequest):
        fb.result(timeout=300)
    st = srv.stats()
    srv.kill()
    assert st["completed"] == 1 and st["rejected"] == 1
