"""End-to-end evaluator invariants (paper Sec. 4.2.4–4.4, 5.1–5.3)."""
import numpy as np
import pytest

from _hypothesis_compat import given, settings, st

from repro.core import (EvalOptions, Evaluator, GemmOp, Task, make_hw,
                        uniform_partition)
from repro.core.workload import Partition, clamp_partition_to_domain


def toy_task(n=3, chained=True):
    ops = [GemmOp("g0", M=512, K=256, N=512)]
    for i in range(1, n):
        ops.append(GemmOp(f"g{i}", M=512, K=ops[-1].N, N=512,
                          chained=chained))
    return Task("toy", ops)


def test_partition_validation():
    task = toy_task()
    part = uniform_partition(task, 4, 4)
    part.validate(task)
    bad = part.copy()
    bad.Px[0, 0] += 1
    with pytest.raises(ValueError):
        bad.validate(task)


def test_latency_positive_all_types():
    task = toy_task()
    for t in "ABCD":
        for mem in ("hbm", "dram"):
            hw = make_hw(t, 4, mem)
            r = Evaluator(task, hw, EvalOptions()).evaluate(
                uniform_partition(task, 4, 4))
            assert r.latency > 0 and r.energy > 0 and r.edp > 0


def test_redistribution_helps_chained():
    task = toy_task(chained=True)
    hw = make_hw("A", 4, "hbm")
    base = Evaluator(task, hw, EvalOptions()).evaluate(
        uniform_partition(task, 4, 4))
    red = Evaluator(task, hw, EvalOptions(redistribution=True)).evaluate(
        uniform_partition(task, 4, 4))
    assert red.latency <= base.latency


def test_redistribution_noop_unchained():
    task = toy_task(chained=False)
    hw = make_hw("A", 4, "hbm")
    a = Evaluator(task, hw, EvalOptions()).evaluate(
        uniform_partition(task, 4, 4))
    b = Evaluator(task, hw, EvalOptions(redistribution=True)).evaluate(
        uniform_partition(task, 4, 4))
    assert a.latency == pytest.approx(b.latency)


def test_async_never_hurts():
    task = toy_task()
    hw = make_hw("A", 4, "hbm")
    part = uniform_partition(task, 4, 4)
    sync = Evaluator(task, hw, EvalOptions()).evaluate(part)
    fused = Evaluator(task, hw, EvalOptions(async_exec=True)).evaluate(part)
    assert fused.latency <= sync.latency + 1e-12


def test_diagonal_links_never_hurt():
    task = toy_task()
    part = uniform_partition(task, 4, 4)
    plain = Evaluator(task, make_hw("A", 4), EvalOptions()).evaluate(part)
    diag = Evaluator(task, make_hw("A", 4, diagonal_links=True),
                     EvalOptions()).evaluate(part)
    assert diag.latency <= plain.latency + 1e-12


def test_memory_bw_monotonicity():
    """More off-chip bandwidth can only help."""
    task = toy_task()
    part = uniform_partition(task, 4, 4)
    lat = []
    for bw in (30e9, 60e9, 240e9, 1000e9):
        hw = make_hw("A", 4).replace(bw_mem=bw)
        lat.append(Evaluator(task, hw, EvalOptions()).evaluate(part).latency)
    assert all(a >= b - 1e-15 for a, b in zip(lat, lat[1:]))


def test_batch_eval_matches_single():
    task = toy_task()
    hw = make_hw("B", 4, "hbm")
    ev = Evaluator(task, hw, EvalOptions(redistribution=True))
    rng = np.random.default_rng(0)
    parts = []
    for _ in range(5):
        p = uniform_partition(task, 4, 4)
        p.collectors = rng.integers(0, 4, len(task))
        parts.append(p)
    Px = np.stack([p.Px for p in parts]).astype(float)
    Py = np.stack([p.Py for p in parts]).astype(float)
    co = np.stack([p.collectors for p in parts])
    rd = np.ones((5, len(task)))
    batch = ev.evaluate_batch(Px, Py, co, rd)
    for i, p in enumerate(parts):
        single = ev.evaluate(p, redist_mask=np.ones(len(task), bool))
        assert batch["latency"][i] == pytest.approx(single.latency)
        assert batch["energy"][i] == pytest.approx(single.energy)


def test_energy_modes():
    task = toy_task()
    hw = make_hw("A", 4)
    part = uniform_partition(task, 4, 4)
    paper = Evaluator(task, hw, EvalOptions(energy_mode="paper")
                      ).evaluate(part)
    per = Evaluator(task, hw, EvalOptions(energy_mode="per_chiplet")
                    ).evaluate(part)
    # paper mode charges max-cycles on every chiplet -> upper bound
    assert paper.energy >= per.energy - 1e-15


@settings(max_examples=30, deadline=None)
@given(m=st.integers(64, 4096), k=st.integers(16, 2048),
       n=st.integers(64, 4096), t=st.sampled_from(["A", "B", "C", "D"]))
def test_single_gemm_properties(m, k, n, t):
    task = Task("one", [GemmOp("g", M=m, K=k, N=n)])
    hw = make_hw(t, 4)
    r = Evaluator(task, hw, EvalOptions()).evaluate(
        uniform_partition(task, 4, 4))
    assert np.isfinite(r.latency) and r.latency > 0
    assert np.isfinite(r.energy) and r.energy > 0


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 10_000))
def test_clamp_to_domain_feasible(seed):
    rng = np.random.default_rng(seed)
    task = toy_task(4)
    part = uniform_partition(task, 4, 4)
    part.Px = part.Px + rng.integers(-64, 64, part.Px.shape)
    part.Px = np.maximum(part.Px, 0)
    for i, op in enumerate(task.ops):
        d = op.M - part.Px[i].sum()
        part.Px[i, 0] += d
        part.Px[i] = np.maximum(part.Px[i], 0)
        part.Px[i, np.argmax(part.Px[i])] += op.M - part.Px[i].sum()
    fixed = clamp_partition_to_domain(part, task, 4, 4, 16, 16)
    fixed.validate(task)
