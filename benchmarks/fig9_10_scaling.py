"""Fig. 9/10 reproduction: latency and EDP scaling on type-A systems of
4×4 / 8×8 / 16×16 chiplets.

Paper claims: MIQP geo-mean 55.5% (latency) / 60.3% (EDP) over LS; GA
24.2% / 35.1%. MIQP > GA, with AlexNet gaining more on larger systems
(redistribution savings grow with scale); GA is relatively stronger on
EDP than latency.
"""
from __future__ import annotations

from repro.core import make_hw, optimize
from repro.core.ga import GAConfig
from repro.core.miqp import MIQPConfig
from repro.graphs import WORKLOADS

from .common import emit, geomean, save_json, timed

GA_CFG = GAConfig(generations=60, population=64)
MIQP_CFG = MIQPConfig(time_limit=60, edp_sweep=3)


def main(fast: bool = False):
    grids = (4, 8) if fast else (4, 8, 16)
    wnames = ("alexnet", "hydranet") if fast else tuple(WORKLOADS)
    results = {}
    for objective in ("latency", "edp"):
        fig = "fig9" if objective == "latency" else "fig10"
        sp_all = {"ga": [], "miqp": []}
        for grid in grids:
            hw = make_hw("A", grid, "hbm")
            for wname in wnames:
                task = WORKLOADS[wname](batch=1)
                base = optimize(task, hw, "baseline")
                ref = (base.baseline.latency if objective == "latency"
                       else base.baseline.edp)
                for method, kw in (("ga", {"ga_config": GA_CFG}),
                                   ("miqp", {"miqp_config": MIQP_CFG})):
                    r, us = timed(optimize, task, hw, method, objective,
                                  **kw)
                    val = r.latency if objective == "latency" else r.edp
                    sp = ref / val
                    sp_all[method].append(sp)
                    results[f"{fig}/{grid}/{wname}/{method}"] = sp
                    emit(f"{fig}/{grid}x{grid}/{wname}/{method}", us,
                         f"speedup={sp:.3f}x")
        for m in sp_all:
            emit(f"{fig}/geomean/{m}", 0.0,
                 f"{(geomean(sp_all[m]) - 1) * 100:+.1f}% vs LS "
                 f"(paper: GA +24.2/35.1%, MIQP +55.5/60.3%)")
    save_json("fig9_10", results)


if __name__ == "__main__":
    main()
