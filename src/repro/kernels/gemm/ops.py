"""jit'd public wrapper: Pallas on TPU, jnp elsewhere."""
from __future__ import annotations

import jax

from .kernel import matmul as matmul_pallas
from .ref import matmul_ref


def matmul(a, b, *, use_pallas: bool | None = None, interpret: bool = False):
    if use_pallas is None:
        use_pallas = jax.default_backend() == "tpu"
    if use_pallas or interpret:
        return matmul_pallas(a, b, interpret=interpret
                             or jax.default_backend() != "tpu")
    return matmul_ref(a, b)
