"""MCMComm as a TPU layout planner: treat a 16x16 pod as the paper's MCM
grid and use the analytical framework to score layout choices for the
assigned architectures (DESIGN.md §3 bridge).

    PYTHONPATH=src python examples/mcm_plan_tpu.py
"""
from repro.configs import ARCHS, get_config
from repro.sharding.mcm_planner import plan


def main():
    print(f"{'arch':<18} {'base_ms':>9} {'opt_ms':>9} {'overlap':>8} "
          f"{'nonuniform_headroom':>20}")
    for arch in ARCHS:
        cfg = get_config(arch)
        if cfg.is_encoder:
            seq, batch = 4096, 256
        else:
            seq, batch = 4096, 256
        r = plan(cfg, (16, 16), seq, batch, layers=2, ga_budget=10)
        print(f"{arch:<18} {r.baseline_latency*1e3:>9.3f} "
              f"{r.optimized_latency*1e3:>9.3f} "
              f"{r.modeled_speedup:>7.2f}x "
              f"{r.nonuniform_headroom:>19.2f}x")
    print("\n(headroom = extra gain from non-uniform partitions the")
    print(" paper's GA finds but equal-shard SPMD cannot realize)")


if __name__ == "__main__":
    main()
