"""Model assembly for all assigned families.

Functional style: ``init_model(cfg, key) → params`` (nested dicts of
arrays, layers *stacked* on a leading axis) and
``forward(params, cfg, batch, mode, caches, pos) → (logits, caches, aux)``.

Layer stacks run under ``jax.lax.scan`` (compact HLO at 48–62 layers, which
is what makes the 512-device dry-run compile tractable) with optional
``jax.checkpoint`` remat. Families:

  dense / vlm / audio : [attn | MLA] + MLP blocks (gemma2 alternates
                        local/global pairs inside one scan step)
  moe                 : attn + top-k MoE (optional leading dense layers)
  hybrid (zamba2)     : Mamba-2 backbone; one *shared-weight* attention
                        block (on concat(hidden, embed₀)) every k blocks
  ssm (rwkv6)         : RWKV-6 time-mix + channel-mix blocks
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from ..sharding.logical import shard
from .config import ModelConfig
from .layers.attention import attn_apply, init_attn, init_cache
from .layers.common import dense_init, rms_norm, softcap
from .layers.mamba2 import init_mamba2, init_mamba_state, mamba2_apply
from .layers.mla import init_mla, init_mla_cache, mla_apply
from .layers.mlp import init_mlp, init_moe, mlp_apply, moe_apply
from .layers.rwkv6 import (init_rwkv6, init_rwkv_state, rwkv6_channel_mix,
                           rwkv6_time_mix)

# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------


def _init_block(key, cfg: ModelConfig, moe: bool, dtype):
    ks = jax.random.split(key, 4)
    p = {"ln1": jnp.zeros((cfg.d_model,), dtype),
         "ln2": jnp.zeros((cfg.d_model,), dtype)}
    if cfg.attn_type == "mla":
        p["attn"] = init_mla(ks[0], cfg, dtype)
    else:
        p["attn"] = init_attn(ks[0], cfg, dtype=dtype)
    if moe:
        p["moe"] = init_moe(ks[1], cfg, dtype)
    else:
        p["mlp"] = init_mlp(ks[1], cfg.d_model, cfg.d_ff, cfg.d_model,
                            dtype)
    if cfg.local_global_period:   # gemma2 post-norms
        p["ln1b"] = jnp.zeros((cfg.d_model,), dtype)
        p["ln2b"] = jnp.zeros((cfg.d_model,), dtype)
    return p


def _init_shared_attn(key, cfg: ModelConfig, dtype):
    """Zamba2 shared block operating on concat(hidden, embed0) = 2·D."""
    D2 = 2 * cfg.d_model
    ks = jax.random.split(key, 4)
    sub = cfg.replace(d_head=D2 // cfg.n_heads)
    return {
        "ln1": jnp.zeros((D2,), dtype),
        "attn": init_attn(ks[0], sub, d_in=D2, d_out=D2, dtype=dtype),
        "ln2": jnp.zeros((D2,), dtype),
        "mlp": init_mlp(ks[1], D2, cfg.d_ff, D2, dtype),
        "down": dense_init(ks[2], (D2, cfg.d_model), D2, dtype),
    }


def init_model(cfg: ModelConfig, key) -> dict:
    dtype = jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(key, 8)
    Vp, D = cfg.padded_vocab, cfg.d_model
    params: dict = {
        "embed": dense_init(ks[0], (Vp, D), D, dtype) * D ** 0.5,
        "final_norm": jnp.zeros((D,), dtype),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = dense_init(ks[1], (D, Vp), D, dtype)
    if cfg.frontend == "vision_stub":
        params["patch_proj"] = dense_init(ks[2], (cfg.frontend_dim, D),
                                          cfg.frontend_dim, dtype)
    if cfg.frontend == "audio_stub":
        params["frame_proj"] = dense_init(ks[2], (cfg.frontend_dim, D),
                                          cfg.frontend_dim, dtype)
        params["mask_emb"] = dense_init(ks[3], (D,), D, dtype)

    fam = cfg.family
    if fam in ("dense", "vlm", "audio"):
        per = max(cfg.local_global_period, 1)
        n_steps = cfg.n_layers // per
        keys = jax.random.split(ks[4], n_steps)
        if cfg.local_global_period:
            init_one = lambda k: {
                "local": _init_block(jax.random.fold_in(k, 0), cfg, False,
                                     dtype),
                "global": _init_block(jax.random.fold_in(k, 1), cfg, False,
                                      dtype)}
        else:
            init_one = lambda k: _init_block(k, cfg, False, dtype)
        params["blocks"] = jax.vmap(init_one)(keys)
    elif fam == "moe":
        nd = cfg.first_dense_layers
        if nd:
            dk = jax.random.split(ks[5], nd)
            params["dense_blocks"] = jax.vmap(
                lambda k: _init_block(k, cfg.replace(moe_d_ff=0), False,
                                      dtype))(dk)
        keys = jax.random.split(ks[4], cfg.n_layers - nd)
        params["blocks"] = jax.vmap(
            lambda k: _init_block(k, cfg, True, dtype))(keys)
    elif fam == "hybrid":
        keys = jax.random.split(ks[4], cfg.n_layers)
        mb = jax.vmap(lambda k: {
            "ln": jnp.zeros((D,), dtype),
            "mamba": init_mamba2(k, cfg, dtype)})(keys)
        per = cfg.hybrid_attn_period
        n_groups = cfg.n_layers // per
        params["blocks"] = jax.tree.map(
            lambda a: a.reshape((n_groups, per) + a.shape[1:]), mb)
        params["shared_attn"] = _init_shared_attn(ks[5], cfg, dtype)
    elif fam == "ssm":
        keys = jax.random.split(ks[4], cfg.n_layers)
        params["blocks"] = jax.vmap(lambda k: {
            "ln1": jnp.zeros((D,), dtype),
            "time": init_rwkv6(k, cfg, dtype),
            "ln2": jnp.zeros((D,), dtype)})(keys)
    else:
        raise ValueError(fam)
    return params


# ---------------------------------------------------------------------------
# caches
# ---------------------------------------------------------------------------
def init_caches(cfg: ModelConfig, batch: int, capacity: int,
                dtype=jnp.bfloat16) -> dict:
    """Serving state for the whole model (stacked along the scan axis)."""
    fam = cfg.family

    def stack(n, one):
        return jax.tree.map(lambda x: jnp.broadcast_to(
            x, (n,) + x.shape), one)

    if fam in ("dense", "vlm", "audio", "moe"):
        if cfg.is_encoder:
            return {}
        if cfg.attn_type == "mla":
            one = init_mla_cache(cfg, batch, capacity, dtype)
        else:
            cap = capacity if cfg.window is None else min(capacity,
                                                          cfg.window)
            if cfg.local_global_period:
                one = {
                    "local": init_cache(cfg, batch,
                                        min(capacity, cfg.window), dtype),
                    "global": init_cache(cfg, batch, capacity, dtype)}
                return {"layers": stack(
                    cfg.n_layers // cfg.local_global_period, one)}
            one = init_cache(cfg, batch, cap, dtype)
        n = cfg.n_layers - cfg.first_dense_layers
        out = {"layers": stack(n, one)}
        if cfg.first_dense_layers:
            out["dense_layers"] = stack(cfg.first_dense_layers, one)
        return out
    if fam == "hybrid":
        per = cfg.hybrid_attn_period
        n_groups = cfg.n_layers // per
        mstate = init_mamba_state(cfg, batch, dtype)
        # shared attention runs at width 2D with its own window-capped cache
        sub = cfg.replace(d_head=2 * cfg.d_model // cfg.n_heads)
        acap = min(capacity, cfg.window or capacity)
        acache = init_cache(sub, batch, acap, dtype)
        return {"mamba": stack(n_groups, stack(per, mstate)),
                "shared": stack(n_groups, acache)}
    if fam == "ssm":
        return {"layers": stack(cfg.n_layers,
                                init_rwkv_state(cfg, batch, dtype))}
    raise ValueError(fam)


# ---------------------------------------------------------------------------
# forward
# ---------------------------------------------------------------------------
def _block_apply(p, x, cfg, *, positions, window, cache, pos, mode, dtype,
                 moe: bool):
    h = rms_norm(x, p["ln1"], cfg.norm_eps, plus_one=True)
    if cfg.attn_type == "mla":
        a, new_cache = mla_apply(p["attn"], h, cfg, positions=positions,
                                 cache=cache, pos=pos, mode=mode,
                                 dtype=dtype)
    else:
        a, new_cache = attn_apply(p["attn"], h, cfg, positions=positions,
                                  window=window, cache=cache, pos=pos,
                                  mode=mode, causal=not cfg.is_encoder,
                                  dtype=dtype)
    if "ln1b" in p:
        a = rms_norm(a, p["ln1b"], cfg.norm_eps, plus_one=True)
    x = x + a
    h = rms_norm(x, p["ln2"], cfg.norm_eps, plus_one=True)
    aux = 0.0
    if moe:
        m, aux = moe_apply(p["moe"], h, cfg, dtype=dtype)
    else:
        m = mlp_apply(p["mlp"], h, cfg.act, dtype=dtype)
    if "ln2b" in p:
        m = rms_norm(m, p["ln2b"], cfg.norm_eps, plus_one=True)
    return x + m, new_cache, aux


def _scan_blocks(body, x0, stacked_params, stacked_caches, cfg, mode):
    """Scan ``body`` over the stacked layer axis, threading caches.

    ``cfg.scan_layers=False`` unrolls to a python loop — used by the
    roofline calibration lowers (XLA's cost analysis counts while-loop
    bodies once, so scanned graphs under-report FLOPs by the trip count).
    """
    use_cache = stacked_caches is not None

    if not cfg.scan_layers:
        n = jax.tree.leaves(stacked_params)[0].shape[0]
        aux = jnp.zeros((), jnp.float32)
        new_cs = []
        x = x0
        for i in range(n):
            bp = jax.tree.map(lambda a: a[i], stacked_params)
            bc = None if not use_cache else jax.tree.map(
                lambda a: a[i], stacked_caches)
            x, nc, a = body(bp, x, bc)
            aux = aux + a
            new_cs.append(nc)
        stacked = None
        if use_cache:
            stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *new_cs)
        return x, stacked, aux

    def step(carry, xs):
        if use_cache:
            bp, bc = xs
        else:
            bp, bc = xs, None
        x, aux = carry
        x, new_c, a = body(bp, x, bc)
        return (x, aux + a), new_c

    if cfg.remat and mode == "train":
        step = jax.checkpoint(step,
                              policy=jax.checkpoint_policies.nothing_saveable)
    xs = (stacked_params, stacked_caches) if use_cache else stacked_params
    aux0 = jnp.zeros((), jnp.float32)
    (x, aux), new_caches = jax.lax.scan(step, (x0, aux0), xs)
    return x, (new_caches if use_cache else None), aux


def embed_inputs(params, cfg: ModelConfig, batch: dict, mode: str,
                 pos, dtype):
    """Token/frontend embedding → (x (B,S,D), positions, loss_mask)."""
    if cfg.frontend == "audio_stub":
        x = jnp.einsum("btf,fd->btd", batch["frames"].astype(dtype),
                       params["frame_proj"].astype(dtype))
        if "mask" in batch:
            m = batch["mask"][..., None]
            x = jnp.where(m, params["mask_emb"].astype(dtype)[None, None],
                          x)
        B, S = x.shape[:2]
        positions = jnp.arange(S)[None, :]
        return shard(x, "act_btd"), positions, None
    tok = batch["tokens"]
    B, S = tok.shape
    x = jnp.take(params["embed"], tok, axis=0).astype(dtype)
    if cfg.emb_scale_by_sqrt_dim:
        x = x * jnp.asarray(cfg.d_model ** 0.5, dtype)
    loss_mask = None
    if cfg.frontend == "vision_stub" and "patches" in batch:
        pe = jnp.einsum("bnf,fd->bnd", batch["patches"].astype(dtype),
                        params["patch_proj"].astype(dtype))
        x = jnp.concatenate([pe, x], axis=1)
        Np = pe.shape[1]
        loss_mask = jnp.concatenate(
            [jnp.zeros((B, Np), bool), jnp.ones((B, S), bool)], axis=1)
        S = S + Np
    if mode == "decode":
        positions = jnp.full((B, 1), pos, dtype=jnp.int32)
    else:
        positions = jnp.arange(S)[None, :]
    return shard(x, "act_btd"), positions, loss_mask


def forward(params, cfg: ModelConfig, batch: dict, *, mode: str = "train",
            caches: dict | None = None, pos=None,
            output: str = "logits"):
    """Returns (logits, new_caches, (aux, loss_mask)).

    ``output="hidden"`` returns ((hidden, head), ...) instead — the fused
    training-loss path that never materializes (B, S, vocab) logits."""
    dtype = jnp.dtype(cfg.dtype)
    x, positions, loss_mask = embed_inputs(params, cfg, batch, mode, pos,
                                           dtype)
    fam = cfg.family
    aux = 0.0

    if fam in ("dense", "vlm", "audio"):
        if cfg.local_global_period:
            def body(bp, x, bc):
                x, c1, a1 = _block_apply(
                    bp["local"], x, cfg, positions=positions,
                    window=cfg.window,
                    cache=None if bc is None else bc["local"], pos=pos,
                    mode=mode, dtype=dtype, moe=False)
                x, c2, a2 = _block_apply(
                    bp["global"], x, cfg, positions=positions, window=None,
                    cache=None if bc is None else bc["global"], pos=pos,
                    mode=mode, dtype=dtype, moe=False)
                cc = None if bc is None else {"local": c1, "global": c2}
                return x, cc, a1 + a2
        else:
            def body(bp, x, bc):
                return _block_apply(bp, x, cfg, positions=positions,
                                    window=cfg.window, cache=bc, pos=pos,
                                    mode=mode, dtype=dtype, moe=False)
        lc = None if caches in (None, {}) else caches["layers"]
        x, new_l, aux = _scan_blocks(body, x, params["blocks"], lc, cfg,
                                     mode)
        new_caches = None if lc is None else {"layers": new_l}
    elif fam == "moe":
        new_caches = {}
        if cfg.first_dense_layers:
            def dbody(bp, x, bc):
                return _block_apply(bp, x, cfg, positions=positions,
                                    window=cfg.window, cache=bc, pos=pos,
                                    mode=mode, dtype=dtype, moe=False)
            dc = None if caches in (None, {}) else caches["dense_layers"]
            x, new_d, a = _scan_blocks(dbody, x, params["dense_blocks"],
                                       dc, cfg, mode)
            aux += a
            if new_d is not None:
                new_caches["dense_layers"] = new_d

        def body(bp, x, bc):
            return _block_apply(bp, x, cfg, positions=positions,
                                window=cfg.window, cache=bc, pos=pos,
                                mode=mode, dtype=dtype, moe=True)
        lc = None if caches in (None, {}) else caches["layers"]
        x, new_l, a = _scan_blocks(body, x, params["blocks"], lc, cfg,
                                   mode)
        aux += a
        if new_l is not None:
            new_caches["layers"] = new_l
        new_caches = new_caches or None
    elif fam == "hybrid":
        embed0 = x
        shared = params["shared_attn"]
        sub = cfg.replace(d_head=2 * cfg.d_model // cfg.n_heads)

        def mamba_body(bp, x, bc):
            h = rms_norm(x, bp["ln"], cfg.norm_eps, plus_one=True)
            o, st = mamba2_apply(bp["mamba"], h, cfg, state=bc, mode=mode,
                                 dtype=dtype)
            return x + o, st, 0.0

        def group_body(gp, x, gc):
            mstack = gp
            mc = None if gc is None else gc["m"]
            x, new_m, _ = _scan_blocks(mamba_body, x, mstack, mc, cfg,
                                       mode)
            # shared attention block on concat(hidden, embed0)
            xc = jnp.concatenate([x, embed0], axis=-1)
            h = rms_norm(xc, shared["ln1"], cfg.norm_eps, plus_one=True)
            a, new_ac = attn_apply(
                shared["attn"], h, sub, positions=positions,
                window=cfg.window,
                cache=None if gc is None else gc["a"], pos=pos, mode=mode,
                dtype=dtype)
            xc2 = xc + a
            h2 = rms_norm(xc2, shared["ln2"], cfg.norm_eps, plus_one=True)
            m = mlp_apply(shared["mlp"], h2, cfg.act, dtype=dtype)
            xc2 = xc2 + m
            x = x + jnp.einsum("bse,ed->bsd", xc2.astype(dtype),
                               shared["down"].astype(dtype))
            cc = None if gc is None else {"m": new_m, "a": new_ac}
            return x, cc, 0.0

        gc = None if caches in (None, {}) else {"m": caches["mamba"],
                                                "a": caches["shared"]}
        x, new_g, _ = _scan_blocks(group_body, x, params["blocks"], gc,
                                   cfg, mode)
        new_caches = None if new_g is None else {"mamba": new_g["m"],
                                                 "shared": new_g["a"]}
    elif fam == "ssm":
        def body(bp, x, bc):
            h = rms_norm(x, bp["ln1"], cfg.norm_eps, plus_one=True)
            tstate = None if bc is None else {"shift_t": bc["shift_t"],
                                              "wkv": bc["wkv"]}
            t, new_t = rwkv6_time_mix(bp["time"], h, cfg, state=tstate,
                                      mode=mode, dtype=dtype)
            x = x + t
            h = rms_norm(x, bp["ln2"], cfg.norm_eps, plus_one=True)
            cstate = None if bc is None else {"shift_c": bc["shift_c"]}
            c, new_c = rwkv6_channel_mix(bp["time"], h, cfg, state=cstate,
                                         mode=mode, dtype=dtype)
            x = x + c
            nc = None
            if new_t is not None:
                nc = {**new_t, **new_c}
            return x, nc, 0.0
        lc = None if caches in (None, {}) else caches["layers"]
        x, new_l, _ = _scan_blocks(body, x, params["blocks"], lc, cfg,
                                   mode)
        new_caches = None if new_l is None else {"layers": new_l}
    else:
        raise ValueError(fam)

    x = rms_norm(x, params["final_norm"], cfg.norm_eps, plus_one=True)
    head = (params["embed"].T if cfg.tie_embeddings
            else params["lm_head"]).astype(dtype)
    if output == "hidden":
        return (x.astype(dtype), head), new_caches, (aux, loss_mask)
    logits = jnp.einsum("bsd,dv->bsv", x.astype(dtype), head)
    logits = softcap(logits, cfg.final_logit_softcap)
    # mask vocab padding
    if cfg.padded_vocab != cfg.vocab_size:
        pad_mask = jnp.arange(cfg.padded_vocab) >= cfg.vocab_size
        logits = jnp.where(pad_mask[None, None, :], -1e9, logits)
    logits = shard(logits, "logits")
    return logits, new_caches, (aux, loss_mask)
