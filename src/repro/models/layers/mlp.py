"""Gated MLP (SwiGLU / GeGLU) and top-k Mixture-of-Experts."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ...sharding.logical import shard
from .common import act_fn, dense_init


def init_mlp(key, d_in: int, d_ff: int, d_out: int, dtype=jnp.float32):
    ks = jax.random.split(key, 3)
    return {
        "w_gate": dense_init(ks[0], (d_in, d_ff), d_in, dtype),
        "w_up": dense_init(ks[1], (d_in, d_ff), d_in, dtype),
        "w_down": dense_init(ks[2], (d_ff, d_out), d_ff, dtype),
    }


def mlp_apply(p, x, act: str = "silu", dtype=jnp.bfloat16):
    x = x.astype(dtype)
    g = jnp.einsum("bsd,df->bsf", x, p["w_gate"].astype(dtype))
    u = jnp.einsum("bsd,df->bsf", x, p["w_up"].astype(dtype))
    h = shard(act_fn(act)(g) * u, "act_btf")
    return shard(jnp.einsum("bsf,fd->bsd", h, p["w_down"].astype(dtype)),
                 "act_btd")


# --------------------------------------------------------------------------
# Mixture of Experts: top-k routing with *group-wise* capacity-based dense
# dispatch (tokens are split into fixed-size groups, each with its own
# expert capacity — keeps the one-hot dispatch tensor O(T·K·cf) instead of
# O(T²·K·cf/E), the standard MaxText formulation). Deterministic and
# shardable: experts → "model", token groups → "data". Shared experts
# (DeepSeek-V2) run densely on all tokens.
# --------------------------------------------------------------------------
def init_moe(key, cfg, dtype=jnp.float32):
    D, F = cfg.d_model, cfg.moe_d_ff
    E = cfg.n_experts
    ks = jax.random.split(key, 5)
    p = {
        "router": dense_init(ks[0], (D, E), D, dtype),
        "w_gate": dense_init(ks[1], (E, D, F), D, dtype),
        "w_up": dense_init(ks[2], (E, D, F), D, dtype),
        "w_down": dense_init(ks[3], (E, F, D), F, dtype),
    }
    if cfg.n_shared_experts:
        p["shared"] = init_mlp(ks[4], D, F * cfg.n_shared_experts, D, dtype)
    return p


def moe_apply(p, x, cfg, *, capacity_factor: float | None = None,
              group_size: int = 256, dtype=jnp.bfloat16):
    B, S, D = x.shape
    E, K = cfg.n_experts, cfg.moe_top_k
    T = B * S
    cf = capacity_factor or cfg.moe_capacity_factor
    xt = x.reshape(T, D).astype(dtype)

    # group-wise dispatch: fixed-size token groups each with their own
    # expert capacity keep the one-hot tensors O(T*K*cf); a single
    # dropless group for decode/tiny batches.
    gs = T if T <= 256 else min(group_size, T)
    pad = (-T) % gs
    xg = jnp.pad(xt, ((0, pad), (0, 0))) if pad else xt
    G = xg.shape[0] // gs
    xg = shard(xg.reshape(G, gs, D), "moe_gtd")

    logits = jnp.einsum("gtd,de->gte", xg, p["router"].astype(dtype))
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    if pad:
        valid = (jnp.arange(G * gs) < T).reshape(G, gs)
        probs = probs * valid[..., None]
    gate_vals, sel = jax.lax.top_k(probs, K)              # (G,gs,K)
    gate_vals = gate_vals / jnp.maximum(
        gate_vals.sum(-1, keepdims=True), 1e-9)

    C = gs * K if T <= 256 else max(1, int(cf * gs * K / E))
    onehot = jax.nn.one_hot(sel, E, dtype=jnp.int32)      # (G,gs,K,E)
    flat = onehot.reshape(G, gs * K, E)
    ranks = (jnp.cumsum(flat, axis=1) - flat).reshape(G, gs, K, E)
    rank_sel = (ranks * onehot).sum(-1)                   # (G,gs,K)
    keep = rank_sel < C
    disp = (onehot * keep[..., None]).astype(dtype)       # (G,gs,K,E)
    pos_oh = jax.nn.one_hot(jnp.where(keep, rank_sel, C), C + 1,
                            dtype=dtype)[..., :C]         # (G,gs,K,C)
    dispatch = jnp.einsum("gtke,gtkc->gtec", disp, pos_oh)
    combine = jnp.einsum("gtke,gtkc,gtk->gtec", disp, pos_oh,
                         gate_vals.astype(dtype))

    ex_in = shard(jnp.einsum("gtd,gtec->gecd", xg, dispatch), "moe_ecd")
    g = jnp.einsum("gecd,edf->gecf", ex_in, p["w_gate"].astype(dtype))
    u = jnp.einsum("gecd,edf->gecf", ex_in, p["w_up"].astype(dtype))
    h = shard(act_fn(cfg.act)(g) * u, "moe_ecf")
    ex_out = jnp.einsum("gecf,efd->gecd", h, p["w_down"].astype(dtype))
    out = jnp.einsum("gecd,gtec->gtd", ex_out, combine)
    out = out.reshape(G * gs, D)[:T]

    out = out.reshape(B, S, D)
    if cfg.n_shared_experts:
        # run shared experts on the (B, S, D) layout so the batch dim
        # keeps its data sharding (a (1, T, D) view cannot shard)
        out = out + mlp_apply(p["shared"], x.astype(dtype), cfg.act, dtype)
    # router aux load-balancing loss surface
    me = probs.reshape(G * gs, E).mean(axis=0)
    ce = onehot.reshape(G * gs, K, E).sum(1).astype(
        jnp.float32).mean(axis=0) / K
    aux = (me * ce).sum() * E
    return shard(out, "act_btd"), aux
