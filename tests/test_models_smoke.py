"""Per-architecture smoke tests (assignment requirement): instantiate a
REDUCED config of each family, run one forward/train step on CPU, assert
output shapes and absence of NaNs; plus one decode step for decoders."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_config
from repro.models import forward, init_caches, init_model, lm_loss, \
    masked_pred_loss

KEY = jax.random.PRNGKey(0)
B, S = 2, 64


def _batch(cfg, key, seq=S, batch=B):
    out = {}
    if cfg.frontend == "audio_stub":
        out["frames"] = jax.random.normal(key, (batch, seq,
                                                cfg.frontend_dim))
        out["mask"] = jax.random.bernoulli(key, 0.3, (batch, seq))
        out["labels"] = jax.random.randint(key, (batch, seq), 0,
                                           cfg.vocab_size)
    else:
        st = seq - (cfg.frontend_tokens if cfg.frontend == "vision_stub"
                    else 0)
        out["tokens"] = jax.random.randint(key, (batch, st), 0,
                                           cfg.vocab_size)
        if cfg.frontend == "vision_stub":
            out["patches"] = jax.random.normal(
                key, (batch, cfg.frontend_tokens, cfg.frontend_dim))
    return out


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_shapes_and_finite(arch):
    cfg = get_config(arch, reduced=True)
    params = init_model(cfg, KEY)
    batch = _batch(cfg, KEY)
    logits, caches, (aux, _) = jax.jit(
        lambda p, b: forward(p, cfg, b))(params, batch)
    assert logits.shape == (B, S, cfg.padded_vocab)
    assert bool(jnp.isfinite(logits).all())
    assert caches is None


@pytest.mark.parametrize("arch", ARCHS)
def test_train_step_loss_and_grads_finite(arch):
    cfg = get_config(arch, reduced=True)
    params = init_model(cfg, KEY)
    batch = _batch(cfg, KEY)

    def loss_fn(p):
        logits, _, (aux, _) = forward(p, cfg, batch)
        if cfg.is_encoder:
            loss = masked_pred_loss(logits, batch["labels"], batch["mask"])
        elif cfg.frontend == "vision_stub":
            np_ = cfg.frontend_tokens
            loss = lm_loss(logits[:, np_:], batch["tokens"])
        else:
            loss = lm_loss(logits, batch["tokens"])
        return loss + 0.01 * aux

    loss, grads = jax.jit(jax.value_and_grad(loss_fn))(params)
    assert bool(jnp.isfinite(loss))
    leaves = jax.tree.leaves(grads)
    assert all(bool(jnp.isfinite(g).all()) for g in leaves)
    # something actually trains
    gnorm = sum(float(jnp.sum(g.astype(jnp.float32) ** 2))
                for g in leaves) ** 0.5
    assert gnorm > 0


@pytest.mark.parametrize("arch", [a for a in ARCHS
                                  if not get_config(a).is_encoder])
def test_decode_steps(arch):
    cfg = get_config(arch, reduced=True)
    params = init_model(cfg, KEY)
    caches = init_caches(cfg, B, 32)
    tok = jnp.ones((B, 1), jnp.int32)
    step = jax.jit(lambda p, c, t, pos: forward(
        p, cfg, {"tokens": t}, mode="decode", caches=c, pos=pos))
    for i in range(3):
        logits, caches, _ = step(params, caches, tok, jnp.asarray(i))
        assert bool(jnp.isfinite(logits).all())
        tok = jnp.argmax(logits[:, :, : cfg.vocab_size], -1).astype(
            jnp.int32)


def test_param_shapes_match_config():
    """Full (unreduced) configs build abstract params with sane counts —
    no allocation via eval_shape."""
    expected = {
        "gemma2-2b": (2.0e9, 3.5e9),
        "smollm-360m": (0.30e9, 0.5e9),
        "internlm2-20b": (17e9, 23e9),
        "mixtral-8x22b": (120e9, 150e9),
        "deepseek-v2-236b": (200e9, 260e9),
        "pixtral-12b": (10e9, 14e9),
        "rwkv6-3b": (2.5e9, 4e9),
        "hubert-xlarge": (0.9e9, 1.3e9),
        "minicpm3-4b": (3.3e9, 5e9),
        "zamba2-2.7b": (2.2e9, 3.6e9),
    }
    for arch, (lo, hi) in expected.items():
        cfg = get_config(arch)
        shapes = jax.eval_shape(lambda k: init_model(cfg, k), KEY)
        n = sum(int(np.prod(s.shape)) for s in jax.tree.leaves(shapes))
        assert lo <= n <= hi, f"{arch}: {n/1e9:.2f}B params out of band"
