"""Memory-efficient blockwise attention in pure JAX (flash-attention
algorithm: streaming softmax over KV blocks inside a scan over Q blocks).

This is the XLA execution path used by every model in the zoo — O(S·c)
memory instead of O(S²) — and the numerical template the Pallas kernel
mirrors tile-for-tile. Supports GQA grouping, causal, sliding-window,
soft-capping and valid-cache-length masking.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -2.0e38


def _pad_to(x, mult, axis):
    s = x.shape[axis]
    pad = (-s) % mult
    if pad == 0:
        return x, s
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths), s


def blockwise_attention(
    q: jnp.ndarray,            # (B, Sq, H, Dh)
    k: jnp.ndarray,            # (B, Skv, KV, Dh)
    v: jnp.ndarray,            # (B, Skv, KV, Dh)
    *,
    causal: bool = True,
    window: int | None = None,
    softcap: float | None = None,
    q_offset: int | jnp.ndarray = 0,
    kv_len: jnp.ndarray | None = None,
    q_chunk: int = 512,
    kv_chunk: int = 1024,
    scale: float | None = None,
) -> jnp.ndarray:
    B, Sq, H, Dh = q.shape
    _, Skv, KV, _ = k.shape
    Dv = v.shape[-1]                     # may differ from Dh (MLA latent)
    G = H // KV
    orig_dtype = q.dtype
    if scale is None:
        scale = 1.0 / jnp.sqrt(float(Dh))

    q_chunk = min(q_chunk, Sq)
    kv_chunk = min(kv_chunk, Skv)
    qp, Sq0 = _pad_to(q, q_chunk, 1)
    kp, Skv0 = _pad_to(k, kv_chunk, 1)
    vp, _ = _pad_to(v, kv_chunk, 1)
    nq, nk = qp.shape[1] // q_chunk, kp.shape[1] // kv_chunk

    qp = qp.reshape(B, nq, q_chunk, KV, G, Dh)
    kp = kp.reshape(B, nk, kv_chunk, KV, Dh)
    vp = vp.reshape(B, nk, kv_chunk, KV, Dv)

    def q_step(_, qi):
        qblk, qidx = qi                      # (B,c,KV,G,Dh), scalar
        # optional sharding point: when head counts don't divide the model
        # axis, the runtime can shard the query-chunk dim instead
        # ("attn_qchunk" rule) so attention compute still parallelizes.
        from ...sharding.logical import shard as _shard
        qblk = _shard(qblk, "attn_qchunk")
        qpos = q_offset + qidx * q_chunk + jnp.arange(q_chunk)

        def kv_step(carry, ki):
            acc, m, l = carry
            kblk, vblk, kidx = ki
            kpos = kidx * kv_chunk + jnp.arange(kv_chunk)
            s = jnp.einsum("bqkgd,bskd->bkgqs",
                           qblk.astype(jnp.float32),
                           kblk.astype(jnp.float32)) * scale
            if softcap is not None:
                s = softcap * jnp.tanh(s / softcap)
            msk = jnp.ones((q_chunk, kv_chunk), dtype=bool)
            if causal:
                msk &= kpos[None, :] <= qpos[:, None]
            if window is not None:
                msk &= kpos[None, :] > qpos[:, None] - window
            msk = jnp.broadcast_to(msk[None], (B, q_chunk, kv_chunk))
            if kv_len is not None:
                msk &= kpos[None, None, :] < kv_len[:, None, None]
            else:
                msk &= (kpos < Skv0)[None, None, :]
            s = jnp.where(msk[:, None, None], s, NEG_INF)
            m_new = jnp.maximum(m, s.max(axis=-1))
            p = jnp.exp(s - m_new[..., None])
            alpha = jnp.exp(m - m_new)
            l_new = l * alpha + p.sum(axis=-1)
            pv = jnp.einsum("bkgqs,bskd->bkgqd", p,
                            vblk.astype(jnp.float32))
            acc_new = acc * alpha[..., None] + pv
            return (acc_new, m_new, l_new), None

        acc0 = jnp.zeros((B, KV, G, q_chunk, Dv), jnp.float32)
        m0 = jnp.full((B, KV, G, q_chunk), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, KV, G, q_chunk), jnp.float32)
        # remat each KV block: backward recomputes the block's probability
        # matrix instead of saving it (flash-attention backward memory
        # behaviour under plain XLA autodiff).
        (acc, m, l), _ = jax.lax.scan(
            jax.checkpoint(kv_step,
                           policy=jax.checkpoint_policies.nothing_saveable),
            (acc0, m0, l0),
            (kp.swapaxes(0, 1), vp.swapaxes(0, 1), jnp.arange(nk)))
        out = acc / jnp.maximum(l[..., None], 1e-37)
        # (B,KV,G,c,Dh) -> (B,c,KV,G,Dh)
        return None, out.transpose(0, 3, 1, 2, 4)

    _, outs = jax.lax.scan(q_step, None,
                           (qp.swapaxes(0, 1), jnp.arange(nq)))
    out = outs.transpose(1, 0, 2, 3, 4, 5).reshape(B, nq * q_chunk, H, Dv)
    return out[:, :Sq0].astype(orig_dtype)
