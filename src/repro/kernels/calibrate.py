"""Calibration mode for roofline cost extraction + measured HW profiles.

XLA's ``cost_analysis()`` counts a ``while``-loop body once, not per trip,
so scanned graphs under-report FLOPs/bytes/collective traffic by their
trip counts. Under ``calibration()`` the chunked recurrences (SSD, WKV)
fully unroll their chunk scans so every chunk's work appears in the HLO —
this preserves the *production* chunk sizes, i.e. the linear-in-S compute
profile, unlike simply setting chunk=S (which would be quadratic).

On top of that mode this module builds the **calibration pass** (DESIGN.md
§17): microbenchmark the four kernel families (``gemm``,
``flash_attention``, ``rwkv6``, ``ssm_scan``) on the host backend, read
their trip-exact FLOP/byte counts from ``cost_analysis()`` under
``calibration()``, time the production-compiled executables, and fit the
analytical evaluator's constants into a versioned :class:`CalibratedHW`
profile.  The profile is persisted with the ``serve/cache_store`` record
framing (magic + schema header, CRC-framed records, atomic save), so a
stale or corrupt profile degrades to a cold re-calibration, never a crash.

Fitting contract
----------------
``flops_per_s``   achieved matmul throughput (gemm samples only — the
                  eq.-7 systolic model is a matmul model).  Applied as
                  ``freq_hz = flops_per_s / (2·R·C)`` so R·C·2·freq
                  reproduces the measured peak, mirroring
                  ``sharding/mcm_planner.tpu_hw``.
``bytes_per_s``   achieved HLO-byte streaming rate (best over all
                  samples) — the unit the dryrun cost-analysis side of
                  the validation gate also reports, so predicted and
                  measured roofline terms share a basis.
``byte_overhead`` median HLO-bytes / ideal-bytes (operand+result element
                  counts × dtype size) across samples, clipped ≥ 1.  The
                  evaluator traffics *ideal* bytes, so its effective
                  memory bandwidth is ``bytes_per_s / byte_overhead``.
``nop_frac``      NoP-link : memory bandwidth ratio.  One host exposes no
                  inter-chip fabric, so this architectural ratio is kept
                  from the v5e datasheet (ICI / HBM) rather than fitted —
                  documented, not hidden.
"""
from __future__ import annotations

import contextlib
import contextvars
import dataclasses
import statistics
import time

_CAL = contextvars.ContextVar("kernel_calibration", default=False)

# Profile schema — bump when CalibratedHW fields change meaning; old
# profiles then miss on the versioned key and trigger re-calibration.
PROFILE_SCHEMA = 1

# v5e ICI link (50 GB/s) : HBM (819 GB/s) — architectural ratio used for
# bw_nop when calibrating on a host with no measurable interconnect.
ICI_OVER_HBM = 50e9 / 819e9


@contextlib.contextmanager
def calibration(on: bool = True):
    tok = _CAL.set(on)
    try:
        yield
    finally:
        _CAL.reset(tok)


def scan_unroll():
    """unroll= argument for inner lax.scans: full unroll when calibrating."""
    return True if _CAL.get() else 1


# --------------------------------------------------------------------------
# Measured samples and the fitted profile
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class KernelSample:
    """One microbenchmark point: trip-exact HLO counts + wall clock."""
    kernel: str                # gemm | flash_attention | rwkv6 | ssm_scan
    shape: tuple               # human-readable problem dims
    flops: float               # HLO FLOPs under calibration() (trip-exact)
    hlo_bytes: float           # HLO bytes accessed under calibration()
    ideal_bytes: float         # operand+result elements × dtype size
    wall_s: float              # median production-executable wall clock
    reps: int = 1

    @property
    def achieved_flops_per_s(self) -> float:
        return self.flops / self.wall_s if self.wall_s > 0 else 0.0

    @property
    def achieved_bytes_per_s(self) -> float:
        return self.hlo_bytes / self.wall_s if self.wall_s > 0 else 0.0

    @property
    def byte_overhead(self) -> float:
        return (self.hlo_bytes / self.ideal_bytes
                if self.ideal_bytes > 0 else 1.0)


@dataclasses.dataclass(frozen=True)
class CalibratedHW:
    """Fitted evaluator constants for one backend (see module docstring)."""
    backend: str
    flops_per_s: float         # per model chip, matmul-achieved
    bytes_per_s: float         # per model chip, HLO-byte basis
    byte_overhead: float       # HLO bytes per ideal byte (≥ 1)
    nop_frac: float = ICI_OVER_HBM
    schema: int = PROFILE_SCHEMA
    samples: tuple = ()

    def freq_for(self, R: int, C: int) -> float:
        """Systolic clock reproducing the measured matmul peak: the eq.-7
        model delivers R·C·2·freq FLOP/s per chiplet."""
        return self.flops_per_s / (2.0 * R * C)

    @property
    def bw_mem_model(self) -> float:
        """Effective memory bandwidth on the evaluator's ideal-byte basis."""
        return self.bytes_per_s / max(self.byte_overhead, 1.0)

    @property
    def bw_nop_model(self) -> float:
        """Per-link NoP bandwidth: architectural ratio × measured memory."""
        return self.bw_mem_model * self.nop_frac

    def apply(self, hw) -> "HWConfig":  # noqa: F821 - forward ref
        """Rescale an :class:`~repro.core.hw.HWConfig` onto the measured
        constants: every chiplet owns one calibrated memory port (the
        type-C / pod mapping of ``sharding/mcm_planner``)."""
        n_chips = hw.X * hw.Y
        return hw.replace(
            freq_hz=self.freq_for(hw.R, hw.C),
            bw_mem=self.bw_mem_model * n_chips,
            bw_nop=self.bw_nop_model)


# --------------------------------------------------------------------------
# Microbenchmarks (host-backend XLA paths; Pallas interpret mode is far
# too slow off-TPU to time honestly)
# --------------------------------------------------------------------------

def _cost_dict(compiled) -> dict:
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):       # older jax returns [dict]
        ca = ca[0] if ca else {}
    return dict(ca or {})


def _measure(fn, args, *, reps: int) -> tuple[float, float, float]:
    """(calib_flops, calib_bytes, median_wall_s) for fn(*args).

    Counts come from the calibration()-unrolled lowering so scanned
    kernels report per-trip work; timing runs the production executable
    (rolled scans) — both execute the same arithmetic.
    """
    import jax

    with calibration():
        calib = jax.jit(fn).lower(*args).compile()
    cd = _cost_dict(calib)
    flops = float(cd.get("flops", 0.0))
    nbytes = float(cd.get("bytes accessed", 0.0))

    prod = jax.jit(fn).lower(*args).compile()
    out = prod(*args)                       # warm-up / ensure executable
    jax.block_until_ready(out)
    walls = []
    for _ in range(max(reps, 1)):
        t0 = time.perf_counter()
        jax.block_until_ready(prod(*args))
        walls.append(time.perf_counter() - t0)
    return flops, nbytes, statistics.median(walls)


def _nbytes(*arrays) -> float:
    return float(sum(a.size * a.dtype.itemsize for a in arrays))


def _bench_gemm(rng, sizes, reps) -> list[KernelSample]:
    import jax.numpy as jnp

    from .gemm.ref import matmul_ref

    out = []
    for m, k, n in sizes:
        a = jnp.asarray(rng.standard_normal((m, k)), jnp.float32)
        b = jnp.asarray(rng.standard_normal((k, n)), jnp.float32)
        f, hb, w = _measure(matmul_ref, (a, b), reps=reps)
        ideal = _nbytes(a, b) + 4.0 * m * n
        out.append(KernelSample("gemm", (m, k, n), f, hb, ideal, w, reps))
    return out


def _bench_attention(rng, sizes, reps) -> list[KernelSample]:
    import jax.numpy as jnp

    from .flash_attention.blockwise import blockwise_attention

    out = []
    for bsz, s, h, dh in sizes:
        q = jnp.asarray(rng.standard_normal((bsz, s, h, dh)), jnp.float32)
        k = jnp.asarray(rng.standard_normal((bsz, s, h, dh)), jnp.float32)
        v = jnp.asarray(rng.standard_normal((bsz, s, h, dh)), jnp.float32)
        fn = lambda q, k, v: blockwise_attention(q, k, v, causal=True)
        f, hb, w = _measure(fn, (q, k, v), reps=reps)
        ideal = _nbytes(q, k, v) * 4.0 / 3.0    # q,k,v + same-shaped out
        out.append(KernelSample("flash_attention", (bsz, s, h, dh),
                                f, hb, ideal, w, reps))
    return out


def _bench_rwkv6(rng, sizes, reps) -> list[KernelSample]:
    import jax.numpy as jnp

    from .rwkv6.chunked import wkv6_chunked

    out = []
    for bsz, s, h, k, chunk in sizes:
        shp = (bsz, s, h, k)
        r = jnp.asarray(rng.standard_normal(shp), jnp.float32)
        kk = jnp.asarray(rng.standard_normal(shp), jnp.float32)
        v = jnp.asarray(rng.standard_normal(shp), jnp.float32)
        w = jnp.asarray(rng.uniform(0.6, 0.99, shp), jnp.float32)
        u = jnp.asarray(rng.standard_normal((h, k)), jnp.float32)
        fn = lambda r, kk, v, w, u: wkv6_chunked(r, kk, v, w, u,
                                                 chunk=chunk)[0]
        f, hb, wall = _measure(fn, (r, kk, v, w, u), reps=reps)
        ideal = _nbytes(r, kk, v, w, u) + _nbytes(r)   # out ~ r-shaped
        out.append(KernelSample("rwkv6", (bsz, s, h, k, chunk),
                                f, hb, ideal, wall, reps))
    return out


def _bench_ssm_scan(rng, sizes, reps) -> list[KernelSample]:
    import jax.numpy as jnp

    from .ssm_scan.chunked import ssm_scan_chunked

    out = []
    for bsz, s, h, p, g, n, chunk in sizes:
        x = jnp.asarray(rng.standard_normal((bsz, s, h, p)), jnp.float32)
        dt = jnp.asarray(rng.uniform(0.001, 0.1, (bsz, s, h)), jnp.float32)
        a = jnp.asarray(-rng.uniform(0.5, 2.0, (h,)), jnp.float32)
        B = jnp.asarray(rng.standard_normal((bsz, s, g, n)), jnp.float32)
        C = jnp.asarray(rng.standard_normal((bsz, s, g, n)), jnp.float32)
        D = jnp.asarray(rng.standard_normal((h,)), jnp.float32)
        fn = lambda x, dt, a, B, C, D: ssm_scan_chunked(x, dt, a, B, C, D,
                                                        chunk=chunk)[0]
        f, hb, wall = _measure(fn, (x, dt, a, B, C, D), reps=reps)
        ideal = _nbytes(x, dt, a, B, C, D) + _nbytes(x)
        out.append(KernelSample("ssm_scan", (bsz, s, h, p, g, n, chunk),
                                f, hb, ideal, wall, reps))
    return out


def profile_kernels(*, smoke: bool = False, reps: int = 3,
                    seed: int = 0) -> CalibratedHW:
    """Run the kernel microbenchmarks and fit a :class:`CalibratedHW`."""
    import numpy as np

    import jax

    rng = np.random.default_rng(seed)
    if smoke:
        gemm_sizes = [(128, 128, 128), (256, 256, 256)]
        attn_sizes = [(1, 128, 2, 32)]
        rwkv_sizes = [(1, 64, 1, 16, 16)]
        ssm_sizes = [(1, 128, 1, 8, 1, 8, 32)]
    else:
        gemm_sizes = [(256, 256, 256), (512, 512, 512), (768, 768, 768)]
        attn_sizes = [(1, 256, 4, 64), (2, 512, 4, 64)]
        rwkv_sizes = [(1, 128, 2, 32, 32), (2, 256, 2, 32, 32)]
        ssm_sizes = [(1, 256, 2, 16, 1, 16, 64), (2, 512, 2, 16, 1, 16, 64)]

    samples: list[KernelSample] = []
    samples += _bench_gemm(rng, gemm_sizes, reps)
    samples += _bench_attention(rng, attn_sizes, reps)
    samples += _bench_rwkv6(rng, rwkv_sizes, reps)
    samples += _bench_ssm_scan(rng, ssm_sizes, reps)

    gemm = [s for s in samples if s.kernel == "gemm"]
    flops_per_s = max(s.achieved_flops_per_s for s in gemm)
    bytes_per_s = max(s.achieved_bytes_per_s for s in samples)
    overhead = max(1.0, statistics.median(
        s.byte_overhead for s in samples if s.ideal_bytes > 0))
    return CalibratedHW(
        backend=jax.default_backend(),
        flops_per_s=flops_per_s,
        bytes_per_s=bytes_per_s,
        byte_overhead=overhead,
        samples=tuple(samples))


# --------------------------------------------------------------------------
# Persistence — serve/cache_store record idiom (versioned key; corrupt or
# stale files degrade to a miss, never a crash)
# --------------------------------------------------------------------------

_PROFILE_KEY = ("calibrated_hw", PROFILE_SCHEMA)


def save_profile(profile: CalibratedHW, path: str) -> int:
    from ..serve.cache_store import CacheStore
    return CacheStore(path).save({_PROFILE_KEY: profile})


def load_profile(path: str) -> CalibratedHW | None:
    from ..serve.cache_store import CacheStore
    prof = CacheStore(path).load().get(_PROFILE_KEY)
    if isinstance(prof, CalibratedHW) and prof.schema == PROFILE_SCHEMA:
        return prof
    return None
