"""Training losses: next-token LM cross-entropy and HuBERT-style masked
prediction. Cross-entropy is computed from log-softmax in f32 with the
padded-vocab entries already masked by the model head.

``fused_lm_loss`` is the memory-efficient training path: it consumes the
final *hidden* states and the unembedding matrix and scans over sequence
chunks (rematerialized), so the (B, S, vocab) logits tensor — 4+ GiB/device
in f32 for a 256k vocab — never exists."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..sharding.logical import shard


def _xent(logits, labels):
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    return -jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]


def lm_loss(logits, tokens, loss_mask=None):
    """Next-token prediction: logits (B,S,V) predict tokens shifted by 1.
    ``loss_mask`` (B,S) marks positions whose *predictions* count (e.g.
    text-only for VLM)."""
    lg = logits[:, :-1]
    tg = tokens[:, 1:]
    ls = _xent(lg, tg)
    if loss_mask is not None:
        m = loss_mask[:, 1:].astype(jnp.float32)
    else:
        m = jnp.ones_like(ls)
    return (ls * m).sum() / jnp.maximum(m.sum(), 1.0)


def masked_pred_loss(logits, labels, mask):
    """Encoder masked prediction: CE only on masked frames."""
    ls = _xent(logits, labels)
    m = mask.astype(jnp.float32)
    return (ls * m).sum() / jnp.maximum(m.sum(), 1.0)


def fused_lm_loss(hidden, head, targets, *, mask=None,
                  final_softcap=None, vocab_size=None, chunk: int = 512,
                  shift: bool = True):
    """Chunked CE over hidden states: per-position loss for predicting
    ``targets`` (already aligned: position i predicts targets[i]).

    hidden (B,S,D), head (D,Vp), targets (B,S), mask (B,S) or None.
    ``shift=True`` applies the standard next-token shift internally.
    """
    if shift:
        hidden = hidden[:, :-1]
        targets = targets[:, 1:]
        mask = None if mask is None else mask[:, 1:]
    B, S, D = hidden.shape
    Vp = head.shape[-1]
    if mask is None:
        mask = jnp.ones((B, S), jnp.float32)
    mask = mask.astype(jnp.float32)
    c = min(chunk, S)
    pad = (-S) % c
    if pad:
        hidden = jnp.pad(hidden, ((0, 0), (0, pad), (0, 0)))
        targets = jnp.pad(targets, ((0, 0), (0, pad)))
        mask = jnp.pad(mask, ((0, 0), (0, pad)))
    n = hidden.shape[1] // c

    def piece(carry, xs):
        h, t, m = xs                                  # (B,c,D),(B,c),(B,c)
        lg = jnp.einsum("bsd,dv->bsv", h, head).astype(jnp.float32)
        lg = shard(lg, "logits")
        if final_softcap is not None:
            lg = final_softcap * jnp.tanh(lg / final_softcap)
        if vocab_size is not None and vocab_size != Vp:
            lg = jnp.where(jnp.arange(Vp)[None, None] >= vocab_size,
                           -1e9, lg)
        ls = _xent(lg, t)
        tot, cnt = carry
        return (tot + (ls * m).sum(), cnt + m.sum()), None

    def chunks(x):
        return x.reshape((B, n, c) + x.shape[2:]).swapaxes(0, 1)

    (tot, cnt), _ = jax.lax.scan(
        jax.checkpoint(piece,
                       policy=jax.checkpoint_policies.nothing_saveable),
        (jnp.zeros((), jnp.float32), jnp.zeros((), jnp.float32)),
        (chunks(hidden), chunks(targets), chunks(mask)))
    return tot / jnp.maximum(cnt, 1.0)
