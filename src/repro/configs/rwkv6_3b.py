"""rwkv6-3b (Finch) [ssm]: 32L d_model=2560 (attn-free) d_ff=8960
vocab=65536 — data-dependent decay WKV [arXiv:2404.05892; hf]."""
from ..models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="rwkv6-3b", family="ssm",
        n_layers=32, d_model=2560, d_ff=8960, vocab_size=65536,
        attn_type="none", rwkv_head_dim=64,
        rwkv_decay_lora=64, rwkv_mix_lora=32,
        ssm_chunk=32,   # WKV chunk: the (i,j,channel) intra tensor is O(Lc^2 K)
        act="relu",
    )


def reduced_config() -> ModelConfig:
    return config().replace(
        name="rwkv6-smoke", n_layers=3, d_model=64, d_ff=128,
        vocab_size=256, rwkv_head_dim=16, rwkv_decay_lora=16,
        rwkv_mix_lora=8, ssm_chunk=16, remat=False)
