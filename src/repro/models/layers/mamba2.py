"""Mamba-2 block (fused in_proj, causal depthwise conv, SSD scan, gated
RMSNorm, out_proj) with train / prefill / decode paths.

Decode state: conv ring (last conv_k−1 inputs of the conv channels) plus
the SSM state (B, H, P, N) — constant-size, which is what makes the
``long_500k`` cell servable.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ...kernels.ssm_scan.chunked import ssm_decode_step, ssm_scan_chunked
from ...sharding.logical import shard
from .common import dense_init, rms_norm

G = 1  # state groups


def init_mamba2(key, cfg, dtype=jnp.float32):
    D = cfg.d_model
    di = cfg.d_inner
    H, P, N = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state
    conv_dim = di + 2 * G * N
    ks = jax.random.split(key, 4)
    return {
        "in_proj": dense_init(ks[0], (D, 2 * di + 2 * G * N + H), D, dtype),
        "conv_w": dense_init(ks[1], (cfg.ssm_conv, conv_dim), cfg.ssm_conv,
                             dtype),
        "conv_b": jnp.zeros((conv_dim,), dtype),
        "dt_bias": jnp.zeros((H,), dtype),
        "A_log": jnp.zeros((H,), dtype),
        "D": jnp.ones((H,), dtype),
        "norm": jnp.zeros((di,), dtype),
        "out_proj": dense_init(ks[3], (di, D), di, dtype),
    }


def init_mamba_state(cfg, batch: int, dtype):
    di = cfg.d_inner
    conv_dim = di + 2 * G * cfg.ssm_state
    return {
        "conv": jnp.zeros((batch, cfg.ssm_conv - 1, conv_dim), dtype),
        "ssm": jnp.zeros((batch, cfg.ssm_heads, cfg.ssm_head_dim,
                          cfg.ssm_state), jnp.float32),
    }


def _causal_conv(xBC, w, b, state=None):
    """Depthwise causal conv along time. xBC (B,S,Cc), w (K,Cc)."""
    K = w.shape[0]
    if state is None:
        pad = jnp.zeros((xBC.shape[0], K - 1, xBC.shape[2]), xBC.dtype)
    else:
        pad = state.astype(xBC.dtype)
    xp = jnp.concatenate([pad, xBC], axis=1)
    out = sum(xp[:, i:i + xBC.shape[1]] * w[i][None, None, :]
              for i in range(K))
    new_state = xp[:, -(K - 1):] if K > 1 else pad
    return jax.nn.silu(out + b[None, None, :]), new_state


def mamba2_apply(p, x, cfg, *, state=None, mode="train",
                 dtype=jnp.bfloat16):
    """x (B,S,D) → (out (B,S,D), new_state)."""
    B, S, D = x.shape
    di = cfg.d_inner
    H, P, N = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state
    x = x.astype(dtype)
    zxbcdt = jnp.einsum("bsd,de->bse", x, p["in_proj"].astype(dtype))
    z, xBC, dt = jnp.split(zxbcdt, [di, 2 * di + 2 * G * N], axis=-1)
    xBC = shard(xBC, "act_bti")
    conv_state = None if state is None else state["conv"]
    xBC, new_conv = _causal_conv(xBC, p["conv_w"].astype(dtype),
                                 p["conv_b"].astype(dtype), conv_state)
    xs, Bm, Cm = jnp.split(xBC, [di, di + G * N], axis=-1)
    xs = xs.reshape(B, S, H, P)
    Bm = Bm.reshape(B, S, G, N)
    Cm = Cm.reshape(B, S, G, N)
    dt = jax.nn.softplus(dt.astype(jnp.float32)
                         + p["dt_bias"].astype(jnp.float32))
    a = -jnp.exp(p["A_log"].astype(jnp.float32))

    if mode == "decode":
        y, new_ssm = ssm_decode_step(
            state["ssm"], xs[:, 0], dt[:, 0], a, Bm[:, 0], Cm[:, 0],
            p["D"].astype(jnp.float32))
        y = y[:, None]
    else:
        h0 = None if state is None else state["ssm"]
        y, new_ssm = ssm_scan_chunked(xs, dt, a, Bm, Cm,
                                      p["D"].astype(jnp.float32), h0=h0,
                                      chunk=cfg.ssm_chunk)
    y = y.reshape(B, S, di)
    y = rms_norm(y * jax.nn.silu(z.astype(jnp.float32)).astype(dtype),
                 p["norm"], cfg.norm_eps, plus_one=True)
    out = jnp.einsum("bsi,id->bsd", y.astype(dtype),
                     p["out_proj"].astype(dtype))
    new_state = None
    if mode in ("prefill", "decode"):
        cdt = state["conv"].dtype if state is not None else new_conv.dtype
        new_state = {"conv": new_conv.astype(cdt), "ssm": new_ssm}
    return shard(out, "act_btd"), new_state
