"""Checkpointed sweep resume (DESIGN.md §15): periodic cache
persistence through ``serve.cache_store``, kill/restart recovery with
exact hit accounting, straggler flagging, and the run_grid progress
line. The chaos test kills a real child process mid-grid (``os._exit``
after K checkpoint appends — no cleanup, no atexit) and asserts the
restarted run recomputes only the tail, bitwise-identically to an
uninterrupted run. Children use the numpy backend: no jax import, so
they start in milliseconds."""
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from repro.core import EvalOptions, GemmOp, Task, make_hw
from repro.core import sweep
from repro.core.ga import GAConfig
from repro.runtime.fault_tolerance import StragglerMonitor

SRC = os.path.join(os.path.dirname(__file__), os.pardir, "src")


def toy_task(n=3, m=512):
    ops = [GemmOp("g0", M=m, K=256, N=512)]
    for i in range(1, n):
        ops.append(GemmOp(f"g{i}", M=m, K=ops[-1].N, N=512, chained=True))
    return Task(f"toy{n}_{m}", ops)


@pytest.fixture(autouse=True)
def _fresh_cache():
    sweep.clear_cache()
    yield
    sweep.clear_cache()


def _points(k=6):
    task = toy_task(3)
    return [sweep.EvalPoint(task, make_hw("A", 4, "hbm", bw_nop=64.0 + i),
                            EvalOptions(redistribution=True))
            for i in range(k)]


# ------------------------------------------------- in-process semantics
def test_checkpoint_requires_cache():
    with pytest.raises(ValueError, match="cache=True"):
        sweep.eval_sweep(_points(2), backend="numpy", cache=False,
                         checkpoint="/tmp/unused-store.bin")


def test_eval_sweep_checkpoint_roundtrip(tmp_path):
    path = str(tmp_path / "store.bin")
    pts = _points(6)
    recs = sweep.eval_sweep(pts, backend="numpy", checkpoint=path,
                            checkpoint_every=2)
    assert sweep.cache_stats() == {"hits": 0, "misses": 6}
    sweep.clear_cache()
    recs2 = sweep.eval_sweep(pts, backend="numpy", checkpoint=path,
                             checkpoint_every=2)
    # the store held every record: pure resume, zero recomputation
    assert sweep.cache_stats() == {"hits": 6, "misses": 0}
    for a, b in zip(recs, recs2):
        assert a["latency"] == b["latency"]
        assert np.array_equal(a["t_in"], b["t_in"])


def test_partial_store_resumes_tail_only(tmp_path):
    path = str(tmp_path / "store.bin")
    pts = _points(6)
    sweep.eval_sweep(pts[:4], backend="numpy", checkpoint=path)
    sweep.clear_cache()
    sweep.eval_sweep(pts, backend="numpy", checkpoint=path)
    assert sweep.cache_stats() == {"hits": 4, "misses": 2}


def test_solve_grid_checkpoint_and_straggler(tmp_path):
    path = str(tmp_path / "store.bin")
    pts = _points(4)
    cfg = GAConfig(population=16, generations=2, seed=1)
    mon = StragglerMonitor()
    sweep.solve_grid(pts, "latency", cfg, backend="numpy",
                     checkpoint=path, checkpoint_every=2, straggler=mon)
    assert mon.ewma > 0            # observed per-chunk wall-times
    sweep.clear_cache()
    recs = sweep.solve_grid(pts, "latency", cfg, backend="numpy",
                            checkpoint=path, checkpoint_every=2)
    assert sweep.cache_stats() == {"hits": 4, "misses": 0}
    assert all(r is not None for r in recs)


def test_straggler_flag_emits_stderr_line(tmp_path, capsys):
    class AlwaysSlow:
        def observe(self, step, dt):
            return True

    sweep.eval_sweep(_points(4), backend="numpy",
                     checkpoint=str(tmp_path / "s.bin"),
                     checkpoint_every=2, straggler=AlwaysSlow())
    assert "straggler" in capsys.readouterr().err


def test_run_grid_progress_and_checkpoint(tmp_path, capsys):
    path = str(tmp_path / "store.bin")
    pts = _points(3)
    out = sweep.run_grid(
        [{"i": i} for i in range(3)],
        lambda i: sweep.eval_sweep([pts[i]], backend="numpy")[0],
        progress="grid", checkpoint=path)
    assert len(out) == 3
    err = capsys.readouterr().err
    # liveness goes to stderr: label, counter, rate, ETA
    assert "grid point 3/3" in err
    assert "pts/s" in err and "eta" in err
    sweep.clear_cache()
    sweep.eval_sweep(pts, backend="numpy", checkpoint=path)
    assert sweep.cache_stats() == {"hits": 3, "misses": 0}


# ----------------------------------------------------- chaos kill/resume
_CHILD_PRELUDE = """
    import os
    import numpy as np
    from repro.core import sweep, EvalOptions, GemmOp, Task, make_hw

    def points():
        ops = [GemmOp("g0", M=512, K=256, N=512)]
        for i in range(1, 3):
            ops.append(GemmOp(f"g{i}", M=512, K=ops[-1].N, N=512,
                              chained=True))
        task = Task("toy3", ops)
        return [sweep.EvalPoint(
                    task, make_hw("A", 4, "hbm", bw_nop=64.0 + i),
                    EvalOptions(redistribution=True))
                for i in range(6)]

    def digest(recs):
        return "|".join(float(r["latency"]).hex() + ":" +
                        r["t_in"].tobytes().hex() for r in recs)
"""


def _run_child(body: str, store: str) -> subprocess.CompletedProcess:
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    env["STORE"] = store
    script = textwrap.dedent(_CHILD_PRELUDE) + textwrap.dedent(body)
    return subprocess.run([sys.executable, "-c", script], env=env,
                          capture_output=True, text=True, timeout=120)


def test_chaos_kill_midgrid_then_resume(tmp_path):
    store = str(tmp_path / "store.bin")

    # -- worker 1: dies hard (no cleanup) after K=3 checkpoint appends
    killed = _run_child("""
        from repro.serve import cache_store

        K = 3
        real = cache_store.CacheStore.append
        calls = {"n": 0}

        def dying_append(self, entries):
            r = real(self, entries)
            calls["n"] += 1
            if calls["n"] >= K:
                os._exit(9)         # SIGKILL-style: skip atexit/finally
            return r

        cache_store.CacheStore.append = dying_append
        sweep.eval_sweep(points(), backend="numpy",
                         checkpoint=os.environ["STORE"],
                         checkpoint_every=1)
        os._exit(1)                 # unreachable: the grid must die
    """, store)
    assert killed.returncode == 9, (killed.stdout, killed.stderr)
    assert os.path.exists(store)

    # -- worker 2: restart against the same store; only the tail runs
    resumed = _run_child("""
        recs = sweep.eval_sweep(points(), backend="numpy",
                                checkpoint=os.environ["STORE"],
                                checkpoint_every=1)
        st = sweep.cache_stats()
        print(f"HITS={st['hits']} MISSES={st['misses']}")
        print("DIGEST=" + digest(recs))
    """, store)
    assert resumed.returncode == 0, resumed.stderr
    # cache_hits == points completed before the kill, misses == the rest
    assert "HITS=3 MISSES=3" in resumed.stdout

    # -- reference: uninterrupted run, no store — bitwise-equal records
    reference = _run_child("""
        recs = sweep.eval_sweep(points(), backend="numpy")
        print("DIGEST=" + digest(recs))
    """, store)
    assert reference.returncode == 0, reference.stderr
    dig = [line for line in resumed.stdout.splitlines()
           if line.startswith("DIGEST=")]
    ref = [line for line in reference.stdout.splitlines()
           if line.startswith("DIGEST=")]
    assert dig == ref and dig, (resumed.stdout, reference.stdout)
