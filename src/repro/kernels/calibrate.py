"""Calibration mode for roofline cost extraction.

XLA's ``cost_analysis()`` counts a ``while``-loop body once, not per trip,
so scanned graphs under-report FLOPs/bytes/collective traffic by their
trip counts. Under ``calibration()`` the chunked recurrences (SSD, WKV)
fully unroll their chunk scans so every chunk's work appears in the HLO —
this preserves the *production* chunk sizes, i.e. the linear-in-S compute
profile, unlike simply setting chunk=S (which would be quadratic).
"""
from __future__ import annotations

import contextlib
import contextvars

_CAL = contextvars.ContextVar("kernel_calibration", default=False)


@contextlib.contextmanager
def calibration(on: bool = True):
    tok = _CAL.set(on)
    try:
        yield
    finally:
        _CAL.reset(tok)


def scan_unroll():
    """unroll= argument for inner lax.scans: full unroll when calibrating."""
    return True if _CAL.get() else 1
