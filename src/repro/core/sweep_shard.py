"""Device-sharded sweep fabric — ``shard_map`` over the grid axis
(DESIGN.md §15).

Every batched engine behind :mod:`repro.core.sweep` evaluates a shape
group as ONE compiled call with a leading *grid* (or *island*) axis:
``evaluator_jax.grid_evaluate`` (``jit(vmap(vmap))``),
``ga_jax.solve_islands`` (``jit(vmap(scan))``),
``netsim_jax.simulate_pull_batch`` and
``pipelining_jax.schedule_batch`` (``jit(vmap(...))``), and the MIQP
lattice scorer's chunked ``grid_evaluate`` calls. Those calls all run on
one device; this module shards that leading axis across every local
device instead:

  * :func:`resolve_devices` — the uniform
    ``devices="single"|"sharded"|"auto"`` knob carried by
    ``EvalOptions``/``GAConfig``/``MIQPConfig``/``PipelineConfig`` (and
    overridable per sweep call / per ``OptServer``). ``"auto"`` picks
    ``"sharded"`` iff more than one device exists and the group has ≥ 2
    points; an explicit ``"sharded"`` always goes through ``shard_map``,
    even on a 1-device mesh, so single-device hosts exercise the exact
    code path multi-device hosts run.
  * :func:`grid_mesh` — the mesh, from
    :func:`repro.launch.mesh.make_debug_mesh` over all local devices
    (``XLA_FLAGS=--xla_force_host_platform_device_count=N`` carves a
    CPU host into N devices; ``benchmarks/common.py`` exposes it as the
    ``--devices`` flag).
  * :func:`sharded_grid_call` — pad the grid axis to a multiple of the
    device count (tail points replicate row 0 — *valid* data, so
    ``lax.while_loop``/``scan`` engines terminate on the padding —
    and are sliced off after the call), then run the engine's unjitted
    vmapped inner function under ``jit(shard_map(...))`` with batched
    arguments sharded over dim 0 and the rest replicated.

Exactness (the §9 contract, extended): per-point math inside every
engine is lane-independent — no cross-point reduction, no batch-size-
dependent tie-break — so a point's record is **bitwise identical solo,
batched, or sharded**. The sweep-cache fingerprints therefore normalize
the ``devices`` field away (:func:`repro.core.sweep._strip_devices`):
records are device-count-independent and one cache serves all three
modes. ``tests/test_sweep_shard.py`` pins the contract;
``benchmarks/perf_iterations.py --cell sweep_shard`` gates it bitwise
in CI.

Performance note: on real multi-device hardware the win is ~linear in
device count for the scan/while_loop-bound engines (GA evolution, flow
netsim) whose single-device form cannot use intra-op parallelism. On a
CPU host carved into virtual devices the shards still share the same
physical cores, so forced-host speedups are bounded by the *physical*
core count (the ``sweep_shard`` artifact records both).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec

from .evaluator import DEVICE_MODES

__all__ = [
    "DEVICE_MODES",
    "device_count",
    "resolve_devices",
    "grid_mesh",
    "sharded_grid_call",
]


def device_count() -> int:
    return len(jax.devices())


def resolve_devices(devices: str | None, n_points: int) -> str:
    """Resolve the ``devices`` knob to a concrete execution mode.

    ``None`` means "auto". ``"auto"`` → ``"sharded"`` iff more than one
    device exists and the group carries ≥ 2 points (sharding a single
    point buys nothing); the choice is correctness-neutral — solo ==
    batched == sharded bitwise — so auto-resolution never splits the
    result cache. An explicit ``"sharded"`` is honored even on one
    device (a 1-device mesh), so the shard_map path is testable
    anywhere."""
    if devices is None:
        devices = "auto"
    if devices not in DEVICE_MODES:
        raise ValueError(f"unknown devices mode {devices!r}; "
                         f"one of {DEVICE_MODES}")
    if devices == "auto":
        return ("sharded" if device_count() > 1 and n_points >= 2
                else "single")
    return devices


@functools.lru_cache(maxsize=None)
def grid_mesh():
    """The sweep fabric's mesh: a debug mesh over ALL local devices
    (cached — mesh identity keys the compiled shard_map wrappers). The
    grid axis is sharded over the product of every mesh axis, so the
    mesh shape (2-D/3-D, :func:`repro.launch.mesh.make_debug_mesh`)
    only affects axis naming, not the sharding."""
    from ..launch.mesh import make_debug_mesh

    return make_debug_mesh()


def _pad0(tree, pad: int):
    """Pad every leaf's leading axis with ``pad`` copies of row 0.
    Replicated *valid* rows — never zeros — so iterative engines
    (waterfilling ``while_loop``, GA ``scan``) behave on the tail
    exactly like they do on a real point."""
    return jax.tree_util.tree_map(
        lambda a: jnp.concatenate(
            [a, jnp.broadcast_to(a[:1], (pad,) + a.shape[1:])]), tree)


@functools.lru_cache(maxsize=None)
def _sharded_fn(inner, mesh, batched: tuple):
    """``jit(shard_map(inner))`` cached by (inner fn, mesh, batched
    mask) — engines pass lru-cached inner functions, so the jit cache
    never grows per call. ``batched[i]`` shards positional arg ``i``'s
    leading axis over the whole mesh; False replicates (hyperparams,
    shared RNG keys). ``check_rep=False``: per-shard computation is
    independent, there is no replication to infer across lanes."""
    axes = PartitionSpec(tuple(mesh.axis_names))
    in_specs = tuple(axes if b else PartitionSpec() for b in batched)
    return jax.jit(shard_map(inner, mesh=mesh, in_specs=in_specs,
                             out_specs=axes, check_rep=False))


def sharded_grid_call(inner, args: tuple, batched: tuple, n_points: int,
                      mesh=None):
    """Run ``inner(*args)`` with batched args sharded over the mesh.

    ``inner`` must be the engine's *unjitted* vmapped function (shapes
    [G, ...] on batched args); callers invoke this inside their own
    ``jax.experimental.enable_x64()`` scope — padding concatenates in
    jnp and must not downcast float64. Pads the grid axis to a multiple
    of the device count, dispatches one compiled shard_map call, slices
    outputs back to ``n_points``."""
    mesh = mesh if mesh is not None else grid_mesh()
    pad = (-n_points) % mesh.size
    if pad:
        args = tuple(_pad0(a, pad) if b else a
                     for a, b in zip(args, batched))
    out = _sharded_fn(inner, mesh, tuple(batched))(*args)
    if pad:
        out = jax.tree_util.tree_map(lambda x: x[:n_points], out)
    return out
