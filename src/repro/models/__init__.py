"""JAX model zoo: ten assigned architectures behind one functional API.

>>> cfg = get_config("gemma2-2b", reduced=True)
>>> params = init_model(cfg, jax.random.PRNGKey(0))
>>> logits, caches, aux = forward(params, cfg, {"tokens": tok})
"""
from .config import ModelConfig  # noqa: F401
from .loss import lm_loss, masked_pred_loss  # noqa: F401
from .transformer import forward, init_caches, init_model  # noqa: F401
