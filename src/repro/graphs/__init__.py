"""Paper evaluation workloads as GEMM-sequence Tasks (Sec. 7): AlexNet,
ViT, Vision Mamba, HydraNet — plus conversion of any assigned-architecture
config into a Task for the TPU layout planner."""
from .alexnet import alexnet_task  # noqa: F401
from .hydranet import hydranet_task  # noqa: F401
from .vision_mamba import vision_mamba_task  # noqa: F401
from .vit import vit_task  # noqa: F401

WORKLOADS = {
    "alexnet": alexnet_task,
    "vit": vit_task,
    "vision_mamba": vision_mamba_task,
    "hydranet": hydranet_task,
}
