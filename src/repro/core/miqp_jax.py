"""Batched device-resident MIQP engine — exact lattice enumeration
(DESIGN.md §12).

The MILP path (:mod:`repro.core.miqp`) hands the Sec. 6.3 program to
HiGHS one instance at a time under a wall-clock budget, and approximates
the EDP product objective with an ε-constraint sweep. This module takes
the observation in that module's docstring to its conclusion: on the
paper's constrained search space (partitions are multiples of R within
±``slack`` units of uniform, Sec. 6.2) every integer variable has a
small one-hot domain — the feasible set is a finite *lattice* — so
instead of relaxing products into binary McCormick envelopes we
materialize candidate schedules as genome tensors and arg-min the
**exact** evaluator over them:

  * **Per-layer choice lattices** — for every op, the unit compositions
    of the padded row/column sums inside the Sec.-6.2 window, enumerated
    nearest-uniform-first (ordered by L1 deviation from the in-window
    anchor, lexicographic within a deviation level) and capped per
    axis/layer; an op's candidate set is the (rows × cols) product,
    ordered by combined deviation. Candidate 0 is always the anchor —
    the in-window projection of the LS-uniform split.
  * **Exact mode** — when the joint cross-product over ops fits
    ``cfg.candidate_budget``, every joint assignment is scored and the
    arg-min is the lattice optimum (exhaustive over the enumerated
    sets; globally exact whenever no cap bound).
  * **Beam mode** — otherwise a deterministic beam of
    ``cfg.beam_width`` assignments is extended layer by layer over the
    full per-layer sets (capturing the forward redistribution coupling
    between consecutive ops), then width-1 refinement sweeps re-scan
    every layer against the final assignment until a fixpoint or
    ``cfg.refine_sweeps``. Per-layer caps derive from
    ``cfg.eval_budget`` — a *deterministic* budget in scored genomes,
    not wall-clock, so a point's result is identical whether it is
    solved alone or batched in a sweep group (the §9 cache invariant;
    the GA gets the same property from seed-only RNG).
  * **Unit-move descent** — both modes finish with sum-preserving
    single-unit moves (the GA's mutation move, searched exhaustively):
    every (op, donor, receiver) R/C-unit transfer that stays inside the
    Sec.-6.2 window is scored at once, the best improving move per op
    is applied (joint application verified against a single-move
    fallback, so the objective is monotone), until a fixpoint or
    ``cfg.descent_sweeps``. This escapes the candidate caps — large
    grids win coordinated high-deviation patterns the nearest-uniform
    sets cannot reach — and is a no-op when the enumerated sets were
    complete (an exact-mode optimum is already unit-move optimal).
  * **Chunked scoring** — candidates are scored through the §8 jitted
    evaluator in fixed-shape chunks (grid axis = same-shape sweep
    points, population axis = candidate chunk, bucketed to powers of
    two ≤ ``cfg.score_chunk`` and padded with candidate 0, masked on
    the host), so a handful of compiled executables serve every chunk
    of every layer of every same-shape group. The numpy backend scores
    identical chunks through the reference evaluator and is the parity
    engine. EDP is an output key of the evaluator, so the product
    objective is scored directly — no ε-constraint sweep — and
    ``congestion="flow"`` simply traces the waterfilling netsim inside
    the same chunks (§11).

Like the MILP, the lattice fixes the non-partition genome dimensions the
way Sec. 6.3 does — collector column ``Y//2``, redistribution on every
semantically valid chained pair — and leaves them to ``api._polish``.
``sweep.solve_grid(..., method="miqp")`` batches same-shape grids
through :func:`solve_lattice_batch` exactly like GA islands.
"""
from __future__ import annotations

from typing import Sequence

import numpy as np

from .evaluator import EvalOptions, Evaluator, resolve_auto_backend
from .hw import HWConfig
from .miqp import MIQPConfig, MIQPResult, _unpad_rows
from .workload import Partition, Task, partition_domain

__all__ = ["OBJECTIVES", "axis_lattice", "layer_lattice",
           "solve_lattice_batch"]

#: Objective keys the lattice can minimize (evaluator outputs; the MILP
#: engine supports only latency/edp, and edp only via its ε-sweep).
OBJECTIVES = ("latency", "energy", "edp")

_MIN_CHUNK = 64


# ------------------------------------------------------------ enumeration
def _axis_anchor(S: int, parts: int, lo: int, hi: int) -> np.ndarray:
    """The in-window projection of the uniform split: ``parts`` unit
    counts in ``[lo, hi]`` summing to ``S``, as even as possible."""
    if not lo * parts <= S <= hi * parts:
        raise ValueError(f"infeasible axis window: {parts}x[{lo},{hi}] "
                         f"cannot sum to {S}")
    base, rem = divmod(S, parts)
    a = np.clip(np.full(parts, base, dtype=np.int64), lo, hi)
    a[:rem] = np.clip(a[:rem] + 1, lo, hi)
    resid = int(S - a.sum())
    while resid != 0:
        step = 1 if resid > 0 else -1
        for k in range(parts):
            if resid == 0:
                break
            if lo <= a[k] + step <= hi:
                a[k] += step
                resid -= step
    return a


def _repair_units(units: np.ndarray, S: int, lo: int, hi: int
                  ) -> np.ndarray:
    """Project an arbitrary unit vector into the axis window: clip to
    ``[lo, hi]``, then walk the residue one unit at a time (the same
    repair loop as :func:`_axis_anchor`). Used to turn external anchor
    proposals (e.g. the co-search projected-gradient seeds, DESIGN.md
    §16) into valid lattice anchors."""
    parts = len(units)
    if not lo * parts <= S <= hi * parts:
        raise ValueError(f"infeasible axis window: {parts}x[{lo},{hi}] "
                         f"cannot sum to {S}")
    a = np.clip(np.asarray(units, dtype=np.int64), lo, hi)
    resid = int(S - a.sum())
    while resid != 0:
        step = 1 if resid > 0 else -1
        for k in range(parts):
            if resid == 0:
                break
            if lo <= a[k] + step <= hi:
                a[k] += step
                resid -= step
    return a


def _monotone_axis(S: int, parts: int, lo: int, hi: int, cap: int
                   ) -> tuple[list[tuple[int, ...]], bool]:
    """All non-decreasing unit compositions of ``S`` into ``parts``
    entries within ``[lo, hi]`` (the window is entry-independent, so
    monotone value vectors are placement families)."""
    out: list[tuple[int, ...]] = []
    v = [0] * parts

    def rec(k: int, prev: int, rem: int) -> bool:
        left = parts - k
        if k == parts:
            if rem == 0:
                out.append(tuple(v))
                return len(out) < cap
            return True
        lo_k = max(lo, prev, rem - hi * (left - 1))
        hi_k = min(hi, rem - lo * (left - 1))
        for val in range(lo_k, hi_k + 1):
            v[k] = val
            if not rec(k + 1, val, rem - val):
                return False
        return True

    complete = rec(0, lo, S)
    return out, complete


def axis_lattice(S: int, parts: int, lo: int, hi: int, cap: int,
                 anchor: np.ndarray | None = None,
                 ) -> tuple[np.ndarray, np.ndarray, bool]:
    """Enumerate unit compositions of ``S`` into ``parts`` entries within
    ``[lo, hi]``, structured-candidates-first.

    The list opens with the **ridge family** — every monotone
    composition, emitted in both placements (non-increasing, then
    non-decreasing), ordered by L1 deviation from the in-window uniform
    anchor. Monotone-by-position patterns are what the serialization
    maxima of eqs. 8–12 reward (trade compute balance against
    entrance-distance-weighted delivery), and they reach arbitrarily
    high deviation at tiny candidate cost, where exhaustive
    nearest-uniform enumeration drowns. The remaining slots fill with
    the general enumeration ordered by L1 deviation (lexicographic in
    deviation space within a level), deduplicated — so candidate 0 is
    always the anchor and small caps keep global structure *and* the
    near-uniform neighbourhood.

    Returns ``(units [C, parts], l1 [C], complete)``; ``complete`` means
    the *general* enumeration finished before hitting ``cap`` (the set
    is the full window lattice).

    ``anchor`` (optional) recenters the enumeration on an external unit
    vector instead of the in-window uniform projection — deviation
    ordering, ridge ranking and the dfs budget levels all measure L1
    distance from it, so a capped lattice keeps the *anchor's*
    neighbourhood (how the co-search gradient seeds prune the MIQP
    enumeration, DESIGN.md §16). The anchor is window-repaired and
    emitted as candidate 0; ``anchor=None`` preserves the uniform-anchor
    lattice bit-for-bit.
    """
    if anchor is None:
        a = _axis_anchor(S, parts, lo, hi)
    else:
        a = _repair_units(anchor, S, lo, hi)
    seen: set[tuple[int, ...]] = set()
    out: list[tuple[int, ...]] = []

    def push(vec) -> bool:
        t = tuple(int(x) for x in vec)
        if t not in seen:
            seen.add(t)
            out.append(t)
        return len(out) < cap

    if anchor is not None:
        # A custom anchor need not be monotone — emit it explicitly so
        # candidate 0 is the anchor under any cap.
        push(a)
    ridge, ridge_complete = _monotone_axis(S, parts, lo, hi, cap)
    ridge = sorted(ridge, key=lambda t: (int(np.abs(np.array(t) - a).sum()),
                                         t))
    capped = False
    for t in ridge:
        if not (push(t[::-1]) and push(t)):
            capped = True
            break

    dlo = (lo - a).astype(int)
    dhi = (hi - a).astype(int)
    # Suffix feasibility bounds for pruning: achievable remaining sum and
    # remaining L1 capacity from position k onward.
    smin = np.concatenate([np.cumsum(dlo[::-1])[::-1], [0]])
    smax = np.concatenate([np.cumsum(dhi[::-1])[::-1], [0]])
    capl1 = np.concatenate(
        [np.cumsum(np.maximum(np.abs(dlo), np.abs(dhi))[::-1])[::-1], [0]])
    d = [0] * parts

    def dfs(k: int, cur_sum: int, rem_l1: int) -> bool:
        """Emit deviation vectors spending exactly ``rem_l1`` more L1;
        returns False once the cap is hit."""
        if k == parts:
            if cur_sum == 0 and rem_l1 == 0:
                return push(np.asarray(d) + a)
            return True
        need = -cur_sum
        if not (smin[k] <= need <= smax[k]):
            return True
        if abs(need) > rem_l1 or rem_l1 > capl1[k] \
                or (rem_l1 - abs(need)) % 2:
            return True
        for v in range(max(dlo[k], -rem_l1), min(dhi[k], rem_l1) + 1):
            d[k] = v
            if not dfs(k + 1, cur_sum + v, rem_l1 - abs(v)):
                d[k] = 0
                return False
            d[k] = 0
        return True

    complete = ridge_complete and not capped
    if not capped:
        for budget in range(0, int(capl1[0]) + 1, 2):
            if not dfs(0, 0, budget):
                complete = False
                break
    units = np.asarray(out, dtype=np.int64).reshape(len(out), parts)
    return units, np.abs(units - a).sum(axis=1), complete


def layer_lattice(task: Task, hw: HWConfig, cfg: MIQPConfig,
                  anchor: Partition | None = None) -> list[dict]:
    """Per-op candidate sets, ordered by combined row+column deviation
    from uniform. Each entry holds the R/C *unit* vectors (``ux [C, X]``,
    ``uy [C, Y]``, the descent phase moves in this space), the un-padded
    exact-sum partition values (``px``, ``py`` — what the evaluator
    scores), and a ``complete`` flag (no cap bound).

    ``anchor`` (optional :class:`Partition`) recenters each op's axis
    lattices on the anchor's rows instead of the uniform projection —
    value-space rows convert back to units via ``ceil(p / unit)``, the
    inverse of the ``unpad(u·unit)`` emission."""
    X, Y = hw.X, hw.Y
    lo, hi = partition_domain(task, X, Y, hw.R, hw.C, cfg.slack)
    out = []
    for i, op in enumerate(task.ops):
        Mu = int(np.ceil(op.M / hw.R))
        Nu = int(np.ceil(op.N / hw.C))
        ax = ay = None
        if anchor is not None:
            ax = np.ceil(anchor.Px[i] / hw.R).astype(np.int64)
            ay = np.ceil(anchor.Py[i] / hw.C).astype(np.int64)
        ux, l1x, cx = axis_lattice(Mu, X, int(lo[i, 0]), int(hi[i, 0]),
                                   cfg.max_axis_candidates, anchor=ax)
        uy, l1y, cy = axis_lattice(Nu, Y, int(lo[i, 1]), int(hi[i, 1]),
                                   cfg.max_axis_candidates, anchor=ay)
        # (rows × cols) pairs by combined axis *rank* (not raw L1 — the
        # axis lists lead with the ridge family, and rank order is what
        # keeps it alive under the layer cap); the stable argsort of the
        # row-major ravel keeps (jx, jy)-lex order within a level.
        comb = (np.arange(len(l1x))[:, None]
                + np.arange(len(l1y))[None, :]).ravel()
        order = np.argsort(comb, kind="stable")[:cfg.max_layer_candidates]
        jx, jy = order // len(l1y), order % len(l1y)
        out.append({
            "ux": ux[jx], "uy": uy[jy],
            "px": _unpad_rows(ux[jx] * hw.R, op.M),
            "py": _unpad_rows(uy[jy] * hw.C, op.N),
            "complete": (cx and cy
                         and comb.size <= cfg.max_layer_candidates),
        })
    return out


class _Space:
    """One point's enumerated search lattice + its Sec.-6.2 windows."""

    def __init__(self, task: Task, hw: HWConfig, cfg: MIQPConfig,
                 anchor: Partition | None = None):
        self.task = task
        self.hw = hw
        lo, hi = partition_domain(task, hw.X, hw.Y, hw.R, hw.C, cfg.slack)
        self.lo, self.hi = lo, hi
        self.cands = layer_lattice(task, hw, cfg, anchor=anchor)
        self.sizes = [len(c["px"]) for c in self.cands]
        self.joint = int(np.prod(self.sizes, dtype=object))
        self.complete = all(c["complete"] for c in self.cands)

    def recap(self, cap: int) -> None:
        """Beam mode: shrink every layer to its budget-derived cap. The
        sets are deviation-ordered, so slicing keeps the nearest-uniform
        candidates (and candidate 0 stays the anchor)."""
        for c in self.cands:
            c["complete"] = c["complete"] and len(c["px"]) <= cap
            for k in ("ux", "uy", "px", "py"):
                c[k] = c[k][:cap]
        self.sizes = [len(c["px"]) for c in self.cands]

    def genome(self, assign: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
        """``assign [P, n]`` candidate indices → ``(Px [P, n, X],
        Py [P, n, Y])`` float64 genome tensors.

        Indices are clipped to each layer's candidate count: lockstep
        group phases extend up to the group-wide max per layer, and a
        smaller point's out-of-range columns are placeholders whose
        scores the caller masks to +inf before any selection — they
        only need to score *something* without faulting."""
        Px = np.stack([
            self.cands[i]["px"][np.minimum(assign[:, i],
                                           self.sizes[i] - 1)]
            for i in range(assign.shape[1])], axis=1)
        Py = np.stack([
            self.cands[i]["py"][np.minimum(assign[:, i],
                                           self.sizes[i] - 1)]
            for i in range(assign.shape[1])], axis=1)
        return Px.astype(np.float64), Py.astype(np.float64)

    def units(self, assign: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
        """``assign [n]`` → unit-count vectors ``(ux [n, X], uy [n, Y])``
        — the descent phase's working representation."""
        ux = np.stack([self.cands[i]["ux"][assign[i]]
                       for i in range(len(assign))])
        uy = np.stack([self.cands[i]["uy"][assign[i]]
                       for i in range(len(assign))])
        return ux, uy

    def unpad(self, Ux: np.ndarray, Uy: np.ndarray
              ) -> tuple[np.ndarray, np.ndarray]:
        """Unit tensors ``[P, n, X/Y]`` → exact-sum genome tensors."""
        Px = np.empty(Ux.shape, dtype=np.float64)
        Py = np.empty(Uy.shape, dtype=np.float64)
        for i, op in enumerate(self.task.ops):
            Px[:, i] = _unpad_rows(Ux[:, i] * self.hw.R, op.M)
            Py[:, i] = _unpad_rows(Uy[:, i] * self.hw.C, op.N)
        return Px, Py


# --------------------------------------------------------------- scoring
def _bucket(p: int, chunk: int) -> int:
    b = _MIN_CHUNK
    while b < min(p, chunk):
        b *= 2
    return b


class _GroupScorer:
    """Chunked exact scoring for one group of same-shape points (grid
    axis = points, population axis = candidate chunk; chunk shapes are
    bucketed to powers of two so a handful of compiled executables cover
    every call)."""

    def __init__(self, tasks, hws, spaces, options: EvalOptions,
                 objective: str, backend: str, chunk: int,
                 devices: str = "single"):
        self.spaces = spaces
        self.options = options
        self.objective = objective
        self.backend = backend
        self.chunk = chunk
        self.devices = devices
        self.evals = 0
        self.evs = [Evaluator(t, h, options, backend="numpy")
                    for t, h in zip(tasks, hws)]
        n = len(tasks[0])
        self.co = np.stack([np.full(n, h.Y // 2, dtype=np.float64)
                            for h in hws])
        self.rd = np.stack([
            (ev.chain_valid & options.redistribution).astype(np.float64)
            for ev in self.evs])
        if backend == "jax":
            consts = [ev.consts() for ev in self.evs]
            self._stacked = {k: np.stack([c[k] for c in consts])
                             for k in consts[0]}

    def _score_genomes(self, Px: np.ndarray, Py: np.ndarray) -> np.ndarray:
        """``Px [G, P, n, X]``, ``Py [G, P, n, Y]`` → ``[G, P]``. P must
        already be a bucket size (callers pad)."""
        G, P = Px.shape[:2]
        co = np.broadcast_to(self.co[:, None], (G, P, self.co.shape[1]))
        rd = np.broadcast_to(self.rd[:, None], (G, P, self.rd.shape[1]))
        if self.backend == "jax":
            from . import evaluator_jax

            vals = evaluator_jax.grid_evaluate(
                self._stacked, self.options, Px, Py, co, rd,
                devices=self.devices,
            )[self.objective]
        else:
            vals = np.stack([
                self.evs[g].evaluate_batch(Px[g], Py[g], co[g],
                                           rd[g])[self.objective]
                for g in range(G)])
        self.evals += G * P
        return np.asarray(vals)

    def _chunked(self, P: int, make_genomes) -> np.ndarray:
        """Drive ``make_genomes(s, e, pad)`` → (Px, Py) chunk factories
        through bucketed scoring calls; returns ``[G, P]``."""
        G = len(self.spaces)
        out = np.empty((G, P), dtype=np.float64)
        s = 0
        while s < P:
            e = min(s + self.chunk, P)
            b = _bucket(e - s, self.chunk)
            Px, Py = make_genomes(s, e, b - (e - s))
            out[:, s:e] = self._score_genomes(Px, Py)[:, : e - s]
            s = e
        return out

    def score(self, assign: np.ndarray) -> np.ndarray:
        """``assign [G, P, n]`` candidate indices → objectives ``[G, P]``
        float64. Pad columns (candidate 0) never reach an arg-min —
        callers mask by per-point length."""
        G, P, n = assign.shape

        def make(s, e, pad):
            blk = assign[:, s:e]
            if pad:
                blk = np.concatenate(
                    [blk, np.zeros((G, pad, n), dtype=assign.dtype)],
                    axis=1)
            Px = np.stack([sp.genome(blk[g])[0]
                           for g, sp in enumerate(self.spaces)])
            Py = np.stack([sp.genome(blk[g])[1]
                           for g, sp in enumerate(self.spaces)])
            return Px, Py

        return self._chunked(P, make)

    def score_units(self, Ux: np.ndarray, Uy: np.ndarray) -> np.ndarray:
        """``Ux [G, P, n, X]``, ``Uy [G, P, n, Y]`` unit tensors →
        objectives ``[G, P]`` (descent phase)."""
        G, P = Ux.shape[:2]

        def make(s, e, pad):
            bx, by = Ux[:, s:e], Uy[:, s:e]
            if pad:
                bx = np.concatenate([bx, bx[:, :1].repeat(pad, 1)], axis=1)
                by = np.concatenate([by, by[:, :1].repeat(pad, 1)], axis=1)
            Px = np.empty(bx.shape, dtype=np.float64)
            Py = np.empty(by.shape, dtype=np.float64)
            for g, sp in enumerate(self.spaces):
                Px[g], Py[g] = sp.unpad(bx[g], by[g])
            return Px, Py

        return self._chunked(P, make)


# ----------------------------------------------------------------- modes
def _solve_exact(spaces: Sequence[_Space], scorer: _GroupScorer
                 ) -> tuple[np.ndarray, np.ndarray]:
    """Score every joint assignment (mixed-radix over the per-layer
    sets); returns per-point (best assignment [G, n], best objective)."""
    G = len(spaces)
    n = len(spaces[0].sizes)
    chunk = scorer.chunk
    T = np.array([sp.joint for sp in spaces], dtype=np.int64)
    strides = []
    for sp in spaces:
        st = np.ones(n, dtype=np.int64)
        for i in range(n - 2, -1, -1):
            st[i] = st[i + 1] * sp.sizes[i + 1]
        strides.append(st)
    best = np.full(G, np.inf)
    best_a = np.zeros((G, n), dtype=np.int64)
    for s in range(0, int(T.max()), chunk):
        width = min(chunk, int(T.max()) - s)
        ids = np.arange(s, s + width, dtype=np.int64)
        assign = np.zeros((G, width, n), dtype=np.int64)
        for g, sp in enumerate(spaces):
            t = np.minimum(ids, T[g] - 1)
            assign[g] = (t[:, None] // strides[g][None]) \
                % np.asarray(sp.sizes, dtype=np.int64)[None]
        sc = scorer.score(assign)
        sc[ids[None, :] >= T[:, None]] = np.inf
        j = np.argmin(sc, axis=1)
        for g in range(G):
            if sc[g, j[g]] < best[g]:
                best[g] = sc[g, j[g]]
                best_a[g] = assign[g, j[g]]
    return best_a, best


def _solve_beam(spaces: Sequence[_Space], scorer: _GroupScorer,
                cfg: MIQPConfig) -> tuple[np.ndarray, np.ndarray]:
    """Deterministic beam over layers + width-1 refinement sweeps.
    Returns (best assignment [G, n], best objective [G])."""
    G = len(spaces)
    n = len(spaces[0].sizes)
    W = max(1, cfg.beam_width)
    sizes = np.array([sp.sizes for sp in spaces])          # [G, n]
    beam = np.zeros((G, W, n), dtype=np.int64)
    bsc = np.full((G, W), np.inf)
    bsc[:, :1] = scorer.score(beam[:, :1, :])
    for i in range(n):
        Cmax = int(sizes[:, i].max())
        ext = np.repeat(beam, Cmax, axis=1)                # [G, W·Cmax, n]
        cand = np.tile(np.arange(Cmax), W)
        ext[:, :, i] = cand[None, :]
        sc = scorer.score(ext)
        invalid = (cand[None, :] >= sizes[:, i][:, None]) \
            | np.repeat(~np.isfinite(bsc), Cmax, axis=1)
        sc[invalid] = np.inf
        order = np.argsort(sc, axis=1, kind="stable")[:, :W]
        for g in range(G):
            beam[g] = ext[g, order[g]]
            bsc[g] = sc[g, order[g]]
    best_a, best = beam[:, 0].copy(), bsc[:, 0].copy()
    for _ in range(max(0, cfg.refine_sweeps)):
        improved = False
        for i in range(n):
            Cmax = int(sizes[:, i].max())
            ext = np.repeat(best_a[:, None, :], Cmax, axis=1)
            ext[:, :, i] = np.arange(Cmax)[None, :]
            sc = scorer.score(ext)
            sc[np.arange(Cmax)[None, :] >= sizes[:, i][:, None]] = np.inf
            j = np.argmin(sc, axis=1)
            for g in range(G):
                if sc[g, j[g]] < best[g]:
                    best[g] = sc[g, j[g]]
                    best_a[g] = ext[g, j[g]]
                    improved = True
        if not improved:
            break
    return best_a, best


def _pair_refine(spaces: Sequence[_Space], scorer: _GroupScorer,
                 best_a: np.ndarray, best: np.ndarray,
                 cfg: MIQPConfig) -> tuple[np.ndarray, np.ndarray]:
    """Joint re-scan of chained layer pairs: for every (i, i+1) with a
    semantically valid chain (the pairs coupled through the Sec.-5.2
    crossing term and the keep-A input mask), score the top-k × top-k
    product of both layers' candidate sets against the current
    assignment and keep strict improvements. Width-1 refinement cannot
    cross these plateaus — the per-op terms of two tied placements are
    equal and only their *joint* alignment moves the crossing max. k is
    derived deterministically from ``cfg.eval_budget`` (≤ a quarter of
    it across all pairs) and capped at ``cfg.pair_refine``."""
    if cfg.pair_refine < 2:
        return best_a, best
    G = len(spaces)
    n = len(spaces[0].sizes)
    chains = [np.where(scorer.evs[g].chain_valid)[0] for g in range(G)]
    pairs = sorted({int(i) for cv in chains for i in cv if i + 1 < n})
    if not pairs:
        return best_a, best
    # k is a *per-point* function of that point's own chain count — a
    # point's result must not depend on which group solved it (§9 cache
    # invariant); the lockstep loop runs over the union of pairs and
    # masks each point to its own k.
    kg = np.array([
        min(cfg.pair_refine,
            max(2, int(np.sqrt(cfg.eval_budget
                               // max(1, 4 * len(chains[g]))))))
        for g in range(G)])
    sizes = np.array([sp.sizes for sp in spaces])          # [G, n]
    for i in pairs:
        ka = int(np.minimum(kg, sizes[:, i]).max())
        kb = int(np.minimum(kg, sizes[:, i + 1]).max())
        ext = np.repeat(best_a[:, None, :], ka * kb, axis=1)
        a_idx = np.repeat(np.arange(ka), kb)
        b_idx = np.tile(np.arange(kb), ka)
        ext[:, :, i] = a_idx[None, :]
        ext[:, :, i + 1] = b_idx[None, :]
        sc = scorer.score(ext)
        lim_a = np.minimum(kg, sizes[:, i])[:, None]
        lim_b = np.minimum(kg, sizes[:, i + 1])[:, None]
        invalid = (a_idx[None, :] >= lim_a) \
            | (b_idx[None, :] >= lim_b) \
            | ~np.array([i in chains[g] for g in range(G)])[:, None]
        sc[invalid] = np.inf
        j = np.argmin(sc, axis=1)
        for g in range(G):
            if sc[g, j[g]] < best[g]:
                best[g] = sc[g, j[g]]
                best_a[g] = ext[g, j[g]]
    return best_a, best


def _unit_descent(spaces: Sequence[_Space], scorer: _GroupScorer,
                  Ux: np.ndarray, Uy: np.ndarray, cur: np.ndarray,
                  cfg: MIQPConfig) -> tuple[np.ndarray, np.ndarray,
                                            np.ndarray, np.ndarray]:
    """Exhaustive sum-preserving local search from the beam/exact
    solution (``Ux [G, n, X]``, ``Uy [G, n, Y]`` unit counts, ``cur
    [G]`` their objectives). Every sweep scores two deterministic move
    families at once — single-unit (op, donor, receiver) transfers (the
    GA's mutation move) and entry *transpositions* (swap two shares of
    one op's axis, which crosses the placement plateaus that
    single-unit paths cannot: permuting a share vector changes the
    Sec.-5.2 crossing terms against its chained neighbours while
    keeping every per-entry window constraint), and *range swaps* —
    the same row transposition applied to a whole chained span of ops
    at once (chained neighbours with aligned placements must move
    together or the crossing max punishes every intermediate step) —
    applies the best improving move per op jointly (verified, falling
    back to the single best move if the joint step is not an
    improvement; range swaps participate keyed by their first op), and
    stops at a fixpoint or ``cfg.descent_sweeps``. Deterministic,
    strictly monotone. Returns updated units, objectives, and
    per-point accepted-move counts."""
    G, n, X = Ux.shape
    Y = Uy.shape[2]
    mv_x = [(i, d, r) for i in range(n) for d in range(X)
            for r in range(X) if d != r]
    mv_y = [(i, d, r) for i in range(n) for d in range(Y)
            for r in range(Y) if d != r]
    sw_x = [(i, d, r) for i in range(n) for d in range(X)
            for r in range(d + 1, X)]
    sw_y = [(i, d, r) for i in range(n) for d in range(Y)
            for r in range(d + 1, Y)]
    # Chained range swaps: spans [a, b] whose interior pairs are all
    # chain-valid on at least one point (per-point validity masked
    # below), capped at length 8 — the Sec.-5.2 coupling radius worth
    # paying for.
    chain_any = np.zeros(n, dtype=bool)
    for sp in spaces:
        cv = np.zeros(n, dtype=bool)
        for i in range(n - 1):
            cv[i] = bool(sp.task.ops[i + 1].chained)
        chain_any |= cv
    rg_x = [(a, b, d, r)
            for a in range(n) for b in range(a + 1, min(n, a + 8))
            if chain_any[a:b].all()
            for d in range(X) for r in range(d + 1, X)]
    P = len(mv_x) + len(mv_y) + len(sw_x) + len(sw_y) + len(rg_x)
    moves = np.zeros(G, dtype=np.int64)
    if P == 0:
        return Ux, Uy, cur, moves
    ix, dx, rx = (np.array([m[k] for m in mv_x], dtype=np.int64)
                  for k in range(3))
    iy, dy, ry = (np.array([m[k] for m in mv_y], dtype=np.int64)
                  for k in range(3))
    sxi, sxd, sxr = (np.array([m[k] for m in sw_x], dtype=np.int64)
                     for k in range(3))
    syi, syd, syr = (np.array([m[k] for m in sw_y], dtype=np.int64)
                     for k in range(3))
    # Range swaps are excluded from the per-op joint step (they span
    # several ops); they compete through the single-best-move path,
    # which copies the full proposal.
    op_of = np.concatenate([ix, iy, sxi, syi,
                            np.full(len(rg_x), -1, dtype=np.int64)])
    lo_x = np.stack([sp.lo[:, 0] for sp in spaces])        # [G, n]
    hi_x = np.stack([sp.hi[:, 0] for sp in spaces])
    lo_y = np.stack([sp.lo[:, 1] for sp in spaces])
    hi_y = np.stack([sp.hi[:, 1] for sp in spaces])
    rg_chain = np.array([[all(sp.task.ops[i + 1].chained
                              for i in range(a, b))
                          for (a, b, d, r) in rg_x]
                         for sp in spaces]).reshape(G, len(rg_x))
    for _ in range(max(0, cfg.descent_sweeps)):
        pUx = np.repeat(Ux[:, None], P, axis=1)            # [G, P, n, X]
        pUy = np.repeat(Uy[:, None], P, axis=1)
        ax = np.arange(len(mv_x))
        pUx[:, ax, ix, dx] -= 1
        pUx[:, ax, ix, rx] += 1
        ay = len(mv_x) + np.arange(len(mv_y))
        pUy[:, ay, iy, dy] -= 1
        pUy[:, ay, iy, ry] += 1
        asx = len(mv_x) + len(mv_y) + np.arange(len(sw_x))
        pUx[:, asx, sxi, sxd] = Ux[:, sxi, sxr]
        pUx[:, asx, sxi, sxr] = Ux[:, sxi, sxd]
        asy = len(mv_x) + len(mv_y) + len(sw_x) + np.arange(len(sw_y))
        pUy[:, asy, syi, syd] = Uy[:, syi, syr]
        pUy[:, asy, syi, syr] = Uy[:, syi, syd]
        rg_valid = np.zeros((G, len(rg_x)), dtype=bool)
        arg = len(mv_x) + len(mv_y) + len(sw_x) + len(sw_y)
        for q, (a, b, d, r) in enumerate(rg_x):
            span = slice(a, b + 1)
            pUx[:, arg + q, span, d] = Ux[:, span, r]
            pUx[:, arg + q, span, r] = Ux[:, span, d]
            rg_valid[:, q] = rg_chain[:, q] & (Ux[:, span, d]
                                               != Ux[:, span, r]).any(axis=1)
        valid = np.concatenate([
            (Ux[:, ix, dx] - 1 >= lo_x[:, ix])
            & (Ux[:, ix, rx] + 1 <= hi_x[:, ix]),
            (Uy[:, iy, dy] - 1 >= lo_y[:, iy])
            & (Uy[:, iy, ry] + 1 <= hi_y[:, iy]),
            Ux[:, sxi, sxd] != Ux[:, sxi, sxr],   # swaps: window-free,
            Uy[:, syi, syd] != Uy[:, syi, syr],   # no-ops masked out
            rg_valid,
        ], axis=1)                                         # [G, P]
        sc = scorer.score_units(pUx, pUy)
        sc[~valid] = np.inf
        improving = sc < cur[:, None]
        if not improving.any():
            break
        # Joint candidate: best improving move per op, all applied.
        jUx, jUy = Ux.copy(), Uy.copy()
        n_chosen = np.zeros(G, dtype=np.int64)
        for g in range(G):
            for i in range(n):
                mask = improving[g] & (op_of == i)
                if not mask.any():
                    continue
                j = int(np.argmin(np.where(mask, sc[g], np.inf)))
                jUx[g, i] = pUx[g, j, i]
                jUy[g, i] = pUy[g, j, i]
                n_chosen[g] += 1
        ver = scorer.score_units(jUx[:, None], jUy[:, None])[:, 0]
        for g in range(G):
            if not improving[g].any():
                continue
            j = int(np.argmin(sc[g]))
            if n_chosen[g] > 1 and ver[g] < min(cur[g], sc[g, j]):
                Ux[g], Uy[g], cur[g] = jUx[g], jUy[g], ver[g]
                moves[g] += n_chosen[g]
            else:
                Ux[g], Uy[g], cur[g] = pUx[g, j], pUy[g, j], sc[g, j]
                moves[g] += 1
    return Ux, Uy, cur, moves


# ------------------------------------------------------------ entry point
def solve_lattice_batch(
    tasks: Sequence[Task],
    hws: Sequence[HWConfig],
    options: EvalOptions,
    objective: str,
    cfg: MIQPConfig,
    anchors: Sequence[Partition | None] | None = None,
) -> list[MIQPResult]:
    """Solve one MIQP lattice search per (task, hw) point through batched
    scoring calls. All points must share a shape signature (n_ops, X, Y,
    n_entrances) — :func:`repro.core.sweep.solve_grid` does the grouping;
    a solo :func:`repro.core.miqp.run_miqp` call is the ``G=1`` case of
    the same deterministic program, so results are identical either way.
    Returns one :class:`repro.core.miqp.MIQPResult` per point, aligned
    with the inputs.

    ``anchors`` (optional, per point, entries may be ``None``) recenters
    each point's lattice enumeration on an external :class:`Partition`
    proposal (see :func:`layer_lattice`) — capped enumerations then
    spend their candidate budget around the proposal instead of the
    uniform split. ``anchors=None`` is the classic uniform-anchored
    search, bit-for-bit."""
    if objective not in OBJECTIVES:
        raise ValueError(f"unknown objective {objective!r}; "
                         f"one of {OBJECTIVES}")
    G = len(tasks)
    assert G == len(hws) and G > 0
    if anchors is not None and len(anchors) != G:
        raise ValueError(f"anchors must align with points: "
                         f"{len(anchors)} != {G}")
    backend = resolve_auto_backend(cfg.backend, cfg.score_chunk)
    n = len(tasks[0])
    spaces = [_Space(t, h, cfg,
                     anchor=None if anchors is None else anchors[g])
              for g, (t, h) in enumerate(zip(tasks, hws))]

    # Mode is a per-point decision (it must not depend on grouping).
    exact = [g for g in range(G)
             if spaces[g].joint <= max(1, cfg.candidate_budget)]
    beam = [g for g in range(G) if g not in exact]
    results: list[MIQPResult | None] = [None] * G

    def run_subset(idxs: list[int], mode: str) -> None:
        sub = [spaces[g] for g in idxs]
        if mode == "beam":
            # Deterministic per-layer cap from the eval budget: one beam
            # pass costs ~W candidates per layer slot, each refinement
            # sweep ~1 (descent is bounded separately by its move count).
            cap = max(1, cfg.eval_budget // max(
                1, n * (cfg.beam_width + max(1, cfg.refine_sweeps))))
            cap = min(cap, cfg.max_layer_candidates)
            for sp in sub:
                sp.recap(cap)
        scorer = _GroupScorer([tasks[g] for g in idxs],
                              [hws[g] for g in idxs], sub, options,
                              objective, backend, cfg.score_chunk,
                              devices=getattr(cfg, "devices", "single"))
        if mode == "exact":
            best_a, best = _solve_exact(sub, scorer)
        else:
            best_a, best = _solve_beam(sub, scorer, cfg)
            best_a, best = _pair_refine(sub, scorer, best_a, best, cfg)
        Ux = np.stack([sp.units(best_a[k])[0] for k, sp in enumerate(sub)])
        Uy = np.stack([sp.units(best_a[k])[1] for k, sp in enumerate(sub)])
        Ux, Uy, best, moves = _unit_descent(sub, scorer, Ux, Uy, best, cfg)
        for k, g in enumerate(idxs):
            task, hw = tasks[g], hws[g]
            Px, Py = sub[k].unpad(Ux[k][None], Uy[k][None])
            part = Partition(Px[0].astype(np.int64),
                             Py[0].astype(np.int64),
                             np.full(n, hw.Y // 2, dtype=np.int64))
            part.validate(task)
            rd = scorer.evs[k].chain_valid & options.redistribution
            if mode == "exact":
                status = (f"lattice exact: {sub[k].joint} candidates"
                          + ("" if sub[k].complete else " (capped sets)")
                          + f", +{moves[k]} descent moves")
            else:
                status = (f"lattice beam: W={cfg.beam_width}, "
                          f"cap={max(sub[k].sizes)}, "
                          f"+{moves[k]} descent moves")
            mobj = float(best[k]) * 1e6 if objective == "latency" else -1.0
            results[g] = MIQPResult(part, rd, float(best[k]), status,
                                    mobj, engine="lattice")

    if exact:
        run_subset(exact, "exact")
    if beam:
        run_subset(beam, "beam")
    return results  # type: ignore[return-value]
