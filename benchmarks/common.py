"""Shared benchmark plumbing: timing, CSV emission, result caching."""
from __future__ import annotations

import json
import os
import time

ART = os.path.join(os.path.dirname(__file__), "artifacts")
os.makedirs(ART, exist_ok=True)

_FORCE_FLAG = "--xla_force_host_platform_device_count"


def force_host_devices(n: int) -> int:
    """Carve the host CPU into ``n`` virtual XLA devices by setting
    ``XLA_FLAGS`` — **must run before the first jax import** (the flag
    is read once at backend initialization). An explicit
    ``--xla_force_host_platform_device_count`` already present in the
    environment wins (so CI matrix legs can pin the count); returns the
    device count that will be in effect."""
    existing = os.environ.get("XLA_FLAGS", "")
    if _FORCE_FLAG in existing:
        for tok in existing.split():
            if tok.startswith(_FORCE_FLAG + "="):
                return int(tok.split("=", 1)[1])
        return int(n)
    os.environ["XLA_FLAGS"] = (f"{_FORCE_FLAG}={int(n)} " + existing).strip()
    return int(n)


def apply_devices_flag(argv=None, default: int | None = None) -> int | None:
    """Pre-parse ``--devices N`` / ``--devices=N`` from ``argv`` (or
    ``sys.argv``) and apply :func:`force_host_devices` — call before any
    jax import so benchmark CLIs can vary the virtual device count.
    Returns the applied count, or ``None`` when no flag and no default.
    The argument is left in ``argv`` for the real argparse pass."""
    import sys

    argv = sys.argv[1:] if argv is None else list(argv)
    n = default
    for i, a in enumerate(argv):
        if a == "--devices" and i + 1 < len(argv):
            n = int(argv[i + 1])
        elif a.startswith("--devices="):
            n = int(a.split("=", 1)[1])
    if n is None:
        return None
    return force_host_devices(n)

_ROWS: list[tuple[str, float, str]] = []


def emit(name: str, us_per_call: float, derived: str = ""):
    """One CSV row: ``name,us_per_call,derived``."""
    _ROWS.append((name, us_per_call, derived))
    print(f"{name},{us_per_call:.3f},{derived}")


def rows():
    return list(_ROWS)


def timed(fn, *args, **kw):
    t0 = time.perf_counter()
    out = fn(*args, **kw)
    return out, (time.perf_counter() - t0) * 1e6


def save_json(name: str, data):
    path = os.path.join(ART, f"{name}.json")
    with open(path, "w") as f:
        json.dump(data, f, indent=1)
    return path


def geomean(xs):
    import numpy as np
    xs = np.asarray(list(xs), dtype=float)
    return float(np.exp(np.log(np.maximum(xs, 1e-12)).mean()))
