"""Roofline benchmark: the three-term table for every dry-run cell
(EXPERIMENTS.md §Roofline), from the compiled artifacts."""
from __future__ import annotations

import os

from repro.roofline import analyze_record, load_records
from repro.roofline.analysis import format_table

from .common import ART, emit, save_json


def main(mesh: str = "single_pod_16x16"):
    recs = load_records(os.path.join(ART, "dryrun"), mesh)
    if not recs:
        emit("roofline/no_artifacts", 0.0,
             "run `python -m repro.launch.dryrun --all --calibrate` first")
        return
    terms = [analyze_record(r) for r in recs]
    print(format_table(terms))
    table = {}
    for t in terms:
        key = f"{t.arch}/{t.shape}"
        table[key] = {
            "compute_s": t.compute_s,
            "memory_s": t.memory_s,
            "collective_s": t.collective_s,
            "dominant": t.dominant,
            "useful_ratio": t.useful_ratio,
            "roofline_fraction": t.roofline_fraction,
        }
        emit(f"roofline/{key}", t.bound_time * 1e6,
             f"dominant={t.dominant} roofline="
             f"{t.roofline_fraction*100:.1f}% useful={t.useful_ratio:.2f}")
    save_json(f"roofline_{mesh}", table)


if __name__ == "__main__":
    main()
