"""Pallas TPU GEMM with explicit VMEM tiling.

Output-stationary accumulation (the dataflow the paper's eq.-7 compute
model assumes): grid (M/bm, N/bn, K/bk) with K innermost — each (i, j)
tile's f32 accumulator lives in VMEM scratch across the K steps, and
blocks are MXU-aligned multiples of 128.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _matmul_kernel(a_ref, b_ref, o_ref, acc_ref, *, n_k: int):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(a_ref[...], b_ref[...],
                            preferred_element_type=jnp.float32)

    @pl.when(k == n_k - 1)
    def _done():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk", "interpret"))
def matmul(a: jnp.ndarray, b: jnp.ndarray, *, bm: int = 256, bn: int = 256,
           bk: int = 256, interpret: bool = False) -> jnp.ndarray:
    """C = A @ B with (M, K) x (K, N); pads every dim to its block."""
    M, K = a.shape
    K2, N = b.shape
    assert K == K2
    bm, bn, bk = min(bm, M), min(bn, N), min(bk, K)
    pm, pn, pk = (-M) % bm, (-N) % bn, (-K) % bk
    if pm or pk:
        a = jnp.pad(a, ((0, pm), (0, pk)))
    if pk or pn:
        b = jnp.pad(b, ((0, pk), (0, pn)))
    Mp, Kp, Np = M + pm, K + pk, N + pn
    n_k = Kp // bk
    out = pl.pallas_call(
        functools.partial(_matmul_kernel, n_k=n_k),
        grid=(Mp // bm, Np // bn, n_k),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((Mp, Np), a.dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(a, b)
    return out[:M, :N]
