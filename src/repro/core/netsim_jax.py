"""JAX backend for the flow-level netsim — jitted max-min waterfilling.

Port of the vectorized numpy engine in :mod:`repro.core.netsim`
(:func:`~repro.core.netsim.waterfill_rates` /
:func:`~repro.core.netsim.simulate_flows`) to ``lax.while_loop``, so that

  * whole (mesh × memory × placement × bandwidth) grids batch through ONE
    compiled call (:func:`simulate_pull_batch` — ``vmap`` over a leading
    grid axis, mirroring the ``evaluator``/``evaluator_jax`` contract of
    DESIGN.md §8/§11), and
  * the evaluator's ``congestion="flow"`` mode can trace the simulation
    inside its own jit (:func:`waterfill_times` is a pure traced
    function of ``(cap, incidence, bytes)``).

Shapes are the only compile-time statics: the :mod:`repro.core.topology`
link space is a pure function of (X, Y) — every memory placement /
bandwidth cell of a grid is data, not structure — so one executable
serves the entire grid. All entry points run under
``jax.experimental.enable_x64()`` (same float64 rule, and the same
leak-containment scoping, as :mod:`repro.core.evaluator_jax`).

Numerics note: each waterfilling iteration retires the argmin-share
bottleneck link exactly like the numpy engine, and the event loop uses
the same ``EPS_BYTES`` completion threshold — completion times agree
with both host engines to float64 round-off
(``tests/test_core_netsim.py`` enforces the three-way contract).
"""
from __future__ import annotations

import functools

import numpy as np

import jax
import jax.numpy as jnp
from jax import lax

from .netsim import EPS_BYTES, MAX_EVENTS

__all__ = ["waterfill_rates", "waterfill_times", "simulate_pull_batch"]


def waterfill_rates(inc, cap, active):
    """Max-min fair rates (traced): ``inc [F, L]``, ``cap [L]``,
    ``active [F]`` (float 0/1) → rates ``[F]``. Progressive filling via
    ``lax.while_loop`` — at least one link retires per iteration."""
    F = inc.shape[0]

    def users_of(unfixed):
        return unfixed @ inc                              # [L]

    def cond(state):
        _, unfixed, _ = state
        return jnp.any(users_of(unfixed) > 0)

    def body(state):
        residual, unfixed, rates = state
        users = users_of(unfixed)
        live = users > 0
        share = jnp.where(live, residual / jnp.where(live, users, 1.0),
                          jnp.inf)
        l = jnp.argmin(share)
        s = share[l]
        newly = (unfixed > 0) & (inc[:, l] > 0)
        rates = jnp.where(newly, s, rates)
        residual = jnp.maximum(
            residual - (newly.astype(inc.dtype) @ inc) * s, 0.0)
        unfixed = jnp.where(newly, 0.0, unfixed)
        return residual, unfixed, rates

    init = (cap, active.astype(inc.dtype), jnp.zeros(F, dtype=inc.dtype))
    _, _, rates = lax.while_loop(cond, body, init)
    return rates


def waterfill_times(cap, inc, message_bytes):
    """Traced event-driven simulation of ``F`` concurrent flows.

    Line-for-line port of :func:`repro.core.netsim.simulate_flows`:
    each event solves the waterfilling fixed point, advances to the next
    completion, retires finished flows. Returns ``(latency, done [F],
    link_bytes [L])``. Usable inside an outer jit/vmap (the evaluator's
    flow mode vmaps it over the op axis)."""
    F, L = inc.shape
    bytes0 = message_bytes.astype(inc.dtype)

    def cond(state):
        bytes_left, _, _, _, it = state
        return jnp.any(bytes_left > EPS_BYTES) & (it < MAX_EVENTS)

    def body(state):
        bytes_left, t, done, link_bytes, it = state
        active = bytes_left > EPS_BYTES
        rates = waterfill_rates(inc, cap, active.astype(inc.dtype))
        pos = active & (rates > 0)
        dt = jnp.min(jnp.where(
            pos, bytes_left / jnp.where(pos, rates, 1.0), jnp.inf))
        moved = jnp.where(active, rates * dt, 0.0)
        link_bytes = link_bytes + jnp.minimum(moved, bytes_left) @ inc
        bytes_left = jnp.maximum(bytes_left - moved, 0.0)
        newly = active & (bytes_left <= EPS_BYTES)
        done = jnp.where(newly, t + dt, done)
        return bytes_left, t + dt, done, link_bytes, it + 1

    init = (bytes0, jnp.asarray(0.0, dtype=inc.dtype),
            jnp.zeros(F, dtype=inc.dtype), jnp.zeros(L, dtype=inc.dtype),
            jnp.asarray(0, dtype=jnp.int32))
    bytes_left, t, done, link_bytes, _ = lax.while_loop(cond, body, init)
    # Parity with the numpy reference's loud failure: a run that exits
    # with unfinished flows (event-guard hit, or a zero-rate stall whose
    # dt=inf poisoned the carry) must not report a silently truncated
    # latency — surface NaN instead, matching simulate_flows' RuntimeError.
    bad = jnp.any(bytes_left > EPS_BYTES) | ~jnp.isfinite(t)
    nan = jnp.asarray(jnp.nan, dtype=inc.dtype)
    return (jnp.where(bad, nan, t), jnp.where(bad, nan, done),
            jnp.where(bad, nan, link_bytes))


@functools.lru_cache(maxsize=None)
def _batch_inner():
    """``vmap(waterfill_times)`` over a leading grid axis — unjitted, so
    it doubles as the shard_map target of the sharded sweep fabric
    (DESIGN.md §15)."""
    def one(cap, inc, msg):
        t, done, link_bytes = waterfill_times(cap, inc, msg)
        return {"latency": t, "done": done, "link_bytes": link_bytes}

    return jax.vmap(one)


@functools.lru_cache(maxsize=None)
def _batch_fn():
    """``jit(vmap(waterfill_times))`` — one compiled executable per
    (G, F, L) shape signature (cached by jit)."""
    return jax.jit(_batch_inner())


def simulate_pull_batch(caps, incs, msgs,
                        devices: str = "single") -> dict[str, np.ndarray]:
    """Batched flow simulation: ``caps [G, L]``, ``incs [G, F, L]``,
    ``msgs [G, F]`` → dict of numpy float64 arrays (``latency [G]``,
    ``done [G, F]``, ``link_bytes [G, L]``). One compiled call per shape
    signature covers the whole grid; ``devices`` (DESIGN.md §15) shards
    the grid axis across local devices — a sharded grid also runs each
    shard's lockstep ``while_loop`` only as long as its *local* slowest
    point, not the global one."""
    from . import sweep_shard

    G = int(np.shape(caps)[0])
    with jax.experimental.enable_x64():
        args = (jnp.asarray(caps, dtype=jnp.float64),
                jnp.asarray(incs, dtype=jnp.float64),
                jnp.asarray(msgs, dtype=jnp.float64))
        if sweep_shard.resolve_devices(devices, G) == "sharded":
            out = sweep_shard.sharded_grid_call(
                _batch_inner(), args, (True, True, True), G)
        else:
            out = _batch_fn()(*args)
        return {k: np.asarray(v) for k, v in out.items()}
