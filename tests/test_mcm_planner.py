"""Property + contract tests for the TPU layout planner
(``sharding/mcm_planner``): conservation of work under partitioning,
executable knobs, non-negative headroom, calibrated-profile plumbing, and
the plan → dryrun round-trip the validation gate relies on
(DESIGN.md §17)."""
import json

import jax
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.configs import SHAPE_DEFS, get_config
from repro.core.evaluator import EvalOptions, Evaluator
from repro.core.workload import uniform_partition
from repro.sharding.mcm_planner import arch_to_task, plan, tpu_hw

ZOO = ("smollm-360m", "internlm2-20b", "rwkv6-3b", "mixtral-8x22b")
MESHES = ((1, 1), (2, 2), (4, 2), (4, 4))


def _total_partitioned_flops(task, X, Y):
    """FLOPs summed tile-by-tile over an X×Y uniform partition."""
    part = uniform_partition(task, X, Y)
    total = 0
    for i, op in enumerate(task.ops):
        total += 2 * int(part.Px[i].sum()) * op.K * int(part.Py[i].sum())
    return total


@settings(max_examples=24, deadline=None)
@given(st.sampled_from(ZOO), st.sampled_from(MESHES),
       st.integers(min_value=1, max_value=3))
def test_partition_conserves_flops_and_bytes(arch, mesh_shape, layers):
    """arch_to_task GEMM chains conserve FLOPs/bytes across mesh shapes:
    partitioning never creates or destroys work, and the task's totals
    don't depend on the grid it will be scored on."""
    cfg = get_config(arch)
    task = arch_to_task(cfg, 256, 8, layers=layers)
    X, Y = mesh_shape
    part = uniform_partition(task, X, Y)
    for i, op in enumerate(task.ops):
        assert int(part.Px[i].sum()) == op.M
        assert int(part.Py[i].sum()) == op.N
    assert _total_partitioned_flops(task, X, Y) == task.total_flops
    # byte totals come from the task alone — identical across grids
    ref = arch_to_task(cfg, 256, 8, layers=layers).arrays()
    for key in ("M", "K", "N", "w_scale"):
        assert np.array_equal(ref[key], task.arrays()[key])


def test_task_flops_linear_in_layers():
    for arch in ZOO:
        cfg = get_config(arch)
        f1 = arch_to_task(cfg, 128, 4, layers=1).total_flops
        f2 = arch_to_task(cfg, 128, 4, layers=2).total_flops
        f4 = arch_to_task(cfg, 128, 4, layers=4).total_flops
        # affine in L (the lm_head is the constant term)
        assert f4 - f2 == 2 * (f2 - f1)
        assert f2 > f1


def test_task_models_lm_head():
    cfg = get_config("smollm-360m")
    names = [op.name for op in arch_to_task(cfg, 128, 4, layers=1).ops]
    assert names[-1] == "lm_head"


@pytest.mark.parametrize("batch", [1, 2, 3, 6, 8])
def test_plan_knobs_always_executable(batch):
    """Redistribution mask ⊆ chained pairs, microbatch divides batch,
    headroom never below 1 (the planner only adopts a GA win)."""
    cfg = get_config("smollm-360m")
    pr = plan(cfg, (2, 2), 128, batch, layers=1, ga_budget=2)
    accum = pr.knobs["accum_steps"]
    assert batch % accum == 0
    hw = tpu_hw((2, 2))
    task = arch_to_task(cfg, 128, max(batch // 4, 1) * 4, layers=1)
    ev = Evaluator(task, hw, EvalOptions(redistribution=True))
    assert np.all(pr.redist_mask <= ev.chain_valid)
    assert pr.nonuniform_headroom >= 1.0
    assert pr.knobs["shard_residual"] == bool(pr.redist_mask.any())
    knobs = pr.to_dryrun_knobs()
    assert set(knobs) == {"shard_residual", "accum"}
    assert isinstance(knobs["shard_residual"], bool)
    assert isinstance(knobs["accum"], int)


def test_tpu_hw_profile_rescales_constants():
    from repro.kernels.calibrate import CalibratedHW
    prof = CalibratedHW(backend="cpu", flops_per_s=1e11, bytes_per_s=1e10,
                        byte_overhead=2.0)
    base = tpu_hw((4, 2))
    hw = tpu_hw((4, 2), profile=prof)
    assert hw.X == base.X and hw.Y == base.Y and hw.R == base.R
    assert hw.freq_hz == pytest.approx(1e11 / (2 * 128 * 128))
    assert hw.bw_mem == pytest.approx(5e9 * 8)     # ideal-byte basis × chips
    assert hw.bw_nop == pytest.approx(5e9 * prof.nop_frac)
    # plan() accepts the profile and still returns a valid result
    pr = plan(get_config("smollm-360m"), (2, 2), 128, 4, layers=1,
              ga_budget=2, profile=prof)
    assert pr.optimized_latency > 0


def test_plan_roundtrips_into_dryrun_artifact():
    """Acceptance criterion: a planner-chosen layout compiles through
    launch/dryrun — execute_plan lowers, compiles, and costs the plan's
    knobs and returns a JSON-serializable artifact record."""
    from repro.launch.dryrun import execute_plan

    arch = "smollm-360m"
    cfg = get_config(arch, reduced=True)
    n = len(jax.devices())
    d = 2 if n % 2 == 0 and n >= 2 else 1
    mesh = jax.make_mesh((d, n // d), ("data", "model"))
    pr = plan(cfg, (d, n // d), 64, 8, layers=cfg.n_layers, ga_budget=2)
    shape = "__test_plan_roundtrip"
    SHAPE_DEFS[shape] = dict(seq_len=64, global_batch=8, kind="prefill")
    try:
        rec = execute_plan(pr, arch, shape, mesh, mesh_name="test",
                           cfg=cfg, serve_fsdp=("data",))
    finally:
        SHAPE_DEFS.pop(shape, None)
    assert rec["flops_per_device"] > 0
    assert rec["plan"]["knobs"]["shard_residual"] == \
        pr.knobs["shard_residual"]
    assert rec["plan"]["knobs"]["accum"] == pr.knobs["accum_steps"]
    assert rec["plan"]["redist_mask"] == [int(b) for b in pr.redist_mask]
    assert rec["plan"]["nonuniform_headroom"] >= 1.0
    json.dumps(rec)        # artifact-serializable end to end
