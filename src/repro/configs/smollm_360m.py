"""smollm-360m [dense]: 32L d_model=960 15H (GQA kv=5) d_ff=2560
vocab=49152 — llama-arch small [hf:HuggingFaceTB/SmolLM-360M; hf]."""
from ..models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="smollm-360m", family="dense",
        n_layers=32, d_model=960, d_ff=2560, vocab_size=49152,
        n_heads=15, n_kv_heads=5, d_head=64,
        act="silu", tie_embeddings=True,
    )


def reduced_config() -> ModelConfig:
    return config().replace(
        name="smollm-smoke", n_layers=3, d_model=60, d_ff=96,
        vocab_size=256, n_heads=3, n_kv_heads=1, d_head=20,
        attn_chunk=32, remat=False)
