"""Distribution tests on a small host-device mesh: partition-spec rules,
logical sharding sanitization, and a reduced-scale lower+compile of the
dry-run machinery (the full 512-device run is `repro.launch.dryrun`)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import Mesh, PartitionSpec as P

from repro.configs import get_config
from repro.sharding.logical import sanitize_spec, shard, use_rules
from repro.sharding.partition_specs import (activation_rules, data_specs,
                                            param_specs)

N_DEV = len(jax.devices())


def small_mesh():
    n = N_DEV
    d = 2 if n % 2 == 0 and n >= 2 else 1
    return jax.make_mesh((d, n // d), ("data", "model"))


def test_sanitize_spec_drops_nondivisible():
    mesh = jax.make_mesh((1,), ("data",))
    s = sanitize_spec(P("data"), (7,), mesh)
    assert s == P(None) or s == P("data")  # size-1 axis always divides
    mesh2 = jax.make_mesh((1,), ("model",))
    del mesh2


def test_param_specs_cover_all_archs():
    mesh = small_mesh()
    for arch in ("smollm-360m", "mixtral-8x22b", "zamba2-2.7b",
                 "rwkv6-3b", "deepseek-v2-236b"):
        cfg = get_config(arch, reduced=True)
        from repro.models import init_model
        shapes = jax.eval_shape(
            lambda: init_model(cfg, jax.random.PRNGKey(0)))
        specs = param_specs(shapes, mesh)
        # every leaf got a spec of matching rank
        def check(s, l):
            assert len(s) == len(l.shape)
            for d, entry in enumerate(s):
                if entry is None:
                    continue
                axes = entry if isinstance(entry, tuple) else (entry,)
                size = 1
                for a in axes:
                    size *= mesh.shape[a]
                assert l.shape[d] % size == 0
        jax.tree.map(check, specs, shapes)


def test_shard_noop_outside_rules():
    x = jnp.ones((4, 4))
    assert shard(x, "act_btd") is x


def test_shard_applies_constraint_under_jit():
    mesh = small_mesh()
    rules = activation_rules(mesh)

    def f(x):
        return shard(x, "act_btf") * 2

    with use_rules(mesh, rules):
        lowered = jax.jit(f).lower(
            jax.ShapeDtypeStruct((4, 8, mesh.shape["model"] * 4),
                                 jnp.float32))
        txt = lowered.as_text()
    assert "sharding" in txt


@pytest.mark.parametrize("arch", ["smollm-360m", "mixtral-8x22b",
                                  "zamba2-2.7b", "rwkv6-3b"])
def test_reduced_dryrun_compiles(arch):
    """lower+compile a reduced config train step on the host mesh —
    the same machinery the 512-device dry-run uses."""
    from repro.train import adamw
    from repro.train.train_step import init_train_state, make_train_step
    cfg = get_config(arch, reduced=True)
    mesh = small_mesh()
    rules = activation_rules(mesh)
    opt = adamw()
    step = make_train_step(cfg, opt)
    with use_rules(mesh, rules):
        from repro.models import init_model
        shapes = jax.eval_shape(lambda: init_train_state(
            init_model(cfg, jax.random.PRNGKey(0)), opt))
        from repro.sharding.partition_specs import param_shardings
        from jax.sharding import NamedSharding
        sh = {
            "params": param_shardings(shapes["params"], mesh),
            "opt": {"m": param_shardings(shapes["opt"]["m"], mesh),
                    "v": param_shardings(shapes["opt"]["v"], mesh),
                    "count": NamedSharding(mesh, P())},
            "step": NamedSharding(mesh, P()),
        }
        state_abs = jax.tree.map(
            lambda s, h: jax.ShapeDtypeStruct(s.shape, s.dtype,
                                              sharding=h), shapes, sh)
        batch = {"tokens": jax.ShapeDtypeStruct((8, 64), jnp.int32)}
        if cfg.frontend == "vision_stub":
            batch["patches"] = jax.ShapeDtypeStruct(
                (8, cfg.frontend_tokens, cfg.frontend_dim), jnp.bfloat16)
        compiled = jax.jit(step, in_shardings=(sh, None)).lower(
            state_abs, batch).compile()
    from repro.launch.dryrun import cost_analysis_dict
    assert cost_analysis_dict(compiled).get("flops", 0) > 0


def test_collective_bytes_parser():
    from repro.launch.dryrun import collective_bytes
    hlo = """
  %ar = bf16[16,128]{1,0} all-reduce(%x), replica_groups={}
  %ag = f32[4,256]{1,0} all-gather(%y), dimensions={0}
  %rs = bf16[2,64]{1,0} reduce-scatter(%z), dimensions={0}
  %cp = f32[8]{0} collective-permute(%w)
  %other = f32[8]{0} add(%a, %b)
"""
    out = collective_bytes(hlo)
    assert out["all-reduce"] == 16 * 128 * 2
    assert out["all-gather"] == 4 * 256 * 4
    assert out["reduce-scatter"] == 2 * 64 * 2
    assert out["collective-permute"] == 32
    assert "add" not in out


def test_runnable_cells_skips_documented():
    from repro.configs import runnable_cells
    cells = runnable_cells()
    assert ("hubert-xlarge", "decode_32k") not in cells
    assert ("hubert-xlarge", "long_500k") not in cells
    assert ("gemma2-2b", "long_500k") not in cells
    assert ("zamba2-2.7b", "long_500k") in cells
    assert ("rwkv6-3b", "long_500k") in cells
    assert ("mixtral-8x22b", "long_500k") in cells
    assert len(cells) == 32


def test_dryrun_import_leaves_xla_flags_untouched():
    """Regression: importing launch/dryrun as a library must not mutate
    XLA_FLAGS (it used to force 512 host devices at import time, fighting
    benchmarks/common.py:force_host_devices). Topology selection belongs
    to the CLI entrypoint (ensure_virtual_devices) alone."""
    import os
    import subprocess
    import sys

    src = os.path.join(os.path.dirname(__file__), "..", "src")
    code = (
        "import os\n"
        "os.environ.pop('XLA_FLAGS', None)\n"
        "import repro.launch.dryrun as d\n"
        "assert 'XLA_FLAGS' not in os.environ, os.environ.get('XLA_FLAGS')\n"
        "d.ensure_virtual_devices(4)\n"
        "assert os.environ['XLA_FLAGS'] == "
        "'--xla_force_host_platform_device_count=4'\n"
        "os.environ['XLA_FLAGS'] = "
        "'--xla_force_host_platform_device_count=2'\n"
        "d.ensure_virtual_devices(512)\n"   # explicit setting wins
        "assert os.environ['XLA_FLAGS'] == "
        "'--xla_force_host_platform_device_count=2'\n"
    )
    env = dict(os.environ)
    env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
    proc = subprocess.run([sys.executable, "-c", code], env=env,
                          capture_output=True, text=True, timeout=300)
    assert proc.returncode == 0, proc.stderr


def test_mcm_planner():
    from repro.sharding.mcm_planner import arch_to_task, plan, tpu_hw
    cfg = get_config("internlm2-20b")
    task = arch_to_task(cfg, 1024, 16, layers=2)
    assert len(task) > 4
    hw = tpu_hw((4, 4))
    assert hw.R == 128 and hw.mcm_type.value == "C"
    r = plan(cfg, (4, 4), 512, 16, layers=2, ga_budget=5)
    assert r.baseline_latency > 0
    assert r.optimized_latency <= r.baseline_latency * 1.001
    assert r.nonuniform_headroom >= 0.99
