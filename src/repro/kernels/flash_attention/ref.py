"""Pure-jnp oracle for attention (the ``ref.py`` contract).

Direct softmax(Q·Kᵀ)·V with GQA head grouping, causal / sliding-window /
cache-length masking and Gemma-style logit soft-capping. O(S²) memory —
use only for oracle comparisons and small shapes; the model path uses
:mod:`.blockwise` and the TPU path uses the Pallas kernel.
"""
from __future__ import annotations

import jax.numpy as jnp

NEG_INF = -2.0e38


def attention_ref(
    q: jnp.ndarray,            # (B, Sq, H, Dh)
    k: jnp.ndarray,            # (B, Skv, KV, Dh)
    v: jnp.ndarray,            # (B, Skv, KV, Dh)
    *,
    causal: bool = True,
    window: int | None = None,
    softcap: float | None = None,
    q_offset: int | jnp.ndarray = 0,
    kv_len: jnp.ndarray | None = None,   # (B,) valid cache length
    scale: float | None = None,
) -> jnp.ndarray:
    B, Sq, H, Dh = q.shape
    _, Skv, KV, _ = k.shape
    G = H // KV
    if scale is None:
        scale = 1.0 / jnp.sqrt(float(Dh))
    qg = q.reshape(B, Sq, KV, G, Dh)
    scores = jnp.einsum("bqkgd,bskd->bkgqs", qg.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    if softcap is not None:
        scores = softcap * jnp.tanh(scores / softcap)
    qpos = q_offset + jnp.arange(Sq)                    # (Sq,)
    kpos = jnp.arange(Skv)                              # (Skv,)
    mask = jnp.ones((Sq, Skv), dtype=bool)
    if causal:
        mask &= kpos[None, :] <= qpos[:, None]
    if window is not None:
        mask &= kpos[None, :] > qpos[:, None] - window
    mask = jnp.broadcast_to(mask[None], (B, Sq, Skv))
    if kv_len is not None:
        mask &= kpos[None, None, :] < kv_len[:, None, None]
    scores = jnp.where(mask[:, None, None], scores, NEG_INF)
    p = jnp.exp(scores - scores.max(axis=-1, keepdims=True))
    p = p / p.sum(axis=-1, keepdims=True)
    out = jnp.einsum("bkgqs,bskd->bqkgd", p, v.astype(jnp.float32))
    return out.reshape(B, Sq, H, v.shape[-1]).astype(q.dtype)
