"""Three-term roofline analysis from the compiled dry-run artifacts.

    compute term    = HLO_FLOPs_global / (chips × peak_FLOP/s)
    memory term     = HLO_bytes_global / (chips × HBM_bw)
    collective term = collective_bytes_global / (chips × link_bw)

``cost_analysis()`` reports *per-device* FLOPs/bytes for the SPMD-
partitioned module, and the HLO collective byte counts are also
per-device, so the global quantities are (per-device × chips) and each
term reduces to per_device_quantity / per_chip_peak.

MODEL_FLOPS uses 6·N·D for training (N params, D tokens; N_active for
MoE) and 2·N·D for inference; the ratio MODEL_FLOPS / HLO_FLOPs exposes
remat/redundancy waste (>1/3 of compiled compute being recompute is the
remat signature).
"""
from __future__ import annotations

import dataclasses
import json
import os

from ..configs import SHAPE_DEFS, get_config

# TPU v5e per-chip constants (assignment-specified).
PEAK_FLOPS = 197e12        # bf16
HBM_BW = 819e9             # bytes/s
LINK_BW = 50e9             # bytes/s per ICI link


@dataclasses.dataclass
class RooflineTerms:
    arch: str
    shape: str
    mesh: str
    kind: str
    compute_s: float
    memory_s: float          # fusion-aware analytic HBM estimate
    collective_s: float
    model_flops: float
    hlo_flops_global: float
    per_device_hbm_bytes: float
    collective_breakdown: dict
    hlo_bytes_s: float = 0.0  # raw (unfused) HLO byte term — upper bound

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def bound_time(self) -> float:
        """Roofline-model step time = max of the three terms."""
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def useful_ratio(self) -> float:
        """MODEL_FLOPS / global HLO FLOPs (≤1 without remat; <1 means the
        compiled graph burns FLOPs on recompute/redundancy; >1 flags an
        HLO count that misses fused ops)."""
        return (self.model_flops / self.hlo_flops_global
                if self.hlo_flops_global > 0 else 0.0)

    @property
    def roofline_fraction(self) -> float:
        """Fraction of the chips' peak compute the bound-time achieves on
        *useful* (model) FLOPs — the score §Perf drives up."""
        t = self.bound_time
        if t <= 0:
            return 0.0
        n_chips = {"single_pod_16x16": 256, "multi_pod_2x16x16": 512}[
            self.mesh]
        return self.model_flops / t / (n_chips * PEAK_FLOPS)


def analytic_hbm_bytes(arch: str, shape: str) -> float:
    """Fusion-aware per-device HBM traffic estimate (bytes/step).

    XLA-CPU's ``bytes accessed`` counts every HLO op's operand/result
    bytes with no fusion model — a 10–100× overestimate of real HBM
    traffic (EXPERIMENTS.md §Roofline method notes). This estimate counts
    what *must* cross HBM on a TPU:

      train   : gathered-param reads per microbatch (fwd+bwd) over the
                TP shard, Adam state read+write, saved layer-boundary
                activations (write in fwd, read in bwd), token I/O
      prefill : one gathered-param read + KV-cache writes + boundary
                activations
      decode  : one gathered-param read (per-token weight streaming — the
                canonical decode bottleneck) + full cache read + write
    """
    from ..launch.policies import TRAIN_ACCUM, TRAIN_LOWMEM

    cfg = get_config(arch)
    sd = SHAPE_DEFS[shape]
    S, B = sd["seq_len"], sd["global_batch"]
    kind = sd["kind"]
    n_chips, tp, dp = 256, 16, 16

    P = float(cfg.param_count())
    act_p = _active_params(cfg)           # per-token touched params
    pb = 2.0                              # bf16 compute reads
    # gathered (full along data/FSDP axis) parameter bytes per TP shard;
    # MoE: only active experts' weights are read per token group, but
    # capacity-based dense dispatch touches all local experts — use full P
    param_read = P * pb / tp

    # serving state bytes per device
    cache_bytes = _cache_bytes(cfg, B, S) / n_chips

    if kind == "train":
        accum = TRAIN_ACCUM.get(arch, 1)
        opt_b = (2 + 2) if arch in TRAIN_LOWMEM else (4 + 4)
        pdtype = 2 if cfg.param_dtype == "bfloat16" else 4
        adam = P / n_chips * (2 * opt_b + 2 * pdtype + 2 * pb)  # m,v,p rw + grad rw
        tokens_dev = S * B / dp           # batch sharded over data axis
        # saved residuals: one (tokens, d_model) bf16 per layer, written
        # fwd + read bwd; sharded over model when shard_residual
        res_shard = tp if cfg.d_model >= 2048 else 1
        acts = (tokens_dev * cfg.d_model * 2.0 * cfg.n_layers * 2.0
                / res_shard)
        io = tokens_dev * 4.0 * 2
        return 2.0 * accum * param_read + adam + acts + io
    if kind == "prefill":
        tokens_dev = S * B / dp
        acts = tokens_dev * cfg.d_model * 2.0 * cfg.n_layers / tp
        return param_read + cache_bytes + acts
    # decode: stream weights once, read the whole cache, write one slot
    return param_read + cache_bytes


def _cache_bytes(cfg, batch: int, seq: int) -> float:
    """Total serving-state bytes across the pod for one model."""
    L = cfg.n_layers
    if cfg.family == "ssm":     # rwkv6
        H, K = cfg.rwkv_heads, cfg.rwkv_head_dim
        return L * batch * (H * K * K * 4.0 + 2 * cfg.d_model * 2.0)
    if cfg.family == "hybrid":
        di, H = cfg.d_inner, cfg.ssm_heads
        mamba = L * batch * (H * cfg.ssm_head_dim * cfg.ssm_state * 4.0
                             + (cfg.ssm_conv - 1)
                             * (di + 2 * cfg.ssm_state) * 2.0)
        n_groups = L // max(cfg.hybrid_attn_period, 1)
        win = min(seq, cfg.window or seq)
        attn = n_groups * batch * win * cfg.n_kv_heads * cfg.d_head \
            * 2 * 2.0
        return mamba + attn
    if cfg.attn_type == "mla":
        return L * batch * min(seq, 10**9) * (cfg.kv_lora_rank
                                              + cfg.qk_rope_dim) * 2.0
    win = min(seq, cfg.window or seq) if cfg.local_global_period == 0 \
        else seq  # gemma2: half local(window) + half global(full) ≈ avg
    if cfg.local_global_period:
        win = (min(seq, cfg.window) + seq) / 2
    return L * batch * win * cfg.n_kv_heads * cfg.d_head * 2 * 2.0


def _active_params(cfg) -> float:
    """Per-token active parameter count (MoE: top-k + shared experts)."""
    total = cfg.param_count()
    if not cfg.n_experts:
        return float(total)
    expert = 3 * cfg.d_model * cfg.moe_d_ff
    routed_all = cfg.n_experts * expert * (cfg.n_layers
                                           - cfg.first_dense_layers)
    active = (cfg.moe_top_k + cfg.n_shared_experts) * expert * (
        cfg.n_layers - cfg.first_dense_layers)
    return float(total - routed_all + active)


def model_flops_for(arch: str, shape: str) -> float:
    cfg = get_config(arch)
    sd = SHAPE_DEFS[shape]
    n_active = _active_params(cfg)
    if sd["kind"] == "train":
        tokens = sd["seq_len"] * sd["global_batch"]
        return 6.0 * n_active * tokens
    if sd["kind"] == "prefill":
        tokens = sd["seq_len"] * sd["global_batch"]
        return 2.0 * n_active * tokens
    # decode: one token per sequence
    return 2.0 * n_active * sd["global_batch"]


def analyze_record(rec: dict) -> RooflineTerms:
    n_dev = rec["n_devices"]
    src = rec.get("calibrated", rec)  # prefer loop-corrected quantities
    flops_dev = max(src["flops_per_device"], 0.0)
    bytes_dev = max(src["bytes_per_device"], 0.0)
    coll = src.get("collective_bytes_per_device", {})
    coll_dev = float(sum(coll.values()))
    hbm_dev = analytic_hbm_bytes(rec["arch"], rec["shape"])
    return RooflineTerms(
        arch=rec["arch"],
        shape=rec["shape"],
        mesh=rec["mesh"],
        kind=rec["kind"],
        compute_s=flops_dev / PEAK_FLOPS,
        memory_s=hbm_dev / HBM_BW,
        collective_s=coll_dev / LINK_BW,
        model_flops=model_flops_for(rec["arch"], rec["shape"]),
        hlo_flops_global=flops_dev * n_dev,
        per_device_hbm_bytes=hbm_dev,
        collective_breakdown=coll,
        hlo_bytes_s=bytes_dev / HBM_BW,
    )


def load_records(art_dir: str, mesh: str = "single_pod_16x16"
                 ) -> list[dict]:
    d = os.path.join(art_dir, mesh)
    out = []
    if not os.path.isdir(d):
        return out
    for f in sorted(os.listdir(d)):
        if f.endswith(".json"):
            with open(os.path.join(d, f)) as fh:
                out.append(json.load(fh))
    return out


def format_table(terms: list[RooflineTerms]) -> str:
    hdr = (f"{'arch':<18} {'shape':<12} {'comp_ms':>9} {'mem_ms':>9} "
           f"{'coll_ms':>9} {'bound':<10} {'useful':>7} {'roofline':>9}")
    rows = [hdr, "-" * len(hdr)]
    for t in terms:
        rows.append(
            f"{t.arch:<18} {t.shape:<12} {t.compute_s*1e3:>9.2f} "
            f"{t.memory_s*1e3:>9.2f} {t.collective_s*1e3:>9.2f} "
            f"{t.dominant:<10} {t.useful_ratio:>7.2f} "
            f"{t.roofline_fraction*100:>8.1f}%")
    return "\n".join(rows)
