"""Fig. 12 reproduction: low-bandwidth (DRAM) 4×4 type-A systems.

Paper claims: GA/MIQP latency speedups of 40%/72% over LS (EDP 28%/37%),
with the GA–MIQP gap *wider* than the HBM case (off-chip congestion
simplifies the on-chip scheduling space, so MIQP solves closer to
optimal within its budget).
"""
from __future__ import annotations

from repro.core import make_hw, optimize
from repro.core.ga import GAConfig
from repro.core.miqp import MIQPConfig
from repro.graphs import WORKLOADS

from .common import emit, geomean, save_json, timed

GA_CFG = GAConfig(generations=60, population=64)
MIQP_CFG = MIQPConfig(time_limit=60, edp_sweep=3)


def main(fast: bool = False):
    hw = make_hw("A", 4, "dram")
    results = {}
    wnames = ("alexnet", "hydranet") if fast else tuple(WORKLOADS)
    for objective in ("latency", "edp"):
        sp = {"ga": [], "miqp": []}
        for wname in wnames:
            task = WORKLOADS[wname](batch=1)
            base = optimize(task, hw, "baseline")
            ref = (base.baseline.latency if objective == "latency"
                   else base.baseline.edp)
            for method, kw in (("ga", {"ga_config": GA_CFG}),
                               ("miqp", {"miqp_config": MIQP_CFG})):
                r, us = timed(optimize, task, hw, method, objective, **kw)
                val = r.latency if objective == "latency" else r.edp
                sp[method].append(ref / val)
                results[f"{objective}/{wname}/{method}"] = ref / val
                emit(f"fig12/{objective}/{wname}/{method}", us,
                     f"speedup={ref/val:.3f}x")
        for m in sp:
            emit(f"fig12/{objective}/geomean/{m}", 0.0,
                 f"{(geomean(sp[m]) - 1) * 100:+.1f}% vs LS")
    save_json("fig12", results)


if __name__ == "__main__":
    main()
