"""Public entry points for the MCMComm core — the four scheduling schemes
of the paper's Table 3 behind one call, plus pipelining.

>>> from repro.core import api
>>> res = api.optimize(task, hw, method="miqp", objective="latency")
>>> res.latency, res.speedup_vs_baseline
"""
from __future__ import annotations

import dataclasses
import time

import numpy as np

from .evaluator import (EvalOptions, EvalResult, Evaluator,
                        resolve_auto_backend)
from .ga import GAConfig, run_ga
from .hw import HWConfig
from .miqp import MIQPConfig, run_miqp
from .pipelining import PipelineConfig, PipelineResult, pipeline_batch
from .simba import simba_partition
from .sweep import EvalPoint, eval_sweep
from .workload import Partition, Task, uniform_partition

__all__ = ["ScheduleResult", "optimize", "baseline_result",
           "refine_schedule", "cosearch", "METHODS"]

METHODS = ("baseline", "simba", "ga", "miqp")


@dataclasses.dataclass
class ScheduleResult:
    method: str
    objective: str
    partition: Partition
    redist_mask: np.ndarray
    eval: EvalResult
    baseline: EvalResult
    solve_seconds: float
    # Evaluation context (DESIGN.md §13): the task, the *actual* hw the
    # schedule was scored on (optimize() toggles diagonal links per
    # method), the EvalOptions used, and the scoring backend — what
    # segments() needs to re-derive per-op durations under a different
    # congestion model. Defaulted for back-compat construction.
    task: Task | None = None
    hw_used: HWConfig | None = None
    options: EvalOptions | None = None
    backend: str = "numpy"

    @property
    def latency(self) -> float:
        return self.eval.latency

    @property
    def edp(self) -> float:
        return self.eval.edp

    @property
    def speedup_vs_baseline(self) -> float:
        if self.objective == "edp":
            return self.baseline.edp / self.eval.edp
        return self.baseline.latency / self.eval.latency

    def segments(self, congestion: str | None = None
                 ) -> list[tuple[str, float, float, float]]:
        """Per-op ``(name, t_in, t_comp, t_out)`` durations of this
        schedule for the RCPSP pipeliner (Sec. 5.4 / DESIGN.md §13).

        ``congestion`` re-scores the schedule under a different
        congestion model (DESIGN.md §11) — ``"flow"`` makes the segment
        durations come from simulated netsim arrival times instead of
        the closed-form regime pick. Routed through the cached
        :func:`repro.core.sweep.eval_sweep`, so repeated pipelining
        studies on one schedule evaluate it once per congestion mode."""
        if congestion is None:
            return self.eval.segments()
        if self.options is None:
            # Back-compat construction without the context fields must
            # not silently return wrong-congestion durations.
            raise ValueError(
                "congestion-aware segments need the evaluation context "
                "(task/hw_used/options) — construct the ScheduleResult "
                "via optimize()")
        if congestion == self.options.congestion:
            return self.eval.segments()
        opts = dataclasses.replace(self.options, congestion=congestion)
        rec = eval_sweep([EvalPoint(self.task, self.hw_used, opts,
                                    self.partition, self.redist_mask)],
                         backend=self.backend)[0]
        return [(f"op{i}", float(rec["t_in"][i]), float(rec["t_comp"][i]),
                 float(rec["t_out"][i]))
                for i in range(len(rec["t_in"]))]

    def pipeline(self, batch: int, use_milp: bool = False,
                 config: PipelineConfig | None = None,
                 congestion: str | None = None) -> PipelineResult:
        """Cross-sample pipelining of this schedule (Sec. 5.4).

        ``config`` selects the scheduler engine (DESIGN.md §13);
        ``congestion="flow"`` derives the segment durations from netsim
        arrival times (see :meth:`segments`). Batched (workload × batch)
        grids should go through
        :func:`repro.core.sweep.pipeline_sweep` instead."""
        return pipeline_batch(self.segments(congestion), batch,
                              use_milp=use_milp, config=config)


def _polish(task: Task, hw: HWConfig, opts: EvalOptions, part: Partition,
            rd: np.ndarray, objective: str, rounds: int = 2,
            backend: str = "numpy") -> tuple[Partition, np.ndarray]:
    """Coordinate descent on variables MIQP keeps fixed or cannot see:
    collector columns, per-pair redistribution bits, and *placement* of the
    per-row/column shares. The MIQP solve uses the paper's sync
    approximation (max() per comm/comp pair), which is blind to which
    chiplet row carries which share — under fused (async) execution the
    busiest-compute row should sit nearest the entrance. Reordering a
    partition vector is sum-preserving, so these moves stay feasible."""
    ev = Evaluator(task, hw, opts, backend=backend)
    key = "edp" if objective == "edp" else "latency"

    def score(p, m):
        return getattr(ev.evaluate(p, m), key)

    best = score(part, rd)
    part = part.copy()
    rd = rd.copy()
    n = len(task)
    for _ in range(rounds):
        improved = False
        for i in range(n):
            # placement polish: try monotone orderings of the shares
            for arr in (part.Px[i], part.Py[i]):
                cur = arr.copy()
                for cand in (np.sort(cur)[::-1], np.sort(cur), cur[::-1]):
                    arr[:] = cand
                    s = score(part, rd)
                    if s < best - 1e-18:
                        best = s
                        cur = arr.copy()
                        improved = True
                    else:
                        arr[:] = cur
            if rd[i]:
                for c in range(hw.Y):
                    if c == part.collectors[i]:
                        continue
                    old = part.collectors[i]
                    part.collectors[i] = c
                    s = score(part, rd)
                    if s < best:
                        best = s
                        improved = True
                    else:
                        part.collectors[i] = old
            if ev.chain_valid[i]:
                rd[i] = not rd[i]
                s = score(part, rd)
                if s < best:
                    best = s
                    improved = True
                else:
                    rd[i] = not rd[i]
        if not improved:
            break
    return part, rd


def refine_schedule(task: Task, hw: HWConfig, options: EvalOptions,
                    partition: Partition, redist_mask: np.ndarray,
                    objective: str = "latency", backend: str = "numpy",
                    rounds: int = 2) -> tuple[Partition, np.ndarray]:
    """Public wrapper around the MIQP side-variable polish: exact-
    evaluator coordinate descent on collector columns, per-pair
    redistribution bits, and share placement — the variables both MIQP
    engines fix during the solve (DESIGN.md §6/§12). Batched sweeps use
    it to reproduce ``optimize(method="miqp")``'s polish step after a
    ``solve_grid(method="miqp")`` call."""
    return _polish(task, hw, options, partition, redist_mask, objective,
                   rounds=rounds, backend=backend)


def baseline_result(task: Task, hw: HWConfig,
                    backend: str = "numpy") -> EvalResult:
    """Layer-Sequential baseline: uniform partitioning, no optimizations
    (Table 3 row 1). Evaluated on the plain mesh (no diagonal links).

    Routed through :mod:`repro.core.sweep` so repeated baselines — every
    ``optimize`` call scores against one, and the figure sweeps share
    workloads — are evaluated once per process (DESIGN.md §9)."""
    hw0 = hw.replace(diagonal_links=False)
    rec = eval_sweep([EvalPoint(task, hw0)], backend=backend)[0]
    return EvalResult(
        latency=rec["latency"], energy=rec["energy"], edp=rec["edp"],
        t_in=rec["t_in"], t_comp=rec["t_comp"], t_out=rec["t_out"],
        redist=np.zeros(len(task), dtype=bool),
    )


def optimize(
    task: Task,
    hw: HWConfig,
    method: str = "miqp",
    objective: str = "latency",
    options: EvalOptions | None = None,
    ga_config: GAConfig | None = None,
    miqp_config: MIQPConfig | None = None,
    backend: str | None = None,
) -> ScheduleResult:
    """Run one scheduling scheme of Table 3 and score it against the LS
    baseline. ``ga``/``miqp`` enable the co-optimizations (diagonal links
    + redistribution; GA additionally uses async fusion); ``baseline`` and
    ``simba`` run without them, as in the paper's methodology.

    ``backend`` selects the evaluator engine (DESIGN.md §8) for the GA
    fitness loop, the baseline, and every scoring/polish pass; backends
    agree to float64 round-off (rtol 1e-9; identical GA trajectories
    under a fixed seed on CPU). ``None`` means numpy, except the ``ga``
    branch which follows ``ga_config.backend`` end-to-end (fitness and
    scoring always use the same engine). ``"auto"`` resolves by the GA
    population size (jax at ≥1024, DESIGN.md §8); ``ga_config.engine``
    additionally selects the evolution loop — ``"vectorized"`` with the
    jax backend runs the device-resident engine of DESIGN.md §10.
    ``miqp_config.engine`` likewise selects the MIQP solver engine
    (DESIGN.md §12): ``"milp"`` = the HiGHS program, ``"lattice"`` (the
    ``"auto"`` default) = batched exact enumeration of the Sec.-6.2
    search lattice, scored by the chosen evaluator backend."""
    scoring_backend = resolve_auto_backend(backend or "numpy", 1)
    base = baseline_result(task, hw, backend=scoring_backend)
    t0 = time.perf_counter()
    if method == "baseline":
        hw_used = hw.replace(diagonal_links=False)
        opts = EvalOptions()
        part = uniform_partition(task, hw.X, hw.Y)
        ev = Evaluator(task, hw_used, opts, backend=scoring_backend)
        res = ev.evaluate(part)
        rd = np.zeros(len(task), dtype=bool)
    elif method == "simba":
        hw_used = hw.replace(diagonal_links=False)
        opts = EvalOptions()
        part = simba_partition(task, hw_used)
        ev = Evaluator(task, hw_used, opts, backend=scoring_backend)
        res = ev.evaluate(part)
        rd = np.zeros(len(task), dtype=bool)
    elif method == "ga":
        opts = options or EvalOptions(redistribution=True, async_exec=True)
        hw_used = hw.replace(diagonal_links=True)
        cfg = ga_config or GAConfig()
        # Score with the engine the GA fitness actually ran on, so a
        # GAConfig(backend="jax") caller never silently mixes engines.
        ga_backend = resolve_auto_backend(backend or cfg.backend,
                                          cfg.population)
        scoring_backend = ga_backend
        out = run_ga(task, hw_used, objective, opts, cfg,
                     backend=ga_backend)
        part, rd = out.partition, out.redist_mask
        res = Evaluator(task, hw_used, opts,
                        backend=ga_backend).evaluate(part, rd)
    elif method == "miqp":
        # Solve under the paper's sync approximation (Sec. 6.3.2 adds max()
        # sync per comm/comp pair), then score the resulting partition under
        # the full runtime (same options as GA) and polish the discrete
        # side-variables (collectors, redistribution bits) with the exact
        # evaluator — MIQP fixes those during the solve. Both engines
        # (HiGHS milp / batched lattice, DESIGN.md §12) run the same
        # solve→polish→score pipeline; ``miqp_config.engine`` selects
        # (default "auto" → lattice), and an explicit ``backend`` also
        # drives the lattice engine's scoring chunks.
        solve_opts = EvalOptions(redistribution=True, async_exec=False)
        opts = options or EvalOptions(redistribution=True, async_exec=True)
        hw_used = hw.replace(diagonal_links=True)
        mcfg = miqp_config or MIQPConfig()
        if backend is not None:
            mcfg = dataclasses.replace(mcfg, backend=backend)
        out = run_miqp(task, hw_used, objective, solve_opts, mcfg)
        part, rd = out.partition, out.redist_mask
        part, rd = _polish(task, hw_used, opts, part, rd, objective,
                           backend=scoring_backend)
        res = Evaluator(task, hw_used, opts,
                        backend=scoring_backend).evaluate(part, rd)
    else:
        raise ValueError(f"unknown method {method!r}; one of {METHODS}")
    dt = time.perf_counter() - t0
    return ScheduleResult(method, objective, part, rd, res, base, dt,
                          task=task, hw_used=hw_used, options=opts,
                          backend=scoring_backend)


def cosearch(
    task: Task,
    hw: HWConfig,
    objective: str = "edp",
    options: EvalOptions | None = None,
    cfg=None,
    cache: bool = True,
    devices: str | None = None,
):
    """One-call front door for the fused joint search (DESIGN.md §16):
    partition × diagonal links × pipeline segmentation scored end-to-end
    in one jitted fitness. Returns a
    :class:`repro.core.cosearch.CoSearchResult` — the best genome on
    ``objective`` plus the batched Pareto front over (EDP, latency,
    energy). Unlike :func:`optimize`, the link configuration is *part of
    the genome* (``hw.diagonal_links`` is ignored), and the pipeline
    schedule is searched jointly instead of refined afterwards.

    Routes through :func:`repro.core.sweep.cosearch_sweep`, so results
    land in (and are served from) the §9 cache under the ``"cosearch"``
    method tag; ``cfg`` defaults to
    :class:`repro.core.cosearch.CoSearchConfig()`."""
    from .sweep import cosearch_sweep

    opts = options or EvalOptions(redistribution=True, async_exec=True)
    return cosearch_sweep([EvalPoint(task, hw, opts)], objective=objective,
                          cfg=cfg, cache=cache, devices=devices)[0]
