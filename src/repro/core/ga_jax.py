"""Device-resident GA engine — jit-fused evolution loop (DESIGN.md §10).

The python/numpy engines in :mod:`repro.core.ga` pay a host↔device round
trip per generation (fitness on device, genetic operators on host). This
module keeps the whole genome tensor on device and fuses fitness +
tournament selection + per-op uniform crossover + sum-preserving unit-move
mutation + collector/redist resampling into ONE jitted generation step,
driven by ``lax.scan`` in chunks of ~``patience`` generations:

  * **Genome layout on device** — ``Px [G, P, n, X]``, ``Py [G, P, n, Y]``,
    ``collectors [G, P, n]``, ``redist [G, P, n]``, all float64 (fitness
    needs float64 anyway and unit moves are exact in it). ``G`` is the
    *island* axis: :func:`solve_islands` evolves many same-shape sweep
    points' searches through one compiled call (``jit(vmap(scan(step)))``);
    a single :func:`run_ga_jax` search is the ``G=1`` special case of the
    same executable, so per-point results are identical whether a point is
    solved alone or inside a grid (the sweep-cache invariant).
  * **Chunked early stop** — the scan runs ``min(patience, remaining)``
    generations per compiled call and only then syncs the ``flat`` counters
    to the host, so early stopping costs one device→host transfer per
    ~``patience`` generations instead of one per generation. Islands whose
    ``flat`` counter reached ``patience`` freeze: the step computes the next
    epoch but keeps the old carry, so a done island's history/best/
    evaluations are exactly what a solo early-stopped run would report.
  * **RNG** — all randomness is ``jax.random`` (host init excepted: the
    initial population comes from the shared numpy init in
    :func:`repro.core.ga._random_population_vec`, so both vectorized
    engines start identically). numpy↔jax trajectory parity is therefore
    impossible; the cross-engine contract is property-based invariants plus
    fixed-seed solution-quality equivalence (DESIGN.md §10,
    ``tests/test_core_ga_engines.py``).

Static (compile-time) knobs: population/op/grid shapes, ``elite``,
``tournament``, ``freeze_redist``, the objective key, and the
:class:`EvalOptions` toggles. Everything else — mutation probabilities,
``patience``, domain windows, all evaluator constants — is traced, so one
executable serves every same-shape config (same sharing rule as
:mod:`repro.core.evaluator_jax`).
"""
from __future__ import annotations

import functools
from typing import Sequence

import numpy as np

import jax
import jax.numpy as jnp
from jax import lax, random

from .evaluator import EvalOptions, Evaluator
from .evaluator_jax import _eval_single
from .ga import MOVE_ATTEMPTS
from .hw import HWConfig
from .workload import Partition, Task, partition_domain

__all__ = ["run_ga_jax", "solve_islands"]

#: Objectives the fused step can minimize (keys of the evaluator output).
OBJECTIVES = ("latency", "energy", "edp")

# Carry tuple layout (all leaves carry a leading island axis under vmap):
# (Px, Py, collectors, redist, best_obj, best_Px, best_Py, best_co,
#  best_rd, flat, steps)
_BEST_OBJ, _FLAT, _STEPS = 4, 9, 10


def _move_units(u, P_, unit, lo, hi, active):
    """Device port of :func:`repro.core.ga._move_units_vec`: rejection-
    sampled sum-preserving unit moves over the whole ``[Q, n, X]`` tensor.
    Four fixed attempts (same constant as the host engines).

    ``u`` is a pre-drawn uniform block ``[2, MOVE_ATTEMPTS, Q, n]``
    (donor/receiver per attempt). Donor/receiver selection and the
    scatter update are expressed as iota-mask arithmetic rather than
    gather/one-hot ops — XLA-CPU lowers the masked form ~4× faster inside
    a vmapped scan, and it fuses with the surrounding elementwise work."""
    Q, n, X = P_.shape
    if X < 2:
        return P_
    iota = jnp.arange(X)[None, None, :]
    d_all = jnp.floor(u[0] * X).astype(jnp.int32)
    r_all = jnp.floor(u[1] * X).astype(jnp.int32)
    pending = active
    for t in range(MOVE_ATTEMPTS):
        d, r = d_all[t], r_all[t]
        dm = iota == d[..., None]
        rm = iota == r[..., None]
        dv = (P_ * dm).sum(-1)
        rv = (P_ * rm).sum(-1)
        ok = (pending & (d != r)
              & (dv - unit >= lo[None] * unit)
              & (rv + unit <= hi[None] * unit))
        delta = (rm.astype(P_.dtype) - dm.astype(P_.dtype)) * unit
        P_ = P_ + ok[..., None] * delta
        pending = pending & ~ok
    return P_


@functools.lru_cache(maxsize=None)
def _chunk_inner(elite: int, tournament: int, freeze_redist: bool,
                 objective: str, redistribution: bool, async_exec: bool,
                 energy_mode: str, congestion: str = "regime"):
    """Unjitted ``vmap(scan(generation-step))`` per static signature —
    the shard_map target of the sharded sweep fabric (DESIGN.md §15).

    Call as ``fn(consts, win, hp, carry, keys)`` with consts/win/carry
    stacked on a leading island axis and ``keys [L, 2]`` shared across
    islands (islands differ through their fitness landscape, not their
    random draws — which keeps a point's trajectory independent of which
    grid it is solved in)."""
    evalp = jax.vmap(
        functools.partial(_eval_single, redistribution=redistribution,
                          async_exec=async_exec, energy_mode=energy_mode,
                          congestion=congestion),
        in_axes=(None, 0, 0, 0, 0))

    def step(consts, win, hp, carry, key):
        (Px, Py, co, rd, best_obj, bPx, bPy, bco, brd, flat, steps) = carry
        pop, n, X = Px.shape
        Y = Py.shape[2]
        # steps > 0 mirrors the host engines' loop shape: generation 0
        # always evaluates (its history entry + best genome must exist),
        # the early-stop check runs after it — so patience <= 0 stops
        # after exactly one generation instead of freezing a zeroed
        # genome carry.
        done = (flat >= hp["patience"]) & (steps > 0)

        # ------------------------------------------------ fitness + best
        fit = evalp(consts, Px, Py, co, rd)[objective]
        order = jnp.argsort(fit)
        gi = order[0]
        gen_best = fit[gi]
        improved = gen_best < best_obj * (1.0 - 1e-4)
        n_flat = jnp.where(improved, 0, flat + 1)
        better = gen_best < best_obj
        n_best_obj = jnp.where(better, gen_best, best_obj)
        n_bPx = jnp.where(better, Px[gi], bPx)
        n_bPy = jnp.where(better, Py[gi], bPy)
        n_bco = jnp.where(better, co[gi], bco)
        n_brd = jnp.where(better, rd[gi], brd)

        # ------------------------------------- selection + crossover
        # Three batched uniform draws cover every random decision of the
        # generation — per-decision threefry calls are the dominant
        # overhead of a naive port on CPU.
        Q = pop - elite
        kt, km, kv = random.split(key, 3)
        ut = random.uniform(kt, (2, Q, tournament))
        um = random.uniform(km, (7, Q, n))
        uv = random.uniform(kv, (4, MOVE_ATTEMPTS, Q, n))

        def tourney(u):
            idx = jnp.floor(u * pop).astype(jnp.int32)
            return idx[jnp.arange(Q), jnp.argmin(fit[idx], axis=1)]

        a = tourney(ut[0])
        b = tourney(ut[1])
        mask = ((um[0, :, 0] < hp["p_crossover"])[:, None]
                & (um[1] < 0.5))
        cPx = jnp.where(mask[..., None], Px[b], Px[a])
        cPy = jnp.where(mask[..., None], Py[b], Py[a])
        cco = jnp.where(mask, co[b], co[a])
        crd = jnp.where(mask, rd[b], rd[a])

        # -------------------------------------------------- mutations
        cPx = _move_units(uv[0:2], cPx, consts["R"], win["lo_x"],
                          win["hi_x"], um[2] < hp["p_mutate_partition"])
        cPy = _move_units(uv[2:4], cPy, consts["C"], win["lo_y"],
                          win["hi_y"], um[3] < hp["p_mutate_partition"])
        mutc = um[4] < hp["p_mutate_collector"]
        cco = jnp.where(
            mutc, jnp.floor(um[5] * Y).astype(cco.dtype), cco)
        if not freeze_redist:
            mutr = um[6] < hp["p_mutate_redist"]
            crd = jnp.where(mutr, 1.0 - crd, crd)

        new = (
            jnp.concatenate([Px[order[:elite]], cPx]),
            jnp.concatenate([Py[order[:elite]], cPy]),
            jnp.concatenate([co[order[:elite]], cco]),
            jnp.concatenate([rd[order[:elite]], crd]),
            n_best_obj, n_bPx, n_bPy, n_bco, n_brd, n_flat, steps + 1,
        )
        # Freeze done islands: a finished search must report exactly what
        # a solo early-stopped run would (history length, best, counts).
        carry = jax.tree_util.tree_map(
            lambda old, upd: jnp.where(done, old, upd), carry, new)
        return carry, (carry[_BEST_OBJ], carry[_FLAT])

    def chunk(consts, win, hp, carry, keys):
        def body(c, k):
            return step(consts, win, hp, c, k)
        return lax.scan(body, carry, keys)

    return jax.vmap(chunk, in_axes=(0, 0, None, 0, None))


@functools.lru_cache(maxsize=None)
def _chunk_fn(elite: int, tournament: int, freeze_redist: bool,
              objective: str, redistribution: bool, async_exec: bool,
              energy_mode: str, congestion: str = "regime"):
    """One compiled ``vmap(scan(generation-step))`` per static
    signature — the single-device form of :func:`_chunk_inner`."""
    return jax.jit(_chunk_inner(elite, tournament, freeze_redist,
                                objective, redistribution, async_exec,
                                energy_mode, congestion))


def solve_islands(
    tasks: Sequence[Task],
    hws: Sequence[HWConfig],
    options: EvalOptions,
    objective: str,
    cfg,
    devices: str | None = None,
    seeds: Sequence[Sequence[Partition]] | None = None,
) -> list:
    """Evolve one GA search per (task, hw) island through a single
    compiled call. All islands must share a shape signature (n_ops, X, Y,
    n_entrances) — :func:`repro.core.sweep.solve_grid` does the grouping.
    Returns one :class:`repro.core.ga.GAResult` per island, aligned with
    the inputs.

    ``devices`` (default: ``cfg.devices``, DESIGN.md §15) shards the
    island axis across local devices: consts/window/carry shard, the
    hyperparams and the per-generation keys replicate (keys are shared
    across islands by construction, so a shard sees exactly the draws a
    solo run would). Results are bitwise identical to the single-device
    path.

    ``seeds`` (optional, per island) warm-starts the search: island
    ``g``'s population rows ``1..`` are overwritten with the given
    :class:`Partition` proposals (row 0 keeps the uniform baseline, so a
    seeded run can never start worse than a cold one). Collector /
    redistribution genes of a seeded row keep row 0's values — seeds
    speak only to the partition lattice (e.g. the projected-gradient
    proposals of :func:`repro.core.cosearch.gradient_seeds`, DESIGN.md
    §16). ``seeds=None`` preserves the cold-start init bit-for-bit."""
    from . import sweep_shard
    from .ga import GAResult, _random_population_vec

    if objective not in OBJECTIVES:
        raise ValueError(f"unknown objective {objective!r}; "
                         f"one of {OBJECTIVES}")
    G = len(tasks)
    assert G == len(hws) and G > 0
    pop = cfg.population
    elite = min(cfg.elite, pop - 1)

    evs = [Evaluator(t, h, options, backend="numpy")
           for t, h in zip(tasks, hws)]
    keys0 = evs[0].consts().keys()
    consts = {k: np.stack([ev.consts()[k] for ev in evs]) for k in keys0}
    win = {"lo_x": [], "hi_x": [], "lo_y": [], "hi_y": []}
    inits = []
    for t, h in zip(tasks, hws):
        lo, hi = partition_domain(t, h.X, h.Y, h.R, h.C, cfg.slack)
        win["lo_x"].append(lo[:, 0])
        win["hi_x"].append(hi[:, 0])
        win["lo_y"].append(lo[:, 1])
        win["hi_y"].append(hi[:, 1])
        # Shared host init (per-island RNG seeded by cfg.seed alone, so a
        # point's result never depends on its position in the grid).
        inits.append(_random_population_vec(
            np.random.default_rng(cfg.seed), t, h, cfg, pop))
    if seeds is not None:
        if len(seeds) != G:
            raise ValueError(f"seeds must align with islands: "
                             f"{len(seeds)} != {G}")
        for g, props in enumerate(seeds):
            Px0, Py0 = inits[g][0], inits[g][1]
            for j, p in enumerate(props[:pop - 1]):
                Px0[j + 1] = p.Px
                Py0[j + 1] = p.Py
    win = {k: np.stack(v).astype(np.float64) for k, v in win.items()}
    hp = {
        "p_crossover": float(cfg.p_crossover),
        "p_mutate_partition": float(cfg.p_mutate_partition),
        "p_mutate_collector": float(cfg.p_mutate_collector),
        "p_mutate_redist": float(cfg.p_mutate_redist),
        "patience": int(cfg.patience),
    }
    statics = (elite, int(cfg.tournament), bool(cfg.freeze_redist),
               objective, bool(options.redistribution),
               bool(options.async_exec), options.energy_mode,
               options.congestion)
    if devices is None:
        devices = getattr(cfg, "devices", "single")
    if sweep_shard.resolve_devices(devices, G) == "sharded":
        inner = _chunk_inner(*statics)

        def fn(consts, win, hp, carry, keys):
            # Padding replicates island 0 each chunk: a padded lane
            # evolves exactly like island 0 (same consts, same shared
            # keys), so chunk count and every real island's carry match
            # the single-device run bit-for-bit.
            return sweep_shard.sharded_grid_call(
                inner, (consts, win, hp, carry, keys),
                (True, True, False, True, False), G)
    else:
        fn = _chunk_fn(*statics)

    n = len(tasks[0])
    X, Y = hws[0].X, hws[0].Y
    with jax.experimental.enable_x64():
        consts_j = {k: jnp.asarray(v) for k, v in consts.items()}
        win_j = {k: jnp.asarray(v) for k, v in win.items()}
        f8 = lambda a: jnp.asarray(a, dtype=jnp.float64)
        carry = (
            f8(np.stack([i[0] for i in inits])),
            f8(np.stack([i[1] for i in inits])),
            f8(np.stack([i[2] for i in inits])),
            f8(np.stack([i[3] for i in inits])),
            jnp.full((G,), jnp.inf, dtype=jnp.float64),
            jnp.zeros((G, n, X), dtype=jnp.float64),
            jnp.zeros((G, n, Y), dtype=jnp.float64),
            jnp.zeros((G, n), dtype=jnp.float64),
            jnp.zeros((G, n), dtype=jnp.float64),
            jnp.zeros((G,), dtype=jnp.int32),
            jnp.zeros((G,), dtype=jnp.int32),
        )
        key = random.PRNGKey(cfg.seed)
        best_hist = []
        gens_left = int(cfg.generations)
        chunk_len = max(1, min(int(cfg.patience), gens_left))
        while gens_left > 0:
            L = min(chunk_len, gens_left)
            key, sub = random.split(key)
            keys = random.split(sub, L)
            carry, (yb, _yf) = fn(consts_j, win_j, hp, carry, keys)
            best_hist.append(np.asarray(yb))            # [G, L]
            gens_left -= L
            # One device→host sync per chunk — the early-stop check.
            if (np.asarray(carry[_FLAT]) >= cfg.patience).all():
                break

        best_obj = np.asarray(carry[_BEST_OBJ])
        bPx, bPy, bco, brd = (np.asarray(carry[i]) for i in (5, 6, 7, 8))
        steps = np.asarray(carry[_STEPS])
    best_all = np.concatenate(best_hist, axis=1)        # [G, T]

    results = []
    for g in range(G):
        # steps[g] = generations actually evaluated; frozen tail steps of
        # the last chunk repeat the final state and are dropped.
        T = int(steps[g])
        part = Partition(np.rint(bPx[g]).astype(np.int64),
                         np.rint(bPy[g]).astype(np.int64),
                         np.rint(bco[g]).astype(np.int64))
        part.validate(tasks[g])
        results.append(GAResult(
            partition=part,
            redist_mask=(brd[g] > 0.5) & evs[g].chain_valid,
            objective=float(best_obj[g]),
            history=best_all[g, :T].copy(),
            evaluations=T * pop,
        ))
    return results


def run_ga_jax(task: Task, hw: HWConfig, objective: str,
               options: EvalOptions, cfg):
    """Single-search entry point: the ``G=1`` case of
    :func:`solve_islands` (same executable, so results match the island
    path exactly)."""
    return solve_islands([task], [hw], options, objective, cfg)[0]
