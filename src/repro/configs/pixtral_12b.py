"""pixtral-12b [vlm]: 40L d_model=5120 32H (GQA kv=8) d_ff=14336
vocab=131072 — pixtral-ViT frontend (STUB: precomputed 1024-dim patch
embeddings per the assignment) + mistral-nemo-style decoder
[hf:mistralai/Pixtral-12B-2409; unverified]."""
from ..models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="pixtral-12b", family="vlm",
        n_layers=40, d_model=5120, d_ff=14336, vocab_size=131072,
        n_heads=32, n_kv_heads=8, d_head=128,
        frontend="vision_stub", frontend_dim=1024, frontend_tokens=1024,
        act="silu", rope_theta=1e9,
    )


def reduced_config() -> ModelConfig:
    return config().replace(
        name="pixtral-smoke", n_layers=3, d_model=64, d_ff=128,
        vocab_size=256, n_heads=4, n_kv_heads=2, d_head=16,
        frontend_dim=32, frontend_tokens=8, attn_chunk=32, remat=False)
