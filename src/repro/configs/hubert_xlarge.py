"""hubert-xlarge [audio]: 48L d_model=1280 16H d_ff=5120 vocab=504 —
encoder-only masked-prediction over cluster units; CNN feature extractor
is a STUB (precomputed 512-dim frame embeddings per the assignment)
[arXiv:2106.07447; unverified]."""
from ..models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="hubert-xlarge", family="audio",
        n_layers=48, d_model=1280, d_ff=5120, vocab_size=504,
        n_heads=16, n_kv_heads=16, d_head=80,
        is_encoder=True, causal=False,
        frontend="audio_stub", frontend_dim=512,
        act="gelu",
    )


def reduced_config() -> ModelConfig:
    return config().replace(
        name="hubert-smoke", n_layers=3, d_model=64, d_ff=128,
        vocab_size=64, n_heads=4, n_kv_heads=4, d_head=16,
        frontend_dim=32, attn_chunk=32, remat=False)
