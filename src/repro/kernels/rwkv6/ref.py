"""Pure-jnp oracle for the RWKV-6 (Finch) WKV recurrence.

Per head (K = V' = head dim), with data-dependent per-channel decay
w_t ∈ (0,1) and bonus u:

    y_t = r_t · (S_{t-1} + diag(u) · (k_t ⊗ v_t))
    S_t = diag(w_t) · S_{t-1} + k_t ⊗ v_t

Shapes: r/k/v/w (B,S,H,K), u (H,K); state S (B,H,K,K) [key-major].
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def wkv6_ref(r, k, v, w, u, s0=None):
    B, S, H, K = r.shape
    r32, k32, v32, w32 = (t.astype(jnp.float32) for t in (r, k, v, w))
    u32 = u.astype(jnp.float32)
    if s0 is None:
        s0 = jnp.zeros((B, H, K, K), jnp.float32)

    def step(s, inp):
        rt, kt, vt, wt = inp                      # (B,H,K) each
        kv = kt[..., :, None] * vt[..., None, :]  # (B,H,K,K)
        y = jnp.einsum("bhk,bhkv->bhv", rt, s + u32[None, :, :, None] * kv)
        s = wt[..., :, None] * s + kv
        return s, y

    xs = tuple(t.swapaxes(0, 1) for t in (r32, k32, v32, w32))
    sT, ys = jax.lax.scan(step, s0, xs)
    return ys.swapaxes(0, 1).astype(r.dtype), sT
