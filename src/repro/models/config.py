"""Unified architecture config covering all ten assigned families.

One dataclass; family-specific fields are inert elsewhere. Exact values
for each assigned architecture live in ``repro/configs/<id>.py``.
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                   # dense | moe | hybrid | ssm | vlm | audio
    n_layers: int
    d_model: int
    d_ff: int
    vocab_size: int
    n_heads: int = 0
    n_kv_heads: int = 0
    d_head: int = 0

    # ---- attention variants
    attn_type: str = "gqa"        # gqa | mla | none
    causal: bool = True
    window: int | None = None     # sliding-window size (SWA / local layers)
    local_global_period: int = 0  # gemma2: alternate local/global every k
    attn_logit_softcap: float | None = None
    final_logit_softcap: float | None = None
    rope_theta: float = 10000.0
    qk_norm: bool = False

    # ---- MLA (DeepSeek-V2 / MiniCPM3)
    q_lora_rank: int = 0          # 0 = full-rank q projection
    kv_lora_rank: int = 0
    qk_nope_dim: int = 0
    qk_rope_dim: int = 0
    v_head_dim: int = 0

    # ---- MoE
    n_experts: int = 0
    n_shared_experts: int = 0
    moe_top_k: int = 0
    moe_d_ff: int = 0             # per-expert hidden
    first_dense_layers: int = 0   # leading dense layers before MoE stack
    moe_capacity_factor: float = 1.25

    # ---- SSM (Mamba2) / hybrid
    ssm_state: int = 0
    ssm_conv: int = 4
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    hybrid_attn_period: int = 0   # zamba2: shared attn block every k blocks

    # ---- RWKV6
    rwkv_head_dim: int = 64
    rwkv_decay_lora: int = 64
    rwkv_mix_lora: int = 32

    # ---- encoder / modality frontends (stubs per assignment)
    is_encoder: bool = False
    frontend: str | None = None   # "vision_stub" | "audio_stub"
    frontend_dim: int = 0         # stub embedding dim
    frontend_tokens: int = 0      # patches prepended (vlm)

    # ---- numerics / runtime
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    act: str = "silu"             # silu | gelu
    emb_scale_by_sqrt_dim: bool = False
    dtype: str = "bfloat16"       # activation/compute dtype
    param_dtype: str = "float32"
    remat: bool = True
    scan_layers: bool = True
    attn_chunk: int = 512         # q-chunk for blockwise attention
    ssm_chunk: int = 256          # chunk length for SSD / WKV scans
    loss_chunk: int = 512         # seq chunk for the fused CE loss
    use_kernels: bool = False     # Pallas path (TPU); jnp refs otherwise

    def __post_init__(self):
        if self.attn_type == "gqa" and self.n_heads and not self.d_head:
            object.__setattr__(self, "d_head", self.d_model // self.n_heads)
        if self.family == "moe" and not self.moe_d_ff:
            object.__setattr__(self, "moe_d_ff", self.d_ff)

    # ---- derived
    @property
    def q_per_kv(self) -> int:
        return max(1, self.n_heads // max(self.n_kv_heads, 1))

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    @property
    def rwkv_heads(self) -> int:
        return self.d_model // self.rwkv_head_dim

    @property
    def padded_vocab(self) -> int:
        """Vocab padded to a shardable multiple (Megatron-style)."""
        m = 256
        return ((self.vocab_size + m - 1) // m) * m

    @property
    def sub_quadratic(self) -> bool:
        """Can this arch serve a 500k context (bounded per-token state)?"""
        if self.family in ("ssm",):
            return True
        if self.family == "hybrid":
            return True             # SSM backbone + windowed shared attn
        if self.window is not None and self.local_global_period == 0:
            return True             # pure SWA (mixtral)
        return False

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    def param_count(self) -> int:
        """Analytic parameter count (total, incl. embeddings)."""
        D, F, L, V = self.d_model, self.d_ff, self.n_layers, self.padded_vocab
        n = V * D  # embed
        if not self.tie_embeddings:
            n += D * V
        per_layer = 0
        if self.family in ("dense", "moe", "vlm", "audio"):
            per_layer += self._attn_params()
            if self.family == "moe":
                e = self.n_experts + self.n_shared_experts
                per_layer += 3 * D * self.moe_d_ff * e + D * self.n_experts
            else:
                per_layer += 3 * D * F
            per_layer += 2 * D  # norms
            n += per_layer * L
            if self.family == "moe" and self.first_dense_layers:
                n += (3 * D * F - 3 * D * self.moe_d_ff
                      * (self.n_experts + self.n_shared_experts)
                      - D * self.n_experts) * self.first_dense_layers
        elif self.family in ("ssm", "hybrid"):
            di = self.d_inner
            mamba = (D * (2 * di + 2 * self.ssm_heads *
                          self.ssm_state)  # in/x proj approx
                     + di * D + di * self.ssm_conv + 2 * D)
            if self.family == "ssm" and self.name.startswith("rwkv"):
                mamba = 0
            n += mamba * L
            if self.hybrid_attn_period:
                n += self._attn_params(2 * D) + 3 * (2 * D) * self.d_ff
        if self.name.startswith("rwkv"):
            n += L * (4 * D * D + D * F + F * D + 6 * D)
        return n

    def _attn_params(self, d_in: int | None = None) -> int:
        D = d_in or self.d_model
        if self.attn_type == "mla":
            q = (D * self.q_lora_rank
                 + self.q_lora_rank * self.n_heads
                 * (self.qk_nope_dim + self.qk_rope_dim)
                 if self.q_lora_rank else
                 D * self.n_heads * (self.qk_nope_dim + self.qk_rope_dim))
            kv = (D * (self.kv_lora_rank + self.qk_rope_dim)
                  + self.kv_lora_rank * self.n_heads
                  * (self.qk_nope_dim + self.v_head_dim))
            o = self.n_heads * self.v_head_dim * self.d_model
            return q + kv + o
        H, KV, Dh = self.n_heads, self.n_kv_heads, self.d_head
        return D * H * Dh + 2 * D * KV * Dh + H * Dh * self.d_model
