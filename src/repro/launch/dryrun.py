"""Multi-pod dry-run: ``lower().compile()`` every (architecture × input
shape) cell on the production meshes, proving the distribution config is
coherent — shardings lower, collectives are legal, and the per-device
memory fits — without any TPU hardware.

Run:
    PYTHONPATH=src python -m repro.launch.dryrun --all
    PYTHONPATH=src python -m repro.launch.dryrun --arch gemma2-2b \
        --shape train_4k --mesh both

Artifacts (memory analysis, cost analysis, per-collective byte counts) are
written to ``benchmarks/artifacts/dryrun/<mesh>/<arch>__<shape>.json`` and
consumed by the roofline benchmark (EXPERIMENTS.md §Dry-run/§Roofline).

Importing this module has no side effects. The CLI entrypoint calls
:func:`ensure_virtual_devices` itself (the production meshes need 512
host devices); library users pick their own topology — e.g. via
``benchmarks/common.py:force_host_devices`` — before first backend use.
"""
import argparse
import json
import os
import re
import time
import traceback

import jax
import jax.numpy as jnp

from ..configs import SHAPE_DEFS, get_config, runnable_cells
from ..models import forward, init_caches, init_model
from ..sharding.logical import use_rules
from ..sharding.partition_specs import activation_rules
from ..train import adamw
from ..train.train_step import make_train_step
from .mesh import make_production_mesh
from .specs import cache_specs, input_specs, params_specs_only, state_specs

ART_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                       "benchmarks", "artifacts", "dryrun")

from .policies import TRAIN_ACCUM, TRAIN_LOWMEM, TRAIN_V_BF16  # noqa: E402

_DEVICES_FLAG = "--xla_force_host_platform_device_count"


def ensure_virtual_devices(n: int = 512) -> None:
    """Carve the host into ``n`` virtual XLA devices unless the caller
    already pinned a count. Must run before jax initializes its backend —
    the CLI below calls it first thing; importing this module never does."""
    flags = os.environ.get("XLA_FLAGS", "")
    if _DEVICES_FLAG not in flags:
        os.environ["XLA_FLAGS"] = f"{_DEVICES_FLAG}={n} {flags}".strip()


_COLL_RE = re.compile(
    r"\b(all-reduce|all-gather|reduce-scatter|all-to-all|"
    r"collective-permute)\b")
_SHAPE_RE = re.compile(r"(f64|f32|bf16|f16|s32|s64|u32|s8|u8|pred|s16|u16)"
                       r"\[([0-9,]*)\]")
_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s32": 4, "s64": 8,
          "u32": 4, "s8": 1, "u8": 1, "pred": 1, "s16": 2, "u16": 2}


def cost_analysis_dict(compiled) -> dict:
    """``Compiled.cost_analysis()`` across jax versions: jax<=0.4.x
    returns a one-element list of dicts, jax>=0.5 returns the dict."""
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        return cost[0] if cost else {}
    return cost


def collective_bytes(hlo_text: str) -> dict[str, float]:
    """Sum per-device output bytes of every collective op in the
    post-SPMD HLO module."""
    out: dict[str, float] = {}
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m or "=" not in line:
            continue
        kind = m.group(1)
        # first shape on the line is the op result type
        sm = _SHAPE_RE.search(line)
        if not sm:
            continue
        dt, dims = sm.group(1), sm.group(2)
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        out[kind] = out.get(kind, 0.0) + n * _BYTES[dt]
    return out


def _make_opt(arch):
    if arch in TRAIN_LOWMEM:
        v_dt = jnp.bfloat16 if arch in TRAIN_V_BF16 else jnp.float32
        return adamw(m_dtype=jnp.bfloat16, v_dtype=v_dt)
    return adamw()


def _step_fn(cfg, kind, accum: int = 1, arch: str = ""):
    if kind == "train":
        opt = _make_opt(arch)
        accum_dtype = jnp.bfloat16 if arch in TRAIN_LOWMEM else jnp.float32
        return make_train_step(cfg, opt, accum_steps=accum,
                               accum_dtype=accum_dtype)
    if kind == "prefill":
        def prefill(params, batch, caches):
            logits, new_caches, _ = forward(params, cfg, batch,
                                            mode="prefill", caches=caches)
            return logits, new_caches
        return prefill
    if kind == "decode":
        def decode(params, batch, caches, pos):
            logits, new_caches, _ = forward(params, cfg, batch,
                                            mode="decode", caches=caches,
                                            pos=pos)
            tok = jnp.argmax(logits[:, -1, : cfg.vocab_size],
                             axis=-1).astype(jnp.int32)
            return tok, new_caches
        return decode
    raise ValueError(kind)


def lower_cell(arch: str, shape: str, mesh, *, shard_residual=None,
               extra_rules=None, accum=None, cfg_overrides=None,
               serve_fsdp=None, cfg=None):
    """Returns (lowered, meta) for one cell on one mesh. The keyword knobs
    (sharding rules, accumulation, config fields) are the §Perf iteration
    surface; ``cfg=`` substitutes an explicit config (e.g. the reduced
    variants) for the registry lookup."""
    if cfg is None:
        cfg = get_config(arch)
    if cfg_overrides:
        cfg = cfg.replace(**cfg_overrides)
    sd = SHAPE_DEFS[shape]
    kind = sd["kind"]
    if cfg.is_encoder and shape == "prefill_32k":
        kind = "prefill_encoder"
    if shard_residual is None:
        # residual-stream sharding on for training of wide models
        shard_residual = kind == "train" and cfg.d_model >= 2048
    rules = activation_rules(mesh, shard_residual=shard_residual)
    if extra_rules:
        rules.update(extra_rules)

    # ≥100B models extend FSDP across pods (their state exceeds one pod).
    fsdp = ("pod", "data") if arch in TRAIN_LOWMEM else ("data",)
    if serve_fsdp is not None and kind != "train":
        fsdp = serve_fsdp            # e.g. () = replicated-params serving
    n_accum = accum if accum is not None else TRAIN_ACCUM.get(arch, 1)
    with use_rules(mesh, rules):
        if kind == "train":
            step = _step_fn(cfg, "train", n_accum, arch)
            state_abs, state_sh = state_specs(cfg, mesh,
                                              optimizer=_make_opt(arch),
                                              fsdp_axes=fsdp)
            batch = input_specs(cfg, shape, mesh)
            lowered = jax.jit(
                step, in_shardings=(state_sh, None),
                donate_argnums=(0,)).lower(state_abs, batch)
        elif kind in ("prefill", "prefill_encoder"):
            params_abs, params_sh = params_specs_only(cfg, mesh, fsdp)
            batch = input_specs(cfg, shape, mesh)
            if kind == "prefill_encoder" or cfg.is_encoder:
                def enc(params, b):
                    logits, _, _ = forward(params, cfg, b, mode="train")
                    return logits
                lowered = jax.jit(enc, in_shardings=(params_sh, None)
                                  ).lower(params_abs, batch)
            else:
                caches_abs, caches_sh = cache_specs(cfg, shape, mesh)
                step = _step_fn(cfg, "prefill")
                lowered = jax.jit(
                    step, in_shardings=(params_sh, None, caches_sh),
                    donate_argnums=(2,)).lower(params_abs, batch,
                                               caches_abs)
        else:  # decode
            params_abs, params_sh = params_specs_only(cfg, mesh, fsdp)
            batch = input_specs(cfg, shape, mesh)
            caches_abs, caches_sh = cache_specs(cfg, shape, mesh)
            step = _step_fn(cfg, "decode")
            lowered = jax.jit(
                step, in_shardings=(params_sh, None, caches_sh, None),
                donate_argnums=(2,)).lower(
                    params_abs, batch, caches_abs,
                    jax.ShapeDtypeStruct((), jnp.int32))
    return lowered, {"arch": arch, "shape": shape, "kind": kind,
                     "cfg": cfg}


def perf_knobs(arch: str, shape: str) -> dict:
    """The beyond-paper layout changes adopted by EXPERIMENTS.md §Perf."""
    kind = SHAPE_DEFS[shape]["kind"]
    knobs: dict = {}
    if kind in ("prefill", "decode"):
        # replicated-params serving (29x on gemma2 decode) — safe whenever
        # the TP-sharded bf16 params fit comfortably (all but deepseek).
        if arch != "deepseek-v2-236b":
            knobs["serve_fsdp"] = ()
    if arch == "internlm2-20b" and kind == "train":
        knobs["shard_residual"] = False      # no ZeRO-R (2.0x)
        knobs["accum"] = 8
    if arch == "minicpm3-4b" and kind == "prefill":
        from jax.sharding import PartitionSpec as P
        knobs["extra_rules"] = {"attn_qchunk": P(("data",), "model",
                                                 None, None, None)}
    return knobs


def run_cell(arch: str, shape: str, mesh_name: str, mesh,
             calibrate: bool = False, **knobs) -> dict:
    t0 = time.time()
    lowered, meta = lower_cell(arch, shape, mesh, **knobs)
    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0
    mem = compiled.memory_analysis()
    cost = cost_analysis_dict(compiled)
    coll = collective_bytes(compiled.as_text())
    rec = {
        "arch": arch,
        "shape": shape,
        "mesh": mesh_name,
        "kind": meta["kind"],
        "n_devices": mesh.size,
        "lower_s": round(t_lower, 2),
        "compile_s": round(t_compile, 2),
        "memory": {
            k: int(getattr(mem, k))
            for k in ("argument_size_in_bytes", "output_size_in_bytes",
                      "temp_size_in_bytes", "generated_code_size_in_bytes")
            if hasattr(mem, k)
        },
        "flops_per_device": float(cost.get("flops", -1.0)),
        "bytes_per_device": float(cost.get("bytes accessed", -1.0)),
        "collective_bytes_per_device": coll,
    }
    if calibrate:
        rec["calibrated"] = calibrate_cell(arch, shape, mesh, **knobs)
    return rec


def execute_plan(plan_result, arch: str, shape: str, mesh,
                 mesh_name: str = "plan", *, calibrate: bool = False,
                 cfg=None, cfg_overrides=None, extra_rules=None,
                 **extra_knobs) -> dict:
    """Lower, compile, and cost the layout a ``sharding/mcm_planner`` plan
    chose: the planner's knobs (residual-stream sharding, microbatch
    accumulation, redistribution mask) become executable dryrun knobs
    instead of a report. Returns a ``run_cell`` record with a ``plan``
    section recording the analytical prediction next to the measured cost
    analysis — the unit the validation gate compares."""
    knobs = plan_result.to_dryrun_knobs()
    knobs.update(extra_knobs)
    rec = run_cell(arch, shape, mesh_name, mesh, calibrate=calibrate,
                   cfg=cfg, cfg_overrides=cfg_overrides,
                   extra_rules=extra_rules, **knobs)
    rec["plan"] = {
        "arch": plan_result.arch,
        "baseline_latency_s": float(plan_result.baseline_latency),
        "optimized_latency_s": float(plan_result.optimized_latency),
        "modeled_speedup": float(plan_result.modeled_speedup),
        "nonuniform_headroom": float(plan_result.nonuniform_headroom),
        "redist_mask": [int(b) for b in plan_result.redist_mask],
        "knobs": {k: v for k, v in knobs.items()},
    }
    return rec


def _calib_layers(cfg) -> tuple[int, int, float, float, float]:
    """(L1, L2, units1, units2, units_full) for per-unit extrapolation."""
    if cfg.family == "hybrid":
        p = cfg.hybrid_attn_period
        return p, 2 * p, 1, 2, cfg.n_layers / p
    if cfg.local_global_period:
        p = cfg.local_global_period
        return p, 2 * p, 1, 2, cfg.n_layers / p
    if cfg.first_dense_layers:
        d = cfg.first_dense_layers
        return d + 1, d + 2, 1, 2, cfg.n_layers - d
    return 1, 2, 1, 2, cfg.n_layers


def calibrate_cell(arch: str, shape: str, mesh, *, extra_rules=None,
                   accum=None, shard_residual=None,
                   cfg_overrides=None, serve_fsdp=None, cfg=None) -> dict:
    """Exact per-cell roofline quantities: lower two small *unrolled*
    configs (single-trip inner scans via calibration mode, attention/loss
    chunks = S, accumulation loop unrolled) and extrapolate per repeating
    unit to full depth. See kernels/calibrate.py for why (while-loop cost
    counting). Accepts the same §Perf knobs as lower_cell."""
    from ..kernels.calibrate import calibration

    base = cfg if cfg is not None else get_config(arch)
    if cfg_overrides:
        base = base.replace(**cfg_overrides)
    L1, L2, u1, u2, uf = _calib_layers(base)

    # Linear-complexity archs (SSM/hybrid: chunked recurrences + windowed
    # attention) calibrate on a 4k slice of long sequences and scale —
    # fully unrolling 32k/Lc chunk bodies is compile-pathological, and
    # their per-token cost is constant beyond the window.
    sd = SHAPE_DEFS[shape]
    shape_used, seq_scale = shape, 1.0
    if (base.family in ("ssm", "hybrid") and sd["kind"] != "decode"
            and sd["seq_len"] > 8192):
        shape_used = f"__calib_{shape}"
        SHAPE_DEFS[shape_used] = dict(sd, seq_len=4096)
        seq_scale = sd["seq_len"] / 4096.0

    # Train cells: per-microbatch work (param re-gathers!) scales linearly
    # with accumulation, so calibrate at accum∈{1,2} and extrapolate
    # bilinearly in (layers, accum) — unrolling accum=8 microbatches would
    # be compile-pathological. Other kinds: accum is not a variable.
    af = accum if accum is not None else TRAIN_ACCUM.get(arch, 1)
    accums = (1, 2) if SHAPE_DEFS[shape]["kind"] == "train" else (None,)

    out = {}
    try:
        for ai, a in enumerate(accums):
            for li, L in enumerate((L1, L2)):
                cfg = base.replace(n_layers=L, scan_layers=False,
                                   attn_chunk=1_000_000_000,
                                   loss_chunk=1_000_000_000)
                with calibration():
                    lowered, _ = _lower_with_cfg(
                        cfg, arch, shape_used, mesh,
                        extra_rules=extra_rules, accum=a,
                        shard_residual=shard_residual,
                        serve_fsdp=serve_fsdp)
                compiled = lowered.compile()
                cost = cost_analysis_dict(compiled)
                out[ai, li] = {
                    "flops": float(cost.get("flops", 0.0)),
                    "bytes": float(cost.get("bytes accessed", 0.0)),
                    "coll": collective_bytes(compiled.as_text()),
                }
    finally:
        if shape_used != shape:
            SHAPE_DEFS.pop(shape_used, None)

    def field(ai, li, key, ck=None):
        v = out[ai, li][key]
        return v.get(ck, 0.0) if ck is not None else v

    def extra(key, ck=None):
        if len(accums) == 1:
            f1, f2 = field(0, 0, key, ck), field(0, 1, key, ck)
            return (f1 + (f2 - f1) / (u2 - u1) * (uf - u1)) * seq_scale
        # bilinear: f(L, a) = a·(A·L + B) + (C·L + D)
        f11, f12 = field(0, 0, key, ck), field(0, 1, key, ck)  # a=1
        f21, f22 = field(1, 0, key, ck), field(1, 1, key, ck)  # a=2
        dL = u2 - u1
        A = (f22 - f21 - f12 + f11) / dL          # per-layer per-accum
        B = (f21 - f11) - A * u1                  # per-accum base
        C = (f12 - f11) / dL - A                  # per-layer const
        D = f11 - A * u1 - B - C * u1
        val = af * (A * uf + B) + (C * uf + D)
        return max(0.0, val) * seq_scale

    kinds = set()
    for v in out.values():
        kinds |= set(v["coll"])
    return {
        "flops_per_device": extra("flops"),
        "bytes_per_device": extra("bytes"),
        "collective_bytes_per_device": {k: extra("coll", k)
                                        for k in kinds},
        "units": [u1, u2, uf],
        "accum_eval": af,
        "seq_scale": seq_scale,
    }


def _lower_with_cfg(cfg, arch, shape, mesh, *, extra_rules=None,
                    accum=None, shard_residual=None, serve_fsdp=None):
    """lower_cell with an explicit (possibly calibration) config. The
    accumulation loop is unrolled so its per-microbatch collective traffic
    is counted exactly."""
    sd = SHAPE_DEFS[shape]
    kind = sd["kind"]
    if cfg.is_encoder and shape == "prefill_32k":
        kind = "prefill_encoder"
    if shard_residual is None:
        shard_residual = kind == "train" and cfg.d_model >= 2048
    rules = activation_rules(mesh, shard_residual=shard_residual)
    if extra_rules:
        rules.update(extra_rules)
    fsdp = ("pod", "data") if arch in TRAIN_LOWMEM else ("data",)
    if serve_fsdp is not None and kind != "train":
        fsdp = serve_fsdp
    n_accum = accum if accum is not None else TRAIN_ACCUM.get(arch, 1)
    with use_rules(mesh, rules):
        if kind == "train":
            opt = _make_opt(arch)
            accum_dtype = (jnp.bfloat16 if arch in TRAIN_LOWMEM
                           else jnp.float32)
            step = make_train_step(cfg, opt, accum_steps=n_accum,
                                   accum_dtype=accum_dtype,
                                   accum_unroll=True)
            state_abs, state_sh = state_specs(cfg, mesh, optimizer=opt,
                                              fsdp_axes=fsdp)
            batch = input_specs(cfg, shape, mesh)
            return jax.jit(step, in_shardings=(state_sh, None),
                           donate_argnums=(0,)).lower(state_abs,
                                                      batch), kind
        if kind in ("prefill", "prefill_encoder") or cfg.is_encoder:
            params_abs, params_sh = params_specs_only(cfg, mesh, fsdp)
            batch = input_specs(cfg, shape, mesh)
            if cfg.is_encoder:
                def enc(params, b):
                    logits, _, _ = forward(params, cfg, b, mode="train")
                    return logits
                return jax.jit(enc, in_shardings=(params_sh, None)
                               ).lower(params_abs, batch), kind
            caches_abs, caches_sh = cache_specs(cfg, shape, mesh)
            step = _step_fn(cfg, "prefill")
            return jax.jit(
                step, in_shardings=(params_sh, None, caches_sh),
                donate_argnums=(2,)).lower(params_abs, batch,
                                           caches_abs), kind
        params_abs, params_sh = params_specs_only(cfg, mesh, fsdp)
        batch = input_specs(cfg, shape, mesh)
        caches_abs, caches_sh = cache_specs(cfg, shape, mesh)
        step = _step_fn(cfg, "decode")
        return jax.jit(
            step, in_shardings=(params_sh, None, caches_sh, None),
            donate_argnums=(2,)).lower(
                params_abs, batch, caches_abs,
                jax.ShapeDtypeStruct((), jnp.int32)), kind


def save_rec(rec: dict):
    d = os.path.join(ART_DIR, rec["mesh"])
    os.makedirs(d, exist_ok=True)
    path = os.path.join(d, f"{rec['arch']}__{rec['shape']}.json")
    with open(path, "w") as f:
        json.dump(rec, f, indent=1)
    return path


def main():
    ensure_virtual_devices()
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="both",
                    choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument("--calibrate", action="store_true",
                    help="add exact (unrolled, extrapolated) roofline "
                         "quantities to each artifact")
    ap.add_argument("--optimized", action="store_true",
                    help="apply the adopted §Perf layout changes "
                         "(replicated-params serving; no-ZeRO-R+accum8 "
                         "for internlm2; attn_qchunk for minicpm3)")
    args = ap.parse_args()

    cells = runnable_cells()
    if args.arch:
        cells = [c for c in cells if c[0] == args.arch]
    if args.shape:
        cells = [c for c in cells if c[1] == args.shape]
    if not cells:
        raise SystemExit("no matching cells")

    meshes = []
    if args.mesh in ("single", "both"):
        meshes.append(("single_pod_16x16", make_production_mesh()))
    if args.mesh in ("multi", "both"):
        meshes.append(("multi_pod_2x16x16",
                       make_production_mesh(multi_pod=True)))

    failures = []
    for mesh_name, mesh in meshes:
        for arch, shape in cells:
            tag = f"{mesh_name} {arch} {shape}"
            out = os.path.join(ART_DIR, mesh_name,
                               f"{arch}__{shape}.json")
            if args.skip_existing and os.path.exists(out):
                done = json.load(open(out))
                if not args.calibrate or "calibrated" in done:
                    print(f"[skip] {tag}")
                    continue
            try:
                knobs = perf_knobs(arch, shape) if args.optimized else {}
                rec = run_cell(arch, shape, mesh_name, mesh,
                               calibrate=args.calibrate, **knobs)
                path = save_rec(rec)
                mem_gb = rec["memory"].get("temp_size_in_bytes", 0) / 2**30
                arg_gb = rec["memory"].get("argument_size_in_bytes",
                                           0) / 2**30
                print(f"[ok] {tag}: compile={rec['compile_s']}s "
                      f"args={arg_gb:.2f}GiB temp={mem_gb:.2f}GiB "
                      f"flops/dev={rec['flops_per_device']:.3g} -> {path}")
            except Exception as e:
                failures.append((tag, repr(e)))
                print(f"[FAIL] {tag}: {e!r}")
                traceback.print_exc()
    if failures:
        print(f"\n{len(failures)} FAILURES:")
        for t, e in failures:
            print(" ", t, e)
        raise SystemExit(1)
    print("\nAll dry-run cells compiled successfully.")


if __name__ == "__main__":
    main()
