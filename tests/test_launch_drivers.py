"""End-to-end driver tests: train.py (with restart) and serve.py mains
on reduced configs + debug mesh."""
import jax
import numpy as np
import pytest

from repro.launch import serve as serve_mod
from repro.launch import train as train_mod


def test_train_driver_end_to_end(tmp_path):
    report = train_mod.main([
        "--arch", "smollm-360m", "--reduced", "--steps", "12",
        "--batch", "4", "--seq", "48", "--lr", "3e-3",
        "--ckpt", str(tmp_path), "--ckpt-every", "5",
        "--log-every", "6",
    ])
    assert report.steps_run == 12
    assert report.losses[-1] < report.losses[0]


def test_train_driver_restart_resumes(tmp_path):
    train_mod.main([
        "--arch", "smollm-360m", "--reduced", "--steps", "6",
        "--batch", "4", "--seq", "48", "--ckpt", str(tmp_path),
        "--ckpt-every", "3", "--log-every", "100",
    ])
    # crash-restart: a fresh process would pass --restore auto
    report = train_mod.main([
        "--arch", "smollm-360m", "--reduced", "--steps", "4",
        "--batch", "4", "--seq", "48", "--ckpt", str(tmp_path),
        "--restore", "auto", "--log-every", "100",
    ])
    assert report.steps_run == 4


def test_serve_driver():
    outs = serve_mod.main([
        "--arch", "smollm-360m", "--reduced", "--requests", "5",
        "--batch", "2", "--new-tokens", "6", "--capacity", "64",
    ])
    assert len(outs) == 5
    assert all(len(o) == 6 for o in outs)
