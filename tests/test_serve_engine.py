"""ServeEngine regression tests: wave-split equivalence, slot-refill
ordering, and temperature semantics (greedy determinism + seeded
sampling reproducibility)."""
import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import init_model
from repro.serve import ServeEngine


@pytest.fixture(scope="module")
def model():
    cfg = get_config("smollm-360m", reduced=True)
    params = init_model(cfg, jax.random.PRNGKey(0))
    return cfg, params


def _prompts(cfg, lengths, seed=0):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, cfg.vocab_size, n).astype(np.int32)
            for n in lengths]


def test_wave_split_matches_single_wave(model):
    """len(prompts) > B runs in waves; the result must equal serving
    each wave through its own generate() call (greedy decode is
    stateless across waves)."""
    cfg, params = model
    eng = ServeEngine(cfg, params, batch_size=2, capacity=64)
    prompts = _prompts(cfg, (5, 9, 3, 7, 4))
    full = eng.generate(prompts, max_new_tokens=6)
    assert len(full) == 5
    by_wave = []
    for i in range(0, len(prompts), 2):
        by_wave.extend(eng.generate(prompts[i: i + 2], max_new_tokens=6))
    assert full == by_wave


def test_wave_split_invariant_to_batch_size(model):
    """Greedy outputs must not depend on how prompts are grouped into
    waves — but padding within a wave is shared, so compare engines
    where wave boundaries differ yet co-batched prompts have equal
    length."""
    cfg, params = model
    prompts = _prompts(cfg, (6, 6, 6, 6))
    outs = {}
    for B in (1, 2, 4):
        eng = ServeEngine(cfg, params, batch_size=B, capacity=64)
        outs[B] = eng.generate(prompts, max_new_tokens=5)
    assert outs[1] == outs[2] == outs[4]


def test_slot_refill_ordering(model):
    """Identical prompts occupying the same slot in different waves must
    produce identical outputs, and results come back in submission
    order ([a, b, a, b] → outs[0]==outs[2], outs[1]==outs[3])."""
    cfg, params = model
    eng = ServeEngine(cfg, params, batch_size=2, capacity=64)
    a, b = _prompts(cfg, (5, 8))
    outs = eng.generate([a, b, a, b], max_new_tokens=6)
    assert len(outs) == 4
    assert outs[0] == outs[2]
    assert outs[1] == outs[3]
    assert outs[0] != outs[1]        # distinct prompts actually differ


def test_greedy_temperature_zero_deterministic(model):
    cfg, params = model
    prompts = _prompts(cfg, (5, 3))
    runs = [ServeEngine(cfg, params, batch_size=2, capacity=64,
                        temperature=0.0, seed=s).generate(prompts, 6)
            for s in (0, 7)]
    # temperature=0 ignores the sampling seed entirely
    assert runs[0] == runs[1]


def test_temperature_sampling_seed_reproducible(model):
    cfg, params = model
    prompts = _prompts(cfg, (5, 3))
    gen = lambda seed: ServeEngine(
        cfg, params, batch_size=2, capacity=64,
        temperature=0.8, seed=seed).generate(prompts, 8)
    assert gen(3) == gen(3)          # same seed → identical stream
    assert gen(3) != gen(4)          # different seed → diverges


def test_outputs_in_vocab_range(model):
    cfg, params = model
    eng = ServeEngine(cfg, params, batch_size=3, capacity=64,
                      temperature=0.5, seed=1)
    outs = eng.generate(_prompts(cfg, (4, 2, 6, 3)), max_new_tokens=4)
    assert all(len(o) == 4 for o in outs)
    assert all(0 <= t < cfg.vocab_size for o in outs for t in o)
