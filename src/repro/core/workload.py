"""Workload model — paper Sec. 4.2.2/4.2.3.

A machine-learning task is a topologically-ordered sequence of GEMM
operators (eq. 1/2). Each operator carries the synchronization / sharing
attributes the communication model needs, plus a ``chained`` flag marking
that its activation input is the previous operator's output (the case
on-package redistribution, Sec. 5.2, optimizes).

SIMD-class operators (ReLU, softmax, layernorm — Sec. 4.2.2) are modeled as
attributes of the preceding GEMM: ``epilogue_flops_per_elem`` adds vector
cycles, and ``sync=True`` forces an output synchronization (softmax /
layernorm over distributed outputs).
"""
from __future__ import annotations

import dataclasses

import numpy as np

__all__ = ["GemmOp", "Task", "uniform_partition", "partition_domain", "Partition"]


@dataclasses.dataclass(frozen=True)
class GemmOp:
    """One GEMM: out[M, N] = inp[M, K] @ w[K, N] (paper eq. 2)."""

    name: str
    M: int
    K: int
    N: int
    sync: bool = False          # output must synchronize across chiplets
    shared_row: bool = False    # chiplets of same row produce same out rows
    shared_col: bool = False
    chained: bool = False       # activation input = previous op's output
    weight_bytes_scale: float = 1.0  # grouped GEMMs reuse one weight tile
    epilogue_flops_per_elem: int = 0  # SIMD epilogue (ReLU=1, softmax≈5, ...)
    n_groups: int = 1           # grouped GEMM (e.g. attention heads)

    def __post_init__(self):
        for d in (self.M, self.K, self.N):
            if d < 1:
                raise ValueError(f"bad GEMM dims in {self.name}: "
                                 f"{self.M}x{self.K}x{self.N}")

    @property
    def flops(self) -> int:
        return 2 * self.M * self.K * self.N

    @property
    def out_elems(self) -> int:
        return self.M * self.N

    @property
    def in_elems(self) -> int:
        return self.M * self.K

    @property
    def w_elems(self) -> int:
        return int(self.K * self.N * self.weight_bytes_scale)


@dataclasses.dataclass
class Task:
    """``Task = [OP_0 .. OP_{N-1}]`` (eq. 1) plus metadata."""

    name: str
    ops: list[GemmOp]

    def __post_init__(self):
        if not self.ops:
            raise ValueError("empty task")

    def __len__(self) -> int:
        return len(self.ops)

    def __iter__(self):
        return iter(self.ops)

    @property
    def total_flops(self) -> int:
        return sum(op.flops for op in self.ops)

    def arrays(self) -> dict[str, np.ndarray]:
        """Stack op attributes into arrays for the vectorized evaluator."""
        f = lambda a: np.array([getattr(op, a) for op in self.ops])
        return {
            "M": f("M"),
            "K": f("K"),
            "N": f("N"),
            "sync": f("sync"),
            "shared_row": f("shared_row"),
            "shared_col": f("shared_col"),
            "chained": f("chained"),
            "w_scale": f("weight_bytes_scale"),
            "epilogue": f("epilogue_flops_per_elem"),
        }

    def describe(self) -> str:
        rows = [f"Task {self.name}: {len(self.ops)} GEMMs, "
                f"{self.total_flops/1e9:.2f} GFLOPs"]
        for op in self.ops:
            flags = "".join(
                c
                for c, v in zip("scr", (op.sync, op.chained, op.shared_row))
                if v
            )
            rows.append(
                f"  {op.name:<24} M={op.M:<7} K={op.K:<7} N={op.N:<7} {flags}"
            )
        return "\n".join(rows)


# --------------------------------------------------------------------------
# Partitions (Sec. 4.2.3): Px[i, x] output rows on chiplet-row x for op i,
# Py[i, y] output cols on chiplet-col y; collectors[i] is the collection
# column used by on-package redistribution (a GA gene, Sec. 6.2).
# --------------------------------------------------------------------------
@dataclasses.dataclass
class Partition:
    Px: np.ndarray          # [n_ops, X] ints, rows sum to M_i
    Py: np.ndarray          # [n_ops, Y] ints, rows sum to N_i
    collectors: np.ndarray  # [n_ops] ints in [0, Y)

    def validate(self, task: Task) -> None:
        n = len(task)
        assert self.Px.shape[0] == n and self.Py.shape[0] == n
        for i, op in enumerate(task.ops):
            sx, sy = int(self.Px[i].sum()), int(self.Py[i].sum())
            if sx != op.M:
                raise ValueError(f"{op.name}: sum(Px)={sx} != M={op.M}")
            if sy != op.N:
                raise ValueError(f"{op.name}: sum(Py)={sy} != N={op.N}")
            if (self.Px[i] < 0).any() or (self.Py[i] < 0).any():
                raise ValueError(f"{op.name}: negative partition")

    def copy(self) -> "Partition":
        return Partition(self.Px.copy(), self.Py.copy(), self.collectors.copy())


def _split_even(total: int, parts: int) -> np.ndarray:
    """Uniform split with remainder spread over the first entries."""
    base, rem = divmod(total, parts)
    out = np.full(parts, base, dtype=np.int64)
    out[:rem] += 1
    return out


def uniform_partition(task: Task, X: int, Y: int) -> Partition:
    """The paper's LS baseline: uniform workload partitioning."""
    Px = np.stack([_split_even(op.M, X) for op in task.ops])
    Py = np.stack([_split_even(op.N, Y) for op in task.ops])
    return Partition(Px, Py, np.full(len(task), Y // 2, dtype=np.int64))


def partition_domain(
    task: Task, X: int, Y: int, R: int, C: int, slack: int = 2
) -> tuple[np.ndarray, np.ndarray]:
    """Solver search windows from Sec. 6.2.

    Each Px_i[x] is constrained to multiples of R within
    ``[max(R, R*(ceil(M/X/R) - slack)), R*(ceil(M/X/R) + slack)]`` (and the
    symmetric window in C for Py); smaller would under-utilize the systolic
    array. Returns (lo, hi) arrays of shape [n_ops, 2] holding the inclusive
    multiple-of-R index window for rows ([:,0] -> Px) and cols ([:,1] -> Py).
    """
    lo = np.zeros((len(task), 2), dtype=np.int64)
    hi = np.zeros((len(task), 2), dtype=np.int64)
    for i, op in enumerate(task.ops):
        ux = max(1, int(np.ceil(op.M / X / R)))   # uniform share in R units
        uy = max(1, int(np.ceil(op.N / Y / C)))
        # If there are fewer R-units than chiplet rows, some rows must idle:
        # the paper's "min Px = R" floor only applies when work suffices.
        floor_x = 1 if int(np.ceil(op.M / R)) >= X else 0
        floor_y = 1 if int(np.ceil(op.N / C)) >= Y else 0
        lo[i, 0] = max(floor_x, ux - slack)
        hi[i, 0] = ux + slack
        lo[i, 1] = max(floor_y, uy - slack)
        hi[i, 1] = uy + slack
    return lo, hi


def clamp_partition_to_domain(
    part: Partition, task: Task, X: int, Y: int, R: int, C: int, slack: int = 2
) -> Partition:
    """Project an arbitrary partition into the solver domain: multiples of
    R/C inside the Sec-6.2 window, then fix the sum by adjusting entries
    greedily (keeps feasibility invariant for GA mutations)."""
    lo, hi = partition_domain(task, X, Y, R, C, slack)
    out = part.copy()
    for i, op in enumerate(task.ops):
        out.Px[i] = _repair_axis(out.Px[i], op.M, R, lo[i, 0], hi[i, 0])
        out.Py[i] = _repair_axis(out.Py[i], op.N, C, lo[i, 1], hi[i, 1])
    out.collectors = np.clip(out.collectors, 0, Y - 1)
    return out


def _repair_axis(p: np.ndarray, total: int, unit: int, lo: int, hi: int
                 ) -> np.ndarray:
    """Snap to units, clamp to window, then repair the sum.

    The last entry absorbs the residual so that sums stay exact even when
    ``total`` is not a multiple of ``unit`` (real layer dims rarely are).
    """
    n = len(p)
    units = np.clip(np.round(p / unit).astype(np.int64), lo, hi)
    vals = units * unit
    resid = total - int(vals.sum())
    j = 0
    # Greedy repair: walk entries, move one unit at a time within bounds.
    guard = 0
    while resid >= unit or resid <= -unit:
        guard += 1
        if guard > 10 * n * (hi - lo + 2):
            break
        k = j % n
        if resid > 0 and units[k] < hi:
            units[k] += 1
            resid -= unit
        elif resid < 0 and units[k] > lo:
            units[k] -= 1
            resid += unit
        j += 1
    vals = units * unit
    # Absorb sub-unit residue (and any window-infeasible remainder) in the
    # largest entry, keeping non-negativity.
    resid = total - int(vals.sum())
    k = int(np.argmax(vals))
    vals[k] = max(0, vals[k] + resid)
    # Final exactness fix (can only trigger if vals[k] clipped at 0).
    d = total - int(vals.sum())
    if d != 0:
        k2 = int(np.argmax(vals))
        vals[k2] += d
    return vals
