"""Chunk-parallel Mamba-2 SSD in pure JAX — the XLA execution path.

Splits the sequence into chunks of length Lc; within a chunk the output is
an attention-like masked matmul (all decay exponents are differences of a
monotone cumulative sum, hence ≤ 0 → numerically safe exp), and chunk
states are carried by a scan. Matches :func:`..ref.ssm_scan_ref` to f32
tolerance; the Pallas kernel mirrors this chunk decomposition with one
grid step per (batch, chunk).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def ssm_scan_chunked(x, dt, a, Bmat, Cmat, D, h0=None, chunk: int = 256):
    Bsz, S, H, P = x.shape
    G, N = Bmat.shape[2], Bmat.shape[3]
    rep = H // G
    dtype_in = x.dtype
    x32 = x.astype(jnp.float32)
    dt32 = dt.astype(jnp.float32)
    B32 = jnp.repeat(Bmat.astype(jnp.float32), rep, axis=2)  # (B,S,H,N)
    C32 = jnp.repeat(Cmat.astype(jnp.float32), rep, axis=2)
    a32 = a.astype(jnp.float32)

    Lc = min(chunk, S)
    pad = (-S) % Lc
    if pad:
        x32 = jnp.pad(x32, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt32 = jnp.pad(dt32, ((0, 0), (0, pad), (0, 0)))
        B32 = jnp.pad(B32, ((0, 0), (0, pad), (0, 0), (0, 0)))
        C32 = jnp.pad(C32, ((0, 0), (0, pad), (0, 0), (0, 0)))
    nc = x32.shape[1] // Lc

    def to_chunks(t):
        return t.reshape((Bsz, nc, Lc) + t.shape[2:]).swapaxes(0, 1)

    xc, dtc, Bc, Cc = map(to_chunks, (x32, dt32, B32, C32))
    if h0 is None:
        h0 = jnp.zeros((Bsz, H, P, N), jnp.float32)

    def chunk_step(h, inp):
        xk, dk, Bk, Ck = inp     # (B,Lc,H,P),(B,Lc,H),(B,Lc,H,N),(B,Lc,H,N)
        da = dk * a32[None, None, :]                 # (B,Lc,H) ≤ 0
        cum = jnp.cumsum(da, axis=1)                 # (B,Lc,H)
        # ---- intra-chunk (attention-like, lower-triangular)
        # L[i,j] = exp(cum_i − cum_j) for i ≥ j
        diff = cum[:, :, None, :] - cum[:, None, :, :]       # (B,i,j,H)
        tri = jnp.tril(jnp.ones((Lc, Lc), bool))
        Lmat = jnp.where(tri[None, :, :, None], jnp.exp(diff), 0.0)
        CB = jnp.einsum("bihn,bjhn->bijh", Ck, Bk)           # (B,i,j,H)
        W = CB * Lmat * dk[:, None, :, :]                    # weight on x_j
        y_intra = jnp.einsum("bijh,bjhp->bihp", W, xk)
        # ---- inter-chunk (contribution of the incoming state)
        y_inter = jnp.einsum("bihn,bhpn->bihp", Ck * jnp.exp(
            cum)[..., None], h)
        # ---- state update
        decay_to_end = jnp.exp(cum[:, -1:, :] - cum)         # (B,Lc,H)
        dB = (dk * decay_to_end)[..., None] * Bk             # (B,Lc,H,N)
        h_new = (h * jnp.exp(cum[:, -1, :])[..., None, None]
                 + jnp.einsum("bjhn,bjhp->bhpn", dB, xk))
        return h_new, y_intra + y_inter

    # remat each chunk: backward recomputes the intra-chunk decay/attention
    # tensors, saving only the (small) inter-chunk states.
    from ..calibrate import scan_unroll
    hT, ys = jax.lax.scan(
        jax.checkpoint(chunk_step,
                       policy=jax.checkpoint_policies.nothing_saveable),
        h0, (xc, dtc, Bc, Cc), unroll=scan_unroll())
    y = ys.swapaxes(0, 1).reshape(Bsz, nc * Lc, H, P)[:, :S]
    y = y + x32[:, :S] * D[None, None, :, None]
    return y.astype(dtype_in), hT


def ssm_decode_step(h, x, dt, a, Bmat, Cmat, D):
    """Single-token state update for serving. x (B,H,P), dt (B,H),
    Bmat/Cmat (B,G,N); returns (y (B,H,P), h_new)."""
    G = Bmat.shape[1]
    rep = x.shape[1] // G
    Bh = jnp.repeat(Bmat.astype(jnp.float32), rep, axis=1)
    Ch = jnp.repeat(Cmat.astype(jnp.float32), rep, axis=1)
    dt32 = dt.astype(jnp.float32)
    decay = jnp.exp(dt32 * a.astype(jnp.float32)[None, :])
    h_new = (h * decay[..., None, None]
             + (dt32[..., None] * x.astype(jnp.float32))[..., None]
             * Bh[:, :, None, :])
    y = jnp.einsum("bhpn,bhn->bhp", h_new, Ch) + x * D[None, :, None]
    return y.astype(x.dtype), h_new
