"""Genetic Algorithm scheduler — paper Sec. 6.2.

Genome (per candidate):
  * ``Px`` [n_ops, X], ``Py`` [n_ops, Y] — workload partitions, constrained
    to multiples of R (C) inside the Sec-6.2 window around uniform (±slack),
    with exact per-op sums.
  * ``collectors`` [n_ops] — collection-chiplet column for on-package
    redistribution (the second GA variable set named in the paper).
  * ``redist`` [n_ops] — whether to redistribute after op i (masked to
    semantically valid chain pairs).

Constraint-preserving operators:
  * crossover swaps whole per-op rows between parents (sums stay exact);
  * partition mutation moves one R-unit between two chiplet rows of the
    same op (sum invariant);
  * collector / redist mutations are uniform resamples.

Fitness is the vectorized evaluator over the whole population at once.

Two evolution engines (DESIGN.md §10):
  * ``engine="python"`` — the original per-individual offspring loop;
    the behavioral reference, with exactly reproducible trajectories
    across fitness backends (``tests/test_backend_parity.py``).
  * ``engine="vectorized"`` — all genetic operators act on the whole
    population at once. With ``backend="numpy"`` this module's
    pure-numpy port runs; with ``backend="jax"`` the device-resident
    engine (:mod:`repro.core.ga_jax`) fuses fitness + selection +
    crossover + mutation into one jitted generation step driven by
    ``lax.scan``. The two vectorized paths share the same host-side
    population init but draw from different RNGs, so the contract
    across engines is property-based (exact per-op sums, domain
    windows, monotone best objective) plus fixed-seed solution-quality
    equivalence — not trajectory identity
    (``tests/test_core_ga_engines.py``).
"""
from __future__ import annotations

import dataclasses

import numpy as np

from .evaluator import EvalOptions, Evaluator, resolve_auto_backend
from .hw import HWConfig
from .workload import (Partition, Task, clamp_partition_to_domain,
                       partition_domain, uniform_partition)

__all__ = ["GAConfig", "GAResult", "run_ga", "ENGINES"]

ENGINES = ("python", "vectorized")

#: Attempts per rejection-sampled unit move (both engines; the python
#: reference used the same constant inline).
MOVE_ATTEMPTS = 4


@dataclasses.dataclass(frozen=True)
class GAConfig:
    population: int = 96
    generations: int = 200
    elite: int = 4
    tournament: int = 3
    p_crossover: float = 0.85
    p_mutate_partition: float = 0.5
    p_mutate_collector: float = 0.2
    p_mutate_redist: float = 0.15
    slack: int = 2
    patience: int = 40          # early stop after this many flat generations
    seed: int = 0
    freeze_redist: bool = False  # force redistribution on all valid pairs
                                 # (TPU bridge: no shared-memory path exists)
    backend: str = "numpy"       # fitness backend: "numpy" | "jax" | "auto"
                                 # ("auto" picks jax at population >= 1024,
                                 # the measured crossover point; DESIGN.md §8)
    engine: str = "python"       # evolution engine: "python" | "vectorized"
                                 # (DESIGN.md §10)
    devices: str = "auto"        # island-axis execution for batched jax
                                 # solves: "single" | "sharded" | "auto"
                                 # (DESIGN.md §15; result-neutral — never
                                 # part of a cache fingerprint)


@dataclasses.dataclass
class GAResult:
    partition: Partition
    redist_mask: np.ndarray
    objective: float
    history: np.ndarray         # best objective per generation
    evaluations: int


def _random_population(rng, task, hw, cfg, pop):
    """Seed: uniform partition + random unit moves (keeps diversity while
    starting near the feasible center, as the paper's window implies)."""
    n = len(task)
    X, Y = hw.X, hw.Y
    base = uniform_partition(task, X, Y)
    base = clamp_partition_to_domain(base, task, X, Y, hw.R, hw.C, cfg.slack)
    Px = np.repeat(base.Px[None], pop, axis=0).astype(np.int64)
    Py = np.repeat(base.Py[None], pop, axis=0).astype(np.int64)
    lo, hi = partition_domain(task, X, Y, hw.R, hw.C, cfg.slack)
    # Random unit moves per candidate (individual 0 stays uniform — elitism
    # guarantees GA can never be worse than the LS baseline partition).
    for p in range(1, pop):
        for i in range(n):
            for _ in range(rng.integers(0, X + Y)):
                _move_unit(rng, Px[p, i], hw.R, lo[i, 0], hi[i, 0])
                _move_unit(rng, Py[p, i], hw.C, lo[i, 1], hi[i, 1])
    coll = rng.integers(0, Y, size=(pop, n))
    coll[0] = Y // 2
    if cfg.freeze_redist:
        redist = np.ones((pop, n), dtype=bool)
    else:
        redist = rng.random((pop, n)) < 0.5
        redist[0] = True
    return Px, Py, coll.astype(np.int64), redist


def _random_population_vec(rng, task, hw, cfg, pop):
    """Vectorized-engine population init: same shape/spirit as
    :func:`_random_population` (uniform center, random unit moves,
    individual 0 stays uniform) but applies the moves to the whole
    ``[P, n]`` tensor per round instead of per individual. The jax engine
    reuses this host-side init so both vectorized paths start from the
    identical population (RNG divergence begins at generation 0)."""
    n = len(task)
    X, Y = hw.X, hw.Y
    base = uniform_partition(task, X, Y)
    base = clamp_partition_to_domain(base, task, X, Y, hw.R, hw.C, cfg.slack)
    Px = np.repeat(base.Px[None], pop, axis=0).astype(np.int64)
    Py = np.repeat(base.Py[None], pop, axis=0).astype(np.int64)
    lo, hi = partition_domain(task, X, Y, hw.R, hw.C, cfg.slack)
    rounds = rng.integers(0, X + Y, size=(pop, n))
    rounds[0] = 0                       # individual 0 stays uniform
    for t in range(X + Y - 1):
        active = rounds > t
        _move_units_vec(rng, Px, hw.R, lo[:, 0], hi[:, 0], active)
        _move_units_vec(rng, Py, hw.C, lo[:, 1], hi[:, 1], active)
    coll = rng.integers(0, Y, size=(pop, n))
    coll[0] = Y // 2
    if cfg.freeze_redist:
        redist = np.ones((pop, n), dtype=bool)
    else:
        redist = rng.random((pop, n)) < 0.5
        redist[0] = True
    return Px, Py, coll.astype(np.int64), redist


def _move_unit(rng, row: np.ndarray, unit: int, lo: int, hi: int) -> None:
    """Move one ``unit`` from a donor entry to a receiver, in place,
    respecting the window — sum-preserving mutation. Rejection-samples a
    few times rather than materializing candidate sets (hot path)."""
    n = len(row)
    if n < 2:
        return
    for _ in range(MOVE_ATTEMPTS):
        d = int(rng.integers(n))
        r = int(rng.integers(n))
        if d == r:
            continue
        if row[d] - unit >= lo * unit and row[r] + unit <= hi * unit:
            row[d] -= unit
            row[r] += unit
            return


def _move_units_vec(rng, P_: np.ndarray, unit: int, lo: np.ndarray,
                    hi: np.ndarray, active: np.ndarray) -> None:
    """Population-wide sum-preserving unit move, in place.

    ``P_`` is ``[P, n, X]`` ints, ``lo``/``hi`` are per-op unit windows
    ``[n]``, ``active`` ``[P, n]`` selects which rows mutate. Rejection
    sampling runs over the whole tensor at once: each attempt draws a
    donor/receiver column per ``(p, i)`` and applies every row whose move
    is feasible; infeasible rows stay pending for the next attempt (the
    per-row semantics of :func:`_move_unit`, batched)."""
    P, n, X = P_.shape
    if X < 2:
        return
    pending = active.copy()
    for _ in range(MOVE_ATTEMPTS):
        if not pending.any():
            return
        d = rng.integers(0, X, size=(P, n))
        r = rng.integers(0, X, size=(P, n))
        dv = np.take_along_axis(P_, d[..., None], axis=-1)[..., 0]
        rv = np.take_along_axis(P_, r[..., None], axis=-1)[..., 0]
        ok = (pending & (d != r)
              & (dv - unit >= lo[None] * unit)
              & (rv + unit <= hi[None] * unit))
        pi, ni = np.nonzero(ok)
        P_[pi, ni, d[ok]] -= unit
        P_[pi, ni, r[ok]] += unit
        pending &= ~ok


def run_ga(
    task: Task,
    hw: HWConfig,
    objective: str = "latency",
    options: EvalOptions | None = None,
    cfg: GAConfig = GAConfig(),
    backend: str | None = None,
    engine: str | None = None,
) -> GAResult:
    """Run the Sec-6.2 GA. ``backend`` picks the fitness evaluator
    (``"numpy"``/``"jax"``/``"auto"``); ``engine`` picks the evolution
    loop (``"python"``/``"vectorized"``, DESIGN.md §10). Both default to
    the :class:`GAConfig` fields."""
    if options is None:
        options = EvalOptions(redistribution=True, async_exec=True)
    engine = engine or cfg.engine
    if engine not in ENGINES:
        raise ValueError(f"unknown engine {engine!r}; one of {ENGINES}")
    backend = resolve_auto_backend(backend or cfg.backend, cfg.population)
    if engine == "vectorized":
        if backend == "jax":
            from . import ga_jax
            return ga_jax.run_ga_jax(task, hw, objective, options, cfg)
        return _run_ga_vectorized(task, hw, objective, options, cfg, backend)
    return _run_ga_python(task, hw, objective, options, cfg, backend)


def _run_ga_python(task, hw, objective, options, cfg, backend) -> GAResult:
    """Reference engine: per-individual offspring loop (PR-1 behavior)."""
    ev = Evaluator(task, hw, options, backend=backend)
    rng = np.random.default_rng(cfg.seed)
    n = len(task)
    X, Y = hw.X, hw.Y
    pop = cfg.population
    elite = min(cfg.elite, pop - 1)   # same clamp as the vectorized engines
    lo, hi = partition_domain(task, X, Y, hw.R, hw.C, cfg.slack)

    Px, Py, coll, redist = _random_population(rng, task, hw, cfg, pop)
    n_eval = 0
    history = []
    best = None  # (obj, genome)
    flat = 0

    for gen in range(cfg.generations):
        fit = ev.objective_batch(
            Px.astype(np.float64), Py.astype(np.float64), coll,
            redist.astype(np.float64), objective)
        n_eval += pop
        order = np.argsort(fit)
        gen_best = float(fit[order[0]])
        if best is None or gen_best < best[0] * (1.0 - 1e-4):
            flat = 0
        else:
            flat += 1
        if best is None or gen_best < best[0]:
            best = (gen_best, (Px[order[0]].copy(), Py[order[0]].copy(),
                               coll[order[0]].copy(), redist[order[0]].copy()))
        history.append(best[0])
        if flat >= cfg.patience:
            break

        # ---------------------------------------------------- next epoch
        nPx = np.empty_like(Px)
        nPy = np.empty_like(Py)
        nco = np.empty_like(coll)
        nrd = np.empty_like(redist)
        # elites
        for e in range(elite):
            j = order[e]
            nPx[e], nPy[e], nco[e], nrd[e] = Px[j], Py[j], coll[j], redist[j]
        # offspring
        for p in range(elite, pop):
            a = _tournament(rng, fit, cfg.tournament)
            b = _tournament(rng, fit, cfg.tournament)
            cPx, cPy = Px[a].copy(), Py[a].copy()
            cco, crd = coll[a].copy(), redist[a].copy()
            if rng.random() < cfg.p_crossover:
                mask = rng.random(n) < 0.5   # per-op uniform crossover
                cPx[mask] = Px[b][mask]
                cPy[mask] = Py[b][mask]
                cco[mask] = coll[b][mask]
                crd[mask] = redist[b][mask]
            # mutations
            for i in range(n):
                if rng.random() < cfg.p_mutate_partition:
                    _move_unit(rng, cPx[i], hw.R, lo[i, 0], hi[i, 0])
                if rng.random() < cfg.p_mutate_partition:
                    _move_unit(rng, cPy[i], hw.C, lo[i, 1], hi[i, 1])
                if rng.random() < cfg.p_mutate_collector:
                    cco[i] = rng.integers(0, Y)
                if not cfg.freeze_redist and \
                        rng.random() < cfg.p_mutate_redist:
                    crd[i] = not crd[i]
            nPx[p], nPy[p], nco[p], nrd[p] = cPx, cPy, cco, crd
        Px, Py, coll, redist = nPx, nPy, nco, nrd

    obj, (bPx, bPy, bco, brd) = best
    part = Partition(bPx, bPy, bco)
    part.validate(task)
    return GAResult(
        partition=part,
        redist_mask=brd & ev.chain_valid,
        objective=obj,
        history=np.array(history),
        evaluations=n_eval,
    )


def _run_ga_vectorized(task, hw, objective, options, cfg, backend
                       ) -> GAResult:
    """Vectorized engine, numpy RNG: every genetic operator acts on the
    whole population per generation — the host-side reference for the
    device-resident port in :mod:`repro.core.ga_jax`."""
    ev = Evaluator(task, hw, options, backend=backend)
    rng = np.random.default_rng(cfg.seed)
    n = len(task)
    X, Y = hw.X, hw.Y
    pop = cfg.population
    elite = min(cfg.elite, pop - 1)
    Q = pop - elite
    lo, hi = partition_domain(task, X, Y, hw.R, hw.C, cfg.slack)

    Px, Py, coll, redist = _random_population_vec(rng, task, hw, cfg, pop)
    n_eval = 0
    history = []
    best = None
    flat = 0

    for gen in range(cfg.generations):
        fit = ev.objective_batch(
            Px.astype(np.float64), Py.astype(np.float64), coll,
            redist.astype(np.float64), objective)
        n_eval += pop
        order = np.argsort(fit)
        gen_best = float(fit[order[0]])
        if best is None or gen_best < best[0] * (1.0 - 1e-4):
            flat = 0
        else:
            flat += 1
        if best is None or gen_best < best[0]:
            best = (gen_best, (Px[order[0]].copy(), Py[order[0]].copy(),
                               coll[order[0]].copy(), redist[order[0]].copy()))
        history.append(best[0])
        if flat >= cfg.patience:
            break

        # --------------------------------------- next epoch, all at once
        a = _tournament_vec(rng, fit, cfg.tournament, Q)
        b = _tournament_vec(rng, fit, cfg.tournament, Q)
        mask = ((rng.random(Q) < cfg.p_crossover)[:, None]
                & (rng.random((Q, n)) < 0.5))      # per-op uniform crossover
        cPx = np.where(mask[..., None], Px[b], Px[a])
        cPy = np.where(mask[..., None], Py[b], Py[a])
        cco = np.where(mask, coll[b], coll[a])
        crd = np.where(mask, redist[b], redist[a])
        # mutations
        _move_units_vec(rng, cPx, hw.R, lo[:, 0], hi[:, 0],
                        rng.random((Q, n)) < cfg.p_mutate_partition)
        _move_units_vec(rng, cPy, hw.C, lo[:, 1], hi[:, 1],
                        rng.random((Q, n)) < cfg.p_mutate_partition)
        resample = rng.random((Q, n)) < cfg.p_mutate_collector
        cco = np.where(resample, rng.integers(0, Y, size=(Q, n)), cco)
        if not cfg.freeze_redist:
            flip = rng.random((Q, n)) < cfg.p_mutate_redist
            crd = np.where(flip, ~crd, crd)
        Px = np.concatenate([Px[order[:elite]], cPx])
        Py = np.concatenate([Py[order[:elite]], cPy])
        coll = np.concatenate([coll[order[:elite]], cco])
        redist = np.concatenate([redist[order[:elite]], crd])

    obj, (bPx, bPy, bco, brd) = best
    part = Partition(bPx, bPy, bco)
    part.validate(task)
    return GAResult(
        partition=part,
        redist_mask=brd & ev.chain_valid,
        objective=obj,
        history=np.array(history),
        evaluations=n_eval,
    )


def _tournament(rng, fit: np.ndarray, k: int) -> int:
    idx = rng.integers(0, len(fit), size=k)
    return int(idx[np.argmin(fit[idx])])


def _tournament_vec(rng, fit: np.ndarray, k: int, num: int) -> np.ndarray:
    """``num`` independent k-way tournaments in one draw: [num] winners."""
    idx = rng.integers(0, len(fit), size=(num, k))
    return idx[np.arange(num), np.argmin(fit[idx], axis=1)]
