"""Per-arch partition specs: parameter shardings by tree-path semantics
(2-D FSDP("data") × TP("model"), MaxText-style) and logical activation
rules. The "pod" axis is pure data parallelism — parameters are replicated
across pods and only gradient all-reduces cross the pod boundary (DCN),
matching the multi-pod production layout.

Every assignment is sanitized against divisibility, so non-divisible kv
head counts, expert counts, odd vocabs or batch=1 cells silently degrade
to replication on that dim instead of failing to lower.
"""
from __future__ import annotations

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from .logical import sanitize_spec

DATA, MODEL, POD = "data", "model", "pod"

# Canonical trailing-dims spec per parameter name. Leading (stack) dims are
# padded with None at application time.
_PARAM_RULES: dict[str, P] = {
    # embeddings / heads
    "embed": P(MODEL, DATA),
    "lm_head": P(DATA, MODEL),
    "patch_proj": P(None, DATA),
    "frame_proj": P(None, DATA),
    "mask_emb": P(None),
    # attention (GQA)
    "wq": P(DATA, MODEL, None),
    "wk": P(DATA, MODEL, None),
    "wv": P(DATA, MODEL, None),
    "wo": P(MODEL, None, DATA),
    # MLA (fallbacks relocate the axis when head counts don't divide)
    "wq_a": P(DATA, MODEL),
    "wq_b": [P(None, MODEL, None), P(DATA, None, None)],
    "wkv_a": P(DATA, None),
    "wk_b": [P(None, MODEL, None), P(DATA, None, None)],
    "wv_b": [P(None, MODEL, None), P(DATA, None, None)],
    # MLP
    "w_gate": P(DATA, MODEL),
    "w_up": P(DATA, MODEL),
    "w_down": P(MODEL, DATA),
    # MoE (fallback: TP the expert FFN dim when n_experts doesn't divide
    # the model axis — the mixtral 8-expert case)
    "router": P(DATA, None),
    "moe_w_gate": [P(MODEL, DATA, None), P(None, DATA, MODEL)],
    "moe_w_up": [P(MODEL, DATA, None), P(None, DATA, MODEL)],
    "moe_w_down": [P(MODEL, None, DATA), P(None, MODEL, DATA)],
    # Mamba2
    "in_proj": P(DATA, MODEL),
    "conv_w": P(None, MODEL),
    "out_proj": P(MODEL, DATA),
    # RWKV6
    "wr": P(DATA, MODEL),
    "ck": P(DATA, MODEL),
    "cv": P(MODEL, DATA),
    "cr": P(DATA, MODEL),
    "wg": P(DATA, MODEL),
    "mix_A": P(DATA, None),
    "mix_B": P(None, None, DATA),
    "decay_A": P(DATA, None),
    "decay_B": P(None, DATA),
    "down": P(DATA, None),   # zamba2 shared-block down projection
}
# rwkv time-mix projections share attention-style names wk/wv/wo but are
# rank-2 — the rank-aware padding below handles both.

_MOE_CONTEXT = ("moe",)


def _rule_for(path: tuple, leaf) -> P | None:
    names = [getattr(k, "key", getattr(k, "name", str(k))) for k in path]
    name = names[-1]
    in_moe = any(n == "moe" for n in names)
    in_rwkv = any(n == "time" for n in names)
    if in_rwkv and name in ("wq", "wk", "wv", "wo"):
        # rwkv time-mix projections are plain (D, D) matrices, not the
        # attention-shaped (D, H, Dh) tensors sharing their names
        rule = P(DATA, MODEL)
    else:
        key = f"moe_{name}" if in_moe and f"moe_{name}" in _PARAM_RULES \
            else name
        rule = _PARAM_RULES.get(key)
    if rule is None:
        return None                       # norms, scalars → replicate
    rank = leaf.ndim if hasattr(leaf, "ndim") else len(leaf.shape)
    candidates = rule if isinstance(rule, list) else [rule]
    padded = []
    for r in candidates:
        trailing = len(r)
        if rank < trailing:
            r = P(DATA, MODEL) if rank == 2 else P(*([None] * rank))
            trailing = len(r)
        padded.append(P(*([None] * (rank - trailing) + list(r))))
    return padded


def _coverage(spec: P, mesh: Mesh) -> int:
    n = 1
    for e in spec:
        if e is None:
            continue
        for a in (e if isinstance(e, tuple) else (e,)):
            n *= mesh.shape[a]
    return n


def _sub_fsdp(spec: P, fsdp) -> P:
    """Replace the symbolic DATA (FSDP) axis with the chosen axis tuple —
    (POD, DATA) extends parameter/optimizer sharding across pods (ZeRO
    over DCN) for models whose state exceeds one pod's HBM; () disables
    FSDP entirely (replicated-params serving: weights stream from local
    HBM instead of being re-gathered per decode step)."""
    out = []
    for e in spec:
        if e == DATA:
            if not fsdp:
                out.append(None)
            else:
                out.append(fsdp if len(fsdp) > 1 else fsdp[0])
        else:
            out.append(e)
    return P(*out)


def param_specs(params_shape, mesh: Mesh, fsdp_axes=(DATA,)):
    """PartitionSpec tree matching a (possibly abstract) param tree.
    Rules may list fallback candidates; the one that keeps the most mesh
    axes after divisibility sanitization wins."""
    fsdp = tuple(a for a in fsdp_axes if a in mesh.shape)

    def one(path, leaf):
        cands = _rule_for(path, leaf)
        if cands is None:
            return P(*([None] * (leaf.ndim if hasattr(leaf, "ndim")
                                 else len(leaf.shape))))
        best, best_cov = None, -1
        for c in cands:
            s = sanitize_spec(_sub_fsdp(c, fsdp), leaf.shape, mesh)
            cov = _coverage(s, mesh)
            if cov > best_cov:
                best, best_cov = s, cov
        return best

    return jax.tree_util.tree_map_with_path(one, params_shape)


def param_shardings(params_shape, mesh: Mesh, fsdp_axes=(DATA,)):
    return jax.tree.map(lambda s: NamedSharding(mesh, s),
                        param_specs(params_shape, mesh, fsdp_axes))


def batch_axes(mesh: Mesh):
    return (POD, DATA) if POD in mesh.shape else (DATA,)


def activation_rules(mesh: Mesh, *, shard_residual: bool = False) -> dict:
    """Logical-name → spec map consumed by ``sharding.logical.shard``.

    ``shard_residual``: additionally shard the residual stream's d_model
    over the model axis (ZeRO-R style activation sharding) — a memory/
    collective trade-off knob used by §Perf.
    """
    dp = batch_axes(mesh)
    res = MODEL if shard_residual else None
    return {
        "act_btd": P(dp, None, res),
        "act_btf": P(dp, None, MODEL),
        "act_bshd": P(dp, None, MODEL, None),
        "act_bti": P(dp, None, MODEL),
        "logits": P(dp, None, MODEL),
        "cache": P(dp, MODEL, None, None),      # seq-sharded KV cache
        "cache_mla": P(dp, MODEL, None),
        "moe_gtd": P(dp, None, None),           # (groups, group_size, D)
        # (groups, experts, capacity, feat): EP over experts, falling back
        # to TP over the expert-FFN dim when n_experts doesn't divide.
        "moe_ecd": P(dp, MODEL, None, None),
        "moe_ecf": [P(dp, MODEL, None, None), P(dp, None, None, MODEL)],
    }


def data_specs(mesh: Mesh) -> dict[str, P]:
    """Input-batch shardings (keyed by input name)."""
    dp = batch_axes(mesh)
    return {
        "tokens": P(dp, None),
        "labels": P(dp, None),
        "mask": P(dp, None),
        "patches": P(dp, None, None),
        "frames": P(dp, None, None),
    }


def cache_shardings(caches_shape, cfg, mesh: Mesh):
    """Shardings for serving state: batch over data axes; attention-cache
    seq (or MLA latent seq) over model; SSM/WKV states over heads."""
    dp = batch_axes(mesh)

    def one(path, leaf):
        names = [getattr(k, "key", getattr(k, "name", str(k)))
                 for k in path]
        name = names[-1]
        rank = len(leaf.shape)
        if name in ("k", "v"):          # (stack.., B, S, KV, Dh)
            rule = P(dp, MODEL, None, None)
        elif name == "ckv" or name == "krope":
            rule = P(dp, MODEL, None)
        elif name == "ssm":             # (.., B, H, P, N)
            rule = P(dp, MODEL, None, None)
        elif name == "wkv":
            rule = P(dp, MODEL, None, None)
        elif name == "conv":            # (.., B, K-1, Cc)
            rule = P(dp, None, MODEL)
        elif name in ("shift_t", "shift_c"):
            rule = P(dp, None)
        else:
            rule = P(*([None] * rank))
        pad = rank - len(rule)
        rule = P(*([None] * pad + list(rule)))
        return NamedSharding(mesh, sanitize_spec(rule, leaf.shape, mesh))

    return jax.tree_util.tree_map_with_path(one, caches_shape)
