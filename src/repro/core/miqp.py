"""MIQP scheduler — paper Sec. 6.3.

The paper formulates workload partitioning as a mixed-integer *quadratic*
program (compute time is the product Px·Py, redistribution gathers are
partition×partition products) and applies two tricks to make it solvable:
(1) multiply constant denominators through the equations, with a global
scaling factor to keep coefficient magnitudes sane, and (2) a first-order
replacement ``1/(c+x) ≈ (c−x)/c²`` for variable denominators.

We go one step further: on the paper's own constrained search space
(partitions are multiples of R within ±slack units of uniform — Sec. 6.2)
every quadratic term is a product of *small-domain integer* variables, so
the QP linearizes **exactly** to an MILP via binary choice expansion:

  * ``u[i,x] = Σ_a val_a·z[i,x,a]``  (one-hot choice binaries),
  * ``max_x u[i,x]`` via one-hot epigraph selection ``mxz``,
  * products ``mx·my`` via ``q_ab ≥ mxz_a + myz_b − 1`` (objective pressure
    makes the relaxation tight),
  * choice×affine products via exact binary McCormick envelopes.

Trick (1) survives as the time-scaling constant (`_SCALE`, seconds→µs);
trick (2) is provided as :func:`approx_inverse` for irregular-hardware
extensions but is not needed on the regular grids evaluated here (all
denominators are constants). The MILP is solved by HiGHS through
``scipy.optimize.milp`` with the paper's wall-clock budget.

The EDP objective (a product of two end-to-end sums) is handled — as the
paper observes, imperfectly — via an ε-constraint sweep on linearized
energy, re-scored exactly afterwards.

Two solver *engines* sit behind :func:`run_miqp` (DESIGN.md §12):

  * ``engine="milp"`` — this module: the linearized program above,
    handed to HiGHS one instance at a time under ``cfg.time_limit``.
    Kept as the Sec.-6.3 reference/audit path (it can *prove* model
    optimality, which the enumeration engine cannot certify once its
    candidate caps bind).
  * ``engine="lattice"`` — :mod:`repro.core.miqp_jax`: the same
    observation taken to its conclusion. Every choice binary above is
    one cell of a small finite lattice, so instead of relaxing the
    products we materialize candidate schedules as genome tensors and
    arg-min the **exact** evaluator over them in batched jitted chunks
    — both congestion modes, EDP scored directly (no ε-sweep), and
    whole sweep grids batched through ``sweep.solve_grid``.
  * ``engine="auto"`` (the default) resolves like ``backend="auto"``
    (:func:`resolve_auto_engine`): it picks ``"lattice"`` — measured
    ≥5× faster end-to-end and never worse on every benchmarked grid
    (``benchmarks/artifacts/miqp_solve.json``); select ``"milp"``
    explicitly when you need HiGHS's optimality certificate.
"""
from __future__ import annotations

import dataclasses

import numpy as np
import scipy.sparse as sp
from scipy.optimize import Bounds, LinearConstraint, milp

from .evaluator import EvalOptions, Evaluator
from .hw import HWConfig, MCMType
from .workload import (Partition, Task, partition_domain,
                       uniform_partition)

__all__ = ["ENGINES", "MIQPConfig", "MIQPResult", "run_miqp",
           "approx_inverse", "resolve_auto_engine"]

_SCALE = 1e6  # model time in microseconds (paper trick #1: constant scaling)

#: Solver engines behind :func:`run_miqp` (DESIGN.md §12).
ENGINES = ("milp", "lattice", "auto")


def approx_inverse(c, x):
    """Paper Sec. 6.3.1 trick #2: 1/(c+x) ≈ (c−x)/c² near x≈0.

    Accepts scalars or (numpy/jax) arrays for both arguments — the
    irregular-hardware extension feeds arrays of variable denominators,
    and the lattice engine may trace it — and stays a pure arithmetic
    expression so it lowers under ``jax.jit``. Relative error is exactly
    ``(x/c)²`` (``tests/test_core_solvers.py`` pins the window)."""
    return (c - x) / (c * c)


def resolve_auto_engine(engine: str) -> str:
    """Resolve ``"auto"`` to a concrete solver engine, mirroring
    :func:`repro.core.evaluator.resolve_auto_backend`. Auto picks
    ``"lattice"``: on the Sec.-6.2 search space it scores the exact
    evaluator (no linearization gap, EDP direct) and measured ≥5×
    faster than the HiGHS path on every benchmarked grid
    (DESIGN.md §12); ``"milp"`` stays available explicitly as the
    optimality-certificate reference."""
    if engine == "auto":
        return "lattice"
    if engine not in ("milp", "lattice"):
        raise ValueError(f"unknown engine {engine!r}; one of {ENGINES}")
    return engine


@dataclasses.dataclass(frozen=True)
class MIQPConfig:
    slack: int = 2
    time_limit: float = 240.0     # paper: ~4 minutes average (milp engine)
    mip_rel_gap: float = 1e-3
    edp_sweep: int = 5            # ε-constraint points (milp EDP objective)
    # ---- engine selection (DESIGN.md §12) -------------------------------
    engine: str = "auto"          # "milp" | "lattice" | "auto" (→ lattice)
    # ---- lattice-engine knobs (ignored by the milp engine) --------------
    # All lattice budgets are *deterministic* candidate counts, not
    # wall-clock: a point's result is identical whether it is solved
    # alone or batched inside a sweep group (the §9 cache invariant).
    backend: str = "auto"         # scoring backend: "numpy"|"jax"|"auto"
    candidate_budget: int = 65536  # exact-mode ceiling on the joint lattice
    eval_budget: int = 120_000    # beam-mode scoring budget (genomes)
    beam_width: int = 8           # beam assignments kept per layer pass
    refine_sweeps: int = 2        # width-1 coordinate-descent passes
    pair_refine: int = 48         # joint chained-pair re-scan: top-k²
    descent_sweeps: int = 10      # unit/swap local-search passes
    max_axis_candidates: int = 512   # per-op per-axis enumeration cap
    max_layer_candidates: int = 1024  # per-op (rows × cols) cap
    score_chunk: int = 2048       # fixed scoring-chunk shape (compile key)
    devices: str = "auto"         # grid-axis execution of the chunked
                                  # scoring calls: "single" | "sharded" |
                                  # "auto" (DESIGN.md §15; result-neutral —
                                  # never part of a cache fingerprint)


@dataclasses.dataclass
class MIQPResult:
    partition: Partition
    redist_mask: np.ndarray
    objective: float              # exact re-evaluated objective
    milp_status: str
    milp_objective: float         # model objective (µs) — diagnostics; the
                                  # lattice engine's model IS the exact
                                  # evaluator, so it reports objective·1e6
                                  # for latency and −1.0 otherwise
    engine: str = "milp"          # which engine produced this result


class _LP:
    """Tiny incremental MILP builder over scipy/HiGHS."""

    def __init__(self):
        self.nv = 0
        self.cost: list[float] = []
        self.lb: list[float] = []
        self.ub: list[float] = []
        self.integer: list[bool] = []
        self.rows: list[tuple[list[int], list[float], float, float]] = []

    def var(self, lb=0.0, ub=np.inf, integer=False, cost=0.0) -> int:
        self.cost.append(cost)
        self.lb.append(lb)
        self.ub.append(ub)
        self.integer.append(integer)
        self.nv += 1
        return self.nv - 1

    def vars(self, n, **kw) -> list[int]:
        return [self.var(**kw) for _ in range(n)]

    def con(self, idx: list[int], coef: list[float], lo: float, hi: float):
        self.rows.append((idx, coef, lo, hi))

    def solve(self, time_limit: float, mip_rel_gap: float):
        data, ri, ci = [], [], []
        clo, chi = [], []
        for r, (idx, coef, lo, hi) in enumerate(self.rows):
            for j, a in zip(idx, coef):
                ri.append(r)
                ci.append(j)
                data.append(a)
            clo.append(lo)
            chi.append(hi)
        A = sp.csr_matrix((data, (ri, ci)), shape=(len(self.rows), self.nv))
        res = milp(
            c=np.array(self.cost),
            constraints=LinearConstraint(A, np.array(clo), np.array(chi)),
            integrality=np.array(self.integer, dtype=int),
            bounds=Bounds(np.array(self.lb), np.array(self.ub)),
            options={"time_limit": time_limit, "mip_rel_gap": mip_rel_gap,
                     "presolve": True},
        )
        return res


def _choice_vals(lo: int, hi: int) -> np.ndarray:
    return np.arange(lo, hi + 1)


def run_miqp(
    task: Task,
    hw: HWConfig,
    objective: str = "latency",
    options: EvalOptions | None = None,
    cfg: MIQPConfig = MIQPConfig(),
    engine: str | None = None,
) -> MIQPResult:
    """Solve for partitions; redistribution decisions follow the fixed
    strategy of Sec. 6.1 (all semantically-valid chained pairs when the
    evaluator options enable redistribution).

    ``engine`` overrides ``cfg.engine`` (DESIGN.md §12): ``"milp"`` is
    the HiGHS program below, ``"lattice"`` the batched exact-enumeration
    engine (:mod:`repro.core.miqp_jax`), ``"auto"``/``None`` resolves
    via :func:`resolve_auto_engine`. The lattice engine additionally
    accepts ``objective="energy"`` and any ``options.congestion``; the
    MILP models the regime pick only."""
    if options is None:
        options = EvalOptions(redistribution=True, async_exec=False)
    if resolve_auto_engine(engine or cfg.engine) == "lattice":
        from . import miqp_jax

        return miqp_jax.solve_lattice_batch(
            [task], [hw], options, objective, cfg)[0]
    if hw.is_hetero:
        # The HiGHS formulation linearizes against the package-scalar
        # rates; per-chiplet rates need the lattice engine, which scores
        # through the (hetero-exact) evaluator constants.
        raise ValueError(
            "engine='milp' models homogeneous grids only; use "
            "engine='lattice' for heterogeneous chiplet classes")
    ev = Evaluator(task, hw, options)
    if objective == "latency":
        try:
            x, status, mobj = _solve_once(task, hw, ev, cfg,
                                          energy_cap=None)
            part, rd = _decode(task, hw, ev, cfg, x)
        except _Infeasible as e:
            # solver hit its budget with no incumbent (large instances):
            # fall back to the uniform partition — downstream polish still
            # improves collectors/placement, and the result is reported
            # honestly as a timeout fallback.
            part = uniform_partition(task, hw.X, hw.Y)
            rd = ev.chain_valid & ev.opts.redistribution
            exact = ev.evaluate(part, rd).latency
            return MIQPResult(part, rd, exact, f"fallback: {e}", -1.0)
        exact = ev.evaluate(part, rd).latency
        return MIQPResult(part, rd, exact, status, mobj)
    if objective == "edp":
        # ε-constraint sweep on linearized energy; exact re-scoring.
        try:
            x0, status, mobj = _solve_once(task, hw, ev, cfg,
                                           energy_cap=None)
            part0, rd0 = _decode(task, hw, ev, cfg, x0)
        except _Infeasible as e:
            part0 = uniform_partition(task, hw.X, hw.Y)
            rd0 = ev.chain_valid & ev.opts.redistribution
            base0 = ev.evaluate(part0, rd0)
            return MIQPResult(part0, rd0, base0.edp,
                              f"fallback: {e}", -1.0)
        base = ev.evaluate(part0, rd0)
        best = (base.edp, part0, rd0, status, mobj)
        e_lo, e_hi = 0.55 * base.energy, 1.0 * base.energy
        for cap in np.geomspace(e_lo, e_hi, cfg.edp_sweep):
            try:
                x, st, mo = _solve_once(
                    task, hw, ev, cfg, energy_cap=float(cap),
                    time_limit=cfg.time_limit / cfg.edp_sweep)
            except _Infeasible:
                continue
            p, rd = _decode(task, hw, ev, cfg, x)
            r = ev.evaluate(p, rd)
            if r.edp < best[0]:
                best = (r.edp, p, rd, st, mo)
        return MIQPResult(best[1], best[2], best[0], best[3], best[4])
    raise ValueError(f"unknown objective {objective}")


class _Infeasible(RuntimeError):
    pass


def _solve_once(task, hw, ev, cfg, energy_cap=None, time_limit=None):
    lp, handles = _formulate(task, hw, ev, cfg, energy_cap)
    res = lp.solve(time_limit or cfg.time_limit, cfg.mip_rel_gap)
    if res.x is None:
        raise _Infeasible(f"MILP failed: {res.message}")
    return res.x, res.message, float(res.fun)


# --------------------------------------------------------------------------
# Formulation
# --------------------------------------------------------------------------
def _formulate(task: Task, hw: HWConfig, ev: Evaluator, cfg: MIQPConfig,
               energy_cap: float | None):
    lp = _LP()
    n = len(task)
    X, Y = hw.X, hw.Y
    R, C = hw.R, hw.C
    B = ev.B
    bw_nop, bw_ent, freq = ev.bw_nop, ev.bw_ent, ev.freq
    top = ev.top
    lo, hi = partition_domain(task, X, Y, R, C, cfg.slack)
    redist = ev.chain_valid & ev.opts.redistribution
    keepA = np.concatenate([[1.0], 1.0 - redist[:-1].astype(float)])
    c_fix = Y // 2  # fixed collector column (GA optimizes it; MIQP fixes it)

    M, K, N = ev.M, ev.K, ev.N
    Mu = np.ceil(M / R).astype(int)
    Nu = np.ceil(N / C).astype(int)
    fill = 2.0 * R + C + K - 2.0
    cyc_coef = (fill + ev.epilogue * R)  # cycles per (u·v) unit product

    S = _SCALE
    z = {}   # (i,x) -> (vals, [var ids])
    w = {}
    mxz = {}
    myz = {}
    energy_terms: list[tuple[int, float]] = []   # linear energy expr
    energy_const = 0.0

    for i in range(n):
        vx = _choice_vals(lo[i, 0], hi[i, 0])
        vy = _choice_vals(lo[i, 1], hi[i, 1])
        for x in range(X):
            ids = lp.vars(len(vx), lb=0, ub=1, integer=True)
            lp.con(ids, [1.0] * len(ids), 1.0, 1.0)          # one-hot
            z[i, x] = (vx, ids)
        for y in range(Y):
            ids = lp.vars(len(vy), lb=0, ub=1, integer=True)
            lp.con(ids, [1.0] * len(ids), 1.0, 1.0)
            w[i, y] = (vy, ids)
        # partition sums (padded to R/C units)
        idx = [j for x in range(X) for j in z[i, x][1]]
        coef = [float(a) for x in range(X) for a in z[i, x][0]]
        lp.con(idx, coef, float(Mu[i]), float(Mu[i]))
        idx = [j for y in range(Y) for j in w[i, y][1]]
        coef = [float(b) for y in range(Y) for b in w[i, y][0]]
        lp.con(idx, coef, float(Nu[i]), float(Nu[i]))
        # max-selection one-hots
        mx_ids = lp.vars(len(vx), lb=0, ub=1, integer=True)
        lp.con(mx_ids, [1.0] * len(mx_ids), 1.0, 1.0)
        my_ids = lp.vars(len(vy), lb=0, ub=1, integer=True)
        lp.con(my_ids, [1.0] * len(my_ids), 1.0, 1.0)
        mxz[i] = (vx, mx_ids)
        myz[i] = (vy, my_ids)
        # mx ≥ u[i,x] ∀x  (objective pressure sets mx = max_x u)
        for x in range(X):
            vals, ids = z[i, x]
            lp.con(mx_ids + ids,
                   [float(a) for a in vx] + [-float(a) for a in vals],
                   0.0, np.inf)
        for y in range(Y):
            vals, ids = w[i, y]
            lp.con(my_ids + ids,
                   [float(b) for b in vy] + [-float(b) for b in vals],
                   0.0, np.inf)

    def u_expr(i, x, scale=1.0):
        vals, ids = z[i, x]
        return ids, [scale * float(a) for a in vals]

    def v_expr(i, y, scale=1.0):
        vals, ids = w[i, y]
        return ids, [scale * float(b) for b in vals]

    total_cost_vars = []

    for i in range(n):
        vx, mx_ids = mxz[i]
        vy, my_ids = myz[i]

        # ------------------------------------------------ t_in (epigraph)
        tin = lp.var(cost=1.0)
        total_cost_vars.append(tin)
        #   off-chip per entrance
        for e in range(top.n_entrances):
            idx, coef = [tin], [1.0]
            for x in range(X):
                if ev.row_mask[e, x] and keepA[i] > 0:
                    ii, cc = u_expr(i, x, -S * keepA[i] * R * K[i] * B
                                    / bw_ent)
                    idx += ii
                    coef += cc
            for y in range(Y):
                if ev.col_mask[e, y]:
                    ii, cc = v_expr(
                        i, y, -S * C * K[i] * ev.w_scale[i] * B / bw_ent)
                    idx += ii
                    coef += cc
            lp.con(idx, coef, 0.0, np.inf)
        #   NoP distribution per chiplet
        for x in range(X):
            for y in range(Y):
                hA = ev.hA[x, y]
                hWv = ev.hW[x, y]
                idx, coef = [tin], [1.0]
                if keepA[i] > 0 and hA > 0:
                    ii, cc = u_expr(i, x, -S * keepA[i] * R * K[i] * B * hA
                                    / bw_nop)
                    idx += ii
                    coef += cc
                if hWv > 0:
                    ii, cc = v_expr(
                        i, y,
                        -S * C * K[i] * ev.w_scale[i] * B * hWv / bw_nop)
                    idx += ii
                    coef += cc
                if len(idx) > 1:
                    lp.con(idx, coef, 0.0, np.inf)

        # ------------------------------------------------ t_comp via q
        tcomp = lp.var(cost=1.0)
        total_cost_vars.append(tcomp)
        q_ids = []
        q_vals = []
        for a, va in enumerate(vx):
            for b, vb in enumerate(vy):
                qv = lp.var(lb=0.0, ub=1.0)
                lp.con([qv, mx_ids[a], my_ids[b]], [1.0, -1.0, -1.0],
                       -1.0, np.inf)
                q_ids.append(qv)
                q_vals.append(float(va * vb))
        lp.con([tcomp] + q_ids,
               [1.0] + [-S * cyc_coef[i] * v / freq for v in q_vals],
               0.0, np.inf)
        # E_mac (paper mode): e_mac·maxcyc·R·C·XY
        for qv, val in zip(q_ids, q_vals):
            energy_terms.append(
                (qv, hw.e_mac_cycle * cyc_coef[i] * val * R * C * X * Y))

        # ------------------------------------------------ t_out
        if redist[i]:
            # Step 1: row gather, exact McCormick (choice × affine).
            t1 = lp.var(cost=1.0)
            total_cost_vars.append(t1)
            Lmax = float(sum(C * hi[i, 1] for y in range(Y) if y < c_fix))
            Rmax = float(sum(C * hi[i, 1] for y in range(Y) if y > c_fix))
            for x in range(X):
                vals, ids = z[i, x]
                for side, mx_side in (("L", Lmax), ("R", Rmax)):
                    if mx_side <= 0:
                        continue
                    g_ids = []
                    for a, va in enumerate(vals):
                        g = lp.var(lb=0.0)
                        # g ≥ Sv − Smax(1−z)
                        sv_idx, sv_coef = [], []
                        for y in range(Y):
                            if (y < c_fix) if side == "L" else (y > c_fix):
                                ii, cc = v_expr(i, y, float(C))
                                sv_idx += ii
                                sv_coef += cc
                        lp.con([g, ids[a]] + sv_idx,
                               [1.0, -mx_side] + [-c for c in sv_coef],
                               -mx_side, np.inf)
                        g_ids.append((g, float(va)))
                    # t1 ≥ R·B/bw · Σ va·g
                    lp.con([t1] + [g for g, _ in g_ids],
                           [1.0] + [-S * R * B * va / bw_nop
                                    for _, va in g_ids],
                           0.0, np.inf)
            # Step 2: broadcast — t2 = mx·R·N·B/bw (linear in mx one-hot).
            t2 = lp.var(cost=1.0)
            total_cost_vars.append(t2)
            lp.con([t2] + mx_ids,
                   [1.0] + [-S * float(a) * R * N[i] * B / bw_nop
                            for a in vx],
                   0.0, np.inf)
            # Step 3: |cumfrac(Px_i) − cumfrac(Px_{i+1})| column shuffles
            # (normalized fractions — consecutive-op row counts may differ).
            t3 = lp.var(cost=1.0)
            total_cost_vars.append(t3)
            for x in range(X - 1):
                d = lp.var(lb=0.0)   # crossing fraction at boundary x
                idx, coef = [d], [1.0]
                for xx in range(x + 1):
                    ii, cc = u_expr(i, xx, -float(R) / M[i])
                    idx += ii
                    coef += cc
                    ii, cc = u_expr(i + 1, xx, float(R) / M[i + 1])
                    idx += ii
                    coef += cc
                lp.con(idx, coef, 0.0, np.inf)
                lp.con(idx, [1.0] + [-c for c in coef[1:]], 0.0, np.inf)
                lp.con([t3, d], [1.0, -S * M[i] * N[i] * B / bw_nop],
                       0.0, np.inf)
                energy_terms.append(
                    (d, hw.e_nop_bit_hop * 8.0 * M[i] * N[i] * B * Y))
            # redistribution energy (gather+broadcast, uniform-col approx)
            for x in range(X):
                ii, cc = u_expr(i, x, 1.0)
                for j, c0 in zip(ii, cc):
                    energy_terms.append(
                        (j, c0 * R * N[i] * B * hw.e_nop_bit_hop * 8.0
                         * max(Y - 1, 1)))
        else:
            tout = lp.var(cost=1.0)
            total_cost_vars.append(tout)
            t = hw.mcm_type
            if t == MCMType.A:
                links = float(top.entrance_links[0])
                const = M[i] * N[i] * B
                lp.con([tout], [1.0], S * const / (links * bw_nop), np.inf)
                lp.con([tout], [1.0], S * const / bw_ent, np.inf)
            elif t == MCMType.B:
                # strip groups: out_e = Px[x_e]·(Σ_{y∈e} Py)·B, exact
                # binary-McCormick.
                for e in range(top.n_entrances):
                    xs = np.where(ev.row_mask[e])[0]
                    ys = np.where(ev.col_mask[e])[0]
                    if len(xs) != 1:
                        continue
                    x_e = int(xs[0])
                    vals, ids = z[i, x_e]
                    Smax = float(C * hi[i, 1] * len(ys))
                    g_ids = []
                    for a, va in enumerate(vals):
                        g = lp.var(lb=0.0)
                        sv_idx, sv_coef = [], []
                        for y in ys:
                            ii, cc = v_expr(i, int(y), float(C))
                            sv_idx += ii
                            sv_coef += cc
                        lp.con([g, ids[a]] + sv_idx,
                               [1.0, -Smax] + [-c for c in sv_coef],
                               -Smax, np.inf)
                        g_ids.append((g, float(va)))
                    links = float(max(top.entrance_links[e], 1))
                    for denom in (links * bw_nop, bw_ent):
                        lp.con([tout] + [g for g, _ in g_ids],
                               [1.0] + [-S * R * B * va / denom
                                        for _, va in g_ids],
                               0.0, np.inf)
            elif t == MCMType.C:
                # per-chiplet 3D offload: max chunk / bw_ent = R·C·mx·my/bw.
                lp.con([tout] + q_ids,
                       [1.0] + [-S * R * C * B * v / bw_ent for v in q_vals],
                       0.0, np.inf)
            else:
                # Type D: conservative bound — groupsize · maxchunk.
                gs = float(top.group_size.max())
                links = float(max(top.entrance_links.min(), 1))
                for denom in (links * bw_nop, bw_ent):
                    lp.con([tout] + q_ids,
                           [1.0] + [-S * gs * R * C * B * v / denom
                                    for v in q_vals],
                           0.0, np.inf)
            # offload memory-write energy
            energy_const += hw.e_mem_bit * 8.0 * M[i] * N[i] * B

        # ------------------------------------------------ t_sync
        if ev.sync[i]:
            tsy = lp.var(cost=1.0)
            total_cost_vars.append(tsy)
            lp.con([tsy] + mx_ids,
                   [1.0] + [-S * float(a) * R * 4.0 * B * max(Y - 1, 1)
                            / bw_nop for a in vx],
                   0.0, np.inf)

        # ------------------------------------------------ linear energy
        # SRAM + memory pulls + NoP loads (collection uses uniform-col
        # approximation for the hop-weighted sum — energy only).
        for x in range(X):
            ii, cc = u_expr(i, x, 1.0)
            h_avg = float(ev.hA[x].mean())
            coef = (hw.e_sram_bit * 8.0 * Y * R * K[i] * B
                    + keepA[i] * hw.e_mem_bit * 8.0
                    * float(ev.row_mask[:, x].sum()) * R * K[i] * B
                    + keepA[i] * hw.e_nop_bit_hop * 8.0 * R * K[i] * B
                    * float(ev.hA[x].sum()))
            if not redist[i]:
                coef += (hw.e_nop_bit_hop * 8.0 * R * (N[i] / Y) * B
                         * float(ev.h_min[x].sum()))
            del h_avg
            for j, c0 in zip(ii, cc):
                energy_terms.append((j, c0 * coef))
        for y in range(Y):
            ii, cc = v_expr(i, y, 1.0)
            coef = (hw.e_sram_bit * 8.0 * X * C * K[i] * ev.w_scale[i] * B
                    + hw.e_mem_bit * 8.0 * float(ev.col_mask[:, y].sum())
                    * C * K[i] * ev.w_scale[i] * B
                    + hw.e_nop_bit_hop * 8.0 * C * K[i] * ev.w_scale[i] * B
                    * float(ev.hW[:, y].sum()))
            for j, c0 in zip(ii, cc):
                energy_terms.append((j, c0 * coef))
        energy_const += hw.e_sram_bit * 8.0 * M[i] * N[i] * B

    if energy_cap is not None:
        idx = [j for j, _ in energy_terms]
        coef = [c for _, c in energy_terms]
        lp.con(idx, coef, -np.inf, float(energy_cap - energy_const))

    return lp, {"z": z, "w": w, "lo": lo, "hi": hi}


def _unpad_rows(vals: np.ndarray, total: int) -> np.ndarray:
    """Un-pad candidate rows to exact sums: the solvers work on R/C-unit
    counts whose padded sums are ``ceil(M/R)·R ≥ M``; the residue comes
    off each row's largest entry (spilling to a neighbour if that entry
    would go negative). Shared by the MILP decode and the lattice
    engine's candidate materialization so both engines land in the same
    actual-partition space (DESIGN.md §12)."""
    arr = np.atleast_2d(np.asarray(vals, dtype=np.int64)).copy()
    d = arr.sum(axis=1) - int(total)
    rows = np.arange(len(arr))
    k = np.argmax(arr, axis=1)
    arr[rows, k] -= d
    for r in np.where(arr[rows, k] < 0)[0]:
        kk = int(k[r])
        j = kk + 1 if kk + 1 < arr.shape[1] else kk - 1
        arr[r, j] += arr[r, kk]
        arr[r, kk] = 0
    return arr


def _decode(task, hw, ev, cfg, x) -> tuple[Partition, np.ndarray]:
    lp, handles = _formulate(task, hw, ev, cfg, None)
    # Rebuild the variable layout deterministically to decode: instead of
    # re-solving, we track z/w ids from the handles of this formulation —
    # they match the solved vector because _formulate is deterministic.
    z, w = handles["z"], handles["w"]
    n = len(task)
    X, Y = hw.X, hw.Y
    Px = np.zeros((n, X), dtype=np.int64)
    Py = np.zeros((n, Y), dtype=np.int64)
    for i in range(n):
        for xx in range(X):
            vals, ids = z[i, xx]
            sel = int(np.argmax([x[j] for j in ids]))
            Px[i, xx] = int(vals[sel]) * hw.R
        for yy in range(Y):
            vals, ids = w[i, yy]
            sel = int(np.argmax([x[j] for j in ids]))
            Py[i, yy] = int(vals[sel]) * hw.C
        # un-pad to exact sums
        Px[i] = _unpad_rows(Px[i], task.ops[i].M)[0]
        Py[i] = _unpad_rows(Py[i], task.ops[i].N)[0]
    coll = np.full(n, hw.Y // 2, dtype=np.int64)
    part = Partition(Px, Py, coll)
    part.validate(task)
    rd = ev.chain_valid & ev.opts.redistribution
    return part, rd
