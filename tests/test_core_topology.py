"""Shared topology layer (DESIGN.md §11): primitives vs the hw.Topology
facade, mesh-link counting on non-square grids, and route-incidence
invariants of the flow network."""
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import topology as topo
from repro.core.hw import _n_mesh_links, make_hw


# ------------------------------------------------------- hw consistency
@pytest.mark.parametrize("t", ["A", "B", "C", "D"])
@pytest.mark.parametrize("grid", [2, 4, 5])
def test_hw_topology_consumes_shared_primitives(t, grid):
    """hw.Topology must be a thin composition of the topology layer —
    same entrances, assignment, and hop matrices."""
    hw = make_hw(t, grid)
    top = hw.topology
    ents = topo.entrances(t, grid, grid)
    assert top.entrances == ents
    eid, xl, yl, Xg, Yg = topo.assign_entrances(grid, grid, ents)
    np.testing.assert_array_equal(top.entrance_id, eid)
    np.testing.assert_array_equal(top.x_local, xl)
    np.testing.assert_array_equal(top.y_local, yl)
    ent_mask, ent_pos, rows, cols = topo.entrance_masks(
        grid, grid, ents, eid)
    np.testing.assert_array_equal(top.entrance_member, ent_mask)
    np.testing.assert_array_equal(top.entrance_pos, ent_pos)
    np.testing.assert_array_equal(top.entrance_rows, rows)
    np.testing.assert_array_equal(top.entrance_cols, cols)


def test_hop_matrices_match_hw_without_3d_masking():
    """The primitive returns raw eq. 10–12 values; hw zeroes 3D chiplets."""
    hw = make_hw("A", 5, diagonal_links=True)
    top = hw.topology
    low, row, col = topo.hop_matrices(top.x_local, top.y_local,
                                      top.Xg, top.Yg, True)
    np.testing.assert_array_equal(top.hops_low, low)       # A has no 3D
    np.testing.assert_array_equal(top.hops_row_shared, row)
    np.testing.assert_array_equal(top.hops_col_shared, col)


# ------------------------------------------- mesh-link counting (eq. 8)
@pytest.mark.parametrize("X,Y,gx,gy,plain,diag", [
    # corners of a non-square 3×5 grid: 2 mesh links, +1 diagonal
    (3, 5, 0, 0, 2, 3),
    (3, 5, 2, 4, 2, 3),
    # edge chiplets: 3 links, +1 diagonal
    (3, 5, 0, 2, 3, 4),
    (3, 5, 1, 0, 3, 4),
    # interior: 4 links, +1 diagonal
    (3, 5, 1, 2, 4, 5),
    # degenerate 1×N strip: interior has 2, ends have 1; no diagonals
    (1, 4, 0, 0, 1, 1),
    (1, 4, 0, 2, 2, 2),
    # 1×1: isolated chiplet
    (1, 1, 0, 0, 0, 0),
    # 2×2: every chiplet is a corner with an interior diagonal mate
    (2, 2, 0, 0, 2, 3),
    (2, 2, 1, 1, 2, 3),
])
def test_n_mesh_links_non_square(X, Y, gx, gy, plain, diag):
    assert _n_mesh_links(gx, gy, X, Y, False) == plain
    assert _n_mesh_links(gx, gy, X, Y, True) == diag
    # the shared-layer function is the same object (single source of truth)
    assert _n_mesh_links is topo.n_mesh_links


def test_n_mesh_links_totals_match_enumeration():
    """Σ per-chiplet incident links = 2 × undirected mesh links (each
    link touches two chiplets) — on a non-square grid."""
    X, Y = 3, 5
    total = sum(_n_mesh_links(gx, gy, X, Y, False)
                for gx in range(X) for gy in range(Y))
    n_undirected = X * (Y - 1) + Y * (X - 1)
    assert total == 2 * n_undirected
    g = topo.MeshGraph(X, Y)
    assert g.n_links == 2 * n_undirected + 2 * X * Y


# ----------------------------------------------------- route incidence
def test_xy_route_is_row_first_and_minimal():
    g = topo.MeshGraph(4, 4)
    r = g.xy_route(0, 15)          # (0,0) -> (3,3)
    assert len(r) == 6             # manhattan distance
    # row-first: the first hops move along the row index
    assert r[0] == (0, 4) and r[2] == (8, 12)
    assert r[3] == (12, 13)
    assert g.xy_route(5, 5) == []


def test_pull_routes_start_at_memory_and_are_contiguous():
    g = topo.MeshGraph(3, 4)
    attach = [0, 7]
    for dst in range(g.n_nodes):
        route = g.pull_route(attach, dst)
        assert route[0][0] == g.mem and route[0][1] in attach
        for (a, b), (c, d) in zip(route, route[1:]):
            assert b == c          # contiguous path
        assert route[-1][1] == dst


def test_incidence_shapes_are_placement_invariant():
    """The link axis is a pure function of (X, Y) — different attachment
    sets batch together (the netsim_jax grid contract)."""
    g = topo.MeshGraph(4, 4)
    a = g.pull_incidence([0])
    b = g.pull_incidence([5])
    c = g.push_incidence([0, 3, 12, 15])
    assert a.shape == b.shape == c.shape == (16, g.n_links)
    assert (g.link_caps(60e9, 1024e9, [0]).shape
            == g.link_caps(60e9, 1024e9, [5, 10]).shape)


def test_pull_and_push_incidence_route_lengths():
    """Pull route length = local hop distance + 1 port link; push is the
    mirror (same length, reversed directions)."""
    g = topo.MeshGraph(4, 4)
    pull = g.pull_incidence([0])
    push = g.push_incidence([0])
    for d in range(16):
        dist = d // 4 + d % 4      # manhattan from corner attach
        assert pull[d].sum() == dist + 1
        assert push[d].sum() == dist + 1
    # pull uses mem->c port direction, push the reverse
    mesh = g.mesh_link_mask()
    assert (pull[:, ~mesh].sum(axis=1) == 1).all()
    assert (push[:, ~mesh].sum(axis=1) == 1).all()
    assert not (pull[:, ~mesh] * push[:, ~mesh]).any()


def test_nearest_attach_tie_break_matches_order():
    # dst 3 at (0,3) is 3 hops from both attach 0 at (0,0) and attach 15
    # at (3,3) — the tie goes to whichever comes first in the list.
    assert topo.nearest_attach([0, 15], 3, 4) == 0
    assert topo.nearest_attach([15, 0], 3, 4) == 15


@settings(max_examples=25, deadline=None)
@given(X=st.integers(1, 4), Y=st.integers(1, 4), seed=st.integers(0, 99))
def test_incidence_uses_only_real_links(X, Y, seed):
    rng = np.random.default_rng(seed)
    g = topo.MeshGraph(X, Y)
    k = int(rng.integers(1, X * Y + 1))
    attach = sorted(rng.choice(X * Y, size=k, replace=False).tolist())
    inc = g.pull_incidence(attach)
    mesh = g.mesh_link_mask()
    port_cols = np.where(~mesh)[0]
    used_ports = port_cols[inc[:, ~mesh].any(axis=0)]
    # every used memory port belongs to an attach chiplet, downstream dir
    for l in used_ports:
        u, v = g.links[l]
        assert u == g.mem and v in attach
