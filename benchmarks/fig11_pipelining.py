"""Fig. 11 reproduction: per-sample pipelining speedup vs batch size.

Paper claims: the RCPSP (ILP) pipeliner finds ample overlap and the
per-sample speedup stays roughly constant across batch sizes.

Grid driving (benchmarks/README.md): one MIQP schedule per workload,
then the whole (workload × batch) pipelining grid runs *batched* through
``sweep.pipeline_sweep`` — one compiled vectorized-SGS call per
(n_ops, batch) shape group, records cached process-wide (DESIGN.md §13).
A congestion-aware variant re-derives the segment durations from netsim
arrival times (``ScheduleResult.segments(congestion="flow")``,
DESIGN.md §11) and pipelines those through the same batched path. The
MILP refinement (which cannot batch) stays per-point via
``sweep.run_grid``.
"""
from __future__ import annotations

from repro.core import make_hw, optimize, sweep
from repro.core.miqp import MIQPConfig
from repro.core.pipelining import PipelineConfig
from repro.core.sweep import PipelinePoint
from repro.graphs import WORKLOADS

from .common import emit, save_json, timed

BATCHES = (2, 4, 8, 16)


def main(fast: bool = False, backend: str = "jax"):
    hw = make_hw("A", 4, "hbm")
    results = {}
    stats0 = sweep.cache_stats()
    wnames = ("alexnet",) if fast else ("alexnet", "vit", "hydranet")
    scheds = {w: optimize(WORKLOADS[w](batch=1), hw, "miqp",
                          backend=backend,
                          miqp_config=MIQPConfig(time_limit=30))
              for w in wnames}

    # Batched pipelining grid: every (workload × batch × congestion)
    # point through pipeline_sweep — same-(n_ops, batch) points share one
    # compiled call; the congestion="flow" variants scheduled alongside
    # the regime ones cost no extra compilations (durations are data).
    cfg = PipelineConfig(engine="vectorized", backend=backend)
    pts, keys = [], []
    for cong in ("regime", "flow"):
        for w in wnames:
            segs = scheds[w].segments(None if cong == "regime" else cong)
            for b in BATCHES:
                pts.append(PipelinePoint(segs, b))
                keys.append((w, b, cong))
    recs, us = timed(sweep.pipeline_sweep, pts, cfg, backend)
    for (w, b, cong), r in zip(keys, recs):
        tag = "" if cong == "regime" else "/flow"
        results[f"{w}/b{b}{tag}"] = r.speedup
        emit(f"fig11/{w}/batch{b}{tag}", us / len(pts),
             f"speedup={r.speedup:.3f}x per_sample_us="
             f"{r.per_sample*1e6:.1f}")

    # MILP refinement on the smallest instance (paper: solver-based) —
    # per-point, the one pipelining path that cannot batch.
    sweep.run_grid(
        sweep.grid(wname=wnames),
        lambda wname: scheds[wname].pipeline(4, use_milp=True),
        emit=lambda pt, r, us: emit(f"fig11/{pt['wname']}/batch4_ilp", us,
                                    f"speedup={r.speedup:.3f}x"))
    stats = sweep.cache_stats()
    print(f"# fig11: sweep cache +{stats['hits'] - stats0['hits']} hits "
          f"/ +{stats['misses'] - stats0['misses']} misses")
    save_json("fig11", results)


if __name__ == "__main__":
    main()
