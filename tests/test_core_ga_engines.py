"""GA engine contract (DESIGN.md §10): python loop vs vectorized numpy
vs device-resident jax.

Exact numpy↔jax trajectory parity is impossible across RNGs, so the
cross-engine contract is property-based —

  * exact per-op partition sums (crossover/mutation are sum-preserving),
  * membership in the Sec-6.2 domain window (multiples of R within
    uniform ± slack),
  * the best objective never regresses across generations,
  * elitism: the final objective never loses to the uniform-partition
    individual seeded at index 0,

— plus fixed-seed solution-quality equivalence: the vectorized engine's
final objective lands within 1% of the python engine's (median over 5
seeds) on alexnet/vit. Hypothesis drives randomized instances of the
operator-level invariants when installed (tests/_hypothesis_compat.py
skips them otherwise; the seeded parametrized tests below always run).
"""
import numpy as np
import pytest

from _hypothesis_compat import given, settings, st

from repro.core import (AUTO_POPULATION_THRESHOLD, EvalOptions, Evaluator,
                        GemmOp, Task, make_hw, resolve_auto_backend,
                        uniform_partition)
from repro.core import sweep
from repro.core.ga import (ENGINES, GAConfig, _move_units_vec,
                           _tournament_vec, run_ga)
from repro.core.workload import partition_domain

# Engine axis: (engine, fitness backend). "vectorized"+"jax" is the
# device-resident path (repro.core.ga_jax).
ENGINE_AXIS = [("python", "numpy"), ("vectorized", "numpy"),
               ("vectorized", "jax")]

OPTS = EvalOptions(redistribution=True, async_exec=True)


def divisible_task(n_ops=4, mx=4, nx=4, X=4, Y=4, R=16, C=16):
    """Task whose dims are exact multiples of (X*R)/(Y*C): the uniform
    partition sits exactly on the window center, so every genome an
    engine can reach stays strictly inside the Sec-6.2 window (no
    repair-residue escape hatch) — the strict-window property holds."""
    ops = [GemmOp("g0", M=mx * X * R, K=256, N=nx * Y * C)]
    for i in range(1, n_ops):
        ops.append(GemmOp(f"g{i}", M=mx * X * R, K=ops[-1].N,
                          N=nx * Y * C, chained=True, sync=(i % 3 == 0)))
    return Task(f"div{n_ops}_{mx}_{nx}", ops)


def assert_invariants(task, hw, cfg, result):
    part = result.partition
    part.validate(task)                       # exact per-op sums
    lo, hi = partition_domain(task, hw.X, hw.Y, hw.R, hw.C, cfg.slack)
    for i in range(len(task)):
        assert (part.Px[i] % hw.R == 0).all()
        assert (part.Px[i] >= lo[i, 0] * hw.R).all()
        assert (part.Px[i] <= hi[i, 0] * hw.R).all()
        assert (part.Py[i] % hw.C == 0).all()
        assert (part.Py[i] >= lo[i, 1] * hw.C).all()
        assert (part.Py[i] <= hi[i, 1] * hw.C).all()
    assert (part.collectors >= 0).all() and (part.collectors < hw.Y).all()
    # best-so-far history never regresses
    assert (np.diff(result.history) <= 1e-18).all()
    assert result.objective == pytest.approx(result.history[-1])
    assert result.evaluations == len(result.history) * cfg.population


@pytest.mark.parametrize("engine,backend", ENGINE_AXIS)
@pytest.mark.parametrize("seed", [0, 3])
def test_engine_invariants(engine, backend, seed):
    task = divisible_task()
    hw = make_hw("A", 4, "hbm", diagonal_links=True)
    cfg = GAConfig(generations=10, population=24, patience=10, seed=seed)
    r = run_ga(task, hw, "latency", OPTS, cfg, backend=backend,
               engine=engine)
    assert_invariants(task, hw, cfg, r)


@pytest.mark.parametrize("engine,backend", ENGINE_AXIS)
def test_engine_elitism_beats_uniform(engine, backend):
    """Individual 0 is the LS-uniform partition and elitism keeps the
    best genome, so no engine may end worse than the uniform schedule."""
    task = divisible_task()
    hw = make_hw("A", 4, "hbm", diagonal_links=True)
    cfg = GAConfig(generations=10, population=24, patience=10, seed=1)
    base = Evaluator(task, hw, OPTS).evaluate(
        uniform_partition(task, hw.X, hw.Y))
    r = run_ga(task, hw, "latency", OPTS, cfg, backend=backend,
               engine=engine)
    assert r.objective <= base.latency * (1 + 1e-12)


@pytest.mark.parametrize("engine,backend", ENGINE_AXIS)
def test_engine_deterministic_given_seed(engine, backend):
    task = divisible_task(n_ops=3)
    hw = make_hw("A", 4)
    cfg = GAConfig(generations=6, population=24, patience=6, seed=9)
    a = run_ga(task, hw, "latency", OPTS, cfg, backend=backend,
               engine=engine)
    b = run_ga(task, hw, "latency", OPTS, cfg, backend=backend,
               engine=engine)
    assert a.objective == b.objective
    np.testing.assert_array_equal(a.partition.Px, b.partition.Px)
    np.testing.assert_array_equal(a.history, b.history)


def test_unknown_engine_rejected():
    task = divisible_task(n_ops=2)
    with pytest.raises(ValueError, match="engine"):
        run_ga(task, make_hw("A", 2), engine="fortran")
    assert ENGINES == ("python", "vectorized")


@pytest.mark.parametrize("engine,backend", ENGINE_AXIS)
def test_zero_patience_runs_one_generation(engine, backend):
    """patience <= 0 means no flat-generation tolerance: every engine
    must still evaluate generation 0 (history/best exist) and stop right
    after it, never freeze an uninitialized genome."""
    task = divisible_task(n_ops=2)
    hw = make_hw("A", 4)
    cfg = GAConfig(generations=5, population=8, patience=0, seed=0)
    r = run_ga(task, hw, "latency", OPTS, cfg, backend=backend,
               engine=engine)
    assert len(r.history) == 1
    assert r.evaluations == cfg.population
    assert_invariants(task, hw, cfg, r)


@pytest.mark.parametrize("engine,backend", ENGINE_AXIS)
def test_oversized_elite_clamped(engine, backend):
    """cfg.elite >= population must clamp (to population-1), identically
    on every engine, instead of crashing the offspring loop."""
    task = divisible_task(n_ops=2)
    hw = make_hw("A", 4)
    cfg = GAConfig(generations=3, population=4, elite=8, patience=3)
    r = run_ga(task, hw, "latency", OPTS, cfg, backend=backend,
               engine=engine)
    assert r.objective > 0
    assert r.evaluations == len(r.history) * cfg.population


def _median_objectives(task, hw, cfg_kw, engine, backend, seeds):
    objs = []
    for s in seeds:
        cfg = GAConfig(seed=s, **cfg_kw)
        objs.append(run_ga(task, hw, "latency", OPTS, cfg,
                           backend=backend, engine=engine).objective)
    return float(np.median(objs))


@pytest.mark.parametrize("wname", ["alexnet", "vit"])
def test_fixed_seed_quality_equivalence(wname):
    """The vectorized (device) engine must match the python engine's
    solution quality within 1% — median over 5 seeds (the engines draw
    from different RNGs, so point-wise trajectory equality is out of
    scope; DESIGN.md §10)."""
    from repro.graphs import WORKLOADS

    task = WORKLOADS[wname](batch=1)
    hw = make_hw("A", 4, "hbm", diagonal_links=True)
    cfg_kw = dict(generations=30, population=32, patience=30)
    seeds = range(5)
    py = _median_objectives(task, hw, cfg_kw, "python", "numpy", seeds)
    vec = _median_objectives(task, hw, cfg_kw, "vectorized", "jax", seeds)
    assert vec == pytest.approx(py, rel=0.01)


# ------------------------------------------------------------- auto backend
def test_resolve_auto_backend():
    assert AUTO_POPULATION_THRESHOLD == 1024
    assert resolve_auto_backend("auto", AUTO_POPULATION_THRESHOLD) == "jax"
    assert resolve_auto_backend("auto",
                                AUTO_POPULATION_THRESHOLD - 1) == "numpy"
    # concrete backends pass through untouched
    assert resolve_auto_backend("numpy", 10**6) == "numpy"
    assert resolve_auto_backend("jax", 1) == "jax"


def test_evaluator_auto_backend_matches_numpy():
    """backend="auto" resolves per evaluate_batch call by population
    size; small batches take the numpy path and must agree exactly."""
    task = divisible_task(n_ops=2)
    hw = make_hw("B", 4)
    part = uniform_partition(task, 4, 4)
    ev_auto = Evaluator(task, hw, OPTS, backend="auto")
    ev_np = Evaluator(task, hw, OPTS, backend="numpy")
    ra = ev_auto.evaluate(part)
    rn = ev_np.evaluate(part)
    assert ra.latency == rn.latency
    assert ra.energy == rn.energy


def test_ga_auto_backend_runs():
    task = divisible_task(n_ops=2)
    hw = make_hw("A", 4)
    cfg = GAConfig(generations=3, population=16, patience=3,
                   backend="auto", engine="vectorized")
    r = run_ga(task, hw, "latency", OPTS, cfg)
    assert r.objective > 0


# --------------------------------------------------------------- solve_grid
@pytest.fixture()
def _fresh_cache():
    sweep.clear_cache()
    yield
    sweep.clear_cache()


def test_solve_grid_matches_run_ga(_fresh_cache):
    """A point solved inside an island batch must equal the same point
    solved alone (per-island RNG depends only on cfg.seed) — the
    invariant that makes solver records cacheable."""
    task = divisible_task()
    other = divisible_task(mx=5)
    hw = make_hw("A", 4, "hbm", diagonal_links=True)
    cfg = GAConfig(generations=8, population=24, patience=8, seed=2)
    recs = sweep.solve_grid(
        [sweep.EvalPoint(task, hw, OPTS), sweep.EvalPoint(other, hw, OPTS)],
        "latency", cfg, cache=False)
    solo = run_ga(task, hw, "latency", OPTS, cfg, backend="jax",
                  engine="vectorized")
    assert recs[0].objective == solo.objective
    np.testing.assert_array_equal(recs[0].partition.Px, solo.partition.Px)
    np.testing.assert_array_equal(recs[0].history, solo.history)
    assert recs[0].evaluations == solo.evaluations
    for rec, t in zip(recs, (task, other)):
        assert_invariants(t, hw, cfg, rec)


def test_solve_grid_caches_solver_records(_fresh_cache):
    task = divisible_task(n_ops=3)
    hw = make_hw("A", 4)
    cfg = GAConfig(generations=4, population=16, patience=4, seed=0)
    pts = [sweep.EvalPoint(task, hw, OPTS)]
    a = sweep.solve_grid(pts, "latency", cfg)[0]
    assert sweep.cache_stats() == {"hits": 0, "misses": 1}
    b = sweep.solve_grid(pts, "latency", cfg)[0]
    assert sweep.cache_stats() == {"hits": 1, "misses": 1}
    assert a.objective == b.objective
    np.testing.assert_array_equal(a.partition.Px, b.partition.Px)
    # a different objective / config / backend is a different record
    sweep.solve_grid(pts, "edp", cfg)
    assert sweep.cache_stats()["misses"] == 2
    sweep.solve_grid(pts, "latency", GAConfig(generations=4, population=16,
                                              patience=4, seed=7))
    assert sweep.cache_stats()["misses"] == 3
    sweep.solve_grid(pts, "latency", cfg, backend="numpy")
    assert sweep.cache_stats()["misses"] == 4
    # cached records cross the boundary by value
    b.partition.Px[0, 0] += 1
    c = sweep.solve_grid(pts, "latency", cfg)[0]
    np.testing.assert_array_equal(a.partition.Px, c.partition.Px)


def test_solve_grid_backend_validation(_fresh_cache):
    """"auto" resolves by cfg.population before fingerprinting (sharing
    the cache with the concrete backend); anything unknown raises."""
    task = divisible_task(n_ops=2)
    pts = [sweep.EvalPoint(task, make_hw("A", 4), OPTS)]
    cfg = GAConfig(generations=2, population=8, patience=2)
    a = sweep.solve_grid(pts, "latency", cfg, backend="auto")[0]
    b = sweep.solve_grid(pts, "latency", cfg, backend="numpy")[0]
    assert sweep.cache_stats() == {"hits": 1, "misses": 1}  # shared record
    assert a.objective == b.objective
    with pytest.raises(ValueError, match="backend"):
        sweep.solve_grid(pts, "latency", cfg, backend="np")
    with pytest.raises(ValueError, match="backend"):
        sweep.eval_sweep(pts, backend="auto")


def test_solve_grid_numpy_backend(_fresh_cache):
    """run.py --backend numpy drives solve_grid too: per-point vectorized
    host engine, same record layout."""
    task = divisible_task(n_ops=2)
    hw = make_hw("A", 4)
    cfg = GAConfig(generations=4, population=16, patience=4)
    rec = sweep.solve_grid([sweep.EvalPoint(task, hw, OPTS)], "latency",
                           cfg, backend="numpy", cache=False)[0]
    ref = run_ga(task, hw, "latency", OPTS, cfg, backend="numpy",
                 engine="vectorized")
    assert rec.objective == ref.objective
    np.testing.assert_array_equal(rec.partition.Px, ref.partition.Px)


# ---------------------------------------------- operator-level properties
@given(seed=st.integers(min_value=0, max_value=2**31 - 1),
       X=st.sampled_from([2, 4, 6]),
       units=st.integers(min_value=2, max_value=8))
@settings(max_examples=25, deadline=None)
def test_move_units_vec_property(seed, X, units):
    """Population-wide unit moves preserve per-row sums and the window."""
    rng = np.random.default_rng(seed)
    n, P, R = 3, 8, 16
    lo = np.full(n, max(1, units - 2), dtype=np.int64)
    hi = np.full(n, units + 2, dtype=np.int64)
    P_ = np.full((P, n, X), units * R, dtype=np.int64)
    sums = P_.sum(axis=-1).copy()
    for _ in range(4):
        _move_units_vec(rng, P_, R, lo, hi,
                        rng.random((P, n)) < 0.7)
    np.testing.assert_array_equal(P_.sum(axis=-1), sums)
    assert (P_ % R == 0).all()
    assert (P_ >= lo[None, :, None] * R).all()
    assert (P_ <= hi[None, :, None] * R).all()


@given(seed=st.integers(min_value=0, max_value=2**31 - 1),
       k=st.integers(min_value=1, max_value=5))
@settings(max_examples=25, deadline=None)
def test_tournament_vec_property(seed, k):
    """Winners are valid indices and a tournament never returns a worse
    candidate than the best of its own draw (argmin semantics)."""
    rng = np.random.default_rng(seed)
    fit = rng.random(17)
    win = _tournament_vec(rng, fit, k, 32)
    assert win.shape == (32,)
    assert ((win >= 0) & (win < len(fit))).all()


@given(seed=st.integers(min_value=0, max_value=2**31 - 1))
@settings(max_examples=10, deadline=None)
def test_vectorized_engine_property(seed):
    """Randomized end-to-end invariants on the vectorized-numpy engine
    (the host reference the device port mirrors)."""
    rng = np.random.default_rng(seed)
    task = divisible_task(n_ops=int(rng.integers(1, 4)),
                          mx=int(rng.integers(2, 6)),
                          nx=int(rng.integers(2, 6)))
    hw = make_hw("A", 4, "hbm",
                 diagonal_links=bool(rng.integers(0, 2)))
    cfg = GAConfig(generations=4, population=12, patience=4,
                   seed=int(rng.integers(0, 2**31)))
    r = run_ga(task, hw, "latency", OPTS, cfg, backend="numpy",
               engine="vectorized")
    assert_invariants(task, hw, cfg, r)
