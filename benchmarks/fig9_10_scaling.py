"""Fig. 9/10 reproduction: latency and EDP scaling on type-A systems of
4×4 / 8×8 / 16×16 chiplets.

Paper claims: MIQP geo-mean 55.5% (latency) / 60.3% (EDP) over LS; GA
24.2% / 35.1%. MIQP > GA, with AlexNet gaining more on larger systems
(redistribution savings grow with scale); GA is relatively stronger on
EDP than latency.

Grid driving (benchmarks/README.md): the (grid × workload) LS references
are one batched sweep (latency and EDP come out of the same records);
the (objective × grid × workload) GA searches run island-batched through
``sweep.solve_grid`` (one compiled call per shape group, DESIGN.md §10)
and their final schedules are scored by one batched ``eval_sweep``; the
MIQP grid goes through ``sweep.run_grid``.
"""
from __future__ import annotations

import time

from repro.core import EvalOptions, make_hw, optimize, sweep
from repro.core.ga import GAConfig
from repro.core.miqp import MIQPConfig
from repro.graphs import WORKLOADS

from .common import emit, geomean, save_json

GA_CFG = GAConfig(generations=60, population=64)
MIQP_CFG = MIQPConfig(time_limit=60, edp_sweep=3)
GA_OPTS = EvalOptions(redistribution=True, async_exec=True)


def main(fast: bool = False, backend: str = "jax"):
    grids = (4, 8) if fast else (4, 8, 16)
    wnames = ("alexnet", "hydranet") if fast else tuple(WORKLOADS)
    tasks = {w: WORKLOADS[w](batch=1) for w in wnames}
    hws = {g: make_hw("A", g, "hbm") for g in grids}

    base_grid = sweep.grid(g=grids, wname=wnames)
    base_recs = sweep.eval_sweep(
        [sweep.EvalPoint(tasks[p["wname"]], hws[p["g"]])
         for p in base_grid],
        backend=backend)
    ref = {(p["g"], p["wname"]): r for p, r in zip(base_grid, base_recs)}

    results = {}
    sp_all = {(o, m): [] for o in ("latency", "edp") for m in ("ga", "miqp")}

    # ---- GA: island-batched solves + one batched scoring sweep per
    # objective (same diagonal-link/options setup as optimize(method="ga")).
    for o in ("latency", "edp"):
        fig = "fig9" if o == "latency" else "fig10"
        pts = [sweep.EvalPoint(tasks[p["wname"]],
                               hws[p["g"]].replace(diagonal_links=True),
                               GA_OPTS)
               for p in base_grid]
        t0 = time.perf_counter()
        ga_recs = sweep.solve_grid(pts, o, GA_CFG, backend=backend)
        us = (time.perf_counter() - t0) * 1e6
        score = sweep.eval_sweep(
            [sweep.EvalPoint(pt.task, pt.hw, GA_OPTS,
                             partition=r.partition,
                             redist_mask=r.redist_mask)
             for pt, r in zip(pts, ga_recs)],
            backend=backend)
        # solve time is per batched call (compile included on a cold
        # cache), not per point — emitted once; per-point rows carry the
        # speedups.
        emit(f"{fig}/ga/solve_grid_total", us, f"{len(pts)} points")
        for p, rec in zip(base_grid, score):
            g, wname = p["g"], p["wname"]
            sp = ref[(g, wname)][o] / rec[o]
            sp_all[(o, "ga")].append(sp)
            results[f"{fig}/{g}/{wname}/ga"] = sp
            emit(f"{fig}/{g}x{g}/{wname}/ga", 0.0, f"speedup={sp:.3f}x")

    # ---- MIQP: per-point solves (cannot batch across points).
    def solve(objective, g, wname):
        return optimize(tasks[wname], hws[g], "miqp", objective,
                        backend=backend, miqp_config=MIQP_CFG)

    def report(pt, r, us):
        o, g, wname = pt["objective"], pt["g"], pt["wname"]
        fig = "fig9" if o == "latency" else "fig10"
        val = r.latency if o == "latency" else r.edp
        sp = ref[(g, wname)][o] / val
        sp_all[(o, "miqp")].append(sp)
        results[f"{fig}/{g}/{wname}/miqp"] = sp
        emit(f"{fig}/{g}x{g}/{wname}/miqp", us, f"speedup={sp:.3f}x")

    sweep.run_grid(
        sweep.grid(objective=("latency", "edp"), g=grids, wname=wnames),
        solve, emit=report, progress="fig9_10/miqp")

    for o in ("latency", "edp"):
        fig = "fig9" if o == "latency" else "fig10"
        for m in ("ga", "miqp"):
            emit(f"{fig}/geomean/{m}", 0.0,
                 f"{(geomean(sp_all[(o, m)]) - 1) * 100:+.1f}% vs LS "
                 f"(paper: GA +24.2/35.1%, MIQP +55.5/60.3%)")
    save_json("fig9_10", results)


if __name__ == "__main__":
    main()
