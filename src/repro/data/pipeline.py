"""Deterministic synthetic data pipeline with host sharding.

Every batch is a pure function of (seed, step, host), so any host can
crash and resume at an arbitrary step with bitwise-identical data — the
property the fault-tolerance layer (checkpoint/restart, stragglers
rescheduled onto fresh hosts) relies on. A real deployment swaps
``synthetic_*`` for tokenized shards; the interface (``__iter__`` over
step-indexed batches + ``at(step)`` random access) is the contract.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    kind: str                 # "lm" | "vlm" | "audio"
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    frontend_dim: int = 0
    frontend_tokens: int = 0
    n_hosts: int = 1
    host_id: int = 0


def _rng(cfg: DataConfig, step: int) -> np.random.Generator:
    return np.random.default_rng(
        np.random.SeedSequence([cfg.seed, step, cfg.host_id]))


class Pipeline:
    def __init__(self, cfg: DataConfig):
        if cfg.global_batch % cfg.n_hosts:
            raise ValueError("global_batch must divide across hosts")
        self.cfg = cfg
        self.local_batch = cfg.global_batch // cfg.n_hosts

    def at(self, step: int) -> dict[str, np.ndarray]:
        cfg = self.cfg
        rng = _rng(cfg, step)
        B, S = self.local_batch, cfg.seq_len
        if cfg.kind == "lm":
            # Markov-ish synthetic text: learnable bigram structure so the
            # example driver's loss actually decreases.
            base = rng.integers(0, cfg.vocab_size, (B, 1))
            steps = rng.integers(0, 17, (B, S - 1)).cumsum(axis=1)
            toks = np.concatenate([base, (base + steps) % cfg.vocab_size],
                                  axis=1)
            return {"tokens": toks.astype(np.int32)}
        if cfg.kind == "vlm":
            st = S - cfg.frontend_tokens
            toks = rng.integers(0, cfg.vocab_size, (B, st), dtype=np.int32)
            patches = rng.standard_normal(
                (B, cfg.frontend_tokens, cfg.frontend_dim)).astype(
                    np.float32)
            return {"tokens": toks, "patches": patches}
        if cfg.kind == "audio":
            frames = rng.standard_normal((B, S, cfg.frontend_dim)).astype(
                np.float32)
            mask = rng.random((B, S)) < 0.08
            # span masking: dilate
            for _ in range(4):
                mask[:, 1:] |= mask[:, :-1]
            labels = rng.integers(0, cfg.vocab_size, (B, S),
                                  dtype=np.int32)
            return {"frames": frames, "mask": mask, "labels": labels}
        raise ValueError(cfg.kind)

    def __iter__(self):
        step = 0
        while True:
            yield self.at(step)
            step += 1


def make_pipeline(cfg, shape_def, seed=0, n_hosts=1, host_id=0) -> Pipeline:
    """Build the pipeline matching a model config + shape cell."""
    kind = {"vlm": "vlm", "audio": "audio"}.get(cfg.family, "lm")
    if cfg.frontend == "vision_stub":
        kind = "vlm"
    return Pipeline(DataConfig(
        kind=kind, vocab_size=cfg.vocab_size,
        seq_len=shape_def["seq_len"], global_batch=shape_def["global_batch"],
        seed=seed, frontend_dim=cfg.frontend_dim,
        frontend_tokens=cfg.frontend_tokens, n_hosts=n_hosts,
        host_id=host_id))
