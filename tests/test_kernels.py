"""Pallas kernel validation (interpret mode on CPU): shape/dtype sweeps
against the pure-jnp ref.py oracles, per the assignment contract."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.kernels.flash_attention.kernel import flash_attention
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.gemm.kernel import matmul
from repro.kernels.gemm.ref import matmul_ref
from repro.kernels.rwkv6.kernel import wkv6
from repro.kernels.rwkv6.ref import wkv6_ref
from repro.kernels.ssm_scan.kernel import ssm_scan
from repro.kernels.ssm_scan.ref import ssm_scan_ref

KEY = jax.random.PRNGKey(0)


# ----------------------------------------------------------------- gemm
@pytest.mark.parametrize("m,k,n", [(128, 128, 128), (256, 512, 128),
                                   (100, 70, 130), (33, 257, 65)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_gemm_sweep(m, k, n, dtype):
    a = jax.random.normal(KEY, (m, k), dtype)
    b = jax.random.normal(jax.random.fold_in(KEY, 1), (k, n), dtype)
    got = matmul(a, b, bm=64, bn=64, bk=64, interpret=True)
    want = matmul_ref(a, b)
    tol = 2e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol * 8)


@settings(max_examples=10, deadline=None)
@given(m=st.integers(8, 200), k=st.integers(8, 200), n=st.integers(8, 200))
def test_gemm_property(m, k, n):
    a = jax.random.normal(KEY, (m, k), jnp.float32)
    b = jax.random.normal(jax.random.fold_in(KEY, 2), (k, n), jnp.float32)
    got = matmul(a, b, bm=64, bn=64, bk=64, interpret=True)
    np.testing.assert_allclose(got, matmul_ref(a, b), rtol=2e-5,
                               atol=1e-4)


# ------------------------------------------------------ flash attention
@pytest.mark.parametrize("kwargs", [
    dict(causal=True),
    dict(causal=False),
    dict(causal=True, window=37),
    dict(causal=True, softcap=30.0),
    dict(causal=True, window=64, softcap=50.0),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_variants(kwargs, dtype):
    B, S, H, KV, Dh = 2, 130, 4, 2, 32
    q = jax.random.normal(KEY, (B, S, H, Dh), dtype)
    k = jax.random.normal(jax.random.fold_in(KEY, 1), (B, S, KV, Dh),
                          dtype)
    v = jax.random.normal(jax.random.fold_in(KEY, 2), (B, S, KV, Dh),
                          dtype)
    got = flash_attention(q, k, v, bq=32, bk=48, interpret=True, **kwargs)
    want = attention_ref(q, k, v, **kwargs)
    tol = 3e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol * 4)


def test_flash_attention_mla_shapes():
    """MLA absorbed form: k-dim != v-dim, MQA (KV=1), custom scale."""
    B, S, H = 2, 96, 8
    dk, dv = 80, 64
    q = jax.random.normal(KEY, (B, S, H, dk), jnp.float32)
    k = jax.random.normal(jax.random.fold_in(KEY, 3), (B, S, 1, dk),
                          jnp.float32)
    v = jax.random.normal(jax.random.fold_in(KEY, 4), (B, S, 1, dv),
                          jnp.float32)
    got = flash_attention(q, k, v, causal=True, scale=0.125, bq=32, bk=32,
                          interpret=True)
    want = attention_ref(q, k, v, causal=True, scale=0.125)
    np.testing.assert_allclose(got, want, rtol=3e-5, atol=1e-4)


@settings(max_examples=6, deadline=None)
@given(s=st.integers(16, 160), h=st.sampled_from([2, 4, 6]),
       g=st.sampled_from([1, 2]))
def test_flash_attention_property(s, h, g):
    B, Dh = 1, 16
    kv = max(1, h // g)
    h = kv * g
    q = jax.random.normal(KEY, (B, s, h, Dh), jnp.float32)
    k = jax.random.normal(jax.random.fold_in(KEY, 5), (B, s, kv, Dh),
                          jnp.float32)
    v = jax.random.normal(jax.random.fold_in(KEY, 6), (B, s, kv, Dh),
                          jnp.float32)
    got = flash_attention(q, k, v, causal=True, bq=32, bk=32,
                          interpret=True)
    want = attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(got, want, rtol=3e-5, atol=1e-4)


# -------------------------------------------------------------- ssm scan
@pytest.mark.parametrize("s,chunk", [(64, 32), (100, 32), (256, 64)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_ssm_scan_sweep(s, chunk, dtype):
    B, H, P, G, N = 2, 4, 16, 2, 8
    ks = jax.random.split(KEY, 6)
    x = jax.random.normal(ks[0], (B, s, H, P), dtype)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, s, H))).astype(dtype)
    a = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.5)
    Bm = jax.random.normal(ks[3], (B, s, G, N), dtype)
    Cm = jax.random.normal(ks[4], (B, s, G, N), dtype)
    D = jax.random.normal(ks[5], (H,)) * 0.1
    got = ssm_scan(x, dt, a, Bm, Cm, D, chunk=chunk, interpret=True)
    want, _ = ssm_scan_ref(x, dt, a, Bm, Cm, D)
    tol = 2e-3 if dtype == jnp.float32 else 6e-2
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol)


# ----------------------------------------------------------------- rwkv6
@pytest.mark.parametrize("s,chunk", [(32, 16), (70, 16), (128, 32)])
def test_wkv6_sweep(s, chunk):
    B, H, K = 2, 3, 16
    ks = jax.random.split(KEY, 5)
    r = jax.random.normal(ks[0], (B, s, H, K))
    k = jax.random.normal(ks[1], (B, s, H, K))
    v = jax.random.normal(ks[2], (B, s, H, K))
    w = jnp.exp(-jnp.exp(jax.random.normal(ks[3], (B, s, H, K)) * 0.5))
    u = jax.random.normal(ks[4], (H, K)) * 0.3
    got = wkv6(r, k, v, w, u, chunk=chunk, interpret=True)
    want, _ = wkv6_ref(r, k, v, w, u)
    np.testing.assert_allclose(got, want, rtol=2e-3, atol=2e-3)


def test_wkv6_bf16():
    B, s, H, K = 1, 48, 2, 16
    ks = jax.random.split(KEY, 5)
    r = jax.random.normal(ks[0], (B, s, H, K), jnp.bfloat16)
    k = jax.random.normal(ks[1], (B, s, H, K), jnp.bfloat16)
    v = jax.random.normal(ks[2], (B, s, H, K), jnp.bfloat16)
    w = jnp.exp(-jnp.exp(jax.random.normal(ks[3], (B, s, H, K)) * 0.5)
                ).astype(jnp.bfloat16)
    u = jax.random.normal(ks[4], (H, K)) * 0.3
    got = wkv6(r, k, v, w, u, chunk=16, interpret=True)
    want, _ = wkv6_ref(r, k, v, w, u)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=6e-2, atol=6e-2)
