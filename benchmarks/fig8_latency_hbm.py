"""Fig. 8 reproduction: normalized end-to-end latency of SIMBA-like / GA /
MIQP vs the LS-uniform baseline, on 4×4 chiplet systems of all four
packaging types with HBM.

Paper claims: GA/MIQP beat LS on every type (geo-means 13%/45%, 5%/15%,
9%/43%, 19%/25% for A–D); SIMBA-like is slightly *worse* than LS; the
GA–MIQP gap is smallest on type D (near-uniform memory distance).
"""
from __future__ import annotations

from repro.core import make_hw, optimize
from repro.core.ga import GAConfig
from repro.core.miqp import MIQPConfig
from repro.graphs import WORKLOADS

from .common import emit, geomean, save_json, timed

GA_CFG = GAConfig(generations=60, population=64)          # ~paper budget
MIQP_CFG = MIQPConfig(time_limit=60)


def main(fast: bool = False):
    workloads = {k: fn(batch=1) for k, fn in WORKLOADS.items()}
    if fast:
        workloads = {k: workloads[k] for k in ("alexnet", "hydranet")}
    results = {}
    for t in "ABCD":
        hw = make_hw(t, 4, "hbm")
        speed = {m: [] for m in ("simba", "ga", "miqp")}
        for wname, task in workloads.items():
            base = optimize(task, hw, "baseline").latency
            for method, cfgkw in (("simba", {}),
                                  ("ga", {"ga_config": GA_CFG}),
                                  ("miqp", {"miqp_config": MIQP_CFG})):
                r, us = timed(optimize, task, hw, method, "latency",
                              **cfgkw)
                sp = base / r.latency
                speed[method].append(sp)
                results[f"{t}/{wname}/{method}"] = sp
                emit(f"fig8/{t}/{wname}/{method}", us,
                     f"speedup={sp:.3f}x")
        for m in speed:
            emit(f"fig8/{t}/geomean/{m}", 0.0,
                 f"{(geomean(speed[m]) - 1) * 100:+.1f}% vs LS")
    save_json("fig8", results)


if __name__ == "__main__":
    main()
