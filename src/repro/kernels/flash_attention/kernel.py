"""Pallas TPU flash attention (forward).

Grid (B·H, Sq/bq, Skv/bk) with the KV dimension innermost: the f32
accumulator / running max / running denominator for one query tile live in
VMEM scratch across KV steps (streaming softmax — identical math to
``blockwise.py``, tile-for-tile). GQA is handled in the index maps: query
head h reads KV head h // (H // KV), so KV tiles are never materialized
per-query-head in HBM. Supports causal, sliding-window and soft-capping.

Block sizes default to (bq, bk) = (256, 512) with Dh up to 256 —
(bq·Dh + 2·bk·Dh + bq·bk) f32 ≈ 1.2 MB of VMEM, comfortably inside the
~16 MB/core budget with double buffering.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -2.0e38


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
                  scale: float, causal: bool, window: int | None,
                  softcap: float | None, bq: int, bk: int, n_k: int,
                  sq: int, skv: int):
    ik = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0].astype(jnp.float32)                 # (bq, Dh)
    k = k_ref[0].astype(jnp.float32)                 # (bk, Dh)
    v = v_ref[0].astype(jnp.float32)                 # (bk, Dv)
    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
    if softcap is not None:
        s = softcap * jnp.tanh(s / softcap)

    iq = pl.program_id(1)
    qpos = iq * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    kpos = ik * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    mask = (kpos < skv) & (qpos < sq)
    if causal:
        mask &= kpos <= qpos
    if window is not None:
        mask &= kpos > qpos - window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]                              # (bq, 1)
    m_new = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
    p = jnp.exp(s - m_new)
    alpha = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * alpha + p.sum(axis=-1, keepdims=True)
    m_ref[...] = m_new
    acc_ref[...] = acc_ref[...] * alpha + jnp.dot(
        p.astype(v.dtype), v, preferred_element_type=jnp.float32)

    @pl.when(ik == n_k - 1)
    def _done():
        o_ref[0, ...] = (acc_ref[...]
                         / jnp.maximum(l_ref[...], 1e-37)
                         ).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "softcap", "scale", "bq", "bk",
                     "interpret"))
def flash_attention(
    q: jnp.ndarray,            # (B, Sq, H, Dh)
    k: jnp.ndarray,            # (B, Skv, KV, Dh)
    v: jnp.ndarray,            # (B, Skv, KV, Dv)
    *,
    causal: bool = True,
    window: int | None = None,
    softcap: float | None = None,
    scale: float | None = None,
    bq: int = 256,
    bk: int = 512,
    interpret: bool = False,
) -> jnp.ndarray:
    B, Sq, H, Dh = q.shape
    _, Skv, KV, Dv = v.shape
    G = H // KV
    if scale is None:
        scale = 1.0 / (Dh ** 0.5)
    bq = min(bq, Sq)
    bk = min(bk, Skv)
    # (B, S, H, D) -> (B*H, S, D) head-major for 2-D tiles
    qh = q.transpose(0, 2, 1, 3).reshape(B * H, Sq, Dh)
    kh = k.transpose(0, 2, 1, 3).reshape(B * KV, Skv, Dh)
    vh = v.transpose(0, 2, 1, 3).reshape(B * KV, Skv, Dv)
    pq, pk = (-Sq) % bq, (-Skv) % bk
    if pq:
        qh = jnp.pad(qh, ((0, 0), (0, pq), (0, 0)))
    if pk:
        kh = jnp.pad(kh, ((0, 0), (0, pk), (0, 0)))
        vh = jnp.pad(vh, ((0, 0), (0, pk), (0, 0)))
    n_q, n_k = (Sq + pq) // bq, (Skv + pk) // bk

    def q_map(bh, iq, ik):
        return (bh, iq, 0)

    # bh enumerates (b, h) pairs; its KV row is b*KV + h//G.
    def kv_index(bh, iq, ik):
        b = bh // H
        h = bh % H
        return (b * KV + h // G, ik, 0)

    out = pl.pallas_call(
        functools.partial(
            _flash_kernel, scale=scale, causal=causal, window=window,
            softcap=softcap, bq=bq, bk=bk, n_k=n_k, sq=Sq, skv=Skv),
        grid=(B * H, n_q, n_k),
        in_specs=[
            pl.BlockSpec((1, bq, Dh), q_map),
            pl.BlockSpec((1, bk, Dh), kv_index),
            pl.BlockSpec((1, bk, Dv), kv_index),
        ],
        out_specs=pl.BlockSpec((1, bq, Dv), q_map),
        out_shape=jax.ShapeDtypeStruct((B * H, Sq + pq, Dv), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, Dv), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
        ],
        interpret=interpret,
    )(qh, kh, vh)
    out = out[:, :Sq].reshape(B, H, Sq, Dv).transpose(0, 2, 1, 3)
    return out
