"""Fused cross-layer co-search — one jitted genome over partition ×
diagonal links × pipeline segmentation, with a batched Pareto front
(DESIGN.md §16).

The paper optimizes partition (GA/MIQP), link configuration, and the
pipeline schedule as *separate passes*. Every pass is now a traced JAX
engine (DESIGN.md §8–§13), so the passes can fuse: this module evolves a
genome spanning all three layers and scores it end-to-end in ONE jitted
fitness that chains the analytical evaluator
(:func:`repro.core.evaluator_jax._eval_single` — regime or flow
congestion) into the vectorized RCPSP scheduler
(:func:`repro.core.pipelining_jax.sgs_instance`):

  * **Genome** — ``Px [n,X]`` / ``Py [n,Y]`` / ``collectors [n]`` /
    ``redist [n]`` (the GA genome, DESIGN.md §10) plus ``diag`` (a
    scalar link-budget gene selecting the plain or the diagonal-link
    mesh — both meshes' evaluator constants ship to device and the gene
    picks per candidate, so link ablation is *searched*, not a separate
    pass) and ``seg [n]`` (a boundary mask: ``seg[i]`` merges a pipeline
    stage boundary after op ``i``; segment durations are a one-hot
    merge of the evaluator's per-op ``(t_in, t_comp, t_out)`` phases,
    so segmentation is searched jointly with the partition that shapes
    those phases).
  * **Fused fitness** — evaluator → segment merge → traced chain
    priorities → SGS makespan at ``cfg.batch`` samples; returns the
    objective vector ``(EDP, latency, energy)`` with EDP/latency on the
    *pipelined* per-sample latency (``makespan / batch``).
  * **Pareto archive in the scan** — each generation merges the
    population's objective vectors into a fixed-size device archive
    (pairwise dominance + deterministic truncation, lowest-EDP
    non-dominated rows kept), so ONE compiled call returns the full
    EDP × latency × energy front instead of N single-objective solves.
  * **Gradient-guided seeding** — the integer partition lattice relaxes
    to a continuous simplex (``softmax(logits) * M``) and the diag gene
    to a sigmoid; ``jax.grad`` of the *smooth* fused fitness
    (``_eval_single(smooth=True)`` + the busiest-resource pipeline
    lower bound ``max(B·Σt_comm, B·Σt_comp, Σt)``) drives a fixed-count
    projected descent whose rounded proposals seed the population
    (rows 2..) and re-anchor the MIQP lattice enumeration
    (:func:`miqp_anchor` → ``miqp_jax._Space(anchor=...)``). All
    budgets are deterministic step counts — never wall-clock.

Exactness: island batching follows the §10 contract — per-island host
init seeded by ``cfg.seed`` alone, per-generation keys shared across
islands — so a point's :class:`CoSearchResult` is bitwise identical
solo, batched, or sharded (``devices=`` via
:mod:`repro.core.sweep_shard`), and
:func:`repro.core.sweep.cosearch_sweep` caches records under
method-tagged fingerprints (§9).

Host-side Pareto utilities (:func:`dominates`, :func:`pareto_mask`,
:class:`ParetoArchive`) mirror the device archive for result extraction
and property tests (``tests/test_pareto_archive.py``).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Sequence

import numpy as np

import jax
import jax.numpy as jnp
from jax import lax, random

from .evaluator import EvalOptions, Evaluator
from .evaluator_jax import _eval_single
from .ga import MOVE_ATTEMPTS, _random_population_vec
from .ga_jax import _move_units
from .hw import HWConfig
from .pipelining_jax import chain_priorities_jnp, sgs_instance
from .workload import (Partition, Task, clamp_partition_to_domain,
                       uniform_partition)

__all__ = [
    "OBJECTIVES",
    "CoSearchConfig",
    "CoSearchResult",
    "dominates",
    "pareto_mask",
    "ParetoArchive",
    "cosearch_islands",
    "run_cosearch",
    "miqp_anchor",
]

#: Objective vector layout of the fused fitness (all minimized): EDP and
#: latency are *pipelined* (makespan / batch); energy is schedule-free.
OBJECTIVES = ("edp", "latency", "energy")

#: Evaluator-constant keys that differ between the plain and the
#: diagonal-link mesh (same shapes — entrances, masks and the flow
#: network are topology-flag-independent); the diag gene selects or, in
#: the smooth relaxation, interpolates exactly these.
DIAG_KEYS = ("hA", "hW", "h_min", "links")

# Carry tuple layout (leaves gain a leading island axis under vmap):
# (Px, Py, co, rd, diag, seg,                      population genes
#  arch_obj, aPx, aPy, aco, ard, adiag, aseg,      Pareto archive
#  best_obj, best_vec, bPx, bPy, bco, brd, bdiag, bseg,
#  flat, steps)
_BEST_OBJ, _BEST_VEC, _FLAT, _STEPS = 13, 14, 21, 22


@dataclasses.dataclass(frozen=True)
class CoSearchConfig:
    """Hyperparameters of the joint search. Frozen + hashable — the full
    config is part of the §9 cache fingerprint and the serve-layer
    CallKey. Every budget is a deterministic count (generations,
    descent steps, archive slots), never wall-clock, so a record is
    reproducible by key alone."""

    population: int = 64
    generations: int = 64
    elite: int = 4
    tournament: int = 3
    p_crossover: float = 0.85
    p_mutate_partition: float = 0.5
    p_mutate_collector: float = 0.2
    p_mutate_redist: float = 0.15
    p_mutate_diag: float = 0.15
    p_mutate_seg: float = 0.25
    slack: int = 2
    patience: int = 64
    seed: int = 0
    #: samples pipelined by the fused fitness (the fig11/fig13 batch).
    batch: int = 4
    #: extra comm-in seconds charged per active pipeline segment — a
    #: sync/drain cost that makes coarse segmentation non-free (0.0
    #: keeps the paper's free-segmentation reading).
    seg_overhead: float = 0.0
    #: device Pareto-archive capacity (finite rows become the front).
    archive_size: int = 32
    #: share of the population replaced by projected-gradient proposals
    #: (rows 2..; rows 0/1 stay the uniform partition on each mesh).
    seed_fraction: float = 0.25
    seed_steps: int = 32
    seed_lr: float = 0.3
    seed_starts: int = 4
    freeze_redist: bool = False
    backend: str = "jax"
    devices: str = "auto"

    def __post_init__(self):
        if self.population < 2:
            raise ValueError("population must be >= 2")
        if self.archive_size < 1:
            raise ValueError("archive_size must be >= 1")
        if not 0.0 <= self.seed_fraction <= 1.0:
            raise ValueError("seed_fraction must be in [0, 1]")
        if self.batch < 1:
            raise ValueError("batch must be >= 1")
        if self.seg_overhead < 0.0:
            raise ValueError("seg_overhead must be >= 0")
        if self.seed_steps < 0 or self.seed_starts < 0:
            raise ValueError("seed_steps/seed_starts must be >= 0")


@dataclasses.dataclass
class CoSearchResult:
    """One point's joint-search result: the best genome on the scalar
    search objective plus the batched Pareto front.

    ``front`` maps ``"edp"/"latency"/"energy"`` to aligned ``[F]``
    arrays and carries the full genome per front row (``"Px" [F,n,X]``,
    ``"Py" [F,n,Y]``, ``"collectors"/"redist"/"seg" [F,n]``,
    ``"diag" [F]``), canonically sorted by (edp, latency, energy) and
    mutually non-dominated. The archive is bounded
    (``cfg.archive_size``), keeping lowest-EDP non-dominated rows — the
    *best* genome is tracked exactly and separately, like the GA's."""

    partition: Partition
    redist_mask: np.ndarray
    diagonal: bool
    seg_mask: np.ndarray
    objective: float
    edp: float
    latency: float
    energy: float
    front: dict[str, np.ndarray]
    history: np.ndarray
    evaluations: int


# ------------------------------------------------ host Pareto utilities
def dominates(a, b) -> bool:
    """Strict Pareto dominance (minimization): every component of ``a``
    <= the matching component of ``b`` and at least one strictly <."""
    a = np.asarray(a, dtype=np.float64)
    b = np.asarray(b, dtype=np.float64)
    return bool(np.all(a <= b) and np.any(a < b))


def pareto_mask(points) -> np.ndarray:
    """``[N] bool`` — non-dominated rows of ``points [N, d]``, with exact
    duplicates keeping only their first occurrence (so the masked set is
    a minimal front: no member dominates or equals another)."""
    pts = np.asarray(points, dtype=np.float64)
    if pts.ndim != 2:
        pts = pts.reshape(len(pts), -1)
    N = len(pts)
    le = np.all(pts[:, None, :] <= pts[None, :, :], axis=-1)
    lt = np.any(pts[:, None, :] < pts[None, :, :], axis=-1)
    dominated = np.any(le & lt, axis=0)
    eq = np.all(pts[:, None, :] == pts[None, :, :], axis=-1)
    idx = np.arange(N)
    dup = np.any(eq & (idx[:, None] < idx[None, :]), axis=0)
    return ~(dominated | dup)


class ParetoArchive:
    """Host mirror of the device archive: insert points, read the front.

    The archive keeps every non-dominated point (pruning newly dominated
    members on insert); :meth:`front` returns the canonical
    (value-sorted) front, optionally truncated to ``k`` rows by the same
    lowest-first rule the device archive uses. Because membership is a
    pure function of the *set* of inserted points, the front is
    invariant to insertion order (``tests/test_pareto_archive.py``
    pins this with hypothesis permutations)."""

    def __init__(self):
        self._points: list[tuple[np.ndarray, object]] = []

    def __len__(self) -> int:
        return len(self._points)

    def insert(self, point, payload=None) -> bool:
        """Add ``point`` (any 1-D objective vector); returns True if it
        joined the archive (i.e. no member dominates or equals it)."""
        p = np.asarray(point, dtype=np.float64).ravel()
        for q, _ in self._points:
            if dominates(q, p) or np.array_equal(q, p):
                return False
        self._points = [(q, pl) for q, pl in self._points
                        if not dominates(p, q)]
        self._points.append((p, payload))
        return True

    def front(self, k: int | None = None) -> np.ndarray:
        """``[F, d]`` front rows, sorted lexicographically by objective
        value; ``k`` keeps the first ``k`` rows (the device archive's
        deterministic truncation rule)."""
        if not self._points:
            return np.zeros((0, 0))
        pts = np.stack([p for p, _ in self._points])
        order = np.lexsort(tuple(pts[:, j]
                                 for j in range(pts.shape[1] - 1, -1, -1)))
        pts = pts[order]
        return pts if k is None else pts[:k]

    def payloads(self, k: int | None = None) -> list:
        """Payloads aligned with :meth:`front` rows."""
        if not self._points:
            return []
        pts = np.stack([p for p, _ in self._points])
        order = np.lexsort(tuple(pts[:, j]
                                 for j in range(pts.shape[1] - 1, -1, -1)))
        out = [self._points[i][1] for i in order]
        return out if k is None else out[:k]


# ----------------------------------------------------- device fitness
def _archive_rank(obj):
    """``obj [Nc, 3]`` → index order: non-dominated rows first (exact
    duplicates keep the lowest index), then by (edp, latency, energy,
    index) — a deterministic total order, so archive truncation is
    reproducible and lane-independent. Empty slots travel as +inf rows:
    any finite row dominates them and they sort last."""
    Nc = obj.shape[0]
    le = jnp.all(obj[:, None, :] <= obj[None, :, :], axis=-1)
    lt = jnp.any(obj[:, None, :] < obj[None, :, :], axis=-1)
    dominated = jnp.any(le & lt, axis=0)
    eq = jnp.all(obj[:, None, :] == obj[None, :, :], axis=-1)
    idx = jnp.arange(Nc)
    dup = jnp.any(eq & (idx[:, None] < idx[None, :]), axis=0)
    bad = (dominated | dup).astype(jnp.int32)
    return jnp.lexsort((idx, obj[:, 2], obj[:, 1], obj[:, 0], bad))


@functools.lru_cache(maxsize=None)
def _fitness_one(batch: int, redistribution: bool, async_exec: bool,
                 energy_mode: str, congestion: str, smooth: bool):
    """The fused single-candidate fitness:
    ``fit(cp, cd, seg_overhead, Px, Py, co, rd, diag, seg)`` → ``[3]``
    objective vector (OBJECTIVES order). ``cp``/``cd`` are the plain and
    diagonal-mesh constant bundles; ``diag`` selects (hard, search) or
    interpolates (``smooth=True``, the differentiable relaxation used by
    the gradient seeding — which also swaps the SGS for its
    busiest-resource lower bound, since ``fori_loop`` scheduling has no
    useful gradient)."""

    def fit(cp, cd, seg_overhead, Px, Py, co, rd, diag, seg):
        n = Px.shape[0]
        if smooth:
            c = {k: ((1.0 - diag) * cp[k] + diag * cd[k]
                     if k in DIAG_KEYS else cp[k]) for k in cp}
        else:
            c = {k: (jnp.where(diag > 0.5, cd[k], cp[k])
                     if k in DIAG_KEYS else cp[k]) for k in cp}
        out = _eval_single(c, Px, Py, co, rd,
                           redistribution=redistribution,
                           async_exec=async_exec, energy_mode=energy_mode,
                           congestion=congestion, smooth=smooth)
        # Segment merge: seg[i] opens a boundary after op i (the last
        # op's bit is ignored), ops map to segment slots by cumulative
        # boundary count, and a one-hot matmul folds per-op phases into
        # per-slot (t_in, t_comp, t_out) durations. Empty slots are
        # zero-duration jobs — harmless to the SGS.
        notlast = jnp.concatenate(
            [jnp.ones((n - 1,), dtype=Px.dtype),
             jnp.zeros((1,), dtype=Px.dtype)])
        b = seg * notlast
        seg_id = jnp.cumsum(jnp.concatenate(
            [jnp.zeros((1,), dtype=Px.dtype), b[:-1]]))
        onehot = (seg_id[:, None] == jnp.arange(n)[None, :]).astype(
            Px.dtype)
        phases = jnp.stack([out["t_in"], out["t_comp"], out["t_out"]],
                           axis=-1)                        # [n, 3]
        slot = onehot.T @ phases                           # [n, 3]
        active = jnp.sign(onehot.sum(axis=0))
        slot = slot + (seg_overhead * active)[:, None] * jnp.asarray(
            [1.0, 0.0, 0.0], dtype=phases.dtype)
        dur = slot.reshape(3 * n)
        if smooth:
            # Busiest-resource lower bound — exact when one resource
            # saturates, differentiable everywhere.
            comm = dur[0::3].sum() + dur[2::3].sum()
            comp = dur[1::3].sum()
            makespan = jnp.maximum(jnp.maximum(batch * comm, batch * comp),
                                   dur.sum())
        else:
            makespan = sgs_instance(3 * n, batch, with_starts=False)(
                dur, chain_priorities_jnp(dur))
        lat = makespan / float(batch)
        energy = out["energy"]
        return jnp.stack([energy * lat, lat, energy])

    return fit


@functools.lru_cache(maxsize=None)
def _chunk_inner(elite: int, tournament: int, freeze_redist: bool,
                 obj_idx: int, batch: int, redistribution: bool,
                 async_exec: bool, energy_mode: str, congestion: str):
    """Unjitted ``vmap(scan(generation-step))`` per static signature —
    the shard_map target of the sharded sweep fabric. Call as
    ``fn(cp, cd, win, hp, carry, keys)`` with consts/window/carry
    stacked on a leading island axis and ``keys [L, 2]`` shared across
    islands (§10 rule: islands differ through their landscape, not
    their draws, so a point's trajectory is grid-independent)."""
    vfit = jax.vmap(
        _fitness_one(batch, redistribution, async_exec, energy_mode,
                     congestion, False),
        in_axes=(None, None, None, 0, 0, 0, 0, 0, 0))

    def step(cp, cd, win, hp, carry, key):
        (Px, Py, co, rd, dg, sg,
         aobj, aPx, aPy, aco, ard, adg, asg,
         best_obj, best_vec, bPx, bPy, bco, brd, bdg, bsg,
         flat, steps) = carry
        pop, n, X = Px.shape
        Y = Py.shape[2]
        K = aobj.shape[0]
        done = (flat >= hp["patience"]) & (steps > 0)

        # ------------------------------------------------ fused fitness
        objs = vfit(cp, cd, hp["seg_overhead"],
                    Px, Py, co, rd, dg, sg)                # [P, 3]
        fit = objs[:, obj_idx]
        order = jnp.argsort(fit)
        gi = order[0]
        gen_best = fit[gi]
        improved = gen_best < best_obj * (1.0 - 1e-4)
        n_flat = jnp.where(improved, 0, flat + 1)
        better = gen_best < best_obj
        n_best_obj = jnp.where(better, gen_best, best_obj)
        n_best_vec = jnp.where(better, objs[gi], best_vec)
        n_bPx = jnp.where(better, Px[gi], bPx)
        n_bPy = jnp.where(better, Py[gi], bPy)
        n_bco = jnp.where(better, co[gi], bco)
        n_brd = jnp.where(better, rd[gi], brd)
        n_bdg = jnp.where(better, dg[gi], bdg)
        n_bsg = jnp.where(better, sg[gi], bsg)

        # ------------------------------------------- Pareto archive merge
        cobj = jnp.concatenate([aobj, objs])               # [K+P, 3]
        keep = _archive_rank(cobj)[:K]
        n_aobj = cobj[keep]
        merge = lambda arch, gene: jnp.concatenate([arch, gene])[keep]
        n_aPx, n_aPy = merge(aPx, Px), merge(aPy, Py)
        n_aco, n_ard = merge(aco, co), merge(ard, rd)
        n_adg, n_asg = merge(adg, dg), merge(asg, sg)

        # ------------------------------------- selection + crossover
        Q = pop - elite
        kt, km, kv = random.split(key, 3)
        ut = random.uniform(kt, (2, Q, tournament))
        um = random.uniform(km, (10, Q, n))
        uv = random.uniform(kv, (4, MOVE_ATTEMPTS, Q, n))

        def tourney(u):
            idx = jnp.floor(u * pop).astype(jnp.int32)
            return idx[jnp.arange(Q), jnp.argmin(fit[idx], axis=1)]

        a = tourney(ut[0])
        b = tourney(ut[1])
        gate = um[0, :, 0] < hp["p_crossover"]
        mask = gate[:, None] & (um[1] < 0.5)
        cPx = jnp.where(mask[..., None], Px[b], Px[a])
        cPy = jnp.where(mask[..., None], Py[b], Py[a])
        cco = jnp.where(mask, co[b], co[a])
        crd = jnp.where(mask, rd[b], rd[a])
        csg = jnp.where(mask, sg[b], sg[a])
        cdg = jnp.where(gate & (um[7, :, 0] < 0.5), dg[b], dg[a])

        # -------------------------------------------------- mutations
        cPx = _move_units(uv[0:2], cPx, cp["R"], win["lo_x"],
                          win["hi_x"], um[2] < hp["p_mutate_partition"])
        cPy = _move_units(uv[2:4], cPy, cp["C"], win["lo_y"],
                          win["hi_y"], um[3] < hp["p_mutate_partition"])
        mutc = um[4] < hp["p_mutate_collector"]
        cco = jnp.where(
            mutc, jnp.floor(um[5] * Y).astype(cco.dtype), cco)
        if not freeze_redist:
            mutr = um[6] < hp["p_mutate_redist"]
            crd = jnp.where(mutr, 1.0 - crd, crd)
        mutd = um[8, :, 0] < hp["p_mutate_diag"]
        cdg = jnp.where(mutd, 1.0 - cdg, cdg)
        notlast = jnp.concatenate(
            [jnp.ones((n - 1,), dtype=sg.dtype),
             jnp.zeros((1,), dtype=sg.dtype)])
        muts = (um[9] < hp["p_mutate_seg"]) & (notlast > 0)
        csg = jnp.where(muts, 1.0 - csg, csg) * notlast

        el = order[:elite]
        new = (
            jnp.concatenate([Px[el], cPx]),
            jnp.concatenate([Py[el], cPy]),
            jnp.concatenate([co[el], cco]),
            jnp.concatenate([rd[el], crd]),
            jnp.concatenate([dg[el], cdg]),
            jnp.concatenate([sg[el], csg]),
            n_aobj, n_aPx, n_aPy, n_aco, n_ard, n_adg, n_asg,
            n_best_obj, n_best_vec, n_bPx, n_bPy, n_bco, n_brd,
            n_bdg, n_bsg, n_flat, steps + 1,
        )
        # Freeze done islands (§10: early-stopped islands must report
        # exactly what a solo early-stopped run would).
        carry = jax.tree_util.tree_map(
            lambda old, upd: jnp.where(done, old, upd), carry, new)
        return carry, (carry[_BEST_OBJ], carry[_FLAT])

    def chunk(cp, cd, win, hp, carry, keys):
        def body(c, k):
            return step(cp, cd, win, hp, c, k)
        return lax.scan(body, carry, keys)

    return jax.vmap(chunk, in_axes=(0, 0, 0, None, 0, None))


@functools.lru_cache(maxsize=None)
def _chunk_fn(*statics):
    """One compiled ``vmap(scan(step))`` per static signature."""
    return jax.jit(_chunk_inner(*statics))


# ------------------------------------------------- gradient seeding
def _hw_pair(hw: HWConfig) -> tuple[HWConfig, HWConfig]:
    return (dataclasses.replace(hw, diagonal_links=False),
            dataclasses.replace(hw, diagonal_links=True))


def _consts_pair(task: Task, hw: HWConfig, options: EvalOptions):
    """(plain, diagonal) constant bundles + the plain Evaluator. Raises
    if the two meshes diverge outside DIAG_KEYS — the diag gene's
    select/interpolate contract."""
    hw_p, hw_d = _hw_pair(hw)
    evp = Evaluator(task, hw_p, options, backend="numpy")
    evd = Evaluator(task, hw_d, options, backend="numpy")
    cp, cd = evp.consts(), evd.consts()
    for k in cp:
        if k in DIAG_KEYS:
            continue
        if not np.array_equal(np.asarray(cp[k]), np.asarray(cd[k])):
            raise RuntimeError(
                f"diagonal-link mesh changed const {k!r} outside "
                f"DIAG_KEYS — the co-search diag gene cannot select it")
    return cp, cd, evp


@functools.lru_cache(maxsize=None)
def _descend_fn(batch: int, redistribution: bool, async_exec: bool,
                energy_mode: str, oi: int, steps: int):
    """One compiled vmapped projected-gradient descent per static
    signature. Rebuilding (and therefore re-jitting) the descent inside
    every :func:`gradient_seeds` call cost ~1.2 s of warm wall-clock per
    island — more than the evolution itself — so the jit wrapper is
    cached here and shape-specializes per (starts, n, X, Y) like any
    jitted function."""
    fit = _fitness_one(batch, redistribution, async_exec, energy_mode,
                       "regime", True)

    def loss(p, cpj, cdj, so, Mj, Nj, cov, rdv, sgv):
        Px = jax.nn.softmax(p["lx"], axis=-1) * Mj
        Py = jax.nn.softmax(p["ly"], axis=-1) * Nj
        w = jax.nn.sigmoid(p["t"])
        return fit(cpj, cdj, so, Px, Py, cov, rdv, w, sgv)[oi]

    def descend(p0, cpj, cdj, so, Mj, Nj, cov, rdv, sgv, lr):
        def body(_, p):
            g = jax.grad(loss)(p, cpj, cdj, so, Mj, Nj, cov, rdv, sgv)
            return jax.tree_util.tree_map(
                lambda x, gg: x - lr * gg
                / (jnp.max(jnp.abs(gg)) + 1e-30), p, g)
        return lax.fori_loop(0, steps, body, p0)

    return jax.jit(jax.vmap(descend, in_axes=(0,) + (None,) * 9))


def gradient_seeds(task: Task, hw: HWConfig, options: EvalOptions,
                   objective: str, cfg: CoSearchConfig
                   ) -> list[tuple[Partition, bool]]:
    """Projected-gradient genome proposals (deduplicated), deterministic
    in ``cfg.seed``: relax the partition lattice to a simplex
    (``softmax(logits) * M``) and the diag gene to a sigmoid, descend
    the smooth fused fitness for ``cfg.seed_steps`` fixed steps from
    ``cfg.seed_starts`` jittered starts (per-leaf max-normalized steps,
    ``lr = cfg.seed_lr``), then round through
    :func:`repro.core.workload.clamp_partition_to_domain`. The smooth
    objective always runs the regime congestion path — the flow
    netsim's ``while_loop`` is not reverse-differentiable — which is
    fine for a *seed*: the search itself scores the requested model."""
    if cfg.seed_starts < 1 or cfg.seed_steps < 1:
        return []
    opts = dataclasses.replace(options, congestion="regime")
    cp, cd, evp = _consts_pair(task, hw, opts)
    n, X, Y = len(task), hw.X, hw.Y
    Mv = np.asarray(evp.M, dtype=np.float64)
    Nv = np.asarray(evp.N, dtype=np.float64)
    co = np.full(n, Y // 2, dtype=np.float64)
    rd = (np.asarray(evp.chain_valid, dtype=np.float64)
          if opts.redistribution else np.zeros(n))
    sg = np.ones(n)
    descend = _descend_fn(int(cfg.batch), bool(opts.redistribution),
                          bool(opts.async_exec), opts.energy_mode,
                          OBJECTIVES.index(objective),
                          int(cfg.seed_steps))
    S = int(cfg.seed_starts)

    with jax.experimental.enable_x64():
        cpj = {k: jnp.asarray(v) for k, v in cp.items()}
        cdj = {k: jnp.asarray(v) for k, v in cd.items()}
        cov = jnp.asarray(co)
        rdv = jnp.asarray(rd)
        sgv = jnp.asarray(sg)
        so = jnp.asarray(float(cfg.seg_overhead))
        Mj = jnp.asarray(Mv)[:, None]
        Nj = jnp.asarray(Nv)[:, None]

        k1, k2, k3 = random.split(random.PRNGKey(cfg.seed), 3)
        p0 = {
            "lx": 0.5 * random.normal(k1, (S, n, X), dtype=jnp.float64),
            "ly": 0.5 * random.normal(k2, (S, n, Y), dtype=jnp.float64),
            "t": random.normal(k3, (S,), dtype=jnp.float64),
        }
        # Start 0 descends from the neutral point (uniform simplex,
        # diag 0.5) — the relaxed analogue of the uniform partition.
        p0 = {k: v.at[0].set(0.0) for k, v in p0.items()}
        pT = descend(p0, cpj, cdj, so, Mj, Nj, cov, rdv, sgv,
                     jnp.asarray(float(cfg.seed_lr)))
        Pxs = np.asarray(jax.nn.softmax(pT["lx"], axis=-1) * Mj)
        Pys = np.asarray(jax.nn.softmax(pT["ly"], axis=-1) * Nj)
        ws = np.asarray(jax.nn.sigmoid(pT["t"]))

    seeds: list[tuple[Partition, bool]] = []
    seen: set = set()
    for s in range(S):
        part = Partition(np.rint(Pxs[s]).astype(np.int64),
                         np.rint(Pys[s]).astype(np.int64),
                         co.astype(np.int64))
        part = clamp_partition_to_domain(part, task, X, Y, hw.R, hw.C,
                                         cfg.slack)
        dg = bool(ws[s] > 0.5)
        key = (part.Px.tobytes(), part.Py.tobytes(), dg)
        if key not in seen:
            seen.add(key)
            seeds.append((part, dg))
    return seeds


def miqp_anchor(task: Task, hw: HWConfig, options: EvalOptions,
                objective: str = "edp",
                cfg: CoSearchConfig | None = None) -> Partition:
    """The best projected-gradient proposal, as a lattice anchor for the
    MIQP enumeration (``miqp_jax._Space(anchor=...)``): candidate sets
    re-order (and, under a cap, prune) around the proposal instead of
    the uniform split. Falls back to the uniform partition when seeding
    is disabled."""
    cfg = cfg or CoSearchConfig()
    seeds = gradient_seeds(task, hw, options, objective, cfg)
    if not seeds:
        return clamp_partition_to_domain(
            uniform_partition(task, hw.X, hw.Y), task, hw.X, hw.Y,
            hw.R, hw.C, cfg.slack)
    return seeds[0][0]


# --------------------------------------------------------- entry points
def _init_island(task: Task, hw: HWConfig, options: EvalOptions,
                 cfg: CoSearchConfig, seeds):
    """Host population init (seeded by ``cfg.seed`` alone — grid-
    position-independent, the §10 rule): the shared GA init for the
    partition genes plus the co-search genes. Row 0 = uniform partition
    on the plain mesh / one segment; row 1 = uniform on the diagonal
    mesh / per-op segments — elitism floors the search at both
    separate-pass baselines. Gradient seeds fill rows 2.. up to
    ``cfg.seed_fraction``."""
    pop = cfg.population
    n, Y = len(task), hw.Y
    rng = np.random.default_rng(cfg.seed)
    Px, Py, coll, redist = _random_population_vec(rng, task, hw, cfg, pop)
    dg = (rng.random(pop) < 0.5).astype(np.float64)
    sg = (rng.random((pop, n)) < 0.5).astype(np.float64)
    sg[:, -1] = 0.0
    dg[0], dg[1] = 0.0, 1.0
    sg[0] = 0.0
    sg[1, :-1] = 1.0
    # Row 1 re-uses row 0's uniform partition so both mesh variants
    # start from the separate-pass baselines' LS genome.
    Px[1], Py[1], coll[1], redist[1] = Px[0], Py[0], coll[0], redist[0]
    k = min(len(seeds), int(round(cfg.seed_fraction * pop)), pop - 2)
    for j in range(k):
        part, diag = seeds[j]
        row = 2 + j
        Px[row], Py[row] = part.Px, part.Py
        coll[row] = part.collectors
        dg[row] = float(diag)
        sg[row, :-1], sg[row, -1] = 1.0, 0.0
    return Px, Py, coll, redist, dg, sg


def cosearch_islands(
    tasks: Sequence[Task],
    hws: Sequence[HWConfig],
    options: EvalOptions,
    objective: str,
    cfg: CoSearchConfig,
    devices: str | None = None,
    seeds: Sequence[Sequence[tuple[Partition, bool]]] | None = None,
) -> list[CoSearchResult]:
    """Evolve one joint search per (task, hw) island through a single
    compiled call (islands must share a shape signature —
    :func:`repro.core.sweep.cosearch_sweep` groups). ``hws`` entries are
    normalized to their plain-mesh variant internally: the diag gene
    *searches* the link axis, so a point's result is independent of the
    incoming ``diagonal_links`` flag. ``seeds=None`` computes
    projected-gradient proposals per island (``cfg.seed_fraction == 0``
    disables); pass explicit per-island seed lists (possibly empty) to
    override — e.g. the cold-start arm of a seeding experiment.

    ``devices`` (default ``cfg.devices``) shards the island axis via
    :mod:`repro.core.sweep_shard`; results are bitwise identical to the
    single-device path."""
    from . import sweep_shard

    if objective not in OBJECTIVES:
        raise ValueError(f"unknown objective {objective!r}; "
                         f"one of {OBJECTIVES}")
    G = len(tasks)
    assert G == len(hws) and G > 0
    pop = cfg.population
    elite = min(cfg.elite, pop - 1)
    K = int(cfg.archive_size)

    pairs = [_consts_pair(t, dataclasses.replace(h, diagonal_links=False),
                          options) for t, h in zip(tasks, hws)]
    keys0 = pairs[0][0].keys()
    cp = {k: np.stack([p[0][k] for p in pairs]) for k in keys0}
    cd = {k: np.stack([p[1][k] for p in pairs]) for k in keys0}
    evs = [p[2] for p in pairs]

    from .workload import partition_domain
    win = {"lo_x": [], "hi_x": [], "lo_y": [], "hi_y": []}
    inits = []
    for g, (t, h) in enumerate(zip(tasks, hws)):
        lo, hi = partition_domain(t, h.X, h.Y, h.R, h.C, cfg.slack)
        win["lo_x"].append(lo[:, 0])
        win["hi_x"].append(hi[:, 0])
        win["lo_y"].append(lo[:, 1])
        win["hi_y"].append(hi[:, 1])
        if seeds is not None:
            sd = list(seeds[g])
        elif cfg.seed_fraction > 0:
            sd = gradient_seeds(t, h, options, objective, cfg)
        else:
            sd = []
        inits.append(_init_island(t, h, options, cfg, sd))
    win = {k: np.stack(v).astype(np.float64) for k, v in win.items()}
    hp = {
        "p_crossover": float(cfg.p_crossover),
        "p_mutate_partition": float(cfg.p_mutate_partition),
        "p_mutate_collector": float(cfg.p_mutate_collector),
        "p_mutate_redist": float(cfg.p_mutate_redist),
        "p_mutate_diag": float(cfg.p_mutate_diag),
        "p_mutate_seg": float(cfg.p_mutate_seg),
        "patience": int(cfg.patience),
        "seg_overhead": float(cfg.seg_overhead),
    }
    statics = (elite, int(cfg.tournament), bool(cfg.freeze_redist),
               OBJECTIVES.index(objective), int(cfg.batch),
               bool(options.redistribution), bool(options.async_exec),
               options.energy_mode, options.congestion)
    if devices is None:
        devices = getattr(cfg, "devices", "single")
    if sweep_shard.resolve_devices(devices, G) == "sharded":
        inner = _chunk_inner(*statics)

        def fn(cp, cd, win, hp, carry, keys):
            return sweep_shard.sharded_grid_call(
                inner, (cp, cd, win, hp, carry, keys),
                (True, True, True, False, True, False), G)
    else:
        fn = _chunk_fn(*statics)

    n = len(tasks[0])
    X, Y = hws[0].X, hws[0].Y
    with jax.experimental.enable_x64():
        cpj = {k: jnp.asarray(v) for k, v in cp.items()}
        cdj = {k: jnp.asarray(v) for k, v in cd.items()}
        win_j = {k: jnp.asarray(v) for k, v in win.items()}
        f8 = lambda a: jnp.asarray(a, dtype=jnp.float64)
        carry = (
            f8(np.stack([i[0] for i in inits])),
            f8(np.stack([i[1] for i in inits])),
            f8(np.stack([i[2] for i in inits])),
            f8(np.stack([i[3] for i in inits])),
            f8(np.stack([i[4] for i in inits])),
            f8(np.stack([i[5] for i in inits])),
            jnp.full((G, K, 3), jnp.inf, dtype=jnp.float64),
            jnp.zeros((G, K, n, X), dtype=jnp.float64),
            jnp.zeros((G, K, n, Y), dtype=jnp.float64),
            jnp.zeros((G, K, n), dtype=jnp.float64),
            jnp.zeros((G, K, n), dtype=jnp.float64),
            jnp.zeros((G, K), dtype=jnp.float64),
            jnp.zeros((G, K, n), dtype=jnp.float64),
            jnp.full((G,), jnp.inf, dtype=jnp.float64),
            jnp.full((G, 3), jnp.inf, dtype=jnp.float64),
            jnp.zeros((G, n, X), dtype=jnp.float64),
            jnp.zeros((G, n, Y), dtype=jnp.float64),
            jnp.zeros((G, n), dtype=jnp.float64),
            jnp.zeros((G, n), dtype=jnp.float64),
            jnp.zeros((G,), dtype=jnp.float64),
            jnp.zeros((G, n), dtype=jnp.float64),
            jnp.zeros((G,), dtype=jnp.int32),
            jnp.zeros((G,), dtype=jnp.int32),
        )
        key = random.PRNGKey(cfg.seed)
        best_hist = []
        gens_left = int(cfg.generations)
        chunk_len = max(1, min(int(cfg.patience), gens_left))
        while gens_left > 0:
            L = min(chunk_len, gens_left)
            key, sub = random.split(key)
            keys = random.split(sub, L)
            carry, (yb, _yf) = fn(cpj, cdj, win_j, hp, carry, keys)
            best_hist.append(np.asarray(yb))
            gens_left -= L
            if (np.asarray(carry[_FLAT]) >= cfg.patience).all():
                break

        host = [np.asarray(leaf) for leaf in carry]
    best_all = np.concatenate(best_hist, axis=1)            # [G, T]

    (aobj, aPx, aPy, aco, ard, adg, asg) = host[6:13]
    best_obj, best_vec = host[13], host[14]
    bPx, bPy, bco, brd, bdg, bsg = host[15:21]
    steps = host[22]

    results = []
    for g in range(G):
        T = int(steps[g])
        part = Partition(np.rint(bPx[g]).astype(np.int64),
                         np.rint(bPy[g]).astype(np.int64),
                         np.rint(bco[g]).astype(np.int64))
        part.validate(tasks[g])
        finite = np.isfinite(aobj[g][:, 0])
        fo = aobj[g][finite]
        mask = pareto_mask(fo)
        order = np.lexsort((fo[mask][:, 2], fo[mask][:, 1],
                            fo[mask][:, 0]))
        sel = np.flatnonzero(finite)[mask][order]
        seg_best = bsg[g] > 0.5
        if n:
            seg_best[-1] = False
        front_seg = asg[g][sel] > 0.5
        if n:
            front_seg[:, -1] = False
        results.append(CoSearchResult(
            partition=part,
            redist_mask=(brd[g] > 0.5) & evs[g].chain_valid,
            diagonal=bool(bdg[g] > 0.5),
            seg_mask=seg_best,
            objective=float(best_obj[g]),
            edp=float(best_vec[g][0]),
            latency=float(best_vec[g][1]),
            energy=float(best_vec[g][2]),
            front={
                "edp": aobj[g][sel][:, 0].copy(),
                "latency": aobj[g][sel][:, 1].copy(),
                "energy": aobj[g][sel][:, 2].copy(),
                "Px": aPx[g][sel].copy(),
                "Py": aPy[g][sel].copy(),
                "collectors": aco[g][sel].copy(),
                "redist": ard[g][sel] > 0.5,
                "diag": adg[g][sel] > 0.5,
                "seg": front_seg,
            },
            history=best_all[g, :T].copy(),
            evaluations=T * pop,
        ))
    return results


def run_cosearch(task: Task, hw: HWConfig, objective: str = "edp",
                 options: EvalOptions | None = None,
                 cfg: CoSearchConfig | None = None) -> CoSearchResult:
    """Single-point entry: the ``G=1`` case of :func:`cosearch_islands`
    (same executable, so the result matches the island path exactly)."""
    return cosearch_islands([task], [hw], options or EvalOptions(),
                            objective, cfg or CoSearchConfig())[0]
