"""§Perf hillclimbing harness: hypothesis → change → re-lower → measure.

Each iteration names a hypothesis, applies a change through the
``lower_cell``/``calibrate_cell`` knobs (sharding-rule overrides,
accumulation, config fields), recompiles the cell, and records the three
roofline terms before/after. Results append to
``benchmarks/artifacts/perf_log.json`` and are summarized in
EXPERIMENTS.md §Perf.

Run (512 virtual devices):
    PYTHONPATH=src python -m benchmarks.perf_iterations --cell smollm

The ``ga_fitness`` cell benchmarks the analytical-evaluator backends
instead (numpy reference vs jax jit+vmap, DESIGN.md §8) — the hot loop
of the paper's GA search:
    PYTHONPATH=src python -m benchmarks.perf_iterations --cell ga_fitness

The ``ga_evolve`` cell benchmarks end-to-end ``run_ga`` wall-clock
(evolution loop included, not just fitness) across the python and
device-resident vectorized engines, plus island-batched ``solve_grid``
vs serial ``run_grid`` on the fig9_10-style GA sweep (DESIGN.md §10):
    PYTHONPATH=src python -m benchmarks.perf_iterations --cell ga_evolve

The ``netsim`` cell benchmarks the flow-level congestion simulator
backends on the Fig. 3 grid (event-driven python loop vs vectorized
numpy vs one batched jitted call, DESIGN.md §11):
    PYTHONPATH=src python -m benchmarks.perf_iterations --cell netsim

The ``miqp_solve`` cell benchmarks the MIQP solver engines on the
fig9_10 MIQP grid (serial per-point HiGHS ``run_grid`` vs batched
lattice ``solve_grid``, DESIGN.md §12) with exact-parity checks —
lattice optimum ≤ the HiGHS incumbent on every point, including the
fig13 ablation points:
    PYTHONPATH=src python -m benchmarks.perf_iterations --cell miqp_solve

The ``pipeline_schedule`` cell benchmarks the RCPSP pipelining engines
on the fig11-style (workload × batch × segment-variant) grid (serial
per-point python heapq ``run_grid`` vs batched vectorized-SGS
``pipeline_sweep``, DESIGN.md §13) with an exact-parity gate — the
engines must agree to float64 round-off on every point, nonzero exit
otherwise:
    PYTHONPATH=src python -m benchmarks.perf_iterations \\
        --cell pipeline_schedule

The ``opt_serve`` cell benchmarks the optimization server (DESIGN.md
§14) under mixed closed-loop traffic (evals across both congestion
models × pipelining × GA solves): serial per-request solo sweep calls —
what a naive one-call-per-request server would do — vs the coalescing
``OptServer``, with a bitwise parity gate (served results must equal
the solo results exactly, nonzero exit otherwise):
    PYTHONPATH=src python -m benchmarks.perf_iterations --cell opt_serve

The ``sweep_shard`` cell benchmarks the sharded sweep fabric (DESIGN.md
§15) on forced virtual host devices: single-device sweeps vs
``devices="sharded"`` shard_map execution over a flow-congestion eval
grid and an island-GA solve grid, with a bitwise parity gate — sharded
results must equal single-device results exactly, nonzero exit
otherwise. The ``--devices N`` flag (valid for any cell) carves the
host into N virtual XLA devices before jax initializes; sweep_shard
defaults to 8:
    PYTHONPATH=src python -m benchmarks.perf_iterations \\
        --cell sweep_shard --devices 8

The ``cosearch`` cell benchmarks the fused cross-layer co-search
(DESIGN.md §16) on the fig13 grid: the sequential per-pass flow (GA
partition search per link variant → pick the better mesh → pipeline the
winner's segments) vs ONE batched Pareto-front ``cosearch_sweep``, with
a per-point dominance gate (co-search best-EDP ≤ the sequential flow's
EDP), a solo==batched bitwise parity gate, and a gradient-seeding gate
(seeded search reaches the cold-start best in ≤ half the generations,
counted deterministically — never wall-clock):
    PYTHONPATH=src python -m benchmarks.perf_iterations --cell cosearch

The ``hetero`` cell gates the heterogeneous-hardware migration
(DESIGN.md §18): a one-class ``ChipletClass`` broadcast must be BITWISE
identical to the legacy scalar config across every engine family
(evaluator regime+flow × numpy+jax, GA, MIQP lattice, pipelining,
co-search — nonzero exit on any bit mismatch), genuinely hetero
configs must batch through the same compiled eval call as homogeneous
ones (≥2× batched vs per-point solo, warm), and the multi-tenant band
search must never lose to the even-split placement (nonzero exit —
even split is always a candidate):
    PYTHONPATH=src python -m benchmarks.perf_iterations --cell hetero
"""
import argparse
import json
import os
import sys
import time

# --devices must be applied BEFORE the first jax import: XLA reads the
# host-device-count flag once at backend init. The sweep_shard cell
# defaults to 8 virtual devices so the fabric has something to shard
# over; every other cell keeps the real topology unless asked.
from .common import apply_devices_flag

apply_devices_flag(
    default=8 if any("sweep_shard" in a for a in sys.argv) else None)

from jax.sharding import PartitionSpec as P

from repro.roofline.analysis import (HBM_BW, LINK_BW, PEAK_FLOPS,
                                     analytic_hbm_bytes, model_flops_for)

# NOTE: the roofline hillclimb cells need 512 virtual host devices; the
# mesh-cell path below calls dryrun.ensure_virtual_devices() explicitly
# before building the production mesh (importing the module itself is
# side-effect-free). The ga_* cells must run WITHOUT it — carving one CPU
# into 512 XLA devices starves the intra-op thread pool and distorts
# evaluator/GA timings several-fold.

ART = os.path.join(os.path.dirname(__file__), "artifacts")
LOG = os.path.join(ART, "perf_log.json")


def measure(arch, shape, mesh, **knobs):
    """Compile + calibrate one variant; return terms + memory."""
    from repro.launch.dryrun import calibrate_cell, lower_cell

    lowered, _ = lower_cell(arch, shape, mesh, **knobs)
    compiled = lowered.compile()
    mem = compiled.memory_analysis()
    cal = calibrate_cell(arch, shape, mesh, **knobs)
    coll = sum(cal["collective_bytes_per_device"].values())
    mf = model_flops_for(arch, shape)
    terms = {
        "compute_s": cal["flops_per_device"] / PEAK_FLOPS,
        "memory_s": analytic_hbm_bytes(arch, shape) / HBM_BW,
        "collective_s": coll / LINK_BW,
        "temp_gib": mem.temp_size_in_bytes / 2**30,
        "args_gib": mem.argument_size_in_bytes / 2**30,
    }
    bound = max(terms["compute_s"], terms["memory_s"],
                terms["collective_s"])
    terms["bound_s"] = bound
    terms["roofline_frac"] = mf / bound / (mesh.size * PEAK_FLOPS)
    terms["dominant"] = max(
        ("compute_s", "memory_s", "collective_s"),
        key=lambda k: terms[k]).split("_")[0]
    return terms


def log_iteration(cell, name, hypothesis, before, after, verdict):
    entries = []
    if os.path.exists(LOG):
        entries = json.load(open(LOG))
    entries.append({"cell": cell, "name": name, "hypothesis": hypothesis,
                    "before": before, "after": after, "verdict": verdict})
    json.dump(entries, open(LOG, "w"), indent=1)
    d = before["dominant"] + "_s"
    print(f"[perf] {cell} :: {name}")
    print(f"       hypothesis: {hypothesis}")
    print(f"       dominant({before['dominant']}): "
          f"{before[d]*1e3:.1f} -> {after[d]*1e3:.1f} ms | "
          f"bound {before['bound_s']*1e3:.1f} -> "
          f"{after['bound_s']*1e3:.1f} ms | roofline "
          f"{before['roofline_frac']*100:.2f}% -> "
          f"{after['roofline_frac']*100:.2f}% | {verdict}")


def fmt(t):
    return (f"comp={t['compute_s']*1e3:.1f}ms mem={t['memory_s']*1e3:.1f}ms "
            f"coll={t['collective_s']*1e3:.1f}ms temp={t['temp_gib']:.1f}GiB "
            f"roofline={t['roofline_frac']*100:.2f}%")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--cell", required=True,
                    help="smollm | internlm2 | deepseek (the three chosen "
                         "hillclimb cells) | ga_fitness (analytical-"
                         "evaluator backend shootout, DESIGN.md §8) | "
                         "ga_evolve (end-to-end GA engine shootout, "
                         "DESIGN.md §10) | netsim (flow-simulator "
                         "backend shootout, DESIGN.md §11) | miqp_solve "
                         "(MIQP engine shootout + exact-parity checks, "
                         "DESIGN.md §12) | pipeline_schedule (RCPSP "
                         "pipelining engine shootout + exact-parity "
                         "gate, DESIGN.md §13) | opt_serve (optimization "
                         "server: serial per-request solves vs the "
                         "coalescing OptServer + bitwise parity gate, "
                         "DESIGN.md §14) | sweep_shard (sharded sweep "
                         "fabric: single-device vs shard_map sweeps + "
                         "bitwise parity gate, DESIGN.md §15) | cosearch "
                         "(fused cross-layer co-search vs the sequential "
                         "GA→link→pipeline pass flow + dominance/parity/"
                         "seeding gates, DESIGN.md §16) | planner_validate "
                         "(measured-vs-predicted gate: calibrated "
                         "analytical evaluator vs dryrun cost analysis "
                         "over the model zoo, DESIGN.md §17) | hetero "
                         "(heterogeneous-hardware migration gate: "
                         "scalar==broadcast bitwise across all engine "
                         "families + hetero batching + multi-tenant vs "
                         "even split, DESIGN.md §18)")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny populations/generations — the no-regression "
                         "smoke profile used by `make bench-smoke`")
    ap.add_argument("--devices", type=int, default=None,
                    help="carve the host into N virtual XLA devices "
                         "(applied before jax init; sweep_shard "
                         "defaults to 8)")
    args = ap.parse_args()
    if args.cell == "ga_fitness":
        run_ga_fitness()     # no device mesh needed
        return
    if args.cell == "ga_evolve":
        run_ga_evolve(smoke=args.smoke)
        return
    if args.cell == "netsim":
        run_netsim(smoke=args.smoke)
        return
    if args.cell == "miqp_solve":
        run_miqp_solve(smoke=args.smoke)
        return
    if args.cell == "pipeline_schedule":
        run_pipeline_schedule(smoke=args.smoke)
        return
    if args.cell == "opt_serve":
        run_opt_serve(smoke=args.smoke)
        return
    if args.cell == "sweep_shard":
        run_sweep_shard(smoke=args.smoke)
        return
    if args.cell == "cosearch":
        run_cosearch(smoke=args.smoke)
        return
    if args.cell == "planner_validate":
        run_planner_validate(smoke=args.smoke)
        return
    if args.cell == "hetero":
        run_hetero(smoke=args.smoke)
        return
    # The hillclimb cells run on the 512-device production meshes; set
    # the topology explicitly (must precede first backend use).
    from repro.launch.dryrun import ensure_virtual_devices
    ensure_virtual_devices()
    from repro.launch.mesh import make_production_mesh

    mesh = make_production_mesh()
    dp = ("data",)
    del dp

    if args.cell == "smollm":
        run_smollm(mesh)
    elif args.cell == "internlm2":
        run_internlm2(mesh)
    elif args.cell == "internlm2_sp":
        run_internlm2_sp(mesh)
    elif args.cell == "internlm2_nozr":
        run_internlm2_nozr(mesh)
    elif args.cell == "deepseek":
        run_deepseek(mesh)
    elif args.cell == "gemma2_decode":
        run_gemma2_decode(mesh)
    elif args.cell == "minicpm3":
        run_minicpm3(mesh)
    else:
        raise SystemExit("unknown cell")


def run_ga_fitness():
    """Backend shootout for the GA fitness hot loop (DESIGN.md §8).

    Measures steady-state ``Evaluator.evaluate_batch`` throughput (numpy
    vs jax, post-warmup) at GA population scales, plus a fixed-seed
    ``run_ga`` on both backends to confirm identical trajectories. The
    acceptance bar is ≥2× on the jax path at search-scale populations
    (P ≥ 1024); small populations stay dispatch-bound and numpy remains
    the right default there.
    """
    import numpy as np

    from repro.core import EvalOptions, Evaluator, make_hw, \
        uniform_partition
    from repro.core.ga import GAConfig, run_ga
    from repro.graphs import WORKLOADS

    task = WORKLOADS["alexnet"](batch=1)
    hw = make_hw("A", 4, "hbm", diagonal_links=True)
    opts = EvalOptions(redistribution=True, async_exec=True)
    n = len(task)
    rng = np.random.default_rng(0)
    rows = []
    for P in (256, 1024, 4096):
        base = uniform_partition(task, 4, 4)
        Px = np.repeat(base.Px[None], P, 0).astype(float)
        Py = np.repeat(base.Py[None], P, 0).astype(float)
        co = rng.integers(0, 4, (P, n))
        rd = (rng.random((P, n)) < 0.5).astype(float)
        ms = {}
        for backend in ("numpy", "jax"):
            ev = Evaluator(task, hw, opts, backend=backend)
            ev.evaluate_batch(Px, Py, co, rd)          # warm / compile
            t0 = time.perf_counter()
            k = 0
            while time.perf_counter() - t0 < 1.0:
                ev.evaluate_batch(Px, Py, co, rd)
                k += 1
            ms[backend] = (time.perf_counter() - t0) / k * 1e3
        sp = ms["numpy"] / ms["jax"]
        rows.append({"population": P, "numpy_ms": ms["numpy"],
                     "jax_ms": ms["jax"], "speedup": sp})
        print(f"[perf] ga_fitness P={P}: numpy={ms['numpy']:.2f}ms "
              f"jax={ms['jax']:.2f}ms speedup={sp:.2f}x")

    cfg = GAConfig(generations=15, population=64, seed=7)
    rn = run_ga(task, hw, "latency", opts, cfg, backend="numpy")
    rj = run_ga(task, hw, "latency", opts, cfg, backend="jax")
    same = bool(np.allclose(rn.history, rj.history, rtol=1e-9)
                and np.array_equal(rn.partition.Px, rj.partition.Px))
    best = max(r["speedup"] for r in rows)
    verdict = ("confirmed (>=2x at search scale)" if best >= 2.0
               else "refuted (<2x)")
    print(f"[perf] ga_fitness trajectories identical: {same}; "
          f"best speedup {best:.2f}x -> {verdict}")
    out = {"rows": rows, "trajectories_identical": same,
           "best_speedup": best, "verdict": verdict}
    os.makedirs(ART, exist_ok=True)
    with open(os.path.join(ART, "ga_fitness.json"), "w") as f:
        json.dump(out, f, indent=1)


def run_ga_evolve(smoke: bool = False):
    """End-to-end GA engine shootout (DESIGN.md §10).

    Measures whole ``run_ga`` wall-clock — evolution loop included, not
    just fitness — for the python reference engine vs the device-resident
    vectorized engine, at search-scale populations; then island-batched
    ``sweep.solve_grid`` vs a serial ``run_grid`` of the same searches on
    the fig9_10-style GA sweep. Acceptance bars: ≥5× end-to-end at
    population ≥256 / 200 generations, ≥2× for island batching. Warm-up
    runs exclude one-time jit compilation from the timed numbers (the
    compiled step is process-cached and amortizes across every sweep
    point of the same shape). ``smoke=True`` shrinks everything to a
    seconds-long no-regression check (`make bench-smoke`), skips the
    verdict thresholds, and writes ``ga_evolve_smoke.json`` so it never
    clobbers the measured acceptance artifact.
    """
    from repro.core import EvalOptions, make_hw, sweep
    from repro.core.ga import GAConfig, run_ga
    from repro.graphs import WORKLOADS

    hw = make_hw("A", 4, "hbm", diagonal_links=True)
    opts = EvalOptions(redistribution=True, async_exec=True)
    if smoke:
        pops, gens, patience = (16,), 4, 4
        sweep_wnames = ("alexnet",)
    else:
        pops, gens, patience = (64, 256), 200, 200
        sweep_wnames = ("alexnet", "hydranet")   # fig9_10 --fast profile
    task = WORKLOADS["alexnet"](batch=1)

    rows = []
    for pop in pops:
        cfg = GAConfig(generations=gens, population=pop,
                       patience=patience, seed=0)
        secs, objs = {}, {}
        for name, kw in (("python", dict(engine="python",
                                         backend="numpy")),
                         ("vectorized", dict(engine="vectorized",
                                             backend="jax"))):
            if name == "vectorized":    # warm the compile cache
                run_ga(task, hw, "latency", opts, cfg, **kw)
            t0 = time.perf_counter()
            r = run_ga(task, hw, "latency", opts, cfg, **kw)
            secs[name] = time.perf_counter() - t0
            objs[name] = r.objective
        sp = secs["python"] / secs["vectorized"]
        rows.append({"population": pop, "generations": gens,
                     "python_s": secs["python"],
                     "vectorized_s": secs["vectorized"], "speedup": sp,
                     "python_obj": objs["python"],
                     "vectorized_obj": objs["vectorized"]})
        print(f"[perf] ga_evolve P={pop} G={gens}: "
              f"python={secs['python']:.2f}s "
              f"vectorized={secs['vectorized']:.2f}s speedup={sp:.2f}x")

    # Island batching vs the PR-1 sweep path: the fig9_10 GA sweep
    # (grid × workload, fig9_10's GA_CFG) driven by device-resident
    # solve_grid vs the serial run_grid of per-point python-engine
    # searches that fig9_10 used before (DESIGN.md §10). Timed warm —
    # the compiled steps are process-cached and reused across the
    # latency/EDP objectives and by fig13's shared shapes.
    cfg = GAConfig(generations=gens if smoke else 60, population=64,
                   patience=patience if smoke else 60, seed=0)
    grid_gs = (4,) if smoke else (4, 8)
    pts = [sweep.EvalPoint(
               WORKLOADS[w](batch=1),
               make_hw("A", g, "hbm", diagonal_links=True), opts)
           for g in grid_gs for w in sweep_wnames]
    sweep.solve_grid(pts, "latency", cfg, cache=False)   # warm compiles
    t0 = time.perf_counter()
    sweep.solve_grid(pts, "latency", cfg, cache=False)
    batched_s = time.perf_counter() - t0
    t0 = time.perf_counter()
    sweep.run_grid(
        [{"pt": pt} for pt in pts],
        lambda pt: run_ga(pt.task, pt.hw, "latency", pt.options, cfg,
                          engine="python", backend="numpy"))
    serial_s = time.perf_counter() - t0
    grid_sp = serial_s / batched_s
    print(f"[perf] ga_evolve solve_grid ({len(pts)} pts): "
          f"serial-python={serial_s:.2f}s batched={batched_s:.2f}s "
          f"speedup={grid_sp:.2f}x")

    out = {"rows": rows, "solve_grid": {
        "points": len(pts), "serial_s": serial_s,
        "batched_s": batched_s, "speedup": grid_sp}}
    if not smoke:
        big = max(r["speedup"] for r in rows if r["population"] >= 256)
        ok = big >= 5.0 and grid_sp >= 2.0
        out["verdict"] = ("confirmed (>=5x end-to-end, >=2x islands)"
                          if ok else "refuted")
        print(f"[perf] ga_evolve best end-to-end {big:.2f}x, islands "
              f"{grid_sp:.2f}x -> {out['verdict']}")
    os.makedirs(ART, exist_ok=True)
    name = "ga_evolve_smoke.json" if smoke else "ga_evolve.json"
    with open(os.path.join(ART, name), "w") as f:
        json.dump(out, f, indent=1)


def run_netsim(smoke: bool = False):
    """Flow-simulator backend shootout on the Fig. 3 grid (DESIGN.md §11).

    Times the full (memory × placement × NoP-BW) Fig. 3 congestion study
    three ways: the event-driven python reference (serial, per cell),
    the vectorized numpy waterfilling engine (serial, per cell), and the
    batched jitted engine (ONE ``netsim_jax.simulate_pull_batch`` call
    for the whole grid — every cell shares the 4×4 link space, so
    capacities/attachments are data, not structure). Timed warm: the
    compiled call is process-cached and amortizes across every grid of
    the same shape. Acceptance bar: ≥5× event-driven → batched-jax on
    the full grid. ``smoke=True`` shrinks the bandwidth axis to a
    seconds-long no-regression check (`make bench-smoke`), skips the
    verdict, and writes ``netsim_smoke.json``.
    """
    import numpy as np

    from repro.core import netsim, netsim_jax

    GB = 1e9
    bws = (60, 120) if smoke else (15, 30, 60, 90, 120, 180, 240, 480)
    cells = [(m, p, bw * GB)
             for m in ("dram", "hbm") for p in ("peripheral", "central")
             for bw in bws]
    nets = [netsim.fig3_net(m, p, bw) for m, p, bw in cells]
    msg = 1 * GB

    t0 = time.perf_counter()
    lat_event = [netsim.simulate_pull(n, msg, engine="event")["latency"]
                 for n in nets]
    event_s = time.perf_counter() - t0

    t0 = time.perf_counter()
    lat_vec = [netsim.simulate_pull(n, msg, engine="vectorized")["latency"]
               for n in nets]
    vec_s = time.perf_counter() - t0

    caps = np.stack([n.link_caps() for n in nets])
    incs = np.stack([n.pull_incidence() for n in nets])
    msgs = np.full((len(nets), nets[0].graph.n_nodes), float(msg))
    netsim_jax.simulate_pull_batch(caps, incs, msgs)     # warm / compile
    t0 = time.perf_counter()
    out = netsim_jax.simulate_pull_batch(caps, incs, msgs)
    jax_s = time.perf_counter() - t0

    # Three-way parity against the event reference — a drifting engine
    # must not report a clean verdict.
    err = max(abs(a - b) / a for a, b in zip(lat_event, out["latency"]))
    err_vec = max(abs(a - b) / a for a, b in zip(lat_event, lat_vec))
    sp_jax = event_s / jax_s
    sp_vec = event_s / vec_s
    print(f"[perf] netsim grid={len(cells)} cells: "
          f"event={event_s*1e3:.1f}ms vectorized={vec_s*1e3:.1f}ms "
          f"batched-jax={jax_s*1e3:.1f}ms | speedup vec={sp_vec:.2f}x "
          f"jax={sp_jax:.2f}x | max rel err "
          f"{max(err, err_vec):.1e}")
    res = {"cells": len(cells), "event_s": event_s, "vectorized_s": vec_s,
           "batched_jax_s": jax_s, "speedup_vectorized": sp_vec,
           "speedup_batched_jax": sp_jax, "max_rel_err": err,
           "max_rel_err_vectorized": err_vec}
    if not smoke:
        res["verdict"] = ("confirmed (>=5x batched)" if sp_jax >= 5.0
                          else "refuted (<5x)")
        print(f"[perf] netsim batched speedup {sp_jax:.2f}x -> "
              f"{res['verdict']}")
    os.makedirs(ART, exist_ok=True)
    name = "netsim_smoke.json" if smoke else "netsim.json"
    with open(os.path.join(ART, name), "w") as f:
        json.dump(res, f, indent=1)


def run_miqp_solve(smoke: bool = False):
    """MIQP engine shootout (DESIGN.md §12).

    Times the fig9_10 MIQP grid two ways — the serial per-point HiGHS
    ``run_grid`` path this repo used before (``engine="milp"``, the
    fig9_10 budget of 60 s / 3 ε-points) and batched lattice solves
    through ``sweep.solve_grid(method="miqp")`` (one call per objective,
    timed warm: the compiled scoring chunks are process-cached and
    amortize across every same-shape sweep) — and runs the exact-parity
    audit: the lattice objective must be ≤ the HiGHS incumbent on every
    grid point *and* on every fig13 ablation point (both engines score
    their solutions with the exact evaluator under identical solve
    options, so the comparison is apples-to-apples; where HiGHS proves
    model optimality the gap additionally shows how much the exact
    evaluator recovers over the padded MILP model). Acceptance bars:
    ≥5× end-to-end on the grid, parity everywhere. ``smoke=True``
    shrinks everything to a seconds-long no-regression check
    (`make bench-smoke`), skips the verdict, and writes
    ``miqp_solve_smoke.json``."""
    from repro.core import EvalOptions, make_hw, sweep
    from repro.core.miqp import MIQPConfig, run_miqp
    from repro.core.workload import GemmOp, Task
    from repro.graphs import WORKLOADS

    opts = EvalOptions(redistribution=True, async_exec=False)
    lat_cfg = MIQPConfig(engine="lattice")
    if smoke:
        task = Task("two", [GemmOp("a", M=512, K=256, N=512),
                            GemmOp("b", M=512, K=512, N=512,
                                   chained=True)])
        cells = [("two", task, 4, o) for o in ("latency", "edp")]
        milp_cfg = MIQPConfig(time_limit=10, edp_sweep=2, engine="milp")
        fig13_cells = []
    else:
        wnames = ("alexnet", "hydranet")      # fig9_10 --fast profile
        cells = [(w, WORKLOADS[w](batch=1), g, o)
                 for o in ("latency", "edp") for g in (4, 8)
                 for w in wnames]
        milp_cfg = MIQPConfig(time_limit=60, edp_sweep=3, engine="milp")
        fig13_cells = [(w, WORKLOADS[w](batch=1), diag)
                       for w in ("alexnet", "vit", "hydranet")
                       for diag in (False, True)]

    def hw_for(g, diag=True):
        return make_hw("A", g, "hbm", diagonal_links=diag)

    # -- serial HiGHS leg (the pre-§12 path)
    t0 = time.perf_counter()
    milp_res = {}
    for w, task, g, o in cells:
        t1 = time.perf_counter()
        r = run_miqp(task, hw_for(g), o, opts, milp_cfg)
        us = (time.perf_counter() - t1) * 1e6
        milp_res[(w, g, o)] = r
        print(f"[perf] miqp_solve milp {w}/{g}x{g}/{o}: "
              f"obj={r.objective:.4e} {us/1e6:.1f}s", flush=True)
    serial_s = time.perf_counter() - t0

    # -- batched lattice leg (timed warm, one solve_grid per objective)
    def lattice_pass(cache):
        out = {}
        for o in ("latency", "edp"):
            sub = [(w, task, g) for w, task, g, oo in cells if oo == o]
            if not sub:
                continue
            pts = [sweep.EvalPoint(task, hw_for(g), opts)
                   for _, task, g in sub]
            recs = sweep.solve_grid(pts, o, lat_cfg, method="miqp",
                                    cache=cache)
            for (w, _, g), r in zip(sub, recs):
                out[(w, g, o)] = r
        return out

    lattice_pass(cache=False)                 # warm the compile caches
    t0 = time.perf_counter()
    lat_res = lattice_pass(cache=False)
    batched_s = time.perf_counter() - t0
    speedup = serial_s / batched_s

    rows, parity_ok = [], True
    for key, m in milp_res.items():
        r = lat_res[key]
        leq = r.objective <= m.objective * (1 + 1e-9)
        parity_ok &= leq
        rows.append({"workload": key[0], "grid": key[1],
                     "objective": key[2], "milp_obj": m.objective,
                     "lattice_obj": r.objective, "lattice_leq": leq,
                     "milp_proved_optimal": "Optimal" in m.milp_status})

    # -- fig13 ablation-point parity audit (latency, 4x4, both variants)
    fig13_rows = []
    for w, task, diag in fig13_cells:
        hw = hw_for(4, diag)
        m = run_miqp(task, hw, "latency", opts,
                     MIQPConfig(time_limit=30, engine="milp"))
        r = run_miqp(task, hw, "latency", opts, lat_cfg)
        leq = r.objective <= m.objective * (1 + 1e-9)
        parity_ok &= leq
        fig13_rows.append({"workload": w, "diagonal": diag,
                           "milp_obj": m.objective,
                           "lattice_obj": r.objective,
                           "lattice_leq": leq})
        print(f"[perf] miqp_solve fig13 {w}/diag={diag}: "
              f"milp={m.objective:.4e} lattice={r.objective:.4e} "
              f"leq={leq}", flush=True)

    print(f"[perf] miqp_solve grid={len(cells)} points: "
          f"serial-milp={serial_s:.1f}s batched-lattice={batched_s:.1f}s "
          f"speedup={speedup:.2f}x parity={'OK' if parity_ok else 'FAIL'}")
    out = {"points": len(cells), "serial_milp_s": serial_s,
           "batched_lattice_s": batched_s, "speedup": speedup,
           "parity_ok": parity_ok, "rows": rows,
           "fig13_parity": fig13_rows}
    if not smoke:
        ok = speedup >= 5.0 and parity_ok
        out["verdict"] = ("confirmed (>=5x batched, lattice <= milp "
                          "everywhere)" if ok else "refuted")
        print(f"[perf] miqp_solve -> {out['verdict']}")
    os.makedirs(ART, exist_ok=True)
    name = "miqp_solve_smoke.json" if smoke else "miqp_solve.json"
    with open(os.path.join(ART, name), "w") as f:
        json.dump(out, f, indent=1)
    if not parity_ok:
        # Parity is a correctness property, not a perf number: a lattice
        # result worse than the HiGHS incumbent must fail the smoke/CI
        # gate loudly (the artifact above still records the rows).
        raise SystemExit("miqp_solve: lattice worse than the HiGHS "
                         "incumbent on at least one point")


def run_pipeline_schedule(smoke: bool = False):
    """RCPSP pipelining engine shootout (DESIGN.md §13).

    Times a fig11-style (workload × batch × segment-variant) pipelining
    grid two ways — the serial per-point python heapq SGS this repo used
    before (``engine="python"`` through ``run_grid``) and the batched
    vectorized SGS through ``sweep.pipeline_sweep`` (one compiled call
    per (n_ops, batch) shape group; timed warm — the compiled step is
    process-cached and amortizes across every same-shape sweep). Segment
    variants come from the Table-3 scheduling methods under both
    congestion models (``ScheduleResult.segments(congestion=...)``,
    DESIGN.md §11), so every group carries several duration sets through
    one executable — exactly the figure-grid batching pattern.

    Parity is a correctness gate, not a perf number: the engines are
    bit-identical by construction (§13), so ANY makespan divergence
    beyond float64 round-off exits nonzero (the artifact still records
    the rows). A solo-vs-batched spot check enforces the §9 cache
    invariant on the same run. Acceptance bar: ≥5× end-to-end on the
    grid. ``smoke=True`` shrinks everything to a seconds-long
    no-regression check (`make bench-smoke`), skips the verdict, and
    writes ``pipeline_schedule_smoke.json``."""
    from repro.core import make_hw, optimize, sweep
    from repro.core.pipelining import PipelineConfig, pipeline_batch
    from repro.core.sweep import PipelinePoint
    from repro.graphs import WORKLOADS

    hw = make_hw("A", 4, "hbm")
    if smoke:
        wnames, batches = ("alexnet",), (4, 8)
        methods, congs = ("baseline", "simba"), ("regime",)
    else:
        wnames, batches = ("alexnet", "vit", "hydranet"), (4, 16, 64)
        methods = ("baseline", "simba", "miqp")
        congs = ("regime", "flow")

    segs = {}
    for w in wnames:
        for m in methods:
            res = optimize(WORKLOADS[w](batch=1), hw, m)
            for c in congs:
                segs[(w, m, c)] = res.segments(
                    None if c == "regime" else c)
    pts = [PipelinePoint(segs[k], b) for k in segs for b in batches]
    keys = [(k, b) for k in segs for b in batches]

    # -- serial python-heapq leg (the pre-§13 path)
    py_cfg = PipelineConfig(engine="python")
    t0 = time.perf_counter()
    serial = sweep.run_grid(
        [{"pt": pt} for pt in pts],
        lambda pt: pipeline_batch(pt.segments, pt.batch, config=py_cfg))
    serial_s = time.perf_counter() - t0

    # -- batched vectorized leg (timed warm, cache off so work is real)
    vec_cfg = PipelineConfig(engine="vectorized", backend="jax")
    sweep.pipeline_sweep(pts, vec_cfg, cache=False)   # warm the compiles
    t0 = time.perf_counter()
    batched = sweep.pipeline_sweep(pts, vec_cfg, cache=False)
    batched_s = time.perf_counter() - t0
    speedup = serial_s / batched_s

    # -- exact-parity audit: python == vectorized on every point, and
    #    solo == batched on a spot-check subset (§9 cache invariant).
    rows, max_err = [], 0.0
    for ((w, m, c), b), (_, sr, _), br in zip(keys, serial, batched):
        err = (abs(sr.pipelined - br.pipelined)
               / max(sr.pipelined, 1e-300))
        max_err = max(max_err, err)
        rows.append({"workload": w, "method": m, "congestion": c,
                     "batch": b, "python_makespan": sr.pipelined,
                     "vectorized_makespan": br.pipelined, "rel_err": err})
    solo_ok = True
    for pt, br in list(zip(pts, batched))[::7]:
        solo = pipeline_batch(pt.segments, pt.batch, config=vec_cfg)
        solo_ok &= solo.pipelined == br.pipelined
    parity_ok = max_err <= 1e-12 and solo_ok

    print(f"[perf] pipeline_schedule grid={len(pts)} points: "
          f"serial-python={serial_s:.2f}s batched={batched_s:.2f}s "
          f"speedup={speedup:.2f}x | max rel err {max_err:.1e} "
          f"solo==batched={solo_ok} "
          f"parity={'OK' if parity_ok else 'FAIL'}")
    out = {"points": len(pts), "serial_python_s": serial_s,
           "batched_s": batched_s, "speedup": speedup,
           "max_rel_err": max_err, "solo_eq_batched": solo_ok,
           "parity_ok": parity_ok, "rows": rows}
    if not smoke:
        ok = speedup >= 5.0 and parity_ok
        out["verdict"] = ("confirmed (>=5x batched, exact parity)"
                          if ok else "refuted")
        print(f"[perf] pipeline_schedule -> {out['verdict']}")
    os.makedirs(ART, exist_ok=True)
    name = ("pipeline_schedule_smoke.json" if smoke
            else "pipeline_schedule.json")
    with open(os.path.join(ART, name), "w") as f:
        json.dump(out, f, indent=1)
    if not parity_ok:
        # A vectorized schedule that diverges from the serial SGS (or a
        # batched record that differs from its solo equivalent) is a
        # correctness bug — fail the smoke/CI gate loudly.
        raise SystemExit("pipeline_schedule: engine parity violated")


def run_opt_serve(smoke: bool = False):
    """Optimization-server shootout (DESIGN.md §14).

    Replays one mixed closed-loop request trace two ways — serial
    per-request solo sweep calls (what a naive one-call-per-request
    server would do: ``eval_sweep([pt])`` / ``solve_grid([pt])`` /
    ``pipeline_sweep([pt])`` per request) and the coalescing
    :class:`~repro.serve.optserver.OptServer` (submit everything, let
    the worker coalesce by CallKey into batched shape-grouped sweep
    calls). Both legs run with ``cache=False`` so every request is real
    work, and both are timed warm — the compiled executables are
    process-cached and shared between the legs, so the measured gap is
    pure dispatch/coalescing, not compilation.

    Parity is a correctness gate, not a perf number: the served result
    must be BITWISE identical to the solo result on every request (the
    solo==served contract, §14) — any divergence exits nonzero (the
    artifact still records the rows). Acceptance bar: ≥3× throughput on
    the mixed trace. ``smoke=True`` shrinks the trace to a seconds-long
    no-regression check (`make bench-smoke`), skips the verdict, and
    writes ``opt_serve_smoke.json``."""
    import numpy as np

    from repro.core import EvalOptions, make_hw, sweep
    from repro.core.ga import GAConfig
    from repro.core.pipelining import PipelineConfig
    from repro.core.workload import uniform_partition
    from repro.graphs import WORKLOADS
    from repro.serve import OptRequest, OptServer

    rng = np.random.default_rng(0)
    if smoke:
        n_eval, n_pipe, n_solve = 20, 4, 0
        wnames, grids = ("alexnet",), (4,)
    else:
        n_eval, n_pipe, n_solve = 384, 48, 8
        wnames, grids = ("alexnet", "vit"), (4, 8)
    tasks = [WORKLOADS[w](batch=1) for w in wnames]
    hws = [make_hw("A", g, "hbm") for g in grids]
    ga_cfg = GAConfig(generations=6, population=32, patience=6, seed=0)
    pipe_cfg = PipelineConfig(engine="vectorized", backend="jax")

    # -- the request trace: evals over workload × grid × congestion ×
    #    redistribution, RCPSP pipelining instances, GA solves.
    reqs = []
    for i in range(n_eval):
        task, hw = tasks[i % len(tasks)], hws[i % len(hws)]
        # flow-congestion evals stay a minority share: the flow netsim
        # is near-linear work batched or solo (see the netsim cell), so
        # it measures the engine, not the serving layer
        opts = EvalOptions(
            redistribution=bool(i % 2), async_exec=True,
            congestion="flow" if i % 32 == 31 else "regime")
        part = uniform_partition(task, hw.X, hw.Y)
        part.collectors[:] = rng.integers(0, hw.Y, len(task))
        reqs.append(OptRequest("eval",
                               sweep.EvalPoint(task, hw, opts, part)))
    for i in range(n_pipe):
        segs = [(f"op{j}", float(rng.uniform(0.1, 1.0)),
                 float(rng.uniform(0.5, 2.0)),
                 float(rng.uniform(0.1, 1.0))) for j in range(6)]
        reqs.append(OptRequest("pipeline", sweep.PipelinePoint(segs, 4),
                               cfg=pipe_cfg))
    for i in range(n_solve):
        # same task shape on purpose: the 8 searches coalesce into ONE
        # island-batched vectorized GA run (DESIGN.md §10)
        reqs.append(OptRequest(
            "solve", sweep.EvalPoint(tasks[0], hws[i % len(hws)],
                                     EvalOptions(redistribution=True,
                                                 async_exec=True)),
            method="ga", cfg=ga_cfg))

    def solo_leg():
        """The naive server: one sweep call per request, in order."""
        out = []
        for r in reqs:
            if r.kind == "eval":
                out.append(sweep.eval_sweep(
                    [r.point], backend=r.backend, cache=False)[0])
            elif r.kind == "solve":
                out.append(sweep.solve_grid(
                    [r.point], r.objective, r.cfg, backend=r.backend,
                    cache=False, method=r.method)[0])
            else:
                out.append(sweep.pipeline_sweep(
                    [r.point], r.cfg, cache=False)[0])
        return out

    def served_leg():
        srv = OptServer(cache=False, autostart=False,
                        max_queue=len(reqs), max_batch=len(reqs))
        futs = [srv.submit(r) for r in reqs]
        t0 = time.perf_counter()
        srv.start()
        out = [f.result(timeout=600) for f in futs]
        dt = time.perf_counter() - t0
        st = srv.stats()
        srv.kill()
        return out, dt, st

    solo_leg()                                   # warm solo-shape compiles
    served_leg()                                 # warm batched compiles
    t0 = time.perf_counter()
    solo = solo_leg()
    serial_s = time.perf_counter() - t0
    served, served_s, st = served_leg()

    # -- bitwise parity gate (solo == served, §14)
    parity_ok = True
    for r, a, b in zip(reqs, solo, served):
        if r.kind == "eval":
            same = (a["latency"] == b["latency"]
                    and a["energy"] == b["energy"]
                    and np.array_equal(a["t_in"], b["t_in"])
                    and np.array_equal(a["t_out"], b["t_out"]))
        elif r.kind == "solve":
            same = (a.objective == b.objective
                    and np.array_equal(a.partition.Px, b.partition.Px)
                    and np.array_equal(a.partition.Py, b.partition.Py))
        else:
            same = (a.sequential == b.sequential
                    and a.pipelined == b.pipelined)
        parity_ok &= same

    speedup = serial_s / served_s
    print(f"[perf] opt_serve trace={len(reqs)} requests "
          f"(eval={n_eval} pipeline={n_pipe} solve={n_solve}): "
          f"serial={serial_s:.2f}s served={served_s:.2f}s "
          f"speedup={speedup:.2f}x | coalesce "
          f"{st['coalesce_factor']:.1f}x over {st['batches']} calls | "
          f"p99={st['p99_ms']:.0f}ms | "
          f"parity={'OK' if parity_ok else 'FAIL'}")
    out = {"requests": len(reqs), "eval": n_eval, "pipeline": n_pipe,
           "solve": n_solve, "serial_s": serial_s, "served_s": served_s,
           "speedup": speedup, "batches": st["batches"],
           "coalesce_factor": st["coalesce_factor"],
           "p50_ms": st["p50_ms"], "p99_ms": st["p99_ms"],
           "requests_per_s": st["requests_per_s"],
           "parity_ok": parity_ok}
    if not smoke:
        ok = speedup >= 3.0 and parity_ok
        out["verdict"] = ("confirmed (>=3x served, solo==served bitwise)"
                          if ok else "refuted")
        print(f"[perf] opt_serve -> {out['verdict']}")
    os.makedirs(ART, exist_ok=True)
    name = "opt_serve_smoke.json" if smoke else "opt_serve.json"
    with open(os.path.join(ART, name), "w") as f:
        json.dump(out, f, indent=1)
    if not parity_ok:
        # A served result that differs from its solo equivalent breaks
        # the §14 contract — fail the smoke/CI gate loudly.
        raise SystemExit("opt_serve: served result != solo result")


def run_sweep_shard(smoke: bool = False):
    """Sharded sweep fabric shootout (DESIGN.md §15).

    Runs the same two sweep legs once per device mode, ``cache=False``
    so every point is real work, warm-timed (executables are compiled
    before the measured passes, so the gap is execution, not tracing):

    * **eval leg** — a flow-congestion evaluation grid (the costliest
      §8 mode: per-point ``lax.while_loop`` event simulation whose
      iteration count varies with the memory-collector placement).
      Sharding splits the grid axis across devices, and each shard's
      lockstep ``vmap(while_loop)`` runs only as long as its *local*
      slowest point — a real algorithmic win on top of parallelism.
    * **solve leg** — island-batched GA searches over bandwidth-scaled
      hardware variants (one ``jit(vmap(scan))`` call, §10); sharding
      splits the island axis.

    Parity is a correctness gate, not a perf number: every sharded
    record must be BITWISE identical to its single-device record (the
    solo == batched == sharded contract, §15) — any divergence exits
    nonzero (the artifact still records the rows). Acceptance bar:
    ≥2x end-to-end on ≥8 devices — evaluated against *physical* cores
    as well: the artifact records ``physical_cores`` because N virtual
    XLA devices carved from one core time-slice it, so wall-clock gains
    require real cores to back the shards. ``smoke=True`` shrinks both
    grids to a seconds-long no-regression check (`make bench-smoke`),
    skips the verdict, and writes ``sweep_shard_smoke.json``."""
    import numpy as np

    import jax

    from repro.core import EvalOptions, make_hw, sweep
    from repro.core.ga import GAConfig
    from repro.core.workload import uniform_partition
    from repro.graphs import WORKLOADS

    n_dev = jax.device_count()
    cores = os.cpu_count() or 1
    rng = np.random.default_rng(0)
    if smoke:
        n_eval, n_solve = 16, 4
        ga_cfg = GAConfig(generations=3, population=16, patience=3,
                          seed=0)
    else:
        n_eval, n_solve = 128, 16
        ga_cfg = GAConfig(generations=8, population=64, patience=8,
                          seed=0)

    task = WORKLOADS["alexnet"](batch=1)
    hw = make_hw("A", 4, "hbm")
    eval_pts = []
    for i in range(n_eval):
        opts = EvalOptions(congestion="flow", async_exec=True,
                           redistribution=bool(i % 2))
        part = uniform_partition(task, hw.X, hw.Y)
        part.collectors[:] = rng.integers(0, hw.Y, len(task))
        eval_pts.append(sweep.EvalPoint(task, hw, opts, part))
    # same task shape on purpose: the searches batch as islands of ONE
    # compiled GA call whose island axis is what sharding splits
    solve_hws = [make_hw("A", 4, "hbm", bw_nop=32.0 * (1 + 0.25 * i))
                 for i in range(n_solve)]
    solve_pts = [sweep.EvalPoint(task, h,
                                 EvalOptions(redistribution=True,
                                             async_exec=True))
                 for h in solve_hws]

    def legs(devices):
        ev = sweep.eval_sweep(eval_pts, cache=False, devices=devices)
        ga = sweep.solve_grid(solve_pts, "latency", ga_cfg, cache=False,
                              devices=devices)
        return ev, ga

    times = {}
    results = {}
    for mode in ("single", "sharded"):
        legs(mode)                                # warm the executables
        t0 = time.perf_counter()
        results[mode] = legs(mode)
        times[mode] = time.perf_counter() - t0

    # -- bitwise parity gate (single == sharded, §15)
    parity_ok = True
    for a, b in zip(results["single"][0], results["sharded"][0]):
        parity_ok &= (a["latency"] == b["latency"]
                      and a["energy"] == b["energy"]
                      and np.array_equal(a["t_in"], b["t_in"])
                      and np.array_equal(a["t_out"], b["t_out"]))
    for a, b in zip(results["single"][1], results["sharded"][1]):
        parity_ok &= (a.objective == b.objective
                      and np.array_equal(a.partition.Px, b.partition.Px)
                      and np.array_equal(a.partition.Py, b.partition.Py)
                      and np.array_equal(a.history, b.history))

    speedup = times["single"] / times["sharded"]
    print(f"[perf] sweep_shard devices={n_dev} (physical cores={cores}) "
          f"grid: eval={n_eval} flow points, solve={n_solve} GA islands "
          f"| single={times['single']:.2f}s "
          f"sharded={times['sharded']:.2f}s speedup={speedup:.2f}x | "
          f"parity={'OK' if parity_ok else 'FAIL'}")
    out = {"n_devices": n_dev, "physical_cores": cores,
           "eval_points": n_eval, "solve_points": n_solve,
           "single_s": times["single"], "sharded_s": times["sharded"],
           "speedup": speedup, "parity_ok": parity_ok}
    if not smoke:
        # The >=2x wall-clock bar only means something when real cores
        # back the shards: N virtual XLA devices carved from one core
        # time-slice it, so a single-core container can never confirm
        # OR refute the speedup claim — it reports skipped. The bitwise
        # parity gate above still ran (and exits nonzero on violation).
        if parity_ok and cores < 2:
            out["verdict"] = ("skipped (no physical parallelism: "
                              f"{n_dev} virtual devices share "
                              f"{cores} physical core(s); parity OK)")
        elif speedup >= 2.0 and parity_ok:
            out["verdict"] = ("confirmed (>=2x sharded end-to-end, "
                              "single==sharded bitwise)")
        else:
            out["verdict"] = "refuted"
        print(f"[perf] sweep_shard -> {out['verdict']}")
    os.makedirs(ART, exist_ok=True)
    name = "sweep_shard_smoke.json" if smoke else "sweep_shard.json"
    with open(os.path.join(ART, name), "w") as f:
        json.dump(out, f, indent=1)
    if not parity_ok:
        # A sharded result that differs from its single-device result
        # breaks the §15 contract — fail the smoke/CI gate loudly.
        raise SystemExit("sweep_shard: sharded result != single result")


def run_cosearch(smoke: bool = False):
    """Fused cross-layer co-search shootout (DESIGN.md §16).

    Times the fig13 grid two ways — the sequential per-pass flow the
    figure scripts used before, and ONE batched Pareto-front
    ``sweep.cosearch_sweep``. The sequential flow must produce what the
    migrated fig12/fig13 consume from the front — the best-latency AND
    the best-EDP operating points — so per workload it runs, per
    objective (latency, edp): one GA partition search per link variant
    [plain mesh, diagonal mesh], picks the better variant, evaluates
    it, and pipelines its segments at batch 4 (the GA →
    link-ablation → pipeline pass sequence, once per objective). The
    co-search leg is one ``cosearch_sweep`` call: links and
    segmentation are genes, and the Pareto archive returns both
    operating points from a single EDP-guided search. Both legs run
    ``cache=False`` and are timed warm, so the gap is search structure,
    not compilation.

    Three gates ride the timing:

    * **Dominance** — co-search best-EDP must be ≤ the sequential
      flow's EDP-pass result on EVERY grid point (same metric on both
      sides: ``energy × pipelined-latency`` at batch 4). The joint
      search may not trade its speed for schedule quality.
    * **Parity** — a solo ``run_cosearch`` must equal the batched sweep
      record BITWISE (the §9 solo==batched contract); any divergence
      exits nonzero.
    * **Seeding** — projected-gradient seeding must measurably help: the
      seeded search must reach the cold-start search's best fitness in
      ≤ half the generations (deterministic generation counts from the
      returned histories — never wall-clock).

    Acceptance bar: ≥3× end-to-end plus all three gates. ``smoke=True``
    shrinks budgets to a seconds-long no-regression check
    (`make bench-smoke`), skips the speedup/seeding verdicts (keeps both
    correctness gates), and writes ``cosearch_smoke.json``."""
    import dataclasses

    import numpy as np

    from repro.core import EvalOptions, Evaluator, make_hw, sweep
    from repro.core import cosearch as cs
    from repro.core.ga import GAConfig
    from repro.core.sweep import PipelinePoint
    from repro.graphs import WORKLOADS

    B = 4
    if smoke:
        wnames = ("alexnet",)
        pop, gens = 16, 8
        co_cfg = cs.CoSearchConfig(population=pop, generations=gens,
                                   patience=gens, batch=B, seed=0,
                                   seed_steps=8, seed_starts=2)
        ga_cfg = GAConfig(population=pop, generations=gens, patience=gens,
                          seed=0)
    else:
        wnames = ("alexnet", "vit", "hydranet")
        gens = 60
        # seeding converges in a handful of generations (the seeding
        # gate below pins that), so the joint search can afford a tight
        # early-stop patience at a slightly smaller population.
        co_cfg = cs.CoSearchConfig(population=48, generations=gens,
                                   patience=8, batch=B, seed=0,
                                   seed_steps=32, seed_starts=4)
        # the fig13 GA budget (GA_CFG there): population 64, full
        # generations, default early-stop patience
        ga_cfg = GAConfig(population=64, generations=gens, seed=0)
    tasks = {w: WORKLOADS[w](batch=1) for w in wnames}
    hw_plain = make_hw("A", 4, "hbm")
    hw_diag = make_hw("A", 4, "hbm", diagonal_links=True)
    opts = EvalOptions(redistribution=True, async_exec=True)

    def sequential_leg():
        """The pre-§16 flow: per workload, per objective consumed by
        the figures (latency, edp), a GA partition pass per link
        variant → keep the better link config → score → pipeline."""
        out = {}
        for w in wnames:
            out[w] = {}
            for obj in ("latency", "edp"):
                best_r, best_hw = None, None
                for hw in (hw_plain, hw_diag):
                    r = sweep.solve_grid(
                        [sweep.EvalPoint(tasks[w], hw, opts)], obj,
                        ga_cfg, cache=False)[0]
                    if best_r is None or r.objective < best_r.objective:
                        best_r, best_hw = r, hw
                ev = Evaluator(tasks[w], best_hw, opts, backend="jax")
                res = ev.evaluate(best_r.partition, best_r.redist_mask)
                pipe = sweep.pipeline_sweep(
                    [PipelinePoint(res.segments(), B)], cache=False)[0]
                lat = pipe.pipelined / B
                out[w][obj] = {
                    "edp": res.energy * lat, "latency": lat,
                    "energy": res.energy,
                    "diagonal": best_hw is hw_diag,
                    "ga_generations": 2 * len(best_r.history)}
        return out

    def cosearch_leg():
        recs = sweep.cosearch_sweep(
            [sweep.EvalPoint(tasks[w], hw_plain, opts) for w in wnames],
            "edp", co_cfg, cache=False)
        return dict(zip(wnames, recs))

    sequential_leg()                             # warm the executables
    cosearch_leg()
    t0 = time.perf_counter()
    seq = sequential_leg()
    seq_s = time.perf_counter() - t0
    t0 = time.perf_counter()
    co = cosearch_leg()
    co_s = time.perf_counter() - t0
    speedup = seq_s / co_s

    # -- dominance gate: joint best-EDP <= the sequential EDP-pass
    #    result, every point. The front's min-latency row vs the
    #    latency pass is reported alongside (the same call serves both
    #    figure readings) but only EDP is gated — the archive is
    #    EDP-guided.
    rows, dominance_ok = [], True
    for w in wnames:
        seq_edp = seq[w]["edp"]["edp"]
        leq = co[w].edp <= seq_edp * (1 + 1e-9)
        dominance_ok &= leq
        rows.append({
            "workload": w, "sequential_edp": seq_edp,
            "cosearch_edp": co[w].edp, "cosearch_leq": leq,
            "sequential_latency": seq[w]["latency"]["latency"],
            "cosearch_front_latency": float(co[w].front["latency"].min()),
            "sequential_diag": seq[w]["edp"]["diagonal"],
            "cosearch_diag": bool(co[w].diagonal),
            "front_size": int(len(co[w].front["edp"])),
            "cosearch_generations": int(len(co[w].history)),
        })
        print(f"[perf] cosearch {w}: seq_edp={seq_edp:.4e} "
              f"co_edp={co[w].edp:.4e} leq={leq} "
              f"front={len(co[w].front['edp'])}", flush=True)

    # -- bitwise parity gate (solo == batched, §9)
    solo = cs.run_cosearch(tasks[wnames[0]], hw_plain, "edp", opts, co_cfg)
    b = co[wnames[0]]
    parity_ok = (solo.objective == b.objective
                 and np.array_equal(solo.partition.Px, b.partition.Px)
                 and np.array_equal(solo.partition.Py, b.partition.Py)
                 and solo.diagonal == b.diagonal
                 and np.array_equal(solo.seg_mask, b.seg_mask)
                 and all(np.array_equal(solo.front[k], b.front[k])
                         for k in solo.front))

    # -- seeding gate: deterministic generation counts, measured at the
    #    fig13 reference budget (population 64, patience 12 — the
    #    tuned perf-leg budget early-stops too fast to resolve
    #    first-attainment) on the workload whose landscape is
    #    non-trivial (alexnet; vit/hydranet reach their optimum in
    #    generation 1 either way). ``cold_first`` = first generation
    #    the cold start attains its final best; the seeded search must
    #    attain that same fitness in <= half as many generations.
    seed_cfg = co_cfg if smoke else dataclasses.replace(
        co_cfg, population=64, patience=12)
    t_seed, hw_seed = tasks[wnames[0]], hw_plain
    cold = cs.cosearch_islands([t_seed], [hw_seed], opts, "edp",
                               seed_cfg, seeds=[[]])[0]
    seeded = cs.cosearch_islands([t_seed], [hw_seed], opts, "edp",
                                 seed_cfg)[0]
    tol = cold.objective * (1 + 1e-12)
    cold_first = int(np.nonzero(cold.history <= tol)[0][0]) + 1
    reach = np.nonzero(seeded.history <= tol)[0]
    gens_to_reach = int(reach[0]) + 1 if reach.size else None
    seeding_ok = (gens_to_reach is not None
                  and 2 * gens_to_reach <= cold_first)

    print(f"[perf] cosearch grid={len(wnames)} points: "
          f"sequential={seq_s:.2f}s cosearch={co_s:.2f}s "
          f"speedup={speedup:.2f}x | dominance="
          f"{'OK' if dominance_ok else 'FAIL'} "
          f"parity={'OK' if parity_ok else 'FAIL'} | seeded reached "
          f"cold best in {gens_to_reach} generations vs cold's "
          f"{cold_first}")
    out = {"points": len(wnames), "sequential_s": seq_s,
           "cosearch_s": co_s, "speedup": speedup,
           "dominance_ok": dominance_ok, "parity_ok": parity_ok,
           "seeded_generations_to_cold_best": gens_to_reach,
           "cold_generations_to_best": cold_first,
           "seeding_ok": seeding_ok,
           "rows": rows}
    if not smoke:
        ok = speedup >= 3.0 and dominance_ok and parity_ok and seeding_ok
        out["verdict"] = ("confirmed (>=3x fused, co-EDP <= sequential "
                          "everywhere, solo==batched bitwise, seeded "
                          "<= half the generations)" if ok else "refuted")
        print(f"[perf] cosearch -> {out['verdict']}")
    os.makedirs(ART, exist_ok=True)
    name = "cosearch_smoke.json" if smoke else "cosearch.json"
    with open(os.path.join(ART, name), "w") as f:
        json.dump(out, f, indent=1)
    if not parity_ok:
        # A batched record that differs from its solo equivalent breaks
        # the §9 contract — fail the smoke/CI gate loudly.
        raise SystemExit("cosearch: batched record != solo record")
    if not dominance_ok:
        # The joint search losing to the pass sequence on its own
        # objective is a correctness property of the search space (the
        # sequential solutions are representable genomes) — fail loudly.
        raise SystemExit("cosearch: joint search worse than the "
                         "sequential per-pass flow on >=1 point")


def run_hetero(smoke: bool = False):
    """Heterogeneous-hardware migration gate + multi-tenant placement
    (DESIGN.md §18).

    Three legs:

    * **Parity (gated, even in smoke)** — a one-class ``ChipletClass``
      broadcast over the grid must be BITWISE equal to the legacy
      scalar config in every engine family: evaluator (regime + flow
      congestion × numpy + jax backends), GA, MIQP lattice, RCPSP
      pipelining, and co-search. Per-chiplet rate views are filled with
      the *same* floats the scalar fields hold and consumed
      elementwise, so any divergence is a real migration bug — exits
      nonzero.
    * **Batching** — genuinely hetero configs share the homogeneous
      shape signature ((n_ops, X, Y, E) + statics), so a (workload ×
      class-assignment) grid runs in ONE compiled eval call. Timed warm
      against per-point solo calls; expect ≥2× batched.
    * **Multi-tenant (gated, even in smoke)** — two models on the
      asymmetric 2-class grid: the band search must never lose to the
      even-split placement (it is always in the candidate set — losing
      means enumeration or scoring broke), and on this grid it should
      strictly win.

    Acceptance bar: all parity families bitwise + ≥2× batched + strict
    multi-tenant improvement. ``smoke=True`` shrinks budgets to a
    seconds-long check and writes ``hetero_smoke.json`` without a
    verdict (both correctness gates still exit nonzero)."""
    import numpy as np

    from repro.core import (ChipletClass, EvalOptions, Evaluator,
                            HWConfig, MultiTenantConfig, make_hw,
                            solve_multitenant, sweep, uniform_partition)
    from repro.core.cosearch import CoSearchConfig
    from repro.core.ga import GAConfig
    from repro.core.miqp import MIQPConfig, run_miqp
    from repro.core.pipelining import pipeline_batch
    from repro.graphs import WORKLOADS

    from .fig_hetero import FAST, SLOW

    if smoke:
        wnames = ("alexnet",)
        ga_cfg = GAConfig(population=16, generations=8, patience=4,
                          seed=0)
        co_cfg = CoSearchConfig(population=16, generations=8, batch=2,
                                archive_size=8, seed=0)
        miqp_cfg = MIQPConfig(engine="lattice", candidate_budget=512,
                              eval_budget=2048, beam_width=4,
                              refine_sweeps=1, pair_refine=8,
                              descent_sweeps=2, max_axis_candidates=16,
                              max_layer_candidates=32, score_chunk=256,
                              backend="numpy")
        n_assign, reps = 4, 1
        mt_cfg = MultiTenantConfig(method="uniform")
    else:
        wnames = ("alexnet", "vit")
        ga_cfg = GAConfig(population=64, generations=40, seed=0)
        co_cfg = CoSearchConfig(population=32, generations=16, batch=4,
                                seed=0)
        miqp_cfg = MIQPConfig(engine="lattice", backend="jax")
        n_assign, reps = 8, 3
        mt_cfg = MultiTenantConfig(
            method="ga", cfg=GAConfig(population=32, generations=20,
                                      patience=8, seed=0))

    tasks = {w: WORKLOADS[w](batch=1) for w in wnames}
    base = make_hw("A", 4, "hbm")
    hw_scalar = base
    hw_bcast = base.replace(chiplet_classes=(ChipletClass(),),
                            class_assignment=(0,) * 16)
    opts = EvalOptions(redistribution=True, async_exec=True)
    task0 = tasks[wnames[0]]

    # ---- leg 1: bitwise parity across the five engine families ------
    def rec_eq(ra, rb):
        # numeric payload only — records also carry the point's hw/task
        # metadata, which differs by construction (scalar vs broadcast).
        return all(
            np.array_equal(ra[k], rb[k]) if isinstance(ra[k], np.ndarray)
            else ra[k] == rb[k]
            for k in ra if isinstance(ra[k], (np.ndarray, float, int)))

    parity = {}
    ok = True
    for be in ("numpy", "jax"):
        for cong in ("regime", "flow"):
            o = EvalOptions(redistribution=True, async_exec=True,
                            congestion=cong)
            ra, rb = sweep.eval_sweep(
                [sweep.EvalPoint(task0, hw_scalar, o),
                 sweep.EvalPoint(task0, hw_bcast, o)],
                backend=be, cache=False)
            parity[f"eval/{be}/{cong}"] = rec_eq(ra, rb)
    ga_a, = sweep.solve_grid([sweep.EvalPoint(task0, hw_scalar, opts)],
                             "edp", ga_cfg, cache=False)
    ga_b, = sweep.solve_grid([sweep.EvalPoint(task0, hw_bcast, opts)],
                             "edp", ga_cfg, cache=False)
    parity["ga"] = (ga_a.objective == ga_b.objective
                    and np.array_equal(ga_a.partition.Px,
                                       ga_b.partition.Px)
                    and np.array_equal(ga_a.partition.Py,
                                       ga_b.partition.Py))
    mq_a = run_miqp(task0, hw_scalar, "edp", opts, miqp_cfg)
    mq_b = run_miqp(task0, hw_bcast, "edp", opts, miqp_cfg)
    parity["miqp_lattice"] = (
        mq_a.objective == mq_b.objective
        and np.array_equal(mq_a.partition.Px, mq_b.partition.Px))
    segs = [Evaluator(task0, hw).evaluate(
        uniform_partition(task0, hw.X, hw.Y)).segments()
        for hw in (hw_scalar, hw_bcast)]
    pa, pb = (pipeline_batch(s, batch=4) for s in segs)
    parity["pipelining"] = (segs[0] == segs[1]
                            and pa.pipelined == pb.pipelined)
    co_a, = sweep.cosearch_sweep([sweep.EvalPoint(task0, hw_scalar,
                                                  opts)],
                                 "edp", co_cfg, cache=False)
    co_b, = sweep.cosearch_sweep([sweep.EvalPoint(task0, hw_bcast,
                                                  opts)],
                                 "edp", co_cfg, cache=False)
    parity["cosearch"] = (
        co_a.objective == co_b.objective
        and np.array_equal(co_a.partition.Px, co_b.partition.Px)
        and co_a.diagonal == co_b.diagonal)
    ok = all(parity.values())
    print("[perf] hetero parity: " + " ".join(
        f"{k}={'OK' if v else 'FAIL'}" for k, v in parity.items()),
        flush=True)

    # ---- leg 2: hetero points batch with homogeneous ones -----------
    rng = np.random.default_rng(0)
    hetero_pts = [
        sweep.EvalPoint(
            tasks[w],
            HWConfig.hetero([FAST, SLOW],
                            rng.integers(0, 2, 16).tolist(),
                            bw_mem=base.bw_mem,
                            mcm_type=base.mcm_type),
            opts)
        for w in wnames for _ in range(n_assign)]

    def batched():
        return sweep.eval_sweep(hetero_pts, backend="jax", cache=False)

    def solo():
        return [sweep.eval_sweep([p], backend="jax", cache=False)[0]
                for p in hetero_pts]

    batched(), solo()                            # warm the executables
    t0 = time.perf_counter()
    for _ in range(reps):
        batched()
    batched_s = (time.perf_counter() - t0) / reps
    t0 = time.perf_counter()
    for _ in range(reps):
        solo()
    solo_s = (time.perf_counter() - t0) / reps
    speedup = solo_s / batched_s

    # ---- leg 3: multi-tenant vs even split --------------------------
    hw2 = base.replace(chiplet_classes=(FAST, SLOW),
                       class_assignment=(0,) * 8 + (1,) * 8)
    mt_tasks = [task0, tasks[wnames[-1]]]
    res = solve_multitenant(mt_tasks, hw2, objective="edp", cfg=mt_cfg)
    mt_ok = res.edp <= res.baseline["edp"] * (1 + 1e-12)
    mt_strict = res.edp < res.baseline["edp"]

    print(f"[perf] hetero: {len(hetero_pts)} hetero points "
          f"batched={batched_s:.3f}s solo={solo_s:.3f}s "
          f"speedup={speedup:.2f}x | parity="
          f"{'OK' if ok else 'FAIL'} | multitenant "
          f"edp={res.edp:.3e} even={res.baseline['edp']:.3e} "
          f"{'beats' if mt_strict else 'ties'} even split", flush=True)
    out = {"parity": parity, "parity_ok": ok,
           "hetero_points": len(hetero_pts),
           "batched_s": batched_s, "solo_s": solo_s, "speedup": speedup,
           "multitenant": {
               "inner_method": mt_cfg.method,
               "search_edp": res.edp,
               "even_split_edp": res.baseline["edp"],
               "beats_even_split": bool(mt_strict),
               "assignment": [list(b) for b in res.assignment]}}
    if not smoke:
        good = ok and mt_strict and speedup >= 2.0
        out["verdict"] = (
            "confirmed (scalar==broadcast bitwise across all five "
            "engine families, >=2x batched hetero eval, multi-tenant "
            "beats even split)" if good else "refuted")
        print(f"[perf] hetero -> {out['verdict']}")
    os.makedirs(ART, exist_ok=True)
    name = "hetero_smoke.json" if smoke else "hetero.json"
    with open(os.path.join(ART, name), "w") as f:
        json.dump(out, f, indent=1)
    if not ok:
        # A broadcast record that differs from its scalar equivalent is
        # a migration bug (DESIGN.md §18) — fail the smoke/CI gate.
        raise SystemExit("hetero: one-class broadcast != scalar config "
                         "in " + ", ".join(k for k, v in parity.items()
                                           if not v))
    if not mt_ok:
        raise SystemExit("hetero: multi-tenant search lost to the "
                         "even-split baseline")


# Pinned tolerances for the planner_validate gate (DESIGN.md §17).
# After the global scale fit, every cell's measured/predicted ratio must
# stay within VALIDATE_MAX_DEV of the fitted scale, and log-predicted vs
# log-measured must correlate at VALIDATE_MIN_CORR across the zoo.
# Pinned from the 2026-08 full run (max dev 1.53x, corr 0.982) with ~2x
# headroom on the deviation and a floor well under the observed corr.
VALIDATE_MAX_DEV = 3.0
VALIDATE_MIN_CORR = 0.85


def run_planner_validate(smoke: bool = False):
    """Measured-vs-predicted validation gate for the analytical evaluator
    (DESIGN.md §17).

    Calibrates the evaluator's constants from kernel microbenchmarks
    (``kernels/calibrate.profile_kernels``), persists + reloads the
    profile through the cache-store idiom, then sweeps the model zoo
    through BOTH cost models on the same validation slice (2 layers,
    seq 512, batch 8, prefill):

      predicted  — ``sharding/mcm_planner.plan`` on the calibrated
                   TPU-as-MCM model (eq. 7–12), and
      measured   — the plan *executed* through ``launch/dryrun``
                   (``execute_plan``: lowered, compiled, costed with
                   trip-exact calibration counts), rooflined with the
                   SAME profile constants.

    A single multiplicative scale is fitted in log space (the two models
    count different overheads; structure, not scale, is the claim); the
    gate pins the max per-cell deviation from that scale and the log-log
    correlation, and exits nonzero on violation — in smoke mode too.
    ``--smoke`` runs 3 archs with the tiny profile; the full run covers
    7 archs and writes the verdict.
    """
    import math

    import numpy as np

    from repro.configs import SHAPE_DEFS, get_config
    from repro.kernels.calibrate import (load_profile, profile_kernels,
                                         save_profile)
    from repro.launch.dryrun import execute_plan
    from repro.launch.mesh import make_debug_mesh
    from repro.sharding.mcm_planner import arch_to_task, plan

    archs = ["smollm-360m", "gemma2-2b", "rwkv6-3b"]
    if not smoke:
        archs += ["minicpm3-4b", "internlm2-20b", "zamba2-2.7b",
                  "mixtral-8x22b"]
    layers, seq, batch = 2, 512, 8

    # 1) Calibrate and round-trip the profile through the store — the
    #    persistence path is part of the production loop, not just a test.
    os.makedirs(ART, exist_ok=True)
    t0 = time.time()
    prof = profile_kernels(smoke=smoke, reps=2 if smoke else 3)
    save_profile(prof, os.path.join(ART, "calibrated_hw.bin"))
    prof = load_profile(os.path.join(ART, "calibrated_hw.bin"))
    if prof is None:
        raise SystemExit("planner_validate: profile store roundtrip "
                         "failed")
    t_cal = time.time() - t0
    print(f"[perf] planner_validate: calibrated {prof.backend} in "
          f"{t_cal:.1f}s — matmul {prof.flops_per_s:.3g} FLOP/s, "
          f"stream {prof.bytes_per_s:.3g} B/s, byte overhead "
          f"{prof.byte_overhead:.2f}x")

    # 2) Sweep the zoo through both cost models on one validation slice.
    mesh = make_debug_mesh()
    mesh_axes = dict(mesh.shape)
    mesh_shape = (mesh_axes.get("data", 1), mesh_axes.get("model", 1))
    vshape = "__planner_validate"
    SHAPE_DEFS[vshape] = dict(seq_len=seq, global_batch=batch,
                              kind="prefill")
    rows = []
    try:
        for arch in archs:
            cfg = get_config(arch)
            # Depth of the validation slice: at least `layers`, rounded up
            # to the arch's repeating unit (hybrid/local-global periods) so
            # the model's block grouping stays constructible.
            per = (getattr(cfg, "hybrid_attn_period", 0)
                   or getattr(cfg, "local_global_period", 0) or 1)
            L = per * max(1, -(-layers // per))
            pr = plan(cfg, mesh_shape, seq, batch, layers=L,
                      ga_budget=3 if smoke else 10, profile=prof)
            t0 = time.time()
            rec = execute_plan(
                pr, arch, vshape, mesh, mesh_name="debug",
                calibrate=True, cfg_overrides={"n_layers": L},
                serve_fsdp=("data",))
            cal = rec["calibrated"]
            coll = sum(cal["collective_bytes_per_device"].values())
            measured = max(
                cal["flops_per_device"] / prof.flops_per_s,
                cal["bytes_per_device"] / prof.bytes_per_s,
                coll / prof.bw_nop_model if coll else 0.0)
            task = arch_to_task(cfg, seq, batch, layers=L)
            hlo_flops = cal["flops_per_device"] * mesh.size
            rows.append({
                "arch": arch,
                "layers": L,
                "predicted_s": pr.optimized_latency,
                "measured_s": measured,
                "task_flops": task.total_flops,
                "hlo_flops": hlo_flops,
                "flops_ratio": hlo_flops / task.total_flops,
                "plan_knobs": rec["plan"]["knobs"],
                "nonuniform_headroom": pr.nonuniform_headroom,
                "compile_s": rec["compile_s"],
            })
            print(f"[perf] planner_validate {arch}: pred="
                  f"{pr.optimized_latency*1e3:.2f}ms meas="
                  f"{measured*1e3:.2f}ms flops-ratio="
                  f"{rows[-1]['flops_ratio']:.2f} "
                  f"({time.time() - t0:.0f}s)")
    finally:
        SHAPE_DEFS.pop(vshape, None)

    # 3) Fit the scale, gate deviation + correlation.
    logs = [math.log(r["measured_s"] / r["predicted_s"]) for r in rows]
    scale = math.exp(sum(logs) / len(logs))
    max_dev = math.exp(max(abs(v - math.log(scale)) for v in logs))
    lp = np.log([r["predicted_s"] for r in rows])
    lm = np.log([r["measured_s"] for r in rows])
    corr = (float(np.corrcoef(lp, lm)[0, 1])
            if len(rows) >= 3 and lp.std() > 0 else 1.0)

    out = {
        "cell": "planner_validate",
        "smoke": smoke,
        "backend": prof.backend,
        "n_devices": mesh.size,
        "mesh_shape": list(mesh_shape),
        "slice": {"min_layers": layers, "seq_len": seq, "batch": batch},
        "profile": {
            "flops_per_s": prof.flops_per_s,
            "bytes_per_s": prof.bytes_per_s,
            "byte_overhead": prof.byte_overhead,
            "nop_frac": prof.nop_frac,
            "schema": prof.schema,
            "calibrate_s": round(t_cal, 2),
        },
        "rows": rows,
        "fitted_scale": scale,
        "max_scale_deviation": max_dev,
        "log_log_corr": corr,
        "tolerances": {"max_deviation": VALIDATE_MAX_DEV,
                       "min_corr": VALIDATE_MIN_CORR},
    }
    ok = max_dev <= VALIDATE_MAX_DEV and corr >= VALIDATE_MIN_CORR
    if not smoke:
        out["verdict"] = (
            f"confirmed (max dev {max_dev:.2f}x <= {VALIDATE_MAX_DEV}x, "
            f"corr {corr:.3f} >= {VALIDATE_MIN_CORR})" if ok else
            f"refuted (max dev {max_dev:.2f}x vs {VALIDATE_MAX_DEV}x, "
            f"corr {corr:.3f} vs {VALIDATE_MIN_CORR})")
        print(f"[perf] planner_validate -> {out['verdict']}")
    else:
        print(f"[perf] planner_validate (smoke): scale={scale:.2f} "
              f"max-dev={max_dev:.2f}x corr={corr:.3f} ok={ok}")

    name = ("planner_validate_smoke.json" if smoke
            else "planner_validate.json")
    with open(os.path.join(ART, name), "w") as f:
        json.dump(out, f, indent=1)
    print("wrote", os.path.join(ART, name))
    if not ok:
        # The gate IS the cell: prediction drifted off measurement.
        raise SystemExit(
            f"planner_validate: measured-vs-predicted gate failed "
            f"(max dev {max_dev:.2f}x, tol {VALIDATE_MAX_DEV}x; corr "
            f"{corr:.3f}, min {VALIDATE_MIN_CORR})")


def run_smollm(mesh):
    """Worst roofline fraction: heads (15) indivisible by model=16 ⇒
    attention replicates across the model axis."""
    cell = ("smollm-360m", "train_4k")
    base = measure(*cell, mesh)
    print("baseline:", fmt(base))

    # It.1: shard the query-chunk dim of blockwise attention over model.
    h1 = ("attention compute is replicated 16x because 15 heads don't "
          "divide the model axis; sharding the 512-long query-chunk dim "
          "over model recovers ~16x attention parallelism at the cost of "
          "one out-chunk all-gather per q block (napkin: attention is "
          "~14/15 of layer FLOPs here -> expect ~10x compute-term drop)")
    after = measure(*cell, mesh,
                    extra_rules={"attn_qchunk": P(("data",), "model",
                                                  None, None, None)})
    verdict = ("confirmed" if after["compute_s"] < base["compute_s"] * 0.5
               else "refuted")
    log_iteration("smollm-360m/train_4k", "seq-chunk-sharded attention",
                  h1, base, after, verdict)
    best = after if after["bound_s"] < base["bound_s"] else base
    best_knobs = ({"extra_rules": {"attn_qchunk": P(("data",), "model",
                                                    None, None, None)}}
                  if best is after else {})

    # It.2: residual sharding off (trade collective for memory headroom).
    h2 = ("residual-stream sharding (ZeRO-R) inserts per-layer "
          "all-gathers; smollm has memory headroom, so dropping it should "
          "cut the collective term with bounded temp growth")
    after2 = measure(*cell, mesh, shard_residual=False, **best_knobs)
    verdict = ("confirmed" if after2["collective_s"]
               < best["collective_s"] else "refuted")
    log_iteration("smollm-360m/train_4k", "residual sharding off", h2,
                  best, after2, verdict)


def run_internlm2(mesh):
    """Most collective-bound dense trainer."""
    cell = ("internlm2-20b", "train_4k")
    base = measure(*cell, mesh)
    print("baseline:", fmt(base))

    # It.1: accum 2 -> 1 (halve FSDP param re-gathers).
    h1 = ("every microbatch re-gathers the FSDP-sharded params; accum 2 "
          "doubles gather traffic. accum=1 halves the all-gather bytes "
          "(collective term ~ -40%) but roughly doubles activation temp "
          "(9.2 -> ~17 GiB, over budget) — expect confirmed on "
          "collectives, rejected on memory fit")
    a1 = measure(*cell, mesh, accum=1)
    verdict = ("confirmed" if a1["collective_s"] < base["collective_s"]
               * 0.75 else "refuted")
    verdict += "; fits" if a1["temp_gib"] + a1["args_gib"] <= 16 else \
        "; does NOT fit 16GiB"
    log_iteration("internlm2-20b/train_4k", "accum 2->1", h1, base, a1,
                  verdict)

    # It.2: accum 1 + smaller attn chunks to claw back activation memory.
    h2 = ("keep accum=1 gather savings; shrink attention q-chunk 512->256 "
          "to reduce the per-layer transient so the cell fits 16 GiB")
    a2 = measure(*cell, mesh, accum=1, cfg_overrides={"attn_chunk": 256})
    fits = a2["temp_gib"] + a2["args_gib"] <= 16
    verdict = ("confirmed" if fits and a2["collective_s"]
               < base["collective_s"] * 0.75 else "refuted")
    log_iteration("internlm2-20b/train_4k", "accum1 + attn_chunk 256",
                  h2, base, a2, verdict)


def run_internlm2_sp(mesh):
    """Beyond-paper iteration: Megatron-SP-style sequence sharding of the
    residual stream instead of d_model (ZeRO-R) sharding."""
    cell = ("internlm2-20b", "train_4k")
    base = measure(*cell, mesh)
    print("baseline:", fmt(base))
    h = ("the d_model-sharded residual (ZeRO-R) pays all-gathers on top "
         "of the TP partial-sum all-reduces; sharding the residual over "
         "SEQUENCE instead converts AR(2Z)+AG/RS(2Z) per block into "
         "AG(Z)+RS(Z) (Megatron-SP) — napkin: ~50% collective-term cut at "
         "equal memory")
    after = measure(*cell, mesh, shard_residual=False,
                    extra_rules={"act_btd": P(("data",), "model", None)})
    verdict = ("confirmed" if after["collective_s"]
               < base["collective_s"] * 0.75 else "refuted")
    log_iteration("internlm2-20b/train_4k", "sequence-parallel residual",
                  h, base, after, verdict)


def run_internlm2_nozr(mesh):
    """Iteration 4: drop ZeRO-R residual sharding entirely (keep TP ARs),
    paying the memory back with accum=4."""
    cell = ("internlm2-20b", "train_4k")
    base = measure(*cell, mesh)
    print("baseline:", fmt(base))
    h = ("after it.1–3: collectives are invariant to accum and naive "
         "seq-sharding backfires (GSPMD re-gathers the sequence per "
         "layer); the remaining removable component is the ZeRO-R "
         "residual AG/RS itself — turn shard_residual off and recover "
         "the activation memory with accum=4 (microbatch 4x smaller). "
         "Napkin: residual AG/RS ≈ 2 x (tokens x D) x layers x microbats "
         "of the 2.0 TB total → expect ~30-45% collective-term cut")
    a = measure(*cell, mesh, shard_residual=False, accum=4)
    fits = a["temp_gib"] + a["args_gib"] <= 16
    verdict = ("confirmed" if a["collective_s"] < base["collective_s"]
               * 0.75 and fits else
               ("partially confirmed" if a["collective_s"]
                < base["collective_s"] else "refuted"))
    verdict += "; fits" if fits else "; does NOT fit"
    log_iteration("internlm2-20b/train_4k", "no ZeRO-R + accum 4", h,
                  base, a, verdict)


def run_gemma2_decode(mesh):
    """Most representative of the paper (communication optimization for
    edge inference): decode is dominated by per-token parameter
    re-gathers under FSDP."""
    cell = ("gemma2-2b", "decode_32k")
    base = measure(*cell, mesh)
    print("baseline:", fmt(base))
    h1 = ("FSDP re-gathers the full 2.6B-param model over ICI on every "
          "decoded token (~0.3 GiB/token/device of all-gather) while the "
          "HBM read of locally-replicated weights would cost only ~2 ms; "
          "serving with params replicated along the data axis (TP-only "
          "sharding) should collapse the collective term to attention-"
          "reduce noise and make decode memory-bound, its natural regime")
    a1 = measure(*cell, mesh, serve_fsdp=())
    verdict = ("confirmed" if a1["collective_s"]
               < base["collective_s"] * 0.3
               and a1["dominant"] == "memory" else
               ("partially confirmed" if a1["collective_s"]
                < base["collective_s"] else "refuted"))
    log_iteration("gemma2-2b/decode_32k", "replicated-params serving",
                  h1, base, a1, verdict)


def run_minicpm3(mesh):
    """Worst roofline fraction: MLA with 40 heads (indivisible by 16) —
    replicated latent-attention compute + gathers."""
    cell = ("minicpm3-4b", "prefill_32k")
    base = measure(*cell, mesh)
    print("baseline:", fmt(base))
    h1 = ("40 q-heads don't divide the 16-way model axis, so MLA latent "
          "attention replicates; sharding the query-chunk dim over model "
          "(attn_qchunk) restores 16x attention parallelism")
    a1 = measure(*cell, mesh,
                 extra_rules={"attn_qchunk": P(("data",), "model",
                                               None, None, None)})
    verdict = ("confirmed" if a1["compute_s"] < base["compute_s"] * 0.5
               else "refuted")
    log_iteration("minicpm3-4b/prefill_32k", "seq-chunk-sharded MLA",
                  h1, base, a1, verdict)


def run_deepseek(mesh):
    """Most representative of the paper's technique (MoE dispatch = the
    forced-sync grouped-GEMM boundary; DESIGN.md §4) and also the worst
    memory cell."""
    cell = ("deepseek-v2-236b", "train_4k")
    base = measure(*cell, mesh)
    print("baseline:", fmt(base))

    # It.1: accum 8 -> 4 (fewer expert-weight re-gathers) at bf16 accum.
    h2 = ("expert weights dominate gather traffic and are re-gathered "
          "once per microbatch; accum 8->4 halves that collective term "
          "if activations still fit (they dominated at accum<=4 before "
          "the MoE fixes; expect ~2x collective improvement, temp "
          "+~2GiB)")
    a2 = measure(*cell, mesh, accum=4)
    verdict = ("confirmed" if a2["collective_s"] < base["collective_s"]
               * 0.65 else "refuted")
    verdict += "; fits" if a2["temp_gib"] + a2["args_gib"] <= 16 else \
        "; does NOT fit single-pod 16GiB"
    log_iteration("deepseek-v2-236b/train_4k", "accum 8->4", h2, base,
                  a2, verdict)


if __name__ == "__main__":
    main()
