"""Fig. 11 reproduction: per-sample pipelining speedup vs batch size.

Paper claims: the RCPSP (ILP) pipeliner finds ample overlap and the
per-sample speedup stays roughly constant across batch sizes.
"""
from __future__ import annotations

from repro.core import make_hw, optimize
from repro.core.miqp import MIQPConfig
from repro.graphs import WORKLOADS

from .common import emit, save_json, timed


def main(fast: bool = False):
    hw = make_hw("A", 4, "hbm")
    results = {}
    wnames = ("alexnet",) if fast else ("alexnet", "vit", "hydranet")
    for wname in wnames:
        task = WORKLOADS[wname](batch=1)
        sched = optimize(task, hw, "miqp",
                         miqp_config=MIQPConfig(time_limit=30))
        for batch in (2, 4, 8, 16):
            r, us = timed(sched.pipeline, batch)
            results[f"{wname}/b{batch}"] = r.speedup
            emit(f"fig11/{wname}/batch{batch}", us,
                 f"speedup={r.speedup:.3f}x per_sample_us="
                 f"{r.per_sample*1e6:.1f}")
        # ILP refinement on the smallest instance (paper: solver-based)
        r, us = timed(sched.pipeline, 4, True)
        emit(f"fig11/{wname}/batch4_ilp", us, f"speedup={r.speedup:.3f}x")
    save_json("fig11", results)


if __name__ == "__main__":
    main()
