"""On-disk sweep-cache store invariants (DESIGN.md §14): exact
round-trip, schema-version cold start, torn-write recovery, and
cross-process reuse through ``sweep.export_cache``/``import_cache``."""
import os

import numpy as np
import pytest

from _hypothesis_compat import given, settings, st
from repro.core import EvalOptions, GemmOp, Task, make_hw
from repro.core import sweep
from repro.core.ga import GAConfig
from repro.core.workload import uniform_partition
from repro.serve import cache_store
from repro.serve.cache_store import CacheStore


def toy_task(n=3, m=512):
    ops = [GemmOp("g0", M=m, K=256, N=512)]
    for i in range(1, n):
        ops.append(GemmOp(f"g{i}", M=m, K=ops[-1].N, N=512, chained=True))
    return Task(f"toy{n}_{m}", ops)


@pytest.fixture(autouse=True)
def _fresh_cache():
    sweep.clear_cache()
    yield
    sweep.clear_cache()


def _populated_cache():
    """Fill the process cache with one record of each family: eval
    (regime + flow), GA solver, pipelining."""
    task, hw = toy_task(), make_hw("A", 2, "hbm")
    pts = [sweep.EvalPoint(task, hw, EvalOptions(redistribution=True)),
           sweep.EvalPoint(task, hw, EvalOptions(congestion="flow"))]
    sweep.eval_sweep(pts)
    sweep.solve_grid(
        [sweep.EvalPoint(toy_task(2), make_hw("A", 2))], "latency",
        GAConfig(generations=3, population=16, patience=3, seed=1))
    sweep.pipeline_sweep(
        [sweep.PipelinePoint([("a", 1.0, 2.0, 1.0),
                              ("b", 0.5, 1.0, 0.5)], 4)])
    return sweep.export_cache()


def _assert_value_equal(a, b):
    if isinstance(a, dict):
        assert set(a) == set(b)
        for k in a:
            if isinstance(a[k], np.ndarray):
                np.testing.assert_array_equal(a[k], b[k])
            else:
                assert a[k] == b[k], k
        return
    # solver records: dataclasses with numpy fields
    assert type(a) is type(b)
    for f in vars(a) if hasattr(a, "__dict__") else ():
        va, vb = getattr(a, f), getattr(b, f)
        if isinstance(va, np.ndarray):
            np.testing.assert_array_equal(va, vb)
        elif hasattr(va, "Px"):          # Partition
            np.testing.assert_array_equal(va.Px, vb.Px)
            np.testing.assert_array_equal(va.Py, vb.Py)
            np.testing.assert_array_equal(va.collectors, vb.collectors)
        else:
            assert va == vb, f


def test_round_trip_exact(tmp_path):
    entries = _populated_cache()
    assert len(entries) >= 4
    store = CacheStore(tmp_path / "c.bin")
    store.save(entries)
    loaded = store.load()
    assert not store.last_load.cold_start
    assert not store.last_load.torn_tail
    assert set(loaded) == set(entries)
    for k in entries:
        _assert_value_equal(entries[k], loaded[k])


def test_append_accumulates(tmp_path):
    task, hw = toy_task(), make_hw("A", 2, "hbm")
    store = CacheStore(tmp_path / "c.bin")
    sweep.eval_sweep([sweep.EvalPoint(task, hw)])
    first = sweep.export_cache()
    store.append(first)                      # creates file + header
    sweep.eval_sweep(
        [sweep.EvalPoint(task, hw, EvalOptions(redistribution=True))])
    snap = sweep.export_cache()
    second = {k: v for k, v in snap.items() if k not in first}
    assert second
    store.append(second)
    loaded = store.load()
    assert set(loaded) == set(snap)


def test_schema_mismatch_cold_start(tmp_path, monkeypatch):
    entries = _populated_cache()
    store = CacheStore(tmp_path / "c.bin")
    monkeypatch.setattr(cache_store, "SCHEMA_VERSION", 999)
    store.save(entries)
    monkeypatch.undo()
    loaded = CacheStore(tmp_path / "c.bin").load()
    assert loaded == {}


def test_schema_mismatch_reports_reason(tmp_path, monkeypatch):
    entries = _populated_cache()
    path = tmp_path / "c.bin"
    monkeypatch.setattr(cache_store, "SCHEMA_VERSION", 999)
    CacheStore(path).save(entries)
    monkeypatch.undo()
    store = CacheStore(path)
    assert store.load() == {}
    assert store.last_load.cold_start
    assert "schema" in store.last_load.reason


def test_missing_and_foreign_files_cold_start(tmp_path):
    store = CacheStore(tmp_path / "absent.bin")
    assert store.load() == {}
    assert store.last_load.cold_start

    junk = tmp_path / "junk.bin"
    junk.write_bytes(b"\x00\x01not a store" * 7)
    store = CacheStore(junk)
    assert store.load() == {}
    assert store.last_load.cold_start


def test_torn_write_recovery(tmp_path):
    entries = _populated_cache()
    path = tmp_path / "c.bin"
    store = CacheStore(path)
    store.save(entries)
    full = os.path.getsize(path)
    # Truncate mid-record (drop the last 7 bytes): the tail record is
    # torn, every earlier record must survive intact.
    with open(path, "r+b") as f:
        f.truncate(full - 7)
    loaded = CacheStore(path).load()
    st2 = CacheStore(path)
    loaded = st2.load()
    assert st2.last_load.torn_tail
    assert not st2.last_load.cold_start
    assert 0 < len(loaded) < len(entries)
    for k, v in loaded.items():
        _assert_value_equal(entries[k], v)
    # Appending after recovery-by-load still works on a fresh save.
    st2.save(entries)
    assert set(CacheStore(path).load()) == set(entries)


def test_torn_header_cold_start(tmp_path):
    entries = _populated_cache()
    path = tmp_path / "c.bin"
    CacheStore(path).save(entries)
    with open(path, "r+b") as f:
        f.truncate(5)                    # inside the header record
    store = CacheStore(path)
    assert store.load() == {}
    assert store.last_load.cold_start


def test_corrupt_record_checksum_drops_tail(tmp_path):
    entries = _populated_cache()
    path = tmp_path / "c.bin"
    CacheStore(path).save(entries)
    size = os.path.getsize(path)
    with open(path, "r+b") as f:         # flip one byte near the end
        f.seek(size - 3)
        b = f.read(1)
        f.seek(size - 3)
        f.write(bytes([b[0] ^ 0xFF]))
    st2 = CacheStore(path)
    loaded = st2.load()
    assert st2.last_load.torn_tail
    assert len(loaded) < len(entries)
    for k, v in loaded.items():
        _assert_value_equal(entries[k], v)


def test_cross_process_reuse_two_sequential_loads(tmp_path):
    """Process A computes + persists; processes B and C (fresh caches)
    both serve the same points entirely from the store."""
    task, hw = toy_task(), make_hw("A", 2, "hbm")
    pts = [sweep.EvalPoint(task, hw),
           sweep.EvalPoint(task, hw, EvalOptions(redistribution=True))]
    ref = sweep.eval_sweep(pts)
    CacheStore(tmp_path / "c.bin").save(sweep.export_cache())

    for _process in ("B", "C"):
        sweep.clear_cache()
        n = sweep.import_cache(CacheStore(tmp_path / "c.bin").load())
        assert n == len(pts)
        recs = sweep.eval_sweep(pts)
        stats = sweep.cache_stats()
        assert stats["misses"] == 0 and stats["hits"] == len(pts)
        for a, b in zip(ref, recs):
            assert a["latency"] == b["latency"]       # bit-identical
            np.testing.assert_array_equal(a["t_in"], b["t_in"])


def test_import_cache_existing_keys_win():
    task, hw = toy_task(), make_hw("A", 2, "hbm")
    pt = sweep.EvalPoint(task, hw)
    rec = sweep.eval_sweep([pt])[0]
    snap = sweep.export_cache()
    (k, v), = snap.items()
    poisoned = dict(v, latency=-1.0)
    assert sweep.import_cache({k: poisoned}) == 0     # resident wins
    assert sweep.eval_sweep([pt])[0]["latency"] == rec["latency"]
    assert sweep.import_cache({k: poisoned}, replace=True) == 1
    assert sweep.eval_sweep([pt])[0]["latency"] == -1.0


# ------------------------------------------------- property-based store
_key_atom = st.one_of(
    st.text(max_size=8),
    st.integers(min_value=-2**40, max_value=2**40),
    st.floats(allow_nan=False, allow_infinity=False),
    st.binary(max_size=16),
    st.booleans(),
)


@settings(max_examples=20, deadline=None)
@given(st.dictionaries(
    st.tuples(_key_atom, _key_atom, _key_atom),
    st.fixed_dictionaries({
        "latency": st.floats(allow_nan=False, allow_infinity=False),
        "arr": st.lists(st.floats(allow_nan=False, allow_infinity=False),
                        max_size=8),
    }),
    max_size=8,
))
def test_store_roundtrip_random_fingerprint_axes(tmp_path_factory, entries):
    """Any pickle-able fingerprint tuple and record dict round-trips the
    store exactly (the axes of PRs 1–5 are tuples of exactly these atom
    types plus frozen dataclasses)."""
    entries = {k: dict(v, arr=np.asarray(v["arr"])) for k, v in
               entries.items()}
    path = tmp_path_factory.mktemp("store") / "c.bin"
    store = CacheStore(path)
    store.save(entries)
    loaded = store.load()
    assert set(loaded) == set(entries)
    for k in entries:
        assert loaded[k]["latency"] == entries[k]["latency"]
        np.testing.assert_array_equal(loaded[k]["arr"], entries[k]["arr"])
