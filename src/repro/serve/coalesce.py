"""Request model + coalescing for the optimization server (DESIGN.md
§14).

An optimization request is one point of the same design space the
batched sweep engine (:mod:`repro.core.sweep`, DESIGN.md §9) already
drives: an evaluation (``eval_sweep``), a solver search (``solve_grid``
— GA, MIQP-lattice, or the fused co-search of DESIGN.md §16), or an
RCPSP pipelining instance (``pipeline_sweep``). The server coalesces queued requests whose
*call key* — (kind, method, objective, solver config, backend) — is
identical into ONE sweep call; the sweep engine then shape-groups that
call into single compiled executions and fingerprints every point into
the process-wide cache, so a request's result is bit-identical whether
it was served alone or coalesced with a thousand others (the
solo==served contract, an extension of §9's solo==batched).

Validation is the bad-request firewall: :meth:`OptRequest.validate`
raises :class:`BadRequest` for malformed points (wrong point type,
partition sums that don't match the task, unknown objective/method/
backend, non-finite segment durations) *before* the point can reach a
batched call, so one poisoned request can neither kill the worker nor
taint its cohort.
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Any

import numpy as np

from ..core import sweep
from ..core.cosearch import CoSearchConfig
from ..core.evaluator import EvalOptions
from ..core.ga import GAConfig
from ..core.miqp import MIQPConfig
from ..core.multitenant import MultiTenantConfig
from ..core.pipelining import PipelineConfig

__all__ = ["BadRequest", "OptRequest", "CallKey", "group_requests",
           "KINDS", "SOLVE_METHODS", "OBJECTIVES"]

KINDS = ("eval", "solve", "pipeline")
SOLVE_METHODS = ("ga", "miqp", "cosearch", "multitenant")
OBJECTIVES = ("latency", "energy", "edp")
_BACKENDS = ("numpy", "jax", "auto")

_rid = itertools.count()


class BadRequest(ValueError):
    """Malformed optimization request — rejected per request, never
    allowed to reach (or kill) a batched worker call."""


@dataclasses.dataclass(frozen=True)
class CallKey:
    """Coalescing key: requests sharing a CallKey go through one sweep
    call (which shape-groups internally). All fields are hashable —
    solver configs are frozen dataclasses."""

    kind: str
    method: str
    objective: str
    cfg: Any
    backend: str


@dataclasses.dataclass
class OptRequest:
    """One optimization request.

    ``kind="eval"``     → ``point`` is a :class:`~repro.core.sweep.
    EvalPoint`, served by ``eval_sweep`` (objective/method/cfg unused).
    ``kind="solve"``    → ``point`` is an ``EvalPoint`` whose partition
    is ignored; ``method`` picks GA, MIQP-lattice, or the fused
    co-search (``"cosearch"``, DESIGN.md §16 — returns a
    ``CoSearchResult`` with the full Pareto front), ``cfg`` the frozen
    solver config, ``objective`` the fitness.
    ``kind="pipeline"`` → ``point`` is a :class:`~repro.core.sweep.
    PipelinePoint`, served by ``pipeline_sweep`` (``cfg`` a
    ``PipelineConfig``).
    """

    kind: str
    point: Any
    objective: str = "latency"
    method: str = "ga"
    cfg: Any = None
    backend: str = "jax"
    rid: int = dataclasses.field(default_factory=lambda: next(_rid))

    # ------------------------------------------------------------ keys
    def call_key(self) -> CallKey:
        if self.kind == "eval":
            # objective/method/cfg don't reach eval_sweep — normalize
            # them out so equivalent requests coalesce.
            return CallKey("eval", "-", "-", None, self.backend)
        if self.kind == "pipeline":
            return CallKey("pipeline", "-", "-", self.cfg, self.backend)
        return CallKey("solve", self.method, self.objective, self.cfg,
                       self.backend)

    def shape_signature(self) -> tuple:
        """Shape-group signature (mirrors the sweep engine's grouping,
        DESIGN.md §9) — per-request observability of which compiled
        executable will serve it; the server reports distinct signatures
        per coalesced call."""
        if self.kind == "pipeline":
            return ("pipeline", len(self.point.segments),
                    int(self.point.batch))
        pt = self.point
        if self.kind == "solve" and self.method == "multitenant":
            return (self.kind, "multitenant",
                    tuple(len(t) for t in pt.tasks),
                    pt.hw.X, pt.hw.Y, pt.hw.mcm_type.value, pt.options)
        return (self.kind, len(pt.task), pt.hw.X, pt.hw.Y,
                pt.hw.mcm_type.value, pt.options)

    # ------------------------------------------------------ validation
    def validate(self) -> None:
        """Raise :class:`BadRequest` on any malformed field. Runs on the
        worker before coalescing; a failure rejects THIS request only."""
        if self.kind not in KINDS:
            raise BadRequest(f"unknown kind {self.kind!r}; one of {KINDS}")
        if self.backend not in _BACKENDS:
            raise BadRequest(f"unknown backend {self.backend!r}; "
                             f"one of {_BACKENDS}")
        if self.kind == "eval" and self.backend == "auto":
            raise BadRequest("eval requests need a concrete backend "
                             "('numpy' | 'jax')")
        if self.kind == "pipeline":
            self._validate_pipeline()
        elif self.kind == "solve" and self.method == "multitenant":
            self._validate_multitenant_point()
        else:
            self._validate_eval_point()
        if self.kind == "solve":
            if self.method not in SOLVE_METHODS:
                raise BadRequest(f"unknown method {self.method!r}; "
                                 f"one of {SOLVE_METHODS}")
            if self.objective not in OBJECTIVES:
                raise BadRequest(f"unknown objective {self.objective!r}; "
                                 f"one of {OBJECTIVES}")
            want = {"ga": GAConfig, "miqp": MIQPConfig,
                    "cosearch": CoSearchConfig,
                    "multitenant": MultiTenantConfig}[self.method]
            if self.cfg is not None and not isinstance(self.cfg, want):
                raise BadRequest(
                    f"cfg for method={self.method!r} must be "
                    f"{want.__name__}, got {type(self.cfg).__name__}")
            if self.method == "cosearch" and self.backend == "numpy":
                # The joint search is a fused traced objective — there
                # is no host engine to serve it on.
                raise BadRequest("method='cosearch' requires backend "
                                 "'jax' (or 'auto'); got 'numpy'")

    def _validate_eval_point(self) -> None:
        pt = self.point
        if not isinstance(pt, sweep.EvalPoint):
            raise BadRequest(f"{self.kind} request needs an EvalPoint, "
                             f"got {type(pt).__name__}")
        if not isinstance(pt.options, EvalOptions):
            raise BadRequest("point.options must be EvalOptions")
        self._validate_hw(pt.hw)
        if self.kind == "eval" and pt.partition is not None:
            self._validate_partition(pt)

    def _validate_hw(self, hw) -> None:
        """Re-run the full :meth:`HWConfig.validate` field checks —
        unpickling (the transport every remote request rides in on)
        bypasses ``__post_init__``, so corrupted hetero fields (wrong
        assignment length, nonpositive class rates, out-of-range
        indices) would otherwise reach a batched worker call."""
        try:
            hw.validate()
        except ValueError as e:
            raise BadRequest(f"invalid hardware config: {e}") from e
        except Exception as e:
            raise BadRequest(f"point.hw is not a valid HWConfig: "
                             f"{e}") from e

    def _validate_multitenant_point(self) -> None:
        from ..core.workload import Task

        pt = self.point
        if not isinstance(pt, sweep.MultiTenantPoint):
            raise BadRequest(
                f"solve method='multitenant' needs a MultiTenantPoint, "
                f"got {type(pt).__name__}")
        if not isinstance(pt.options, EvalOptions):
            raise BadRequest("point.options must be EvalOptions")
        self._validate_hw(pt.hw)
        if not isinstance(pt.tasks, tuple) or not pt.tasks:
            raise BadRequest("multitenant point needs a non-empty "
                             "tuple of tenant tasks")
        for t in pt.tasks:
            if not isinstance(t, Task) or len(t) < 1:
                raise BadRequest("every tenant must be a non-empty Task")
        if len(pt.tasks) > pt.hw.X:
            raise BadRequest(
                f"{len(pt.tasks)} tenants need {len(pt.tasks)} row "
                f"bands but the grid has X={pt.hw.X} rows")

    def _validate_partition(self, pt) -> None:
        """Vectorized mirror of :meth:`Partition.validate` — the
        per-op numpy-scalar loop there costs ~0.3 ms/request, which at
        serving rates is the single largest server-side overhead."""
        part, n = pt.partition, len(pt.task)
        try:
            Px, Py = np.asarray(part.Px), np.asarray(part.Py)
            if Px.ndim != 2 or Py.ndim != 2 or Px.shape[0] != n \
                    or Py.shape[0] != n:
                raise BadRequest(
                    f"invalid partition: Px/Py shapes {Px.shape}/"
                    f"{Py.shape} do not match {n} ops")
            M = np.fromiter((op.M for op in pt.task.ops),
                            dtype=np.int64, count=n)
            N = np.fromiter((op.N for op in pt.task.ops),
                            dtype=np.int64, count=n)
            bad = (Px.sum(axis=1) != M) | (Py.sum(axis=1) != N) \
                | (Px < 0).any(axis=1) | (Py < 0).any(axis=1)
            if bad.any():
                i = int(np.argmax(bad))
                raise BadRequest(
                    f"invalid partition: {pt.task.ops[i].name}: "
                    f"sum(Px)={int(Px[i].sum())} != M={M[i]} or "
                    f"sum(Py)={int(Py[i].sum())} != N={N[i]} or "
                    f"negative entries")
        except BadRequest:
            raise
        except Exception as e:
            raise BadRequest(f"invalid partition: {e}") from e

    def _validate_pipeline(self) -> None:
        pt = self.point
        if not isinstance(pt, sweep.PipelinePoint):
            raise BadRequest("pipeline request needs a PipelinePoint, "
                             f"got {type(pt).__name__}")
        if self.cfg is not None and not isinstance(self.cfg,
                                                   PipelineConfig):
            raise BadRequest("pipeline cfg must be PipelineConfig, got "
                             f"{type(self.cfg).__name__}")
        if int(pt.batch) < 1:
            raise BadRequest(f"pipeline batch must be >= 1, got "
                             f"{pt.batch}")
        if len(pt.segments) < 1:
            raise BadRequest("pipeline request needs >= 1 segment")
        try:
            durs = pt.durations()
        except Exception as e:
            raise BadRequest(f"unreadable segments: {e}") from e
        if not np.isfinite(durs).all():
            raise BadRequest("segment durations must be finite")


def group_requests(requests) -> dict[CallKey, list]:
    """Coalesce: bucket requests by :meth:`OptRequest.call_key`,
    preserving arrival order within each bucket. Each bucket becomes ONE
    batched sweep call."""
    groups: dict[CallKey, list] = {}
    for r in requests:
        groups.setdefault(r.call_key(), []).append(r)
    return groups
