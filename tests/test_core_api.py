"""Top-level core API tests: optimize() across methods/objectives, the
polish pass, and ScheduleResult plumbing."""
import numpy as np
import pytest

from repro.core import GemmOp, Task, make_hw, optimize
from repro.core.ga import GAConfig
from repro.core.miqp import MIQPConfig


def task():
    ops = [GemmOp("a", M=512, K=256, N=1024),
           GemmOp("b", M=512, K=1024, N=512, chained=True),
           GemmOp("c", M=512, K=512, N=1024, chained=True)]
    return Task("t3", ops)


def test_all_methods_run():
    hw = make_hw("A", 4)
    t = task()
    res = {}
    for m in ("baseline", "simba", "ga", "miqp"):
        r = optimize(t, hw, m, "latency",
                     ga_config=GAConfig(generations=15, population=24),
                     miqp_config=MIQPConfig(time_limit=10))
        r.partition.validate(t)
        res[m] = r.latency
    assert res["ga"] <= res["baseline"] + 1e-12
    assert res["miqp"] <= res["baseline"] + 1e-12


def test_speedup_property_and_pipeline():
    hw = make_hw("B", 4)
    r = optimize(task(), hw, "miqp",
                 miqp_config=MIQPConfig(time_limit=10))
    assert r.speedup_vs_baseline >= 1.0 - 1e-9
    p = r.pipeline(batch=4)
    assert p.speedup >= 1.0


def test_pipeline_congestion_aware_segments():
    """ScheduleResult.segments(congestion=...) re-derives the per-op
    durations under the requested congestion model (DESIGN.md §11/§13):
    identical for the model the schedule was scored under, simulated
    netsim arrival times for ``"flow"`` — and both pipeline cleanly."""
    from repro.core import PipelineConfig

    hw = make_hw("A", 4)
    r = optimize(task(), hw, "simba")
    assert r.segments() == r.segments(congestion="regime")
    flow = r.segments(congestion="flow")
    assert len(flow) == len(r.segments())
    p_reg = r.pipeline(batch=4)
    p_flow = r.pipeline(batch=4, congestion="flow")
    assert p_flow.pipelined > 0 and p_flow.speedup >= 1.0
    # engines agree on the flow-segment instance too
    p_flow_py = r.pipeline(batch=4, congestion="flow",
                           config=PipelineConfig(engine="python"))
    assert p_flow.pipelined == p_flow_py.pipelined
    assert p_reg.engine == "vectorized" and p_flow_py.engine == "python"
    # a context-less (back-compat) result must refuse, not silently
    # return wrong-congestion durations
    import dataclasses

    bare = dataclasses.replace(r, task=None, hw_used=None, options=None)
    assert bare.segments() == r.segments()
    with pytest.raises(ValueError):
        bare.segments(congestion="flow")


def test_unknown_method_raises():
    with pytest.raises(ValueError):
        optimize(task(), make_hw("A", 4), "magic")


def test_edp_method_improves_or_matches():
    hw = make_hw("A", 4)
    r = optimize(task(), hw, "ga", "edp",
                 ga_config=GAConfig(generations=20, population=24))
    assert r.edp <= r.baseline.edp * 1.0 + 1e-18


def test_polish_only_improves():
    from repro.core.api import _polish
    from repro.core.evaluator import EvalOptions, Evaluator
    from repro.core.workload import uniform_partition
    hw = make_hw("A", 4, diagonal_links=True)
    t = task()
    opts = EvalOptions(redistribution=True, async_exec=True)
    ev = Evaluator(t, hw, opts)
    part = uniform_partition(t, 4, 4)
    rd = ev.chain_valid.copy()
    before = ev.evaluate(part, rd).latency
    p2, rd2 = _polish(t, hw, opts, part, rd, "latency")
    after = ev.evaluate(p2, rd2).latency
    assert after <= before + 1e-15
