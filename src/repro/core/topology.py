"""Shared MCM topology layer (DESIGN.md §11) — the single source of truth
for mesh geometry.

Before this module, three components re-derived the same mesh facts
independently: :mod:`repro.core.hw` built entrance geometry and the
Sec. 4.3 hop matrices, :mod:`repro.core.evaluator` rebuilt entrance
row/column masks, and :mod:`repro.core.netsim` enumerated links and XY
routes from scratch. Everything lives here now, as array-valued
primitives:

  * **Entrance geometry** — :func:`entrances` (packaging types A–D,
    Fig. 2/4), :func:`assign_entrances` (nearest-entrance chiplet
    grouping + the Sec. 4.2.1 local indices), :func:`entrance_masks`
    (the per-entrance one-hot / row / column masks the evaluator's
    off-chip serialization terms consume).
  * **Hop matrices** — :func:`hop_matrices` (eqs. 10–12 plus the
    Sec. 5.1.1 diagonal-link alternative) and :func:`n_mesh_links`
    (entrance link counts for the eq. 8 collection bandwidth).
  * **Link-level graph** — :class:`MeshGraph`: a dense enumeration of
    every directed NoP link plus one memory port per chiplet, XY
    (row-dimension-first) routing, and route *incidence matrices*
    ``[n_flows, n_links]`` — the representation the vectorized max-min
    netsim (:mod:`repro.core.netsim` / :mod:`repro.core.netsim_jax`) and
    the evaluator's ``congestion="flow"`` mode operate on.

The memory-port convention: every chiplet gets a port-link pair in the
enumeration (``mem → c`` and ``c → mem``) whether or not memory actually
attaches there. Unused ports carry no flows, so they never constrain the
waterfilling — but keeping them in the link space makes the link axis a
pure function of (X, Y), so whole (memory × placement × bandwidth) grids
share one array shape and batch through a single compiled netsim call.
"""
from __future__ import annotations

import dataclasses
from functools import cached_property

import numpy as np

__all__ = [
    "entrances",
    "n_mesh_links",
    "assign_entrances",
    "hop_matrices",
    "entrance_masks",
    "MeshGraph",
    "nearest_attach",
]


# ------------------------------------------------------------- entrances
def entrances(mcm_type, X: int, Y: int) -> list[tuple[int, int, str]]:
    """Memory entrance chiplets as (gx, gy, kind), kind in
    {"corner", "edge", "3d"} — packaging types A–D of Fig. 2/4."""
    t = getattr(mcm_type, "value", mcm_type)
    if t == "A":
        return [(0, 0, "corner")]
    if t == "B":
        # Memory stacks on left and right edges, one per row per side.
        out = []
        for gx in range(X):
            out.append((gx, 0, "edge"))
            if Y > 1:
                out.append((gx, Y - 1, "edge"))
        return out
    if t == "C":
        return [(gx, gy, "3d") for gx in range(X) for gy in range(Y)]
    if t == "D":
        # Type B edges + 3D stacks on the interior quad.
        out = []
        for gx in range(X):
            out.append((gx, 0, "edge"))
            if Y > 1:
                out.append((gx, Y - 1, "edge"))
        x0, x1 = (X - 1) // 2, X // 2
        y0, y1 = (Y - 1) // 2, Y // 2
        for gx in sorted({x0, x1}):
            for gy in sorted({y0, y1}):
                if 0 < gy < Y - 1 or Y <= 2:
                    out.append((gx, gy, "3d"))
        return out
    raise ValueError(f"unknown MCM type {t}")


def n_mesh_links(gx: int, gy: int, X: int, Y: int, diagonal: bool) -> int:
    """Number of NoP links incident to chiplet (gx, gy) in an X×Y mesh.

    Diagonal links (Sec. 5.1) add one diagonal neighbour toward the grid
    interior — a corner global chiplet goes from 2 to 3 entrance links,
    the paper's "50% more bandwidth on the bottleneck communication".
    """
    n = 0
    n += 1 if gx > 0 else 0
    n += 1 if gx < X - 1 else 0
    n += 1 if gy > 0 else 0
    n += 1 if gy < Y - 1 else 0
    if diagonal:
        # One diagonal link per chiplet toward the interior diagonal mate.
        if (gx < X - 1 and gy < Y - 1) or (gx > 0 and gy > 0):
            n += 1
    return n


def assign_entrances(X: int, Y: int, ents: list[tuple[int, int, str]]):
    """Group chiplets by nearest entrance (manhattan, ties broken by
    entrance order). Returns ``(entrance_id, x_local, y_local, Xg, Yg)``,
    all ``[X, Y]`` int arrays — the Sec. 4.2.1 local indexing."""
    gx = np.arange(X)[:, None] * np.ones((1, Y), dtype=int)
    gy = np.ones((X, 1), dtype=int) * np.arange(Y)[None, :]
    dists = np.stack(
        [np.abs(gx - ex) + np.abs(gy - ey) for ex, ey, _ in ents], axis=0)
    entrance_id = np.argmin(dists, axis=0)                    # [X, Y]
    ex = np.array([e[0] for e in ents])
    ey = np.array([e[1] for e in ents])
    x_local = np.abs(gx - ex[entrance_id])
    y_local = np.abs(gy - ey[entrance_id])
    Xg = np.ones((X, Y), dtype=int)
    Yg = np.ones((X, Y), dtype=int)
    for e in range(len(ents)):
        m = entrance_id == e
        if m.any():
            Xg[m] = int(x_local[m].max()) + 1
            Yg[m] = int(y_local[m].max()) + 1
    return entrance_id, x_local, y_local, Xg, Yg


def hop_matrices(x_local, y_local, Xg, Yg, diagonal: bool):
    """The Sec. 4.3 hop-count matrices (eqs. 10–12).

    Returns ``(hops_low, hops_row_shared, hops_col_shared)``:
      * eq. 10 (low off-chip BW): minimal path ``x + y``;
      * eq. 11 (high BW, row-shared): ``X + y`` with farthest-first
        waiting;
      * eq. 12 (high BW, col-shared): ``Y + x``;
      * Sec. 5.1.1 diagonal alternative ``X − x + max(x, y)`` taken as a
        per-chiplet min (the two strategies use disjoint links).

    3D zero-hop masking (a chiplet directly under its memory stack) is
    the caller's job — it needs entrance *kind*, which is not a hop fact.
    """
    x, y = x_local, y_local
    hops_low = x + y
    h_row = Xg + y
    h_col = Yg + x
    if diagonal:
        h_row = np.minimum(h_row, Xg - x + np.maximum(x, y))
        h_col = np.minimum(h_col, Yg - y + np.maximum(x, y))
    return hops_low, h_row, h_col


def entrance_masks(X: int, Y: int, ents, entrance_id):
    """Per-entrance membership masks consumed by the evaluator:
    ``(ent_mask [E,X,Y], ent_pos [E,X,Y], row_mask [E,X], col_mask
    [E,Y])`` — group membership, entrance position one-hots, and their
    row/column projections (off-chip serialization is per entrance over
    the rows/columns its group spans)."""
    E = len(ents)
    ent_mask = np.zeros((E, X, Y), dtype=bool)
    for e in range(E):
        ent_mask[e] = entrance_id == e
    ent_pos = np.zeros((E, X, Y), dtype=bool)
    for i, (exi, eyi, _) in enumerate(ents):
        ent_pos[i, exi, eyi] = True
    return ent_mask, ent_pos, ent_mask.any(axis=2), ent_mask.any(axis=1)


# ------------------------------------------------------------ link graph
def nearest_attach(attach: list[int], dst: int, Y: int) -> int:
    """Attach chiplet closest (manhattan) to ``dst``; ties break by
    ``attach`` order — the netsim's historical routing rule."""
    dr, dc = divmod(dst, Y)
    return min(attach,
               key=lambda a: abs(a // Y - dr) + abs(a % Y - dc))


@dataclasses.dataclass(frozen=True)
class MeshGraph:
    """Directed link enumeration + XY routing for an X×Y mesh with a
    memory node (id ``X*Y``) reachable through per-chiplet ports.

    Link order: all directed mesh links (row-major over chiplets, the
    +x then +y neighbour, both directions), then the ``mem → c`` port of
    every chiplet, then every ``c → mem`` port. The link axis is a pure
    function of (X, Y): ``n_links = 2·(X·(Y−1) + Y·(X−1)) + 2·X·Y``.
    """

    X: int
    Y: int

    @property
    def n_nodes(self) -> int:
        return self.X * self.Y

    @property
    def mem(self) -> int:
        return self.X * self.Y

    @cached_property
    def links(self) -> tuple[tuple[int, int], ...]:
        X, Y, mem = self.X, self.Y, self.mem
        out: list[tuple[int, int]] = []
        for r in range(X):
            for c in range(Y):
                u = r * Y + c
                for (rr, cc) in ((r + 1, c), (r, c + 1)):
                    if rr < X and cc < Y:
                        v = rr * Y + cc
                        out.append((u, v))
                        out.append((v, u))
        out += [(mem, c) for c in range(X * Y)]
        out += [(c, mem) for c in range(X * Y)]
        return tuple(out)

    @cached_property
    def index(self) -> dict[tuple[int, int], int]:
        return {l: i for i, l in enumerate(self.links)}

    @property
    def n_links(self) -> int:
        return len(self.links)

    @property
    def n_mesh_links_directed(self) -> int:
        """Directed mesh (NoP) links — the enumeration prefix before the
        2·n_nodes memory ports. The single source of the layout split."""
        return self.n_links - 2 * self.n_nodes

    def node_rc(self, n: int) -> tuple[int, int]:
        return divmod(n, self.Y)

    # -------------------------------------------------------------- routes
    def xy_route(self, src: int, dst: int) -> list[tuple[int, int]]:
        """Dimension-ordered (row-first) XY route, as directed link keys."""
        Y = self.Y
        links = []
        r, c = self.node_rc(src)
        r1, c1 = self.node_rc(dst)
        while r != r1:
            nr = r + (1 if r1 > r else -1)
            links.append((r * Y + c, nr * Y + c))
            r = nr
        while c != c1:
            nc = c + (1 if c1 > c else -1)
            links.append((r * Y + c, r * Y + nc))
            c = nc
        return links

    def pull_route(self, attach: list[int], dst: int,
                   via: int | None = None) -> list[tuple[int, int]]:
        """Memory → ``dst``: enter through ``via`` (or the nearest attach
        chiplet), then XY."""
        a = via if via is not None else nearest_attach(attach, dst, self.Y)
        return [(self.mem, a)] + self.xy_route(a, dst)

    def push_route(self, attach: list[int], src: int,
                   via: int | None = None) -> list[tuple[int, int]]:
        """``src`` → memory: XY to ``via`` (or the nearest attach
        chiplet), then out through its port."""
        a = via if via is not None else nearest_attach(attach, src, self.Y)
        return self.xy_route(src, a) + [(a, self.mem)]

    def _incidence(self, routes: list[list[tuple[int, int]]]) -> np.ndarray:
        inc = np.zeros((len(routes), self.n_links), dtype=np.float64)
        idx = self.index
        for f, route in enumerate(routes):
            for l in route:
                inc[f, idx[l]] = 1.0
        return inc

    def pull_incidence(self, attach: list[int],
                       assign: np.ndarray | None = None) -> np.ndarray:
        """Route-incidence matrix ``[n_nodes, n_links]`` for one flow per
        chiplet pulling from memory. ``assign[f]`` (optional) picks the
        entrance *node id* each chiplet enters through; default is
        nearest-attach."""
        return self._incidence([
            self.pull_route(attach, d,
                            None if assign is None else int(assign[d]))
            for d in range(self.n_nodes)])

    def push_incidence(self, attach: list[int],
                       assign: np.ndarray | None = None) -> np.ndarray:
        """Route-incidence matrix for one flow per chiplet pushing its
        output to memory (the collection phase)."""
        return self._incidence([
            self.push_route(attach, s,
                            None if assign is None else int(assign[s]))
            for s in range(self.n_nodes)])

    def link_caps(self, bw_nop, bw_mem: float, attach: list[int],
                  mem_scale=None) -> np.ndarray:
        """Per-link capacities ``[n_links]``: mesh links at ``bw_nop``,
        every memory port at ``bw_mem / len(attach)`` (iso-total-bandwidth
        split; non-attach ports carry no flows, so their value is inert
        but keeps the array batchable across attachment sets).

        ``bw_nop`` may be a per-chiplet ``[n_nodes]`` array (heterogeneous
        grids): a mesh link then runs at the min of its endpoint rates.
        ``mem_scale`` (optional ``[n_nodes]``) scales each chiplet's port
        share. With equal-element arrays both reduce bitwise to the
        scalar capacities."""
        b = np.asarray(bw_nop, dtype=np.float64)
        cap = np.empty(self.n_links, dtype=np.float64)
        n_mesh = self.n_mesh_links_directed
        if b.ndim == 0:
            cap[:n_mesh] = b
        elif n_mesh:
            uv = np.asarray(self.links[:n_mesh])
            cap[:n_mesh] = np.minimum(b[uv[:, 0]], b[uv[:, 1]])
        per_port = float(bw_mem) / max(len(attach), 1)
        if mem_scale is None:
            cap[n_mesh:] = per_port
        else:
            s = np.asarray(mem_scale, dtype=np.float64)
            node = np.arange(self.n_nodes)
            cap[n_mesh:] = np.concatenate(
                [per_port * s[node], per_port * s[node]])
        return cap

    def mesh_link_mask(self) -> np.ndarray:
        """Boolean ``[n_links]``: True for mesh (NoP) links, False for
        memory ports."""
        m = np.zeros(self.n_links, dtype=bool)
        m[: self.n_mesh_links_directed] = True
        return m
