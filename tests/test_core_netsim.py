"""Flow simulator vs the paper's Fig. 3 motivation claims."""
import pytest

from repro.core.netsim import MeshNet, fig3_case, simulate_pull

GB = 1e9


def test_dram_memory_bound_nop_scaling_useless():
    """Fig 3(a)/(d): DRAM-bound — 2x NoP bandwidth gives no speedup."""
    a = fig3_case("dram", "peripheral", bw_nop=60 * GB)
    b = fig3_case("dram", "peripheral", bw_nop=120 * GB)
    assert a["latency"] == pytest.approx(b["latency"], rel=1e-6)
    assert a["latency"] == pytest.approx(16 / 60, rel=1e-6)  # 16 GB / BW


def test_hbm_nop_bound_scales_linearly():
    """Fig 3(b)/(d): HBM case scales linearly with NoP bandwidth."""
    a = fig3_case("hbm", "peripheral", bw_nop=60 * GB)
    b = fig3_case("hbm", "peripheral", bw_nop=120 * GB)
    assert a["latency"] / b["latency"] == pytest.approx(2.0, rel=1e-3)


def test_hbm_central_placement_gain():
    """Fig 3(c)/(d): central memory placement ≈1.5x over peripheral
    (paper: 1.53x)."""
    p = fig3_case("hbm", "peripheral")
    c = fig3_case("hbm", "central")
    assert p["latency"] / c["latency"] == pytest.approx(1.5, abs=0.1)


def test_dram_placement_no_impact():
    p = fig3_case("dram", "peripheral")
    c = fig3_case("dram", "central")
    assert p["latency"] == pytest.approx(c["latency"], rel=1e-6)


def test_link_utilization_hotspot_near_entrance():
    out = fig3_case("hbm", "peripheral")
    util = out["link_util"]
    # hottest mesh link is adjacent to the attach chiplet (node 0)
    mesh_links = {l: u for l, u in util.items() if 16 not in l}
    hot = max(mesh_links, key=mesh_links.get)
    assert 0 in hot


def test_flow_conservation():
    net = MeshNet(4, 4, 60 * GB, 1024 * GB, [0])
    out = simulate_pull(net, 1 * GB)
    # every destination got its full message through its last link
    for f in out["flows"]:
        assert f.bytes_left <= 1e-3
        assert f.done_at is not None and f.done_at <= out["latency"] + 1e-9
