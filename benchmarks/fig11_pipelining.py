"""Fig. 11 reproduction: per-sample pipelining speedup vs batch size.

Paper claims: the RCPSP (ILP) pipeliner finds ample overlap and the
per-sample speedup stays roughly constant across batch sizes.

Grid driving (benchmarks/README.md): one MIQP schedule per workload,
then the (workload × batch) pipelining grid runs via ``sweep.run_grid``.
"""
from __future__ import annotations

from repro.core import make_hw, optimize, sweep
from repro.core.miqp import MIQPConfig
from repro.graphs import WORKLOADS

from .common import emit, save_json, timed


def main(fast: bool = False, backend: str = "jax"):
    hw = make_hw("A", 4, "hbm")
    results = {}
    wnames = ("alexnet",) if fast else ("alexnet", "vit", "hydranet")
    scheds = {w: optimize(WORKLOADS[w](batch=1), hw, "miqp",
                          backend=backend,
                          miqp_config=MIQPConfig(time_limit=30))
              for w in wnames}

    def report(pt, r, us):
        wname, batch = pt["wname"], pt["batch"]
        results[f"{wname}/b{batch}"] = r.speedup
        emit(f"fig11/{wname}/batch{batch}", us,
             f"speedup={r.speedup:.3f}x per_sample_us="
             f"{r.per_sample*1e6:.1f}")

    sweep.run_grid(
        sweep.grid(wname=wnames, batch=(2, 4, 8, 16)),
        lambda wname, batch: scheds[wname].pipeline(batch),
        emit=report)

    # ILP refinement on the smallest instance (paper: solver-based)
    for wname in wnames:
        r, us = timed(scheds[wname].pipeline, 4, True)
        emit(f"fig11/{wname}/batch4_ilp", us, f"speedup={r.speedup:.3f}x")
    save_json("fig11", results)


if __name__ == "__main__":
    main()
