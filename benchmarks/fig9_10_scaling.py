"""Fig. 9/10 reproduction: latency and EDP scaling on type-A systems of
4×4 / 8×8 / 16×16 chiplets.

Paper claims: MIQP geo-mean 55.5% (latency) / 60.3% (EDP) over LS; GA
24.2% / 35.1%. MIQP > GA, with AlexNet gaining more on larger systems
(redistribution savings grow with scale); GA is relatively stronger on
EDP than latency.

Grid driving (benchmarks/README.md): the (grid × workload) LS references
are one batched sweep (latency and EDP come out of the same records);
the (objective × grid × workload) GA searches run island-batched through
``sweep.solve_grid`` (one compiled call per shape group, DESIGN.md §10);
the MIQP grid runs batched lattice solves through
``sweep.solve_grid(method="miqp")`` (DESIGN.md §12) followed by the
per-point side-variable polish of ``optimize(method="miqp")``; both
solvers' final schedules are scored by batched ``eval_sweep`` calls.
"""
from __future__ import annotations

import time

from repro.core import (EvalOptions, make_hw, refine_schedule, sweep)
from repro.core.ga import GAConfig
from repro.core.miqp import MIQPConfig
from repro.graphs import WORKLOADS

from .common import emit, geomean, save_json

GA_CFG = GAConfig(generations=60, population=64)
MIQP_CFG = MIQPConfig(time_limit=60, edp_sweep=3)
GA_OPTS = EvalOptions(redistribution=True, async_exec=True)
# Sec. 6.3 solves under the sync approximation (no async fusion); the
# result is then polished + scored under the full GA_OPTS runtime —
# the same split optimize(method="miqp") applies.
MIQP_SOLVE_OPTS = EvalOptions(redistribution=True, async_exec=False)


def main(fast: bool = False, backend: str = "jax"):
    grids = (4, 8) if fast else (4, 8, 16)
    wnames = ("alexnet", "hydranet") if fast else tuple(WORKLOADS)
    tasks = {w: WORKLOADS[w](batch=1) for w in wnames}
    hws = {g: make_hw("A", g, "hbm") for g in grids}

    base_grid = sweep.grid(g=grids, wname=wnames)
    base_recs = sweep.eval_sweep(
        [sweep.EvalPoint(tasks[p["wname"]], hws[p["g"]])
         for p in base_grid],
        backend=backend)
    ref = {(p["g"], p["wname"]): r for p, r in zip(base_grid, base_recs)}

    results = {}
    sp_all = {(o, m): [] for o in ("latency", "edp") for m in ("ga", "miqp")}

    # ---- GA: island-batched solves + one batched scoring sweep per
    # objective (same diagonal-link/options setup as optimize(method="ga")).
    for o in ("latency", "edp"):
        fig = "fig9" if o == "latency" else "fig10"
        pts = [sweep.EvalPoint(tasks[p["wname"]],
                               hws[p["g"]].replace(diagonal_links=True),
                               GA_OPTS)
               for p in base_grid]
        t0 = time.perf_counter()
        ga_recs = sweep.solve_grid(pts, o, GA_CFG, backend=backend)
        us = (time.perf_counter() - t0) * 1e6
        score = sweep.eval_sweep(
            [sweep.EvalPoint(pt.task, pt.hw, GA_OPTS,
                             partition=r.partition,
                             redist_mask=r.redist_mask)
             for pt, r in zip(pts, ga_recs)],
            backend=backend)
        # solve time is per batched call (compile included on a cold
        # cache), not per point — emitted once; per-point rows carry the
        # speedups.
        emit(f"{fig}/ga/solve_grid_total", us, f"{len(pts)} points")
        for p, rec in zip(base_grid, score):
            g, wname = p["g"], p["wname"]
            sp = ref[(g, wname)][o] / rec[o]
            sp_all[(o, "ga")].append(sp)
            results[f"{fig}/{g}/{wname}/ga"] = sp
            emit(f"{fig}/{g}x{g}/{wname}/ga", 0.0, f"speedup={sp:.3f}x")

    # ---- MIQP: batched lattice solves per objective (DESIGN.md §12),
    # then the cheap per-point polish and one batched scoring sweep —
    # the optimize(method="miqp") pipeline, grid-vectorized.
    for o in ("latency", "edp"):
        fig = "fig9" if o == "latency" else "fig10"
        pts = [sweep.EvalPoint(tasks[p["wname"]],
                               hws[p["g"]].replace(diagonal_links=True),
                               MIQP_SOLVE_OPTS)
               for p in base_grid]
        t0 = time.perf_counter()
        mi_recs = sweep.solve_grid(pts, o, MIQP_CFG, backend=backend,
                                   method="miqp")
        us = (time.perf_counter() - t0) * 1e6
        emit(f"{fig}/miqp/solve_grid_total", us, f"{len(pts)} points")
        polished = [refine_schedule(pt.task, pt.hw, GA_OPTS, r.partition,
                                    r.redist_mask, o, backend=backend)
                    for pt, r in zip(pts, mi_recs)]
        score = sweep.eval_sweep(
            [sweep.EvalPoint(pt.task, pt.hw, GA_OPTS, partition=part,
                             redist_mask=rd)
             for pt, (part, rd) in zip(pts, polished)],
            backend=backend)
        for p, rec in zip(base_grid, score):
            g, wname = p["g"], p["wname"]
            sp = ref[(g, wname)][o] / rec[o]
            sp_all[(o, "miqp")].append(sp)
            results[f"{fig}/{g}/{wname}/miqp"] = sp
            emit(f"{fig}/{g}x{g}/{wname}/miqp", 0.0, f"speedup={sp:.3f}x")

    for o in ("latency", "edp"):
        fig = "fig9" if o == "latency" else "fig10"
        for m in ("ga", "miqp"):
            emit(f"{fig}/geomean/{m}", 0.0,
                 f"{(geomean(sp_all[(o, m)]) - 1) * 100:+.1f}% vs LS "
                 f"(paper: GA +24.2/35.1%, MIQP +55.5/60.3%)")
    save_json("fig9_10", results)


if __name__ == "__main__":
    main()
