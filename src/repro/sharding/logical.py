"""Logical-axis sharding: models annotate activations by *name*; the
runtime maps names → PartitionSpecs for the current mesh (MaxText/t5x
style). Outside a mesh context the hints are no-ops, so model code runs
unchanged on a single CPU device.
"""
from __future__ import annotations

import contextlib
import contextvars

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_RULES: contextvars.ContextVar[dict | None] = contextvars.ContextVar(
    "logical_rules", default=None)
_MESH: contextvars.ContextVar[Mesh | None] = contextvars.ContextVar(
    "logical_mesh", default=None)


@contextlib.contextmanager
def use_rules(mesh: Mesh | None, rules: dict[str, P] | None):
    t1 = _MESH.set(mesh)
    t2 = _RULES.set(rules)
    try:
        yield
    finally:
        _MESH.reset(t1)
        _RULES.reset(t2)


def current_mesh() -> Mesh | None:
    return _MESH.get()


def current_rules() -> dict | None:
    return _RULES.get()


def sanitize_spec(spec: P, shape, mesh: Mesh) -> P:
    """Drop mesh axes that do not evenly divide the corresponding dim
    (keeps model code shape-agnostic: batch=1 cells, odd head counts and
    non-divisible vocab all degrade to replication on that dim)."""
    out = []
    for d, entry in enumerate(spec):
        if entry is None or d >= len(shape):
            out.append(None)
            continue
        axes = entry if isinstance(entry, tuple) else (entry,)
        keep = []
        size = 1
        for a in axes:
            n = mesh.shape[a]
            if shape[d] % (size * n) == 0:
                keep.append(a)
                size *= n
        out.append(tuple(keep) if len(keep) > 1 else
                   (keep[0] if keep else None))
    return P(*out)


def _coverage(spec: P, mesh: Mesh) -> int:
    n = 1
    for e in spec:
        if e is None:
            continue
        for a in (e if isinstance(e, tuple) else (e,)):
            n *= mesh.shape[a]
    return n


def shard(x, name: str):
    """Annotate activation ``x`` with the sharding registered for logical
    name ``name``; identity when no rules/mesh are active or the name is
    not mapped. Specs are sanitized against the concrete shape; a rule may
    list fallback candidates — the one covering the most devices after
    sanitization wins."""
    rules, mesh = _RULES.get(), _MESH.get()
    if rules is None or mesh is None:
        return x
    rule = rules.get(name)
    if rule is None:
        return x
    cands = rule if isinstance(rule, list) else [rule]
    best, best_cov = None, -1
    for c in cands:
        s = sanitize_spec(c, x.shape, mesh)
        cov = _coverage(s, mesh)
        if cov > best_cov:
            best, best_cov = s, cov
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, best))
