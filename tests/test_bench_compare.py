"""Verdict-regression gate (benchmarks/bench_compare.py): prefix
classification, the confirmed→refuted failing class, tolerance of
new/skipped/missing cells, and the --update rebase path. Deliberately
jax-free — the gate must run on bare CI runners."""
import json
import pathlib
import sys

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1]))
from benchmarks.bench_compare import classify, collect, compare, main


def _write(d, name, payload):
    (d / name).write_text(json.dumps(payload))


def test_classify_prefixes():
    assert classify("confirmed (>=3x)") == "confirmed"
    assert classify("refuted") == "refuted"
    assert classify("skipped (no physical parallelism: 1 core)") \
        == "skipped"
    assert classify("") == "unknown"
    assert classify(None) == "unknown"
    assert classify("inconclusive") == "unknown"


def test_collect_skips_smoke_and_verdictless(tmp_path):
    _write(tmp_path, "a.json", {"verdict": "confirmed (fast)"})
    _write(tmp_path, "a_smoke.json", {"verdict": "bogus"})
    _write(tmp_path, "fig13.json", {"alexnet": {"pipe": 1.5}})
    (tmp_path / "broken.json").write_text("{not json")
    got = collect(str(tmp_path))
    assert got == {"a": "confirmed (fast)"}


def test_compare_flags_only_confirmed_to_refuted():
    baseline = {"a": "confirmed (x)", "b": "confirmed (y)",
                "c": "refuted", "d": "skipped (no cores)",
                "gone": "confirmed (z)"}
    current = {"a": "refuted",                      # the failing class
               "b": "skipped (no cores today)",     # note only
               "c": "confirmed (now faster)",       # improvement: note
               "d": "skipped (still)",              # unchanged
               "new": "confirmed (fresh)"}          # new cell: note
    regressions, notes = compare(baseline, current)
    assert len(regressions) == 1 and "a: confirmed -> refuted" \
        in regressions[0]
    joined = "\n".join(notes)
    assert "b: confirmed -> skipped" in joined
    assert "c: refuted -> confirmed" in joined
    assert "new: new cell" in joined
    assert "gone: no artifact" in joined
    assert "d:" not in joined


def test_main_exit_codes(tmp_path):
    art = tmp_path / "artifacts"
    art.mkdir()
    base = tmp_path / "baselines" / "verdicts.json"
    _write(art, "cell.json", {"verdict": "confirmed (fast)"})

    # no baseline yet → exit 2 with guidance
    assert main(["--artifacts", str(art), "--baseline", str(base)]) == 2
    # --update creates it; compare then passes
    assert main(["--artifacts", str(art), "--baseline", str(base),
                 "--update"]) == 0
    assert json.loads(base.read_text()) == \
        {"cell": "confirmed (fast)"}
    assert main(["--artifacts", str(art), "--baseline", str(base)]) == 0
    # regression → exit 1
    _write(art, "cell.json", {"verdict": "refuted"})
    assert main(["--artifacts", str(art), "--baseline", str(base)]) == 1
    # skipped is not a regression (single-core hosts)
    _write(art, "cell.json", {"verdict": "skipped (no parallelism)"})
    assert main(["--artifacts", str(art), "--baseline", str(base)]) == 0


def test_no_jax_import():
    """The gate must run on runners without the accelerator stack."""
    import benchmarks.bench_compare as bc
    src = open(bc.__file__).read()
    assert "import jax" not in src and "from jax" not in src
