"""Sharded sweep fabric invariants (DESIGN.md §15): mode resolution,
solo == sharded bitwise parity for every record family, padding of
non-multiple grids, fingerprint device-independence (one cache across
modes), and a forced-8-device subprocess parity check."""
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from repro.core import EvalOptions, GemmOp, Task, make_hw
from repro.core import sweep
from repro.core.ga import GAConfig
from repro.core.miqp import MIQPConfig
from repro.core.netsim import MeshNet
from repro.core.sweep_shard import (DEVICE_MODES, device_count,
                                    resolve_devices)

SRC = os.path.join(os.path.dirname(__file__), os.pardir, "src")


def toy_task(n=3, m=512):
    ops = [GemmOp("g0", M=m, K=256, N=512)]
    for i in range(1, n):
        ops.append(GemmOp(f"g{i}", M=m, K=ops[-1].N, N=512, chained=True))
    return Task(f"toy{n}_{m}", ops)


@pytest.fixture(autouse=True)
def _fresh_cache():
    sweep.clear_cache()
    yield
    sweep.clear_cache()


def _eval_points(k=3):
    task = toy_task(3)
    return [sweep.EvalPoint(task, make_hw(t, 4, "hbm"),
                            EvalOptions(congestion="flow"))
            for t in ("A", "B", "C")][:k]


# ------------------------------------------------------ mode resolution
def test_resolve_devices_modes():
    assert resolve_devices("single", 100) == "single"
    assert resolve_devices("sharded", 1) == "sharded"   # explicit wins
    n = device_count()
    want = "sharded" if n > 1 else "single"
    assert resolve_devices("auto", 100) == want
    assert resolve_devices(None, 100) == want           # None == auto
    assert resolve_devices("auto", 1) == "single"       # nothing to shard
    with pytest.raises(ValueError, match="devices"):
        resolve_devices("tpu", 4)


def test_options_and_configs_validate_devices():
    with pytest.raises(ValueError):
        EvalOptions(devices="bogus")
    for mode in DEVICE_MODES:
        assert EvalOptions(devices=mode).devices == mode
    assert GAConfig(devices="sharded").devices == "sharded"
    assert MIQPConfig(devices="sharded").devices == "sharded"


# ----------------------------------------- bitwise parity (all families)
def test_eval_sweep_sharded_matches_single_bitwise():
    pts = _eval_points()
    solo = sweep.eval_sweep(pts, cache=False, devices="single")
    shard = sweep.eval_sweep(pts, cache=False, devices="sharded")
    for a, b in zip(solo, shard):
        assert a["latency"] == b["latency"]
        assert a["energy"] == b["energy"]
        for k in ("t_in", "t_comp", "t_out"):
            assert np.array_equal(a[k], b[k])


def test_netsim_sweep_sharded_matches_single_bitwise():
    nets = [MeshNet(4, 4, 64.0 + i, 128.0, [0, 3]) for i in range(3)]
    solo = sweep.netsim_sweep(nets, 1e6, cache=False, devices="single")
    shard = sweep.netsim_sweep(nets, 1e6, cache=False, devices="sharded")
    for a, b in zip(solo, shard):
        assert a["latency"] == b["latency"]
        assert np.array_equal(a["done"], b["done"])
        assert np.array_equal(a["link_bytes"], b["link_bytes"])


def test_solve_grid_ga_sharded_matches_single_bitwise():
    pts = _eval_points()
    cfg = GAConfig(population=32, generations=3, seed=7)
    solo = sweep.solve_grid(pts, "latency", cfg, cache=False,
                            devices="single")
    shard = sweep.solve_grid(pts, "latency", cfg, cache=False,
                             devices="sharded")
    for a, b in zip(solo, shard):
        assert a.objective == b.objective
        assert np.array_equal(a.partition.Px, b.partition.Px)
        assert np.array_equal(a.partition.Py, b.partition.Py)
        assert np.array_equal(a.history, b.history)


def test_solve_grid_miqp_sharded_matches_single_bitwise():
    pts = _eval_points()
    cfg = MIQPConfig(candidate_budget=64, eval_budget=256)
    solo = sweep.solve_grid(pts, "latency", cfg, cache=False,
                            method="miqp", devices="single")
    shard = sweep.solve_grid(pts, "latency", cfg, cache=False,
                             method="miqp", devices="sharded")
    for a, b in zip(solo, shard):
        assert a.objective == b.objective
        assert np.array_equal(a.partition.Px, b.partition.Px)


def test_pipeline_sweep_sharded_matches_single_bitwise():
    segs = [(f"op{i}", 1.0 + i, 2.0, 0.5) for i in range(4)]
    pts = [sweep.PipelinePoint(
        [(n, a * (1 + 0.5 * k), b, c) for n, a, b, c in segs], 4)
        for k in range(3)]
    solo = sweep.pipeline_sweep(pts, cache=False, devices="single")
    shard = sweep.pipeline_sweep(pts, cache=False, devices="sharded")
    for a, b in zip(solo, shard):
        assert a.sequential == b.sequential
        assert a.pipelined == b.pipelined


# ----------------------------------------- fingerprints & shared cache
def test_devices_knob_is_fingerprint_invisible():
    task, hw = toy_task(2), make_hw("A", 4, "hbm")
    fps = {sweep._point_fingerprint(
        sweep.EvalPoint(task, hw, EvalOptions(devices=mode)), "jax")
        for mode in DEVICE_MODES}
    assert len(fps) == 1
    cfg_fps = {sweep._solver_fingerprint(
        sweep.EvalPoint(task, hw), "ga", "jax", "latency",
        GAConfig(devices=mode)) for mode in DEVICE_MODES}
    assert len(cfg_fps) == 1


def test_cache_shared_across_device_modes():
    pts = _eval_points()
    sweep.eval_sweep(pts, devices="single")
    assert sweep.cache_stats() == {"hits": 0, "misses": 3}
    recs = sweep.eval_sweep(pts, devices="sharded")
    assert sweep.cache_stats() == {"hits": 3, "misses": 3}
    assert all(r is not None for r in recs)


# ------------------------------------------- forced-8-device subprocess
def test_sharded_parity_on_8_forced_devices():
    """Real shard_map over 8 virtual devices, including a grid (G=10)
    that pads to the next multiple of the mesh size."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    script = textwrap.dedent("""
        import jax
        import numpy as np
        from repro.core import sweep, EvalOptions, GemmOp, Task, make_hw
        from repro.core.ga import GAConfig
        from repro.core.sweep_shard import grid_mesh, resolve_devices

        assert jax.device_count() == 8
        assert grid_mesh().size == 8
        assert resolve_devices("auto", 10) == "sharded"

        ops = [GemmOp("g0", M=512, K=256, N=512)]
        for i in range(1, 3):
            ops.append(GemmOp(f"g{i}", M=512, K=ops[-1].N, N=512,
                              chained=True))
        task = Task("toy3", ops)
        # G=10 pads to 16 over the 8-device mesh (tail replicates row 0)
        hws = [make_hw("A", 4, "hbm", bw_nop=64.0 + i) for i in range(10)]
        pts = [sweep.EvalPoint(task, hw, EvalOptions(congestion="flow"))
               for hw in hws]
        solo = sweep.eval_sweep(pts, cache=False, devices="single")
        shard = sweep.eval_sweep(pts, cache=False, devices="sharded")
        for a, b in zip(solo, shard):
            assert a["latency"] == b["latency"]
            assert np.array_equal(a["t_in"], b["t_in"])
            assert np.array_equal(a["t_out"], b["t_out"])

        cfg = GAConfig(population=32, generations=3, seed=7)
        s1 = sweep.solve_grid(pts[:5], "latency", cfg, cache=False,
                              devices="single")
        s2 = sweep.solve_grid(pts[:5], "latency", cfg, cache=False,
                              devices="sharded")
        for a, b in zip(s1, s2):
            assert a.objective == b.objective
            assert np.array_equal(a.partition.Px, b.partition.Px)
            assert np.array_equal(a.history, b.history)
        print("SHARD-PARITY-OK")
    """)
    out = subprocess.run([sys.executable, "-c", script], env=env,
                         capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, out.stderr
    assert "SHARD-PARITY-OK" in out.stdout
