"""Flow-level NoP network simulator — reproduces the paper's Fig. 3
motivation study (done there with ASTRA-sim).

Model: a 2-D mesh of chiplets with dimension-ordered (row-first) XY
routing, plus a memory node attached to one or more chiplets through its
memory-interface link (capacity = memory bandwidth). All chiplets
concurrently pull a fixed message from memory; flows share links by
max-min fair allocation, advanced event-by-event until completion.

This reproduces the paper's three observations:
  * DRAM (low BW): the memory link is the bottleneck — doubling NoP
    bandwidth yields no improvement (Fig. 3a/d).
  * HBM (high BW): congestion moves onto the mesh links near the
    attachment point — latency scales linearly with NoP BW (Fig. 3b/d).
  * Central placement balances the mesh load (12 flows on the hottest
    corner link vs 8 centrally) — ≈1.5× over peripheral for HBM
    (paper: 1.53×, Fig. 3c/d).
"""
from __future__ import annotations

import dataclasses

import numpy as np

__all__ = ["MeshNet", "simulate_pull", "fig3_case"]

GB = 1e9


@dataclasses.dataclass
class Flow:
    dst: int
    bytes_left: float
    route: list[tuple[int, int]]   # list of directed link keys
    done_at: float | None = None


class MeshNet:
    """X×Y mesh + memory node (id = X*Y) attached to ``attach`` chiplets."""

    def __init__(self, X: int, Y: int, bw_nop: float, bw_mem: float,
                 attach: list[int]):
        self.X, self.Y = X, Y
        self.mem = X * Y
        self.attach = attach
        self.cap: dict[tuple[int, int], float] = {}
        for r in range(X):
            for c in range(Y):
                u = r * Y + c
                for (rr, cc) in ((r + 1, c), (r, c + 1)):
                    if rr < X and cc < Y:
                        v = rr * Y + cc
                        self.cap[(u, v)] = bw_nop
                        self.cap[(v, u)] = bw_nop
        # memory interface link(s): capacity = memory BW split across ports
        for a in attach:
            self.cap[(self.mem, a)] = bw_mem / len(attach)
            self.cap[(a, self.mem)] = bw_mem / len(attach)

    def node_rc(self, n: int) -> tuple[int, int]:
        return divmod(n, self.Y)

    def route(self, src: int, dst: int) -> list[tuple[int, int]]:
        """Memory → nearest attach chiplet → XY (row-dimension-first)."""
        links = []
        if src == self.mem:
            # enter through the attach chiplet closest to dst
            dr, dc = self.node_rc(dst)
            best = min(self.attach,
                       key=lambda a: abs(self.node_rc(a)[0] - dr)
                       + abs(self.node_rc(a)[1] - dc))
            links.append((self.mem, best))
            src = best
        r0, c0 = self.node_rc(src)
        r1, c1 = self.node_rc(dst)
        r, c = r0, c0
        while r != r1:
            nr = r + (1 if r1 > r else -1)
            links.append((r * self.Y + c, nr * self.Y + c))
            r = nr
        while c != c1:
            nc = c + (1 if c1 > c else -1)
            links.append((r * self.Y + c, r * self.Y + nc))
            c = nc
        return links


def _maxmin_rates(flows: list[Flow], cap: dict) -> dict[int, float]:
    """Classic progressive-filling max-min fair allocation."""
    active = {i for i, f in enumerate(flows) if f.bytes_left > 0}
    residual = dict(cap)
    on_link: dict[tuple[int, int], set[int]] = {}
    for i in active:
        for l in flows[i].route:
            on_link.setdefault(l, set()).add(i)
    rates: dict[int, float] = {}
    unfixed = set(active)
    while unfixed:
        best_share, best_link = None, None
        for l, users in on_link.items():
            live = users & unfixed
            if not live:
                continue
            share = residual[l] / len(live)
            if best_share is None or share < best_share:
                best_share, best_link = share, l
        if best_link is None:
            for i in unfixed:
                rates[i] = float("inf")
            break
        for i in on_link[best_link] & set(unfixed):
            rates[i] = best_share
            unfixed.discard(i)
            for l in flows[i].route:
                residual[l] -= best_share
        residual = {l: max(0.0, v) for l, v in residual.items()}
    return rates


def simulate_pull(net: MeshNet, message_bytes: float
                  ) -> dict[str, object]:
    """All chiplets pull ``message_bytes`` from memory concurrently."""
    flows = [Flow(d, message_bytes, net.route(net.mem, d))
             for d in range(net.X * net.Y)]
    t = 0.0
    link_bytes: dict[tuple[int, int], float] = {l: 0.0 for l in net.cap}
    guard = 0
    while any(f.bytes_left > 1e-6 for f in flows):
        guard += 1
        if guard > 10000:
            raise RuntimeError("simulation did not converge")
        rates = _maxmin_rates(flows, net.cap)
        # time to next completion
        dt = min(f.bytes_left / rates[i] for i, f in enumerate(flows)
                 if f.bytes_left > 1e-6 and rates.get(i, 0) > 0)
        for i, f in enumerate(flows):
            if f.bytes_left > 1e-6:
                moved = rates[i] * dt
                for l in f.route:
                    link_bytes[l] += min(moved, f.bytes_left)
                f.bytes_left = max(0.0, f.bytes_left - moved)
                if f.bytes_left <= 1e-6 and f.done_at is None:
                    f.done_at = t + dt
        t += dt
    util = {l: b / (net.cap[l] * t) if t > 0 else 0.0
            for l, b in link_bytes.items()}
    return {"latency": t, "link_bytes": link_bytes, "link_util": util,
            "flows": flows}


def fig3_case(memory: str = "hbm", placement: str = "peripheral",
              bw_nop: float = 60 * GB, message: float = 1 * GB,
              X: int = 4, Y: int = 4) -> dict[str, object]:
    """One cell of the paper's Fig. 3 study (4×4 mesh, 1 GB pulls,
    DRAM 60 GB/s / HBM 1024 GB/s)."""
    bw_mem = 1024 * GB if memory.lower() == "hbm" else 60 * GB
    if placement == "peripheral":
        attach = [0]
    elif placement == "central":
        attach = [1 * Y + 1]
    else:
        raise ValueError(placement)
    net = MeshNet(X, Y, bw_nop, bw_mem, attach)
    out = simulate_pull(net, message)
    out["memory"] = memory
    out["placement"] = placement
    out["bw_nop"] = bw_nop
    return out
