"""Per-architecture training policies shared by dryrun/roofline/perf
(import-safe: no jax device-state side effects)."""

# Microbatch accumulation per train cell (activation-memory fit on 16 GiB
# v5e HBM; the accumulation scan also gives XLA per-microbatch grad
# collectives to overlap — the Sec-5.4 pipelining analogue).
TRAIN_ACCUM = {
    "minicpm3-4b": 4,
    "internlm2-20b": 2,
    "mixtral-8x22b": 4,
    "deepseek-v2-236b": 8,
    "hubert-xlarge": 2,
}

# ≥100B models: bf16 first moment + bf16 grad accumulation (HBM fit);
# the 236B model additionally keeps the second moment in bf16 (2.36 TB of
# model state on a 4 TB pod — DESIGN.md §7 records the trade-off).
TRAIN_LOWMEM = {"deepseek-v2-236b", "mixtral-8x22b"}
TRAIN_V_BF16 = {"deepseek-v2-236b"}
