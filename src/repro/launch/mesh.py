"""Production meshes.

Single pod: (data=16, model=16) — a 16×16 TPU-v5e pod, 256 chips.
Multi-pod: (pod=2, data=16, model=16) — 512 chips; the "pod" axis is pure
data parallelism (DCN between pods carries only gradient reductions).

A FUNCTION, not a module constant — importing this module must never
touch jax device state (the dry-run sets XLA_FLAGS before first init).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_debug_mesh(n_devices: int | None = None, *, pod: bool = False):
    """Small mesh over the first ``n_devices`` host devices — used by
    tests, the train driver, and the sharded sweep fabric (DESIGN.md §15).

    Always carries the ``("data", "model")`` axes the launch-layer
    sharding rules (``launch/specs.py``) are written against (plus
    ``"pod"`` when ``pod=True`` applies). Shape resolution: ``pod=True``
    with ``n`` a multiple of 4 (and ≥ 8) gives the 3-axis
    ``(2, 2, n//4)`` pod mesh — the old code built that shape for ANY
    ``n ≥ 8`` and crashed whenever ``2·2·(n//4) != n`` (n=10, n=13, …);
    even ``n`` puts the factor of 2 on ``data`` — the old fallback gave
    n=2 the degenerate ``(1, 2)`` mesh whose dead ``data`` axis silently
    disabled data parallelism; odd ``n`` is ``(1, n)`` (a 2-way split
    does not exist).
    """
    devs = jax.devices()
    n = n_devices or len(devs)
    if n > len(devs):
        raise ValueError(f"make_debug_mesh: {n} devices requested but "
                         f"only {len(devs)} exist")
    devs = devs[:n]
    if pod and n >= 8 and n % 4 == 0:
        return jax.make_mesh((2, 2, n // 4), ("pod", "data", "model"),
                             devices=devs)
    d = 2 if n % 2 == 0 and n >= 2 else 1
    return jax.make_mesh((d, n // d), ("data", "model"), devices=devs)
