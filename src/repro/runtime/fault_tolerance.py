"""Fault tolerance for the training loop.

Mechanisms (all exercised by tests):
  * **Checkpoint/restart** — periodic async checkpoints + restore-latest on
    start; a crash (or preemption signal) loses at most ``ckpt_every``
    steps. Data pipeline is step-addressable, so resume is deterministic.
  * **Bad-step rejection** — non-finite loss or gradient norm skips the
    optimizer update (keeps the previous state) and counts the incident;
    repeated incidents trigger restore-from-checkpoint.
  * **Retry with restore** — transient execution errors re-run the step;
    persistent ones restore the last checkpoint and continue.
  * **Straggler monitoring** — per-step wall-time EWMA; steps slower than
    ``threshold ×`` EWMA are flagged through a callback (at fleet scale
    the callback reschedules the slow host; here it feeds metrics/logs).
"""
from __future__ import annotations

import dataclasses
import math
import time
from typing import Callable

import numpy as np


class StragglerMonitor:
    """Per-step wall-time EWMA with a robust cold start.

    Cold-start contract (pinned by ``test_straggler_cold_start``):

    * The first observation can never be flagged at observe time — there
      is no baseline yet — and it seeds the EWMA *provisionally*.
    * If the next observation reveals the seed itself was the outlier
      (seed > ``threshold ×`` the new observation — the classic
      jit-compile-on-step-0 case), the seed is flagged retroactively and
      the EWMA re-seeds from the steady observation. The old behavior
      folded the outlier into the baseline permanently, masking every
      later straggler until the EWMA decayed.
    * Flagged observations are never folded into the baseline.
    """

    def __init__(self, threshold: float = 2.0, alpha: float = 0.1):
        self.threshold = threshold
        self.alpha = alpha
        self.ewma: float | None = None
        self.flagged: list[tuple[int, float]] = []
        self._seed: tuple[int, float] | None = None

    def observe(self, step: int, dt: float) -> bool:
        if self.ewma is None:
            self.ewma = dt
            self._seed = (step, dt)      # provisional: step 0 never flags
            return False
        if self._seed is not None:
            if self.ewma > self.threshold * dt:
                # The seed was the outlier, not this step: flag it
                # retroactively and rebase on the steady observation.
                self.flagged.append(self._seed)
                self.ewma = dt
                self._seed = (step, dt)
                return False
            self._seed = None            # seed confirmed by a peer
        is_straggler = False
        if dt > self.threshold * self.ewma:
            self.flagged.append((step, dt))
            is_straggler = True
            # do not fold outliers into the baseline estimate
        else:
            self.ewma = (1 - self.alpha) * self.ewma + self.alpha * dt
        return is_straggler


@dataclasses.dataclass
class LoopReport:
    steps_run: int = 0
    bad_steps: int = 0
    retries: int = 0
    restores: int = 0
    stragglers: int = 0
    losses: list = dataclasses.field(default_factory=list)


class FaultTolerantLoop:
    def __init__(self, step_fn: Callable, pipeline, checkpointer=None,
                 ckpt_every: int = 50, max_retries: int = 2,
                 max_bad_steps: int = 5,
                 straggler: StragglerMonitor | None = None,
                 log: Callable[[str], None] = print):
        self.step_fn = step_fn
        self.pipeline = pipeline
        self.ckpt = checkpointer
        self.ckpt_every = ckpt_every
        self.max_retries = max_retries
        self.max_bad_steps = max_bad_steps
        self.monitor = straggler or StragglerMonitor()
        self.log = log

    def _finite(self, metrics) -> bool:
        loss = float(metrics.get("loss", math.nan))
        gn = float(metrics.get("grad_norm", 0.0))
        return math.isfinite(loss) and math.isfinite(gn)

    def run(self, state, start_step: int, n_steps: int) -> tuple:
        report = LoopReport()
        bad_streak = 0
        step = start_step
        last_good = state
        while step < start_step + n_steps:
            batch = self.pipeline.at(step)
            t0 = time.perf_counter()
            try:
                new_state, metrics = self.step_fn(state, batch)
            except Exception as e:       # transient executor failure
                report.retries += 1
                self.log(f"[ft] step {step}: error {e!r}; retrying")
                if report.retries > self.max_retries:
                    if self.ckpt is not None and self.ckpt.latest() is not None:
                        self.log("[ft] restoring from checkpoint")
                        state, _ = self.ckpt.restore()
                        report.restores += 1
                    report.retries = 0
                continue
            dt = time.perf_counter() - t0
            if self.monitor.observe(step, dt):
                report.stragglers += 1
                self.log(f"[ft] step {step}: straggler ({dt:.3f}s vs "
                         f"ewma {self.monitor.ewma:.3f}s)")
            if not self._finite(metrics):
                report.bad_steps += 1
                bad_streak += 1
                self.log(f"[ft] step {step}: non-finite loss/grad — "
                         f"rejected")
                if bad_streak > self.max_bad_steps:
                    self.log("[ft] too many bad steps; restoring")
                    if self.ckpt is not None and self.ckpt.latest() is not None:
                        restored, rs = self.ckpt.restore()
                        state = restored
                        report.restores += 1
                    bad_streak = 0
                step += 1            # skip the poisoned batch
                continue
            bad_streak = 0
            state = new_state
            last_good = state
            report.losses.append(float(metrics["loss"]))
            report.steps_run += 1
            if self.ckpt is not None and (step + 1) % self.ckpt_every == 0:
                self.ckpt.save(state, step + 1, background=True)
            step += 1
        if self.ckpt is not None:
            self.ckpt.save(last_good, step, background=False)
        return state, report
