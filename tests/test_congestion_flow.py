"""``congestion="flow"`` evaluator mode (DESIGN.md §11): backend parity,
sweep round-trips, cache keying on the congestion axis, and GA solves
under simulated contention."""
import numpy as np
import pytest

from repro.core import (EvalOptions, Evaluator, GemmOp, Task, make_hw,
                        sweep, uniform_partition)
from repro.core.ga import GAConfig


def toy_task(n=3):
    ops = [GemmOp("g0", M=512, K=256, N=512)]
    for i in range(1, n):
        ops.append(GemmOp(f"g{i}", M=512, K=ops[-1].N, N=512,
                          chained=True, sync=(i == 1)))
    return Task(f"flowtoy{n}", ops)


@pytest.fixture(autouse=True)
def _fresh_cache():
    sweep.clear_cache()
    yield
    sweep.clear_cache()


def test_bad_congestion_rejected():
    with pytest.raises(ValueError):
        EvalOptions(congestion="astral")
    with pytest.raises(ValueError):
        Evaluator(toy_task(), make_hw("A", 4), congestion="astral")


def test_ctor_override_merges_into_options():
    ev = Evaluator(toy_task(), make_hw("A", 4),
                   EvalOptions(redistribution=True), congestion="flow")
    assert ev.opts.congestion == "flow"
    assert ev.opts.redistribution is True


@pytest.mark.parametrize("t", list("ABCD"))
def test_flow_mode_backend_parity(t):
    """numpy reference vs jax traced netsim, all packaging types."""
    task = toy_task()
    hw = make_hw(t, 4, "hbm", diagonal_links=(t == "A"))
    part = uniform_partition(task, 4, 4)
    opts = EvalOptions(redistribution=True, async_exec=True,
                       congestion="flow")
    rn = Evaluator(task, hw, opts, backend="numpy").evaluate(part)
    rj = Evaluator(task, hw, opts, backend="jax").evaluate(part)
    assert rj.latency == pytest.approx(rn.latency, rel=1e-9)
    assert rj.energy == pytest.approx(rn.energy, rel=1e-9)
    np.testing.assert_allclose(rj.t_in, rn.t_in, rtol=1e-9)
    np.testing.assert_allclose(rj.t_out, rn.t_out, rtol=1e-9)


def test_flow_mode_batch_parity():
    task = toy_task(2)
    hw = make_hw("A", 4, "hbm")
    opts = EvalOptions(congestion="flow")
    rng = np.random.default_rng(0)
    base = uniform_partition(task, 4, 4)
    P = 4
    Px = np.repeat(base.Px[None], P, 0).astype(float)
    Py = np.repeat(base.Py[None], P, 0).astype(float)
    co = rng.integers(0, 4, (P, 2))
    rd = np.zeros((P, 2))
    a = Evaluator(task, hw, opts, backend="numpy").evaluate_batch(
        Px, Py, co, rd)
    b = Evaluator(task, hw, opts, backend="jax").evaluate_batch(
        Px, Py, co, rd)
    for k in a:
        np.testing.assert_allclose(a[k], b[k], rtol=1e-9, err_msg=k)


def test_flow_differs_from_regime_and_energy_matches():
    """The two congestion models must disagree on latency for a congested
    HBM mesh (else "flow" is a no-op) while agreeing on energy — the
    byte×hop accounting is congestion-independent."""
    task = toy_task()
    hw = make_hw("A", 4, "hbm")
    part = uniform_partition(task, 4, 4)
    r = Evaluator(task, hw, congestion="regime").evaluate(part)
    f = Evaluator(task, hw, congestion="flow").evaluate(part)
    assert f.latency != pytest.approx(r.latency, rel=1e-6)
    assert f.energy == pytest.approx(r.energy, rel=1e-12)


def test_flow_equals_regime_on_type_c():
    """Type C stacks memory on every chiplet: no data touches the mesh,
    so the flow simulation must collapse to the closed-form off-chip
    terms — flow == regime exactly. Pins the §11 accounting split
    (per-entrance multicast off-chip term + mesh-only simulated flows);
    per-chiplet port pulls would break this identity."""
    task = toy_task()
    hw = make_hw("C", 4, "hbm")
    part = uniform_partition(task, 4, 4)
    for backend in ("numpy", "jax"):
        r = Evaluator(task, hw, congestion="regime",
                      backend=backend).evaluate(part)
        f = Evaluator(task, hw, congestion="flow",
                      backend=backend).evaluate(part)
        assert f.latency == pytest.approx(r.latency, rel=1e-12)
        np.testing.assert_allclose(f.t_in, r.t_in, rtol=1e-12)
        np.testing.assert_allclose(f.t_out, r.t_out, rtol=1e-12)


def test_eval_sweep_congestion_axis_round_trip():
    """EvalPoints on a congestion axis batch, cache per mode, and match
    the direct evaluator."""
    task = toy_task()
    hw = make_hw("A", 4, "hbm")
    pts = [sweep.EvalPoint(task, hw, EvalOptions(congestion=c))
           for c in ("regime", "flow")]
    recs = sweep.eval_sweep(pts, backend="jax")
    assert sweep.cache_stats() == {"hits": 0, "misses": 2}
    for pt, rec in zip(pts, recs):
        ref = Evaluator(task, hw, pt.options).evaluate(
            uniform_partition(task, 4, 4))
        assert rec["latency"] == pytest.approx(ref.latency, rel=1e-9)
    # repeat hits the cache, keyed on the congestion axis
    again = sweep.eval_sweep(pts, backend="jax")
    assert sweep.cache_stats() == {"hits": 2, "misses": 2}
    assert again[0]["latency"] != again[1]["latency"]


def test_solve_grid_under_flow_congestion():
    """GA searches optimize under simulated contention (tiny budget) and
    cache under the flow-keyed fingerprint."""
    task = toy_task(2)
    opts = EvalOptions(redistribution=True, async_exec=True,
                       congestion="flow")
    cfg = GAConfig(generations=2, population=8, patience=2, seed=0)
    pts = [sweep.EvalPoint(task, make_hw("A", 2, "hbm"), opts)]
    recs = sweep.solve_grid(pts, "latency", cfg, backend="jax")
    assert np.isfinite(recs[0].objective) and recs[0].objective > 0
    assert sweep.cache_stats()["misses"] >= 1
    again = sweep.solve_grid(pts, "latency", cfg, backend="jax")
    assert sweep.cache_stats()["hits"] >= 1
    assert again[0].objective == recs[0].objective
