"""Training driver with fault tolerance.

CPU example (reduced config, debug mesh):
    PYTHONPATH=src python -m repro.launch.train --arch smollm-360m \
        --reduced --steps 50 --batch 8 --seq 128 --ckpt /tmp/ckpt

On a real cluster the same entry point runs with --mesh single|multi and
the full config; ``--restore auto`` resumes from the latest checkpoint
(crash-restart semantics).
"""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp
import numpy as np

from ..checkpoint import Checkpointer
from ..configs import SHAPE_DEFS, get_config
from ..data.pipeline import make_pipeline
from ..models import init_model
from ..runtime import FaultTolerantLoop, StragglerMonitor
from ..sharding.logical import use_rules
from ..sharding.partition_specs import (activation_rules, data_specs,
                                        param_shardings)
from ..train import adamw, cosine_schedule
from ..train.train_step import init_train_state, make_train_step
from .mesh import make_debug_mesh, make_production_mesh


def build(args):
    cfg = get_config(args.arch, reduced=args.reduced)
    if args.mesh == "debug":
        mesh = make_debug_mesh()
    else:
        mesh = make_production_mesh(multi_pod=args.mesh == "multi")
    shape_def = dict(seq_len=args.seq, global_batch=args.batch,
                     kind="train")
    opt = adamw(lr=cosine_schedule(args.lr, args.warmup, args.steps),
                weight_decay=0.1)
    step_fn = make_train_step(cfg, opt, accum_steps=args.accum)
    rules = activation_rules(mesh, shard_residual=not args.reduced)
    pipeline = make_pipeline(cfg, shape_def, seed=args.seed)
    return cfg, mesh, opt, step_fn, rules, pipeline


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-360m")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--mesh", default="debug",
                    choices=["debug", "single", "multi"])
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--warmup", type=int, default=20)
    ap.add_argument("--accum", type=int, default=1)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--restore", default=None, choices=[None, "auto"])
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args(argv)

    cfg, mesh, opt, step_fn, rules, pipeline = build(args)
    print(f"[train] {cfg.name} on mesh {dict(mesh.shape)} "
          f"({mesh.size} devices)")

    with use_rules(mesh, rules):
        params = init_model(cfg, jax.random.PRNGKey(args.seed))
        p_sh = param_shardings(jax.eval_shape(lambda: params), mesh)
        params = jax.tree.map(jax.device_put, params, p_sh)
        state = init_train_state(params, opt)
        jit_step = jax.jit(step_fn, donate_argnums=(0,))

        ckpt = Checkpointer(args.ckpt) if args.ckpt else None
        start = 0
        if ckpt and args.restore == "auto" and ckpt.latest() is not None:
            state, start = ckpt.restore()
            state = jax.tree.map(jnp.asarray, state)
            print(f"[train] restored step {start}")

        losses = []

        def logging_step(st, batch):
            st, metrics = jit_step(st, batch)
            metrics = {k: float(v) for k, v in metrics.items()}
            losses.append(metrics["loss"])
            n = len(losses)
            if n % args.log_every == 0:
                print(f"  step {start + n:>5}  loss "
                      f"{np.mean(losses[-args.log_every:]):.4f}  "
                      f"gnorm {metrics['grad_norm']:.3f}")
            return st, metrics

        loop = FaultTolerantLoop(logging_step, pipeline, ckpt,
                                 ckpt_every=args.ckpt_every,
                                 straggler=StragglerMonitor())
        state, report = loop.run(state, start, args.steps)
    print(f"[train] done: {report.steps_run} steps, "
          f"{report.bad_steps} rejected, {report.stragglers} stragglers, "
          f"final loss {report.losses[-1]:.4f} "
          f"(first {report.losses[0]:.4f})")
    return report


if __name__ == "__main__":
    main()
