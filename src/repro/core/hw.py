"""Hardware model for MCM (multi-chip-module) systems — paper Sec. 4.1/4.2.1.

Defines the four packaging types (Fig. 2/4), the Table-2 energy/bandwidth
constants, and the chiplet-grid topology: per-chiplet local indices (x, y)
relative to the nearest "global chiplet" (memory entrance), hop-count
matrices for every communication case in Sec. 4.3 (including the diagonal
link strategy of Sec. 5.1), and entrance link counts used by the collection
equation (eq. 8).

Everything here is plain numpy, computed once per (HWConfig) and then
consumed as constants by the jax-vectorized evaluator.
"""
from __future__ import annotations

import dataclasses
import enum
from functools import cached_property

import numpy as np

__all__ = [
    "MCMType",
    "HWConfig",
    "Topology",
    "TABLE2",
    "make_hw",
]


class MCMType(str, enum.Enum):
    """Packaging types from Fig. 2 — position of main memory vs chiplets.

    A: 2.5D, single memory stack at a corner (SIMBA / Manticore).
    B: 2.5D, memory stacks distributed along the left+right edges (MTIA).
    C: 3D, memory stacked on top of every chiplet.
    D: hybrid of B and C — edge stacks plus 3D memory on the interior quad
       (Chiplet-Gym-style); memory distance is near-uniform.
    """

    A = "A"
    B = "B"
    C = "C"
    D = "D"


#: Table 2 — MCMComm system configurations. Bandwidths in bytes/s, energies
#: in Joules/bit (pJ converted), MAC energy in Joules/cycle.
TABLE2 = {
    "bw_hbm": 1000e9,          # 1000 GB/s
    "bw_dram": 60e9,           # 60 GB/s
    "bw_nop": 60e9,            # 60 GB/s per NoP link
    "e_nop_bit_hop": 1.285e-12,
    "e_dram_bit": 14.8e-12,
    "e_hbm_bit": 4.11e-12,
    "e_sram_bit": 0.28e-12,
    "e_mac_cycle": 4.6e-12,
    "freq_hz": 1.0e9,          # 1 GHz chiplet clock (SCALE-Sim default class)
}


@dataclasses.dataclass(frozen=True)
class HWConfig:
    """``HW = {BW_nop, BW_mem, X, Y, R, C, type}`` — paper eq. in Sec 4.2.1.

    ``bw_mem`` is the *total* off-chip bandwidth of the package; it is split
    evenly across memory entrances for types B/C/D so that packaging types
    are iso-bandwidth comparable (the paper's Fig. 3(c) experiment keeps a
    single memory node and moves it; ``n_mem_nodes=1`` reproduces that).
    """

    bw_nop: float = TABLE2["bw_nop"]
    bw_mem: float = TABLE2["bw_hbm"]
    X: int = 4
    Y: int = 4
    R: int = 16
    C: int = 16
    mcm_type: MCMType = MCMType.A
    diagonal_links: bool = False
    freq_hz: float = TABLE2["freq_hz"]
    bytes_per_elem: int = 1            # int8 edge-inference datapath
    # Energy constants (overridable for sensitivity studies).
    e_nop_bit_hop: float = TABLE2["e_nop_bit_hop"]
    e_mem_bit: float = TABLE2["e_hbm_bit"]
    e_sram_bit: float = TABLE2["e_sram_bit"]
    e_mac_cycle: float = TABLE2["e_mac_cycle"]

    def __post_init__(self):
        if self.X < 1 or self.Y < 1:
            raise ValueError("grid must be at least 1x1")
        if self.R < 1 or self.C < 1:
            raise ValueError("systolic array must be at least 1x1")

    @property
    def n_chiplets(self) -> int:
        return self.X * self.Y

    @cached_property
    def topology(self) -> "Topology":
        return Topology(self)

    def replace(self, **kw) -> "HWConfig":
        return dataclasses.replace(self, **kw)


def _entrances(hw: HWConfig) -> list[tuple[int, int, str]]:
    """Memory entrance chiplets as (gx, gy, kind) with kind in
    {"corner", "edge", "3d"}."""
    X, Y = hw.X, hw.Y
    t = hw.mcm_type
    if t == MCMType.A:
        return [(0, 0, "corner")]
    if t == MCMType.B:
        # Memory stacks on left and right edges, one per row per side.
        out = []
        for gx in range(X):
            out.append((gx, 0, "edge"))
            if Y > 1:
                out.append((gx, Y - 1, "edge"))
        return out
    if t == MCMType.C:
        return [(gx, gy, "3d") for gx in range(X) for gy in range(Y)]
    if t == MCMType.D:
        # Type B edges + 3D stacks on the interior quad.
        out = []
        for gx in range(X):
            out.append((gx, 0, "edge"))
            if Y > 1:
                out.append((gx, Y - 1, "edge"))
        x0, x1 = (X - 1) // 2, X // 2
        y0, y1 = (Y - 1) // 2, Y // 2
        for gx in {x0, x1}:
            for gy in {y0, y1}:
                if 0 < gy < Y - 1 or Y <= 2:
                    out.append((gx, gy, "3d"))
        return out
    raise ValueError(f"unknown MCM type {t}")


def _n_mesh_links(gx: int, gy: int, X: int, Y: int, diagonal: bool) -> int:
    """Number of NoP links incident to chiplet (gx, gy) in an X*Y mesh.

    Diagonal links (Sec. 5.1) add one diagonal neighbour toward the grid
    interior — a corner global chiplet goes from 2 to 3 entrance links,
    the paper's "50% more bandwidth on the bottleneck communication".
    """
    n = 0
    n += 1 if gx > 0 else 0
    n += 1 if gx < X - 1 else 0
    n += 1 if gy > 0 else 0
    n += 1 if gy < Y - 1 else 0
    if diagonal:
        # One diagonal link per chiplet toward the interior diagonal mate.
        if (gx < X - 1 and gy < Y - 1) or (gx > 0 and gy > 0):
            n += 1
    return n


class Topology:
    """Precomputed per-chiplet indexing and hop matrices for one HWConfig.

    Arrays are indexed [gx, gy] over the *global* grid. Chiplets are grouped
    by their nearest memory entrance; within a group, (x, y) are the local
    indices of Sec. 4.2.1 ("rows and columns away from the global chiplet")
    and (Xg, Yg) the group extents that replace the global X, Y in the hop
    equations (for type A the group is the whole grid, so they coincide).
    """

    def __init__(self, hw: HWConfig):
        self.hw = hw
        X, Y = hw.X, hw.Y
        ents = _entrances(hw)
        self.entrances = ents
        self.n_entrances = len(ents)
        gx = np.arange(X)[:, None] * np.ones((1, Y), dtype=int)
        gy = np.ones((X, 1), dtype=int) * np.arange(Y)[None, :]

        # Assign each chiplet to its nearest entrance (manhattan), tie-break
        # by entrance order (deterministic).
        dists = np.stack(
            [np.abs(gx - ex) + np.abs(gy - ey) for ex, ey, _ in ents], axis=0
        )
        self.entrance_id = np.argmin(dists, axis=0)  # [X, Y]
        ex = np.array([e[0] for e in ents])
        ey = np.array([e[1] for e in ents])
        self.x_local = np.abs(gx - ex[self.entrance_id])  # [X, Y]
        self.y_local = np.abs(gy - ey[self.entrance_id])

        # Group extents: max local index + 1 within each group.
        self.Xg = np.ones((X, Y), dtype=int)
        self.Yg = np.ones((X, Y), dtype=int)
        for e in range(self.n_entrances):
            m = self.entrance_id == e
            if m.any():
                self.Xg[m] = int(self.x_local[m].max()) + 1
                self.Yg[m] = int(self.y_local[m].max()) + 1

        # Entrance link counts (for eq. 8 collection bandwidth). The
        # entrance chiplet's own data never crosses the NoP (it sits on the
        # off-chip port / 3D via), so collection counts only non-entrance
        # bytes; the links are the mesh links incident to the entrance.
        kinds = [e[2] for e in ents]
        self.entrance_links = np.array(
            [
                _n_mesh_links(exi, eyi, X, Y, hw.diagonal_links)
                for (exi, eyi, k) in ents
            ]
        )
        # One-hot mask of entrance positions per group.
        self.entrance_pos = np.zeros((self.n_entrances, X, Y), dtype=bool)
        for i, (exi, eyi, _) in enumerate(ents):
            self.entrance_pos[i, exi, eyi] = True
        self.entrance_is_3d = np.array([k == "3d" for k in kinds])
        # Per-chiplet: is its entrance a 3D (zero-hop) stack?
        self.is_3d = self.entrance_is_3d[self.entrance_id]

        # Per-entrance memory bandwidth share (iso-total-bandwidth).
        self.bw_mem_per_entrance = hw.bw_mem / self.n_entrances

        # Chiplets per entrance group (for collection-link sharing).
        self.group_size = np.bincount(
            self.entrance_id.ravel(), minlength=self.n_entrances
        )

        self._build_hop_matrices()

    # ----------------------------------------------------------------- hops
    def _build_hop_matrices(self):
        hw = self.hw
        x, y = self.x_local, self.y_local
        Xg, Yg = self.Xg, self.Yg

        # Case 1 (low off-chip BW, eq. 10): links are free when data
        # arrives, minimal path.
        self.hops_low = x + y

        # Case 2.1 (high BW, shared data): send to target row/col first
        # (congested first column/row), farthest-first ordering adds the
        # waiting term. Row-shared (eq. 11): X + y. Col-shared (eq. 12): Y+x.
        h_row = Xg + y
        h_col = Yg + x
        if hw.diagonal_links:
            # Sec 5.1.1: diagonal alternative — wait (X - x), then
            # min(x, y) diagonal hops + |x - y| straight hops
            #   = X - x + max(x, y). The two strategies use disjoint links,
            # so each chiplet takes the min.
            h_row = np.minimum(h_row, Xg - x + np.maximum(x, y))
            h_col = np.minimum(h_col, Yg - y + np.maximum(x, y))
        self.hops_row_shared = h_row
        self.hops_col_shared = h_col

        # 3D-stacked chiplets read memory directly: zero NoP hops.
        for a in ("hops_low", "hops_row_shared", "hops_col_shared"):
            m = getattr(self, a).copy()
            m[self.is_3d & (self.x_local == 0) & (self.y_local == 0)] = 0
            setattr(self, a, m)

        # Collection (eq. 8) effective entrance link bandwidth per group —
        # number of NoP links into the entrance chiplet; 3D entrances
        # collect at memory bandwidth directly (no NoP bottleneck).
        self.collect_links = np.maximum(self.entrance_links, 0)

    # ------------------------------------------------------------- helpers
    def describe(self) -> str:
        hw = self.hw
        lines = [
            f"MCM type {hw.mcm_type.value}: {hw.X}x{hw.Y} chiplets, "
            f"{hw.R}x{hw.C} systolic, NoP {hw.bw_nop/1e9:.0f} GB/s, "
            f"mem {hw.bw_mem/1e9:.0f} GB/s over {self.n_entrances} "
            f"entrance(s), diagonal={hw.diagonal_links}",
            f"entrance links: {self.entrance_links.tolist()}",
        ]
        return "\n".join(lines)


def make_hw(
    mcm_type: str | MCMType = "A",
    grid: int | tuple[int, int] = 4,
    memory: str = "hbm",
    diagonal_links: bool = False,
    **kw,
) -> HWConfig:
    """Convenience constructor: ``make_hw("A", 4, "hbm")``."""
    if isinstance(grid, int):
        grid = (grid, grid)
    bw_mem = TABLE2["bw_hbm"] if memory.lower() == "hbm" else TABLE2["bw_dram"]
    e_mem = TABLE2["e_hbm_bit"] if memory.lower() == "hbm" else TABLE2["e_dram_bit"]
    return HWConfig(
        X=grid[0],
        Y=grid[1],
        mcm_type=MCMType(mcm_type),
        bw_mem=bw_mem,
        e_mem_bit=e_mem,
        diagonal_links=diagonal_links,
        **kw,
    )
