"""Batched serving example: prefill + decode with KV caches through the
ServeEngine, on a reduced gemma2 (local/global attention + softcaps).

    PYTHONPATH=src python examples/serve_batched.py
"""
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.models import init_model
from repro.serve import ServeEngine


def main():
    cfg = get_config("gemma2-2b", reduced=True)
    params = init_model(cfg, jax.random.PRNGKey(0))
    engine = ServeEngine(cfg, params, batch_size=4, capacity=128)

    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab_size, rng.integers(4, 20))
               .astype(np.int32) for _ in range(8)]
    t0 = time.perf_counter()
    outs = engine.generate(prompts, max_new_tokens=16)
    dt = time.perf_counter() - t0
    tok = sum(len(o) for o in outs)
    print(f"[serve] {len(prompts)} requests, {tok} new tokens in "
          f"{dt:.2f}s")
    for i, o in enumerate(outs[:3]):
        print(f"  req{i} ({len(prompts[i])} prompt toks): {o}")
    assert all(len(o) == 16 for o in outs)


if __name__ == "__main__":
    main()
