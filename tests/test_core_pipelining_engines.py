"""Pipelining engine tests (DESIGN.md §13): serial python heapq SGS vs
the vectorized frontier SGS (numpy host reference + batched jax), the
MILP refinement's feasibility contract, scheduler invariants as
hypothesis properties, and the §9 solo==batched cache invariant for
``sweep.pipeline_sweep``."""
import numpy as np
import pytest

from _hypothesis_compat import given, settings, st
from repro.core import sweep
from repro.core.pipelining import (PipelineConfig, build_jobs,
                                   list_schedule, milp_schedule,
                                   pipeline_batch,
                                   resolve_auto_pipeline_engine,
                                   sequential_makespan,
                                   vectorized_schedule)
from repro.core.sweep import PipelinePoint


def _random_segments(rng, n=None, p_zero=0.3):
    n = int(rng.integers(1, 6)) if n is None else n
    segs = []
    for i in range(n):
        durs = np.where(rng.random(3) < p_zero, 0.0, rng.uniform(0.0, 5.0, 3))
        segs.append((f"op{i}", float(durs[0]), float(durs[1]),
                     float(durs[2])))
    return segs


def _serial_starts_array(segments, batch):
    jobs = build_jobs(segments, batch)
    ms, starts = list_schedule(jobs)
    L = 3 * len(segments)
    arr = np.array([[starts[s * L + p] for p in range(L)]
                    for s in range(batch)])
    return ms, arr


def _check_valid(jobs, starts, makespan):
    byid = {j.jid: j for j in jobs}
    for j in jobs:
        for p in j.preds:
            assert starts[j.jid] >= starts[p] + byid[p].dur - 1e-9
    for res in ("comm", "comp"):
        ivals = sorted((starts[j.jid], starts[j.jid] + j.dur)
                       for j in jobs if j.resource == res and j.dur > 0)
        for (s1, e1), (s2, e2) in zip(ivals, ivals[1:]):
            assert s2 >= e1 - 1e-9
    assert makespan >= max(starts[j.jid] + j.dur for j in jobs) - 1e-9


# ------------------------------------------------------ engine parity
def test_python_vs_vectorized_exact():
    """The §13 contract is *bit-identical* makespans and starts — the
    vectorized frontier step performs the serial SGS's exact pop
    sequence and arithmetic, on both backends."""
    rng = np.random.default_rng(0)
    for _ in range(30):
        segs = _random_segments(rng)
        batch = int(rng.integers(1, 7))
        ms, arr = _serial_starts_array(segs, batch)
        for backend in ("numpy", "jax"):
            msv, sv = vectorized_schedule(segs, batch, backend=backend)
            assert msv == ms
            assert np.array_equal(sv, arr)


def test_vectorized_engine_is_auto_default():
    assert resolve_auto_pipeline_engine("auto") == "vectorized"
    with pytest.raises(ValueError):
        resolve_auto_pipeline_engine("nonsense")
    rng = np.random.default_rng(1)
    segs = _random_segments(rng, n=3)
    r_auto = pipeline_batch(segs, 4)
    r_py = pipeline_batch(segs, 4, config=PipelineConfig(engine="python"))
    assert r_auto.engine == "vectorized" and r_py.engine == "python"
    assert r_auto.pipelined == r_py.pipelined


def test_single_step_chains_and_degenerate_batches():
    assert pipeline_batch([("a", 0.0, 0.0, 0.0)], 4).pipelined == 0.0
    segs = [("a", 1.0, 2.0, 3.0)]
    for b in (1, 2, 5):
        ms, _ = _serial_starts_array(segs, b)
        for backend in ("numpy", "jax"):
            assert vectorized_schedule(segs, b, backend=backend)[0] == ms


# ------------------------------------------- batched sweep invariants
def test_pipeline_sweep_solo_eq_batched_mixed_shapes():
    """One sweep over points of *different* (n_ops, batch) shapes must
    return, per point, exactly what a solo call returns (§9 cache
    invariant; shape groups compile separately but share nothing)."""
    rng = np.random.default_rng(2)
    pts = [PipelinePoint(_random_segments(rng, n=n), b)
           for n in (1, 3, 4) for b in (2, 5)]
    batched = sweep.pipeline_sweep(pts, cache=False)
    for pt, rec in zip(pts, batched):
        solo = sweep.pipeline_sweep([pt], cache=False)[0]
        ms, _ = _serial_starts_array(pt.segments, pt.batch)
        assert rec.pipelined == solo.pipelined == ms
        assert rec.sequential == sequential_makespan(pt.segments, pt.batch)


def test_pipeline_sweep_cache_and_config_isolation():
    sweep.clear_cache()
    try:
        rng = np.random.default_rng(3)
        pts = [PipelinePoint(_random_segments(rng, n=2), b) for b in (2, 3)]
        a = sweep.pipeline_sweep(pts)
        assert sweep.cache_stats() == {"hits": 0, "misses": len(pts)}
        b = sweep.pipeline_sweep(pts)
        assert sweep.cache_stats()["hits"] == len(pts)
        assert all(x.pipelined == y.pipelined for x, y in zip(a, b))
        # a different engine config is a different record family
        c = sweep.pipeline_sweep(pts, PipelineConfig(engine="python"))
        assert sweep.cache_stats()["misses"] == 2 * len(pts)
        assert all(x.pipelined == y.pipelined for x, y in zip(a, c))
        # numpy backend: same results, its own cache records
        d = sweep.pipeline_sweep(pts, backend="numpy")
        assert sweep.cache_stats()["misses"] == 3 * len(pts)
        assert all(x.pipelined == y.pipelined for x, y in zip(a, d))
    finally:
        sweep.clear_cache()


def test_pipeline_sweep_honors_config_backend(monkeypatch):
    """An explicit ``cfg.backend="numpy"`` must take the host path even
    though the sweep-level backend defaults to jax (the PipelineConfig
    contract)."""
    import repro.core.pipelining_jax as pjx

    def boom(*a, **k):
        raise AssertionError("jax path taken despite cfg.backend='numpy'")

    monkeypatch.setattr(pjx, "schedule_batch", boom)
    rng = np.random.default_rng(4)
    pt = PipelinePoint(_random_segments(rng, n=2), 3)
    rec = sweep.pipeline_sweep(
        [pt], PipelineConfig(engine="vectorized", backend="numpy"),
        cache=False)[0]
    ms, _ = _serial_starts_array(pt.segments, pt.batch)
    assert rec.pipelined == ms


def test_pipeline_sweep_milp_runs_per_point():
    segs = [("a", 1.0, 2.0, 1.0), ("b", 0.5, 1.0, 0.5)]
    pt = PipelinePoint(segs, 3)
    greedy, _ = list_schedule(build_jobs(segs, 3))
    rec = sweep.pipeline_sweep(
        [pt], PipelineConfig(engine="milp", n_buckets=24, time_limit=10),
        cache=False)[0]
    assert rec.engine == "milp"
    assert rec.pipelined <= greedy + 1e-9


# ---------------- scheduler invariants: seeded spot checks + hypothesis
# variant via the shim (the netsim-suite pattern — the properties still
# run when the optional `hypothesis` dev-dep is absent).
def _check_scheduler_invariants(seed):
    rng = np.random.default_rng(seed)
    segs = _random_segments(rng)
    batch = int(rng.integers(1, 6))
    jobs = build_jobs(segs, batch)
    ms, starts = list_schedule(jobs)
    if jobs:
        _check_valid(jobs, starts, ms)
    comm = sum(j.dur for j in jobs if j.resource == "comm")
    comp = sum(j.dur for j in jobs if j.resource == "comp")
    assert max(comm, comp) - 1e-9 <= ms
    assert ms <= sequential_makespan(segs, batch) + 1e-9
    ms_next, _ = list_schedule(build_jobs(segs, batch + 1))
    assert ms <= ms_next + 1e-9
    for backend in ("numpy", "jax"):
        assert vectorized_schedule(segs, batch, backend=backend)[0] == ms


@pytest.mark.parametrize("seed", range(12))
def test_scheduler_invariants_seeded(seed):
    """Schedule validity, busiest-resource lower bound, sequential upper
    bound, makespan monotone in batch, python==vectorized exact."""
    _check_scheduler_invariants(seed)


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 10_000))
def test_scheduler_invariants_property(seed):
    _check_scheduler_invariants(seed)


def _check_milp_hierarchy(seed):
    rng = np.random.default_rng(seed)
    segs = _random_segments(rng, n=int(rng.integers(1, 4)))
    batch = int(rng.integers(1, 4))
    jobs = build_jobs(segs, batch)
    greedy, _ = list_schedule(jobs)
    ms, starts = milp_schedule(jobs, n_buckets=16, time_limit=5)
    assert set(starts) == {j.jid for j in jobs}
    if jobs:
        _check_valid(jobs, starts, ms)
    assert ms <= greedy + 1e-9 <= sequential_makespan(segs, batch) + 2e-9


@pytest.mark.parametrize("seed", range(4))
def test_milp_leq_list_leq_sequential_seeded(seed):
    """The solver hierarchy of Sec. 5.4: the (re-simulated, feasible)
    MILP schedule never loses to the list schedule, which never loses to
    fully sequential execution — and the MILP starts cover every job."""
    _check_milp_hierarchy(seed)


@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 10_000))
def test_milp_leq_list_leq_sequential_property(seed):
    _check_milp_hierarchy(seed)
