"""Chunked RWKV-6 WKV in pure JAX — the XLA execution path.

Within a chunk of length Lc, decay products are exp of cumulative-log-decay
differences (≤ 0 ⇒ safe); the intra-chunk term is computed with an explicit
(i, j, channel) tensor over a small chunk (Lc ≤ 64 keeps it cheap), and the
state is carried across chunks with a scan. Matches :func:`..ref.wkv6_ref`
to f32 tolerance.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def wkv6_chunked(r, k, v, w, u, s0=None, chunk: int = 32):
    B, S, H, K = r.shape
    dtype_in = r.dtype
    r32, k32, v32 = (t.astype(jnp.float32) for t in (r, k, v))
    lw = jnp.log(jnp.clip(w.astype(jnp.float32), 1e-38, 1.0))  # (B,S,H,K) ≤0
    u32 = u.astype(jnp.float32)

    Lc = min(chunk, S)
    pad = (-S) % Lc
    if pad:
        r32 = jnp.pad(r32, ((0, 0), (0, pad), (0, 0), (0, 0)))
        k32 = jnp.pad(k32, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v32 = jnp.pad(v32, ((0, 0), (0, pad), (0, 0), (0, 0)))
        lw = jnp.pad(lw, ((0, 0), (0, pad), (0, 0), (0, 0)))
    nc = r32.shape[1] // Lc

    def to_chunks(t):
        return t.reshape(B, nc, Lc, H, K).swapaxes(0, 1)

    rc, kc, vc, lwc = map(to_chunks, (r32, k32, v32, lw))
    if s0 is None:
        s0 = jnp.zeros((B, H, K, K), jnp.float32)

    def chunk_step(s, inp):
        rk, kk, vk, lwk = inp                      # (B,Lc,H,K)
        cum = jnp.cumsum(lwk, axis=1)              # (B,Lc,H,K)
        # S_{i-1} sees decay Π_{p=j+1..i-1} w_p = exp(cum_{i-1} − cum_j);
        # shift cum to get cum_{i-1} with cum_{-1}=0.
        cum_im1 = jnp.pad(cum, ((0, 0), (1, 0), (0, 0), (0, 0)))[:, :-1]
        # intra-chunk: A[i,j] = Σ_c r_i[c]·exp(cum_{i-1}[c] − cum_j[c])·k_j[c]
        diff = cum_im1[:, :, None] - cum[:, None, :, :, :]   # (B,i,j,H,K)
        strict = jnp.tril(jnp.ones((Lc, Lc), bool), -1)
        A = jnp.einsum("bihk,bijhk,bjhk->bijh", rk,
                       jnp.where(strict[None, :, :, None, None],
                                 jnp.exp(diff), 0.0), kk)
        # bonus diagonal term: (r_i ∘ u ∘ k_i) · v_i
        diag = jnp.einsum("bihk,hk,bihk->bih", rk, u32, kk)
        y_intra = (jnp.einsum("bijh,bjhv->bihv", A, vk)
                   + diag[..., None] * vk)
        # inter-chunk: r_i ∘ exp(cum_{i-1}) · s
        y_inter = jnp.einsum("bihk,bhkv->bihv", rk * jnp.exp(cum_im1), s)
        # state update: s' = D(exp(cum_L)) s + Σ_j exp(cum_L − cum_j) k_j⊗v_j
        decay_end = jnp.exp(cum[:, -1:] - cum)               # (B,Lc,H,K)
        kv = jnp.einsum("bjhk,bjhv->bhkv", kk * decay_end, vk)
        s_new = jnp.exp(cum[:, -1])[..., None] * s + kv
        return s_new, y_intra + y_inter

    # remat each chunk (same rationale as the SSD scan: recompute the
    # (i, j, channel) decay tensor in backward rather than saving it).
    from ..calibrate import scan_unroll
    sT, ys = jax.lax.scan(
        jax.checkpoint(chunk_step,
                       policy=jax.checkpoint_policies.nothing_saveable),
        s0, (rc, kc, vc, lwc), unroll=scan_unroll())
    y = ys.swapaxes(0, 1).reshape(B, nc * Lc, H, K)[:, :S]
    return y.astype(dtype_in), sT


def wkv6_decode_step(s, r, k, v, w, u):
    """Single-token WKV step. r/k/v/w (B,H,K), u (H,K); s (B,H,K,K)."""
    r32, k32, v32 = (t.astype(jnp.float32) for t in (r, k, v))
    w32 = w.astype(jnp.float32)
    kv = k32[..., :, None] * v32[..., None, :]
    y = jnp.einsum("bhk,bhkv->bhv", r32,
                   s + u.astype(jnp.float32)[None, :, :, None] * kv)
    s_new = w32[..., :, None] * s + kv
    return y.astype(r.dtype), s_new
