"""JAX backend for the RCPSP pipeliner — batched list scheduling
(DESIGN.md §13).

The cross-sample pipelining DAG of Sec. 5.4 is *regular*: every sample of
a batch emits the same (in, comp, out) chain, so a whole instance is a
dense ``[batch, n_ops, 3]`` duration tensor and the serial heapq SGS of
:mod:`repro.core.pipelining` collapses into array form:

  * **Priorities** — a chain job's only successor is the next chain job,
    so the critical-path walk is a reversed cumulative sum, identical for
    every sample (:func:`repro.core.pipelining.chain_priorities`; computed
    on host so both backends compare bit-identical floats on ties).
  * **Ready set = per-sample frontier** — scheduling a job makes its
    chain successor ready immediately, so the heap always holds exactly
    one entry (the next unscheduled chain position) per unfinished
    sample. The SGS step is therefore an ``argmax`` of priority over the
    ``[batch]`` frontier vector (ties → smallest jid, the heap's
    tie-break), dispatched onto its unit resource — ``batch × 3n`` such
    steps driven by ``lax.fori_loop`` schedule the whole instance.
  * **Grids** — ``vmap`` over a leading grid axis batches every instance
    sharing (n_ops, batch) — whole (workload × batch × segment-variant)
    sweeps run through ONE compiled call per shape group
    (:func:`repro.core.sweep.pipeline_sweep` does the grouping); a solo
    call is the ``G=1`` case of the same executable, so solo == batched
    exactly (the §9 cache invariant).

Exactness: every arithmetic op (max, add) matches the serial engine
bit-for-bit — the contract is *bit-identical* makespans and start times,
stronger than the §8 evaluator backends' rtol-1e-9 parity
(``tests/test_core_pipelining_engines.py`` enforces it).

All entry points run under ``jax.experimental.enable_x64()`` (same
float64 rule and leak-containment scoping as
:mod:`repro.core.netsim_jax`).
"""
from __future__ import annotations

import functools

import numpy as np

import jax
import jax.numpy as jnp
from jax import lax

from .pipelining import chain_priorities

__all__ = ["schedule_batch", "sgs_instance", "chain_priorities_jnp"]


def chain_priorities_jnp(dur_flat):
    """Traced equivalent of :func:`repro.core.pipelining.chain_priorities`
    (reversed cumulative sum — a chain job's critical path). Used by
    callers that build priorities *inside* a jitted objective
    (:mod:`repro.core.cosearch`); :func:`schedule_batch` keeps computing
    them on host so the serial-engine bit-parity contract is pinned to
    one accumulation order."""
    return jnp.cumsum(dur_flat[::-1])[::-1]


@functools.lru_cache(maxsize=None)
def sgs_instance(L: int, B: int, with_starts: bool = True):
    """Traced single-instance SGS per (chain length, batch) signature:
    ``one(dur [L], prio [L])`` → ``(makespan, starts [B, L])``, or just
    the makespan with ``with_starts=False`` (skips the per-step start
    scatter — the form embedded in fused objectives such as the
    co-search fitness, DESIGN.md §16). Durations/priorities are data, so
    one instance serves every same-shape schedule; cached so wrappers
    (vmap/jit/shard_map) key on a stable function identity."""
    # Chain resource pattern (in, comp, out) per op: 0 = comm, 1 = comp.
    # Held as numpy and lifted per trace — the instance may be *built*
    # inside an enclosing trace (the co-search fused fitness), and a
    # cached closure over trace-born jnp arrays would leak tracers into
    # later traces.
    res_np = np.tile(np.array([0, 1, 0], dtype=np.int32), L // 3)
    base_np = np.arange(B, dtype=np.int32) * L

    def one(dur, prio):
        res = jnp.asarray(res_np)
        sample_base = jnp.asarray(base_np)

        def step(_, state):
            ptr, ready, free = state[:3]
            active = ptr < L
            pr = jnp.where(active, prio[jnp.minimum(ptr, L - 1)], -jnp.inf)
            # Highest-priority ready job; ties resolve to the smallest
            # jid (= sample*L + ptr), exactly like the serial heap.
            cand = jnp.where(active & (pr == jnp.max(pr)),
                             sample_base + ptr, B * L)
            s = jnp.argmin(cand)
            p = ptr[s]
            r = res[p]
            t0 = jnp.maximum(ready[s], free[r])
            t1 = t0 + dur[p]
            out = (ptr.at[s].add(1), ready.at[s].set(t1),
                   free.at[r].set(t1))
            if with_starts:
                out = out + (state[3].at[s, p].set(t0),)
            return out

        init = (jnp.zeros(B, dtype=jnp.int32),
                jnp.zeros(B, dtype=jnp.float64),
                jnp.zeros(2, dtype=jnp.float64))
        if with_starts:
            init = init + (jnp.zeros((B, L), dtype=jnp.float64),)
        state = lax.fori_loop(0, B * L, step, init)
        # Resource frees only ever ratchet up to the latest finish, so
        # the makespan is their max (0.0 when no job ran — serial init).
        if with_starts:
            return jnp.max(state[2]), state[3]
        return jnp.max(state[2])

    return one


@functools.lru_cache(maxsize=None)
def _sched_inner(L: int, B: int):
    """Unjitted ``vmap(instance)`` per (chain length, batch) signature —
    durations/priorities as data; doubles as the shard_map target of the
    sharded sweep fabric (DESIGN.md §15)."""
    return jax.vmap(sgs_instance(L, B))


@functools.lru_cache(maxsize=None)
def _sched_fn(L: int, B: int):
    """One compiled batched SGS per (chain length, batch) signature, so
    every same-shape grid point shares the executable."""
    return jax.jit(_sched_inner(L, B))


def schedule_batch(segments_grid: np.ndarray, batch: int,
                   devices: str = "single") -> dict[str, np.ndarray]:
    """Batched list scheduling: ``segments_grid [G, n, 3]`` per-op
    (t_in, t_comp, t_out) durations for ``G`` same-shape grid points →
    ``{"makespan": [G], "starts": [G, batch, 3n]}`` (``starts[g, s, p]``
    = start of sample ``s``'s p-th chain job, jid ``s*3n + p`` in
    :func:`repro.core.pipelining.build_jobs` order). One compiled call
    per (n, batch) signature covers the whole group; ``devices``
    (DESIGN.md §15) shards the grid axis across local devices with
    bit-identical schedules."""
    from . import sweep_shard

    seg = np.asarray(segments_grid, dtype=np.float64)
    G, n = seg.shape[0], seg.shape[1]
    L = 3 * n
    dur = np.maximum(seg.reshape(G, L) if L else np.zeros((G, 0)), 0.0)
    if L == 0 or batch == 0:
        return {"makespan": np.zeros(G), "starts": np.zeros((G, batch, L))}
    prio = np.stack([chain_priorities(dur[g]) for g in range(G)])
    with jax.experimental.enable_x64():
        args = (jnp.asarray(dur), jnp.asarray(prio))
        if sweep_shard.resolve_devices(devices, G) == "sharded":
            ms, starts = sweep_shard.sharded_grid_call(
                _sched_inner(L, int(batch)), args, (True, True), G)
        else:
            ms, starts = _sched_fn(L, int(batch))(*args)
        return {"makespan": np.asarray(ms), "starts": np.asarray(starts)}
