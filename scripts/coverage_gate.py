"""Coverage floor for the planner loop, report-only elsewhere.

Reads the ``coverage.json`` that ``make cov`` (pytest --cov) writes and
enforces a line-coverage floor ONLY on the modules the calibration /
validation loop rests on — ``src/repro/sharding/`` and
``src/repro/kernels/calibrate.py`` (DESIGN.md §17) — plus the
multi-tenant placement layer ``src/repro/core/multitenant.py``
(DESIGN.md §18). Every other package
is summarized for the log but never fails the build: the tier-1 suite
is the functional gate there, and a repo-wide floor would punish
unrelated PRs for dead branches in modules they never touched.

    PYTHONPATH=src python -m pytest -q --cov=repro \
        --cov-report=json:coverage.json
    python scripts/coverage_gate.py [coverage.json]
"""
from __future__ import annotations

import json
import os
import sys

#: repo-relative path fragments the floor applies to
FLOOR_PATHS = ("repro/sharding/", "repro/kernels/calibrate.py",
               "repro/core/multitenant.py")
FLOOR_PCT = 80.0


def _norm(path: str) -> str:
    return path.replace(os.sep, "/")


def gate(cov: dict) -> int:
    files = cov.get("files", {})
    if not files:
        print("coverage_gate: empty coverage report", file=sys.stderr)
        return 2

    floor_cov = floor_tot = 0
    by_pkg: dict[str, list[int]] = {}
    for path, rec in files.items():
        s = rec.get("summary", {})
        covered = int(s.get("covered_lines", 0))
        total = int(s.get("num_statements", 0))
        p = _norm(path)
        if any(frag in p for frag in FLOOR_PATHS):
            floor_cov += covered
            floor_tot += total
        # report-only rollup by package under src/repro/
        key = p.split("repro/", 1)[-1].split("/")[0] if "repro/" in p \
            else p
        agg = by_pkg.setdefault(key, [0, 0])
        agg[0] += covered
        agg[1] += total

    for pkg in sorted(by_pkg):
        c, t = by_pkg[pkg]
        if t:
            print(f"coverage_gate: {pkg:24s} {100.0 * c / t:6.1f}% "
                  f"({c}/{t})")

    if floor_tot == 0:
        print("coverage_gate: no floored files measured "
              f"({FLOOR_PATHS})", file=sys.stderr)
        return 2
    pct = 100.0 * floor_cov / floor_tot
    if pct < FLOOR_PCT:
        print(f"coverage_gate: FAIL — planner-loop coverage {pct:.1f}% "
              f"< floor {FLOOR_PCT}% over {FLOOR_PATHS}", file=sys.stderr)
        return 1
    print(f"coverage_gate: OK — planner-loop coverage {pct:.1f}% "
          f">= {FLOOR_PCT}% ({floor_cov}/{floor_tot} lines over "
          f"{len(FLOOR_PATHS)} path groups)")
    return 0


def main(argv=None) -> int:
    argv = sys.argv[1:] if argv is None else argv
    path = argv[0] if argv else "coverage.json"
    try:
        with open(path) as f:
            cov = json.load(f)
    except (OSError, ValueError) as e:
        print(f"coverage_gate: cannot read {path}: {e}", file=sys.stderr)
        return 2
    return gate(cov)


if __name__ == "__main__":
    raise SystemExit(main())
