"""mixtral-8x22b [moe]: 56L d_model=6144 48H (GQA kv=8) d_ff=16384
vocab=32768, 8 experts top-2, SWA window 4096 [arXiv:2401.04088; hf]."""
from ..models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="mixtral-8x22b", family="moe",
        n_layers=56, d_model=6144, d_ff=16384, vocab_size=32768,
        n_heads=48, n_kv_heads=8, d_head=128,
        n_experts=8, moe_top_k=2, moe_d_ff=16384,
        window=4096, act="silu", rope_theta=1e6,
        param_dtype="bfloat16",  # 141B: pure-bf16 params + f32 moments fit v5e HBM
    )


def reduced_config() -> ModelConfig:
    return config().replace(
        name="mixtral-smoke", n_layers=3, d_model=64, d_ff=128,
        vocab_size=256, n_heads=4, n_kv_heads=2, d_head=16,
        n_experts=4, moe_top_k=2, moe_d_ff=128, window=32,
        attn_chunk=32, remat=False)
