"""MCMComm-driven layout planning for the TPU runtime.

The paper's framework answers: *given a chain of GEMMs on a 2-D grid of
compute elements behind limited interconnect, how should work be
partitioned and which inter-op transfers should stay on-package?* A TPU
pod is exactly such a grid (DESIGN.md §3): mesh (data × model) ↔ chiplet
grid (X × Y), ICI ↔ NoP, HBM ↔ off-chip memory, and the choice
"redistribute on-package vs round-trip through memory" ↔ "reshard
activations with collectives vs spill/gather".

This planner:
  1. extracts the per-layer GEMM sequence of an architecture config,
  2. scores layout candidates with the paper's analytical evaluator
     parameterized with TPU-v5e constants (MXU 128×128, HBM 819 GB/s,
     ICI ≈ 50 GB/s/link),
  3. emits executable knobs — residual-stream sharding, microbatch
     accumulation (the Sec-5.4 pipelining analogue), redistribution mask
     (which chained pairs keep activations resident) — plus the
     *non-uniform-partition headroom* the paper's MIQP finds but XLA's
     equal-shard SPMD cannot realize (reported, not executed).
"""
from __future__ import annotations

import dataclasses

import numpy as np

from ..core.evaluator import EvalOptions, Evaluator
from ..core.ga import GAConfig, run_ga
from ..core.hw import HWConfig, MCMType
from ..core.workload import GemmOp, Task, uniform_partition

# TPU v5e constants (per chip).
V5E_PEAK_FLOPS = 197e12          # bf16
V5E_HBM_BW = 819e9               # bytes/s
V5E_ICI_BW = 50e9                # bytes/s per link
V5E_MXU = 128


def tpu_hw(mesh_shape: tuple[int, int], *, profile=None) -> HWConfig:
    """Model one pod as a type-C MCM (every chip has local HBM) with the
    ICI as the NoP. freq chosen so the eq.-7 systolic model reproduces the
    chip's peak matmul throughput: R·C·2·freq = peak FLOP/s.

    With ``profile`` (a :class:`~repro.kernels.calibrate.CalibratedHW`)
    the datasheet constants are replaced by the measured ones: each model
    chip delivers the microbenchmarked matmul throughput and memory rate,
    so planner predictions share a basis with dryrun cost analysis."""
    X, Y = mesh_shape
    hw = HWConfig(
        bw_nop=V5E_ICI_BW, bw_mem=V5E_HBM_BW * X * Y, X=X, Y=Y,
        R=V5E_MXU, C=V5E_MXU, mcm_type=MCMType.C,
        freq_hz=V5E_PEAK_FLOPS / (2 * V5E_MXU * V5E_MXU),
        bytes_per_elem=2)
    return profile.apply(hw) if profile is not None else hw


def arch_to_task(cfg, seq_len: int, batch: int, *, layers: int | None = None
                 ) -> Task:
    """Per-layer GEMM chain of an architecture (training forward)."""
    m = seq_len * batch
    D, F = cfg.d_model, cfg.d_ff
    ops: list[GemmOp] = []
    L = layers if layers is not None else cfg.n_layers

    def block(i: int):
        p = f"l{i}."
        if cfg.attn_type == "mla":
            r_kv = cfg.kv_lora_rank
            dk = cfg.qk_nope_dim + cfg.qk_rope_dim
            ops.append(GemmOp(p + "q", M=m, K=cfg.q_lora_rank or D,
                              N=cfg.n_heads * dk, chained=bool(ops)))
            ops.append(GemmOp(p + "kv_a", M=m, K=D,
                              N=r_kv + cfg.qk_rope_dim))
            ops.append(GemmOp(p + "attn", M=m * 1, K=r_kv + cfg.qk_rope_dim,
                              N=min(seq_len, 4096),
                              n_groups=cfg.n_heads, sync=True,
                              weight_bytes_scale=float(batch)))
            ops.append(GemmOp(p + "o", M=m,
                              K=cfg.n_heads * cfg.v_head_dim, N=D))
        elif cfg.attn_type == "gqa":
            H, Dh = cfg.n_heads, cfg.d_head
            ctx = min(seq_len, cfg.window or seq_len)
            ops.append(GemmOp(p + "qkv", M=m, K=D,
                              N=(H + 2 * cfg.n_kv_heads) * Dh,
                              chained=bool(ops), sync=True))
            ops.append(GemmOp(p + "scores", M=m * H // max(H, 1), K=Dh,
                              N=ctx, n_groups=H, sync=True,
                              weight_bytes_scale=float(H * batch)))
            ops.append(GemmOp(p + "o", M=m, K=H * Dh, N=D))
        elif cfg.family == "ssm":        # rwkv6
            ops.append(GemmOp(p + "rkvgw", M=m, K=D, N=4 * D,
                              chained=bool(ops), sync=True))
            ops.append(GemmOp(p + "wkv_o", M=m, K=D, N=D, chained=True))
        if cfg.family in ("ssm", "hybrid") and cfg.ssm_state:
            di = cfg.d_inner
            ops.append(GemmOp(p + "ssm_in", M=m, K=D,
                              N=2 * di + 2 * cfg.ssm_state,
                              chained=bool(ops), sync=True))
            ops.append(GemmOp(p + "ssm_out", M=m, K=di, N=D,
                              chained=True))
        if cfg.n_experts:
            fe = cfg.moe_d_ff
            k = cfg.moe_top_k
            ops.append(GemmOp(p + "moe_up", M=m * k, K=D, N=2 * fe,
                              n_groups=cfg.n_experts, sync=True,
                              weight_bytes_scale=float(cfg.n_experts * fe)
                              / (2 * fe)))
            ops.append(GemmOp(p + "moe_down", M=m * k, K=fe, N=D,
                              chained=True,
                              weight_bytes_scale=float(cfg.n_experts)))
        elif cfg.family not in ("ssm", "hybrid"):
            ops.append(GemmOp(p + "mlp_up", M=m, K=D, N=2 * F,
                              chained=True))
            ops.append(GemmOp(p + "mlp_down", M=m, K=F, N=D,
                              chained=True))

    for i in range(L):
        block(i)
    # The vocabulary projection is real forward work the dryrun cost
    # analysis counts — model it so measured-vs-predicted comparisons
    # (DESIGN.md §17) share scope. It dominates shallow validation slices.
    if getattr(cfg, "vocab_size", 0):
        ops.append(GemmOp("lm_head", M=m, K=D, N=cfg.vocab_size))
    return Task(f"{cfg.name}_L{L}", ops)


@dataclasses.dataclass
class PlanResult:
    arch: str
    baseline_latency: float        # LS-uniform on the TPU-as-MCM model
    optimized_latency: float       # with redistribution + async overlap
    nonuniform_headroom: float     # extra gain GA finds with non-uniform
    redist_mask: np.ndarray
    knobs: dict

    @property
    def modeled_speedup(self) -> float:
        return self.baseline_latency / self.optimized_latency

    def to_dryrun_knobs(self) -> dict:
        """The executable subset of the plan, as ``launch/dryrun``
        ``lower_cell``/``run_cell`` keyword knobs — what
        :func:`repro.launch.dryrun.execute_plan` lowers and compiles."""
        return {"shard_residual": bool(self.knobs["shard_residual"]),
                "accum": int(self.knobs["accum_steps"])}


def plan(cfg, mesh_shape: tuple[int, int], seq_len: int, batch: int,
         *, layers: int = 2, ga_budget: int = 30,
         profile=None) -> PlanResult:
    """Score layouts for one arch on one pod and emit runtime knobs.
    ``profile`` swaps the datasheet constants for a measured
    :class:`~repro.kernels.calibrate.CalibratedHW`."""
    hw = tpu_hw(mesh_shape, profile=profile)
    task = arch_to_task(cfg, seq_len, max(batch // (mesh_shape[0]
                                                    * mesh_shape[1]), 1)
                        * mesh_shape[0] * mesh_shape[1], layers=layers)
    part = uniform_partition(task, hw.X, hw.Y)
    # Baseline AND optimized both keep chained activations on-fabric
    # (redistribution) — on a pod there is no shared off-chip pool to
    # round-trip through. Optimized adds async comm/comp fusion (Sec 5.3).
    base_ev = Evaluator(task, hw, EvalOptions(redistribution=True))
    rd_all = base_ev.chain_valid.copy()
    base = base_ev.evaluate(part, rd_all).latency

    opt_ev = Evaluator(task, hw,
                       EvalOptions(redistribution=True, async_exec=True))
    optimized = opt_ev.evaluate(part, rd_all).latency

    # On a pod there is no shared-memory bypass: chained activations move
    # over ICI regardless, so redistribution stays frozen on and the GA
    # explores partitions/collectors only. Its extra gain over the uniform
    # plan is the non-uniform headroom XLA's equal-shard SPMD cannot
    # realize (reported in §Perf, not executed).
    ga = run_ga(task, hw, "latency",
                EvalOptions(redistribution=True, async_exec=True),
                GAConfig(generations=ga_budget, population=32, seed=0,
                         freeze_redist=True))
    # The planner only adopts the GA plan when it beats the uniform one,
    # so the reported headroom is ≥ 1 by construction (a GA run that
    # loses to uniform is no headroom, not negative headroom).
    headroom = (optimized / ga.objective
                if 0 < ga.objective < optimized else 1.0)

    knobs = {
        # keeping chained activations resident ↔ shard the residual stream
        # so no per-layer gather/spill of the full hidden state is needed
        "shard_residual": bool(rd_all.any()),
        # the Sec-5.4 cross-sample pipelining analogue: microbatching that
        # lets XLA overlap grad collectives with the next microbatch —
        # largest step count ≤ 4 that divides the global batch, so the
        # microbatch split is always executable
        "accum_steps": max(a for a in (4, 2, 1) if batch % a == 0),
        "redist_mask": rd_all,
    }
    return PlanResult(cfg.name, base, optimized, headroom, rd_all, knobs)
