from .engine import ServeEngine  # noqa: F401
