from .analysis import RooflineTerms, analyze_record, load_records  # noqa: F401
