"""Serving driver: batched generation with the ServeEngine.

CPU example:
    PYTHONPATH=src python -m repro.launch.serve --arch smollm-360m \
        --reduced --requests 8 --new-tokens 12
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from ..configs import get_config
from ..models import init_model
from ..serve import ServeEngine


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-360m")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--new-tokens", type=int, default=12)
    ap.add_argument("--capacity", type=int, default=256)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch, reduced=args.reduced)
    params = init_model(cfg, jax.random.PRNGKey(args.seed))
    engine = ServeEngine(cfg, params, batch_size=args.batch,
                         capacity=args.capacity,
                         temperature=args.temperature, seed=args.seed)
    rng = np.random.default_rng(args.seed)
    prompts = [rng.integers(0, cfg.vocab_size,
                            rng.integers(4, 17)).astype(np.int32)
               for _ in range(args.requests)]
    t0 = time.perf_counter()
    outs = engine.generate(prompts, max_new_tokens=args.new_tokens)
    dt = time.perf_counter() - t0
    total = sum(len(o) for o in outs)
    print(f"[serve] {cfg.name}: {len(prompts)} requests, {total} tokens "
          f"in {dt:.2f}s ({total/dt:.1f} tok/s incl. compile)")
    for i, o in enumerate(outs[:4]):
        print(f"  req{i}: {o}")
    return outs


if __name__ == "__main__":
    main()
