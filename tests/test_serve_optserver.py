"""Optimization-server invariants (DESIGN.md §14): the solo==served
exactness contract across every (kind × method × engine × congestion)
combination, coalescing, bounded-queue backpressure, bad-request
isolation, retry-with-restore, and the kill/restart chaos test over the
persistent cache store."""
import numpy as np
import pytest

from repro.core import EvalOptions, GemmOp, Task, make_hw
from repro.core import sweep
from repro.core.ga import GAConfig
from repro.core.miqp import MIQPConfig
from repro.core.pipelining import PipelineConfig
from repro.core.workload import uniform_partition
from repro.serve import BadRequest, OptRequest, OptServer, ServerOverloaded
from repro.serve.coalesce import group_requests


def toy_task(n=3, m=512):
    ops = [GemmOp("g0", M=m, K=256, N=512)]
    for i in range(1, n):
        ops.append(GemmOp(f"g{i}", M=m, K=ops[-1].N, N=512, chained=True))
    return Task(f"toy{n}_{m}", ops)


HW = make_hw("A", 2, "hbm")
GA_CFG = GAConfig(generations=4, population=16, patience=4, seed=3)
MIQP_CFG = MIQPConfig(engine="lattice", candidate_budget=256,
                      eval_budget=1024, beam_width=4, refine_sweeps=1,
                      pair_refine=4, descent_sweeps=2, score_chunk=256)
SEGS = [("a", 1.0, 2.0, 1.0), ("b", 0.5, 1.0, 0.5), ("c", 0.2, 0.8, 0.3)]


@pytest.fixture(autouse=True)
def _fresh_cache():
    sweep.clear_cache()
    yield
    sweep.clear_cache()


def _result_equal(a, b):
    if isinstance(a, dict):                      # eval record
        assert a["latency"] == b["latency"]
        assert a["energy"] == b["energy"]
        assert a["edp"] == b["edp"]
        np.testing.assert_array_equal(a["t_in"], b["t_in"])
        np.testing.assert_array_equal(a["t_out"], b["t_out"])
        return
    if hasattr(a, "pipelined"):                  # PipelineResult
        assert (a.batch, a.sequential, a.pipelined) == \
            (b.batch, b.sequential, b.pipelined)
        return
    assert a.objective == b.objective            # GAResult / MIQPResult
    np.testing.assert_array_equal(a.partition.Px, b.partition.Px)
    np.testing.assert_array_equal(a.partition.Py, b.partition.Py)
    np.testing.assert_array_equal(a.redist_mask, b.redist_mask)


# ------------------------------------------------------ solo == served
def _eval_requests(backend):
    task = toy_task()
    reqs = []
    for cong in ("regime", "flow"):
        for redist in (False, True):
            opts = EvalOptions(redistribution=redist, async_exec=True,
                               congestion=cong)
            reqs.append(OptRequest(
                "eval", sweep.EvalPoint(task, HW, opts),
                backend=backend))
    return reqs


@pytest.mark.parametrize("backend", ["numpy", "jax"])
def test_served_eval_bit_identical_to_solo(backend):
    """N concurrent same-shape eval requests (both congestion modes)
    coalesce into batched calls yet return bit-identical records to solo
    ``eval_sweep`` calls — the solo==served contract."""
    reqs = _eval_requests(backend)
    solo = [sweep.eval_sweep([r.point], backend=backend, cache=False)[0]
            for r in reqs]
    sweep.clear_cache()
    srv = OptServer(autostart=False)
    futs = [srv.submit(r) for r in reqs]
    srv.start()                       # all queued → one worker batch
    recs = [f.result(timeout=120) for f in futs]
    for s, r in zip(solo, recs):
        _result_equal(s, r)
    st = srv.stats()
    assert st["completed"] == len(reqs)
    # one CallKey → ONE coalesced sweep call for all 4 requests
    assert st["batches"] == 1
    assert st["coalesce_factor"] == len(reqs)
    srv.kill()


@pytest.mark.parametrize("method,cfg,backend", [
    ("ga", GA_CFG, "numpy"),
    ("ga", GA_CFG, "jax"),
    ("miqp", MIQP_CFG, "jax"),
])
def test_served_solve_bit_identical_to_solo(method, cfg, backend):
    pts = [sweep.EvalPoint(toy_task(2), HW),
           sweep.EvalPoint(toy_task(2, 256), HW)]
    reqs = [OptRequest("solve", pt, objective="latency", method=method,
                       cfg=cfg, backend=backend) for pt in pts]
    solo = [sweep.solve_grid([pt], "latency", cfg, backend=backend,
                             cache=False, method=method)[0] for pt in pts]
    sweep.clear_cache()
    srv = OptServer(autostart=False)
    futs = [srv.submit(r) for r in reqs]
    srv.start()
    recs = [f.result(timeout=300) for f in futs]
    for s, r in zip(solo, recs):
        _result_equal(s, r)
    srv.kill()


@pytest.mark.parametrize("engine", ["python", "vectorized"])
def test_served_pipeline_bit_identical_to_solo(engine):
    cfg = PipelineConfig(engine=engine)
    pts = [sweep.PipelinePoint(SEGS, b) for b in (2, 4, 8)]
    reqs = [OptRequest("pipeline", pt, cfg=cfg) for pt in pts]
    solo = [sweep.pipeline_sweep([pt], cfg, cache=False)[0] for pt in pts]
    sweep.clear_cache()
    srv = OptServer(autostart=False)
    futs = [srv.submit(r) for r in reqs]
    srv.start()
    recs = [f.result(timeout=120) for f in futs]
    for s, r in zip(solo, recs):
        _result_equal(s, r)
    srv.kill()


def test_mixed_kind_traffic_coalesces_per_call_key():
    """A mixed batch (eval + solve + pipeline) groups into exactly one
    sweep call per CallKey, results all correct."""
    ereqs = _eval_requests("jax")[:2]
    preqs = [OptRequest("pipeline", sweep.PipelinePoint(SEGS, b))
             for b in (2, 4)]
    sreqs = [OptRequest("solve", sweep.EvalPoint(toy_task(2), HW),
                        cfg=GA_CFG, backend="numpy")]
    reqs = ereqs + preqs + sreqs
    assert len(group_requests(reqs)) == 3
    srv = OptServer(autostart=False)
    futs = [srv.submit(r) for r in reqs]
    srv.start()
    for f in futs:
        f.result(timeout=300)
    st = srv.stats()
    assert st["batches"] == 3
    assert st["completed"] == len(reqs)
    assert st["coalesce_factor"] == pytest.approx(len(reqs) / 3)
    srv.kill()


# -------------------------------------------------------- backpressure
def test_bounded_queue_backpressure():
    srv = OptServer(max_queue=3, autostart=False)
    req = lambda: OptRequest("eval", sweep.EvalPoint(toy_task(), HW))
    futs = [srv.submit_nowait(req()) for _ in range(3)]
    with pytest.raises(ServerOverloaded):
        srv.submit_nowait(req())
    with pytest.raises(ServerOverloaded):
        srv.submit(req(), timeout=0.01)
    # Backpressure clears once the worker drains the queue.
    srv.start()
    for f in futs:
        f.result(timeout=120)
    srv.submit(req()).result(timeout=120)
    assert srv.stats()["completed"] == 4
    srv.kill()


# ------------------------------------------------ bad-request isolation
def test_bad_requests_rejected_not_fatal():
    task = toy_task()
    bad_part = uniform_partition(task, HW.X, HW.Y)
    bad_part.Px[0, 0] += 7            # sums no longer match M
    good = OptRequest("eval", sweep.EvalPoint(task, HW))
    bads = [
        OptRequest("eval", sweep.EvalPoint(task, HW,
                                           partition=bad_part)),
        OptRequest("nonsense", sweep.EvalPoint(task, HW)),
        OptRequest("solve", sweep.EvalPoint(task, HW),
                   objective="speed"),
        OptRequest("solve", sweep.EvalPoint(task, HW), method="ga",
                   cfg=MIQP_CFG),
        OptRequest("pipeline", sweep.PipelinePoint(SEGS, 0)),
        OptRequest("pipeline",
                   sweep.PipelinePoint([("a", np.nan, 1.0, 1.0)], 2)),
        OptRequest("eval", sweep.EvalPoint(task, HW), backend="cuda"),
    ]
    ref = sweep.eval_sweep([good.point], cache=False)[0]
    sweep.clear_cache()
    srv = OptServer(autostart=False)
    bad_futs = [srv.submit(b) for b in bads]
    good_fut = srv.submit(good)
    srv.start()
    # Every malformed request errors with BadRequest on its own future…
    for f in bad_futs:
        with pytest.raises(BadRequest):
            f.result(timeout=60)
    # …while the cohort request and the worker survive.
    _result_equal(ref, good_fut.result(timeout=60))
    _result_equal(ref, srv.submit(good).result(timeout=60))
    st = srv.stats()
    assert st["rejected"] == len(bads)
    assert st["completed"] == 2
    srv.kill()


# ---------------------------------------------------- retry-with-restore
def test_transient_failure_retries_then_succeeds():
    srv = OptServer(autostart=False, max_retries=2)
    real = srv._calls["eval"]
    fails = {"n": 2}

    def flaky(*a, **kw):
        if fails["n"] > 0:
            fails["n"] -= 1
            raise RuntimeError("simulated transient engine failure")
        return real(*a, **kw)

    srv._calls["eval"] = flaky
    req = OptRequest("eval", sweep.EvalPoint(toy_task(), HW))
    fut = srv.submit(req)
    srv.start()
    rec = fut.result(timeout=120)
    assert rec["latency"] > 0
    st = srv.stats()
    assert st["retries"] == 2
    assert st["failed"] == 0
    srv.kill()


def test_persistent_failure_isolated_by_solo_fallback():
    """A request that poisons its whole coalesced call must not take the
    cohort down: after retries the group re-runs solo and only the
    guilty request errors."""
    poison = sweep.EvalPoint(toy_task(4), HW)
    ok_pts = [sweep.EvalPoint(toy_task(), HW),
              sweep.EvalPoint(toy_task(3, 256), HW)]
    srv = OptServer(autostart=False, max_retries=1)
    real = srv._calls["eval"]

    def booby_trapped(pts, **kw):
        if any(p is poison for p in pts):
            raise ValueError("simulated poisoned point")
        return real(pts, **kw)

    srv._calls["eval"] = booby_trapped
    futs = [srv.submit(OptRequest("eval", pt))
            for pt in (ok_pts[0], poison, ok_pts[1])]
    srv.start()
    assert futs[0].result(timeout=120)["latency"] > 0
    assert futs[2].result(timeout=120)["latency"] > 0
    with pytest.raises(ValueError):
        futs[1].result(timeout=120)
    st = srv.stats()
    assert st["retries"] == 1
    assert st["solo_fallbacks"] == 1
    assert st["failed"] == 1
    assert st["completed"] == 2
    srv.kill()


# -------------------------------------------------------- chaos / store
def test_chaos_kill_restart_resumes_from_store(tmp_path):
    """Kill a server mid-grid (after half the points completed, without
    graceful shutdown); a restarted server on the same store must serve
    the completed half purely from cache — zero recomputation — and
    return bit-identical results for the full grid."""
    store = tmp_path / "sweep-cache.bin"
    pts = [sweep.EvalPoint(toy_task(3, m), HW)
           for m in (128, 256, 384, 512, 640, 768)]
    ref = [sweep.eval_sweep([p], cache=False)[0] for p in pts]
    sweep.clear_cache()

    srv1 = OptServer(store_path=str(store), flush_every=1)
    futs = [srv1.submit(OptRequest("eval", pt)) for pt in pts[:3]]
    for f in futs:
        f.result(timeout=120)
    srv1.drain(timeout=60)
    srv1.kill()                        # crash: NO graceful close/save

    sweep.clear_cache()                # "new process"
    srv2 = OptServer(store_path=str(store), flush_every=1)
    assert srv2.store_info["loaded"] == 3
    assert not srv2.store_info["cold_start"]
    futs = [srv2.submit(OptRequest("eval", pt)) for pt in pts]
    recs = [f.result(timeout=120) for f in futs]
    st = srv2.stats()
    # completed points came from the store; only the killed-off half
    # was computed
    assert st["cache_hits"] == 3
    assert st["cache_misses"] == 3
    for a, b in zip(ref, recs):
        _result_equal(a, b)
    srv2.close()
    # graceful close full-saves: a third server loads all six
    sweep.clear_cache()
    srv3 = OptServer(store_path=str(store))
    assert srv3.store_info["loaded"] == 6
    srv3.kill()


def test_store_survives_torn_tail(tmp_path):
    """A store torn mid-record (crash mid-append) still resumes the
    intact prefix on restart."""
    store = tmp_path / "sweep-cache.bin"
    pts = [sweep.EvalPoint(toy_task(3, m), HW) for m in (128, 256, 384)]
    srv = OptServer(store_path=str(store), flush_every=1)
    for f in [srv.submit(OptRequest("eval", pt)) for pt in pts]:
        f.result(timeout=120)
    srv.drain(timeout=60)
    srv.kill()
    size = store.stat().st_size
    with open(store, "r+b") as f:
        f.truncate(size - 11)
    sweep.clear_cache()
    srv2 = OptServer(store_path=str(store))
    assert srv2.store_info["torn_tail"]
    assert 0 < srv2.store_info["loaded"] < len(pts)
    srv2.kill()


# -------------------------------------------------------------- stats
def test_stats_shape_and_latency_fields():
    srv = OptServer(autostart=False)
    reqs = _eval_requests("jax")[:2]
    futs = [srv.submit(r) for r in reqs]
    srv.start()
    for f in futs:
        f.result(timeout=120)
    st = srv.stats()
    assert st["submitted"] == 2 and st["inflight"] == 0
    assert st["requests_per_s"] > 0
    assert 0 < st["p50_ms"] <= st["p99_ms"]
    assert st["cache_hit_rate"] == 0.0       # all fresh points
    assert st["store"]["loaded"] == 0        # no store configured
    srv.kill()


def test_cli_demo_runs(monkeypatch, capsys, tmp_path):
    from repro.serve import optserver as mod

    def tiny_traffic(n):
        return [OptRequest("eval", sweep.EvalPoint(toy_task(), HW))
                for _ in range(n)]

    monkeypatch.setattr(mod, "_demo_requests", tiny_traffic)
    mod.main(["--requests", "3",
              "--store", str(tmp_path / "cli-store.bin")])
    out = capsys.readouterr().out
    assert "served 3/3 requests" in out
