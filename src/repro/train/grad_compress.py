"""Error-feedback int8 gradient compression for the data-parallel
all-reduce (the distributed-optimization trick for DCN-limited multi-pod
training).

Inside a ``shard_map`` over the data axes each host quantizes its local
gradient shard to int8 with a per-tensor scale, all-reduces the int8
payload (8× less DCN traffic than f32), dequantizes, and keeps the
quantization residual locally to be added to the next step's gradient
(error feedback — keeps SGD convergence).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P


def quantize_int8(x):
    x32 = x.astype(jnp.float32)
    scale = jnp.maximum(jnp.max(jnp.abs(x32)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(x32 / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q, scale):
    return q.astype(jnp.float32) * scale


def compress_residual(g, err):
    """Apply error feedback, quantize. Returns (q, scale, new_err)."""
    g32 = g.astype(jnp.float32) + err
    q, scale = quantize_int8(g32)
    new_err = g32 - dequantize_int8(q, scale)
    return q, scale, new_err


def compressed_psum_mean(x, err, axis_names: tuple[str, ...]):
    """Error-feedback int8 psum-mean over ``axis_names`` (call inside
    shard_map)."""
    q, scale, new_err = compress_residual(x, err)
    deq = dequantize_int8(q, scale)          # local dequant
    summed = jax.lax.psum(deq, axis_names)
    n = 1
    for a in axis_names:
        # psum(1) == axis size; jax.lax.axis_size only exists in jax>=0.5
        n *= jax.lax.psum(1, a)
    return summed / n, new_err


def make_compressed_allreduce(mesh, axes: tuple[str, ...], specs=None):
    """Returns ``f(grads, err_tree) -> (mean_grads, new_err_tree)`` running
    the error-feedback int8 all-reduce as a ``shard_map`` over ``axes``.

    ``specs`` gives the PartitionSpec tree of the gradients *excluding*
    the reduced axes (replicated by default — the pure-DP case where each
    data-parallel rank holds a full gradient replica to be averaged).
    """
    from jax.experimental.shard_map import shard_map

    def run(grads, err):
        tdef = jax.tree.structure(grads)
        in_specs = specs if specs is not None else jax.tree.map(
            lambda _: P(), grads)

        def kernel(g, e):
            z = jax.tree.map(
                lambda gg, ee: compressed_psum_mean(gg, ee, axes), g, e)
            leaves = tdef.flatten_up_to(z)
            means = tdef.unflatten([l[0] for l in leaves])
            errs = tdef.unflatten([l[1] for l in leaves])
            return means, errs

        return shard_map(kernel, mesh=mesh,
                         in_specs=(in_specs, in_specs),
                         out_specs=(in_specs, in_specs),
                         check_rep=False)(grads, err)

    return run
