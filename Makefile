# Convenience targets; everything runs with PYTHONPATH=src (no install).
PY ?= python
export PYTHONPATH := src$(if $(PYTHONPATH),:$(PYTHONPATH),)

.PHONY: test smoke bench-fast ga-fitness quickstart

# Tier-1 verify — the command CI and the roadmap pin.
test:
	$(PY) -m pytest -x -q

# Fast gate: environment sanity (imports, optional-hypothesis shim) +
# the core evaluator / backend-parity / sweep suites. Catches the class
# of failure where a missing dev dependency breaks test collection.
smoke:
	$(PY) -m pytest -x -q tests/test_core_evaluator.py \
	    tests/test_backend_parity.py tests/test_core_sweep.py \
	    tests/test_core_api.py

bench-fast:
	$(PY) -m benchmarks.run

# Backend shootout for the GA fitness hot loop (DESIGN.md §8).
ga-fitness:
	$(PY) -m benchmarks.perf_iterations --cell ga_fitness

quickstart:
	$(PY) examples/quickstart.py
