"""Batched serving engine: jit'd prefill + decode steps over a fixed
request batch with greedy/temperature sampling and simple continuous
batching (finished slots are refilled from the queue between decode
steps).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from ..models import forward, init_caches


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray          # (S,) int32
    max_new_tokens: int = 16
    out: list = dataclasses.field(default_factory=list)
    done: bool = False


class ServeEngine:
    def __init__(self, cfg, params, batch_size: int = 4,
                 capacity: int = 256, temperature: float = 0.0,
                 seed: int = 0):
        if cfg.is_encoder:
            raise ValueError("encoder-only models have no decode path")
        self.cfg = cfg
        self.params = params
        self.B = batch_size
        self.capacity = capacity
        self.temperature = temperature
        self.key = jax.random.PRNGKey(seed)

        self._prefill = jax.jit(
            lambda p, b, c: forward(p, cfg, b, mode="prefill", caches=c))
        self._decode = jax.jit(
            lambda p, t, c, pos: forward(p, cfg, {"tokens": t},
                                         mode="decode", caches=c, pos=pos))

    def _sample(self, logits):
        lg = logits[:, -1, : self.cfg.vocab_size]
        if self.temperature <= 0:
            return jnp.argmax(lg, axis=-1).astype(jnp.int32)
        self.key, sub = jax.random.split(self.key)
        return jax.random.categorical(sub, lg / self.temperature).astype(
            jnp.int32)

    def generate(self, prompts: list[np.ndarray],
                 max_new_tokens: int = 16) -> list[list[int]]:
        """Static-batch generation: pad prompts to a common length, prefill
        once, decode greedily. Prompt batches larger than the engine batch
        run in waves."""
        outs: list[list[int]] = []
        for i in range(0, len(prompts), self.B):
            outs.extend(self._generate_wave(prompts[i: i + self.B],
                                            max_new_tokens))
        return outs

    def _generate_wave(self, prompts, max_new_tokens):
        B = len(prompts)
        L = max(len(p) for p in prompts)
        toks = np.zeros((B, L), np.int32)
        for i, p in enumerate(prompts):
            toks[i, L - len(p):] = p      # left-pad (aligned positions)
        caches = init_caches(self.cfg, B, self.capacity)
        logits, caches, _ = self._prefill(self.params,
                                          {"tokens": jnp.asarray(toks)},
                                          caches)
        nxt = self._sample(logits)
        outs = [[int(t)] for t in np.asarray(nxt)]
        pos = L
        for _ in range(max_new_tokens - 1):
            logits, caches, _ = self._decode(self.params, nxt[:, None],
                                             caches, jnp.asarray(pos))
            nxt = self._sample(logits)
            for i, t in enumerate(np.asarray(nxt)):
                outs[i].append(int(t))
            pos += 1
        return outs
