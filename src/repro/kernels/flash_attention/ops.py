"""Public attention op: Pallas flash kernel on TPU, blockwise-XLA
elsewhere; ``attention_ref`` is the O(S²) oracle for tests."""
from __future__ import annotations

import jax

from .blockwise import blockwise_attention
from .kernel import flash_attention
from .ref import attention_ref  # noqa: F401


def attention(q, k, v, *, causal=True, window=None, softcap=None,
              scale=None, use_pallas: bool | None = None,
              interpret: bool = False, **kw):
    if use_pallas is None:
        use_pallas = jax.default_backend() == "tpu"
    if use_pallas or interpret:
        return flash_attention(
            q, k, v, causal=causal, window=window, softcap=softcap,
            scale=scale,
            interpret=interpret or jax.default_backend() != "tpu")
    return blockwise_attention(q, k, v, causal=causal, window=window,
                               softcap=softcap, scale=scale, **kw)
