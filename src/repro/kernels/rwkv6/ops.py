"""Public WKV-6 op: Pallas on TPU, chunked-XLA elsewhere."""
from __future__ import annotations

import jax

from .chunked import wkv6_chunked
from .kernel import wkv6 as wkv6_pallas
from .ref import wkv6_ref  # noqa: F401


def wkv6(r, k, v, w, u, *, chunk: int = 32,
         use_pallas: bool | None = None, interpret: bool = False):
    if use_pallas is None:
        use_pallas = jax.default_backend() == "tpu"
    if use_pallas or interpret:
        return wkv6_pallas(
            r, k, v, w, u, chunk=chunk,
            interpret=interpret or jax.default_backend() != "tpu")
    return wkv6_chunked(r, k, v, w, u, chunk=chunk)[0]
