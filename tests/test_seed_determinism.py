"""Seed-determinism audit: every solver entrypoint returns a
bitwise-identical result when re-run with the same seed — the property
the sweep cache (§9 fingerprints), the serve layer, and the planner's
reported numbers all rest on. One parametrized test, one entrypoint per
case, exact comparison (no tolerances)."""
import numpy as np
import pytest

from repro.core.evaluator import EvalOptions
from repro.core.hw import make_hw
from repro.graphs import WORKLOADS

TASK = WORKLOADS["alexnet"](batch=1)
HW = make_hw("A", 4, "hbm", diagonal_links=True)
OPTS = EvalOptions(redistribution=True, async_exec=True)


def _ga(engine, backend):
    from repro.core.ga import GAConfig, run_ga
    r = run_ga(TASK, HW, "latency", OPTS,
               GAConfig(generations=6, population=16, seed=11),
               backend=backend, engine=engine)
    return {"Px": r.partition.Px, "Py": r.partition.Py,
            "redist": r.redist_mask, "objective": r.objective,
            "history": r.history}


def _miqp():
    from repro.core.miqp import MIQPConfig, run_miqp
    r = run_miqp(TASK, HW, "latency", OPTS,
                 MIQPConfig(engine="lattice", candidate_budget=4096,
                            eval_budget=8192, descent_sweeps=2))
    return {"Px": r.partition.Px, "Py": r.partition.Py,
            "objective": r.objective}


def _cosearch():
    from repro.core.cosearch import CoSearchConfig, run_cosearch
    r = run_cosearch(TASK, HW, "edp", OPTS,
                     CoSearchConfig(population=16, generations=6,
                                    seed=11, seed_steps=4, seed_starts=1))
    return {"Px": r.partition.Px, "Py": r.partition.Py,
            "objective": r.objective}


def _planner():
    from repro.configs import get_config
    from repro.sharding.mcm_planner import plan
    r = plan(get_config("smollm-360m"), (2, 2), 128, 8, layers=1,
             ga_budget=3)
    return {"base": r.baseline_latency, "opt": r.optimized_latency,
            "headroom": r.nonuniform_headroom, "redist": r.redist_mask,
            "knobs": {k: v for k, v in r.knobs.items()
                      if k != "redist_mask"}}


CASES = {
    "ga_python_numpy": lambda: _ga("python", "numpy"),
    "ga_vectorized_numpy": lambda: _ga("vectorized", "numpy"),
    "ga_vectorized_jax": lambda: _ga("vectorized", "jax"),
    "miqp_lattice": _miqp,
    "cosearch": _cosearch,
    "planner_search": _planner,
}


def _assert_identical(a, b, path=""):
    assert type(a) is type(b), f"{path}: {type(a)} != {type(b)}"
    if isinstance(a, dict):
        assert a.keys() == b.keys(), path
        for k in a:
            _assert_identical(a[k], b[k], f"{path}.{k}")
    elif isinstance(a, np.ndarray):
        assert a.dtype == b.dtype and np.array_equal(a, b), path
    else:
        assert a == b, f"{path}: {a!r} != {b!r}"


@pytest.mark.parametrize("name", sorted(CASES))
def test_repeated_seed_is_bitwise_identical(name):
    first = CASES[name]()
    second = CASES[name]()
    _assert_identical(first, second, name)
