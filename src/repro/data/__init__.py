from .pipeline import DataConfig, make_pipeline  # noqa: F401
