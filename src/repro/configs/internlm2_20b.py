"""internlm2-20b [dense]: 48L d_model=6144 48H (GQA kv=8) d_ff=16384
vocab=92544 [arXiv:2403.17297; hf]."""
from ..models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="internlm2-20b", family="dense",
        n_layers=48, d_model=6144, d_ff=16384, vocab_size=92544,
        n_heads=48, n_kv_heads=8, d_head=128,
        act="silu", rope_theta=1e6,
    )


def reduced_config() -> ModelConfig:
    return config().replace(
        name="internlm2-smoke", n_layers=3, d_model=64, d_ff=160,
        vocab_size=256, n_heads=4, n_kv_heads=2, d_head=16,
        attn_chunk=32, remat=False)
