"""Training substrate: AdamW (+ZeRO-style sharded states), schedules,
train step with microbatch accumulation, gradient compression."""
from .optimizer import adamw, cosine_schedule  # noqa: F401
from .train_step import TrainState, make_train_step  # noqa: F401
