"""Pallas TPU kernel for the RWKV-6 chunked WKV recurrence.

Grid (B·H, n_chunks), chunks innermost; the (K, K) WKV state is VMEM
scratch carried across chunk steps. Unlike SSD, the decay here is
*per-channel*, so the intra-chunk pairwise term needs per-channel decay
alignment; the kernel keeps chunks small (Lc ≤ 64) and computes the
(Lc, Lc) interaction with one fori_loop over the chunk's rows feeding the
MXU (row i's decayed query against all j ≤ i−1 keys), which avoids any
(Lc, Lc, K) VMEM tensor.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _wkv_kernel(r_ref, k_ref, v_ref, lw_ref, u_ref, o_ref, s_ref, *,
                Lc: int):
    ic = pl.program_id(1)

    @pl.when(ic == 0)
    def _init():
        s_ref[...] = jnp.zeros_like(s_ref)

    r = r_ref[0].astype(jnp.float32)     # (Lc, K)
    k = k_ref[0].astype(jnp.float32)
    v = v_ref[0].astype(jnp.float32)
    lw = lw_ref[0].astype(jnp.float32)   # (Lc, K) log decay ≤ 0
    u = u_ref[0].astype(jnp.float32)     # (1, K) bonus

    cum = jnp.cumsum(lw, axis=0)                       # (Lc, K)
    cum_im1 = cum - lw                                 # cum_{i-1}
    # intra-chunk pairwise term:
    #   A[i, j] = Σ_c r_i[c]·exp(cum_{i-1}[c] − cum_j[c])·k_j[c],  j < i
    # Computed as (r_i ∘ exp(cum_{i-1})) · (k_j ∘ exp(−cum_j))ᵀ row by
    # row; exponents are normalized per row i so every exp argument stays
    # ≤ 0 (cum is monotonically decreasing in i).
    rq = r * jnp.exp(cum_im1)                          # (Lc, K)

    def row(i, y):
        # keys decayed relative to row i: exp(cum_{i-1} − cum_j) ≤ 1 ∀ j<i
        kd = k * jnp.exp(cum_im1[i] - cum)             # (Lc, K)
        a_i = jnp.sum(jnp.where(
            (jax.lax.broadcasted_iota(jnp.int32, (Lc, 1), 0) < i),
            r[i] * kd, 0.0), axis=-1)                  # (Lc,)
        y_i = jnp.dot(a_i[None, :], v,
                      preferred_element_type=jnp.float32)[0]
        return y.at[i].set(y_i)

    y_intra = jax.lax.fori_loop(
        0, Lc, row, jnp.zeros((Lc, v.shape[-1]), jnp.float32))
    del rq
    # bonus diagonal
    diag = jnp.sum(r * u * k, axis=-1, keepdims=True)  # (Lc, 1)
    y_intra += diag * v
    # inter-chunk: y_i += (r_i ∘ exp(cum_{i-1})) @ S   (S: (K, V))
    y_inter = jnp.dot(r * jnp.exp(cum_im1), s_ref[...],
                      preferred_element_type=jnp.float32)
    # state: S' = D(exp(cum_L))·S + Σ_j (k_j ∘ exp(cum_L − cum_j)) ⊗ v_j
    decay_end = jnp.exp(cum[-1:] - cum)                # (Lc, K)
    s_ref[...] = (s_ref[...] * jnp.exp(cum[-1])[:, None]
                  + jnp.dot((k * decay_end).T, v,
                            preferred_element_type=jnp.float32))
    o_ref[0, ...] = (y_intra + y_inter).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def wkv6(r, k, v, w, u, *, chunk: int = 32, interpret: bool = False):
    """Same contract as ``ref.wkv6_ref`` (y only)."""
    B, S, H, K = r.shape
    Lc = min(chunk, S)
    pad = (-S) % Lc
    lw = jnp.log(jnp.clip(w.astype(jnp.float32), 1e-38, 1.0))

    def head_major(t):
        t = t.transpose(0, 2, 1, 3).reshape(B * H, S, K)
        return jnp.pad(t, ((0, 0), (0, pad), (0, 0))) if pad else t

    rh, kh, vh, lwh = map(head_major, (r, k, v, lw))
    # padding must not decay the state: lw=0 ⇒ w=1 on padded steps
    Sp = S + pad
    nc = Sp // Lc
    uh = jnp.broadcast_to(u[None], (B, H, K)).reshape(B * H, 1, K)

    out = pl.pallas_call(
        functools.partial(_wkv_kernel, Lc=Lc),
        grid=(B * H, nc),
        in_specs=[
            pl.BlockSpec((1, Lc, K), lambda bh, ic: (bh, ic, 0)),
            pl.BlockSpec((1, Lc, K), lambda bh, ic: (bh, ic, 0)),
            pl.BlockSpec((1, Lc, K), lambda bh, ic: (bh, ic, 0)),
            pl.BlockSpec((1, Lc, K), lambda bh, ic: (bh, ic, 0)),
            pl.BlockSpec((1, 1, K), lambda bh, ic: (bh, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, Lc, K), lambda bh, ic: (bh, ic, 0)),
        out_shape=jax.ShapeDtypeStruct((B * H, Sp, K), r.dtype),
        scratch_shapes=[pltpu.VMEM((K, K), jnp.float32)],
        interpret=interpret,
    )(rh, kh, vh, lwh, uh)
    return out[:, :S].reshape(B, H, S, K).transpose(0, 2, 1, 3)
