"""Fig. 9/10 reproduction: latency and EDP scaling on type-A systems of
4×4 / 8×8 / 16×16 chiplets.

Paper claims: MIQP geo-mean 55.5% (latency) / 60.3% (EDP) over LS; GA
24.2% / 35.1%. MIQP > GA, with AlexNet gaining more on larger systems
(redistribution savings grow with scale); GA is relatively stronger on
EDP than latency.

Grid driving (benchmarks/README.md): the (grid × workload) LS references
are one batched sweep (latency and EDP come out of the same records);
the (objective × grid × workload × method) solver grid goes through
``sweep.run_grid``.
"""
from __future__ import annotations

from repro.core import make_hw, optimize, sweep
from repro.core.ga import GAConfig
from repro.core.miqp import MIQPConfig
from repro.graphs import WORKLOADS

from .common import emit, geomean, save_json

GA_CFG = GAConfig(generations=60, population=64)
MIQP_CFG = MIQPConfig(time_limit=60, edp_sweep=3)
METHOD_KW = {"ga": {"ga_config": GA_CFG}, "miqp": {"miqp_config": MIQP_CFG}}


def main(fast: bool = False, backend: str = "jax"):
    grids = (4, 8) if fast else (4, 8, 16)
    wnames = ("alexnet", "hydranet") if fast else tuple(WORKLOADS)
    tasks = {w: WORKLOADS[w](batch=1) for w in wnames}
    hws = {g: make_hw("A", g, "hbm") for g in grids}

    base_grid = sweep.grid(g=grids, wname=wnames)
    base_recs = sweep.eval_sweep(
        [sweep.EvalPoint(tasks[p["wname"]], hws[p["g"]])
         for p in base_grid],
        backend=backend)
    ref = {(p["g"], p["wname"]): r for p, r in zip(base_grid, base_recs)}

    results = {}
    sp_all = {(o, m): [] for o in ("latency", "edp") for m in METHOD_KW}

    def solve(objective, g, wname, method):
        return optimize(tasks[wname], hws[g], method, objective,
                        backend=backend, **METHOD_KW[method])

    def report(pt, r, us):
        o, g, wname, m = pt["objective"], pt["g"], pt["wname"], pt["method"]
        fig = "fig9" if o == "latency" else "fig10"
        val = r.latency if o == "latency" else r.edp
        sp = ref[(g, wname)][o] / val
        sp_all[(o, m)].append(sp)
        results[f"{fig}/{g}/{wname}/{m}"] = sp
        emit(f"{fig}/{g}x{g}/{wname}/{m}", us, f"speedup={sp:.3f}x")

    sweep.run_grid(
        sweep.grid(objective=("latency", "edp"), g=grids, wname=wnames,
                   method=list(METHOD_KW)),
        solve, emit=report)

    for o in ("latency", "edp"):
        fig = "fig9" if o == "latency" else "fig10"
        for m in METHOD_KW:
            emit(f"{fig}/geomean/{m}", 0.0,
                 f"{(geomean(sp_all[(o, m)]) - 1) * 100:+.1f}% vs LS "
                 f"(paper: GA +24.2/35.1%, MIQP +55.5/60.3%)")
    save_json("fig9_10", results)


if __name__ == "__main__":
    main()
