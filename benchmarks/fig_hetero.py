"""Heterogeneous-grid figure: homogeneous vs 2-class vs 4-class chiplet
grids, plus multi-tenant placement vs the even-split baseline
(DESIGN.md §18).

Hardware is data (PR 10): a mixed-class package is an ordinary
``HWConfig``, so the whole (workload × class-count) grid shares one
shape signature and batches through ONE compiled evaluator call per
backend — same contract as the fig8/fig9 sweeps. The GA search leg runs
island-batched ``solve_grid`` over every grid cell in one call (hetero
and homogeneous islands co-batch).

The multi-tenant leg places two models on disjoint row bands of the
2-class grid through ``solve_multitenant`` and records the search EDP
against the naive even-split placement. The even split is always in the
candidate set, so search > even-split is a correctness violation — this
script exits nonzero on it (and the artifact records the strict
improvement the asymmetric grid is expected to show).
"""
from __future__ import annotations

import dataclasses
import time

from repro.core import (ChipletClass, EvalOptions, MultiTenantConfig,
                        make_hw, solve_multitenant, sweep)
from repro.core.ga import GAConfig
from repro.graphs import WORKLOADS

from .common import emit, save_json

FAST = ChipletClass("fast", freq_hz=1.5e9, bw_nop=120e9)
BASE = ChipletClass("base")
MID = ChipletClass("mid", freq_hz=0.75e9, bw_nop=45e9)
SLOW = ChipletClass("slow", freq_hz=0.5e9, bw_nop=30e9, mem_scale=0.5)


def hetero_grids() -> dict:
    """The class-count axis on a 4×4 type-A HBM package: homogeneous,
    2-class (fast/slow half rows), 4-class (one class per row)."""
    base = make_hw("A", 4, "hbm")
    return {
        "homogeneous": base,
        "two_class": dataclasses.replace(
            base, chiplet_classes=(FAST, SLOW),
            class_assignment=(0,) * 8 + (1,) * 8),
        "four_class": dataclasses.replace(
            base, chiplet_classes=(FAST, BASE, MID, SLOW),
            class_assignment=(0,) * 4 + (1,) * 4 + (2,) * 4 + (3,) * 4),
    }


def main(fast: bool = True, backend: str = "jax"):
    wnames = ("alexnet", "vit") if fast else ("alexnet", "vit",
                                              "vision_mamba", "hydranet")
    ga_cfg = (GAConfig(population=32, generations=20, patience=8, seed=0)
              if fast else GAConfig(population=64, generations=60, seed=0))
    opts = EvalOptions(redistribution=True, async_exec=True)
    grids = hetero_grids()
    tasks = {w: WORKLOADS[w](batch=1) for w in wnames}
    results: dict = {}

    # -- LS baseline: the whole (workload × class-count) grid in one
    #    batched eval call per shape signature.
    points = [sweep.EvalPoint(tasks[w], hw, opts)
              for w in wnames for hw in grids.values()]
    t0 = time.perf_counter()
    recs = sweep.eval_sweep(points, backend=backend)
    emit("fig_hetero/eval_sweep_total",
         (time.perf_counter() - t0) * 1e6,
         f"{len(points)} cells, backend={backend}")
    ls = {}
    it = iter(recs)
    for w in wnames:
        for g in grids:
            ls[(w, g)] = next(it)

    # -- GA search on every grid cell, island-batched in one call.
    t0 = time.perf_counter()
    sols = sweep.solve_grid(points, objective="edp", cfg=ga_cfg,
                            backend=backend)
    emit("fig_hetero/solve_grid_total",
         (time.perf_counter() - t0) * 1e6,
         f"{len(points)} GA searches, pop={ga_cfg.population}")
    it = iter(sols)
    for w in wnames:
        results[w] = {}
        for g in grids:
            sol = next(it)
            ls_edp = ls[(w, g)]["edp"]
            results[w][g] = {
                "ls_edp": float(ls_edp),
                "ga_edp": float(sol.objective),
                "ga_speedup_vs_ls": float(ls_edp / sol.objective),
            }
            emit(f"fig_hetero/{w}/{g}", 0.0,
                 f"ls_edp={ls_edp:.3e} ga_edp={sol.objective:.3e} "
                 f"x{ls_edp / sol.objective:.2f}")

    # -- multi-tenant placement on the asymmetric 2-class grid: the
    #    search must never lose to even split (it is a candidate), and
    #    on this grid it should strictly win.
    mt_cfg = (MultiTenantConfig(method="uniform") if fast
              else MultiTenantConfig(method="ga", cfg=ga_cfg))
    tenants = ("alexnet", "vit")
    res = solve_multitenant([tasks[t] for t in tenants],
                            grids["two_class"], objective="edp",
                            cfg=mt_cfg, backend=backend)
    even_edp = res.baseline["edp"]
    results["multitenant"] = {
        "grid": "two_class",
        "tenants": list(tenants),
        "inner_method": mt_cfg.method,
        "search_edp": res.edp,
        "even_split_edp": even_edp,
        "improvement_vs_even_split": even_edp / res.edp,
        "beats_even_split": bool(res.edp < even_edp),
        "assignment": [list(b) for b in res.assignment],
        "even_assignment": [list(b)
                            for b in res.baseline["assignment"]],
        "per_tenant": [dict(d) for d in res.per_tenant],
    }
    emit("fig_hetero/multitenant", 0.0,
         f"search_edp={res.edp:.3e} even={even_edp:.3e} "
         f"x{even_edp / res.edp:.2f}")
    save_json("fig_hetero", results)
    if res.edp > even_edp * (1 + 1e-12):
        # even split is in the candidate set — losing to it means the
        # assignment enumeration or scoring broke.
        raise SystemExit("fig_hetero: multi-tenant search lost to the "
                         "even-split baseline")
    return results


if __name__ == "__main__":
    main()
