"""HydraNet (Tesla-style multi-task vision network) as a GEMM sequence.

No exact public config exists (DESIGN.md §5); we use the publicly
described shape: a RegNet-ish shared backbone, a BiFPN-style fusion stage,
and three task heads (object detection, lane/edge prediction, traffic
lights) operating on the shared feature map. The backbone is sequentially
chained (redistribution applies); the heads branch — each head's first op
consumes the shared fused features, so only the first head op is chained.
"""
from __future__ import annotations

from ..core.workload import GemmOp, Task

# (name, spatial, k, c_in, c_out) — input 640x480-ish, /2 per stage
_BACKBONE = [
    ("stem", 160 * 120, 7, 3, 32),
    ("s1_c1", 80 * 60, 3, 32, 64),
    ("s1_c2", 80 * 60, 3, 64, 64),
    ("s2_c1", 40 * 30, 3, 64, 128),
    ("s2_c2", 40 * 30, 3, 128, 128),
    ("s3_c1", 20 * 15, 3, 128, 256),
    ("s3_c2", 20 * 15, 3, 256, 256),
    ("s4_c1", 10 * 8, 3, 256, 512),
    ("s4_c2", 10 * 8, 3, 512, 512),
]
_FPN = [
    ("fpn_lat", 20 * 15, 1, 512 + 256, 256),
    ("fpn_fuse", 20 * 15, 3, 256, 256),
]
_HEADS = [
    ("det_c1", 20 * 15, 3, 256, 256),
    ("det_out", 20 * 15, 1, 256, 6 * 9),      # 9 anchors x (4+1+1)
    ("lane_c1", 20 * 15, 3, 256, 128),
    ("lane_out", 20 * 15, 1, 128, 8),
    ("tl_c1", 20 * 15, 3, 256, 128),
    ("tl_out", 20 * 15, 1, 128, 16),
]


def hydranet_task(batch: int = 1) -> Task:
    ops = []
    first = True
    for name, spatial, k, cin, cout in _BACKBONE + _FPN:
        ops.append(GemmOp(name, M=spatial * batch, K=cin * k * k, N=cout,
                          chained=not first, epilogue_flops_per_elem=1))
        first = False
    for j, (name, spatial, k, cin, cout) in enumerate(_HEADS):
        # each head re-reads the shared FPN features: only the op directly
        # following the trunk keeps the chain.
        ops.append(GemmOp(name, M=spatial * batch, K=cin * k * k, N=cout,
                          chained=(j % 2 == 1),     # within-head chain
                          epilogue_flops_per_elem=1))
    return Task(f"hydranet_b{batch}", ops)
