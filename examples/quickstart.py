"""Quickstart: optimize a ViT inference schedule on a 4x4 MCM with
MCMComm — the paper's core use-case in ~20 lines.

    PYTHONPATH=src python examples/quickstart.py
"""
from repro.core import make_hw, optimize
from repro.core.miqp import MIQPConfig
from repro.graphs import vit_task


def main():
    task = vit_task(batch=1)               # ViT-B/16 as a GEMM chain
    hw = make_hw("A", grid=4, memory="hbm")  # SIMBA-like corner-HBM MCM

    print(hw.topology.describe())
    print(f"\nworkload: {task.name}, {len(task)} GEMMs, "
          f"{task.total_flops/1e9:.1f} GFLOPs")

    for method in ("baseline", "simba", "ga", "miqp"):
        r = optimize(task, hw, method, "latency",
                     miqp_config=MIQPConfig(time_limit=30))
        print(f"  {method:<9} latency={r.latency*1e6:9.1f} us  "
              f"EDP={r.edp:.3e}  speedup={r.speedup_vs_baseline:5.2f}x  "
              f"(solve {r.solve_seconds:.1f}s)")

    # The MIQP has two solver engines (DESIGN.md §12): engine="milp" is
    # the Sec.-6.3 HiGHS program under a wall-clock budget;
    # engine="lattice" — what "auto" picked above — enumerates the
    # Sec.-6.2 search lattice and arg-mins the exact evaluator over
    # batched jitted scoring chunks (EDP scored directly, no ε-sweep).
    best = optimize(task, hw, "miqp", "latency",
                    miqp_config=MIQPConfig(engine="lattice"))
    print(f"\nmiqp engine=lattice: latency={best.latency*1e6:.1f} us "
          f"({best.speedup_vs_baseline:.2f}x vs LS)")
    pipe = best.pipeline(batch=8)
    print(f"with cross-sample pipelining (batch 8): "
          f"{pipe.speedup:.2f}x additional throughput")


if __name__ == "__main__":
    main()
