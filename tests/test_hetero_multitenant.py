"""Heterogeneous chiplet grids + multi-tenant placement (DESIGN.md §18).

Covers the hardware-is-data refactor end to end:

  * migration gate — a one-class heterogeneous config broadcast over the
    grid is *bitwise* identical to the legacy scalar config across every
    engine family (evaluator regime/flow × numpy/jax, GA, MIQP lattice,
    pipelining, co-search);
  * drift gates — every ``HWConfig`` dataclass field must appear in
    ``__getstate__`` and perturb the §9 sweep fingerprint;
  * validation — hetero field checks at construction and again at the
    serve-layer BadRequest firewall (unpickling bypasses
    ``__post_init__``);
  * waterfilling — per-link capacity conservation under hetero caps;
  * multi-tenant — band enumeration properties (disjoint, covering,
    even split always present) and the never-worse-than-even-split
    search invariant.
"""
import dataclasses
import pickle

import numpy as np
import pytest

from _hypothesis_compat import given, settings, st
from repro.core import (ChipletClass, EvalOptions, Evaluator, GemmOp,
                        HWConfig, MultiTenantConfig, Task,
                        uniform_partition)
from repro.core import multitenant, netsim, sweep
from repro.core.cosearch import CoSearchConfig
from repro.core.ga import GAConfig
from repro.core.hw import TABLE2
from repro.core.miqp import MIQPConfig, run_miqp
from repro.core.pipelining import pipeline_batch
from repro.serve.coalesce import BadRequest, OptRequest


def toy_task(n=3, m=512, name=None):
    ops = [GemmOp("g0", M=m, K=256, N=512)]
    for i in range(1, n):
        ops.append(GemmOp(f"g{i}", M=m, K=ops[-1].N, N=512, chained=True))
    return Task(name or f"toy{n}_{m}", ops)


def broadcast_hw(**kw):
    """One default class on every chiplet — must equal HWConfig(**kw)
    bitwise everywhere (the migration gate)."""
    hw = HWConfig(**kw)
    return HWConfig.hetero([ChipletClass()], [0] * (hw.X * hw.Y), **kw)


def two_class_hw(**kw):
    fast = ChipletClass("fast", freq_hz=2e9, bw_nop=120e9)
    slow = ChipletClass("slow", freq_hz=0.5e9, bw_nop=30e9,
                        mem_scale=0.5)
    hw = HWConfig(**kw)
    half = hw.X * hw.Y // 2
    return HWConfig.hetero([fast, slow], [0] * half + [1] * half, **kw)


@pytest.fixture(autouse=True)
def _fresh_cache():
    sweep.clear_cache()
    yield
    sweep.clear_cache()


# ------------------------------------------------------------ validation
def test_chiplet_class_validation():
    ChipletClass().validate()  # defaults valid
    for bad in (dict(bw_nop=0.0), dict(bw_nop=-1.0),
                dict(freq_hz=float("nan")), dict(freq_hz=float("inf")),
                dict(mem_scale=0.0), dict(freq_hz=True)):
        with pytest.raises(ValueError):
            ChipletClass(**bad)


def test_hetero_validation_rejections():
    c = ChipletClass()
    with pytest.raises(ValueError, match="set.*together"):
        HWConfig(chiplet_classes=(c,))
    with pytest.raises(ValueError, match="set.*together"):
        HWConfig(class_assignment=(0,) * 16)
    with pytest.raises(ValueError, match="X\\*Y=16"):
        HWConfig.hetero([c], [0] * 5)
    with pytest.raises(ValueError, match="out of range"):
        HWConfig.hetero([c], [0] * 15 + [1])
    with pytest.raises(ValueError, match="ChipletClass"):
        HWConfig(chiplet_classes=("fast",), class_assignment=(0,) * 16)
    with pytest.raises(ValueError, match="finite positive"):
        HWConfig(bw_nop=-1.0)


def test_hetero_accepts_lists_and_numpy_indices():
    c = ChipletClass()
    hw = HWConfig(chiplet_classes=[c],
                  class_assignment=list(np.zeros(16, dtype=np.int64)))
    assert hw.chiplet_classes == (c,)
    assert hw.class_assignment == (0,) * 16
    assert hw == broadcast_hw()  # normalization → hashable equality
    assert hash(hw) == hash(broadcast_hw())


def test_rate_views_shapes_and_values():
    hw = two_class_hw()
    assert hw.is_hetero
    assert hw.bw_nop_xy.shape == (4, 4)
    np.testing.assert_array_equal(hw.bw_nop_xy[:2], 120e9)
    np.testing.assert_array_equal(hw.bw_nop_xy[2:], 30e9)
    np.testing.assert_array_equal(hw.freq_xy[:2], 2e9)
    np.testing.assert_array_equal(hw.mem_scale_xy[2:], 0.5)
    homo = HWConfig()
    assert not homo.is_hetero
    np.testing.assert_array_equal(homo.bw_nop_xy,
                                  np.full((4, 4), homo.bw_nop))


# ----------------------------------------------------------- drift gates
def test_getstate_covers_every_declared_field():
    """New HWConfig fields must join the pickle state (the sweep-cache
    store round-trips configs by value) — this fails the moment a field
    is added without extending the declared-field contract."""
    hw = two_class_hw()
    state = hw.__getstate__()
    names = {f.name for f in dataclasses.fields(HWConfig)}
    assert set(state) == names
    clone = pickle.loads(pickle.dumps(hw))
    assert clone == hw and hash(clone) == hash(hw)
    assert "topology" not in pickle.dumps(hw).decode("latin1")


# Two valid HWConfigs differing ONLY in the named field. Every dataclass
# field needs a row: the test below fails on a new field until a
# fingerprint-sensitivity witness is added — which is exactly the moment
# to check the new axis actually reaches the §9 cache key.
_BASE2 = dict(chiplet_classes=(ChipletClass(), ChipletClass(bw_nop=3e10)),
              class_assignment=(0,) * 16)
_FP_VARIANTS = {
    "bw_nop": ({}, {"bw_nop": 2 * TABLE2["bw_nop"]}),
    "bw_mem": ({}, {"bw_mem": 2 * TABLE2["bw_hbm"]}),
    "X": ({}, {"X": 5}),
    "Y": ({}, {"Y": 5}),
    "R": ({}, {"R": 8}),
    "C": ({}, {"C": 8}),
    "mcm_type": ({}, {"mcm_type": "B"}),
    "diagonal_links": ({}, {"diagonal_links": True}),
    "freq_hz": ({}, {"freq_hz": 2 * TABLE2["freq_hz"]}),
    "bytes_per_elem": ({}, {"bytes_per_elem": 2}),
    "e_nop_bit_hop": ({}, {"e_nop_bit_hop": 1e-12}),
    "e_mem_bit": ({}, {"e_mem_bit": 1e-12}),
    "e_sram_bit": ({}, {"e_sram_bit": 1e-12}),
    "e_mac_cycle": ({}, {"e_mac_cycle": 1e-12}),
    "chiplet_classes": (
        _BASE2,
        {**_BASE2,
         "chiplet_classes": (ChipletClass(bw_nop=4.5e10),
                             ChipletClass(bw_nop=3e10))}),
    "class_assignment": (
        _BASE2, {**_BASE2, "class_assignment": (1,) * 16}),
}


def test_fingerprint_covers_every_hw_field():
    task = toy_task(2)
    missing = ({f.name for f in dataclasses.fields(HWConfig)}
               - set(_FP_VARIANTS))
    assert not missing, (
        f"HWConfig grew fields {sorted(missing)} with no fingerprint "
        f"witness — add a _FP_VARIANTS row proving the new axis reaches "
        f"the sweep cache key")
    for field, (kw_a, kw_b) in _FP_VARIANTS.items():
        hw_a, hw_b = HWConfig(**kw_a), HWConfig(**kw_b)
        assert getattr(hw_a, field) != getattr(hw_b, field), field
        fa = sweep._point_fingerprint(
            sweep.EvalPoint(task, hw_a), "numpy")
        fb = sweep._point_fingerprint(
            sweep.EvalPoint(task, hw_b), "numpy")
        assert fa != fb, f"fingerprint blind to HWConfig.{field}"


def test_netsim_fingerprint_handles_hetero_rates():
    scalar = netsim.MeshNet(4, 4, 256e9 / 2, 8e12, [0])
    caps = np.linspace(1e9, 2e9, 16)
    het = netsim.MeshNet(4, 4, caps, 8e12, [0],
                         mem_scale=np.linspace(0.5, 1.0, 16))
    fp_s = sweep._netsim_fingerprint(scalar, 1e6, "numpy")
    fp_h = sweep._netsim_fingerprint(het, 1e6, "numpy")
    assert fp_s != fp_h
    assert hash(fp_h) == hash(fp_h)  # tuple is hashable (tobytes, not array)


# -------------------------------------- migration gate: bitwise parity
def _assert_records_bitwise(ra, rb):
    assert set(ra) == set(rb)
    for k in ra:
        va, vb = ra[k], rb[k]
        if isinstance(va, np.ndarray):
            np.testing.assert_array_equal(va, vb, err_msg=k)
        elif isinstance(va, float):
            assert va == vb, (k, va, vb)


@pytest.mark.parametrize("backend", ["numpy", "jax"])
@pytest.mark.parametrize("congestion", ["regime", "flow"])
def test_eval_parity_broadcast_vs_scalar(backend, congestion):
    opts = EvalOptions(redistribution=True, async_exec=True,
                       congestion=congestion)
    task = toy_task(3)
    for mcm in ("A", "B"):
        hw_s = HWConfig(mcm_type=mcm)
        hw_b = broadcast_hw(mcm_type=mcm)
        rec_s, = sweep.eval_sweep([sweep.EvalPoint(task, hw_s, opts)],
                                  backend=backend, cache=False)
        rec_b, = sweep.eval_sweep([sweep.EvalPoint(task, hw_b, opts)],
                                  backend=backend, cache=False)
        _assert_records_bitwise(rec_s, rec_b)


def test_eval_hetero_actually_differs():
    """Guard against the parity gate passing vacuously: a genuinely
    heterogeneous grid must change the score."""
    task = toy_task(3)
    r_s, = sweep.eval_sweep([sweep.EvalPoint(task, HWConfig())],
                            backend="numpy", cache=False)
    r_h, = sweep.eval_sweep([sweep.EvalPoint(task, two_class_hw())],
                            backend="numpy", cache=False)
    assert r_s["edp"] != r_h["edp"]


def test_ga_parity_broadcast_vs_scalar():
    cfg = GAConfig(population=16, generations=8, elite=2, patience=4,
                   seed=0)
    task = toy_task(2)
    rec_s, = sweep.solve_grid([sweep.EvalPoint(task, HWConfig())],
                              objective="edp", cfg=cfg, backend="jax",
                              cache=False)
    rec_b, = sweep.solve_grid([sweep.EvalPoint(task, broadcast_hw())],
                              objective="edp", cfg=cfg, backend="jax",
                              cache=False)
    assert rec_s.objective == rec_b.objective
    np.testing.assert_array_equal(rec_s.partition.Px, rec_b.partition.Px)
    np.testing.assert_array_equal(rec_s.partition.Py, rec_b.partition.Py)
    np.testing.assert_array_equal(rec_s.redist_mask, rec_b.redist_mask)


def test_miqp_lattice_parity_broadcast_vs_scalar():
    cfg = MIQPConfig(engine="lattice", candidate_budget=512,
                     eval_budget=2048, beam_width=4, refine_sweeps=1,
                     pair_refine=8, descent_sweeps=2,
                     max_axis_candidates=16, max_layer_candidates=32,
                     score_chunk=256, backend="numpy")
    task = toy_task(2)
    rec_s = run_miqp(task, HWConfig(), "edp", cfg=cfg)
    rec_b = run_miqp(task, broadcast_hw(), "edp", cfg=cfg)
    assert rec_s.objective == rec_b.objective
    np.testing.assert_array_equal(rec_s.partition.Px, rec_b.partition.Px)
    np.testing.assert_array_equal(rec_s.partition.Py, rec_b.partition.Py)


def test_cosearch_parity_broadcast_vs_scalar():
    cfg = CoSearchConfig(population=16, generations=8, batch=2,
                         archive_size=8, seed=0)
    task = toy_task(2)
    rec_s, = sweep.cosearch_sweep([sweep.EvalPoint(task, HWConfig())],
                                  objective="edp", cfg=cfg, cache=False)
    rec_b, = sweep.cosearch_sweep(
        [sweep.EvalPoint(task, broadcast_hw())],
        objective="edp", cfg=cfg, cache=False)
    assert (rec_s.objective, rec_s.edp, rec_s.latency, rec_s.energy) \
        == (rec_b.objective, rec_b.edp, rec_b.latency, rec_b.energy)
    np.testing.assert_array_equal(rec_s.partition.Px, rec_b.partition.Px)
    assert rec_s.diagonal == rec_b.diagonal


def test_pipelining_parity_broadcast_vs_scalar():
    task = toy_task(3)
    segs = []
    for hw in (HWConfig(), broadcast_hw()):
        res = Evaluator(task, hw).evaluate(
            uniform_partition(task, hw.X, hw.Y))
        segs.append(res.segments())
    assert segs[0] == segs[1]  # durations bitwise equal
    pa = pipeline_batch(segs[0], batch=4)
    pb = pipeline_batch(segs[1], batch=4)
    assert (pa.sequential, pa.pipelined) == (pb.sequential, pb.pipelined)


def test_milp_engine_rejects_hetero():
    with pytest.raises(ValueError, match="homogeneous"):
        run_miqp(toy_task(2), two_class_hw(), engine="milp")


# -------------------------------------------------- hetero waterfilling
@settings(max_examples=20, deadline=None)
@given(st.integers(0, 2 ** 32 - 1))
def test_waterfill_conserves_per_link_capacity(seed):
    """No link ever carries more than cap × latency, for arbitrary
    per-chiplet NoP rates and per-port memory scales."""
    rng = np.random.default_rng(seed)
    X = Y = 3
    caps_nop = rng.uniform(1e9, 8e9, X * Y)
    mem_scale = rng.uniform(0.25, 1.0, X * Y)
    net = netsim.MeshNet(X, Y, caps_nop, 16e9, [0, 4],
                         mem_scale=mem_scale)
    inc = net.pull_incidence()
    cap = net.link_caps()
    demand = rng.uniform(0.0, 1e6, X * Y)
    demand[rng.uniform(size=X * Y) < 0.3] = 0.0
    if not demand.any():
        demand[0] = 1e6
    out = netsim.simulate_flows(inc, cap, demand)
    lat = out["latency"]
    assert lat > 0
    assert (out["link_bytes"] <= cap * lat * (1 + 1e-9) + 1e-6).all()
    # every flow's bytes arrived
    assert out["done"][demand > 0].max() <= lat * (1 + 1e-12)


def test_mesh_links_run_at_min_endpoint_rate():
    caps_nop = np.arange(1, 17, dtype=float) * 1e9
    net = netsim.MeshNet(4, 4, caps_nop, 8e12, [0])
    for (u, v), c in net.cap.items():
        if net.mem in (u, v):
            continue
        assert c == min(caps_nop[u], caps_nop[v])


# ----------------------------------------------------- tenant geometry
@settings(max_examples=50, deadline=None)
@given(st.integers(1, 8), st.integers(1, 8), st.integers(1, 40))
def test_band_assignments_disjoint_and_covering(X, T, cap):
    if T > X:
        with pytest.raises(ValueError):
            multitenant.band_assignments(X, T, cap)
        return
    asg = multitenant.band_assignments(X, T, cap)
    assert 1 <= len(asg) <= cap
    even = multitenant.even_split_assignment(X, T)
    assert even in asg  # baseline always in the candidate set
    for bands in asg:
        assert len(bands) == T
        edges = [0] + [b[1] for b in bands]
        for (x0, x1), e in zip(bands, edges):
            assert x0 == e and x1 > x0  # contiguous, non-empty, ordered
        assert bands[-1][1] == X  # covering


def test_region_hw_slices_assignment_and_shares_bw():
    hw = two_class_hw()
    top = multitenant.region_hw(hw, 0, 2)
    bot = multitenant.region_hw(hw, 2, 4)
    assert top.X == bot.X == 2 and top.Y == 4
    assert top.bw_mem == bot.bw_mem == hw.bw_mem / 2
    assert set(top.class_assignment) == {0}
    assert set(bot.class_assignment) == {1}
    top.validate()
    with pytest.raises(ValueError):
        multitenant.region_hw(hw, 3, 3)
    homo = multitenant.region_hw(HWConfig(), 1, 4)
    assert homo.X == 3 and not homo.is_hetero


# --------------------------------------------------- multi-tenant search
def test_solve_multitenant_never_worse_than_even_split():
    tasks = [toy_task(2, 256, "tenant_a"), toy_task(3, 512, "tenant_b")]
    hw = two_class_hw()
    cfg = MultiTenantConfig(method="uniform")
    res = multitenant.solve_multitenant(tasks, hw, objective="edp",
                                        cfg=cfg)
    assert res.objective <= res.baseline["edp"]
    assert res.objective == res.edp == res.energy * res.latency
    assert len(res.assignment) == len(res.partitions) == 2
    assert res.latency == max(d["latency"] for d in res.per_tenant)
    assert res.energy == sum(d["energy"] for d in res.per_tenant)
    assert all(d["slowdown"] >= 1.0 for d in res.per_tenant)
    # scores are JSON-clean host floats (artifact contract)
    for d in (*res.per_tenant, res.baseline):
        for k, v in d.items():
            if k != "assignment":
                assert type(v) is float, (k, type(v))
    # asymmetric hetero grid: the search should strictly beat even split
    assert res.objective < res.baseline["edp"]
    assert res.assignment != res.baseline["assignment"]


def test_solve_multitenant_with_ga_inner_engine():
    """The solver branch of _solve_tenants: every tenant region is
    searched through sweep.solve_grid and decoded by the shared
    _decode_schedule path."""
    tasks = [toy_task(2, 256, "ga_a"), toy_task(2, 512, "ga_b")]
    cfg = MultiTenantConfig(
        method="ga", cfg=GAConfig(population=16, generations=4,
                                  patience=2, seed=0))
    res = multitenant.solve_multitenant(tasks, two_class_hw(),
                                        objective="edp", cfg=cfg)
    assert res.objective <= res.baseline["edp"]
    assert res.evaluations > 0
    for part, (x0, x1) in zip(res.partitions, res.assignment):
        assert part.Px.shape[1] == (x1 - x0)  # searched inside the band


def test_multitenant_sweep_caches_bitwise():
    pts = [sweep.MultiTenantPoint(
        (toy_task(2, 256), toy_task(2, 512)), two_class_hw())]
    cfg = MultiTenantConfig(method="uniform")
    r1, = sweep.multitenant_sweep(pts, cfg=cfg)
    before = sweep.cache_stats()
    r2, = sweep.multitenant_sweep(pts, cfg=cfg)
    after = sweep.cache_stats()
    assert after["hits"] > before["hits"]
    assert r1.objective == r2.objective
    assert r1.assignment == r2.assignment
    r2.baseline["edp"] = -1.0  # returned records are copies
    r3, = sweep.multitenant_sweep(pts, cfg=cfg)
    assert r3.baseline["edp"] == r1.baseline["edp"]


def test_solve_grid_routes_multitenant():
    pts = [sweep.MultiTenantPoint(
        (toy_task(2, 256),), HWConfig(X=2))]
    rec, = sweep.solve_grid(pts, objective="edp",
                            cfg=MultiTenantConfig(method="uniform"),
                            method="multitenant")
    assert isinstance(rec, multitenant.MultiTenantResult)


def test_solve_multitenant_rejects_bad_inputs():
    with pytest.raises(ValueError, match="objective"):
        multitenant.solve_multitenant([toy_task(2)], HWConfig(),
                                      objective="speed")
    with pytest.raises(ValueError, match="at least one"):
        multitenant.solve_multitenant([], HWConfig())
    with pytest.raises(ValueError, match="row"):
        multitenant.solve_multitenant([toy_task(2)] * 5, HWConfig())
    with pytest.raises(ValueError, match="unknown tenant method"):
        MultiTenantConfig(method="annealing")


# --------------------------------------------------- serve-layer firewall
def _mt_request(pt, **kw):
    kw.setdefault("cfg", MultiTenantConfig(method="uniform"))
    return OptRequest(kind="solve", method="multitenant", point=pt,
                      objective="edp", **kw)


def test_firewall_accepts_valid_multitenant_request():
    pt = sweep.MultiTenantPoint(
        (toy_task(2, 256), toy_task(2, 512)), two_class_hw())
    req = _mt_request(pt)
    req.validate()
    sig = req.shape_signature()
    assert sig[1] == "multitenant" and sig[2] == (2, 2)


def test_firewall_rejects_corrupted_hetero_fields():
    """Unpickling bypasses __post_init__ — the firewall must re-run the
    field validation on request ingress."""
    pt = sweep.MultiTenantPoint((toy_task(2),), two_class_hw())
    bad_hw = pickle.loads(pickle.dumps(pt.hw))
    object.__setattr__(bad_hw, "class_assignment", (0,) * 5)
    bad_pt = sweep.MultiTenantPoint(pt.tasks, bad_hw)
    with pytest.raises(BadRequest, match="X\\*Y=16"):
        _mt_request(bad_pt).validate()
    object.__setattr__(bad_hw, "class_assignment", (0,) * 16)
    object.__setattr__(bad_hw, "bw_nop", -5.0)
    with pytest.raises(BadRequest, match="finite positive"):
        _mt_request(sweep.MultiTenantPoint(pt.tasks, bad_hw)).validate()


def test_firewall_rejects_malformed_multitenant_points():
    hw = HWConfig()
    with pytest.raises(BadRequest, match="MultiTenantPoint"):
        _mt_request(sweep.EvalPoint(toy_task(2), hw)).validate()
    with pytest.raises(BadRequest, match="non-empty"):
        _mt_request(sweep.MultiTenantPoint((), hw)).validate()
    with pytest.raises(BadRequest, match="Task"):
        _mt_request(
            sweep.MultiTenantPoint(("not-a-task",), hw)).validate()
    with pytest.raises(BadRequest, match="row"):
        _mt_request(sweep.MultiTenantPoint(
            tuple(toy_task(2, name=f"t{i}") for i in range(5)),
            hw)).validate()
    with pytest.raises(BadRequest, match="cfg"):
        _mt_request(sweep.MultiTenantPoint((toy_task(2),), hw),
                    cfg=GAConfig()).validate()


def test_eval_firewall_also_checks_hw():
    hw = pickle.loads(pickle.dumps(two_class_hw()))
    object.__setattr__(hw, "chiplet_classes", ())
    req = OptRequest(kind="eval", point=sweep.EvalPoint(toy_task(2), hw),
                     backend="numpy")
    with pytest.raises(BadRequest, match="invalid hardware config"):
        req.validate()
