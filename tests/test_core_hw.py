"""Topology / hop-formula invariants (paper Sec. 4.1/4.3, 5.1)."""
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core.hw import HWConfig, MCMType, make_hw


@pytest.mark.parametrize("t", ["A", "B", "C", "D"])
@pytest.mark.parametrize("grid", [2, 4, 5, 8])
def test_topology_basics(t, grid):
    hw = make_hw(t, grid)
    top = hw.topology
    assert top.entrance_id.shape == (grid, grid)
    assert (top.x_local >= 0).all() and (top.y_local >= 0).all()
    # every chiplet maps to a real entrance
    assert top.entrance_id.max() < top.n_entrances
    # entrance chiplets are their own entrance (distance 0)
    for e, (ex, ey, kind) in enumerate(top.entrances):
        assert top.entrance_id[ex, ey] == e or (
            top.x_local[ex, ey] + top.y_local[ex, ey] == 0)


def test_type_a_indexing_matches_paper():
    """Type A: local index = global index (corner global chiplet)."""
    top = make_hw("A", 4).topology
    gx, gy = np.meshgrid(np.arange(4), np.arange(4), indexing="ij")
    assert (top.x_local == gx).all()
    assert (top.y_local == gy).all()
    assert top.n_entrances == 1


def test_hop_formulas_eq10_11_12():
    top = make_hw("A", 5).topology
    x, y = top.x_local, top.y_local
    assert (top.hops_low == x + y).all()
    assert (top.hops_row_shared == 5 + y).all()      # eq. 11: X + y
    assert (top.hops_col_shared == 5 + x).all()      # eq. 12: Y + x


def test_diagonal_links_hop_formula():
    """Sec. 5.1.1: with diagonals, hops = min(X+y, X−x+max(x,y))."""
    plain = make_hw("A", 5).topology
    diag = make_hw("A", 5, diagonal_links=True).topology
    x, y = plain.x_local, plain.y_local
    expect = np.minimum(5 + y, 5 - x + np.maximum(x, y))
    assert (diag.hops_row_shared == expect).all()
    assert (diag.hops_row_shared <= plain.hops_row_shared).all()


def test_diagonal_entrance_bandwidth_50pct():
    """The paper's '50% more bandwidth on the bottleneck': corner entrance
    links go from 2 to 3."""
    assert make_hw("A", 4).topology.entrance_links[0] == 2
    assert make_hw("A", 4, diagonal_links=True).topology.entrance_links[0] \
        == 3


def test_type_c_zero_hops():
    top = make_hw("C", 4).topology
    assert (top.hops_low == 0).all()
    assert top.n_entrances == 16


def test_type_d_near_uniform():
    """Paper Sec. 7.1: type-D memory distance is almost uniform at 4x4."""
    top = make_hw("D", 4).topology
    dist = top.x_local + top.y_local
    assert dist.max() <= 1


@settings(max_examples=50, deadline=None)
@given(grid=st.integers(2, 8), t=st.sampled_from(["A", "B", "C", "D"]))
def test_hops_nonnegative_and_bounded(grid, t):
    top = make_hw(t, grid).topology
    for h in (top.hops_low, top.hops_row_shared, top.hops_col_shared):
        assert (h >= 0).all()
        assert h.max() <= 3 * grid


def test_bad_config_rejected():
    with pytest.raises(ValueError):
        HWConfig(X=0)
    with pytest.raises(ValueError):
        HWConfig(R=0)
