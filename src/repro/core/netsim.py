"""Flow-level NoP network simulator — reproduces the paper's Fig. 3
motivation study (done there with ASTRA-sim).

Model: a 2-D mesh of chiplets with dimension-ordered (row-first) XY
routing, plus a memory node attached to one or more chiplets through its
memory-interface link (capacity = memory bandwidth). All chiplets
concurrently pull a fixed message from memory; flows share links by
max-min fair allocation, advanced event-by-event until completion.

Two engines share the :mod:`repro.core.topology` link graph (DESIGN.md
§11):

  * ``engine="event"`` — the original per-flow progressive-filling loop
    over dict-keyed links; the behavioral reference.
  * ``engine="vectorized"`` (default) — flows become one dense
    ``[n_flows, n_links]`` route-incidence matrix and each event step
    solves the max-min waterfilling fixed point with array ops
    (:func:`waterfill_rates` / :func:`simulate_flows`). Completion times
    match the event engine to float64 round-off; the same array program,
    ported to a jitted ``lax.while_loop`` in
    :mod:`repro.core.netsim_jax`, batches whole
    (mesh × memory × placement × bandwidth) grids in one compiled call.

This reproduces the paper's three observations:
  * DRAM (low BW): the memory link is the bottleneck — doubling NoP
    bandwidth yields no improvement (Fig. 3a/d).
  * HBM (high BW): congestion moves onto the mesh links near the
    attachment point — latency scales linearly with NoP BW (Fig. 3b/d).
  * Central placement balances the mesh load (12 flows on the hottest
    corner link vs 8 centrally) — ≈1.5× over peripheral for HBM
    (paper: 1.53×, Fig. 3c/d).
"""
from __future__ import annotations

import dataclasses

import numpy as np

from .topology import MeshGraph

__all__ = [
    "MeshNet",
    "simulate_pull",
    "simulate_flows",
    "waterfill_rates",
    "fig3_case",
    "fig3_net",
]

GB = 1e9

#: A flow is "finished" below this many bytes (absolute, matches the
#: historical event-driven threshold).
EPS_BYTES = 1e-6

#: Event-loop iteration guard — the simulation must converge long before.
MAX_EVENTS = 10000

ENGINES = ("vectorized", "event")


@dataclasses.dataclass
class Flow:
    dst: int
    bytes_left: float
    route: list[tuple[int, int]]   # list of directed link keys
    done_at: float | None = None


class MeshNet:
    """X×Y mesh + memory node (id = X*Y) attached to ``attach`` chiplets.

    Geometry (link enumeration, XY routing) comes from
    :class:`repro.core.topology.MeshGraph`; this class binds capacities
    and the attachment set to it. ``cap`` keeps the historical dict form
    (mesh links + the attach ports only) for the event engine and the
    utilization reports; the vectorized engine reads the dense
    ``link_caps`` array over the full batchable link space.
    """

    def __init__(self, X: int, Y: int, bw_nop, bw_mem: float,
                 attach: list[int], mem_scale=None):
        self.X, self.Y = X, Y
        self.graph = MeshGraph(X, Y)
        self.mem = self.graph.mem
        self.attach = attach
        # ``bw_nop`` may be per-chiplet (``[X, Y]`` or ``[X·Y]``) for
        # heterogeneous grids; a mesh link runs at the min of its
        # endpoint rates. Scalars keep the historical float attribute.
        b = np.asarray(bw_nop, dtype=np.float64)
        self.bw_nop = float(b) if b.ndim == 0 else b.reshape(-1)
        self.bw_mem = float(bw_mem)
        self.mem_scale = (None if mem_scale is None
                          else np.asarray(mem_scale,
                                          dtype=np.float64).reshape(-1))
        per_node = (np.full(X * Y, float(bw_nop)) if b.ndim == 0
                    else b.reshape(-1))
        self.cap: dict[tuple[int, int], float] = {}
        for (u, v) in self.graph.links[: self.graph.n_mesh_links_directed]:
            self.cap[(u, v)] = min(per_node[u], per_node[v])
        # memory interface link(s): capacity = memory BW split across ports
        for a in attach:
            share = bw_mem / len(attach)
            if self.mem_scale is not None:
                share = share * self.mem_scale[a]
            self.cap[(self.mem, a)] = share
            self.cap[(a, self.mem)] = share

    def node_rc(self, n: int) -> tuple[int, int]:
        return divmod(n, self.Y)

    def route(self, src: int, dst: int) -> list[tuple[int, int]]:
        """Memory → nearest attach chiplet → XY (row-dimension-first)."""
        if src == self.mem:
            return self.graph.pull_route(self.attach, dst)
        return self.graph.xy_route(src, dst)

    # ------------------------------------------------------- dense views
    def link_caps(self) -> np.ndarray:
        """Capacities over the full :class:`MeshGraph` link space [L]."""
        return self.graph.link_caps(self.bw_nop, self.bw_mem, self.attach,
                                    mem_scale=self.mem_scale)

    def pull_incidence(self) -> np.ndarray:
        """[n_flows, n_links] incidence of the all-chiplets-pull flows."""
        return self.graph.pull_incidence(self.attach)


def _maxmin_rates(flows: list[Flow], cap: dict) -> dict[int, float]:
    """Classic progressive-filling max-min fair allocation (event-engine
    reference; :func:`waterfill_rates` is the array-program equivalent).

    A flow is live while it holds more than ``EPS_BYTES`` — the same
    threshold the event loop uses to retire flows, so a float residue in
    (0, EPS] can never linger as a phantom link user."""
    active = {i for i, f in enumerate(flows) if f.bytes_left > EPS_BYTES}
    residual = dict(cap)
    on_link: dict[tuple[int, int], set[int]] = {}
    for i in active:
        for l in flows[i].route:
            on_link.setdefault(l, set()).add(i)
    rates: dict[int, float] = {}
    unfixed = set(active)
    while unfixed:
        best_share, best_link = None, None
        for l, users in on_link.items():
            live = users & unfixed
            if not live:
                continue
            share = residual[l] / len(live)
            if best_share is None or share < best_share:
                best_share, best_link = share, l
        if best_link is None:
            for i in unfixed:
                rates[i] = float("inf")
            break
        for i in on_link[best_link] & set(unfixed):
            rates[i] = best_share
            unfixed.discard(i)
            for l in flows[i].route:
                residual[l] -= best_share
        residual = {l: max(0.0, v) for l, v in residual.items()}
    return rates


# ----------------------------------------------------- vectorized engine
def waterfill_rates(inc: np.ndarray, cap: np.ndarray,
                    active: np.ndarray) -> np.ndarray:
    """Max-min fair rates by progressive filling, as array ops.

    ``inc`` is the ``[F, L]`` route-incidence matrix, ``cap`` the ``[L]``
    capacities, ``active`` a ``[F]`` bool mask. Each iteration finds the
    bottleneck link (minimum residual fair share), fixes its flows at
    that share, and subtracts; at least one link retires per iteration,
    so the fixed point lands in ≤L steps. Mirrors the event engine's
    :func:`_maxmin_rates` (the max-min allocation is unique, so the two
    agree to float64 round-off)."""
    F, L = inc.shape
    residual = cap.astype(np.float64).copy()
    unfixed = active.astype(bool).copy()
    rates = np.zeros(F, dtype=np.float64)
    for _ in range(L + 1):
        users = unfixed.astype(np.float64) @ inc          # [L]
        live = users > 0
        if not live.any():
            break
        share = np.where(live, residual / np.where(live, users, 1.0),
                         np.inf)
        l = int(np.argmin(share))
        s = share[l]
        newly = unfixed & (inc[:, l] > 0)
        rates[newly] = s
        residual = np.maximum(
            residual - (newly.astype(np.float64) @ inc) * s, 0.0)
        unfixed &= ~newly
    return rates


def simulate_flows(inc: np.ndarray, cap: np.ndarray,
                   message_bytes: np.ndarray) -> dict[str, np.ndarray]:
    """Vectorized event-driven simulation of ``F`` concurrent flows.

    Each event step solves the waterfilling fixed point, advances to the
    next flow completion, and retires finished flows. Returns
    ``latency`` (scalar), per-flow ``done`` times ``[F]`` and per-link
    ``link_bytes`` ``[L]``. This is the numpy reference for the jitted
    :mod:`repro.core.netsim_jax` port — both must agree to float64
    round-off.
    """
    bytes_left = np.asarray(message_bytes, dtype=np.float64).copy()
    F, L = inc.shape
    t = 0.0
    done = np.zeros(F, dtype=np.float64)
    link_bytes = np.zeros(L, dtype=np.float64)
    guard = 0
    while (bytes_left > EPS_BYTES).any():
        guard += 1
        if guard > MAX_EVENTS:
            raise RuntimeError("simulation did not converge")
        active = bytes_left > EPS_BYTES
        rates = waterfill_rates(inc, cap, active)
        pos = active & (rates > 0)
        if not pos.any():
            raise RuntimeError("simulation stalled (zero rates)")
        dt = float(np.min(np.where(
            pos, bytes_left / np.where(pos, rates, 1.0), np.inf)))
        moved = np.where(active, rates * dt, 0.0)
        link_bytes += np.minimum(moved, bytes_left) @ inc
        bytes_left = np.maximum(bytes_left - moved, 0.0)
        newly = active & (bytes_left <= EPS_BYTES)
        done = np.where(newly, t + dt, done)
        t += dt
    return {"latency": t, "done": done, "link_bytes": link_bytes}


def simulate_pull(net: MeshNet, message_bytes: float,
                  engine: str = "vectorized") -> dict[str, object]:
    """All chiplets pull ``message_bytes`` from memory concurrently."""
    if engine not in ENGINES:
        raise ValueError(f"unknown engine {engine!r}; one of {ENGINES}")
    if engine == "vectorized":
        return _simulate_pull_vec(net, message_bytes)
    return _simulate_pull_event(net, message_bytes)


def _simulate_pull_vec(net: MeshNet, message_bytes: float
                       ) -> dict[str, object]:
    inc = net.pull_incidence()
    caps = net.link_caps()
    F = net.X * net.Y
    out = simulate_flows(inc, caps, np.full(F, float(message_bytes)))
    t = out["latency"]
    idx = net.graph.index
    link_bytes = {l: float(out["link_bytes"][idx[l]]) for l in net.cap}
    util = {l: b / (net.cap[l] * t) if t > 0 else 0.0
            for l, b in link_bytes.items()}
    flows = []
    for d in range(F):
        f = Flow(d, 0.0, net.route(net.mem, d))
        f.done_at = float(out["done"][d])
        flows.append(f)
    return {"latency": t, "link_bytes": link_bytes, "link_util": util,
            "flows": flows, "done": out["done"]}


def _simulate_pull_event(net: MeshNet, message_bytes: float
                         ) -> dict[str, object]:
    flows = [Flow(d, message_bytes, net.route(net.mem, d))
             for d in range(net.X * net.Y)]
    t = 0.0
    link_bytes: dict[tuple[int, int], float] = {l: 0.0 for l in net.cap}
    guard = 0
    while any(f.bytes_left > EPS_BYTES for f in flows):
        guard += 1
        if guard > MAX_EVENTS:
            raise RuntimeError("simulation did not converge")
        rates = _maxmin_rates(flows, net.cap)
        # time to next completion
        dt = min(f.bytes_left / rates[i] for i, f in enumerate(flows)
                 if f.bytes_left > EPS_BYTES and rates.get(i, 0) > 0)
        for i, f in enumerate(flows):
            if f.bytes_left > EPS_BYTES:
                moved = rates[i] * dt
                for l in f.route:
                    link_bytes[l] += min(moved, f.bytes_left)
                f.bytes_left = max(0.0, f.bytes_left - moved)
                if f.bytes_left <= EPS_BYTES and f.done_at is None:
                    f.done_at = t + dt
        t += dt
    util = {l: b / (net.cap[l] * t) if t > 0 else 0.0
            for l, b in link_bytes.items()}
    return {"latency": t, "link_bytes": link_bytes, "link_util": util,
            "flows": flows,
            "done": np.array([f.done_at or 0.0 for f in flows])}


def fig3_net(memory: str = "hbm", placement: str = "peripheral",
             bw_nop: float = 60 * GB, X: int = 4, Y: int = 4) -> MeshNet:
    """The mesh of one Fig. 3 cell (DRAM 60 GB/s / HBM 1024 GB/s;
    peripheral = corner attach, central = interior attach)."""
    bw_mem = 1024 * GB if memory.lower() == "hbm" else 60 * GB
    if placement == "peripheral":
        attach = [0]
    elif placement == "central":
        attach = [1 * Y + 1]
    else:
        raise ValueError(placement)
    return MeshNet(X, Y, bw_nop, bw_mem, attach)


def fig3_case(memory: str = "hbm", placement: str = "peripheral",
              bw_nop: float = 60 * GB, message: float = 1 * GB,
              X: int = 4, Y: int = 4,
              engine: str = "vectorized") -> dict[str, object]:
    """One cell of the paper's Fig. 3 study (4×4 mesh, 1 GB pulls,
    DRAM 60 GB/s / HBM 1024 GB/s)."""
    net = fig3_net(memory, placement, bw_nop, X, Y)
    out = simulate_pull(net, message, engine=engine)
    out["memory"] = memory
    out["placement"] = placement
    out["bw_nop"] = bw_nop
    return out
