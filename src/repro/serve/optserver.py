"""Optimization-as-a-service: async co-optimization server with
continuous request batching and a persistent sweep cache (DESIGN.md
§14).

PRs 1–5 turned every MCMComm solver into a batched device-resident
engine behind :mod:`repro.core.sweep`; this module gives those engines a
serving path. Architecture (queue → coalescer → engine worker → cache
store)::

    submit() ──► bounded queue ──► worker thread
                                     │  drain ≤ max_batch requests
                                     │  validate (BadRequest firewall)
                                     │  coalesce by CallKey (§14)
                                     ├─► eval_sweep / solve_grid /
                                     │   pipeline_sweep   (ONE call per
                                     │   group; shape-grouped compiled
                                     │   executions inside)
                                     ├─► futures ◄─ per-request results
                                     └─► CacheStore.append (new
                                         fingerprints only)

Contracts:

* **solo == served** — a request's result is bit-identical to the same
  point solved through a direct solo sweep call: coalescing only routes
  points into the §9 batched calls, whose solo==batched exactness PRs
  1–5 pinned; every budget is a deterministic count, never wall-clock.
* **Bad-request isolation** — malformed requests are rejected with
  :class:`~repro.serve.coalesce.BadRequest` on their own future; the
  worker and the cohort batch keep going.
* **Retry with restore** — a transient engine failure re-runs the
  coalesced call (``max_retries``); persistent failures fall back to
  per-request solo calls so one poisoned request cannot take down its
  cohort, and only the guilty request errors.
* **Crash-safe persistence** — newly computed cache entries append to a
  versioned on-disk store (:mod:`repro.serve.cache_store`) every
  ``flush_every`` batches; a killed server resumes from the store with
  no recomputation of completed points (the chaos test in
  ``tests/test_serve_optserver.py``).

Observability: :meth:`OptServer.stats` reports requests/s, p50/p99
latency, cache hit-rate, coalesce factor, retry/reject/straggler
counts — the straggler EWMA rides
:class:`repro.runtime.fault_tolerance.StragglerMonitor` over batch
wall-times.

CLI demo (closed-loop mixed traffic against an in-process server)::

    PYTHONPATH=src python -m repro.serve.optserver --requests 64 \\
        --store /tmp/sweep-cache.bin
"""
from __future__ import annotations

import argparse
import queue
import threading
import time
from concurrent.futures import Future
from typing import Any

from ..core import sweep
from ..runtime.fault_tolerance import StragglerMonitor
from .cache_store import CacheStore
from .coalesce import BadRequest, CallKey, OptRequest

__all__ = ["OptServer", "ServerOverloaded", "OptRequest", "BadRequest"]


class ServerOverloaded(RuntimeError):
    """Bounded-queue backpressure: the request queue is full."""


class _Pending:
    __slots__ = ("req", "future", "t_submit")

    def __init__(self, req: OptRequest, future: Future, t_submit: float):
        self.req = req
        self.future = future
        self.t_submit = t_submit


class OptServer:
    """Long-running optimization server over the batched sweep engines.

    ``submit`` returns a :class:`concurrent.futures.Future` per request;
    results stream back as the worker completes coalesced batches.
    ``store_path`` enables the persistent cache: loaded into the
    process-wide sweep cache on startup, appended to as requests
    complete, full-saved (atomic rename) on :meth:`close`.
    """

    def __init__(self, store_path: str | None = None,
                 max_queue: int = 256, max_batch: int = 64,
                 max_retries: int = 2, flush_every: int = 1,
                 cache: bool = True,
                 devices: str | None = None,
                 straggler: StragglerMonitor | None = None,
                 autostart: bool = True, log=None):
        self.max_batch = max(1, int(max_batch))
        self.max_retries = max(0, int(max_retries))
        self.flush_every = max(1, int(flush_every))
        self.cache = cache
        # §15 execution knob forwarded to every coalesced sweep call;
        # result-neutral and fingerprint-invisible, so a sharded server
        # shares its store with single-device clients. None defers to
        # each request's options/config.
        self.devices = devices
        self.monitor = straggler or StragglerMonitor()
        self.log = log or (lambda msg: None)
        self._queue: queue.Queue = queue.Queue(maxsize=max_queue)
        self._lock = threading.Lock()
        self._stop = threading.Event()
        self._thread: threading.Thread | None = None
        self._inflight = 0
        self._batches_since_flush = 0
        self._t_start = time.perf_counter()
        self._latencies: list[float] = []
        self._counts = {"submitted": 0, "completed": 0, "failed": 0,
                        "rejected": 0, "retries": 0, "batches": 0,
                        "coalesced": 0, "solo_fallbacks": 0}
        self._cache_base = sweep.cache_stats()

        self._store: CacheStore | None = None
        self._persisted: set = set()
        self.store_info: dict[str, Any] = {"loaded": 0}
        if store_path is not None:
            self._store = CacheStore(store_path)
            entries = self._store.load()
            loaded = sweep.import_cache(entries) if self.cache else 0
            self._persisted = set(entries)
            self.store_info = {"loaded": loaded,
                               "cold_start": self._store.last_load.cold_start,
                               "reason": self._store.last_load.reason,
                               "torn_tail": self._store.last_load.torn_tail}
            if self._store.last_load.cold_start:
                self.log(f"[optserve] cold start: "
                         f"{self._store.last_load.reason}")
            else:
                self.log(f"[optserve] restored {loaded} cache entries")

        # Dispatch table — tests monkeypatch entries to inject transient
        # failures (retry-with-restore) without faking sweep internals.
        self._calls = {"eval": sweep.eval_sweep,
                       "solve": sweep.solve_grid,
                       "pipeline": sweep.pipeline_sweep}
        if autostart:
            self.start()

    # -------------------------------------------------------- lifecycle
    def start(self) -> None:
        if self._thread is not None and self._thread.is_alive():
            return
        self._stop.clear()
        self._thread = threading.Thread(target=self._worker,
                                        name="optserve-worker", daemon=True)
        self._thread.start()

    def drain(self, timeout: float = 60.0) -> bool:
        """Block until every submitted request has resolved (or timeout);
        returns True when drained."""
        deadline = time.perf_counter() + timeout
        while time.perf_counter() < deadline:
            with self._lock:
                idle = self._inflight == 0
            if idle and self._queue.empty():
                return True
            time.sleep(0.002)
        return False

    def close(self, timeout: float = 60.0) -> None:
        """Graceful shutdown: drain, stop the worker, full-save the
        store (atomic rename)."""
        self.drain(timeout)
        self._stop.set()
        if self._thread is not None:
            self._thread.join(timeout=10.0)
        if self._store is not None and self.cache:
            self._store.save(sweep.export_cache())

    def kill(self) -> None:
        """Crash simulation (chaos tests): stop the worker immediately,
        *without* the final save — only incrementally appended entries
        survive, exactly like a SIGKILL between batches."""
        self._stop.set()
        if self._thread is not None:
            self._thread.join(timeout=10.0)

    # ----------------------------------------------------------- submit
    def submit(self, req: OptRequest | None = None, *, block: bool = True,
               timeout: float | None = None, **kw) -> Future:
        """Enqueue one request; returns its future. ``kw`` builds an
        :class:`OptRequest` when ``req`` is not given. A full queue
        raises :class:`ServerOverloaded` (immediately when
        ``block=False``, after ``timeout`` otherwise) — bounded-queue
        backpressure, the client's signal to slow down."""
        if req is None:
            req = OptRequest(**kw)
        fut: Future = Future()
        item = _Pending(req, fut, time.perf_counter())
        try:
            self._queue.put(item, block=block, timeout=timeout)
        except queue.Full:
            raise ServerOverloaded(
                f"request queue full ({self._queue.maxsize}); retry later"
            ) from None
        with self._lock:
            self._counts["submitted"] += 1
            self._inflight += 1
        return fut

    def submit_nowait(self, req: OptRequest | None = None, **kw) -> Future:
        return self.submit(req, block=False, **kw)

    async def submit_async(self, req: OptRequest | None = None,
                           **kw) -> Any:
        """Asyncio adapter: await the served result. The blocking
        backpressure ``put`` runs off-loop."""
        import asyncio

        fut = await asyncio.to_thread(self.submit, req, **kw)
        return await asyncio.wrap_future(fut)

    # ----------------------------------------------------------- worker
    def _worker(self) -> None:
        while not self._stop.is_set():
            try:
                first = self._queue.get(timeout=0.05)
            except queue.Empty:
                continue
            batch = [first]
            while len(batch) < self.max_batch:
                try:
                    batch.append(self._queue.get_nowait())
                except queue.Empty:
                    break
            try:
                self._run_batch(batch)
            except Exception as e:   # pragma: no cover — last-ditch guard
                for p in batch:
                    if not p.future.done():
                        self._resolve(p, failed=True, latency=False)
                        p.future.set_exception(e)

    def _run_batch(self, batch: list[_Pending]) -> None:
        valid: list[_Pending] = []
        for p in batch:
            try:
                p.req.validate()
            except BadRequest as e:
                # counters first: a client that sees the future resolve
                # must already see it reflected in stats()
                self._resolve(p, rejected=True)
                p.future.set_exception(e)
            else:
                valid.append(p)
        if not valid:
            return
        by_key: dict[CallKey, list[_Pending]] = {}
        for p in valid:
            by_key.setdefault(p.req.call_key(), []).append(p)
        for key, items in by_key.items():
            t0 = time.perf_counter()
            self._serve_group(key, items)
            dt = time.perf_counter() - t0
            with self._lock:
                self._counts["batches"] += 1
                self._counts["coalesced"] += len(items)
                n = self._counts["batches"]
            if self.monitor.observe(n - 1, dt):
                self.log(f"[optserve] straggler batch {n - 1}: "
                         f"{dt:.3f}s vs ewma {self.monitor.ewma:.3f}s")
        self._batches_since_flush += 1
        if self._batches_since_flush >= self.flush_every:
            self._flush()
            self._batches_since_flush = 0

    def _dispatch(self, key: CallKey, reqs: list[OptRequest]) -> list:
        pts = [r.point for r in reqs]
        if key.kind == "eval":
            return self._calls["eval"](pts, backend=key.backend,
                                       cache=self.cache,
                                       devices=self.devices)
        if key.kind == "solve":
            return self._calls["solve"](pts, key.objective, key.cfg,
                                        backend=key.backend,
                                        cache=self.cache,
                                        method=key.method,
                                        devices=self.devices)
        return self._calls["pipeline"](pts, key.cfg, backend=key.backend,
                                       cache=self.cache,
                                       devices=self.devices)

    def _serve_group(self, key: CallKey, items: list[_Pending]) -> None:
        """One coalesced call, with retry-with-restore and solo-fallback
        isolation."""
        last_err: Exception | None = None
        for attempt in range(self.max_retries + 1):
            try:
                results = self._dispatch(key, [p.req for p in items])
            except Exception as e:
                last_err = e
                if attempt < self.max_retries:
                    with self._lock:
                        self._counts["retries"] += 1
                    self.log(f"[optserve] {key.kind} batch error "
                             f"{e!r}; retrying "
                             f"({attempt + 1}/{self.max_retries})")
                    continue
                break
            for p, res in zip(items, results):
                self._resolve(p)
                p.future.set_result(res)
            return
        # Retries exhausted: isolate the failure — serve each request
        # solo so only the guilty one errors.
        self.log(f"[optserve] {key.kind} batch failed after "
                 f"{self.max_retries} retries ({last_err!r}); "
                 f"falling back to solo serves")
        with self._lock:
            self._counts["solo_fallbacks"] += 1
        for p in items:
            try:
                res = self._dispatch(key, [p.req])[0]
            except Exception as e:
                self._resolve(p, failed=True)
                p.future.set_exception(e)
            else:
                self._resolve(p)
                p.future.set_result(res)

    def _resolve(self, p: _Pending, failed: bool = False,
                 rejected: bool = False, latency: bool = True) -> None:
        dt = time.perf_counter() - p.t_submit
        with self._lock:
            self._inflight -= 1
            if rejected:
                self._counts["rejected"] += 1
            elif failed:
                self._counts["failed"] += 1
            else:
                self._counts["completed"] += 1
                if latency:
                    self._latencies.append(dt)

    # ------------------------------------------------------ persistence
    def _flush(self) -> None:
        """Append cache entries added since the last flush to the store.
        Append-only + crc-framed records: a crash mid-flush tears at
        most the tail record, which the next load drops."""
        if self._store is None or not self.cache:
            return
        snap = sweep.export_cache()
        new = {k: v for k, v in snap.items() if k not in self._persisted}
        if new:
            self._store.append(new)
            self._persisted.update(new)

    # ------------------------------------------------------------ stats
    def stats(self) -> dict[str, Any]:
        """Service metrics: throughput, latency percentiles, cache
        hit-rate (since server start), coalesce factor, fault counters,
        straggler EWMA state."""
        with self._lock:
            counts = dict(self._counts)
            lat = sorted(self._latencies)
            inflight = self._inflight
        elapsed = time.perf_counter() - self._t_start
        cs = sweep.cache_stats()
        hits = cs["hits"] - self._cache_base["hits"]
        misses = cs["misses"] - self._cache_base["misses"]
        lookups = hits + misses

        def pct(q: float) -> float:
            if not lat:
                return 0.0
            return lat[min(len(lat) - 1, int(q * len(lat)))]

        return {
            **counts,
            "inflight": inflight,
            "elapsed_s": elapsed,
            "requests_per_s": counts["completed"] / elapsed
            if elapsed > 0 else 0.0,
            "p50_ms": pct(0.50) * 1e3,
            "p99_ms": pct(0.99) * 1e3,
            "coalesce_factor": (counts["coalesced"] / counts["batches"]
                                if counts["batches"] else 0.0),
            "cache_hits": hits,
            "cache_misses": misses,
            "cache_hit_rate": hits / lookups if lookups else 0.0,
            "stragglers": len(self.monitor.flagged),
            "batch_ewma_s": self.monitor.ewma,
            "store": dict(self.store_info,
                          persisted=len(self._persisted)),
        }


# ----------------------------------------------------------------- CLI
def _demo_requests(n: int):
    """Mixed closed-loop demo traffic: evaluations across workloads ×
    grids × congestion modes, plus pipelining instances."""
    import numpy as np

    from ..core import EvalOptions, make_hw
    from ..core.workload import uniform_partition
    from ..graphs import WORKLOADS

    rng = np.random.default_rng(0)
    hws = [make_hw(t, g, "hbm") for t in "AB" for g in (2, 4)]
    tasks = [WORKLOADS[w](batch=1) for w in ("alexnet", "vit")]
    reqs = []
    for i in range(n):
        task = tasks[i % len(tasks)]
        hw = hws[i % len(hws)]
        opts = EvalOptions(redistribution=bool(i % 2), async_exec=True)
        if i % 5 == 4:
            segs = [(f"op{j}", float(rng.uniform(0.1, 1)),
                     float(rng.uniform(0.5, 2)),
                     float(rng.uniform(0.1, 1))) for j in range(4)]
            reqs.append(OptRequest(
                "pipeline", sweep.PipelinePoint(segs, 4 + i % 3)))
        else:
            part = uniform_partition(task, hw.X, hw.Y)
            reqs.append(OptRequest(
                "eval", sweep.EvalPoint(task, hw, opts, part)))
    return reqs


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(
        description="MCMComm optimization server demo: serve mixed "
                    "closed-loop traffic in-process and print stats.")
    ap.add_argument("--requests", type=int, default=32)
    ap.add_argument("--store", default=None,
                    help="persistent sweep-cache store path")
    ap.add_argument("--max-batch", type=int, default=64)
    ap.add_argument("--devices", default=None,
                    choices=("single", "sharded", "auto"),
                    help="§15 sweep sharding mode (default: per-request)")
    args = ap.parse_args(argv)

    srv = OptServer(store_path=args.store, max_batch=args.max_batch,
                    devices=args.devices, log=print)
    futs = [srv.submit(r) for r in _demo_requests(args.requests)]
    for f in futs:
        f.result(timeout=300)
    st = srv.stats()
    srv.close()
    print(f"[optserve] served {st['completed']}/{st['submitted']} "
          f"requests in {st['elapsed_s']:.2f}s "
          f"({st['requests_per_s']:.1f} req/s, coalesce "
          f"{st['coalesce_factor']:.1f}x, p50 {st['p50_ms']:.1f}ms "
          f"p99 {st['p99_ms']:.1f}ms, cache hit-rate "
          f"{st['cache_hit_rate'] * 100:.0f}%)")
    if args.store:
        print(f"[optserve] store: {st['store']}")


if __name__ == "__main__":
    main()
