"""Fig. 13 reproduction: ablation of the co-design features.

Paper claims: partition-only optimization gives a relatively small
speedup; adding diagonal links unlocks most of the gain (bypassing
collection congestion + flattening memory-latency non-uniformity);
pipelining adds further latency gains on top.
"""
from __future__ import annotations

import numpy as np

from repro.core import EvalOptions, Evaluator, make_hw, optimize
from repro.core.ga import GAConfig, run_ga
from repro.graphs import WORKLOADS

from .common import emit, save_json, timed

GA_CFG = GAConfig(generations=60, population=64)


def main(fast: bool = False):
    results = {}
    wnames = ("alexnet", "hydranet") if fast else ("alexnet", "vit",
                                                   "hydranet")
    for wname in wnames:
        task = WORKLOADS[wname](batch=1)
        hw_plain = make_hw("A", 4, "hbm")
        hw_diag = make_hw("A", 4, "hbm", diagonal_links=True)
        base = optimize(task, hw_plain, "baseline").baseline.latency
        opts = EvalOptions(redistribution=True, async_exec=True)

        # 1) partitioning only (no diagonal links)
        ga1, us1 = timed(run_ga, task, hw_plain, "latency", opts, GA_CFG)
        # 2) + diagonal links
        ga2, us2 = timed(run_ga, task, hw_diag, "latency", opts, GA_CFG)
        # 3) + pipelining (batch 4)
        ev = Evaluator(task, hw_diag, opts)
        res = ev.evaluate(ga2.partition, ga2.redist_mask)
        from repro.core.pipelining import pipeline_batch
        pipe = pipeline_batch(res.segments(), 4)
        part_sp = base / ga1.objective
        diag_sp = base / ga2.objective
        pipe_sp = base / (pipe.pipelined / 4)

        results[wname] = {"partition": part_sp, "diag": diag_sp,
                          "pipe": pipe_sp}
        emit(f"fig13/{wname}/partition_only", us1, f"{part_sp:.3f}x")
        emit(f"fig13/{wname}/plus_diagonal", us2, f"{diag_sp:.3f}x")
        emit(f"fig13/{wname}/plus_pipelining", 0.0, f"{pipe_sp:.3f}x")
    save_json("fig13", results)


if __name__ == "__main__":
    main()
