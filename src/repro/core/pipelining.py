"""Cross-sample pipelining — paper Sec. 5.4 / Fig. 7 / Fig. 11.

Within one sample the GEMM chain is sequential, but samples of a batch are
independent, so communication of one sample can overlap computation of
another. The paper casts this as a resource-constrained project scheduling
problem (RCPSP) with two unit-capacity resources — the NoP ("comm") and the
chiplet array ("comp") — and solves it with an ILP.

We provide both a priority list scheduler (critical-path-first serial SGS —
instantaneous, used as the feasible incumbent) and a time-indexed MILP via
HiGHS (the paper's ILP, with a wall-clock budget). Durations come from the
evaluator's per-op (comm_in, comp, comm_out) breakdown.
"""
from __future__ import annotations

import dataclasses
import heapq

import numpy as np

__all__ = ["Job", "build_jobs", "list_schedule", "milp_schedule",
           "sequential_makespan", "PipelineResult", "pipeline_batch"]

COMM, COMP = "comm", "comp"


@dataclasses.dataclass
class Job:
    jid: int
    sample: int
    op: int
    kind: str          # "in" | "comp" | "out"
    dur: float
    resource: str      # COMM or COMP
    preds: list[int]


def build_jobs(segments: list[tuple[str, float, float, float]],
               batch: int) -> list[Job]:
    """``segments`` = per-op (name, t_in, t_comp, t_out) for ONE sample."""
    jobs: list[Job] = []
    for s in range(batch):
        prev = -1
        for i, (_, tin, tcomp, tout) in enumerate(segments):
            trip = [("in", tin, COMM), ("comp", tcomp, COMP),
                    ("out", tout, COMM)]
            for kind, dur, res in trip:
                preds = [prev] if prev >= 0 else []
                j = Job(len(jobs), s, i, kind, float(max(dur, 0.0)), res,
                        preds)
                jobs.append(j)
                prev = j.jid
    return jobs


def sequential_makespan(segments, batch: int) -> float:
    return batch * float(sum(t1 + t2 + t3 for _, t1, t2, t3 in segments))


def _critical_path(jobs: list[Job]) -> np.ndarray:
    """Longest path from each job to the sink (priority for the SGS)."""
    succ: dict[int, list[int]] = {j.jid: [] for j in jobs}
    for j in jobs:
        for p in j.preds:
            succ[p].append(j.jid)
    prio = np.zeros(len(jobs))
    for j in reversed(jobs):  # jobs are topologically ordered by build
        tail = max((prio[s] for s in succ[j.jid]), default=0.0)
        prio[j.jid] = j.dur + tail
    return prio


def list_schedule(jobs: list[Job]) -> tuple[float, dict[int, float]]:
    """Serial schedule-generation scheme, critical-path-first."""
    prio = _critical_path(jobs)
    n = len(jobs)
    indeg = {j.jid: len(j.preds) for j in jobs}
    ready_time = {j.jid: 0.0 for j in jobs}
    free = {COMM: 0.0, COMP: 0.0}
    start: dict[int, float] = {}
    done = 0
    # ready heap keyed by (-priority, jid)
    heap = [(-prio[j.jid], j.jid) for j in jobs if indeg[j.jid] == 0]
    heapq.heapify(heap)
    pending: list[tuple[float, int]] = []   # (available_at, jid)
    succ: dict[int, list[int]] = {j.jid: [] for j in jobs}
    for j in jobs:
        for p in j.preds:
            succ[p].append(j.jid)
    byid = {j.jid: j for j in jobs}
    makespan = 0.0
    while done < n:
        if not heap:
            # release the earliest pending job
            t, jid = heapq.heappop(pending)
            heapq.heappush(heap, (-prio[jid], jid))
            continue
        _, jid = heapq.heappop(heap)
        j = byid[jid]
        t0 = max(ready_time[jid], free[j.resource])
        start[jid] = t0
        t1 = t0 + j.dur
        free[j.resource] = t1
        makespan = max(makespan, t1)
        done += 1
        for s in succ[jid]:
            ready_time[s] = max(ready_time[s], t1)
            indeg[s] -= 1
            if indeg[s] == 0:
                heapq.heappush(heap, (-prio[s], s))
    return makespan, start


def milp_schedule(jobs: list[Job], n_buckets: int = 64,
                  time_limit: float = 60.0
                  ) -> tuple[float, dict[int, float] | None]:
    """Time-indexed RCPSP MILP (the paper's ILP). Falls back to the list
    schedule if the model is too large or the solver finds nothing better."""
    import scipy.sparse as sp
    from scipy.optimize import Bounds, LinearConstraint, milp

    ub_makespan, greedy_start = list_schedule(jobs)
    if ub_makespan <= 0:
        return ub_makespan, greedy_start
    active = [j for j in jobs if j.dur > 0]
    if len(active) * n_buckets > 60000:
        return ub_makespan, greedy_start
    dt = ub_makespan / n_buckets
    d = {j.jid: max(1, int(np.ceil(j.dur / dt))) for j in active}
    H = n_buckets + max(d.values())

    nv = 0
    var = {}
    for j in active:
        for t in range(H - d[j.jid] + 1):
            var[j.jid, t] = nv
            nv += 1
    cmax = nv
    nv += 1

    rows, lo, hi = [], [], []

    def add(idx, coef, l, h):
        rows.append((idx, coef))
        lo.append(l)
        hi.append(h)

    for j in active:
        ids = [var[j.jid, t] for t in range(H - d[j.jid] + 1)]
        add(ids, [1.0] * len(ids), 1.0, 1.0)
        # makespan
        add([cmax] + ids,
            [1.0] + [-(t + d[j.jid]) for t in range(len(ids))], 0.0, np.inf)

    # precedence (pred may be zero-duration → collapse to nearest active)
    startexpr = {}
    for j in active:
        startexpr[j.jid] = ([var[j.jid, t]
                             for t in range(H - d[j.jid] + 1)],
                            list(range(H - d[j.jid] + 1)))
    act_ids = {j.jid for j in active}

    def resolve_pred(p):  # walk through zero-duration predecessors
        byid = {j.jid: j for j in jobs}
        stack = [p]
        out = []
        while stack:
            q = stack.pop()
            if q in act_ids:
                out.append(q)
            else:
                stack.extend(byid[q].preds)
        return out

    for j in active:
        for p in j.preds:
            for q in resolve_pred(p):
                ji, jc = startexpr[j.jid]
                qi, qc = startexpr[q]
                add(ji + qi, [float(c) for c in jc] + [-float(c) for c in qc],
                    float(d[q]), np.inf)

    # resource capacity per bucket
    for res in (COMM, COMP):
        members = [j for j in active if j.resource == res]
        for tau in range(H):
            idx = []
            for j in members:
                for t in range(max(0, tau - d[j.jid] + 1),
                               min(tau, H - d[j.jid]) + 1):
                    idx.append(var[j.jid, t])
            if len(idx) > 1:
                add(idx, [1.0] * len(idx), -np.inf, 1.0)

    data, ri, ci = [], [], []
    for r, (idx, coef) in enumerate(rows):
        for jj, a in zip(idx, coef):
            ri.append(r)
            ci.append(jj)
            data.append(a)
    A = sp.csr_matrix((data, (ri, ci)), shape=(len(rows), nv))
    c = np.zeros(nv)
    c[cmax] = 1.0
    integrality = np.ones(nv, dtype=int)
    integrality[cmax] = 0
    res = milp(c=c,
               constraints=LinearConstraint(A, np.array(lo), np.array(hi)),
               integrality=integrality,
               bounds=Bounds(np.zeros(nv),
                             np.concatenate([np.ones(nv - 1), [np.inf]])),
               options={"time_limit": time_limit, "presolve": True})
    if res.x is None:
        return ub_makespan, greedy_start
    ms = float(res.x[cmax]) * dt
    if ms >= ub_makespan:
        return ub_makespan, greedy_start
    starts = {}
    for (jid, t), v in var.items():
        if res.x[v] > 0.5:
            starts[jid] = t * dt
    return ms, starts


@dataclasses.dataclass
class PipelineResult:
    batch: int
    sequential: float
    pipelined: float

    @property
    def speedup(self) -> float:
        return self.sequential / self.pipelined if self.pipelined > 0 else 1.0

    @property
    def per_sample(self) -> float:
        return self.pipelined / self.batch


def pipeline_batch(segments, batch: int, use_milp: bool = False,
                   time_limit: float = 30.0) -> PipelineResult:
    jobs = build_jobs(segments, batch)
    if use_milp:
        ms, _ = milp_schedule(jobs, time_limit=time_limit)
    else:
        ms, _ = list_schedule(jobs)
    return PipelineResult(batch, sequential_makespan(segments, batch), ms)
