"""MIQP engine tests (DESIGN.md §12): lattice-vs-exhaustive parity,
candidate-budget fallback, solve_grid batching/cache isolation, and the
approx_inverse irregular-hardware regression."""
import dataclasses
import itertools

import numpy as np
import pytest

from repro.core import (EvalOptions, Evaluator, GemmOp, Task, make_hw,
                        sweep)
from repro.core.miqp import (MIQPConfig, approx_inverse,
                             resolve_auto_engine, run_miqp)
from repro.core import miqp_jax

OPTS = EvalOptions(redistribution=True, async_exec=False)


def tiny_task():
    """Windows small enough that the joint lattice is brute-forceable."""
    return Task("tiny", [GemmOp("a", M=64, K=64, N=64),
                         GemmOp("b", M=64, K=64, N=96, chained=True)])


def tiny_hw(**kw):
    return make_hw("A", 2, "hbm", **kw)


def brute_force(task, hw, objective, options, slack=2):
    """Independent exhaustive reference: every unit composition in the
    Sec.-6.2 window per op/axis, cross product over ops, scored by the
    exact evaluator with MIQP's fixed collector/redistribution."""
    from repro.core.workload import partition_domain

    ev = Evaluator(task, hw, options)
    lo, hi = partition_domain(task, hw.X, hw.Y, hw.R, hw.C, slack)
    rd = ev.chain_valid & options.redistribution

    def axis(total_units, parts, l, h):
        out = []
        for combo in itertools.product(range(l, h + 1), repeat=parts):
            if sum(combo) == total_units:
                out.append(combo)
        return out

    def unpad(units, unit, total):
        arr = np.asarray(units, dtype=np.int64) * unit
        d = int(arr.sum()) - total
        k = int(np.argmax(arr))
        arr[k] -= d
        if arr[k] < 0:
            arr[k + 1 if k + 1 < len(arr) else k - 1] += arr[k]
            arr[k] = 0
        return arr

    per_op = []
    for i, op in enumerate(task.ops):
        Mu = int(np.ceil(op.M / hw.R))
        Nu = int(np.ceil(op.N / hw.C))
        xs = axis(Mu, hw.X, int(lo[i, 0]), int(hi[i, 0]))
        ys = axis(Nu, hw.Y, int(lo[i, 1]), int(hi[i, 1]))
        per_op.append([(unpad(x, hw.R, op.M), unpad(y, hw.C, op.N))
                       for x in xs for y in ys])

    best = np.inf
    coll = np.full(len(task), hw.Y // 2, dtype=np.int64)
    for combo in itertools.product(*per_op):
        Px = np.stack([c[0] for c in combo])
        Py = np.stack([c[1] for c in combo])
        from repro.core.workload import Partition

        res = ev.evaluate(Partition(Px, Py, coll.copy()), rd)
        val = getattr(res, objective if objective != "edp" else "edp")
        best = min(best, val)
    return best


@pytest.mark.parametrize("objective", ["latency", "edp"])
def test_lattice_matches_bruteforce(objective):
    """Exact mode == an independent exhaustive scan of the window
    lattice (the tentpole's correctness anchor)."""
    task, hw = tiny_task(), tiny_hw(diagonal_links=True)
    cfg = MIQPConfig(backend="numpy")
    r = run_miqp(task, hw, objective, OPTS, cfg, engine="lattice")
    assert r.milp_status.startswith("lattice exact")
    assert "capped" not in r.milp_status
    ref = brute_force(task, hw, objective, OPTS)
    assert r.objective == pytest.approx(ref, rel=1e-12)
    r.partition.validate(task)


def test_lattice_budget_fallback_beam():
    """Forcing the joint cross-product over the candidate budget must
    switch to beam mode and still return a valid schedule no worse than
    the anchor (uniform projection) and no better than the exact
    optimum."""
    task, hw = tiny_task(), tiny_hw(diagonal_links=True)
    exact = run_miqp(task, hw, "latency", OPTS,
                     MIQPConfig(backend="numpy"), engine="lattice")
    beam = run_miqp(task, hw, "latency", OPTS,
                    MIQPConfig(backend="numpy", candidate_budget=1),
                    engine="lattice")
    assert beam.milp_status.startswith("lattice beam")
    beam.partition.validate(task)
    assert beam.objective >= exact.objective - 1e-18
    # the tiny space fits inside one beam pass, so beam == exact here
    assert beam.objective == pytest.approx(exact.objective, rel=1e-12)


def test_lattice_flow_congestion_and_energy_objective():
    """The lattice scores the evaluator directly, so flow congestion and
    the energy objective come for free (the MILP models neither)."""
    task, hw = tiny_task(), tiny_hw()
    flow = run_miqp(task, hw, "latency",
                    EvalOptions(redistribution=True, async_exec=False,
                                congestion="flow"),
                    MIQPConfig(backend="numpy"), engine="lattice")
    flow.partition.validate(task)
    assert np.isfinite(flow.objective) and flow.objective > 0
    ref = brute_force(task, hw, "latency",
                      EvalOptions(redistribution=True, async_exec=False,
                                  congestion="flow"))
    assert flow.objective == pytest.approx(ref, rel=1e-12)
    en = run_miqp(task, hw, "energy", OPTS, MIQPConfig(backend="numpy"),
                  engine="lattice")
    assert en.objective == pytest.approx(
        brute_force(task, hw, "energy", OPTS), rel=1e-12)


def test_lattice_leq_milp_incumbent():
    """The acceptance contract: the lattice optimum is never worse than
    the HiGHS incumbent's exact score (same solve options)."""
    scipy = pytest.importorskip("scipy")
    del scipy
    task = Task("chain3", [
        GemmOp("g0", M=512, K=256, N=512),
        GemmOp("g1", M=512, K=512, N=256, chained=True),
        GemmOp("g2", M=512, K=256, N=512, chained=True)])
    hw = make_hw("A", 4, "hbm", diagonal_links=True)
    lat = run_miqp(task, hw, "latency", OPTS, MIQPConfig(),
                   engine="lattice")
    milp = run_miqp(task, hw, "latency", OPTS,
                    MIQPConfig(time_limit=20), engine="milp")
    assert lat.objective <= milp.objective * (1 + 1e-9)
    if "Optimal" in milp.milp_status:
        # where HiGHS proves model optimality, the proven model optimum
        # (µs) bounds the exact optimum to the linearization accuracy
        # (the 2% of test_miqp_model_matches_evaluator) — the lattice
        # result must sit under that bound too
        assert lat.objective <= milp.milp_objective * 1e-6 * 1.02


def test_engine_resolution_and_result_fields():
    assert resolve_auto_engine("auto") == "lattice"
    assert resolve_auto_engine("milp") == "milp"
    with pytest.raises(ValueError):
        resolve_auto_engine("simplex")
    task, hw = tiny_task(), tiny_hw()
    r = run_miqp(task, hw, "latency", OPTS, MIQPConfig(backend="numpy"))
    assert r.engine == "lattice"        # auto default
    # latency reports the exact objective in µs as the model objective
    assert r.milp_objective == pytest.approx(r.objective * 1e6)
    with pytest.raises(ValueError):
        run_miqp(task, hw, "throughput", OPTS,
                 MIQPConfig(backend="numpy"))


def test_solve_grid_miqp_batched_matches_solo():
    """A point's record is identical whether solved alone or batched
    with a same-shape neighbour (the §9 cache invariant — lattice
    budgets are deterministic candidate counts, not wall-clock)."""
    task = tiny_task()
    hw_a = tiny_hw(diagonal_links=True)
    hw_b = tiny_hw()
    cfg = MIQPConfig(backend="numpy")
    pts = [sweep.EvalPoint(task, hw_a, OPTS),
           sweep.EvalPoint(task, hw_b, OPTS)]
    recs = sweep.solve_grid(pts, "latency", cfg, backend="numpy",
                            method="miqp", cache=False)
    for pt, rec in zip(pts, recs):
        solo = run_miqp(pt.task, pt.hw, "latency", OPTS, cfg,
                        engine="lattice")
        assert rec.objective == solo.objective
        assert np.array_equal(rec.partition.Px, solo.partition.Px)
        assert np.array_equal(rec.partition.Py, solo.partition.Py)
        assert np.array_equal(rec.redist_mask, solo.redist_mask)


def test_solve_grid_miqp_mixed_chain_group_matches_solo():
    """Two tasks with the same shape signature but different chain
    structures land in ONE lockstep group — per-point budgets (pair-
    refine k, range-move masks) must still make each record identical
    to its solo solve."""
    chained = tiny_task()
    unchained = Task("tiny2", [GemmOp("a", M=64, K=64, N=64),
                               GemmOp("b", M=64, K=64, N=96)])
    hw = tiny_hw(diagonal_links=True)
    cfg = MIQPConfig(backend="numpy", candidate_budget=1)  # force beam
    pts = [sweep.EvalPoint(chained, hw, OPTS),
           sweep.EvalPoint(unchained, hw, OPTS)]
    recs = sweep.solve_grid(pts, "latency", cfg, backend="numpy",
                            method="miqp", cache=False)
    for pt, rec in zip(pts, recs):
        solo = run_miqp(pt.task, pt.hw, "latency", OPTS, cfg,
                        engine="lattice")
        assert rec.objective == solo.objective
        assert np.array_equal(rec.partition.Px, solo.partition.Px)
        assert np.array_equal(rec.partition.Py, solo.partition.Py)


def test_solve_grid_miqp_unequal_lattice_sizes_group():
    """Same shape signature, different dims → different per-layer
    candidate counts inside ONE lockstep group. The group-wide max
    extension indices must clip (not fault) on the smaller point, and
    each record must still equal its solo solve — on the jax backend,
    whose grouped path is the only one that locksteps."""
    big = Task("big", [GemmOp("a", M=256, K=64, N=128),
                       GemmOp("b", M=256, K=128, N=96, chained=True)])
    small = tiny_task()                    # same n_ops, smaller windows
    hw = tiny_hw(diagonal_links=True)
    cfg = MIQPConfig(candidate_budget=1)   # force the beam lockstep
    pts = [sweep.EvalPoint(big, hw, OPTS),
           sweep.EvalPoint(small, hw, OPTS)]
    recs = sweep.solve_grid(pts, "latency", cfg, backend="jax",
                            method="miqp", cache=False)
    for pt, rec in zip(pts, recs):
        solo = run_miqp(pt.task, pt.hw, "latency", OPTS, cfg,
                        engine="lattice")
        assert rec.objective == solo.objective
        assert np.array_equal(rec.partition.Px, solo.partition.Px)
        assert np.array_equal(rec.partition.Py, solo.partition.Py)


def test_solve_grid_miqp_cache_axis_isolation():
    """MIQP records cache under a method-tagged key: repeats hit, and
    neither objective/config changes nor GA records on the same points
    can collide."""
    from repro.core.ga import GAConfig

    task = tiny_task()
    hw = tiny_hw(diagonal_links=True)
    cfg = MIQPConfig(backend="numpy")
    pts = [sweep.EvalPoint(task, hw, OPTS)]
    sweep.clear_cache()
    r1 = sweep.solve_grid(pts, "latency", cfg, backend="numpy",
                          method="miqp")
    assert sweep.cache_stats() == {"hits": 0, "misses": 1}
    r2 = sweep.solve_grid(pts, "latency", cfg, backend="numpy",
                          method="miqp")
    assert sweep.cache_stats() == {"hits": 1, "misses": 1}
    assert r2[0].objective == r1[0].objective
    # cached records are copies — mutating one must not poison the cache
    r2[0].partition.Px[:] = -1
    r3 = sweep.solve_grid(pts, "latency", cfg, backend="numpy",
                          method="miqp")
    assert sweep.cache_stats() == {"hits": 2, "misses": 1}
    assert np.array_equal(r3[0].partition.Px, r1[0].partition.Px)
    # a different objective and a different config are different records
    sweep.solve_grid(pts, "edp", cfg, backend="numpy", method="miqp")
    assert sweep.cache_stats()["misses"] == 2
    sweep.solve_grid(pts, "latency",
                     dataclasses.replace(cfg, beam_width=4),
                     backend="numpy", method="miqp")
    assert sweep.cache_stats()["misses"] == 3
    # auto engine resolves before fingerprinting: shares the record
    sweep.solve_grid(pts, "latency",
                     dataclasses.replace(cfg, engine="auto"),
                     backend="numpy", method="miqp")
    assert sweep.cache_stats()["hits"] == 3
    # GA searches on the identical points live on their own cache axis
    ga = sweep.solve_grid(pts, "latency",
                          GAConfig(generations=2, population=8),
                          backend="numpy", method="ga")
    from repro.core.ga import GAResult

    assert isinstance(ga[0], GAResult)
    assert sweep.cache_stats()["misses"] == 4
    sweep.clear_cache()


def test_solve_grid_method_validation():
    with pytest.raises(ValueError):
        sweep.solve_grid([], method="annealing")


def test_approx_inverse_irregular_hardware_regression():
    """The irregular-hardware extension feeds *arrays* of variable
    denominators (per-entrance bandwidth terms) and the lattice engine
    may trace the expression under jit — the trick must stay a pure
    broadcastable expression with the documented (x/c)² error."""
    c = np.array([0.25, 1.0, 16.0, 1e6])
    x = 0.05 * c
    out = approx_inverse(c, x)
    np.testing.assert_allclose(out, (c - x) / (c * c), rtol=1e-15)
    rel = np.abs(out - 1.0 / (c + x)) * (c + x)
    np.testing.assert_allclose(rel, (x / c) ** 2, atol=1e-12)

    jax = pytest.importorskip("jax")
    import jax.numpy as jnp

    f = jax.jit(lambda xx: approx_inverse(16.0, xx))
    xs = jnp.linspace(-1.0, 1.0, 7)
    np.testing.assert_allclose(np.asarray(f(xs)),
                               (16.0 - np.asarray(xs)) / 256.0,
                               rtol=1e-12)
