"""End-to-end analytical cost evaluator — paper Sec. 4.2.4–4.4 and 5.1–5.3.

Implements ``Cost = Sche({comp(*_i), comm(*_i)})`` (eq. 3–6) for a Task on
an HWConfig under a candidate Partition, returning latency, energy and EDP
plus a per-op breakdown that the RCPSP pipeliner (Sec. 5.4) consumes.

All math is vectorized with a leading *population* axis so that the
genetic algorithm (Sec. 6.2) evaluates its whole population in one call.
float64 throughout — cycle counts overflow float32 mantissas.

Two interchangeable backends (DESIGN.md §8):
  * ``backend="numpy"`` — the reference implementation (this module);
  * ``backend="jax"`` — a ``jax.jit`` + ``vmap`` port
    (:mod:`repro.core.evaluator_jax`) that must match the reference
    within float64 round-off; the parity suite in
    ``tests/test_backend_parity.py`` enforces the contract.

Modeling conventions (documented in DESIGN.md §5):
  * Off-chip and NoP serialization per phase combine as ``max`` — the
    congestion-aware regime pick of Sec. 3.2/4.3.3 (memory-bound vs
    NoP-bound); the slower resource is the bottleneck. That is
    ``congestion="regime"``; ``congestion="flow"`` (DESIGN.md §11)
    instead scores the distribution/collection phases against link
    rates simulated by the max-min waterfilling netsim on the shared
    topology's flow network (energy is congestion-independent).
  * Per-chiplet NoP time for distribution = received_bytes × hops / BW_nop
    with the hop matrices of eqs. 10–12 (+ the diagonal-link alternative
    of Sec. 5.1.1 taken as a per-chiplet min).
  * Collection (eq. 8) = non-entrance group bytes / (entrance_links × BW).
"""
from __future__ import annotations

import dataclasses

import numpy as np

from .hw import HWConfig
from .workload import Partition, Task

__all__ = ["CONGESTION_MODES", "DEVICE_MODES", "EvalOptions", "EvalResult",
           "Evaluator"]


#: Congestion models for the communication phases (DESIGN.md §11):
#: "regime" = the closed-form max-pick of Sec. 3.2/4.3.3 (memory-bound vs
#: NoP-bound, whichever serializes longer); "flow" = score the
#: distribution/collection phases against link rates simulated by the
#: max-min waterfilling netsim on the shared topology's flow network.
CONGESTION_MODES = ("regime", "flow")

#: Execution modes for the batched sweep calls (DESIGN.md §15):
#: "single" = one device runs the whole grid group; "sharded" = the grid
#: axis is shard_map-sharded across every local device
#: (:mod:`repro.core.sweep_shard`); "auto" = sharded iff more than one
#: device exists and the group has ≥ 2 points. Results are bitwise
#: identical across modes (solo == batched == sharded), so the knob is
#: purely a performance choice and is normalized out of every sweep-cache
#: fingerprint.
DEVICE_MODES = ("single", "sharded", "auto")


@dataclasses.dataclass(frozen=True)
class EvalOptions:
    """Optimization toggles (Sec. 5). The LS baseline has all False."""

    redistribution: bool = False   # Sec. 5.2 on-package redistribution
    async_exec: bool = False       # Sec. 5.3 fused comm+comp
    energy_mode: str = "paper"     # "paper" (eq. 4.4.1 verbatim) | "per_chiplet"
    congestion: str = "regime"     # "regime" (Sec. 4.3.3) | "flow" (§11)
    devices: str = "auto"          # sweep execution: "single"|"sharded"|
                                   # "auto" (§15; result-neutral)

    def __post_init__(self):
        if self.energy_mode not in ("paper", "per_chiplet"):
            raise ValueError(f"bad energy_mode {self.energy_mode}")
        if self.congestion not in CONGESTION_MODES:
            raise ValueError(f"bad congestion {self.congestion!r}; "
                             f"one of {CONGESTION_MODES}")
        if self.devices not in DEVICE_MODES:
            raise ValueError(f"bad devices {self.devices!r}; "
                             f"one of {DEVICE_MODES}")


@dataclasses.dataclass
class EvalResult:
    latency: float            # seconds
    energy: float             # joules
    edp: float                # J*s
    t_in: np.ndarray          # [n_ops] input-communication seconds
    t_comp: np.ndarray        # [n_ops]
    t_out: np.ndarray         # [n_ops] offload-or-redistribution seconds
    redist: np.ndarray        # [n_ops] bool, redistribution used after op i

    def segments(self) -> list[tuple[str, float, float, float]]:
        """(name, comm_in, comp, comm_out) per op for the pipeliner."""
        return [
            (f"op{i}", float(self.t_in[i]), float(self.t_comp[i]),
             float(self.t_out[i]))
            for i in range(len(self.t_in))
        ]


def _ceil_div(a, b):
    return -(-a // b) if isinstance(a, int) else np.ceil(a / b)


BACKENDS = ("numpy", "jax", "auto")

#: ``backend="auto"`` crossover point: the jax jit+vmap path wins above
#: this population size, numpy below (dispatch overhead dominates small
#: batches). Measured on this container by
#: ``benchmarks/perf_iterations --cell ga_fitness`` (DESIGN.md §8);
#: ``benchmarks/artifacts/ga_fitness.json`` holds the numbers.
AUTO_POPULATION_THRESHOLD = 1024


def resolve_auto_backend(backend: str, population: int) -> str:
    """Resolve ``"auto"`` to a concrete engine for a given batch size:
    jax at ``population >= AUTO_POPULATION_THRESHOLD``, numpy below."""
    if backend == "auto":
        return "jax" if population >= AUTO_POPULATION_THRESHOLD else "numpy"
    return backend


class Evaluator:
    """Evaluates partitions for one (Task, HWConfig, EvalOptions) triple.

    ``backend`` selects the execution engine: ``"numpy"`` (reference) or
    ``"jax"`` (jit+vmap, DESIGN.md §8). Both produce identical result
    dicts of float64 numpy arrays. ``"auto"`` defers the choice to each
    ``evaluate_batch`` call: jax for populations ≥
    :data:`AUTO_POPULATION_THRESHOLD`, numpy below.

    ``congestion`` (shorthand for ``options.congestion``, DESIGN.md §11)
    selects the communication model: ``"regime"`` keeps the closed-form
    Sec. 3.2/4.3.3 max pick, ``"flow"`` scores distribution/collection
    against the simulated link rates of the waterfilling netsim.
    """

    def __init__(self, task: Task, hw: HWConfig,
                 options: EvalOptions = EvalOptions(),
                 backend: str = "numpy",
                 congestion: str | None = None):
        if backend not in BACKENDS:
            raise ValueError(f"unknown backend {backend!r}; one of {BACKENDS}")
        if congestion is not None:
            # ctor-level override of the options field (DESIGN.md §11):
            # Evaluator(congestion="flow") without spelling a full
            # EvalOptions. The merged options object is what travels into
            # fingerprints and the jax static key.
            options = dataclasses.replace(options, congestion=congestion)
        self.backend = backend
        self._jax_consts = None         # lazy EvalConsts cache (jax backend)
        self._jax_device_consts = None  # device-resident copy of the above
        self.task = task
        self.hw = hw
        self.opts = options
        top = hw.topology
        self.top = top
        n = len(task)
        arr = task.arrays()
        self.M = arr["M"].astype(np.float64)
        self.K = arr["K"].astype(np.float64)
        self.N = arr["N"].astype(np.float64)
        self.sync = arr["sync"].astype(bool)
        self.w_scale = arr["w_scale"].astype(np.float64)
        self.epilogue = arr["epilogue"].astype(np.float64)

        # chain_valid[i]: redistribution after op i is semantically legal —
        # op i+1 consumes op i's output as its activation. Dims need not
        # match exactly (pooling / im2col between conv layers reshapes the
        # tensor locally in SRAM — the paper's AlexNet case); step 3 works
        # on normalized row fractions.
        cv = np.zeros(n, dtype=bool)
        for i in range(n - 1):
            cv[i] = bool(task.ops[i + 1].chained)
        self.chain_valid = cv

        # Topology constants. The scalar fields stay (the HiGHS MILP
        # formulation and external callers read them); the per-chiplet /
        # per-entrance arrays below are what the phase equations divide
        # by — for a homogeneous config every array broadcasts the
        # scalar, so the arithmetic is bitwise-identical to the scalar
        # code it replaced (same divisor element, same argmax).
        self.B = float(hw.bytes_per_elem)
        self.bw_nop = float(hw.bw_nop)
        self.bw_ent = float(top.bw_mem_per_entrance)
        self.freq = float(hw.freq_hz)
        self.bw_nop_xy = top.bw_nop_xy.astype(np.float64)      # [X, Y]
        self.freq_xy = top.freq_xy.astype(np.float64)          # [X, Y]
        self.bw_ent_e = top.bw_mem_entrance.astype(np.float64)  # [E]
        self.bw_nop_ent = top.bw_nop_entrance.astype(np.float64)  # [E]
        # Redistribution runs along rows (step 1/2) and across adjacent
        # rows (step 3): bottleneck at the slowest link on the path.
        self.row_bw = self.bw_nop_xy.min(axis=1)               # [X]
        self.cross_bw = (np.minimum(self.row_bw[:-1], self.row_bw[1:])
                         if hw.X > 1 else self.row_bw[:0])     # [X-1]
        self.bw_nop_min = float(self.bw_nop_xy.min())
        self.high_bw = (float(self.bw_ent_e.max())
                        > self.bw_nop_min)         # congestion regime
        self.hA = (top.hops_row_shared if self.high_bw else top.hops_low
                   ).astype(np.float64)            # A is row-shared
        self.hW = (top.hops_col_shared if self.high_bw else top.hops_low
                   ).astype(np.float64)            # W is col-shared
        self.h_min = top.hops_low.astype(np.float64)

        # Per-entrance masks come straight from the shared topology layer
        # (DESIGN.md §11) — no local re-derivation.
        self.ent_mask = top.entrance_member        # [E, X, Y]
        self.row_mask = top.entrance_rows          # [E, X]
        self.col_mask = top.entrance_cols          # [E, Y]
        self.ent_pos = top.entrance_pos            # [E, X, Y]
        self.links = top.entrance_links.astype(np.float64)  # [E]

    # ------------------------------------------------------------------ API
    def evaluate(self, part: Partition, redist_mask: np.ndarray | None = None
                 ) -> EvalResult:
        part.validate(self.task)
        Px = part.Px[None].astype(np.float64)
        Py = part.Py[None].astype(np.float64)
        coll = part.collectors[None].astype(np.int64)
        if redist_mask is None:
            rd = (self.chain_valid & self.opts.redistribution)[None]
        else:
            rd = (np.asarray(redist_mask, dtype=bool) & self.chain_valid)[None]
            if not self.opts.redistribution:
                rd = np.zeros_like(rd)
        out = self.evaluate_batch(Px, Py, coll, rd.astype(np.float64))
        return EvalResult(
            latency=float(out["latency"][0]),
            energy=float(out["energy"][0]),
            edp=float(out["edp"][0]),
            t_in=out["t_in"][0],
            t_comp=out["t_comp"][0],
            t_out=out["t_out"][0],
            redist=rd[0],
        )

    def evaluate_batch(
        self,
        Px: np.ndarray,      # [P, n, X] float
        Py: np.ndarray,      # [P, n, Y] float
        collectors: np.ndarray,  # [P, n] int
        redist: np.ndarray,  # [P, n] float in {0,1}: redistribute after op i
    ) -> dict[str, np.ndarray]:
        backend = resolve_auto_backend(self.backend, int(Px.shape[0]))
        if backend == "jax":
            from . import evaluator_jax
            if self._jax_device_consts is None:
                self._jax_device_consts = evaluator_jax.to_device(self.consts())
            return evaluator_jax.batch_evaluate(
                self._jax_device_consts, self.opts, Px, Py, collectors, redist)
        return self._evaluate_batch_numpy(Px, Py, collectors, redist)

    def consts(self):
        """Constant bundle for the JAX backend / sweep engine (cached)."""
        if self._jax_consts is None:
            from . import evaluator_jax
            self._jax_consts = evaluator_jax.consts_from_evaluator(self)
        return self._jax_consts

    def _evaluate_batch_numpy(
        self, Px, Py, collectors, redist
    ) -> dict[str, np.ndarray]:
        hw, top = self.hw, self.top
        B = self.B
        bw_ent = self.bw_ent_e[None, None]                       # [1,1,E]
        X, Y = hw.X, hw.Y
        R, C = float(hw.R), float(hw.C)
        M, K, N = self.M, self.K, self.N

        redist = redist * self.chain_valid[None, :]
        if not self.opts.redistribution:
            redist = np.zeros_like(redist)
        # redist_in[i] = output of op i-1 was redistributed (A already local).
        redist_in = np.concatenate(
            [np.zeros_like(redist[:, :1]), redist[:, :-1]], axis=1)
        keepA = 1.0 - redist_in       # fraction of A loads from memory
        redist_out = redist

        # -------------------------------------------------- data volumes
        chunk = Px[:, :, :, None] * Py[:, :, None, :] * B        # [P,n,X,Y]
        inA = Px * K[None, :, None] * B                          # [P,n,X]
        inW = Py * (K * self.w_scale)[None, :, None] * B         # [P,n,Y]

        # --------------------------------------------- phase 1: data load
        # Off-chip serialization per entrance (duplicated pulls per group —
        # the paper's LS data-duplication overhead shows up here).
        A_e = np.einsum("ex,pnx->pne", self.row_mask, inA)
        W_e = np.einsum("ey,pny->pne", self.col_mask, inW)
        t_off_in = ((keepA[..., None] * A_e + W_e) / bw_ent).max(axis=-1)

        # NoP distribution: per-chiplet received bytes × hops / BW.
        tA_xy = inA[:, :, :, None] * self.hA[None, None]          # bytes*hops
        tW_xy = inW[:, :, None, :] * self.hW[None, None]

        flow_mode = self.opts.congestion == "flow"
        if flow_mode:
            # §11 flow congestion: per-chiplet NoP arrival times from the
            # simulated mesh link rates replace the hop-matrix closed
            # form; off-chip serialization keeps the exact per-entrance
            # term (shared stripes are fetched once per group — simulating
            # the sole-user port would just re-derive t_off_in).
            demand = (keepA[..., None, None] * inA[:, :, :, None]
                      + inW[:, :, None, :])                      # [P,n,X,Y]
            dist_done, t_coll_flow = self._flow_times(demand, chunk)
            nop_in_xy = None          # regime-only (tA/tW still feed energy)
            t_in = np.maximum(t_off_in, dist_done.max(axis=(-1, -2)))
        else:
            nop_in_xy = ((keepA[..., None, None] * tA_xy + tW_xy)
                         / self.bw_nop_xy[None, None])
            t_in = np.maximum(t_off_in, nop_in_xy.max(axis=(-1, -2)))

        # ------------------------------------------------ phase 2: compute
        # SCALE-Sim output-stationary latency (eq. 7) + SIMD epilogue.
        fill = (2.0 * R + C + K - 2.0)[None, :, None, None]
        tiles = np.ceil(Px / R)[:, :, :, None] * np.ceil(Py / C)[:, :, None, :]
        cyc = fill * tiles
        cyc = cyc + (self.epilogue[None, :, None, None]
                     * Px[:, :, :, None] * Py[:, :, None, :] / C)
        t_comp_xy = cyc / self.freq_xy[None, None]
        t_comp = t_comp_xy.max(axis=(-1, -2))

        # ----------------------------------------- phase 3a: offload path
        # eq. 8 uses the *full* group bytes over the entrance links for 2.5D
        # packages; only a 3D entrance's own chunk bypasses the NoP (it sits
        # directly under its memory stack).
        out_e = np.einsum("exy,pnxy->pne", self.ent_mask, chunk)
        t_off_out = (out_e / bw_ent).max(axis=-1)
        if flow_mode:
            # Collection: simulated mesh-flow completion replaces the
            # entrance-link closed form; the off-chip write term stays.
            t_offload = np.maximum(t_coll_flow, t_off_out)
        else:
            out_at_ent = np.einsum("exy,pnxy->pne", self.ent_pos, chunk)
            is3d = self.top.entrance_is_3d[None, None, :]
            nonlocal_out = out_e - np.where(is3d, out_at_ent, 0.0)
            with np.errstate(divide="ignore", invalid="ignore"):
                t_collect = np.where(
                    self.links[None, None] > 0,
                    nonlocal_out
                    / (self.links[None, None] * self.bw_nop_ent[None, None]),
                    0.0,
                ).max(axis=-1)
            t_offload = np.maximum(t_collect, t_off_out)

        # --------------------------------- phase 3b: redistribution path
        # (Sec. 5.2) Step 1: row gather toward collector column c.
        yidx = np.arange(Y)[None, None, :]
        cc = collectors[..., None]
        left_m = (yidx < cc).astype(np.float64)                  # [P,n,Y]
        right_m = (yidx > cc).astype(np.float64)
        left_x = np.einsum("pnxy,pny->pnx", chunk, left_m)
        right_x = np.einsum("pnxy,pny->pnx", chunk, right_m)
        t1 = (np.maximum(left_x, right_x)
              / self.row_bw[None, None]).max(axis=-1)
        # Step 2: broadcast the assembled row block along the row.
        rowbytes = Px * N[None, :, None] * B                     # [P,n,X]
        t2 = (rowbytes / self.row_bw[None, None]).max(axis=-1)
        # Step 3: column redistribution from Px_i to Px_{i+1}. Row counts of
        # consecutive ops may differ (pooling/im2col); compare normalized
        # cumulative fractions and scale by op-i bytes.
        cumf = np.cumsum(Px, axis=-1) / np.maximum(M[None, :, None], 1.0)
        cumf_next = np.concatenate([cumf[:, 1:], cumf[:, -1:]], axis=1)
        crossing = (np.abs(cumf - cumf_next)[:, :, : X - 1]
                    * M[None, :, None]) if X > 1 else \
            np.zeros_like(cumf[:, :, :0])
        cross_bytes = crossing * N[None, :, None] * B
        t3 = ((cross_bytes / self.cross_bw[None, None]).max(axis=-1)
              if X > 1 else np.zeros_like(t1))
        t_redist = t1 + t2 + t3

        t_out = np.where(redist_out > 0, t_redist, t_offload)

        # Output sync for softmax/layernorm-class ops: exchange of row
        # statistics across the chiplet row (small, eq.-9 convention).
        t_sync = (self.sync[None, :]
                  * (Px.max(axis=-1) * 4.0 * B * max(Y - 1, 1))
                  / self.bw_nop_min)

        # ------------------------------------------------------- schedule
        if self.opts.async_exec:
            # Fuse comm+comp per chiplet for non-sync ops (Sec. 5.3).
            if flow_mode:
                t_fused = np.maximum(
                    (dist_done + t_comp_xy).max(axis=(-1, -2)), t_off_in)
            else:
                fused_xy = nop_in_xy + t_comp_xy
                t_fused = np.maximum(fused_xy.max(axis=(-1, -2)), t_off_in)
            core = np.where(self.sync[None, :], t_in + t_comp, t_fused)
        else:
            core = t_in + t_comp
        t_ops = core + t_out + t_sync
        latency = t_ops.sum(axis=1)

        # --------------------------------------------------------- energy
        e_sram = hw.e_sram_bit * 8.0
        e_mem = hw.e_mem_bit * 8.0
        e_nop = hw.e_nop_bit_hop * 8.0
        e_mac = hw.e_mac_cycle

        sram_bytes = (Y * inA.sum(axis=-1) + X * inW.sum(axis=-1)
                      + chunk.sum(axis=(-1, -2)))
        E_sram = e_sram * sram_bytes.sum(axis=1)

        if self.opts.energy_mode == "paper":
            # eq. 4.4.1 verbatim: c_MAC * cycles * R * C * (X*Y).
            E_mac = e_mac * (cyc.max(axis=(-1, -2)) * R * C * X * Y).sum(axis=1)
        else:
            E_mac = e_mac * (cyc.sum(axis=(-1, -2)) * R * C).sum(axis=1)

        mem_bytes = (keepA[..., None] * A_e + W_e
                     + (1.0 - redist_out)[..., None] * out_e).sum(axis=(-1, -2))
        E_mem = e_mem * mem_bytes

        # NoP bytes×hops: loads + (collection | redistribution).
        load_bh = (keepA[..., None, None] * tA_xy + tW_xy).sum(axis=(-1, -2))
        collect_bh = (chunk * self.h_min[None, None]).sum(axis=(-1, -2))
        red_bh = (
            (left_x + right_x).sum(axis=-1)            # step-1 gather
            + rowbytes.sum(axis=-1) * max(Y - 1, 1)    # step-2 broadcast
            + (cross_bytes.sum(axis=-1) * Y if X > 1 else 0.0)  # step 3
        )
        nop_bh = load_bh + np.where(redist_out > 0, red_bh, collect_bh)
        E_nop = e_nop * nop_bh.sum(axis=1)

        energy = E_sram + E_mac + E_mem + E_nop
        return {
            "latency": latency,
            "energy": energy,
            "edp": energy * latency,
            "t_in": t_in,
            "t_comp": t_comp,
            "t_out": t_out,
            "E_sram": E_sram,
            "E_mac": E_mac,
            "E_mem": E_mem,
            "E_nop": E_nop,
        }

    # -------------------------------------------------------------- helpers
    def _flow_times(self, demand: np.ndarray, chunk: np.ndarray
                    ) -> tuple[np.ndarray, np.ndarray]:
        """Simulate the distribution and collection phases per
        (candidate, op) on the topology's flow network (DESIGN.md §11).

        ``demand``/``chunk`` are ``[P, n, X, Y]`` byte tensors. Returns
        per-chiplet distribution completion times ``[P, n, X, Y]`` and
        collection-phase latencies ``[P, n]``. Chiplets with an empty
        mesh route (they sit on their entrance / under a 3D stack) are
        masked to zero bytes — their data never touches the NoP, and the
        off-chip terms already account for it. This is the numpy
        reference loop; the jax backend traces the same waterfilling
        program inside its compiled evaluator
        (:mod:`repro.core.netsim_jax`)."""
        from . import netsim

        caps, dinc, cinc = self.top.flow_net()
        P, n, X, Y = demand.shape
        d_routed = (dinc.sum(axis=1) > 0).reshape(X, Y)
        c_routed = (cinc.sum(axis=1) > 0).reshape(X, Y)
        demand = demand * d_routed
        chunk = chunk * c_routed
        dist_done = np.zeros((P, n, X, Y), dtype=np.float64)
        t_coll = np.zeros((P, n), dtype=np.float64)
        for p in range(P):
            for i in range(n):
                r = netsim.simulate_flows(dinc, caps, demand[p, i].ravel())
                dist_done[p, i] = r["done"].reshape(X, Y)
                rc = netsim.simulate_flows(cinc, caps, chunk[p, i].ravel())
                t_coll[p, i] = rc["latency"]
        return dist_done, t_coll

    def objective_batch(self, Px, Py, collectors, redist, objective: str
                        ) -> np.ndarray:
        out = self.evaluate_batch(Px, Py, collectors, redist)
        if objective not in out:
            raise ValueError(f"unknown objective {objective}")
        return out[objective]
