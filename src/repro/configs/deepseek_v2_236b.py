"""deepseek-v2-236b [moe]: 60L d_model=5120 128H vocab=102400 — MLA
(q_lora=1536, kv_lora=512, nope/rope 128/64, v=128), 2 shared + 160
routed experts top-6 (expert d_ff=1536), first layer dense (d_ff=12288)
[arXiv:2405.04434; hf]."""
from ..models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="deepseek-v2-236b", family="moe",
        n_layers=60, d_model=5120, d_ff=12288, vocab_size=102400,
        n_heads=128, attn_type="mla",
        q_lora_rank=1536, kv_lora_rank=512,
        qk_nope_dim=128, qk_rope_dim=64, v_head_dim=128,
        n_experts=160, n_shared_experts=2, moe_top_k=6, moe_d_ff=1536,
        first_dense_layers=1,
        act="silu",
        param_dtype="bfloat16",  # 236B: pure-bf16 params + f32 moments fit v5e HBM
    )


def reduced_config() -> ModelConfig:
    return config().replace(
        name="deepseek-v2-smoke", n_layers=3, d_model=64, d_ff=160,
        vocab_size=256, n_heads=4, q_lora_rank=32, kv_lora_rank=16,
        qk_nope_dim=8, qk_rope_dim=4, v_head_dim=8,
        n_experts=8, n_shared_experts=1, moe_top_k=2, moe_d_ff=32,
        first_dense_layers=1, attn_chunk=32, remat=False)
