"""Fig. 8 reproduction: normalized end-to-end latency of SIMBA-like / GA /
MIQP vs the LS-uniform baseline, on 4×4 chiplet systems of all four
packaging types with HBM.

Paper claims: GA/MIQP beat LS on every type (geo-means 13%/45%, 5%/15%,
9%/43%, 19%/25% for A–D); SIMBA-like is slightly *worse* than LS; the
GA–MIQP gap is smallest on type D (near-uniform memory distance).

Grid driving (benchmarks/README.md): LS baselines for the whole
(type × workload) grid come from the batched sweep engine — one compiled
call per shape group, cached process-wide; the solver points go through
the per-point ``sweep.run_grid``/``optimize`` path — every packaging
type is its own shape signature here, so there is nothing to batch
within a (type, workload) cell, though ``optimize(method="miqp")`` now
solves each point with the lattice engine (DESIGN.md §12).
"""
from __future__ import annotations

from repro.core import EvalOptions, make_hw, optimize, sweep
from repro.core.ga import GAConfig
from repro.core.miqp import MIQPConfig
from repro.graphs import WORKLOADS

from .common import emit, geomean, save_json

GA_CFG = GAConfig(generations=60, population=64)          # ~paper budget
MIQP_CFG = MIQPConfig(time_limit=60)
METHOD_KW = {"simba": {},
             "ga": {"ga_config": GA_CFG},
             "miqp": {"miqp_config": MIQP_CFG}}


def main(fast: bool = False, backend: str = "jax"):
    workloads = {k: fn(batch=1) for k, fn in WORKLOADS.items()}
    if fast:
        workloads = {k: workloads[k] for k in ("alexnet", "hydranet")}
    hws = {t: make_hw(t, 4, "hbm") for t in "ABCD"}

    # LS baselines: one batched + cached sweep over the full
    # (type × workload × congestion-model) grid. The congestion axis
    # (DESIGN.md §11) scores the same schedules against the flow-level
    # netsim; the regime records anchor the speedup columns below, the
    # flow/regime ratio is reported as a model-fidelity diagnostic.
    base_grid = sweep.grid(t=list(hws), wname=list(workloads),
                           congestion=("regime", "flow"))
    base_recs = sweep.eval_sweep(
        [sweep.EvalPoint(workloads[p["wname"]], hws[p["t"]],
                         EvalOptions(congestion=p["congestion"]))
         for p in base_grid],
        backend=backend)
    base = {(p["t"], p["wname"]): r["latency"]
            for p, r in zip(base_grid, base_recs)
            if p["congestion"] == "regime"}
    flow = {(p["t"], p["wname"]): r["latency"]
            for p, r in zip(base_grid, base_recs)
            if p["congestion"] == "flow"}

    results = {}
    for (t, wname), lat in flow.items():
        ratio = lat / base[(t, wname)]
        results[f"{t}/{wname}/flow_vs_regime"] = ratio
        emit(f"fig8/{t}/{wname}/flow_vs_regime", 0.0, f"{ratio:.3f}x")
    speed = {(t, m): [] for t in hws for m in METHOD_KW}

    def solve(t, wname, method):
        return optimize(workloads[wname], hws[t], method, "latency",
                        backend=backend, **METHOD_KW[method])

    def report(pt, r, us):
        t, wname, method = pt["t"], pt["wname"], pt["method"]
        sp = base[(t, wname)] / r.latency
        speed[(t, method)].append(sp)
        results[f"{t}/{wname}/{method}"] = sp
        emit(f"fig8/{t}/{wname}/{method}", us, f"speedup={sp:.3f}x")

    sweep.run_grid(
        sweep.grid(t=list(hws), wname=list(workloads),
                   method=list(METHOD_KW)),
        solve, emit=report)

    for t in hws:
        for m in METHOD_KW:
            emit(f"fig8/{t}/geomean/{m}", 0.0,
                 f"{(geomean(speed[(t, m)]) - 1) * 100:+.1f}% vs LS")
    save_json("fig8", results)


if __name__ == "__main__":
    main()
