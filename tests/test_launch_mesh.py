"""Debug-mesh shape resolution (DESIGN.md §15): the mesh always carries
the ``("data", "model")`` axes the launch-layer sharding rules reference
(``launch/specs.py`` FSDP specs, the train driver's batch sharding) —
n=1 gives the trivial ``(1, 1)`` mesh, even n puts the factor of 2 on
``data`` (the old fallback gave n=2 a dead ``(1, 2)`` data axis), odd n
is ``(1, n)``, and ``pod=True`` adds the third axis only when
``2·2·(n//4) == n`` (the old code crashed on n=10, n=13, …). Multi-device
shapes run in a subprocess with forced virtual host devices (the pytest
process initialized jax with the real topology)."""
import os
import subprocess
import sys
import textwrap

import pytest

SRC = os.path.join(os.path.dirname(__file__), os.pardir, "src")


def _run_forced(n_devices: int, script: str) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n_devices}"
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(script)],
                         env=env, capture_output=True, text=True,
                         timeout=300)
    assert out.returncode == 0, out.stderr
    return out.stdout


# ------------------------------------------------- in-process (1 device)
def test_single_device_mesh_keeps_data_model_axes():
    from repro.launch.mesh import make_debug_mesh

    m = make_debug_mesh(1)
    assert tuple(m.shape.values()) == (1, 1)
    assert m.axis_names == ("data", "model")
    assert m.size == 1


def test_default_covers_all_devices():
    import jax

    from repro.launch.mesh import make_debug_mesh

    m = make_debug_mesh()
    assert m.size == len(jax.devices())


def test_requesting_more_devices_than_exist_raises():
    import jax

    from repro.launch.mesh import make_debug_mesh

    with pytest.raises(ValueError, match="devices requested"):
        make_debug_mesh(len(jax.devices()) + 1)


# ------------------------------------------- forced-topology subprocess
def test_mesh_shapes_across_device_counts():
    """n ∈ {1, 2, 3, 4, 6, 8} + pod=True — one 8-device subprocess."""
    out = _run_forced(8, """
        import jax
        from repro.launch.mesh import make_debug_mesh

        assert jax.device_count() == 8
        expect = {
            1: ((1, 1), ("data", "model")),
            2: ((2, 1), ("data", "model")),  # old code: dead (1, 2) axis
            3: ((1, 3), ("data", "model")),  # prime: no 2-way split
            4: ((2, 2), ("data", "model")),
            6: ((2, 3), ("data", "model")),
            8: ((2, 4), ("data", "model")),
        }
        for n, (shape, names) in expect.items():
            m = make_debug_mesh(n)
            assert tuple(m.shape.values()) == shape, (n, m.shape)
            assert m.axis_names == names, (n, m.axis_names)
            assert m.size == n
            # first-n device selection keeps shard order deterministic
            assert [d.id for d in m.devices.flat] == list(range(n))

        pod = make_debug_mesh(8, pod=True)
        assert tuple(pod.shape.values()) == (2, 2, 2)
        assert pod.axis_names == ("pod", "data", "model")
        # pod=True off the 3-axis grid falls back gracefully: n=4 is
        # below the threshold, n=6 would need 2*2*(6//4) != 6 devices
        # (the old code crashed there), n=3 has no 2-way split at all.
        assert make_debug_mesh(4, pod=True).axis_names == ("data", "model")
        m6 = make_debug_mesh(6, pod=True)
        assert tuple(m6.shape.values()) == (2, 3)
        assert make_debug_mesh(3, pod=True).size == 3
        print("MESH-SHAPES-OK")
    """)
    assert "MESH-SHAPES-OK" in out
