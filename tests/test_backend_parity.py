"""numpy ↔ jax evaluator backend parity (DESIGN.md §8 contract).

The numpy implementation is the reference; the jax backend must agree on
latency/energy/EDP and the per-op breakdown within float64 round-off,
across randomized HWConfig / Task / Partition cases, and the GA must
produce identical trajectories under a fixed seed on both backends.
"""
import numpy as np
import pytest

from repro.core import (EvalOptions, Evaluator, GemmOp, Task, make_hw,
                        uniform_partition)
from repro.core.ga import GAConfig, run_ga
from repro.core.workload import clamp_partition_to_domain

RTOL = 1e-9

OPTION_SETS = [
    EvalOptions(),
    EvalOptions(redistribution=True),
    EvalOptions(async_exec=True),
    EvalOptions(redistribution=True, async_exec=True),
    EvalOptions(redistribution=True, async_exec=True,
                energy_mode="per_chiplet"),
]


def random_task(rng, n_ops=4):
    ops = []
    prev_n = None
    for i in range(n_ops):
        m = int(rng.integers(4, 80)) * 16
        k = prev_n if (prev_n and rng.random() < 0.5) \
            else int(rng.integers(2, 40)) * 16
        n = int(rng.integers(4, 80)) * 16
        ops.append(GemmOp(
            f"g{i}", M=m, K=k, N=n,
            sync=bool(rng.random() < 0.3),
            chained=bool(i > 0 and rng.random() < 0.6),
            epilogue_flops_per_elem=int(rng.integers(0, 4)),
            weight_bytes_scale=float(rng.choice([0.25, 0.5, 1.0])),
        ))
        prev_n = n
    return Task("rand", ops)


def random_hw(rng):
    t = rng.choice(list("ABCD"))
    g = int(rng.choice([2, 4, 6]))
    mem = rng.choice(["hbm", "dram"])
    return make_hw(str(t), g, str(mem),
                   diagonal_links=bool(rng.random() < 0.5))


def random_population(rng, task, hw, pop=6):
    X, Y = hw.X, hw.Y
    base = uniform_partition(task, X, Y)
    parts = []
    for _ in range(pop):
        p = base.copy()
        p.Px = p.Px + rng.integers(-2, 3, p.Px.shape) * hw.R
        p.Px = np.maximum(p.Px, 0)
        p = clamp_partition_to_domain(p, task, X, Y, hw.R, hw.C)
        p.collectors = rng.integers(0, Y, len(task))
        parts.append(p)
    Px = np.stack([p.Px for p in parts]).astype(np.float64)
    Py = np.stack([p.Py for p in parts]).astype(np.float64)
    co = np.stack([p.collectors for p in parts])
    rd = (rng.random((pop, len(task))) < 0.5).astype(np.float64)
    return Px, Py, co, rd


@pytest.mark.parametrize("seed", range(8))
def test_randomized_batch_parity(seed):
    rng = np.random.default_rng(seed)
    task = random_task(rng, n_ops=int(rng.integers(1, 6)))
    hw = random_hw(rng)
    opts = OPTION_SETS[seed % len(OPTION_SETS)]
    evn = Evaluator(task, hw, opts, backend="numpy")
    evj = Evaluator(task, hw, opts, backend="jax")
    Px, Py, co, rd = random_population(rng, task, hw)
    a = evn.evaluate_batch(Px, Py, co, rd)
    b = evj.evaluate_batch(Px, Py, co, rd)
    assert set(a) == set(b)
    for k in a:
        np.testing.assert_allclose(a[k], b[k], rtol=RTOL, err_msg=k)


@pytest.mark.parametrize("t", list("ABCD"))
def test_single_eval_parity_all_types(t):
    task = Task("chain", [
        GemmOp("g0", M=512, K=256, N=512),
        GemmOp("g1", M=512, K=512, N=256, chained=True, sync=True),
        GemmOp("g2", M=512, K=256, N=512, chained=True),
    ])
    hw = make_hw(t, 4, "hbm", diagonal_links=True)
    part = uniform_partition(task, 4, 4)
    rd = np.array([True, True, False])
    for opts in OPTION_SETS:
        rn = Evaluator(task, hw, opts, backend="numpy").evaluate(part, rd)
        rj = Evaluator(task, hw, opts, backend="jax").evaluate(part, rd)
        assert rj.latency == pytest.approx(rn.latency, rel=RTOL)
        assert rj.energy == pytest.approx(rn.energy, rel=RTOL)
        assert rj.edp == pytest.approx(rn.edp, rel=RTOL)
        np.testing.assert_allclose(rj.t_in, rn.t_in, rtol=RTOL)
        np.testing.assert_allclose(rj.t_comp, rn.t_comp, rtol=RTOL)
        np.testing.assert_allclose(rj.t_out, rn.t_out, rtol=RTOL)


def test_ga_identical_trajectories():
    """Fixed seed ⇒ the GA visits the same genomes on both backends.

    Per-platform guarantee (DESIGN.md §8): holds on CPU where XLA's
    float64 reductions track numpy to ≤1 ulp with no near-tie flips; on
    a platform where this fails with tiny fitness deltas, weaken to the
    rtol=1e-9 value contract rather than loosening it here for CPU.
    """
    from repro.graphs import WORKLOADS

    task = WORKLOADS["alexnet"](batch=1)
    hw = make_hw("A", 4, "hbm", diagonal_links=True)
    cfg = GAConfig(generations=12, population=32, seed=11)
    rn = run_ga(task, hw, "latency", cfg=cfg, backend="numpy")
    rj = run_ga(task, hw, "latency", cfg=cfg, backend="jax")
    assert rn.evaluations == rj.evaluations
    assert len(rn.history) == len(rj.history)
    np.testing.assert_allclose(rn.history, rj.history, rtol=RTOL)
    assert rj.objective == pytest.approx(rn.objective, rel=RTOL)
    np.testing.assert_array_equal(rn.partition.Px, rj.partition.Px)
    np.testing.assert_array_equal(rn.partition.Py, rj.partition.Py)
    np.testing.assert_array_equal(rn.partition.collectors,
                                  rj.partition.collectors)
    np.testing.assert_array_equal(rn.redist_mask, rj.redist_mask)


def test_backend_validation():
    task = Task("one", [GemmOp("g", M=64, K=64, N=64)])
    with pytest.raises(ValueError):
        Evaluator(task, make_hw("A", 2), backend="tpu")


def test_objective_batch_jax():
    task = Task("one", [GemmOp("g", M=256, K=128, N=256)])
    hw = make_hw("B", 4)
    part = uniform_partition(task, 4, 4)
    for obj in ("latency", "energy", "edp"):
        a = Evaluator(task, hw, backend="numpy").objective_batch(
            part.Px[None].astype(float), part.Py[None].astype(float),
            part.collectors[None], np.zeros((1, 1)), obj)
        b = Evaluator(task, hw, backend="jax").objective_batch(
            part.Px[None].astype(float), part.Py[None].astype(float),
            part.collectors[None], np.zeros((1, 1)), obj)
        np.testing.assert_allclose(a, b, rtol=RTOL)


def test_x64_does_not_leak():
    """The jax backend's x64 scope must not flip global jax defaults."""
    import jax.numpy as jnp

    task = Task("one", [GemmOp("g", M=256, K=128, N=256)])
    hw = make_hw("A", 4)
    Evaluator(task, hw, backend="jax").evaluate(
        uniform_partition(task, 4, 4))
    assert jnp.asarray(1.0).dtype == jnp.float32
