"""Fig. 12 reproduction: low-bandwidth (DRAM) 4×4 type-A systems.

Paper claims: GA/MIQP latency speedups of 40%/72% over LS (EDP 28%/37%),
with the GA–MIQP gap *wider* than the HBM case (off-chip congestion
simplifies the on-chip scheduling space, so MIQP solves closer to
optimal within its budget).

Grid driving (benchmarks/README.md): per-workload LS references come
from one batched sweep (latency + EDP from the same records); the
(objective × workload × method) solver grid runs via ``sweep.run_grid``.
"""
from __future__ import annotations

from repro.core import make_hw, optimize, sweep
from repro.core.ga import GAConfig
from repro.core.miqp import MIQPConfig
from repro.graphs import WORKLOADS

from .common import emit, geomean, save_json

GA_CFG = GAConfig(generations=60, population=64)
MIQP_CFG = MIQPConfig(time_limit=60, edp_sweep=3)
METHOD_KW = {"ga": {"ga_config": GA_CFG}, "miqp": {"miqp_config": MIQP_CFG}}


def main(fast: bool = False, backend: str = "jax"):
    hw = make_hw("A", 4, "dram")
    wnames = ("alexnet", "hydranet") if fast else tuple(WORKLOADS)
    tasks = {w: WORKLOADS[w](batch=1) for w in wnames}

    base_recs = sweep.eval_sweep(
        [sweep.EvalPoint(tasks[w], hw) for w in wnames], backend=backend)
    ref = dict(zip(wnames, base_recs))

    results = {}
    sp = {(o, m): [] for o in ("latency", "edp") for m in METHOD_KW}

    def solve(objective, wname, method):
        return optimize(tasks[wname], hw, method, objective,
                        backend=backend, **METHOD_KW[method])

    def report(pt, r, us):
        o, wname, m = pt["objective"], pt["wname"], pt["method"]
        val = r.latency if o == "latency" else r.edp
        s = ref[wname][o] / val
        sp[(o, m)].append(s)
        results[f"{o}/{wname}/{m}"] = s
        emit(f"fig12/{o}/{wname}/{m}", us, f"speedup={s:.3f}x")

    sweep.run_grid(
        sweep.grid(objective=("latency", "edp"), wname=wnames,
                   method=list(METHOD_KW)),
        solve, emit=report)

    for o in ("latency", "edp"):
        for m in METHOD_KW:
            emit(f"fig12/{o}/geomean/{m}", 0.0,
                 f"{(geomean(sp[(o, m)]) - 1) * 100:+.1f}% vs LS")
    save_json("fig12", results)


if __name__ == "__main__":
    main()
