"""Flow simulator vs the paper's Fig. 3 motivation claims, plus the
engine contract of DESIGN.md §11: the vectorized max-min waterfilling
engine (and its jitted batched port) must reproduce the event-driven
reference to float64 round-off, and every allocation must satisfy the
max-min invariants (capacity conservation; every unfinished flow
bottlenecked on a saturated link)."""
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import netsim_jax, sweep
from repro.core.netsim import (MeshNet, fig3_case, fig3_net,
                               simulate_flows, simulate_pull,
                               waterfill_rates)

GB = 1e9

FIG3_CELLS = [(m, p, bw * GB) for m in ("dram", "hbm")
              for p in ("peripheral", "central") for bw in (60, 120)]


def test_dram_memory_bound_nop_scaling_useless():
    """Fig 3(a)/(d): DRAM-bound — 2x NoP bandwidth gives no speedup."""
    a = fig3_case("dram", "peripheral", bw_nop=60 * GB)
    b = fig3_case("dram", "peripheral", bw_nop=120 * GB)
    assert a["latency"] == pytest.approx(b["latency"], rel=1e-6)
    assert a["latency"] == pytest.approx(16 / 60, rel=1e-6)  # 16 GB / BW


def test_hbm_nop_bound_scales_linearly():
    """Fig 3(b)/(d): HBM case scales linearly with NoP bandwidth."""
    a = fig3_case("hbm", "peripheral", bw_nop=60 * GB)
    b = fig3_case("hbm", "peripheral", bw_nop=120 * GB)
    assert a["latency"] / b["latency"] == pytest.approx(2.0, rel=1e-3)


def test_hbm_central_placement_gain():
    """Fig 3(c)/(d): central memory placement ≈1.5x over peripheral
    (paper: 1.53x)."""
    p = fig3_case("hbm", "peripheral")
    c = fig3_case("hbm", "central")
    assert p["latency"] / c["latency"] == pytest.approx(1.5, abs=0.1)


def test_dram_placement_no_impact():
    p = fig3_case("dram", "peripheral")
    c = fig3_case("dram", "central")
    assert p["latency"] == pytest.approx(c["latency"], rel=1e-6)


def test_link_utilization_hotspot_near_entrance():
    out = fig3_case("hbm", "peripheral")
    util = out["link_util"]
    # hottest mesh link is adjacent to the attach chiplet (node 0)
    mesh_links = {l: u for l, u in util.items() if 16 not in l}
    hot = max(mesh_links, key=mesh_links.get)
    assert 0 in hot


def test_flow_conservation():
    net = MeshNet(4, 4, 60 * GB, 1024 * GB, [0])
    out = simulate_pull(net, 1 * GB)
    # every destination got its full message through its last link
    for f in out["flows"]:
        assert f.bytes_left <= 1e-3
        assert f.done_at is not None and f.done_at <= out["latency"] + 1e-9


# ------------------------------------------- engine contract (DESIGN §11)
@pytest.mark.parametrize("mem,placement,bw", FIG3_CELLS)
def test_event_and_vectorized_engines_agree(mem, placement, bw):
    a = fig3_case(mem, placement, bw, engine="event")
    b = fig3_case(mem, placement, bw, engine="vectorized")
    assert b["latency"] == pytest.approx(a["latency"], rel=1e-9)
    for l, v in a["link_bytes"].items():
        assert b["link_bytes"][l] == pytest.approx(v, rel=1e-9, abs=1e-3)
    for fa, fb in zip(a["flows"], b["flows"]):
        assert fb.done_at == pytest.approx(fa.done_at, rel=1e-9)


def test_jax_batch_matches_vectorized_reference():
    """One compiled call over the whole Fig. 3 grid == per-cell numpy."""
    nets = [fig3_net(m, p, bw) for m, p, bw in FIG3_CELLS]
    caps = np.stack([n.link_caps() for n in nets])
    incs = np.stack([n.pull_incidence() for n in nets])
    msgs = np.full((len(nets), 16), 1 * GB)
    out = netsim_jax.simulate_pull_batch(caps, incs, msgs)
    for g, net in enumerate(nets):
        ref = simulate_flows(net.pull_incidence(), net.link_caps(),
                             msgs[g])
        np.testing.assert_allclose(out["latency"][g], ref["latency"],
                                   rtol=1e-9)
        np.testing.assert_allclose(out["done"][g], ref["done"], rtol=1e-9)
        np.testing.assert_allclose(out["link_bytes"][g],
                                   ref["link_bytes"], rtol=1e-9, atol=1e-3)


def _random_case(seed: int):
    rng = np.random.default_rng(seed)
    X, Y = int(rng.integers(1, 5)), int(rng.integers(1, 5))
    k = int(rng.integers(1, X * Y + 1))
    attach = sorted(rng.choice(X * Y, size=k, replace=False).tolist())
    net = MeshNet(X, Y, float(rng.uniform(20, 200)) * GB,
                  float(rng.uniform(20, 2000)) * GB, attach)
    msgs = rng.uniform(0.01, 1.0, X * Y) * GB
    return net, msgs


def _check_waterfill_invariants(net: MeshNet, msgs: np.ndarray):
    inc = net.pull_incidence()
    cap = net.link_caps()
    active = msgs > 0
    rates = waterfill_rates(inc, cap, active)
    load = (rates * active) @ inc
    # capacity conservation on every link
    assert (load <= cap * (1 + 1e-9)).all()
    # max-min optimality: every active flow crosses a saturated link
    saturated = load >= cap * (1 - 1e-9)
    for f in np.where(active)[0]:
        assert (inc[f] * saturated).any(), f"flow {f} not bottlenecked"
    # event-driven == vectorized completion times to float64 round-off
    out = simulate_flows(inc, cap, msgs)
    flows_done = _event_reference(net, msgs)
    np.testing.assert_allclose(out["done"], flows_done, rtol=1e-9)
    # batched jax port agrees too
    j = netsim_jax.simulate_pull_batch(cap[None], inc[None], msgs[None])
    np.testing.assert_allclose(j["done"][0], out["done"], rtol=1e-9)
    # every flow pushed its whole message across each link of its route
    np.testing.assert_allclose(out["link_bytes"], msgs @ inc,
                               rtol=1e-9, atol=1e-3)
    assert out["latency"] == pytest.approx(out["done"].max(), rel=1e-12)


def _event_reference(net: MeshNet, msgs: np.ndarray) -> np.ndarray:
    """Per-flow done times from the event engine, with per-flow sizes
    (the public event path takes one message size, so drive the engine
    internals directly)."""
    from repro.core.netsim import EPS_BYTES, Flow, _maxmin_rates

    flows = [Flow(d, float(msgs[d]), net.route(net.mem, d))
             for d in range(net.X * net.Y)]
    for f in flows:
        if f.bytes_left <= EPS_BYTES:
            f.done_at = 0.0
    t = 0.0
    while any(f.bytes_left > EPS_BYTES for f in flows):
        rates = _maxmin_rates(flows, net.cap)
        dt = min(f.bytes_left / rates[i] for i, f in enumerate(flows)
                 if f.bytes_left > EPS_BYTES and rates.get(i, 0) > 0)
        for i, f in enumerate(flows):
            if f.bytes_left > EPS_BYTES:
                f.bytes_left = max(0.0, f.bytes_left - rates[i] * dt)
                if f.bytes_left <= EPS_BYTES and f.done_at is None:
                    f.done_at = t + dt
        t += dt
    return np.array([f.done_at for f in flows])


@pytest.mark.parametrize("seed", range(8))
def test_waterfill_invariants_random_meshes(seed):
    """Deterministic spot checks of the §11 invariants on random meshes
    and attachment sets (always runs; the hypothesis variant widens the
    search when the dev dependency is installed)."""
    net, msgs = _random_case(seed)
    _check_waterfill_invariants(net, msgs)


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 10_000))
def test_waterfill_invariants_property(seed):
    net, msgs = _random_case(seed)
    _check_waterfill_invariants(net, msgs)


def test_netsim_sweep_cache_and_backend_parity():
    sweep.clear_cache()
    try:
        nets = [fig3_net(m, p, bw) for m, p, bw in FIG3_CELLS]
        a = sweep.netsim_sweep(nets, 1 * GB, backend="jax")
        assert sweep.cache_stats() == {"hits": 0, "misses": len(nets)}
        b = sweep.netsim_sweep(nets, 1 * GB, backend="jax")
        assert sweep.cache_stats()["hits"] == len(nets)
        # numpy backend is cached under its own key and agrees to 1e-9
        c = sweep.netsim_sweep(nets, 1 * GB, backend="numpy")
        assert sweep.cache_stats()["misses"] == 2 * len(nets)
        for ra, rb, rc in zip(a, b, c):
            assert ra["latency"] == rb["latency"]
            assert rc["latency"] == pytest.approx(ra["latency"], rel=1e-9)
    finally:
        sweep.clear_cache()
