"""Hardware model for MCM (multi-chip-module) systems — paper Sec. 4.1/4.2.1.

Defines the four packaging types (Fig. 2/4), the Table-2 energy/bandwidth
constants, and the chiplet-grid :class:`Topology`: per-chiplet local
indices (x, y) relative to the nearest "global chiplet" (memory entrance),
hop-count matrices for every communication case in Sec. 4.3 (including the
diagonal link strategy of Sec. 5.1), entrance link counts used by the
collection equation (eq. 8), and the link-level flow network consumed by
the ``congestion="flow"`` evaluator mode.

All geometry primitives live in :mod:`repro.core.topology` (DESIGN.md
§11) — this module composes them per :class:`HWConfig` and is the one
place the rest of the stack reads topology facts from. Everything here is
plain numpy, computed once per (HWConfig) and then consumed as constants
by the jax-vectorized evaluator.
"""
from __future__ import annotations

import dataclasses
import enum
from functools import cached_property

import numpy as np

from . import topology as topo

__all__ = [
    "MCMType",
    "ChipletClass",
    "HWConfig",
    "Topology",
    "TABLE2",
    "make_hw",
]


class MCMType(str, enum.Enum):
    """Packaging types from Fig. 2 — position of main memory vs chiplets.

    A: 2.5D, single memory stack at a corner (SIMBA / Manticore).
    B: 2.5D, memory stacks distributed along the left+right edges (MTIA).
    C: 3D, memory stacked on top of every chiplet.
    D: hybrid of B and C — edge stacks plus 3D memory on the interior quad
       (Chiplet-Gym-style); memory distance is near-uniform.
    """

    A = "A"
    B = "B"
    C = "C"
    D = "D"


#: Table 2 — MCMComm system configurations. Bandwidths in bytes/s, energies
#: in Joules/bit (pJ converted), MAC energy in Joules/cycle.
TABLE2 = {
    "bw_hbm": 1000e9,          # 1000 GB/s
    "bw_dram": 60e9,           # 60 GB/s
    "bw_nop": 60e9,            # 60 GB/s per NoP link
    "e_nop_bit_hop": 1.285e-12,
    "e_dram_bit": 14.8e-12,
    "e_hbm_bit": 4.11e-12,
    "e_sram_bit": 0.28e-12,
    "e_mac_cycle": 4.6e-12,
    "freq_hz": 1.0e9,          # 1 GHz chiplet clock (SCALE-Sim default class)
}


@dataclasses.dataclass(frozen=True)
class ChipletClass:
    """One hardware class in a heterogeneous chiplet grid (SCAR-style).

    A class scales the three per-chiplet rates relative to the package
    baseline: ``freq_hz`` and ``bw_nop`` are absolute rates for chiplets
    of this class, ``mem_scale`` multiplies the chiplet's share of the
    off-chip bandwidth (1.0 = the homogeneous iso-split share). Defaults
    reproduce the Table-2 baseline exactly, so a one-class grid is the
    homogeneous machine.
    """

    name: str = "base"
    freq_hz: float = TABLE2["freq_hz"]
    bw_nop: float = TABLE2["bw_nop"]
    mem_scale: float = 1.0

    def __post_init__(self):
        self.validate()

    def validate(self) -> None:
        """Re-runnable rate validation (unpickling bypasses
        ``__post_init__``; the serve firewall calls this directly)."""
        for f in ("freq_hz", "bw_nop", "mem_scale"):
            v = getattr(self, f)
            if not isinstance(v, (int, float)) or isinstance(v, bool) \
                    or not np.isfinite(v) or v <= 0:
                raise ValueError(
                    f"ChipletClass.{f} must be a finite positive rate, "
                    f"got {v!r}")


@dataclasses.dataclass(frozen=True)
class HWConfig:
    """``HW = {BW_nop, BW_mem, X, Y, R, C, type}`` — paper eq. in Sec 4.2.1.

    ``bw_mem`` is the *total* off-chip bandwidth of the package; it is split
    evenly across memory entrances for types B/C/D so that packaging types
    are iso-bandwidth comparable (the paper's Fig. 3(c) experiment keeps a
    single memory node and moves it; ``n_mem_nodes=1`` reproduces that).
    """

    bw_nop: float = TABLE2["bw_nop"]
    bw_mem: float = TABLE2["bw_hbm"]
    X: int = 4
    Y: int = 4
    R: int = 16
    C: int = 16
    mcm_type: MCMType = MCMType.A
    diagonal_links: bool = False
    freq_hz: float = TABLE2["freq_hz"]
    bytes_per_elem: int = 1            # int8 edge-inference datapath
    # Energy constants (overridable for sensitivity studies).
    e_nop_bit_hop: float = TABLE2["e_nop_bit_hop"]
    e_mem_bit: float = TABLE2["e_hbm_bit"]
    e_sram_bit: float = TABLE2["e_sram_bit"]
    e_mac_cycle: float = TABLE2["e_mac_cycle"]
    # Heterogeneous chiplet grid (empty = homogeneous): a table of
    # :class:`ChipletClass` rows plus a row-major ``[X·Y]`` assignment of
    # each chiplet to a class index. Tuples keep the config hashable, so
    # the hetero axes join every §9 fingerprint/cache key for free.
    chiplet_classes: tuple = ()
    class_assignment: tuple = ()

    def __post_init__(self):
        # Normalize list inputs to tuples so equal configs hash equal.
        if not isinstance(self.chiplet_classes, tuple):
            object.__setattr__(self, "chiplet_classes",
                               tuple(self.chiplet_classes))
        if not isinstance(self.class_assignment, tuple):
            object.__setattr__(self, "class_assignment",
                               tuple(int(i) for i in self.class_assignment))
        self.validate()

    def validate(self) -> None:
        """Full field validation, re-runnable on an already-constructed
        instance (unpickling via ``__setstate__`` bypasses
        ``__post_init__``, so the serve-layer BadRequest firewall calls
        this explicitly on request ingress)."""
        if self.X < 1 or self.Y < 1:
            raise ValueError("grid must be at least 1x1")
        if self.R < 1 or self.C < 1:
            raise ValueError("systolic array must be at least 1x1")
        for f in ("bw_nop", "bw_mem", "freq_hz"):
            v = getattr(self, f)
            if not np.isfinite(v) or v <= 0:
                raise ValueError(
                    f"HWConfig.{f} must be a finite positive rate, "
                    f"got {v!r}")
        classes, assign = self.chiplet_classes, self.class_assignment
        if bool(classes) != bool(assign):
            raise ValueError(
                "chiplet_classes and class_assignment must be set "
                "together (both empty = homogeneous)")
        if not classes:
            return
        for c in classes:
            if not isinstance(c, ChipletClass):
                raise ValueError(
                    f"chiplet_classes entries must be ChipletClass, "
                    f"got {type(c).__name__}")
            c.validate()
        if len(assign) != self.X * self.Y:
            raise ValueError(
                f"class_assignment must have X*Y={self.X * self.Y} "
                f"entries (row-major), got {len(assign)}")
        n = len(classes)
        for i in assign:
            if not isinstance(i, (int, np.integer)) \
                    or isinstance(i, bool) or not 0 <= i < n:
                raise ValueError(
                    f"class_assignment index {i!r} out of range for "
                    f"{n} chiplet class(es)")

    @classmethod
    def hetero(cls, classes, assignment, **kw) -> "HWConfig":
        """Heterogeneous constructor: ``classes`` is a sequence of
        :class:`ChipletClass`, ``assignment`` the row-major ``[X·Y]``
        class index per chiplet. One class broadcast everywhere is
        bitwise-identical to the legacy scalar config — the migration
        gate every engine is tested against."""
        return cls(chiplet_classes=tuple(classes),
                   class_assignment=tuple(int(i) for i in assignment),
                   **kw)

    @property
    def is_hetero(self) -> bool:
        return bool(self.chiplet_classes)

    # Per-chiplet rate views ``[X, Y]`` (float64). Homogeneous configs
    # broadcast the scalar fields, so downstream elementwise math is
    # bitwise-identical to the scalar code it replaced; hetero configs
    # gather the class table through the assignment.
    @cached_property
    def bw_nop_xy(self) -> np.ndarray:
        if not self.is_hetero:
            return np.full((self.X, self.Y), float(self.bw_nop))
        vals = np.array([c.bw_nop for c in self.chiplet_classes])
        return vals[np.array(self.class_assignment)].reshape(
            self.X, self.Y)

    @cached_property
    def freq_xy(self) -> np.ndarray:
        if not self.is_hetero:
            return np.full((self.X, self.Y), float(self.freq_hz))
        vals = np.array([c.freq_hz for c in self.chiplet_classes])
        return vals[np.array(self.class_assignment)].reshape(
            self.X, self.Y)

    @cached_property
    def mem_scale_xy(self) -> np.ndarray:
        if not self.is_hetero:
            return np.ones((self.X, self.Y))
        vals = np.array([c.mem_scale for c in self.chiplet_classes])
        return vals[np.array(self.class_assignment)].reshape(
            self.X, self.Y)

    @property
    def n_chiplets(self) -> int:
        return self.X * self.Y

    @cached_property
    def topology(self) -> "Topology":
        return Topology(self)

    # Pickle only the declared fields: the default protocol would drag
    # the cached ``topology`` (hop matrices, flow nets) along, bloating
    # the on-disk sweep-cache store (repro.serve.cache_store) — and the
    # unpickled copy must hash/compare equal to a fresh HWConfig, which
    # field-only state guarantees.
    def __getstate__(self):
        return {f.name: getattr(self, f.name)
                for f in dataclasses.fields(self)}

    def __setstate__(self, state):
        for k, v in state.items():
            object.__setattr__(self, k, v)

    def replace(self, **kw) -> "HWConfig":
        return dataclasses.replace(self, **kw)


def _entrances(hw: HWConfig) -> list[tuple[int, int, str]]:
    """Memory entrance chiplets as (gx, gy, kind) with kind in
    {"corner", "edge", "3d"} (:func:`repro.core.topology.entrances`)."""
    return topo.entrances(hw.mcm_type, hw.X, hw.Y)


#: Back-compat alias — the implementation lives in the shared topology
#: layer (DESIGN.md §11).
_n_mesh_links = topo.n_mesh_links


class Topology:
    """Precomputed per-chiplet indexing and hop matrices for one HWConfig.

    Arrays are indexed [gx, gy] over the *global* grid. Chiplets are grouped
    by their nearest memory entrance; within a group, (x, y) are the local
    indices of Sec. 4.2.1 ("rows and columns away from the global chiplet")
    and (Xg, Yg) the group extents that replace the global X, Y in the hop
    equations (for type A the group is the whole grid, so they coincide).
    """

    def __init__(self, hw: HWConfig):
        self.hw = hw
        X, Y = hw.X, hw.Y
        ents = _entrances(hw)
        self.entrances = ents
        self.n_entrances = len(ents)

        # Nearest-entrance grouping + Sec. 4.2.1 local indices.
        (self.entrance_id, self.x_local, self.y_local,
         self.Xg, self.Yg) = topo.assign_entrances(X, Y, ents)

        # Entrance link counts (for eq. 8 collection bandwidth). The
        # entrance chiplet's own data never crosses the NoP (it sits on the
        # off-chip port / 3D via), so collection counts only non-entrance
        # bytes; the links are the mesh links incident to the entrance.
        kinds = [e[2] for e in ents]
        self.entrance_links = np.array(
            [
                topo.n_mesh_links(exi, eyi, X, Y, hw.diagonal_links)
                for (exi, eyi, k) in ents
            ]
        )
        # Per-entrance masks (one-hot positions, membership, row/column
        # projections — the evaluator's serialization terms).
        (self.entrance_member, self.entrance_pos,
         self.entrance_rows, self.entrance_cols) = topo.entrance_masks(
            X, Y, ents, self.entrance_id)
        self.entrance_is_3d = np.array([k == "3d" for k in kinds])
        # Per-chiplet: is its entrance a 3D (zero-hop) stack?
        self.is_3d = self.entrance_is_3d[self.entrance_id]

        # Per-entrance memory bandwidth share (iso-total-bandwidth).
        self.bw_mem_per_entrance = hw.bw_mem / self.n_entrances

        # Per-chiplet / per-entrance rate arrays (hetero grids; for a
        # homogeneous config these broadcast the scalars bitwise — the
        # ``* 1.0`` mem scale and equal-element arrays change nothing).
        self.bw_nop_xy = hw.bw_nop_xy                       # [X, Y]
        self.freq_xy = hw.freq_xy                           # [X, Y]
        ex = np.array([e[0] for e in ents])
        ey = np.array([e[1] for e in ents])
        self.bw_nop_entrance = self.bw_nop_xy[ex, ey]       # [E]
        self.bw_mem_entrance = (
            self.bw_mem_per_entrance * hw.mem_scale_xy[ex, ey])  # [E]

        # Chiplets per entrance group (for collection-link sharing).
        self.group_size = np.bincount(
            self.entrance_id.ravel(), minlength=self.n_entrances
        )

        self._build_hop_matrices()
        self._flow_net = None

    # ----------------------------------------------------------------- hops
    def _build_hop_matrices(self):
        hw = self.hw
        # eq. 10 (low BW minimal path), eq. 11/12 (high-BW row/col-shared
        # with farthest-first waiting), Sec. 5.1.1 diagonal alternative.
        self.hops_low, self.hops_row_shared, self.hops_col_shared = \
            topo.hop_matrices(self.x_local, self.y_local, self.Xg, self.Yg,
                              hw.diagonal_links)

        # 3D-stacked chiplets read memory directly: zero NoP hops.
        for a in ("hops_low", "hops_row_shared", "hops_col_shared"):
            m = getattr(self, a).copy()
            m[self.is_3d & (self.x_local == 0) & (self.y_local == 0)] = 0
            setattr(self, a, m)

        # Collection (eq. 8) effective entrance link bandwidth per group —
        # number of NoP links into the entrance chiplet; 3D entrances
        # collect at memory bandwidth directly (no NoP bottleneck).
        self.collect_links = np.maximum(self.entrance_links, 0)

    # ------------------------------------------------------- flow network
    @property
    def mesh_graph(self) -> topo.MeshGraph:
        return topo.MeshGraph(self.hw.X, self.hw.Y)

    def flow_net(self):
        """Link-level flow network for ``congestion="flow"`` (DESIGN.md
        §11): ``(link_cap [L], dist_inc [X·Y, L], coll_inc [X·Y, L])``.

        One *mesh-only* flow per chiplet, routed assigned entrance → XY
        for the distribution phase and the reverse for collection
        (chiplets use their hop-model entrance, ``entrance_id``, so the
        flow and regime modes agree on which entrance serves which
        chiplet). The memory-port columns are zeroed out of the
        incidence: off-chip serialization stays the exact closed-form
        per-entrance term — a port is used only by its own group, so
        waterfilling it adds nothing, and shared row/column stripes are
        fetched once per group (the paper's multicast accounting), not
        once per chiplet. Only NoP delivery — which the paper does count
        per chiplet — is simulated. A chiplet sitting on its entrance
        (or under a 3D stack) has an empty mesh route: its incidence row
        is zero and the evaluator masks its simulated demand to zero.
        """
        if self._flow_net is None:
            hw = self.hw
            g = self.mesh_graph
            Y = hw.Y
            attach = [ex * Y + ey for ex, ey, _ in self.entrances]
            assign = np.array(
                [attach[e] for e in self.entrance_id.ravel()])
            dist = g.pull_incidence(attach, assign)
            coll = g.push_incidence(attach, assign)
            ports = ~g.mesh_link_mask()
            dist[:, ports] = 0.0
            coll[:, ports] = 0.0
            self._flow_net = (
                g.link_caps(hw.bw_nop_xy.ravel(), hw.bw_mem, attach,
                            mem_scale=hw.mem_scale_xy.ravel()),
                dist,
                coll,
            )
        return self._flow_net

    # ------------------------------------------------------------- helpers
    def describe(self) -> str:
        hw = self.hw
        lines = [
            f"MCM type {hw.mcm_type.value}: {hw.X}x{hw.Y} chiplets, "
            f"{hw.R}x{hw.C} systolic, NoP {hw.bw_nop/1e9:.0f} GB/s, "
            f"mem {hw.bw_mem/1e9:.0f} GB/s over {self.n_entrances} "
            f"entrance(s), diagonal={hw.diagonal_links}",
            f"entrance links: {self.entrance_links.tolist()}",
        ]
        return "\n".join(lines)


def make_hw(
    mcm_type: str | MCMType = "A",
    grid: int | tuple[int, int] = 4,
    memory: str = "hbm",
    diagonal_links: bool = False,
    **kw,
) -> HWConfig:
    """Convenience constructor: ``make_hw("A", 4, "hbm")``."""
    if isinstance(grid, int):
        grid = (grid, grid)
    bw_mem = TABLE2["bw_hbm"] if memory.lower() == "hbm" else TABLE2["bw_dram"]
    e_mem = TABLE2["e_hbm_bit"] if memory.lower() == "hbm" else TABLE2["e_dram_bit"]
    return HWConfig(
        X=grid[0],
        Y=grid[1],
        mcm_type=MCMType(mcm_type),
        bw_mem=bw_mem,
        e_mem_bit=e_mem,
        diagonal_links=diagonal_links,
        **kw,
    )
