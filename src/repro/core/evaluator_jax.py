"""JAX backend for the analytical evaluator — jit + vmap over populations.

Mirrors :meth:`repro.core.evaluator.Evaluator.evaluate_batch` (eqs. 3–12)
op-for-op so the two backends agree to float64 round-off; the numpy
implementation stays the reference and the parity suite
(``tests/test_backend_parity.py``) asserts the contract (DESIGN.md §8).

Structure:
  * every per-(Task, HWConfig) constant — GEMM dims, hop matrices,
    entrance masks, Table-2 scalars — travels in an :class:`EvalConsts`
    dict pytree *argument* rather than a trace-time closure, so one
    compiled executable serves every config with the same shape signature
    (the sweep engine in :mod:`repro.core.sweep` stacks these along a grid
    axis and vmaps over them);
  * :func:`population_fn` = ``jit(vmap(single-candidate))`` — the GA
    fitness path; :func:`grid_fn` adds a second vmap over the grid axis;
  * all entry points run under ``jax.experimental.enable_x64()`` — cycle
    counts overflow float32 mantissas (same float64 rule as the numpy
    path) and the scope keeps x64 from leaking into the rest of the
    repo's float32 jax code.

Only the modeling toggles (:class:`EvalOptions` fields — redistribution,
async_exec, energy_mode, congestion) are static: they select code paths,
so each combination compiles once per shape signature and is cached in
``population_fn`` / ``grid_fn``. The ``congestion="flow"`` path traces
the max-min waterfilling netsim (:mod:`repro.core.netsim_jax`) inside
the same jit, vmapped over the op axis (DESIGN.md §11).
"""
from __future__ import annotations

import functools
from typing import Any, Dict

import numpy as np

import jax
import jax.numpy as jnp

from .evaluator import EvalOptions
from .netsim_jax import waterfill_times

__all__ = [
    "EvalConsts",
    "consts_from_evaluator",
    "population_fn",
    "grid_fn",
    "batch_evaluate",
]

#: dict pytree of per-(Task, HWConfig) constants; see CONST_KEYS.
EvalConsts = Dict[str, Any]

#: Array-valued keys ([n]: per-op, [X,Y]: per-chiplet, [E...]: per-entrance,
#: [L]/[XY,L]: link-level flow network, DESIGN.md §11) followed by the 0-d
#: scalar keys. Order is the canonical stacking order used by the sweep
#: engine.
CONST_KEYS = (
    # per-op [n]
    "M", "K", "N", "sync", "w_scale", "epilogue", "chain_valid",
    # per-chiplet [X, Y] (hop matrices + heterogeneous rate arrays;
    # homogeneous configs broadcast the scalar rates, bitwise)
    "hA", "hW", "h_min", "bw_nop_xy", "freq_xy",
    # per-row/cross-row redistribution bottlenecks [X] / [X-1]
    "row_bw", "cross_bw",
    # per-entrance ("bw_ent" is the [E] off-chip share, "bw_nop_ent"
    # the [E] entrance-link NoP rate)
    "row_mask", "col_mask", "ent_mask", "ent_pos", "is3d", "links",
    "bw_ent", "bw_nop_ent",
    # link-level flow network (congestion="flow")
    "flow_cap", "dist_inc", "coll_inc",
    # scalars (0-d)
    "B", "bw_nop_min", "R", "C",
    "e_sram", "e_mem", "e_nop", "e_mac",
)


def consts_from_evaluator(ev) -> EvalConsts:
    """Extract the constant bundle from a (numpy) Evaluator instance.

    Returns plain float64/bool numpy arrays — conversion to device arrays
    happens inside the x64 scope at call time.
    """
    hw = ev.hw
    f8 = lambda a: np.asarray(a, dtype=np.float64)
    if ev.opts.congestion == "flow":
        flow_cap, dist_inc, coll_inc = ev.top.flow_net()
    else:
        # Regime mode never reads the flow network; ship 1-element
        # placeholders instead of the [X·Y, L] incidence matrices —
        # consts are stacked per sweep point and moved to device, and
        # XLA's dead-code elimination cannot recover that traffic.
        flow_cap = dist_inc = coll_inc = np.zeros(1)
    return {
        "flow_cap": f8(flow_cap),
        "dist_inc": f8(dist_inc), "coll_inc": f8(coll_inc),
        "M": f8(ev.M), "K": f8(ev.K), "N": f8(ev.N),
        "sync": f8(ev.sync),
        "w_scale": f8(ev.w_scale), "epilogue": f8(ev.epilogue),
        "chain_valid": f8(ev.chain_valid),
        "hA": f8(ev.hA), "hW": f8(ev.hW), "h_min": f8(ev.h_min),
        "row_mask": f8(ev.row_mask), "col_mask": f8(ev.col_mask),
        "ent_mask": f8(ev.ent_mask), "ent_pos": f8(ev.ent_pos),
        "is3d": np.asarray(ev.top.entrance_is_3d, dtype=bool),
        "links": f8(ev.links),
        "bw_nop_xy": f8(ev.bw_nop_xy), "freq_xy": f8(ev.freq_xy),
        "row_bw": f8(ev.row_bw), "cross_bw": f8(ev.cross_bw),
        "bw_ent": f8(ev.bw_ent_e), "bw_nop_ent": f8(ev.bw_nop_ent),
        "B": f8(ev.B), "bw_nop_min": f8(ev.bw_nop_min),
        "R": f8(float(hw.R)), "C": f8(float(hw.C)),
        "e_sram": f8(hw.e_sram_bit * 8.0), "e_mem": f8(hw.e_mem_bit * 8.0),
        "e_nop": f8(hw.e_nop_bit_hop * 8.0), "e_mac": f8(hw.e_mac_cycle),
    }


def _eval_single(c: EvalConsts, Px, Py, collectors, redist, *,
                 redistribution: bool, async_exec: bool, energy_mode: str,
                 congestion: str = "regime", smooth: bool = False):
    """One candidate: Px [n,X], Py [n,Y], collectors [n], redist [n].

    Line-for-line port of ``Evaluator.evaluate_batch`` with the population
    axis removed (vmap adds it back). Static python ints n/X/Y come from
    the traced shapes; R/C/bandwidths stay traced so compilations are
    shared across HWConfigs of equal shape.

    ``smooth=True`` replaces the ``ceil(P/unit)`` tile counts — zero
    gradient almost everywhere — with their continuous relaxation
    ``P/unit``, making the whole objective reverse-differentiable for
    the projected-gradient seeding of :mod:`repro.core.cosearch`
    (DESIGN.md §16). Only the ``congestion="regime"`` path is
    differentiable (the flow netsim's waterfilling ``while_loop`` has no
    reverse rule); search/scoring always runs ``smooth=False``.
    """
    n, X = Px.shape
    Y = Py.shape[1]
    # "bw_ent" is the per-entrance [E] off-chip share; per-[n,E] terms
    # divide by it with a plain last-axis broadcast.
    B, bw_ent = c["B"], c["bw_ent"]
    R, C = c["R"], c["C"]
    M, K, N = c["M"], c["K"], c["N"]
    sync = c["sync"]

    redist = redist * c["chain_valid"]
    if not redistribution:
        redist = jnp.zeros_like(redist)
    # redist_in[i] = output of op i-1 was redistributed (A already local).
    redist_in = jnp.concatenate([jnp.zeros_like(redist[:1]), redist[:-1]])
    keepA = 1.0 - redist_in
    redist_out = redist

    # ---------------------------------------------------- data volumes
    chunk = Px[:, :, None] * Py[:, None, :] * B                # [n,X,Y]
    inA = Px * K[:, None] * B                                  # [n,X]
    inW = Py * (K * c["w_scale"])[:, None] * B                 # [n,Y]

    # ----------------------------------------------- phase 1: data load
    A_e = jnp.einsum("ex,nx->ne", c["row_mask"], inA)
    W_e = jnp.einsum("ey,ny->ne", c["col_mask"], inW)
    t_off_in = ((keepA[:, None] * A_e + W_e) / bw_ent).max(axis=-1)

    tA_xy = inA[:, :, None] * c["hA"][None]                    # bytes*hops
    tW_xy = inW[:, None, :] * c["hW"][None]
    nop_in_xy = ((keepA[:, None, None] * tA_xy + tW_xy)
                 / c["bw_nop_xy"][None])
    t_nop_in = nop_in_xy.max(axis=(-1, -2))

    flow_mode = congestion == "flow"
    if flow_mode:
        # §11 flow congestion: trace the waterfilling netsim per op
        # (vmapped over the op axis) against the topology's mesh-only
        # flow network — simulated per-chiplet NoP arrival times replace
        # the hop-matrix closed form; off-chip serialization keeps the
        # exact per-entrance term. Routeless chiplets (on their
        # entrance / under a 3D stack) are masked to zero bytes.
        d_routed = (c["dist_inc"].sum(axis=1) > 0).astype(inA.dtype)
        c_routed = (c["coll_inc"].sum(axis=1) > 0).astype(inA.dtype)
        demand = (keepA[:, None, None] * inA[:, :, None]
                  + inW[:, None, :]).reshape(n, X * Y) * d_routed

        def dist_one(b):
            _, done, _ = waterfill_times(c["flow_cap"], c["dist_inc"], b)
            return done

        def coll_one(b):
            t, _, _ = waterfill_times(c["flow_cap"], c["coll_inc"], b)
            return t

        dist_done = jax.vmap(dist_one)(demand).reshape(n, X, Y)
        t_coll_flow = jax.vmap(coll_one)(
            chunk.reshape(n, X * Y) * c_routed)
        t_in = jnp.maximum(t_off_in, dist_done.max(axis=(-1, -2)))
    else:
        t_in = jnp.maximum(t_off_in, t_nop_in)

    # -------------------------------------------------- phase 2: compute
    fill = (2.0 * R + C + K - 2.0)[:, None, None]
    if smooth:
        tiles = (Px / R)[:, :, None] * (Py / C)[:, None, :]
    else:
        tiles = jnp.ceil(Px / R)[:, :, None] * jnp.ceil(Py / C)[:, None, :]
    cyc = fill * tiles
    cyc = cyc + c["epilogue"][:, None, None] * Px[:, :, None] \
        * Py[:, None, :] / C
    t_comp_xy = cyc / c["freq_xy"][None]
    t_comp = t_comp_xy.max(axis=(-1, -2))

    # ------------------------------------------- phase 3a: offload path
    out_e = jnp.einsum("exy,nxy->ne", c["ent_mask"], chunk)
    out_at_ent = jnp.einsum("exy,nxy->ne", c["ent_pos"], chunk)
    nonlocal_out = out_e - jnp.where(c["is3d"][None, :], out_at_ent, 0.0)
    links = c["links"][None, :]
    links_safe = jnp.where(links > 0, links, 1.0)
    t_collect = jnp.where(
        links > 0, nonlocal_out / (links_safe * c["bw_nop_ent"][None, :]),
        0.0,
    ).max(axis=-1)
    t_off_out = (out_e / bw_ent).max(axis=-1)
    t_offload = jnp.maximum(t_coll_flow if flow_mode else t_collect,
                            t_off_out)

    # ----------------------------------- phase 3b: redistribution path
    yidx = jnp.arange(Y)[None, :]
    cc = collectors[:, None]
    left_m = (yidx < cc).astype(jnp.float64)
    right_m = (yidx > cc).astype(jnp.float64)
    left_x = jnp.einsum("nxy,ny->nx", chunk, left_m)
    right_x = jnp.einsum("nxy,ny->nx", chunk, right_m)
    t1 = (jnp.maximum(left_x, right_x) / c["row_bw"][None]).max(axis=-1)
    rowbytes = Px * N[:, None] * B                             # [n,X]
    t2 = (rowbytes / c["row_bw"][None]).max(axis=-1)
    cumf = jnp.cumsum(Px, axis=-1) / jnp.maximum(M[:, None], 1.0)
    cumf_next = jnp.concatenate([cumf[1:], cumf[-1:]], axis=0)
    if X > 1:
        crossing = jnp.abs(cumf - cumf_next)[:, : X - 1] * M[:, None]
        cross_bytes = crossing * N[:, None] * B
        t3 = (cross_bytes / c["cross_bw"][None]).max(axis=-1)
    else:
        cross_bytes = jnp.zeros_like(cumf[:, :0])
        t3 = jnp.zeros_like(t1)
    t_redist = t1 + t2 + t3

    t_out = jnp.where(redist_out > 0, t_redist, t_offload)

    t_sync = (sync * (Px.max(axis=-1) * 4.0 * B * max(Y - 1, 1))
              / c["bw_nop_min"])

    # ----------------------------------------------------- schedule
    if async_exec:
        fused_xy = (dist_done if flow_mode else nop_in_xy) + t_comp_xy
        t_fused = jnp.maximum(fused_xy.max(axis=(-1, -2)), t_off_in)
        core = jnp.where(sync > 0, t_in + t_comp, t_fused)
    else:
        core = t_in + t_comp
    t_ops = core + t_out + t_sync
    latency = t_ops.sum()

    # ------------------------------------------------------- energy
    sram_bytes = (Y * inA.sum(axis=-1) + X * inW.sum(axis=-1)
                  + chunk.sum(axis=(-1, -2)))
    E_sram = c["e_sram"] * sram_bytes.sum()

    if energy_mode == "paper":
        E_mac = c["e_mac"] * (cyc.max(axis=(-1, -2)) * R * C * X * Y).sum()
    else:
        E_mac = c["e_mac"] * (cyc.sum(axis=(-1, -2)) * R * C).sum()

    mem_bytes = (keepA[:, None] * A_e + W_e
                 + (1.0 - redist_out)[:, None] * out_e).sum()
    E_mem = c["e_mem"] * mem_bytes

    load_bh = (keepA[:, None, None] * tA_xy + tW_xy).sum(axis=(-1, -2))
    collect_bh = (chunk * c["h_min"][None]).sum(axis=(-1, -2))
    red_bh = (
        (left_x + right_x).sum(axis=-1)
        + rowbytes.sum(axis=-1) * max(Y - 1, 1)
        + (cross_bytes.sum(axis=-1) * Y if X > 1 else 0.0)
    )
    nop_bh = load_bh + jnp.where(redist_out > 0, red_bh, collect_bh)
    E_nop = c["e_nop"] * nop_bh.sum()

    energy = E_sram + E_mac + E_mem + E_nop
    return {
        "latency": latency,
        "energy": energy,
        "edp": energy * latency,
        "t_in": t_in,
        "t_comp": t_comp,
        "t_out": t_out,
        "E_sram": E_sram,
        "E_mac": E_mac,
        "E_mem": E_mem,
        "E_nop": E_nop,
    }


def to_device(consts: EvalConsts) -> EvalConsts:
    """Convert a constant bundle to float64 device arrays once, so repeated
    population calls skip host→device transfer (no-op on device arrays)."""
    with jax.experimental.enable_x64():
        return {k: jnp.asarray(v) for k, v in consts.items()}


def _static_key(opts: EvalOptions) -> tuple:
    return (bool(opts.redistribution), bool(opts.async_exec),
            opts.energy_mode, opts.congestion)


@functools.lru_cache(maxsize=None)
def population_fn(redistribution: bool, async_exec: bool, energy_mode: str,
                  congestion: str = "regime"):
    """``jit(vmap(candidate))``: (consts, Px[P,n,X], Py[P,n,Y],
    collectors[P,n], redist[P,n]) → dict of [P]/[P,n] arrays."""
    single = functools.partial(
        _eval_single, redistribution=redistribution,
        async_exec=async_exec, energy_mode=energy_mode,
        congestion=congestion)
    return jax.jit(jax.vmap(single, in_axes=(None, 0, 0, 0, 0)))


@functools.lru_cache(maxsize=None)
def _grid_inner(redistribution: bool, async_exec: bool, energy_mode: str,
                congestion: str = "regime"):
    """Unjitted grid×population function — the shard_map target of the
    sharded sweep fabric (DESIGN.md §15). Cached so the sharded wrapper
    in :mod:`repro.core.sweep_shard` keys its jit cache on a stable
    function identity."""
    single = functools.partial(
        _eval_single, redistribution=redistribution,
        async_exec=async_exec, energy_mode=energy_mode,
        congestion=congestion)
    over_pop = jax.vmap(single, in_axes=(None, 0, 0, 0, 0))
    return jax.vmap(over_pop, in_axes=(0, 0, 0, 0, 0))


@functools.lru_cache(maxsize=None)
def grid_fn(redistribution: bool, async_exec: bool, energy_mode: str,
            congestion: str = "regime"):
    """Grid×population form for the sweep engine: consts stacked on a
    leading grid axis, genomes shaped [G,P,...]; one compiled call per
    shape signature covers the whole grid group."""
    return jax.jit(_grid_inner(redistribution, async_exec, energy_mode,
                               congestion))


def _run_x64(fn, consts: EvalConsts, Px, Py, collectors, redist
             ) -> dict[str, np.ndarray]:
    """Shared call wrapper: float64 conversion inside the x64 scope,
    numpy float64 outputs with the numpy backend's keys/shapes."""
    with jax.experimental.enable_x64():
        cj = {k: jnp.asarray(v) for k, v in consts.items()}
        out = fn(cj,
                 jnp.asarray(Px, dtype=jnp.float64),
                 jnp.asarray(Py, dtype=jnp.float64),
                 jnp.asarray(collectors, dtype=jnp.float64),
                 jnp.asarray(redist, dtype=jnp.float64))
        return {k: np.asarray(v) for k, v in out.items()}


def batch_evaluate(consts: EvalConsts, opts: EvalOptions,
                   Px, Py, collectors, redist) -> dict[str, np.ndarray]:
    """Population-batched evaluation (genomes [P,...]) — the GA path."""
    return _run_x64(population_fn(*_static_key(opts)),
                    consts, Px, Py, collectors, redist)


def grid_evaluate(consts_stack: EvalConsts, opts: EvalOptions,
                  Px, Py, collectors, redist,
                  devices: str = "single") -> dict[str, np.ndarray]:
    """Grid-batched evaluation: every array carries a leading grid axis
    (consts [G,...], genomes [G,P,...]); used by :mod:`repro.core.sweep`.

    ``devices`` (DESIGN.md §15) shards the grid axis across local
    devices via :mod:`repro.core.sweep_shard`; outputs are bitwise
    identical to the single-device call."""
    G = int(np.shape(Px)[0])
    fn = grid_fn(*_static_key(opts))
    from . import sweep_shard

    if sweep_shard.resolve_devices(devices, G) == "sharded":
        inner = _grid_inner(*_static_key(opts))

        def fn(*args):
            return sweep_shard.sharded_grid_call(
                inner, args, (True,) * 5, G)
    return _run_x64(fn, consts_stack, Px, Py, collectors, redist)
