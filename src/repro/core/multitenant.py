"""Multi-tenant placement on one MCM package (DESIGN.md §18).

Several models co-resident on a single (possibly heterogeneous) chiplet
grid: each tenant gets a contiguous *row band* of the mesh, the bands
are disjoint and cover assignment candidates enumerated
deterministically (:func:`band_assignments`), and every tenant is solved
*inside its band* by one of the existing engines (GA / MIQP lattice /
co-search / the LS-uniform baseline) through :func:`repro.core.sweep.
solve_grid` — so all three search engines share one tenant
partition/decode path and the §9 sweep cache dedupes identical region
solves across assignments.

Scoring is two-stage:

  1. **Solo** — each tenant's chosen schedule is re-scored exactly by
     the evaluator on its *region* hardware (:func:`region_hw`: the band
     becomes an ``(x1−x0)×Y`` sub-package with a proportional share of
     the off-chip bandwidth and the matching slice of the chiplet-class
     assignment — hardware is data, so a region is just another
     HWConfig).
  2. **Contention** — tenants share the package NoP: one pull flow per
     chiplet on the *package* flow network (``Topology.flow_net()``,
     hetero link caps included) carries each tenant's input bytes spread
     over its band; the max-min waterfilling netsim runs once per tenant
     alone and once jointly, and the per-tenant slowdown (joint/solo
     completion, ≥ 1) stretches the tenant's input-load phase.

Package latency is the max over tenants (they run concurrently),
package energy the sum; the best assignment wins by strict ``<`` on the
requested objective with the lexicographically-first candidate as the
deterministic tie-break. The naive even-split assignment is always in
the candidate set, so the search result is never worse than it — the
``fig_hetero`` benchmark asserts it is strictly better on heterogeneous
grids.

All budgets are deterministic counts (assignment enumeration order,
inner-solver budgets); there is no wall-clock anywhere, so a point
solved alone equals the same point solved in a batch — the §9 contract.
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Any, Sequence

import numpy as np

from .evaluator import EvalOptions
from .hw import HWConfig
from .workload import Partition, Task, _split_even, uniform_partition

__all__ = [
    "MultiTenantConfig",
    "MultiTenantResult",
    "band_assignments",
    "even_split_assignment",
    "region_hw",
    "solve_multitenant",
]

#: Inner per-tenant solvers ("uniform" = the LS baseline, no search).
TENANT_METHODS = ("uniform", "ga", "miqp", "cosearch")


@dataclasses.dataclass(frozen=True)
class MultiTenantConfig:
    """Search configuration for :func:`solve_multitenant`.

    ``method``/``cfg`` pick the inner per-tenant engine and its (frozen)
    config — any of the three search engines, or ``"uniform"`` for the
    LS baseline. ``contention=False`` skips the joint netsim (solo
    scores only). ``max_assignments`` caps the deterministic
    band-composition enumeration (lexicographic prefix; the even split
    is always kept). ``devices`` follows the §15 knob and is normalized
    out of fingerprints.
    """

    method: str = "ga"
    cfg: Any = None
    contention: bool = True
    max_assignments: int = 64
    devices: str = "auto"

    def __post_init__(self):
        if self.method not in TENANT_METHODS:
            raise ValueError(f"unknown tenant method {self.method!r}; "
                             f"one of {TENANT_METHODS}")
        if self.max_assignments < 1:
            raise ValueError("max_assignments must be >= 1")


@dataclasses.dataclass(frozen=True)
class MultiTenantResult:
    """Best placement found: per-tenant row bands + schedules + scores.

    ``assignment`` is a tuple of per-tenant ``(x0, x1)`` row bands
    (disjoint, covering), ``partitions`` the per-tenant
    :class:`Partition` inside each band, ``per_tenant`` a tuple of dicts
    (latency/energy/edp/slowdown per tenant under the winning
    assignment), ``baseline`` the even-split scores the search must not
    lose to, ``evaluations`` the summed inner-solver evaluation counts.
    """

    assignment: tuple
    partitions: tuple
    objective: float
    latency: float
    energy: float
    edp: float
    per_tenant: tuple
    baseline: dict
    evaluations: int

    def copy(self) -> "MultiTenantResult":
        return MultiTenantResult(
            assignment=self.assignment,
            partitions=tuple(p.copy() for p in self.partitions),
            objective=self.objective,
            latency=self.latency,
            energy=self.energy,
            edp=self.edp,
            per_tenant=tuple(dict(d) for d in self.per_tenant),
            baseline=dict(self.baseline),
            evaluations=self.evaluations,
        )


# ------------------------------------------------------- band enumeration
def band_assignments(X: int, n_tenants: int,
                     max_assignments: int = 64) -> list[tuple]:
    """All contiguous row-band placements of ``n_tenants`` tenants on an
    ``X``-row mesh, as tuples of per-tenant ``(x0, x1)`` bands (disjoint,
    covering, tenant order fixed).

    Enumeration is the lexicographic cut-point order of
    ``itertools.combinations`` — deterministic, so budgets are counts.
    Truncation keeps the lexicographic prefix but always retains the
    even-split candidate (the baseline the search must dominate)."""
    if not 1 <= n_tenants <= X:
        raise ValueError(f"need 1 <= n_tenants <= X rows, got "
                         f"{n_tenants} tenants on {X} rows")
    out = []
    for cuts in itertools.combinations(range(1, X), n_tenants - 1):
        edges = (0,) + cuts + (X,)
        out.append(tuple((edges[i], edges[i + 1])
                         for i in range(n_tenants)))
    if len(out) > max_assignments:
        even = even_split_assignment(X, n_tenants)
        out = out[:max_assignments]
        if even not in out:
            out[-1] = even
    return out


def even_split_assignment(X: int, n_tenants: int) -> tuple:
    """The naive baseline: rows split as evenly as possible, remainder
    spread over the leading tenants (same convention as the partition
    layer's ``_split_even``)."""
    sizes = _split_even(X, n_tenants)
    edges = np.concatenate([[0], np.cumsum(sizes)])
    return tuple((int(edges[i]), int(edges[i + 1]))
                 for i in range(n_tenants))


# --------------------------------------------------------- region decode
def region_hw(hw: HWConfig, x0: int, x1: int) -> HWConfig:
    """The sub-package a tenant band ``[x0, x1)`` sees: an
    ``(x1−x0)×Y`` grid with a row-proportional share of the off-chip
    bandwidth and the matching row slice of the chiplet-class
    assignment. Because hardware is data (PR 3 / this refactor), the
    region is an ordinary :class:`HWConfig` every engine already
    accepts."""
    if not 0 <= x0 < x1 <= hw.X:
        raise ValueError(f"band [{x0}, {x1}) out of range for X={hw.X}")
    rows = x1 - x0
    kw = dict(X=rows, bw_mem=hw.bw_mem * rows / hw.X)
    if hw.is_hetero:
        kw["class_assignment"] = hw.class_assignment[x0 * hw.Y:x1 * hw.Y]
    return dataclasses.replace(hw, **kw)


def _decode_schedule(rec, method: str, region: HWConfig
                     ) -> tuple[Partition, np.ndarray, HWConfig, int]:
    """Shared decode of any engine's solver record into the exact-scoring
    genome: (partition, redist_mask, scoring hw, evaluations). The
    co-search diag gene folds into the scoring hardware."""
    score_hw = region
    if method == "cosearch" and getattr(rec, "diagonal", False):
        score_hw = dataclasses.replace(region, diagonal_links=True)
    return (rec.partition, np.asarray(rec.redist_mask, dtype=bool),
            score_hw, int(getattr(rec, "evaluations", 0)))


def _solve_tenants(tasks, regions, objective, options, cfg,
                   backend, cache, devices):
    """One inner solve + exact eval per tenant; returns
    (partitions, eval records, scoring hws, evaluation count)."""
    from . import sweep

    parts, rds, score_hws, evals = [], [], [], 0
    if cfg.method == "uniform":
        for task, region in zip(tasks, regions):
            parts.append(uniform_partition(task, region.X, region.Y))
            rds.append(None)
            score_hws.append(region)
    else:
        pts = [sweep.EvalPoint(task, region, options)
               for task, region in zip(tasks, regions)]
        recs = sweep.solve_grid(pts, objective=objective, cfg=cfg.cfg,
                                backend=backend, cache=cache,
                                method=cfg.method, devices=devices)
        for rec, region in zip(recs, regions):
            part, rd, score_hw, ev_n = _decode_schedule(
                rec, cfg.method, region)
            parts.append(part)
            rds.append(rd)
            score_hws.append(score_hw)
            evals += ev_n
    eval_pts = [
        sweep.EvalPoint(task, hw2, options, partition=part,
                        redist_mask=rd)
        for task, hw2, part, rd in zip(tasks, score_hws, parts, rds)]
    recs = sweep.eval_sweep(eval_pts, backend=backend, cache=cache,
                            devices=devices)
    evals += len(eval_pts)
    return parts, recs, score_hws, evals


# ------------------------------------------------------------ contention
def _tenant_demand(task: Task, band: tuple[int, int], hw: HWConfig
                   ) -> np.ndarray:
    """Per-chiplet input bytes ``[X·Y]``: the tenant's total load-phase
    traffic (activations + weights) spread evenly over its band."""
    arr = task.arrays()
    total = float(((arr["M"] * arr["K"]
                    + arr["K"] * arr["N"] * arr["w_scale"]).sum())
                  * hw.bytes_per_elem)
    x0, x1 = band
    demand = np.zeros(hw.X * hw.Y, dtype=np.float64)
    idx = np.arange(x0 * hw.Y, x1 * hw.Y)
    demand[idx] = total / len(idx)
    return demand


def _contention_slowdowns(tasks, assignment, hw: HWConfig) -> np.ndarray:
    """Per-tenant NoP contention slowdowns (≥ 1) from the shared package
    flow netsim: joint vs solo completion of each tenant's band flows.
    Routeless chiplets (on their entrance / under a 3D stack) are masked
    to zero bytes, exactly like the evaluator's flow mode."""
    from . import netsim

    caps, dist_inc, _ = hw.topology.flow_net()
    routed = dist_inc.sum(axis=1) > 0
    demands = [_tenant_demand(t, band, hw) * routed
               for t, band in zip(tasks, assignment)]
    joint = np.sum(demands, axis=0)
    if not joint.any():
        return np.ones(len(tasks))
    done_joint = netsim.simulate_flows(dist_inc, caps, joint)["done"]
    slow = np.ones(len(tasks))
    for t, demand in enumerate(demands):
        if not demand.any():
            continue
        done_solo = netsim.simulate_flows(dist_inc, caps, demand)["done"]
        live = demand > 0
        with np.errstate(divide="ignore", invalid="ignore"):
            ratio = np.where(live & (done_solo > 0),
                             done_joint / np.where(done_solo > 0,
                                                   done_solo, 1.0), 1.0)
        slow[t] = max(1.0, float(ratio.max()))
    return slow


# --------------------------------------------------------------- search
def solve_multitenant(
    tasks: Sequence[Task],
    hw: HWConfig,
    objective: str = "edp",
    options: EvalOptions | None = None,
    cfg: MultiTenantConfig = MultiTenantConfig(),
    backend: str = "jax",
    cache: bool = True,
    devices: str | None = None,
) -> MultiTenantResult:
    """Search row-band placements of ``tasks`` on ``hw`` and return the
    best package schedule (module docstring has the model).

    ``objective`` is ``"edp"`` / ``"latency"`` / ``"energy"`` — the
    package-level score both the inner solvers and the assignment
    selection optimize. The even-split baseline is always scored (and
    returned in ``result.baseline``), and the candidate set contains it,
    so ``result.objective <= baseline[objective]`` by construction."""
    if options is None:
        options = EvalOptions()
    if objective not in ("edp", "latency", "energy"):
        raise ValueError(f"unknown objective {objective!r}; "
                         f"one of ('edp', 'latency', 'energy')")
    tasks = tuple(tasks)
    if not tasks:
        raise ValueError("need at least one tenant task")
    if len(tasks) > hw.X:
        raise ValueError(f"{len(tasks)} tenants need {len(tasks)} row "
                         f"bands but the grid has X={hw.X} rows")
    hw.validate()

    assignments = band_assignments(hw.X, len(tasks),
                                   cfg.max_assignments)
    even = even_split_assignment(hw.X, len(tasks))
    best = None
    baseline: dict[str, Any] = {}
    total_evals = 0
    for assignment in assignments:
        regions = [region_hw(hw, x0, x1) for x0, x1 in assignment]
        parts, recs, score_hws, evals = _solve_tenants(
            tasks, regions, objective, options, cfg, backend, cache,
            devices)
        total_evals += evals
        if cfg.contention:
            slow = _contention_slowdowns(tasks, assignment, hw)
            total_evals += len(tasks) + 1
        else:
            slow = np.ones(len(tasks))
        per_tenant = []
        for rec, s in zip(recs, slow):
            lat = float(rec["latency"]
                        + float(rec["t_in"].sum()) * (s - 1.0))
            per_tenant.append({
                "latency": lat, "energy": float(rec["energy"]),
                "edp": float(rec["energy"]) * lat, "slowdown": float(s),
            })
        latency = max(d["latency"] for d in per_tenant)
        energy = sum(d["energy"] for d in per_tenant)
        scores = {"latency": latency, "energy": energy,
                  "edp": energy * latency}
        if assignment == even:
            baseline = {"assignment": even, **scores}
        if best is None or scores[objective] < best[0]:
            best = (scores[objective], assignment, tuple(parts),
                    tuple(per_tenant), scores)
    assert best is not None and baseline, "even split must be scored"
    _, assignment, parts, per_tenant, scores = best
    return MultiTenantResult(
        assignment=assignment,
        partitions=parts,
        objective=best[0],
        latency=scores["latency"],
        energy=scores["energy"],
        edp=scores["edp"],
        per_tenant=per_tenant,
        baseline=baseline,
        evaluations=total_evals,
    )
