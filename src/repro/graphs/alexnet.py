"""AlexNet as an im2col GEMM sequence.

The paper's most sequentially-chained workload — "every operator takes
only output from the previous convolution layer and static filter weight
as inputs", so on-package redistribution applies between every pair and
AlexNet shows MCMComm's largest gains (Sec. 7.1).

Conv layer → GEMM: M = out_h·out_w·batch, K = C_in·k·k, N = C_out.
"""
from __future__ import annotations

from ..core.workload import GemmOp, Task

# (name, out_spatial, k, c_in, c_out)
_CONVS = [
    ("conv1", 55 * 55, 11, 3, 96),
    ("conv2", 27 * 27, 5, 96, 256),
    ("conv3", 13 * 13, 3, 256, 384),
    ("conv4", 13 * 13, 3, 384, 384),
    ("conv5", 13 * 13, 3, 384, 256),
]
_FCS = [
    ("fc6", 9216, 4096),
    ("fc7", 4096, 4096),
    ("fc8", 4096, 1000),
]


def alexnet_task(batch: int = 1) -> Task:
    ops = []
    first = True
    for name, spatial, k, cin, cout in _CONVS:
        ops.append(
            GemmOp(
                name,
                M=spatial * batch,
                K=cin * k * k,
                N=cout,
                chained=not first,
                epilogue_flops_per_elem=1,  # ReLU in the SIMD unit
            )
        )
        first = False
    for name, k, n in _FCS:
        ops.append(
            GemmOp(
                name,
                M=batch,
                K=k,
                N=n,
                chained=True,
                epilogue_flops_per_elem=1 if name != "fc8" else 0,
            )
        )
    return Task(f"alexnet_b{batch}", ops)
