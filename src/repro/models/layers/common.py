"""Shared building blocks: norms, RoPE, activations, initializers."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def rms_norm(x, scale, eps: float = 1e-5, plus_one: bool = False):
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    y = x32 * jax.lax.rsqrt(var + eps)
    s = (1.0 + scale.astype(jnp.float32)) if plus_one \
        else scale.astype(jnp.float32)
    return (y * s).astype(x.dtype)


def layer_norm(x, scale, bias, eps: float = 1e-5):
    x32 = x.astype(jnp.float32)
    mu = x32.mean(axis=-1, keepdims=True)
    var = ((x32 - mu) ** 2).mean(axis=-1, keepdims=True)
    y = (x32 - mu) * jax.lax.rsqrt(var + eps)
    return (y * scale.astype(jnp.float32)
            + bias.astype(jnp.float32)).astype(x.dtype)


def act_fn(name: str):
    return {"silu": jax.nn.silu, "gelu": lambda x: jax.nn.gelu(x,
            approximate=True), "relu": jax.nn.relu}[name]


def rope(x, positions, theta: float = 10000.0, rot_dim: int | None = None):
    """Rotary embedding. x (..., S, H, D) rotates the first ``rot_dim``
    dims (default: all). positions (..., S) or (S,)."""
    D = x.shape[-1]
    rd = rot_dim or D
    assert rd % 2 == 0
    x_rot, x_pass = x[..., :rd], x[..., rd:]
    freqs = theta ** (-jnp.arange(0, rd, 2, dtype=jnp.float32) / rd)
    ang = positions.astype(jnp.float32)[..., None] * freqs  # (..., S, rd/2)
    cos, sin = jnp.cos(ang)[..., None, :], jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x_rot.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos],
                          axis=-1)
    return jnp.concatenate([out.astype(x.dtype), x_pass], axis=-1)


def dense_init(key, shape, in_axis_size: int, dtype):
    """Truncated-normal fan-in init."""
    std = (1.0 / max(in_axis_size, 1)) ** 0.5
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32)
            * std).astype(dtype)


def softcap(x, cap: float | None):
    if cap is None:
        return x
    return cap * jnp.tanh(x / cap)
