"""End-to-end training driver: train a ~100M-param llama-family model for
a few hundred steps on CPU with the full production stack (data pipeline,
AdamW + cosine schedule, fault-tolerant loop, checkpointing).

    PYTHONPATH=src python examples/train_lm.py --steps 200

The model is the smollm-360m *family* scaled to ~100M params (fewer
layers/width, real vocab) — same code path the 256-chip config lowers.
"""
import argparse

import jax

from repro.checkpoint import Checkpointer
from repro.configs import get_config
from repro.data.pipeline import DataConfig, Pipeline
from repro.models import init_model
from repro.runtime import FaultTolerantLoop
from repro.train import adamw, cosine_schedule
from repro.train.train_step import init_train_state, make_train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt", default="/tmp/repro_train_lm")
    args = ap.parse_args()

    # ~100M params: smollm family at reduced depth/width, real vocab
    # (tied 49152x768 embedding ≈ 38M + 8 blocks ≈ 62M).
    cfg = get_config("smollm-360m").replace(
        name="smollm-100m", n_layers=8, d_model=768, d_ff=2304,
        n_heads=12, n_kv_heads=4, d_head=64, remat=False, attn_chunk=128)
    n = cfg.param_count()
    print(f"[example] {cfg.name}: ~{n/1e6:.0f}M params, "
          f"{args.steps} steps @ batch {args.batch} x seq {args.seq}")

    params = init_model(cfg, jax.random.PRNGKey(0))
    opt = adamw(lr=cosine_schedule(3e-3, 20, args.steps))
    state = init_train_state(params, opt)
    step = jax.jit(make_train_step(cfg, opt), donate_argnums=(0,))

    pipe = Pipeline(DataConfig(kind="lm", vocab_size=cfg.vocab_size,
                               seq_len=args.seq, global_batch=args.batch))
    losses = []

    def logged(st, batch):
        st, m = step(st, batch)
        losses.append(float(m["loss"]))
        if len(losses) % 20 == 0:
            import numpy as np
            print(f"  step {len(losses):4d}  loss "
                  f"{np.mean(losses[-20:]):.4f}")
        return st, m

    loop = FaultTolerantLoop(logged, pipe, Checkpointer(args.ckpt),
                             ckpt_every=100)
    state, report = loop.run(state, 0, args.steps)
    print(f"[example] loss {report.losses[0]:.3f} -> "
          f"{report.losses[-1]:.3f} over {report.steps_run} steps "
          f"({report.bad_steps} rejected)")
    assert report.losses[-1] < report.losses[0]


if __name__ == "__main__":
    main()
