"""RWKV-6 (Finch) block: data-dependent token-shift mixing, WKV recurrence
with per-channel data-dependent decay, and the squared-ReLU channel mix.

Decode state per block: last hidden token for the two token-shifts plus
the WKV state (B, H, K, K) — constant-size (attention-free long context).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ...kernels.rwkv6.chunked import wkv6_chunked, wkv6_decode_step
from ...sharding.logical import shard
from .common import dense_init

_MIX = ("r", "k", "v", "g", "w")


def init_rwkv6(key, cfg, dtype=jnp.float32):
    D = cfg.d_model
    F = cfg.d_ff
    r_lo = cfg.rwkv_decay_lora
    m_lo = cfg.rwkv_mix_lora
    ks = jax.random.split(key, 16)
    p = {
        # time mix
        "mu_base": jnp.full((len(_MIX), D), 0.5, dtype),
        "mix_A": dense_init(ks[0], (D, len(_MIX) * m_lo), D, dtype),
        "mix_B": dense_init(ks[1], (len(_MIX), m_lo, D), m_lo, dtype),
        "wr": dense_init(ks[2], (D, D), D, dtype),
        "wk": dense_init(ks[3], (D, D), D, dtype),
        "wv": dense_init(ks[4], (D, D), D, dtype),
        "wg": dense_init(ks[5], (D, D), D, dtype),
        "w0": jnp.full((D,), -4.0, dtype),       # base decay (w≈exp(-e^-4))
        "decay_A": dense_init(ks[6], (D, r_lo), D, dtype),
        "decay_B": dense_init(ks[7], (r_lo, D), r_lo, dtype),
        "u": dense_init(ks[8], (cfg.rwkv_heads, cfg.rwkv_head_dim),
                        cfg.rwkv_head_dim, dtype),
        "ln_x": jnp.ones((D,), dtype),
        "wo": dense_init(ks[9], (D, D), D, dtype),
        # channel mix
        "cmix_mu": jnp.full((2, D), 0.5, dtype),
        "ck": dense_init(ks[10], (D, F), D, dtype),
        "cv": dense_init(ks[11], (F, D), F, dtype),
        "cr": dense_init(ks[12], (D, D), D, dtype),
    }
    return p


def init_rwkv_state(cfg, batch: int, dtype):
    D = cfg.d_model
    H, K = cfg.rwkv_heads, cfg.rwkv_head_dim
    return {
        "shift_t": jnp.zeros((batch, D), dtype),   # time-mix shift
        "shift_c": jnp.zeros((batch, D), dtype),   # channel-mix shift
        "wkv": jnp.zeros((batch, H, K, K), jnp.float32),
    }


def _token_shift(x, last):
    """x (B,S,D) → previous token (B,S,D); ``last`` seeds position 0."""
    prev = jnp.concatenate([last[:, None, :].astype(x.dtype),
                            x[:, :-1]], axis=1)
    return prev


def rwkv6_time_mix(p, x, cfg, *, state=None, mode="train",
                   dtype=jnp.bfloat16):
    B, S, D = x.shape
    H, K = cfg.rwkv_heads, cfg.rwkv_head_dim
    x = x.astype(dtype)
    last = (jnp.zeros((B, D), dtype) if state is None
            else state["shift_t"])
    prev = _token_shift(x, last)
    dxp = prev - x
    # data-dependent mixing (LoRA over the 5 mixes)
    lora = jnp.tanh(jnp.einsum("bsd,dm->bsm", x + 0.5 * dxp,
                               p["mix_A"].astype(dtype)))
    lora = lora.reshape(B, S, len(_MIX), -1)
    mix = (p["mu_base"].astype(dtype)[None, None]
           + jnp.einsum("bsnm,nmd->bsnd", lora, p["mix_B"].astype(dtype)))
    xm = x[:, :, None, :] + dxp[:, :, None, :] * mix      # (B,S,5,D)
    xr, xk, xv, xg, xw = (xm[:, :, i] for i in range(len(_MIX)))

    r = jnp.einsum("bsd,de->bse", xr, p["wr"].astype(dtype))
    k = jnp.einsum("bsd,de->bse", xk, p["wk"].astype(dtype))
    v = jnp.einsum("bsd,de->bse", xv, p["wv"].astype(dtype))
    g = jnp.einsum("bsd,de->bse", xg, p["wg"].astype(dtype))
    ww = (p["w0"].astype(jnp.float32)
          + jnp.einsum("bsd,dr,re->bse", xw.astype(jnp.float32),
                       p["decay_A"].astype(jnp.float32),
                       p["decay_B"].astype(jnp.float32)))
    w = jnp.exp(-jnp.exp(ww))                              # (0,1)

    hsplit = lambda t: t.reshape(B, S, H, K)
    rh, kh, vh, wh = map(hsplit, (r, k, v, w.astype(dtype)))
    if mode == "decode":
        y, new_wkv = wkv6_decode_step(state["wkv"], rh[:, 0], kh[:, 0],
                                      vh[:, 0], wh[:, 0], p["u"])
        y = y[:, None]
    else:
        s0 = None if state is None else state["wkv"]
        y, new_wkv = wkv6_chunked(rh, kh, vh, wh, p["u"], s0=s0,
                                  chunk=cfg.ssm_chunk)
    y = y.reshape(B, S, D)
    # group norm over heads approximated by rms over D (standard in jax
    # ports), then output gate
    y32 = y.astype(jnp.float32)
    y = (y32 * jax.lax.rsqrt(jnp.mean(y32 * y32, -1, keepdims=True)
                             + cfg.norm_eps)
         * p["ln_x"].astype(jnp.float32)).astype(dtype)
    out = jnp.einsum("bsd,de->bse", y * jax.nn.silu(g),
                     p["wo"].astype(dtype))
    new_state = None
    if mode in ("prefill", "decode"):
        sdt = x.dtype if state is None else state["shift_t"].dtype
        new_state = {"shift_t": x[:, -1].astype(sdt), "wkv": new_wkv}
    return shard(out, "act_btd"), new_state


def rwkv6_channel_mix(p, x, cfg, *, state=None, mode="train",
                      dtype=jnp.bfloat16):
    B, S, D = x.shape
    x = x.astype(dtype)
    last = (jnp.zeros((B, D), dtype) if state is None
            else state["shift_c"])
    prev = _token_shift(x, last)
    dxp = prev - x
    mu = p["cmix_mu"].astype(dtype)
    xk = x + dxp * mu[0][None, None]
    xr = x + dxp * mu[1][None, None]
    k = jnp.einsum("bsd,df->bsf", xk, p["ck"].astype(dtype))
    k = jnp.square(jax.nn.relu(k))
    v = jnp.einsum("bsf,fd->bsd", shard(k, "act_btf"),
                   p["cv"].astype(dtype))
    r = jax.nn.sigmoid(jnp.einsum("bsd,de->bse", xr,
                                  p["cr"].astype(dtype)))
    new_state = None
    if mode in ("prefill", "decode"):
        sdt = x.dtype if state is None else state["shift_c"].dtype
        new_state = {"shift_c": x[:, -1].astype(sdt)}
    return shard(r * v, "act_btd"), new_state
