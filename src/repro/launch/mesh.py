"""Production meshes.

Single pod: (data=16, model=16) — a 16×16 TPU-v5e pod, 256 chips.
Multi-pod: (pod=2, data=16, model=16) — 512 chips; the "pod" axis is pure
data parallelism (DCN between pods carries only gradient reductions).

A FUNCTION, not a module constant — importing this module must never
touch jax device state (the dry-run sets XLA_FLAGS before first init).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_debug_mesh(n_devices: int | None = None, *, pod: bool = False):
    """Small mesh over however many (host) devices exist — used by tests."""
    n = n_devices or len(jax.devices())
    if pod and n >= 8:
        return jax.make_mesh((2, 2, n // 4), ("pod", "data", "model"))
    d = 2 if n % 2 == 0 and n >= 4 else 1
    return jax.make_mesh((d, n // d), ("data", "model"))
