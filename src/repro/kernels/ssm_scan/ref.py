"""Pure-jnp oracle for the Mamba-2 selective state-space scan (SSD).

Sequential (per-timestep) recurrence — the obviously-correct oracle:

    h_t = exp(dt_t · a_h) · h_{t-1} + dt_t · x_t ⊗ B_t
    y_t = C_t · h_t + D_h · x_t

Shapes follow Mamba-2: x (B,S,H,P), dt (B,S,H) [post-softplus], a (H,)
[negative], Bmat/Cmat (B,S,G,N) with G state groups broadcast over heads,
D (H,). Returns y (B,S,H,P) and the final state (B,H,P,N).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def ssm_scan_ref(x, dt, a, Bmat, Cmat, D, h0=None):
    Bsz, S, H, P = x.shape
    G, N = Bmat.shape[2], Bmat.shape[3]
    rep = H // G
    x, dt = x.astype(jnp.float32), dt.astype(jnp.float32)
    Bmat, Cmat = Bmat.astype(jnp.float32), Cmat.astype(jnp.float32)
    a = a.astype(jnp.float32)
    if h0 is None:
        h0 = jnp.zeros((Bsz, H, P, N), jnp.float32)

    def step(h, inp):
        xt, dtt, Bt, Ct = inp               # (B,H,P),(B,H),(B,G,N),(B,G,N)
        Bh = jnp.repeat(Bt, rep, axis=1)    # (B,H,N)
        Ch = jnp.repeat(Ct, rep, axis=1)
        decay = jnp.exp(dtt * a[None, :])   # (B,H)
        h = (h * decay[..., None, None]
             + (dtt[..., None] * xt)[..., None] * Bh[:, :, None, :])
        y = jnp.einsum("bhpn,bhn->bhp", h, Ch)
        return h, y

    xs = (x.swapaxes(0, 1), dt.swapaxes(0, 1),
          Bmat.swapaxes(0, 1), Cmat.swapaxes(0, 1))
    hT, ys = jax.lax.scan(step, h0, xs)
    y = ys.swapaxes(0, 1) + x * D[None, None, :, None]
    return y.astype(x.dtype), hT
