"""ViT-B/16 as a GEMM sequence.

Attention is a *grouped* GEMM over heads (the paper: "the existence of
attention heads makes the matrix multiplication a grouped GEMM operator,
resulting in more complex data mapping. Therefore, such models only
benefit from on-chip data redistribution in MLP layers") — so the
score/context ops break the redistribution chain (``chained=False``) and
carry ``n_groups=heads``; softmax adds a sync.
"""
from __future__ import annotations

from ..core.workload import GemmOp, Task


def vit_task(batch: int = 1, *, depth: int = 12, d: int = 768,
             heads: int = 12, mlp_ratio: int = 4, tokens: int = 197,
             patch_dim: int = 768) -> Task:
    m = tokens * batch
    ops = [GemmOp("patch_embed", M=m, K=patch_dim, N=d)]
    for b in range(depth):
        p = f"blk{b}."
        ops.append(GemmOp(p + "qkv", M=m, K=d, N=3 * d, chained=True,
                          sync=True))  # layernorm before, heads split after
        # scores: per-head (tokens x d_h) @ (d_h x tokens), heads stacked on
        # M (grouped GEMM flattened — total FLOPs preserved); the "weight"
        # operand is the K activation (one copy per head per sample), and
        # softmax adds a SIMD epilogue + sync.
        dh = d // heads
        ops.append(GemmOp(p + "scores", M=tokens * heads * batch, K=dh,
                          N=tokens, n_groups=heads, sync=True,
                          epilogue_flops_per_elem=5,
                          weight_bytes_scale=float(heads * batch)))
        ops.append(GemmOp(p + "ctx", M=tokens * heads * batch, K=tokens,
                          N=dh, n_groups=heads,
                          weight_bytes_scale=float(heads * batch)))
        ops.append(GemmOp(p + "proj", M=m, K=d, N=d))
        ops.append(GemmOp(p + "fc1", M=m, K=d, N=mlp_ratio * d,
                          chained=True, sync=True,
                          epilogue_flops_per_elem=4))   # GELU
        ops.append(GemmOp(p + "fc2", M=m, K=mlp_ratio * d, N=d,
                          chained=True))
    ops.append(GemmOp("head", M=batch, K=d, N=1000))
    return Task(f"vit_b16_b{batch}", ops)
