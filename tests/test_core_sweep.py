"""Sweep engine invariants (DESIGN.md §9): grid products, batched
evaluation parity with the direct evaluator, and result caching."""
import numpy as np
import pytest

from repro.core import (EvalOptions, Evaluator, GemmOp, Task, make_hw,
                        uniform_partition)
from repro.core import sweep
from repro.core.api import baseline_result


def toy_task(n=3, m=512):
    ops = [GemmOp("g0", M=m, K=256, N=512)]
    for i in range(1, n):
        ops.append(GemmOp(f"g{i}", M=m, K=ops[-1].N, N=512, chained=True))
    return Task(f"toy{n}_{m}", ops)


@pytest.fixture(autouse=True)
def _fresh_cache():
    sweep.clear_cache()
    yield
    sweep.clear_cache()


def test_grid_product_order():
    g = sweep.grid(a=[1, 2], b="xy")
    assert g == [{"a": 1, "b": "x"}, {"a": 1, "b": "y"},
                 {"a": 2, "b": "x"}, {"a": 2, "b": "y"}]
    assert sweep.grid() == [{}]


def test_run_grid_times_and_emits():
    seen = []
    out = sweep.run_grid(
        sweep.grid(x=[1, 2, 3]),
        lambda x: x * 10,
        emit=lambda pt, res, us: seen.append((pt["x"], res)),
    )
    assert [r for _, r, _ in out] == [10, 20, 30]
    assert all(us >= 0 for _, _, us in out)
    assert seen == [(1, 10), (2, 20), (3, 30)]


@pytest.mark.parametrize("backend", ["numpy", "jax"])
def test_eval_sweep_matches_direct_eval(backend):
    tasks = [toy_task(2), toy_task(4)]
    hws = [make_hw(t, 4, "hbm") for t in "AB"]
    points = [sweep.EvalPoint(task, hw) for task in tasks for hw in hws]
    recs = sweep.eval_sweep(points, backend=backend, cache=False)
    for pt, rec in zip(points, recs):
        r = Evaluator(pt.task, pt.hw, pt.options).evaluate(
            uniform_partition(pt.task, pt.hw.X, pt.hw.Y))
        assert rec["latency"] == pytest.approx(r.latency, rel=1e-9)
        assert rec["energy"] == pytest.approx(r.energy, rel=1e-9)
        assert rec["edp"] == pytest.approx(r.edp, rel=1e-9)
        np.testing.assert_allclose(rec["t_comp"], r.t_comp, rtol=1e-9)


def test_eval_sweep_batches_mixed_shapes():
    """Grid points of different shape signatures (different n_ops and
    entrance counts) must land in separate compiled groups yet return
    aligned records."""
    points = [
        sweep.EvalPoint(toy_task(2), make_hw("A", 4)),
        sweep.EvalPoint(toy_task(3), make_hw("A", 4)),
        sweep.EvalPoint(toy_task(2), make_hw("C", 4)),
        sweep.EvalPoint(toy_task(2), make_hw("A", 2)),
    ]
    recs = sweep.eval_sweep(points, backend="jax", cache=False)
    assert [r["task"] for r in recs] == [p.task.name for p in points]
    assert all(r["latency"] > 0 for r in recs)


def test_eval_sweep_options_and_partition():
    task = toy_task(3)
    hw = make_hw("A", 4)
    opts = EvalOptions(redistribution=True, async_exec=True)
    part = uniform_partition(task, 4, 4)
    part.collectors = np.array([1, 2, 3])
    rd = np.array([True, True, False])
    rec = sweep.eval_sweep(
        [sweep.EvalPoint(task, hw, opts, partition=part, redist_mask=rd)],
        backend="jax", cache=False)[0]
    ref = Evaluator(task, hw, opts).evaluate(part, rd)
    assert rec["latency"] == pytest.approx(ref.latency, rel=1e-9)
    assert rec["energy"] == pytest.approx(ref.energy, rel=1e-9)


def test_cache_hits_and_clear():
    points = [sweep.EvalPoint(toy_task(2), make_hw("A", 4))]
    sweep.eval_sweep(points)
    assert sweep.cache_stats() == {"hits": 0, "misses": 1}
    r1 = sweep.eval_sweep(points)
    assert sweep.cache_stats() == {"hits": 1, "misses": 1}
    # cache key includes options/partition content
    opts = EvalOptions(redistribution=True)
    sweep.eval_sweep([sweep.EvalPoint(toy_task(2), make_hw("A", 4), opts)])
    assert sweep.cache_stats()["misses"] == 2
    sweep.clear_cache()
    assert sweep.cache_stats() == {"hits": 0, "misses": 0}
    assert r1[0]["latency"] > 0


def test_cache_is_per_backend():
    """Backends agree only to rtol 1e-9 (not bitwise), so records are
    cached per backend — results never depend on evaluation order."""
    points = [sweep.EvalPoint(toy_task(2), make_hw("B", 4))]
    a = sweep.eval_sweep(points, backend="numpy")[0]
    b = sweep.eval_sweep(points, backend="jax")[0]  # separate key
    assert sweep.cache_stats() == {"hits": 0, "misses": 2}
    sweep.eval_sweep(points, backend="numpy")
    sweep.eval_sweep(points, backend="jax")
    assert sweep.cache_stats() == {"hits": 2, "misses": 2}
    assert a["latency"] == pytest.approx(b["latency"], rel=1e-9)


def test_baseline_result_uses_sweep_cache():
    task = toy_task(3)
    hw = make_hw("A", 4, "hbm", diagonal_links=True)
    r1 = baseline_result(task, hw)
    stats = sweep.cache_stats()
    r2 = baseline_result(task, hw)
    assert sweep.cache_stats()["hits"] == stats["hits"] + 1
    assert r1.latency == r2.latency
    # diagonal links are stripped for the LS baseline
    plain = Evaluator(task, hw.replace(diagonal_links=False),
                      EvalOptions()).evaluate(uniform_partition(task, 4, 4))
    assert r1.latency == pytest.approx(plain.latency, rel=1e-12)
