"""Fig. 3 reproduction: DRAM vs HBM congestion and memory placement on a
4×4 mesh (flow-level simulator standing in for ASTRA-sim).

Grid driving (benchmarks/README.md): the (memory × placement × NoP-BW)
grid is a generic ``sweep.grid`` product run through ``sweep.run_grid``
(the netsim is event-driven — no batched-eval path).
"""
from __future__ import annotations

from repro.core import sweep
from repro.core.netsim import fig3_case

from .common import emit, save_json

GB = 1e9


def main():
    results = {}

    def report(pt, out, us):
        key = f"{pt['memory']}_{pt['placement']}_nop{int(pt['bw_nop'] / GB)}"
        results[key] = out["latency"]
        emit(f"fig3/{key}", us, f"latency_ms={out['latency']*1e3:.2f}")

    sweep.run_grid(
        sweep.grid(memory=("dram", "hbm"),
                   placement=("peripheral", "central"),
                   bw_nop=(60 * GB, 120 * GB)),
        fig3_case, emit=report)

    # headline claims
    nop_scale = results["hbm_peripheral_nop60"] / \
        results["hbm_peripheral_nop120"]
    dram_scale = results["dram_peripheral_nop60"] / \
        results["dram_peripheral_nop120"]
    placement = results["hbm_peripheral_nop60"] / \
        results["hbm_central_nop60"]
    emit("fig3/hbm_nop_scaling", 0.0,
         f"{nop_scale:.2f}x (paper: linear, 2.00x)")
    emit("fig3/dram_nop_scaling", 0.0,
         f"{dram_scale:.2f}x (paper: none, 1.00x)")
    emit("fig3/central_vs_peripheral", 0.0,
         f"{placement:.2f}x (paper: 1.53x)")
    save_json("fig3", results)


if __name__ == "__main__":
    main()
