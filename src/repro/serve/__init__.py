from .cache_store import SCHEMA_VERSION, CacheStore  # noqa: F401
from .coalesce import BadRequest, OptRequest, group_requests  # noqa: F401
from .engine import ServeEngine  # noqa: F401
from .optserver import OptServer, ServerOverloaded  # noqa: F401
