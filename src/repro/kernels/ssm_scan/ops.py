"""Public SSD-scan op: Pallas on TPU, chunked-XLA elsewhere."""
from __future__ import annotations

import jax

from .chunked import ssm_scan_chunked
from .kernel import ssm_scan as ssm_scan_pallas
from .ref import ssm_scan_ref  # noqa: F401


def ssm_scan(x, dt, a, Bmat, Cmat, D, *, chunk: int = 128,
             use_pallas: bool | None = None, interpret: bool = False):
    if use_pallas is None:
        use_pallas = jax.default_backend() == "tpu"
    if use_pallas or interpret:
        return ssm_scan_pallas(
            x, dt, a, Bmat, Cmat, D, chunk=chunk,
            interpret=interpret or jax.default_backend() != "tpu")
    return ssm_scan_chunked(x, dt, a, Bmat, Cmat, D, chunk=chunk)[0]
