"""Batched design-space sweep engine (DESIGN.md §9).

Design-space exploration hammers the analytical evaluator across
(HWConfig × Task × EvalOptions) grids — Figs. 8–13 alone cover four
packaging types × four workloads × three solvers × multiple grid sizes.
This module turns those hand-rolled Python loops into:

  * :func:`grid` — generic named-axis cartesian product (any axes, not
    just eval triples; ``benchmarks/fig3_motivation.py`` builds its
    netsim grid with it too);
  * :func:`run_grid` — the timed per-point driver for work that stays
    per-point (the HiGHS ``engine="milp"`` path and other external
    solvers), with an optional per-point progress line;
  * :class:`PipelinePoint` / :func:`pipeline_sweep` — *batched* RCPSP
    pipelining (DESIGN.md §13): same-(n_ops, batch) points schedule
    through one compiled ``pipelining_jax.schedule_batch`` call, with
    method-tagged cached records (the ``engine="milp"`` refinement stays
    per-point);
  * :func:`netsim_sweep` — *batched* flow simulation (DESIGN.md §11):
    same-mesh-shape nets run through one compiled
    ``netsim_jax.simulate_pull_batch`` call, with cached records;
  * :class:`EvalPoint` / :func:`eval_sweep` — *batched* evaluation: all
    points whose shape signature (n_ops, X, Y, n_entrances) and static
    options match are stacked along a grid axis and evaluated by ONE
    ``jax.jit`` call (``evaluator_jax.grid_fn`` = jit(vmap(vmap))); the
    numpy backend loops per point and is the parity reference;
  * :func:`solve_grid` — *batched solver searches*: ``method="ga"``
    (DESIGN.md §10) evolves same-shape points as islands of one
    device-resident ``jit(vmap(scan))`` call (:mod:`repro.core.ga_jax`);
    ``method="miqp"`` (DESIGN.md §12) runs the lattice-enumeration MIQP
    engine (:mod:`repro.core.miqp_jax`) with same-shape points batched
    along the grid axis of its chunked scoring calls. The numpy backend
    runs the host engines per point and is the fallback/reference;
  * a process-wide result cache keyed by content fingerprints
    (backend + task ops + HWConfig + options + partition bytes for
    evaluation records; + objective and the full solver config —
    GAConfig or MIQPConfig, method-tagged — for solver records;
    segment-duration bytes + batch + the resolved PipelineConfig for
    pipelining records), so
    repeated baselines across figure scripts — e.g.
    ``run.py`` invoking fig8 then fig9 on the same workloads — are
    evaluated once per backend (backends agree only to rtol 1e-9, so
    records are not shared across them — results must not depend on
    evaluation order).

Two orthogonal execution knobs ride on every sweep (DESIGN.md §15):

  * ``devices`` — ``"single" | "sharded" | "auto"`` shards each batched
    group's grid axis across the local devices via ``shard_map``
    (:mod:`repro.core.sweep_shard`). Sharding is *result-neutral*: solo
    == batched == sharded bit-for-bit, so the knob is normalized out of
    every cache fingerprint (:func:`_strip_devices`) and records are
    device-count-independent — one cache serves all modes.
  * ``checkpoint`` — a store path (or :class:`SweepCheckpointer`) makes
    the sweep persist its new cache records every ``checkpoint_every``
    points through :class:`repro.serve.cache_store.CacheStore`. Kill the
    process anywhere and a rerun pointed at the same store resumes:
    completed points load back as cache hits, only the tail recomputes.

Typical use (LS baselines for one figure)::

    points = [EvalPoint(task, hw) for hw in hws for task in tasks]
    recs = eval_sweep(points)                  # one compiled call
    recs[0]["latency"], recs[0]["edp"]
"""
from __future__ import annotations

import dataclasses
import itertools
import sys
import time
from typing import Any, Callable, Iterable, Sequence

import numpy as np

from .evaluator import EvalOptions, Evaluator
from .hw import HWConfig
from .workload import Partition, Task, uniform_partition

__all__ = [
    "EvalPoint",
    "PipelinePoint",
    "cosearch_sweep",
    "eval_sweep",
    "grid",
    "run_grid",
    "solve_grid",
    "netsim_sweep",
    "pipeline_sweep",
    "clear_cache",
    "cache_stats",
    "export_cache",
    "import_cache",
    "SweepCheckpointer",
]


# --------------------------------------------------------------- generic grid
def grid(**axes: Iterable) -> list[dict[str, Any]]:
    """Named-axis cartesian product: ``grid(a=[1,2], b="xy")`` →
    ``[{"a":1,"b":"x"}, {"a":1,"b":"y"}, ...]``. Axis order follows the
    keyword order, last axis fastest (matches nested-loop reading)."""
    names = list(axes)
    values = [list(axes[n]) for n in names]
    return [dict(zip(names, combo)) for combo in itertools.product(*values)]


def run_grid(
    points: Sequence[dict[str, Any]],
    fn: Callable[..., Any],
    emit: Callable[[dict, Any, float], None] | None = None,
    progress: bool | str = False,
    checkpoint=None,
    checkpoint_every: int = 1,
) -> list[tuple[dict, Any, float]]:
    """Timed per-point driver for sweeps whose body stays per-point —
    external-solver work such as the HiGHS ``engine="milp"`` MIQP path
    or the pipelining MILP refinement (batched MIQP lattice solves go
    through :func:`solve_grid` with ``method="miqp"`` and pipelining
    grids through :func:`pipeline_sweep` instead, DESIGN.md §12/§13).
    Calls ``fn(**point)`` for every point, returning
    ``(point, result, microseconds)`` triples; ``emit`` (if given) is
    invoked per point for CSV-style reporting.

    ``progress`` writes a ``point i/N`` liveness line to **stderr**
    after each point — per-point solve time, aggregate points/sec, and
    an ETA for the remainder (pass a string to label the sweep) — so
    long solver grids show progress without a custom ``emit`` and
    without polluting piped-stdout CSV output.

    ``checkpoint`` (a store path or :class:`SweepCheckpointer`) flushes
    the process-wide result cache to disk every ``checkpoint_every``
    points: when ``fn`` runs cached sweeps internally (the usual case —
    per-point ``solve_grid``/``run_miqp`` wrappers), a killed grid
    resumes from the same store with completed points as cache hits."""
    label = progress if isinstance(progress, str) else "run_grid"
    ckpt = _resolve_checkpoint(checkpoint, checkpoint_every)
    out = []
    t_start = time.perf_counter()
    for i, pt in enumerate(points):
        t0 = time.perf_counter()
        res = fn(**pt)
        us = (time.perf_counter() - t0) * 1e6
        out.append((pt, res, us))
        if ckpt is not None and (i + 1) % ckpt.every == 0:
            ckpt.flush()
        if progress:
            done = i + 1
            elapsed = time.perf_counter() - t_start
            rate = done / elapsed if elapsed > 0 else float("inf")
            eta = (len(points) - done) / rate if rate > 0 else 0.0
            print(f"[sweep] {label} point {done}/{len(points)} "
                  f"{us:.0f}us ({rate:.1f} pts/s, eta {eta:.1f}s)",
                  file=sys.stderr)
        if emit is not None:
            emit(pt, res, us)
    if ckpt is not None:
        ckpt.flush()
    return out


# ----------------------------------------------------------- batched eval
@dataclasses.dataclass
class EvalPoint:
    """One grid point of the batched evaluator sweep.

    ``partition=None`` means the LS-uniform partition (the baseline of
    every figure); ``redist_mask=None`` follows ``Evaluator.evaluate``:
    redistribute on every chained pair iff ``options.redistribution``.
    """

    task: Task
    hw: HWConfig
    options: EvalOptions = EvalOptions()
    partition: Partition | None = None
    redist_mask: np.ndarray | None = None

    def resolved_partition(self) -> Partition:
        if self.partition is not None:
            return self.partition
        return uniform_partition(self.task, self.hw.X, self.hw.Y)


def _task_fingerprint(task: Task) -> tuple:
    return (task.name, tuple(task.ops))


def _strip_devices(obj):
    """Normalize the §15 ``devices`` execution knob out of a fingerprint
    component. Sharding is result-neutral — solo == batched == sharded,
    bit-for-bit — so records produced under any device mode (or device
    count) must share ONE cache entry; a fingerprint that embedded the
    knob would make a sharded run miss a single-device store."""
    if dataclasses.is_dataclass(obj) and hasattr(obj, "devices"):
        return dataclasses.replace(obj, devices="auto")
    return obj


def _point_fingerprint(pt: EvalPoint, backend: str) -> tuple:
    part = pt.resolved_partition()
    rd = (None if pt.redist_mask is None
          else np.asarray(pt.redist_mask, dtype=bool).tobytes())
    # backend is part of the key: the two engines agree only to rtol
    # 1e-9 (not bitwise), so sharing records across backends would make
    # results depend on which backend touched a fingerprint first.
    return (
        backend,
        _task_fingerprint(pt.task),
        pt.hw,
        _strip_devices(pt.options),
        part.Px.tobytes(), part.Py.tobytes(), part.collectors.tobytes(),
        rd,
    )


_CACHE: dict[tuple, dict[str, Any]] = {}
_STATS = {"hits": 0, "misses": 0}


def _copy_record(rec: dict[str, Any]) -> dict[str, Any]:
    """Records cross the cache boundary by value — callers mutating a
    returned record (or its arrays) must not poison the process cache."""
    return {k: (v.copy() if isinstance(v, np.ndarray) else v)
            for k, v in rec.items()}


def clear_cache() -> None:
    _CACHE.clear()
    _STATS["hits"] = _STATS["misses"] = 0


def cache_stats() -> dict[str, int]:
    return dict(_STATS)


def _copy_cache_value(value):
    """Copy-by-value for any record family the cache holds: evaluation /
    netsim records are plain dicts (numpy arrays copied), solver records
    are ``GAResult``/``MIQPResult``/``PipelineResult`` dataclasses."""
    if isinstance(value, dict):
        return _copy_record(value)
    return _copy_solver_record(value)


def export_cache() -> dict[tuple, Any]:
    """Snapshot the process-wide result cache as ``{fingerprint: record}``
    (records copied by value — mutating the snapshot cannot poison the
    cache). The fingerprints are the exact §9/§10/§12/§13 cache keys, so
    a snapshot can be persisted and re-imported in another process
    (:mod:`repro.serve.cache_store`) without weakening the
    solo==batched contract: a key either matches exactly or misses."""
    return {k: _copy_cache_value(v) for k, v in _CACHE.items()}


def import_cache(entries: dict, replace: bool = False) -> int:
    """Merge ``{fingerprint: record}`` entries (an :func:`export_cache`
    snapshot, possibly from another process via the on-disk store) into
    the process-wide cache; returns the number of entries inserted.
    Existing keys win unless ``replace=True`` — records are exact, so a
    collision is by construction the same result and keeping the
    resident copy is the cheaper choice."""
    n = 0
    for k, v in entries.items():
        if replace or k not in _CACHE:
            _CACHE[k] = _copy_cache_value(v)
            n += 1
    return n


# ------------------------------------------------- checkpointed resume
class SweepCheckpointer:
    """Periodic persistence of the §9 result cache to an on-disk
    :class:`repro.serve.cache_store.CacheStore` (DESIGN.md §15).

    Construction *loads* the store into the process cache — a sweep
    pointed at the store of a killed run resumes with every completed
    point a cache hit — and remembers which fingerprints the store
    already holds. :meth:`flush` appends only the delta (cache entries
    not yet persisted); the store's append path tears at most the tail
    record on a crash, and :meth:`~repro.serve.cache_store.CacheStore.
    load` drops a torn tail, so a kill at ANY instant costs at most one
    unflushed chunk of points.

    ``every`` is the flush cadence in points (sweep functions chunk the
    grid by it); ``resumed`` counts the records imported at construction.
    """

    def __init__(self, path, every: int = 8):
        from ..serve.cache_store import CacheStore

        self.store = path if isinstance(path, CacheStore) else \
            CacheStore(path)
        self.every = max(1, int(every))
        entries = self.store.load()
        self.resumed = import_cache(entries)
        self._persisted = set(entries)
        self.flushes = 0

    def pending(self) -> int:
        """Cache entries not yet persisted to the store."""
        return sum(1 for k in _CACHE if k not in self._persisted)

    def flush(self) -> int:
        """Append every unpersisted cache entry; returns the count."""
        new = {k: v for k, v in _CACHE.items()
               if k not in self._persisted}
        if new:
            self.store.append(new)
            self._persisted.update(new)
            self.flushes += 1
        return len(new)


def _resolve_checkpoint(checkpoint, every: int):
    if checkpoint is None or isinstance(checkpoint, SweepCheckpointer):
        return checkpoint
    return SweepCheckpointer(checkpoint, every=every)


def _checkpointed(points, ckpt: SweepCheckpointer, straggler, run_chunk):
    """Drive a batched sweep in checkpoint-sized chunks: each chunk's
    records land in the process cache (the sweep bodies insert them) and
    :meth:`SweepCheckpointer.flush` persists the delta, so a kill loses
    at most the in-flight chunk. ``straggler`` (a
    :class:`repro.runtime.fault_tolerance.StragglerMonitor`) observes
    per-chunk wall time and flags outlier chunks to stderr — the §15
    liveness signal for heterogeneous shards."""
    out = []
    for c, s in enumerate(range(0, len(points), ckpt.every)):
        chunk = points[s:s + ckpt.every]
        t0 = time.perf_counter()
        out.extend(run_chunk(chunk))
        dt = time.perf_counter() - t0
        ckpt.flush()
        if straggler is not None and straggler.observe(c, dt):
            print(f"[sweep] straggler: chunk {c} "
                  f"(points {s}:{s + len(chunk)}) took {dt:.3f}s",
                  file=sys.stderr)
    return out


def _record(point: EvalPoint, out: dict[str, np.ndarray], i: int | tuple
            ) -> dict[str, Any]:
    """Extract one point's scalars/arrays from a batched output dict."""
    def at(v):
        return v[i]

    rec = {
        "task": point.task.name,
        "hw": point.hw,
        "options": point.options,
        "latency": float(at(out["latency"])),
        "energy": float(at(out["energy"])),
        "edp": float(at(out["edp"])),
        "t_in": np.asarray(at(out["t_in"])),
        "t_comp": np.asarray(at(out["t_comp"])),
        "t_out": np.asarray(at(out["t_out"])),
    }
    for k in ("E_sram", "E_mac", "E_mem", "E_nop"):
        rec[k] = float(at(out[k]))
    return rec


def _genome(pt: EvalPoint, ev: Evaluator):
    part = pt.resolved_partition()
    if pt.redist_mask is None:
        rd = ev.chain_valid & pt.options.redistribution
    else:
        rd = np.asarray(pt.redist_mask, dtype=bool) & ev.chain_valid
        if not pt.options.redistribution:
            rd = np.zeros_like(rd)
    return (part.Px.astype(np.float64), part.Py.astype(np.float64),
            part.collectors.astype(np.float64), rd.astype(np.float64))


def eval_sweep(
    points: Sequence[EvalPoint],
    backend: str = "jax",
    cache: bool = True,
    devices: str | None = None,
    checkpoint=None,
    checkpoint_every: int = 8,
    straggler=None,
) -> list[dict[str, Any]]:
    """Evaluate every point; returns records aligned with ``points``.

    JAX backend: uncached points are grouped by shape signature + static
    options and each group is evaluated in one compiled call (consts and
    genomes stacked on a leading grid axis). Numpy backend: per-point
    reference loop — same records, used by the parity tests.

    ``devices`` (DESIGN.md §15) shards each group's grid axis across
    local devices — result-neutral, see the module docstring; ``None``
    defers to each point's ``options.devices``. ``checkpoint`` (a store
    path or :class:`SweepCheckpointer`) persists records every
    ``checkpoint_every`` points for kill/resume; requires ``cache=True``.
    """
    if backend not in ("numpy", "jax"):
        raise ValueError(f"unknown backend {backend!r}; "
                         f"one of ('numpy', 'jax')")
    ckpt = _resolve_checkpoint(checkpoint, checkpoint_every)
    if ckpt is not None:
        if not cache:
            raise ValueError("checkpointing requires cache=True — "
                             "records persist through the result cache")
        return _checkpointed(
            points, ckpt, straggler,
            lambda c: eval_sweep(c, backend=backend, cache=True,
                                 devices=devices))
    records: list[dict[str, Any] | None] = [None] * len(points)
    todo: list[int] = []
    fps: list[tuple | None] = [None] * len(points)
    for i, pt in enumerate(points):
        if cache:
            fp = _point_fingerprint(pt, backend)
            fps[i] = fp
            hit = _CACHE.get(fp)
            if hit is not None:
                _STATS["hits"] += 1
                records[i] = _copy_record(hit)
                continue
            _STATS["misses"] += 1
        todo.append(i)

    if todo and backend == "numpy":
        for i in todo:
            pt = points[i]
            ev = Evaluator(pt.task, pt.hw, pt.options, backend="numpy")
            Px, Py, co, rd = _genome(pt, ev)
            out = ev.evaluate_batch(Px[None], Py[None], co[None], rd[None])
            records[i] = _record(pt, out, 0)
    elif todo:
        from . import evaluator_jax

        # Group by (shape signature, static options): one compiled+batched
        # call per group.
        groups: dict[tuple, list[int]] = {}
        evs: dict[int, Evaluator] = {}
        for i in todo:
            pt = points[i]
            ev = Evaluator(pt.task, pt.hw, pt.options, backend="jax")
            evs[i] = ev
            sig = (len(pt.task), pt.hw.X, pt.hw.Y, ev.top.n_entrances,
                   pt.options.redistribution, pt.options.async_exec,
                   pt.options.energy_mode, pt.options.congestion)
            groups.setdefault(sig, []).append(i)

        for sig, idxs in groups.items():
            consts = [evs[i].consts() for i in idxs]
            stacked = {k: np.stack([c[k] for c in consts])
                       for k in consts[0]}
            genomes = [_genome(points[i], evs[i]) for i in idxs]
            Px = np.stack([g[0] for g in genomes])[:, None]   # [G,1,n,X]
            Py = np.stack([g[1] for g in genomes])[:, None]
            co = np.stack([g[2] for g in genomes])[:, None]
            rd = np.stack([g[3] for g in genomes])[:, None]
            out = evaluator_jax.grid_evaluate(
                stacked, points[idxs[0]].options, Px, Py, co, rd,
                devices=(points[idxs[0]].options.devices
                         if devices is None else devices))
            for g, i in enumerate(idxs):
                records[i] = _record(points[i], out, (g, 0))

    if cache:
        for i in todo:
            _CACHE[fps[i]] = _copy_record(records[i])
    return records  # type: ignore[return-value]


# ------------------------------------------------------ batched netsim
def _rate_fp(v):
    """Fingerprint component for a scalar-or-array rate: heterogeneous
    per-chiplet capacities key by content bytes, scalars stay plain
    floats (so every pre-hetero cache key is unchanged)."""
    a = np.asarray(v, dtype=np.float64)
    return float(a) if a.ndim == 0 else a.tobytes()


def _netsim_fingerprint(net, message_bytes: float, backend: str) -> tuple:
    ms = getattr(net, "mem_scale", None)
    return ("netsim", backend, net.X, net.Y, _rate_fp(net.bw_nop),
            float(net.bw_mem),
            None if ms is None else _rate_fp(ms),
            tuple(net.attach), float(message_bytes))


def netsim_sweep(
    nets: Sequence,
    message_bytes: float,
    backend: str = "jax",
    cache: bool = True,
    devices: str | None = None,
    checkpoint=None,
    checkpoint_every: int = 8,
    straggler=None,
) -> list[dict[str, Any]]:
    """Run the all-chiplets-pull flow simulation on every
    :class:`repro.core.netsim.MeshNet`; returns records aligned with
    ``nets`` (DESIGN.md §11).

    JAX backend: uncached nets are grouped by mesh shape (the
    :mod:`repro.core.topology` link space is a pure function of (X, Y) —
    capacities and attachment sets are data) and each group's whole
    (memory × placement × bandwidth) grid runs through ONE compiled
    ``lax.while_loop`` call (:func:`repro.core.netsim_jax.
    simulate_pull_batch`). Numpy backend: the per-net vectorized host
    engine — the parity reference. Records carry ``latency`` (seconds),
    per-flow ``done`` times and per-link ``link_bytes`` over the dense
    link space, and share the process-wide result cache (fingerprint:
    backend, mesh shape, bandwidths, attachment set, message size).

    ``devices`` / ``checkpoint`` / ``straggler`` follow the §15 contract
    (module docstring): sharding is result-neutral and checkpointing
    persists records for kill/resume."""
    from . import netsim

    if backend not in ("numpy", "jax"):
        raise ValueError(f"unknown backend {backend!r}; "
                         f"one of ('numpy', 'jax')")
    ckpt = _resolve_checkpoint(checkpoint, checkpoint_every)
    if ckpt is not None:
        if not cache:
            raise ValueError("checkpointing requires cache=True — "
                             "records persist through the result cache")
        return _checkpointed(
            nets, ckpt, straggler,
            lambda c: netsim_sweep(c, message_bytes, backend=backend,
                                   cache=True, devices=devices))
    records: list[dict[str, Any] | None] = [None] * len(nets)
    todo: list[int] = []
    fps: list[tuple | None] = [None] * len(nets)
    for i, net in enumerate(nets):
        if cache:
            fp = _netsim_fingerprint(net, message_bytes, backend)
            fps[i] = fp
            hit = _CACHE.get(fp)
            if hit is not None:
                _STATS["hits"] += 1
                records[i] = _copy_record(hit)
                continue
            _STATS["misses"] += 1
        todo.append(i)

    if todo and backend == "numpy":
        for i in todo:
            net = nets[i]
            out = netsim.simulate_flows(
                net.pull_incidence(), net.link_caps(),
                np.full(net.X * net.Y, float(message_bytes)))
            records[i] = {"latency": float(out["latency"]),
                          "done": out["done"], "link_bytes": out["link_bytes"]}
    elif todo:
        from . import netsim_jax

        groups: dict[tuple, list[int]] = {}
        for i in todo:
            groups.setdefault((nets[i].X, nets[i].Y), []).append(i)
        for (X, Y), idxs in groups.items():
            caps = np.stack([nets[i].link_caps() for i in idxs])
            incs = np.stack([nets[i].pull_incidence() for i in idxs])
            msgs = np.full((len(idxs), X * Y), float(message_bytes))
            out = netsim_jax.simulate_pull_batch(
                caps, incs, msgs,
                devices="auto" if devices is None else devices)
            for g, i in enumerate(idxs):
                records[i] = {"latency": float(out["latency"][g]),
                              "done": out["done"][g],
                              "link_bytes": out["link_bytes"][g]}

    if cache:
        for i in todo:
            _CACHE[fps[i]] = _copy_record(records[i])
    return records  # type: ignore[return-value]


# ----------------------------------------------------------- batched solves
def _solver_fingerprint(pt: EvalPoint, method: str, backend: str,
                        objective: str, cfg) -> tuple:
    """Cache key for a solver search. The method tag and the full
    (frozen, hashable) solver config — GAConfig or MIQPConfig — are part
    of the key, so GA and MIQP records on the same point never collide
    and any hyperparameter change is a different record; so is the
    backend: the GA engines draw from different RNGs and the lattice
    scorers agree only to rtol 1e-9 (arg-min ties could flip), so
    records must never be served across backends. The §15 ``devices``
    knob is normalized out of both the options and the config
    (:func:`_strip_devices`) — sharding never changes a result."""
    return (
        method, backend,
        _task_fingerprint(pt.task),
        pt.hw,
        _strip_devices(pt.options),
        objective,
        _strip_devices(cfg),
    )


def _copy_solver_record(rec):
    import dataclasses as _dc

    from .cosearch import CoSearchResult
    from .ga import GAResult
    from .miqp import MIQPResult
    from .multitenant import MultiTenantResult
    from .pipelining import PipelineResult

    if isinstance(rec, PipelineResult):
        return _dc.replace(rec)      # all fields immutable scalars
    if isinstance(rec, MultiTenantResult):
        return rec.copy()
    if isinstance(rec, CoSearchResult):
        return CoSearchResult(
            partition=rec.partition.copy(),
            redist_mask=rec.redist_mask.copy(),
            diagonal=rec.diagonal,
            seg_mask=rec.seg_mask.copy(),
            objective=rec.objective,
            edp=rec.edp,
            latency=rec.latency,
            energy=rec.energy,
            front={k: v.copy() for k, v in rec.front.items()},
            history=rec.history.copy(),
            evaluations=rec.evaluations,
        )
    if isinstance(rec, MIQPResult):
        return MIQPResult(
            partition=rec.partition.copy(),
            redist_mask=rec.redist_mask.copy(),
            objective=rec.objective,
            milp_status=rec.milp_status,
            milp_objective=rec.milp_objective,
            engine=rec.engine,
        )
    return GAResult(
        partition=rec.partition.copy(),
        redist_mask=rec.redist_mask.copy(),
        objective=rec.objective,
        history=rec.history.copy(),
        evaluations=rec.evaluations,
    )


def solve_grid(
    points: Sequence[EvalPoint],
    objective: str = "latency",
    cfg=None,
    backend: str = "jax",
    cache: bool = True,
    method: str = "ga",
    devices: str | None = None,
    checkpoint=None,
    checkpoint_every: int = 8,
    straggler=None,
) -> list:
    """Run one solver search per point; returns records aligned with
    ``points`` — ``GAResult`` for ``method="ga"`` (DESIGN.md §10),
    ``MIQPResult`` for ``method="miqp"`` (DESIGN.md §12).

    JAX backend: uncached points are grouped by shape signature — (n_ops,
    X, Y, n_entrances); the :class:`EvalOptions` statics live in the
    compiled function's cache key — and each group batches through ONE
    compiled program per call: GA searches evolve as *islands* of one
    ``jit(vmap(scan))`` call (:func:`repro.core.ga_jax.solve_islands`);
    MIQP lattice searches share the grid axis of the chunked scoring
    calls (:func:`repro.core.miqp_jax.solve_lattice_batch`). Numpy
    backend: per-point host engines — the fallback used by ``run.py
    --backend numpy``. A point's result (and its cache record) is
    identical whether it is solved alone or batched with others: GA
    island RNG depends only on ``cfg.seed``, and the lattice budgets are
    deterministic candidate counts.

    ``pt.partition`` / ``pt.redist_mask`` are ignored — a solve searches
    the genome space, it does not score a fixed schedule.
    ``backend="auto"`` resolves before fingerprinting (by
    ``cfg.population`` for GA, ``cfg.score_chunk`` for MIQP — the
    DESIGN.md §8 threshold), so auto-resolved records share the cache
    with their concrete-backend equivalents; likewise
    ``MIQPConfig(engine="auto")`` resolves first. ``method="miqp"`` with
    ``engine="milp"`` cannot batch — those points run serially through
    :func:`repro.core.miqp.run_miqp` (still cached).

    ``devices`` (DESIGN.md §15) shards each group's island/grid axis
    across local devices — result-neutral and fingerprint-invisible;
    ``None`` defers to ``cfg.devices``. ``checkpoint`` (a store path or
    :class:`SweepCheckpointer`) persists solver records every
    ``checkpoint_every`` points for kill/resume (``cache=True`` only);
    ``straggler`` flags outlier chunk wall-times to stderr."""
    ckpt = _resolve_checkpoint(checkpoint, checkpoint_every)
    if ckpt is not None:
        if not cache:
            raise ValueError("checkpointing requires cache=True — "
                             "records persist through the result cache")
        return _checkpointed(
            points, ckpt, straggler,
            lambda c: solve_grid(c, objective, cfg, backend=backend,
                                 cache=True, method=method,
                                 devices=devices))
    if method == "miqp":
        return _solve_grid_miqp(points, objective, cfg, backend, cache,
                                devices)
    if method == "cosearch":
        return cosearch_sweep(points, objective=objective, cfg=cfg,
                              backend=backend, cache=cache,
                              devices=devices)
    if method == "multitenant":
        return multitenant_sweep(points, objective=objective, cfg=cfg,
                                 backend=backend, cache=cache,
                                 devices=devices)
    if method != "ga":
        raise ValueError(f"unknown method {method!r}; "
                         f"one of ('ga', 'miqp', 'cosearch', "
                         f"'multitenant')")
    from .evaluator import resolve_auto_backend
    from .ga import GAConfig, run_ga

    if cfg is None:
        cfg = GAConfig()
    backend = resolve_auto_backend(backend, cfg.population)
    if backend not in ("numpy", "jax"):
        raise ValueError(f"unknown backend {backend!r}; "
                         f"one of ('numpy', 'jax', 'auto')")
    records: list = [None] * len(points)
    todo: list[int] = []
    fps: list[tuple | None] = [None] * len(points)
    for i, pt in enumerate(points):
        if cache:
            fp = _solver_fingerprint(pt, "ga", backend, objective, cfg)
            fps[i] = fp
            hit = _CACHE.get(fp)
            if hit is not None:
                _STATS["hits"] += 1
                records[i] = _copy_solver_record(hit)
                continue
            _STATS["misses"] += 1
        todo.append(i)

    if todo and backend == "numpy":
        for i in todo:
            pt = points[i]
            records[i] = run_ga(pt.task, pt.hw, objective, pt.options,
                                cfg, backend="numpy", engine="vectorized")
    elif todo:
        from . import ga_jax

        groups: dict[tuple, list[int]] = {}
        for i in todo:
            pt = points[i]
            sig = (len(pt.task), pt.hw.X, pt.hw.Y,
                   pt.hw.topology.n_entrances, _strip_devices(pt.options))
            groups.setdefault(sig, []).append(i)
        for sig, idxs in groups.items():
            outs = ga_jax.solve_islands(
                [points[i].task for i in idxs],
                [points[i].hw for i in idxs],
                points[idxs[0]].options, objective, cfg,
                devices=devices)
            for i, out in zip(idxs, outs):
                records[i] = out

    if cache:
        for i in todo:
            _CACHE[fps[i]] = _copy_solver_record(records[i])
    return records


# ------------------------------------------------- batched co-search
def cosearch_sweep(
    points: Sequence[EvalPoint],
    objective: str = "edp",
    cfg=None,
    backend: str = "jax",
    cache: bool = True,
    devices: str | None = None,
    checkpoint=None,
    checkpoint_every: int = 8,
    straggler=None,
) -> list:
    """Run one fused joint search (partition × diagonal links × pipeline
    segmentation, DESIGN.md §16) per point; returns
    :class:`repro.core.cosearch.CoSearchResult` records aligned with
    ``points`` — also reachable as ``solve_grid(method="cosearch")``.

    Uncached points are grouped by shape signature — (n_ops, X, Y,
    n_entrances); the :class:`EvalOptions` statics live in the compiled
    function's cache key — and each group evolves as islands of ONE
    ``jit(vmap(scan))`` call
    (:func:`repro.core.cosearch.cosearch_islands`). A point's record is
    identical solo or batched (island RNG depends only on ``cfg.seed``,
    budgets are deterministic counts), so the §9 cache contract holds:
    records are method-tagged ``"cosearch"`` and keyed by the full
    frozen :class:`CoSearchConfig`.

    The diag gene *searches* the link axis, so ``pt.hw.diagonal_links``
    is normalized to ``False`` before fingerprinting and solving — plain
    and diagonal variants of the same mesh share one record.
    ``pt.partition`` / ``pt.redist_mask`` are ignored, like
    :func:`solve_grid`. Only the JAX backend exists (the fitness chains
    traced engines end-to-end); ``backend="auto"`` resolves to it.

    ``devices`` (DESIGN.md §15) shards each group's island axis —
    result-neutral and fingerprint-invisible; ``None`` defers to
    ``cfg.devices``. ``checkpoint`` / ``checkpoint_every`` /
    ``straggler`` behave exactly like :func:`solve_grid`."""
    from .cosearch import CoSearchConfig, cosearch_islands

    if cfg is None:
        cfg = CoSearchConfig()
    if not isinstance(cfg, CoSearchConfig):
        raise TypeError(f"cosearch_sweep needs a CoSearchConfig, "
                        f"got {type(cfg).__name__}")
    if backend == "auto":
        backend = "jax"
    if backend != "jax":
        raise ValueError(f"unknown backend {backend!r} for cosearch; "
                         f"the fused fitness only exists on 'jax' "
                         f"('auto' resolves to it)")
    ckpt = _resolve_checkpoint(checkpoint, checkpoint_every)
    if ckpt is not None:
        if not cache:
            raise ValueError("checkpointing requires cache=True — "
                             "records persist through the result cache")
        return _checkpointed(
            points, ckpt, straggler,
            lambda c: cosearch_sweep(c, objective, cfg, backend=backend,
                                     cache=True, devices=devices))

    norm_hws = [dataclasses.replace(pt.hw, diagonal_links=False)
                for pt in points]
    records: list = [None] * len(points)
    todo: list[int] = []
    fps: list[tuple | None] = [None] * len(points)
    for i, pt in enumerate(points):
        if cache:
            fp = _solver_fingerprint(
                dataclasses.replace(pt, hw=norm_hws[i]),
                "cosearch", "jax", objective, cfg)
            fps[i] = fp
            hit = _CACHE.get(fp)
            if hit is not None:
                _STATS["hits"] += 1
                records[i] = _copy_solver_record(hit)
                continue
            _STATS["misses"] += 1
        todo.append(i)

    if todo:
        groups: dict[tuple, list[int]] = {}
        for i in todo:
            pt = points[i]
            sig = (len(pt.task), pt.hw.X, pt.hw.Y,
                   pt.hw.topology.n_entrances, _strip_devices(pt.options))
            groups.setdefault(sig, []).append(i)
        for sig, idxs in groups.items():
            outs = cosearch_islands(
                [points[i].task for i in idxs],
                [norm_hws[i] for i in idxs],
                points[idxs[0]].options, objective, cfg,
                devices=devices)
            for i, out in zip(idxs, outs):
                records[i] = out

    if cache:
        for i in todo:
            _CACHE[fps[i]] = _copy_solver_record(records[i])
    return records


# ---------------------------------------------- multi-tenant placement
@dataclasses.dataclass
class MultiTenantPoint:
    """One grid point of the multi-tenant placement sweep (DESIGN.md
    §18): several co-resident tasks on ONE (possibly heterogeneous)
    package, searched by ``solve_grid(method="multitenant")``."""

    tasks: tuple
    hw: HWConfig
    options: EvalOptions = EvalOptions()


def _multitenant_fingerprint(pt: MultiTenantPoint, backend: str,
                             objective: str, cfg) -> tuple:
    """Cache key for a multi-tenant search: tenant task tuple (order
    matters — bands are assigned in tenant order), the full hetero
    HWConfig (chiplet classes/assignment are hashable fields), and the
    frozen config with the §15 devices knob stripped at both levels
    (the outer config and the nested inner-solver config)."""
    inner = _strip_devices(cfg.cfg)
    return (
        "multitenant", backend,
        tuple(_task_fingerprint(t) for t in pt.tasks),
        pt.hw,
        _strip_devices(pt.options),
        objective,
        _strip_devices(dataclasses.replace(cfg, cfg=inner)),
    )


def multitenant_sweep(
    points: Sequence[MultiTenantPoint],
    objective: str = "edp",
    cfg=None,
    backend: str = "jax",
    cache: bool = True,
    devices: str | None = None,
    checkpoint=None,
    checkpoint_every: int = 8,
    straggler=None,
) -> list:
    """Run one multi-tenant placement search per point; returns
    :class:`repro.core.multitenant.MultiTenantResult` records aligned
    with ``points`` — also reachable as
    ``solve_grid(method="multitenant")`` (DESIGN.md §18).

    The outer assignment loop is a host loop (band compositions are
    few); the inner per-tenant solves and exact re-scores go through
    :func:`solve_grid` / :func:`eval_sweep`, so they batch per region
    shape and share the process cache — identical region solves across
    assignments (and across points) dedupe to one engine call. All
    budgets are deterministic counts, so records obey the §9 solo ==
    batched == served contract.

    ``checkpoint`` / ``straggler`` follow the §15 contract; ``devices``
    threads through to the inner engines and is fingerprint-invisible."""
    from .multitenant import MultiTenantConfig, solve_multitenant

    if cfg is None:
        cfg = MultiTenantConfig()
    if not isinstance(cfg, MultiTenantConfig):
        raise TypeError(f"multitenant_sweep needs a MultiTenantConfig, "
                        f"got {type(cfg).__name__}")
    if backend == "auto":
        backend = "jax"
    if backend not in ("numpy", "jax"):
        raise ValueError(f"unknown backend {backend!r}; "
                         f"one of ('numpy', 'jax', 'auto')")
    ckpt = _resolve_checkpoint(checkpoint, checkpoint_every)
    if ckpt is not None:
        if not cache:
            raise ValueError("checkpointing requires cache=True — "
                             "records persist through the result cache")
        return _checkpointed(
            points, ckpt, straggler,
            lambda c: multitenant_sweep(c, objective, cfg,
                                        backend=backend, cache=True,
                                        devices=devices))
    records: list = [None] * len(points)
    todo: list[int] = []
    fps: list[tuple | None] = [None] * len(points)
    for i, pt in enumerate(points):
        if cache:
            fp = _multitenant_fingerprint(pt, backend, objective, cfg)
            fps[i] = fp
            hit = _CACHE.get(fp)
            if hit is not None:
                _STATS["hits"] += 1
                records[i] = _copy_solver_record(hit)
                continue
            _STATS["misses"] += 1
        todo.append(i)

    for i in todo:
        pt = points[i]
        records[i] = solve_multitenant(
            pt.tasks, pt.hw, objective, pt.options, cfg,
            backend=backend, cache=cache, devices=devices)

    if cache:
        for i in todo:
            _CACHE[fps[i]] = _copy_solver_record(records[i])
    return records


# ------------------------------------------------- batched pipelining
@dataclasses.dataclass
class PipelinePoint:
    """One grid point of the batched RCPSP pipelining sweep
    (DESIGN.md §13): per-op ``(name, t_in, t_comp, t_out)`` segment
    durations for ONE sample (``EvalResult.segments()`` /
    ``ScheduleResult.segments()``) plus the batch size to pipeline."""

    segments: Sequence[tuple[str, float, float, float]]
    batch: int

    def durations(self) -> np.ndarray:
        """``[n_ops, 3]`` float64 durations, clamped like ``build_jobs``
        (one conversion shared with the engines, so the clamping
        contract — and the cache fingerprint built on it — cannot
        drift)."""
        from .pipelining import _segment_durations

        return _segment_durations(self.segments).reshape(-1, 3)


def _pipeline_fingerprint(pt: PipelinePoint, cfg) -> tuple:
    """Cache key for a pipelining record: method tag, the resolved
    (frozen) :class:`~repro.core.pipelining.PipelineConfig` — engine and
    backend included — segment-duration bytes and batch. The engines are
    bit-identical (DESIGN.md §13), but the backend stays in the key for
    consistency with every other record family."""
    return ("pipeline", _strip_devices(cfg), pt.durations().tobytes(),
            int(pt.batch))


def pipeline_sweep(
    points: Sequence[PipelinePoint],
    cfg=None,
    backend: str = "jax",
    cache: bool = True,
    devices: str | None = None,
    checkpoint=None,
    checkpoint_every: int = 8,
    straggler=None,
) -> list:
    """Schedule every pipelining point; returns
    :class:`~repro.core.pipelining.PipelineResult` records aligned with
    ``points`` (DESIGN.md §13).

    JAX backend: uncached points are grouped by (n_ops, batch) — the
    chain structure is a pure function of that pair; durations are data —
    and each group schedules through ONE compiled
    ``pipelining_jax.schedule_batch`` call. A point's record is identical
    whether it is scheduled alone or batched (bit-identical, the §9 cache
    invariant). ``backend="numpy"`` runs the host frontier loop per
    point (the parity reference); ``engine="python"``/``"milp"`` configs
    run the serial engines per point — milp cannot batch — with records
    still cached. A non-``"auto"`` ``cfg.backend`` wins over the
    sweep-level ``backend`` argument (the :class:`PipelineConfig`
    contract); ``"auto"`` resolves to jax — grid batching always wins
    here, and the engines agree bit-for-bit, so the resolution is purely
    a performance choice.

    ``devices`` / ``checkpoint`` / ``straggler`` follow the §15 contract
    (module docstring); ``devices=None`` defers to ``cfg.devices``."""
    from .pipelining import (PipelineConfig, PipelineResult,
                             pipeline_batch, resolve_auto_pipeline_engine,
                             sequential_makespan)

    if cfg is None:
        cfg = PipelineConfig()
    ckpt = _resolve_checkpoint(checkpoint, checkpoint_every)
    if ckpt is not None:
        if not cache:
            raise ValueError("checkpointing requires cache=True — "
                             "records persist through the result cache")
        return _checkpointed(
            points, ckpt, straggler,
            lambda c: pipeline_sweep(c, cfg, backend=backend, cache=True,
                                     devices=devices))
    if devices is not None:
        cfg = dataclasses.replace(cfg, devices=devices)
    engine = resolve_auto_pipeline_engine(cfg.engine)
    # An explicit cfg.backend wins over the sweep-level default (the
    # PipelineConfig contract); "auto" resolves to jax here — grid
    # batching always wins, and the engines agree bit-for-bit, so the
    # resolution is purely a performance choice.
    backend = cfg.backend if cfg.backend != "auto" else backend
    backend = "jax" if backend == "auto" else backend
    if backend not in ("numpy", "jax"):
        raise ValueError(f"unknown backend {backend!r}; "
                         f"one of ('numpy', 'jax', 'auto')")
    if engine != "vectorized":
        backend = "numpy"        # serial engines run on host
    # Fingerprint the *resolved* config so auto-selected records share
    # the cache with their concrete equivalents (the §12 rule).
    cfg = dataclasses.replace(cfg, engine=engine, backend=backend)
    records: list = [None] * len(points)
    todo: list[int] = []
    fps: list[tuple | None] = [None] * len(points)
    for i, pt in enumerate(points):
        if cache:
            fp = _pipeline_fingerprint(pt, cfg)
            fps[i] = fp
            hit = _CACHE.get(fp)
            if hit is not None:
                _STATS["hits"] += 1
                records[i] = _copy_solver_record(hit)
                continue
            _STATS["misses"] += 1
        todo.append(i)

    if todo and (engine != "vectorized" or backend == "numpy"):
        for i in todo:
            pt = points[i]
            records[i] = pipeline_batch(pt.segments, pt.batch, config=cfg)
    elif todo:
        from . import pipelining_jax

        groups: dict[tuple, list[int]] = {}
        for i in todo:
            pt = points[i]
            groups.setdefault((len(pt.segments), int(pt.batch)),
                              []).append(i)
        for (n, B), idxs in groups.items():
            durs = np.stack([points[i].durations() for i in idxs])
            out = pipelining_jax.schedule_batch(durs, B,
                                                devices=cfg.devices)
            for g, i in enumerate(idxs):
                records[i] = PipelineResult(
                    B, sequential_makespan(points[i].segments, B),
                    float(out["makespan"][g]), engine="vectorized")

    if cache:
        for i in todo:
            _CACHE[fps[i]] = _copy_solver_record(records[i])
    return records


def _solve_grid_miqp(points, objective, cfg, backend, cache,
                     devices=None) -> list:
    """``solve_grid`` body for ``method="miqp"`` (DESIGN.md §12)."""
    import dataclasses as _dc

    from .evaluator import resolve_auto_backend
    from .miqp import MIQPConfig, resolve_auto_engine, run_miqp

    if cfg is None:
        cfg = MIQPConfig()
    if devices is not None:
        cfg = _dc.replace(cfg, devices=devices)
    engine = resolve_auto_engine(cfg.engine)
    backend = (resolve_auto_backend(backend, cfg.score_chunk)
               if engine == "lattice" else "numpy")
    if backend not in ("numpy", "jax"):
        raise ValueError(f"unknown backend {backend!r}; "
                         f"one of ('numpy', 'jax', 'auto')")
    # Fingerprint the *resolved* config so auto-selected records share
    # the cache with their concrete equivalents.
    cfg = _dc.replace(cfg, engine=engine, backend=backend)
    records: list = [None] * len(points)
    todo: list[int] = []
    fps: list[tuple | None] = [None] * len(points)
    for i, pt in enumerate(points):
        if cache:
            fp = _solver_fingerprint(pt, "miqp", backend, objective, cfg)
            fps[i] = fp
            hit = _CACHE.get(fp)
            if hit is not None:
                _STATS["hits"] += 1
                records[i] = _copy_solver_record(hit)
                continue
            _STATS["misses"] += 1
        todo.append(i)

    if todo and (engine == "milp" or backend == "numpy"):
        # milp cannot batch; the numpy lattice is the per-point reference.
        for i in todo:
            pt = points[i]
            records[i] = run_miqp(pt.task, pt.hw, objective, pt.options,
                                  cfg, engine=engine)
    elif todo:
        from . import miqp_jax

        groups: dict[tuple, list[int]] = {}
        for i in todo:
            pt = points[i]
            sig = (len(pt.task), pt.hw.X, pt.hw.Y,
                   pt.hw.topology.n_entrances, _strip_devices(pt.options))
            groups.setdefault(sig, []).append(i)
        for sig, idxs in groups.items():
            outs = miqp_jax.solve_lattice_batch(
                [points[i].task for i in idxs],
                [points[i].hw for i in idxs],
                points[idxs[0]].options, objective, cfg)
            for i, out in zip(idxs, outs):
                records[i] = out

    if cache:
        for i in todo:
            _CACHE[fps[i]] = _copy_solver_record(records[i])
    return records
