"""Fused cross-layer co-search invariants (DESIGN.md §16).

Pins the §9-contract extension for ``method="cosearch"`` (solo ==
batched bitwise, diag-normalized cache identity, record isolation),
the fused-genome semantics (link config and segmentation are genes;
``hw.diagonal_links`` never changes the record), gradient seeding
(deterministic generation-count budgets — never wall-clock), and the
seeding hooks grown into the GA and MIQP engines (``seeds=`` /
``anchors=``: disabled must be bit-for-bit the pre-hook behavior).

All searches share one tiny (n=4, 2×2 mesh) shape so the compiled
executables are traced once per module run.
"""
import dataclasses

import numpy as np
import pytest

from repro.core import (CoSearchConfig, EvalOptions, Task, api, make_hw,
                        run_cosearch, sweep)
from repro.core import cosearch as cs
from repro.core import ga_jax, miqp_jax
from repro.core.ga import GAConfig
from repro.core.miqp import MIQPConfig
from repro.graphs import WORKLOADS

HW = make_hw("A", 2, "hbm")
HW_DIAG = make_hw("A", 2, "hbm", diagonal_links=True)
OPTS = EvalOptions(redistribution=True, async_exec=True)
CFG = CoSearchConfig(population=16, generations=10, patience=10,
                     batch=3, seed=0, seed_steps=4, seed_starts=2,
                     archive_size=8)


def _task(name="alex4", lo=0, hi=4):
    full = WORKLOADS["alexnet"](batch=1)
    ops = list(full.ops[lo:hi])
    ops[0] = dataclasses.replace(ops[0], chained=False)
    return Task(name, ops)


@pytest.fixture(autouse=True)
def _fresh_cache():
    sweep.clear_cache()
    yield
    sweep.clear_cache()


def _same_result(a, b):
    assert a.objective == b.objective
    assert a.edp == b.edp and a.latency == b.latency
    assert a.energy == b.energy
    assert a.diagonal == b.diagonal
    np.testing.assert_array_equal(a.partition.Px, b.partition.Px)
    np.testing.assert_array_equal(a.partition.Py, b.partition.Py)
    np.testing.assert_array_equal(a.redist_mask, b.redist_mask)
    np.testing.assert_array_equal(a.seg_mask, b.seg_mask)
    assert set(a.front) == set(b.front)
    for k in a.front:
        np.testing.assert_array_equal(a.front[k], b.front[k])
    np.testing.assert_array_equal(a.history, b.history)


# ------------------------------------------------------- result shape
def test_result_contract():
    t = _task()
    r = run_cosearch(t, HW, "edp", OPTS, CFG)
    n = len(t)
    assert r.partition.Px.shape == (n, HW.X)
    assert np.all(r.partition.Px.sum(axis=1) ==
                  [op.M for op in t.ops])
    assert np.all(r.partition.Py.sum(axis=1) ==
                  [op.N for op in t.ops])
    assert r.seg_mask.shape == (n,)
    assert not r.seg_mask[-1]            # last op never a boundary
    assert r.objective == r.edp          # edp-guided search
    assert r.edp == pytest.approx(r.energy * r.latency)
    # front rows are mutually non-dominated and include the best genome
    pts = np.stack([r.front["edp"], r.front["latency"],
                    r.front["energy"]], axis=1)
    assert cs.pareto_mask(pts).all()
    assert r.front["edp"].min() == r.edp
    assert len(r.history) == r.evaluations // CFG.population
    assert np.all(np.diff(r.history) <= 0)   # best-so-far is monotone


def test_objective_validation():
    with pytest.raises(ValueError):
        run_cosearch(_task(), HW, "throughput", OPTS, CFG)


# ---------------------------------------------- §9: solo == batched
def test_solo_equals_batched_bitwise():
    ta, tb = _task("alex4a", 0, 4), _task("alex4b", 1, 5)
    solo = [run_cosearch(ta, HW, "edp", OPTS, CFG),
            run_cosearch(tb, HW, "edp", OPTS, CFG)]
    batched = cs.cosearch_islands([ta, tb], [HW, HW], OPTS, "edp", CFG)
    for s, b in zip(solo, batched):
        _same_result(s, b)


def test_sweep_diag_normalized_cache_identity():
    """hw.diagonal_links is genome territory: plain-mesh and diag-mesh
    points are ONE §9 cache record, and their results are bitwise
    equal."""
    t = _task()
    r_plain = sweep.cosearch_sweep(
        [sweep.EvalPoint(t, HW, OPTS)], "edp", CFG)[0]
    stats0 = dict(sweep.cache_stats())
    r_diag = sweep.cosearch_sweep(
        [sweep.EvalPoint(t, HW_DIAG, OPTS)], "edp", CFG)[0]
    stats1 = dict(sweep.cache_stats())
    assert stats1["hits"] == stats0["hits"] + 1
    _same_result(r_plain, r_diag)


def test_solve_grid_dispatch_and_api_front_door():
    t = _task()
    r = sweep.cosearch_sweep([sweep.EvalPoint(t, HW, OPTS)], "edp",
                             CFG)[0]
    via_grid = sweep.solve_grid([sweep.EvalPoint(t, HW, OPTS)], "edp",
                                CFG, method="cosearch")[0]
    via_api = api.cosearch(t, HW, "edp", OPTS, CFG)
    _same_result(r, via_grid)
    _same_result(r, via_api)


def test_record_mutation_isolation():
    t = _task()
    pt = sweep.EvalPoint(t, HW, OPTS)
    r1 = sweep.cosearch_sweep([pt], "edp", CFG)[0]
    r1.front["edp"][:] = -1.0
    r1.partition.Px[:] = 0
    r2 = sweep.cosearch_sweep([pt], "edp", CFG)[0]
    assert np.all(r2.front["edp"] > 0)
    assert np.all(r2.partition.Px.sum(axis=1) ==
                  [op.M for op in t.ops])


def test_cfg_and_backend_validation():
    pt = sweep.EvalPoint(_task(), HW, OPTS)
    with pytest.raises(TypeError):
        sweep.cosearch_sweep([pt], "edp", GAConfig())
    with pytest.raises(ValueError):
        sweep.cosearch_sweep([pt], "edp", CFG, backend="numpy")


def test_flow_congestion_mode():
    opts = dataclasses.replace(OPTS, congestion="flow")
    r = run_cosearch(_task(), HW, "edp", opts, CFG)
    assert np.isfinite(r.edp) and r.edp > 0


# ------------------------------------------------- gradient seeding
def test_gradient_seeds_deterministic_and_valid():
    t = _task()
    s1 = cs.gradient_seeds(t, HW, OPTS, "edp", CFG)
    s2 = cs.gradient_seeds(t, HW, OPTS, "edp", CFG)
    assert len(s1) == len(s2) >= 1
    for (p1, d1), (p2, d2) in zip(s1, s2):
        np.testing.assert_array_equal(p1.Px, p2.Px)
        np.testing.assert_array_equal(p1.Py, p2.Py)
        assert d1 == d2
    for p, _ in s1:
        assert np.all(p.Px.sum(axis=1) == [op.M for op in t.ops])
        assert np.all(p.Py.sum(axis=1) == [op.N for op in t.ops])


def test_seeding_generation_budget():
    """Seeding must help under a deterministic count budget: the seeded
    search attains the cold run's final best at least as early (and
    never ends worse). Wall-clock is not measured anywhere."""
    t = _task()
    cold = cs.cosearch_islands([t], [HW], OPTS, "edp", CFG,
                               seeds=[[]])[0]
    seeded = cs.cosearch_islands([t], [HW], OPTS, "edp", CFG)[0]
    assert seeded.objective <= cold.objective * (1 + 1e-12)
    tol = cold.objective * (1 + 1e-12)
    cold_first = int(np.nonzero(cold.history <= tol)[0][0])
    hit = np.nonzero(seeded.history <= tol)[0]
    assert hit.size and int(hit[0]) <= cold_first


def test_explicit_empty_seeds_bitwise_cold():
    """seeds=[[]] and seed_fraction=0 are the same cold start."""
    t = _task()
    no_frac = dataclasses.replace(CFG, seed_fraction=0.0)
    a = cs.cosearch_islands([t], [HW], OPTS, "edp", CFG, seeds=[[]])[0]
    b = cs.cosearch_islands([t], [HW], OPTS, "edp", no_frac)[0]
    _same_result(a, b)


def test_miqp_anchor_is_valid_partition():
    t = _task()
    p = cs.miqp_anchor(t, HW, OPTS, "edp", CFG)
    assert np.all(p.Px.sum(axis=1) == [op.M for op in t.ops])
    assert np.all(p.Py.sum(axis=1) == [op.N for op in t.ops])


# ------------------------------------------- engine seeding hooks
GA_CFG = GAConfig(generations=4, population=16, patience=4, seed=3)
MIQP_CFG = MIQPConfig(engine="lattice", candidate_budget=256,
                      eval_budget=1024, beam_width=4, refine_sweeps=1,
                      pair_refine=4, descent_sweeps=2, score_chunk=256)


def test_ga_seeds_hook_none_is_bitwise_cold():
    t = _task()
    a = ga_jax.solve_islands([t], [HW], OPTS, "edp", GA_CFG)[0]
    b = ga_jax.solve_islands([t], [HW], OPTS, "edp", GA_CFG,
                             seeds=None)[0]
    c = ga_jax.solve_islands([t], [HW], OPTS, "edp", GA_CFG,
                             seeds=[[]])[0]
    for other in (b, c):
        assert a.objective == other.objective
        np.testing.assert_array_equal(a.partition.Px,
                                      other.partition.Px)
        np.testing.assert_array_equal(a.history, other.history)


def test_ga_seeds_hook_accepts_proposals():
    t = _task()
    props = [p for p, _ in cs.gradient_seeds(t, HW, OPTS, "edp", CFG)]
    r = ga_jax.solve_islands([t], [HW], OPTS, "edp", GA_CFG,
                             seeds=[props])[0]
    assert np.all(r.partition.Px.sum(axis=1) ==
                  [op.M for op in t.ops])
    with pytest.raises(ValueError):
        ga_jax.solve_islands([t], [HW], OPTS, "edp", GA_CFG,
                             seeds=[props, props])


def test_miqp_anchor_hook_none_is_bitwise_cold():
    t = _task()
    a = miqp_jax.solve_lattice_batch([t], [HW], OPTS, "edp",
                                     MIQP_CFG)[0]
    b = miqp_jax.solve_lattice_batch([t], [HW], OPTS, "edp", MIQP_CFG,
                                     anchors=None)[0]
    c = miqp_jax.solve_lattice_batch([t], [HW], OPTS, "edp", MIQP_CFG,
                                     anchors=[None])[0]
    for other in (b, c):
        assert a.objective == other.objective
        np.testing.assert_array_equal(a.partition.Px,
                                      other.partition.Px)


def test_miqp_anchor_hook_recenters():
    t = _task()
    anchor = cs.miqp_anchor(t, HW, OPTS, "edp", CFG)
    r = miqp_jax.solve_lattice_batch([t], [HW], OPTS, "edp", MIQP_CFG,
                                     anchors=[anchor])[0]
    assert np.isfinite(r.objective)
    assert np.all(r.partition.Px.sum(axis=1) ==
                  [op.M for op in t.ops])
    with pytest.raises(ValueError):
        miqp_jax.solve_lattice_batch([t], [HW], OPTS, "edp", MIQP_CFG,
                                     anchors=[anchor, anchor])
