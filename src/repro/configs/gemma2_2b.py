"""gemma2-2b [dense]: 26L d_model=2304 8H (GQA kv=4) d_ff=9216
vocab=256000 — local+global alternating, logit softcap
[arXiv:2408.00118; hf]."""
from ..models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="gemma2-2b", family="dense",
        n_layers=26, d_model=2304, d_ff=9216, vocab_size=256000,
        n_heads=8, n_kv_heads=4, d_head=256,
        window=4096, local_global_period=2,
        attn_logit_softcap=50.0, final_logit_softcap=30.0,
        act="gelu", tie_embeddings=True, emb_scale_by_sqrt_dim=True,
        norm_eps=1e-6,
    )


def reduced_config() -> ModelConfig:
    return config().replace(
        name="gemma2-smoke", n_layers=4, d_model=64, d_ff=128,
        vocab_size=256, n_heads=4, n_kv_heads=2, d_head=16, window=32,
        attn_chunk=32, remat=False)
