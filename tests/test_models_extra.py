"""Additional model-zoo invariants: scanned vs unrolled layer stacks,
MoE dispatch properties, calibration mode, encoder masking."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import forward, init_model
from repro.models.layers.mlp import init_moe, moe_apply

KEY = jax.random.PRNGKey(3)


@pytest.mark.parametrize("arch", ["smollm-360m", "gemma2-2b",
                                  "zamba2-2.7b", "deepseek-v2-236b"])
def test_scan_vs_unrolled_identical(arch):
    """cfg.scan_layers=False (the calibration path) must be numerically
    identical to the scanned production path."""
    cfg = get_config(arch, reduced=True).replace(dtype="float32")
    params = init_model(cfg, KEY)
    tok = jax.random.randint(KEY, (2, 32), 0, cfg.vocab_size)
    lg1, _, _ = forward(params, cfg, {"tokens": tok})
    lg2, _, _ = forward(params, cfg.replace(scan_layers=False),
                        {"tokens": tok})
    np.testing.assert_allclose(np.asarray(lg1), np.asarray(lg2),
                               rtol=1e-5, atol=1e-5)


def test_calibration_mode_identical():
    """Unrolled chunk scans (calibration) = scanned chunk scans."""
    from repro.kernels.calibrate import calibration
    cfg = get_config("rwkv6-3b", reduced=True).replace(dtype="float32")
    params = init_model(cfg, KEY)
    tok = jax.random.randint(KEY, (2, 48), 0, cfg.vocab_size)
    lg1, _, _ = forward(params, cfg, {"tokens": tok})
    with calibration():
        lg2, _, _ = forward(params, cfg, {"tokens": tok})
    np.testing.assert_allclose(np.asarray(lg1), np.asarray(lg2),
                               rtol=1e-5, atol=1e-5)


def test_moe_group_size_invariance():
    """Generous capacity ⇒ group size must not change the output."""
    cfg = get_config("mixtral-8x22b", reduced=True).replace(
        dtype="float32", param_dtype="float32", moe_capacity_factor=8.0)
    p = init_moe(KEY, cfg, jnp.float32)
    x = jax.random.normal(KEY, (2, 160, cfg.d_model), jnp.float32) * 0.3
    y1, _ = moe_apply(p, x, cfg, group_size=64, dtype=jnp.float32)
    y2, _ = moe_apply(p, x, cfg, group_size=320, dtype=jnp.float32)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               rtol=2e-4, atol=2e-4)


def test_moe_tokens_independent():
    """Per-token routing: changing one token (with dropless capacity)
    must not affect other tokens' outputs."""
    cfg = get_config("mixtral-8x22b", reduced=True).replace(
        dtype="float32", param_dtype="float32", moe_capacity_factor=8.0)
    p = init_moe(KEY, cfg, jnp.float32)
    x = jax.random.normal(KEY, (1, 64, cfg.d_model), jnp.float32) * 0.3
    y1, _ = moe_apply(p, x, cfg, dtype=jnp.float32)
    x2 = x.at[0, 7].set(jax.random.normal(jax.random.fold_in(KEY, 9),
                                          (cfg.d_model,)) * 0.3)
    y2, _ = moe_apply(p, x2, cfg, dtype=jnp.float32)
    mask = np.ones(64, bool)
    mask[7] = False
    np.testing.assert_allclose(np.asarray(y1[0, mask]),
                               np.asarray(y2[0, mask]),
                               rtol=2e-4, atol=2e-4)


def test_moe_aux_loss_balanced_router():
    """Uniform router ⇒ aux loss ≈ 1.0 (its minimum for balanced load)."""
    cfg = get_config("mixtral-8x22b", reduced=True).replace(
        dtype="float32", param_dtype="float32")
    p = init_moe(KEY, cfg, jnp.float32)
    p["router"] = jnp.zeros_like(p["router"])   # uniform probs
    x = jax.random.normal(KEY, (2, 128, cfg.d_model), jnp.float32)
    _, aux = moe_apply(p, x, cfg, dtype=jnp.float32)
    assert float(aux) == pytest.approx(1.0, abs=0.25)


def test_encoder_is_bidirectional():
    """Masking a late frame must change early-frame logits (no causal
    mask in the encoder)."""
    cfg = get_config("hubert-xlarge", reduced=True).replace(
        dtype="float32")
    params = init_model(cfg, KEY)
    frames = jax.random.normal(KEY, (1, 24, cfg.frontend_dim))
    lg1, _, _ = forward(params, cfg, {"frames": frames})
    frames2 = frames.at[0, 20].set(0.0)
    lg2, _, _ = forward(params, cfg, {"frames": frames2})
    assert float(jnp.abs(lg1[0, 2] - lg2[0, 2]).max()) > 1e-6


def test_decoder_is_causal():
    """Changing a late token must NOT change earlier logits."""
    cfg = get_config("smollm-360m", reduced=True).replace(dtype="float32")
    params = init_model(cfg, KEY)
    tok = jax.random.randint(KEY, (1, 24), 0, cfg.vocab_size)
    lg1, _, _ = forward(params, cfg, {"tokens": tok})
    tok2 = tok.at[0, 20].set((tok[0, 20] + 1) % cfg.vocab_size)
    lg2, _, _ = forward(params, cfg, {"tokens": tok2})
    np.testing.assert_allclose(np.asarray(lg1[0, :20]),
                               np.asarray(lg2[0, :20]), atol=1e-5)


def test_gemma2_softcaps_bound_logits():
    cfg = get_config("gemma2-2b", reduced=True).replace(dtype="float32")
    params = init_model(cfg, KEY)
    tok = jax.random.randint(KEY, (1, 16), 0, cfg.vocab_size)
    lg, _, _ = forward(params, cfg, {"tokens": tok})
    real = lg[..., : cfg.vocab_size]
    assert float(jnp.abs(real).max()) <= cfg.final_logit_softcap + 1e-3


def test_long_context_decode_state_is_constant_size():
    """SSM/WKV serving state must not grow with context length — the
    property that makes the long_500k cell servable."""
    from repro.models import init_caches
    for arch in ("rwkv6-3b", "zamba2-2.7b"):
        cfg = get_config(arch, reduced=True)
        c_small = init_caches(cfg, 1, 64)
        c_big = init_caches(cfg, 1, 4096)
        n_small = sum(np.prod(x.shape) for x in jax.tree.leaves(c_small)
                      if x.ndim > 0)
        n_big = sum(np.prod(x.shape) for x in jax.tree.leaves(c_big)
                    if x.ndim > 0)
        if arch == "rwkv6-3b":
            assert n_small == n_big          # purely constant state
        else:
            # zamba2: mamba states constant; shared-attn window capped
            assert n_big <= n_small * 40
