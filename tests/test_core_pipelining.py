"""RCPSP pipelining tests (paper Sec. 5.4 / Fig. 11)."""
import pytest

from repro.core.pipelining import (Job, build_jobs, list_schedule,
                                   milp_schedule, pipeline_batch,
                                   sequential_makespan)

SEGS = [("op0", 2.0, 3.0, 1.0), ("op1", 1.0, 4.0, 1.0),
        ("op2", 2.0, 2.0, 2.0)]


def _check_schedule_valid(jobs, starts, makespan):
    byid = {j.jid: j for j in jobs}
    # precedence
    for j in jobs:
        for p in j.preds:
            assert starts[j.jid] >= starts[p] + byid[p].dur - 1e-9
    # unit resources never overlap
    for res in ("comm", "comp"):
        ivals = sorted((starts[j.jid], starts[j.jid] + j.dur)
                       for j in jobs if j.resource == res and j.dur > 0)
        for (s1, e1), (s2, e2) in zip(ivals, ivals[1:]):
            assert s2 >= e1 - 1e-9
    assert makespan >= max(starts[j.jid] + j.dur for j in jobs) - 1e-9


def test_list_schedule_valid_and_bounded():
    jobs = build_jobs(SEGS, batch=4)
    ms, starts = list_schedule(jobs)
    _check_schedule_valid(jobs, starts, ms)
    seq = sequential_makespan(SEGS, 4)
    # lower bound: busiest resource; upper bound: sequential
    comm = sum(j.dur for j in jobs if j.resource == "comm")
    comp = sum(j.dur for j in jobs if j.resource == "comp")
    assert max(comm, comp) - 1e-9 <= ms <= seq + 1e-9


def test_single_sample_no_overlap_possible():
    jobs = build_jobs(SEGS, batch=1)
    ms, _ = list_schedule(jobs)
    assert ms == pytest.approx(sequential_makespan(SEGS, 1))


def test_pipeline_speedup_grows_then_saturates():
    s2 = pipeline_batch(SEGS, 2).speedup
    s8 = pipeline_batch(SEGS, 8).speedup
    s16 = pipeline_batch(SEGS, 16).speedup
    assert 1.0 <= s2 <= s8 <= s16 + 1e-9
    # bounded by total/bottleneck ratio
    total = sum(a + b + c for _, a, b, c in SEGS)
    bottleneck = max(sum(a + c for _, a, _, c in SEGS),
                     sum(b for _, _, b, _ in SEGS))
    assert s16 <= total / bottleneck + 1e-9


def test_milp_no_worse_than_greedy():
    jobs = build_jobs(SEGS, batch=3)
    greedy, _ = list_schedule(jobs)
    ms, starts = milp_schedule(jobs, n_buckets=40, time_limit=20)
    assert ms <= greedy + 1e-9
    # The reported pair is a *feasible continuous-time* schedule (the
    # MILP's bucket order re-simulated through the SGS) — the raw
    # bucket-quantized objective can violate precedence/resource
    # feasibility by up to one bucket width and is only a bound.
    _check_schedule_valid(jobs, starts, ms)


def test_milp_starts_cover_zero_duration_jobs():
    """Regression: the MILP path used to return starts only for dur>0
    jobs, so any consumer indexing ``starts[jid]`` KeyError'd on
    zero-duration jobs. Every job must now appear, with zero-duration
    jobs placed at their resolved predecessor finish."""
    segs = [("a", 0.0, 2.0, 0.0), ("b", 1.0, 1.0, 0.0)]
    jobs = build_jobs(segs, batch=3)
    ms, starts = milp_schedule(jobs, n_buckets=24, time_limit=10)
    assert set(starts) == {j.jid for j in jobs}
    _check_schedule_valid(jobs, starts, ms)
    byid = {j.jid: j for j in jobs}
    for j in jobs:
        if j.dur == 0 and j.preds:
            assert starts[j.jid] >= max(
                starts[p] + byid[p].dur for p in j.preds) - 1e-9


def test_sgs_heap_never_runs_dry():
    """Regression: the SGS once carried a ``pending`` release branch for
    an empty-heap case that popped from a list nothing ever pushed to —
    an IndexError time bomb. The heap cannot run dry on acyclic input
    (Kahn's invariant: each pop readies its successors), so the branch
    is gone; pin that on a converging multi-predecessor DAG, which the
    regular ``build_jobs`` chains never exercise."""
    jobs = [
        Job(0, 0, 0, "in", 1.0, "comm", []),
        Job(1, 1, 0, "in", 2.0, "comm", []),
        Job(2, 0, 0, "comp", 3.0, "comp", [0, 1]),   # converging preds
        Job(3, 0, 0, "out", 1.0, "comm", [2]),
        Job(4, 1, 1, "comp", 0.0, "comp", [2]),      # zero-duration fan-out
        Job(5, 1, 1, "out", 2.0, "comm", [4]),
    ]
    ms, starts = list_schedule(jobs)
    assert len(starts) == len(jobs)
    _check_schedule_valid(jobs, starts, ms)
    # job 2 cannot start before BOTH predecessors finish
    assert starts[2] >= max(starts[0] + 1.0, starts[1] + 2.0) - 1e-9


def test_zero_duration_segments():
    segs = [("a", 0.0, 2.0, 0.0), ("b", 1.0, 1.0, 0.0)]
    r = pipeline_batch(segs, 4)
    assert r.pipelined > 0
    assert r.speedup >= 1.0
