"""Tests for ``kernels/calibrate``: the calibration context's contract
(reentrant, exception-safe), the exact bug the module exists to fix
(``cost_analysis`` counting a scan body once instead of per trip), and
the measured :class:`CalibratedHW` profile (fit, apply, persistence)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.calibrate import (CalibratedHW, KernelSample,
                                     calibration, load_profile,
                                     profile_kernels, save_profile,
                                     scan_unroll)


def test_calibration_reentrant():
    assert scan_unroll() == 1
    with calibration():
        assert scan_unroll() is True
        with calibration():
            assert scan_unroll() is True
            with calibration(False):       # explicit off nests too
                assert scan_unroll() == 1
            assert scan_unroll() is True
        assert scan_unroll() is True
    assert scan_unroll() == 1


def test_calibration_exception_safe():
    with pytest.raises(RuntimeError):
        with calibration():
            raise RuntimeError("boom")
    assert scan_unroll() == 1
    with pytest.raises(RuntimeError):
        with calibration():
            with calibration():
                raise RuntimeError("inner")
    assert scan_unroll() == 1


def _calib_flops(fn, *args):
    with calibration():
        compiled = jax.jit(fn).lower(*args).compile()
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    return float(cost.get("flops", 0.0))


def _rolled_flops(fn, *args):
    compiled = jax.jit(fn).lower(*args).compile()
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    return float(cost.get("flops", 0.0))


def _ssm_args(S, chunk):
    rng = np.random.default_rng(0)
    B, H, P_, G, N = 1, 1, 8, 1, 8
    x = jnp.asarray(rng.standard_normal((B, S, H, P_)), jnp.float32)
    dt = jnp.asarray(rng.uniform(0.01, 0.1, (B, S, H)), jnp.float32)
    a = jnp.asarray(-rng.uniform(0.5, 2.0, (H,)), jnp.float32)
    Bm = jnp.asarray(rng.standard_normal((B, S, G, N)), jnp.float32)
    Cm = jnp.asarray(rng.standard_normal((B, S, G, N)), jnp.float32)
    D = jnp.asarray(rng.standard_normal((H,)), jnp.float32)

    from repro.kernels.ssm_scan.chunked import ssm_scan_chunked

    def fn(x, dt, a, Bm, Cm, D):
        return ssm_scan_chunked(x, dt, a, Bm, Cm, D, chunk=chunk)[0]

    return fn, (x, dt, a, Bm, Cm, D)


def _rwkv_args(S, chunk):
    rng = np.random.default_rng(0)
    B, H, K = 1, 1, 16
    shp = (B, S, H, K)
    r = jnp.asarray(rng.standard_normal(shp), jnp.float32)
    k = jnp.asarray(rng.standard_normal(shp), jnp.float32)
    v = jnp.asarray(rng.standard_normal(shp), jnp.float32)
    w = jnp.asarray(rng.uniform(0.6, 0.99, shp), jnp.float32)
    u = jnp.asarray(rng.standard_normal((H, K)), jnp.float32)

    from repro.kernels.rwkv6.chunked import wkv6_chunked

    def fn(r, k, v, w, u):
        return wkv6_chunked(r, k, v, w, u, chunk=chunk)[0]

    return fn, (r, k, v, w, u)


@pytest.mark.parametrize("make_args", [_ssm_args, _rwkv_args],
                         ids=["ssm_scan", "rwkv6"])
def test_calibrated_flops_scale_linearly_with_trips(make_args):
    """Under calibration() the chunk scan unrolls, so cost_analysis FLOPs
    grow linearly with the trip count (constant per-chunk work)."""
    chunk = 16
    flops = {}
    for S in (16, 32, 64):                 # 1, 2, 4 trips
        fn, args = make_args(S, chunk)
        flops[S] = _calib_flops(fn, *args)
    assert flops[32] > flops[16] > 0
    d1 = flops[32] - flops[16]             # +1 trip
    d2 = flops[64] - flops[32]             # +2 trips
    assert d2 == pytest.approx(2 * d1, rel=0.05)
    # the rolled form under-counts: its 4-trip graph reports the while
    # body once, i.e. well under half the true work
    fn, args = make_args(64, chunk)
    assert _rolled_flops(fn, *args) < 0.6 * flops[64]


def test_profile_fit_and_apply():
    prof = profile_kernels(smoke=True, reps=1)
    assert prof.backend == jax.default_backend()
    assert prof.flops_per_s > 0 and prof.bytes_per_s > 0
    assert prof.byte_overhead >= 1.0
    kernels = {s.kernel for s in prof.samples}
    assert kernels == {"gemm", "flash_attention", "rwkv6", "ssm_scan"}
    for s in prof.samples:
        assert isinstance(s, KernelSample)
        assert s.flops > 0 and s.wall_s > 0
    from repro.core.hw import HWConfig
    hw = prof.apply(HWConfig(X=2, Y=2, R=64, C=64))
    assert hw.freq_hz == pytest.approx(prof.flops_per_s / (2 * 64 * 64))
    assert hw.bw_mem == pytest.approx(prof.bw_mem_model * 4)
    assert hw.bw_nop == pytest.approx(prof.bw_mem_model * prof.nop_frac)


def test_profile_store_roundtrip(tmp_path):
    prof = CalibratedHW(backend="cpu", flops_per_s=1e11, bytes_per_s=1e10,
                        byte_overhead=3.0,
                        samples=(KernelSample("gemm", (8, 8, 8), 1024.0,
                                              768.0, 768.0, 1e-6),))
    path = str(tmp_path / "prof.bin")
    save_profile(prof, path)
    assert load_profile(path) == prof


def test_profile_load_degrades_to_none(tmp_path):
    missing = str(tmp_path / "nope.bin")
    assert load_profile(missing) is None
    corrupt = tmp_path / "corrupt.bin"
    corrupt.write_bytes(b"not a cache store at all")
    assert load_profile(str(corrupt)) is None
    # a stale-schema profile misses too (versioned key)
    old = CalibratedHW(backend="cpu", flops_per_s=1.0, bytes_per_s=1.0,
                       byte_overhead=1.0, schema=-1)
    from repro.serve.cache_store import CacheStore
    path = str(tmp_path / "stale.bin")
    CacheStore(path).save({("calibrated_hw", -1): old})
    assert load_profile(path) is None
