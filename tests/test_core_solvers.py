"""Solver tests: GA, MIQP, SIMBA, polish (paper Sec. 6 / Table 3)."""
import numpy as np
import pytest

from repro.core import (EvalOptions, Evaluator, GemmOp, Task, make_hw,
                        optimize, uniform_partition)
from repro.core.ga import GAConfig, run_ga
from repro.core.miqp import MIQPConfig, approx_inverse, run_miqp
from repro.core.simba import simba_partition


def chain_task():
    ops = [GemmOp("g0", M=1024, K=512, N=1024)]
    for i in range(1, 5):
        ops.append(GemmOp(f"g{i}", M=1024, K=ops[-1].N,
                          N=512 if i % 2 else 2048, chained=True))
    return Task("chain", ops)


def test_simba_partition_inverse_distance():
    task = chain_task()
    hw = make_hw("A", 4)
    p = simba_partition(task, hw)
    p.validate(task)
    # nearer rows get >= work than farther rows (row 0 is at the entrance)
    assert (p.Px[:, 0] >= p.Px[:, -1]).all()


def test_ga_beats_or_matches_baseline():
    task = chain_task()
    hw = make_hw("A", 4, "hbm", diagonal_links=True)
    opts = EvalOptions(redistribution=True, async_exec=True)
    base = Evaluator(task, hw, opts).evaluate(
        uniform_partition(task, 4, 4),
        redist_mask=np.zeros(len(task), bool))
    out = run_ga(task, hw, "latency", opts,
                 GAConfig(generations=40, population=48, seed=0))
    assert out.objective <= base.latency + 1e-12
    out.partition.validate(task)


def test_ga_deterministic_given_seed():
    task = chain_task()
    hw = make_hw("A", 4)
    cfg = GAConfig(generations=10, population=24, seed=7)
    a = run_ga(task, hw, "latency", None, cfg)
    b = run_ga(task, hw, "latency", None, cfg)
    assert a.objective == pytest.approx(b.objective)


def test_miqp_model_matches_evaluator():
    """The MILP's objective must agree with the exact evaluator on its own
    solution (sync options) — the linearization is exact, not heuristic."""
    task = chain_task()
    hw = make_hw("A", 4, "hbm", diagonal_links=True)
    opts = EvalOptions(redistribution=True, async_exec=False)
    out = run_miqp(task, hw, "latency", opts, MIQPConfig(time_limit=30))
    exact = Evaluator(task, hw, opts).evaluate(out.partition,
                                               out.redist_mask)
    assert out.milp_objective * 1e-6 == pytest.approx(exact.latency,
                                                      rel=0.02)


def test_miqp_beats_baseline():
    task = chain_task()
    hw = make_hw("A", 4, "hbm")
    base = optimize(task, hw, "baseline")
    mi = optimize(task, hw, "miqp",
                  miqp_config=MIQPConfig(time_limit=30))
    assert mi.latency <= base.latency + 1e-12


@pytest.mark.parametrize("t", ["A", "B", "C", "D"])
def test_miqp_all_types(t):
    task = Task("two", [GemmOp("a", M=512, K=256, N=512),
                        GemmOp("b", M=512, K=512, N=512, chained=True)])
    hw = make_hw(t, 4, "hbm")
    out = run_miqp(task, hw, "latency", None, MIQPConfig(time_limit=20))
    out.partition.validate(task)
    assert out.objective > 0


def test_edp_objective():
    task = chain_task()
    hw = make_hw("A", 4, "hbm")
    base = optimize(task, hw, "baseline")
    ga = optimize(task, hw, "ga", objective="edp",
                  ga_config=GAConfig(generations=30, population=32))
    assert ga.edp <= base.baseline.edp * 1.001


def test_paper_ordering_on_alexnet():
    """Table-3 qualitative claim: optimized >= LS >= SIMBA-like."""
    from repro.graphs import alexnet_task
    task = alexnet_task(batch=1)
    hw = make_hw("A", 4, "hbm")
    base = optimize(task, hw, "baseline").latency
    simba = optimize(task, hw, "simba").latency
    ga = optimize(task, hw, "ga",
                  ga_config=GAConfig(generations=40, population=48)).latency
    assert ga <= base * 1.0 + 1e-12
    assert simba >= base * 0.95   # paper: SIMBA slightly worse than LS


def test_approx_inverse_trick():
    # paper Sec 6.3.1: 1/(c+x) ~ (c-x)/c^2 near x=0
    c = 16.0
    for x in (0.0, 0.5, 1.0):
        assert approx_inverse(c, x) == pytest.approx(1.0 / (c + x),
                                                     rel=0.01)


def test_approx_inverse_accuracy_window():
    """The first-order replacement has relative error exactly (x/c)²:
    1/(c+x) − (c−x)/c² = x²/(c²(c+x)). That pins its usable window —
    ≤1% inside |x| ≤ 0.1c, quadratic degradation outside — and the
    bound must hold across coefficient scales (the trick is applied
    after the paper's constant-scaling trick #1, so c spans decades)."""
    for c in (0.25, 1.0, 16.0, 1e6):
        for r in (-0.3, -0.1, -0.01, 0.0, 0.01, 0.1, 0.3):
            x = r * c
            exact = 1.0 / (c + x)
            rel = abs(approx_inverse(c, x) - exact) / exact
            assert rel == pytest.approx(r * r, abs=1e-12)
    # inside the window the error is ≤1%; at 3x the window it is ~9x worse
    assert abs(approx_inverse(10.0, 1.0) - 1 / 11.0) * 11.0 <= 0.01 + 1e-12
    # vectorized x (the irregular-hardware extension path feeds arrays)
    xs = np.linspace(-1.0, 1.0, 11)
    out = approx_inverse(10.0, xs)
    np.testing.assert_allclose(out, (10.0 - xs) / 100.0, rtol=1e-12)


def test_miqp_timeout_fallback():
    """Large instance + tiny budget: the HiGHS engine must fall back to
    a feasible (uniform) schedule instead of raising (fleet robustness).
    Pinned to ``engine="milp"`` — the lattice engine has no external-
    solver timeout failure mode (its budgets are candidate counts,
    DESIGN.md §12; ``tests/test_core_miqp_engines.py`` covers it)."""
    from repro.graphs import vit_task
    task = vit_task(batch=1)
    hw = make_hw("A", 8, "hbm")
    from repro.core import optimize
    r = optimize(task, hw, "miqp",
                 miqp_config=MIQPConfig(time_limit=2, engine="milp"))
    r.partition.validate(task)
    assert r.speedup_vs_baseline >= 0.99
