"""MCMComm core — the paper's contribution as a composable library.

Layers:
  * :mod:`repro.core.hw` — MCM packaging types A–D, Table-2 constants,
    hop-count topology (incl. diagonal links, Sec. 5.1).
  * :mod:`repro.core.workload` — GEMM-sequence tasks and partitions.
  * :mod:`repro.core.evaluator` — end-to-end latency/energy/EDP model
    (Sec. 4.3/4.4) with redistribution + async execution (Sec. 5.2/5.3);
    numpy reference backend plus a ``jax.jit``/``vmap`` backend
    (:mod:`repro.core.evaluator_jax`, DESIGN.md §8).
  * :mod:`repro.core.sweep` — batched (HWConfig × Task × EvalOptions)
    design-space sweeps with result caching (DESIGN.md §9).
  * :mod:`repro.core.ga` / :mod:`repro.core.miqp` — the two solvers
    (Sec. 6.2/6.3); :mod:`repro.core.ga_jax` — the device-resident GA
    evolution engine (jit-fused generation step, DESIGN.md §10);
    :mod:`repro.core.miqp_jax` — the batched lattice-enumeration MIQP
    engine (exact arg-min over the Sec.-6.2 search lattice,
    DESIGN.md §12); :mod:`repro.core.simba` — the heuristic baseline.
  * :mod:`repro.core.pipelining` — RCPSP cross-sample pipelining
    (Sec. 5.4): serial list scheduler + time-indexed MILP;
    :mod:`repro.core.pipelining_jax` — the batched vectorized SGS
    (bit-identical to the serial engine, one jitted call per
    (n_ops, batch) shape group, DESIGN.md §13).
  * :mod:`repro.core.topology` — shared mesh geometry: link enumeration,
    XY/diagonal routing, entrance masks, hop matrices (DESIGN.md §11).
  * :mod:`repro.core.netsim` — flow-level NoP simulator (Fig. 3):
    vectorized max-min waterfilling engine + event-driven reference;
    :mod:`repro.core.netsim_jax` — the jitted batched port, also traced
    by the evaluator's ``congestion="flow"`` mode.
  * :mod:`repro.core.cosearch` — fused cross-layer co-search
    (DESIGN.md §16): one jitted genome spanning partition × diagonal
    links × pipeline segmentation, gradient-guided seeding, batched
    Pareto archive; wired as :func:`repro.core.sweep.cosearch_sweep`.
  * :mod:`repro.core.multitenant` — multi-tenant placement on one
    (possibly heterogeneous) package (DESIGN.md §18): contiguous
    row-band tenant regions, per-tenant inner solves through any
    engine, NoP contention via the shared flow netsim; wired as
    :func:`repro.core.sweep.multitenant_sweep`.
  * :mod:`repro.core.api` — one-call front door.
"""
from .api import (ScheduleResult, baseline_result, optimize,  # noqa: F401
                  refine_schedule)
# NB: the joint-search front door is ``api.cosearch`` — the name
# ``repro.core.cosearch`` stays bound to the submodule.
from .cosearch import (CoSearchConfig, CoSearchResult,  # noqa: F401
                       run_cosearch)
from .evaluator import (AUTO_POPULATION_THRESHOLD, BACKENDS,  # noqa: F401
                        CONGESTION_MODES, EvalOptions, EvalResult,
                        Evaluator, resolve_auto_backend)
from .ga import GAConfig, GAResult, run_ga  # noqa: F401
from .hw import (ChipletClass, HWConfig, MCMType, Topology,  # noqa: F401
                 make_hw)
from .miqp import (MIQPConfig, MIQPResult, run_miqp,  # noqa: F401
                   resolve_auto_engine)
from .multitenant import (MultiTenantConfig, MultiTenantResult,  # noqa: F401
                          solve_multitenant)
from .pipelining import (PIPELINE_ENGINES, PipelineConfig,  # noqa: F401
                         PipelineResult, pipeline_batch,
                         resolve_auto_pipeline_engine)
from .sweep import (EvalPoint, MultiTenantPoint, PipelinePoint,  # noqa: F401
                    cosearch_sweep, eval_sweep, multitenant_sweep,
                    pipeline_sweep, solve_grid)
from .workload import GemmOp, Partition, Task, uniform_partition  # noqa: F401
