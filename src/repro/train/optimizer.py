"""AdamW with decoupled weight decay and global-norm clipping, written
functionally (no optax dependency). Optimizer moments inherit the exact
parameter shardings (FSDP×TP), i.e. ZeRO-style sharded optimizer state by
construction.
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class Optimizer:
    init: Callable
    update: Callable


def cosine_schedule(peak_lr: float, warmup_steps: int, total_steps: int,
                    final_frac: float = 0.1):
    def lr(step):
        step = jnp.asarray(step, jnp.float32)
        warm = peak_lr * step / jnp.maximum(warmup_steps, 1)
        t = jnp.clip((step - warmup_steps)
                     / jnp.maximum(total_steps - warmup_steps, 1), 0.0, 1.0)
        cos = final_frac + (1 - final_frac) * 0.5 * (1 + jnp.cos(jnp.pi * t))
        return jnp.where(step < warmup_steps, warm, peak_lr * cos)
    return lr


def global_norm(tree) -> jnp.ndarray:
    return jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                        for g in jax.tree.leaves(tree)))


def clip_by_global_norm(tree, max_norm: float):
    norm = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale
                                   ).astype(g.dtype), tree), norm


def adamw(lr: float | Callable = 3e-4, b1: float = 0.9, b2: float = 0.95,
          eps: float = 1e-8, weight_decay: float = 0.1,
          clip_norm: float | None = 1.0,
          m_dtype=jnp.float32, v_dtype=jnp.float32) -> Optimizer:
    """``m_dtype=bf16`` halves first-moment memory — used for the ≥100B
    models where full-f32 Adam state exceeds per-chip HBM."""
    lr_fn = lr if callable(lr) else (lambda _: lr)

    def init(params):
        return {"m": jax.tree.map(
                    lambda p: jnp.zeros(p.shape, m_dtype), params),
                "v": jax.tree.map(
                    lambda p: jnp.zeros(p.shape, v_dtype), params),
                "count": jnp.zeros((), jnp.int32)}

    def update(grads, state, params):
        if clip_norm is not None:
            grads, gnorm = clip_by_global_norm(grads, clip_norm)
        else:
            gnorm = global_norm(grads)
        count = state["count"] + 1
        b1c = 1.0 - b1 ** count.astype(jnp.float32)
        b2c = 1.0 - b2 ** count.astype(jnp.float32)
        step_lr = lr_fn(count)

        def upd(g, m, v, p):
            g32 = g.astype(jnp.float32)
            m32 = b1 * m.astype(jnp.float32) + (1 - b1) * g32
            v32 = b2 * v.astype(jnp.float32) + (1 - b2) * g32 * g32
            mh = m32 / b1c
            vh = v32 / b2c
            delta = mh / (jnp.sqrt(vh) + eps)
            if weight_decay and p.ndim >= 2:   # no decay on norms/bias
                delta = delta + weight_decay * p.astype(jnp.float32)
            return ((p.astype(jnp.float32) - step_lr * delta
                     ).astype(p.dtype),
                    m32.astype(m_dtype), v32.astype(v_dtype))

        flat_p, tdef = jax.tree.flatten(params)
        flat_g = tdef.flatten_up_to(grads)
        flat_m = tdef.flatten_up_to(state["m"])
        flat_v = tdef.flatten_up_to(state["v"])
        out = [upd(g, m, v, p)
               for g, m, v, p in zip(flat_g, flat_m, flat_v, flat_p)]
        new_p = tdef.unflatten([o[0] for o in out])
        new_m = tdef.unflatten([o[1] for o in out])
        new_v = tdef.unflatten([o[2] for o in out])
        new_state = {"m": new_m, "v": new_v, "count": count}
        return new_p, new_state, {"grad_norm": gnorm, "lr": step_lr}

    return Optimizer(init=init, update=update)
