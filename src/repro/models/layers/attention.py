"""GQA attention layer with train / prefill / decode paths.

KV cache is a ring buffer of capacity ``Smax`` (= window size for
sliding-window archs, = max context otherwise). RoPE is applied to keys
before caching, so ring rotation only affects masking, which is computed
from reconstructed absolute slot positions.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ...kernels.flash_attention.blockwise import blockwise_attention
from ...sharding.logical import shard
from .common import dense_init, rms_norm, rope, softcap

NEG_INF = -2.0e38


def init_attn(key, cfg, d_in: int | None = None, d_out: int | None = None,
              dtype=jnp.float32):
    D = d_in or cfg.d_model
    Do = d_out or cfg.d_model
    H, KV, Dh = cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    ks = jax.random.split(key, 4)
    p = {
        "wq": dense_init(ks[0], (D, H, Dh), D, dtype),
        "wk": dense_init(ks[1], (D, KV, Dh), D, dtype),
        "wv": dense_init(ks[2], (D, KV, Dh), D, dtype),
        "wo": dense_init(ks[3], (H, Dh, Do), H * Dh, dtype),
    }
    if cfg.qk_norm:
        p["q_norm"] = jnp.zeros((Dh,), dtype)
        p["k_norm"] = jnp.zeros((Dh,), dtype)
    return p


def init_cache(cfg, batch: int, capacity: int, dtype):
    KV, Dh = cfg.n_kv_heads, cfg.d_head
    return {
        "k": jnp.zeros((batch, capacity, KV, Dh), dtype),
        "v": jnp.zeros((batch, capacity, KV, Dh), dtype),
    }


def _prefill_cache(buf, kv):
    """Write S freshly-computed entries into a ring buffer of capacity C.
    S ≤ C: plain placement at slots 0..S−1 (absolute = slot). S > C: keep
    the last C entries, rotated so entry at absolute position p sits in
    slot p % C."""
    S, C = kv.shape[1], buf.shape[1]
    if S <= C:
        return jax.lax.dynamic_update_slice(buf, kv.astype(buf.dtype),
                                            (0, 0, 0, 0))
    tail = kv[:, S - C:].astype(buf.dtype)
    return jnp.roll(tail, shift=(S - C) % C, axis=1)


def _ring_positions(capacity: int, pos):
    """Absolute position held by each cache slot after writing ``pos``."""
    s = jnp.arange(capacity)
    return pos - jnp.mod(pos - s, capacity)


def attn_apply(p, x, cfg, *, positions, window=None, cache=None, pos=None,
               mode="train", causal=True, dtype=jnp.bfloat16):
    """x (B, S, D_in) → (out (B, S, d_model), new_cache)."""
    B, S, _ = x.shape
    H, KV, Dh = cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    xq = x.astype(dtype)
    q = jnp.einsum("bsd,dhk->bshk", xq, p["wq"].astype(dtype))
    k = jnp.einsum("bsd,dhk->bshk", xq, p["wk"].astype(dtype))
    v = jnp.einsum("bsd,dhk->bshk", xq, p["wv"].astype(dtype))
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"], cfg.norm_eps, plus_one=True)
        k = rms_norm(k, p["k_norm"], cfg.norm_eps, plus_one=True)
    q = rope(q, positions, cfg.rope_theta)
    k = rope(k, positions, cfg.rope_theta)
    q = shard(q, "act_bshd")

    cap = cfg.attn_logit_softcap
    new_cache = cache
    if mode == "train":
        out = blockwise_attention(
            q, k, v, causal=causal, window=window, softcap=cap,
            q_chunk=cfg.attn_chunk, kv_chunk=2 * cfg.attn_chunk)
    elif mode == "prefill":
        new_cache = {"k": shard(_prefill_cache(cache["k"], k), "cache"),
                     "v": shard(_prefill_cache(cache["v"], v), "cache")}
        out = blockwise_attention(
            q, k, v, causal=causal, window=window, softcap=cap,
            q_chunk=cfg.attn_chunk, kv_chunk=2 * cfg.attn_chunk)
    elif mode == "decode":
        capacity = cache["k"].shape[1]
        slot = jnp.mod(pos, capacity)
        ck = jax.lax.dynamic_update_slice(
            cache["k"], k.astype(cache["k"].dtype),
            (0, slot.astype(jnp.int32), 0, 0))
        cv = jax.lax.dynamic_update_slice(
            cache["v"], v.astype(cache["v"].dtype),
            (0, slot.astype(jnp.int32), 0, 0))
        new_cache = {"k": shard(ck, "cache"), "v": shard(cv, "cache")}
        out = _decode_attend(q, ck, cv, pos, capacity, window, cap)
    else:
        raise ValueError(mode)
    out = jnp.einsum("bshk,hkd->bsd", out.astype(dtype),
                     p["wo"].astype(dtype))
    return shard(out, "act_btd"), new_cache


def _decode_attend(q, ck, cv, pos, capacity, window, cap):
    """Single-token attention over a ring-buffer cache."""
    B, S, H, Dh = q.shape               # S == 1
    KV = ck.shape[2]
    G = H // KV
    abs_pos = _ring_positions(capacity, pos)        # (cap,)
    valid = abs_pos >= 0
    valid &= abs_pos <= pos
    if window is not None:
        valid &= abs_pos > pos - window
    qg = q.reshape(B, S, KV, G, Dh)
    s = jnp.einsum("bqkgd,bskd->bkgqs", qg.astype(jnp.float32),
                   ck.astype(jnp.float32)) / jnp.sqrt(float(Dh))
    s = softcap(s, cap)
    s = jnp.where(valid[None, None, None, None, :], s, NEG_INF)
    p = jnp.exp(s - s.max(axis=-1, keepdims=True))
    p = p / p.sum(axis=-1, keepdims=True)
    out = jnp.einsum("bkgqs,bskd->bqkgd", p, cv.astype(jnp.float32))
    return out.reshape(B, S, H, Dh).astype(q.dtype)
