"""Abstract input/state specs for lowering — ShapeDtypeStruct stand-ins
with shardings attached; no device allocation ever happens here."""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding

from ..configs import SHAPE_DEFS
from ..models import init_caches, init_model
from ..sharding.partition_specs import (cache_shardings, data_specs,
                                        param_shardings)
from ..train import adamw
from ..train.train_step import init_train_state


def abstract(tree, shardings=None):
    def one(x, s=None):
        return jax.ShapeDtypeStruct(x.shape, x.dtype, sharding=s)
    if shardings is None:
        return jax.tree.map(one, tree)
    return jax.tree.map(one, tree, shardings)


def input_specs(cfg, shape_name: str, mesh) -> dict:
    """ShapeDtypeStructs for every model input of one (arch × shape) cell:
    {tokens,...} for train/prefill; {tokens, pos} for decode."""
    sd = SHAPE_DEFS[shape_name]
    S, B = sd["seq_len"], sd["global_batch"]
    kind = sd["kind"]
    ds = data_specs(mesh)

    def spec(shape, dtype, key):
        return jax.ShapeDtypeStruct(
            shape, dtype, sharding=NamedSharding(
                mesh, _safe(ds[key], shape, mesh)))

    batch = {}
    if cfg.frontend == "audio_stub":
        batch["frames"] = spec((B, S, cfg.frontend_dim), jnp.bfloat16,
                               "frames")
        if kind == "train":
            batch["mask"] = spec((B, S), jnp.bool_, "mask")
            batch["labels"] = spec((B, S), jnp.int32, "labels")
        return batch
    if kind == "decode":
        batch["tokens"] = spec((B, 1), jnp.int32, "tokens")
        return batch
    st = S - (cfg.frontend_tokens if cfg.frontend == "vision_stub" else 0)
    batch["tokens"] = spec((B, st), jnp.int32, "tokens")
    if cfg.frontend == "vision_stub":
        batch["patches"] = spec((B, cfg.frontend_tokens, cfg.frontend_dim),
                                jnp.bfloat16, "patches")
    return batch


def _safe(spec, shape, mesh):
    from ..sharding.logical import sanitize_spec
    return sanitize_spec(spec, shape, mesh)


def state_specs(cfg, mesh, optimizer=None, fsdp_axes=("data",)):
    """Abstract train state with shardings (params + Adam moments share
    the FSDP×TP layout; ZeRO by construction). ``fsdp_axes=("pod","data")``
    extends the sharding across pods for models exceeding one pod's HBM."""
    opt = optimizer or adamw()
    shapes = jax.eval_shape(
        lambda: init_train_state(
            init_model(cfg, jax.random.PRNGKey(0)), opt))
    shardings = {
        "params": param_shardings(shapes["params"], mesh, fsdp_axes),
        "opt": {
            "m": param_shardings(shapes["opt"]["m"], mesh, fsdp_axes),
            "v": param_shardings(shapes["opt"]["v"], mesh, fsdp_axes),
            "count": NamedSharding(mesh, jax.sharding.PartitionSpec()),
        },
        "step": NamedSharding(mesh, jax.sharding.PartitionSpec()),
    }
    return abstract(shapes, shardings), shardings


def params_specs_only(cfg, mesh, fsdp_axes=("data",)):
    shapes = jax.eval_shape(
        lambda: init_model(cfg, jax.random.PRNGKey(0)))
    sh = param_shardings(shapes, mesh, fsdp_axes)
    return abstract(shapes, sh), sh


def cache_specs(cfg, shape_name: str, mesh):
    sd = SHAPE_DEFS[shape_name]
    S, B = sd["seq_len"], sd["global_batch"]
    shapes = jax.eval_shape(
        lambda: init_caches(cfg, B, S, dtype=jnp.bfloat16))
    sh = cache_shardings(shapes, cfg, mesh)
    return abstract(shapes, sh), sh
