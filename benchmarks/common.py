"""Shared benchmark plumbing: timing, CSV emission, result caching."""
from __future__ import annotations

import json
import os
import time

ART = os.path.join(os.path.dirname(__file__), "artifacts")
os.makedirs(ART, exist_ok=True)

_ROWS: list[tuple[str, float, str]] = []


def emit(name: str, us_per_call: float, derived: str = ""):
    """One CSV row: ``name,us_per_call,derived``."""
    _ROWS.append((name, us_per_call, derived))
    print(f"{name},{us_per_call:.3f},{derived}")


def rows():
    return list(_ROWS)


def timed(fn, *args, **kw):
    t0 = time.perf_counter()
    out = fn(*args, **kw)
    return out, (time.perf_counter() - t0) * 1e6


def save_json(name: str, data):
    path = os.path.join(ART, f"{name}.json")
    with open(path, "w") as f:
        json.dump(data, f, indent=1)
    return path


def geomean(xs):
    import numpy as np
    xs = np.asarray(list(xs), dtype=float)
    return float(np.exp(np.log(np.maximum(xs, 1e-12)).mean()))
