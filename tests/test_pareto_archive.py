"""Property tests for the co-search Pareto utilities (DESIGN.md §16):
dominance is a strict partial order, ``pareto_mask`` fronts are
minimal and complete, and the archive's front is a pure function of
the *set* of inserted points (insertion-order invariance).

Runs property-based via ``hypothesis`` when installed
(tests/_hypothesis_compat.py); otherwise the same properties run
against deterministic seeded sample batteries so the suite's pass
count does not depend on a dev-only dependency. Samples draw from a
small integer lattice on purpose — exact ties and duplicate rows are
where dominance/front bugs live.
"""
import numpy as np
import pytest

from _hypothesis_compat import HAVE_HYPOTHESIS, given, settings, st

from repro.core.cosearch import ParetoArchive, dominates, pareto_mask

SEEDS = range(25)


def _points(seed, max_n=12, dim=3):
    rng = np.random.default_rng(seed)
    n = int(rng.integers(1, max_n + 1))
    return rng.integers(0, 4, size=(n, dim)).astype(np.float64)


# ---------------------------------------------------------- properties
def check_dominance_partial_order(pts):
    """Irreflexive, antisymmetric, transitive — on every pair/triple of
    the sample (incl. constructed dominated chains so the transitivity
    premise actually fires)."""
    for a in pts:
        assert not dominates(a, a)
    for a in pts:
        for b in pts:
            assert not (dominates(a, b) and dominates(b, a))
    # constructed chain a < b < c (elementwise bumps) → a < c
    for a in pts:
        b = a + np.array([1.0] + [0.0] * (len(a) - 1))
        c = b + 1.0
        assert dominates(a, b) and dominates(b, c)
        assert dominates(a, c)


def check_front_minimal_and_complete(pts):
    """No front member dominates or equals another; every excluded
    point is dominated by (or duplicates) some member."""
    mask = pareto_mask(pts)
    assert mask.any()
    front = pts[mask]
    for i, a in enumerate(front):
        for j, b in enumerate(front):
            if i != j:
                assert not dominates(a, b)
                assert not np.array_equal(a, b)
    for p in pts[~mask]:
        assert any(dominates(q, p) or np.array_equal(q, p)
                   for q in front)


def check_archive_order_invariance(pts, perm_seed):
    """The archive front is identical for any insertion order, and
    matches ``pareto_mask`` applied to the whole batch at once."""
    a1, a2 = ParetoArchive(), ParetoArchive()
    for p in pts:
        a1.insert(p)
    order = np.random.default_rng(perm_seed).permutation(len(pts))
    for i in order:
        a2.insert(pts[i])
    f1, f2 = a1.front(), a2.front()
    assert np.array_equal(f1, f2)
    ref = pts[pareto_mask(pts)]
    ref = ref[np.lexsort(tuple(ref[:, j]
                               for j in range(ref.shape[1] - 1, -1, -1)))]
    assert np.array_equal(f1, ref)
    # truncation is a prefix rule: front(k) == front()[:k]
    k = max(1, len(f1) - 1)
    assert np.array_equal(a1.front(k), f1[:k])


# ------------------------------------------------------------- drivers
if HAVE_HYPOTHESIS:
    lattice_points = st.lists(
        st.tuples(st.integers(0, 3), st.integers(0, 3),
                  st.integers(0, 3)),
        min_size=1, max_size=12,
    ).map(lambda rows: np.asarray(rows, dtype=np.float64))

    @settings(max_examples=100, deadline=None)
    @given(pts=lattice_points)
    def test_dominance_partial_order(pts):
        check_dominance_partial_order(pts)

    @settings(max_examples=100, deadline=None)
    @given(pts=lattice_points)
    def test_front_minimal_and_complete(pts):
        check_front_minimal_and_complete(pts)

    @settings(max_examples=100, deadline=None)
    @given(pts=lattice_points, perm_seed=st.integers(0, 2**32 - 1))
    def test_archive_order_invariance(pts, perm_seed):
        check_archive_order_invariance(pts, perm_seed)

else:
    @pytest.mark.parametrize("seed", SEEDS)
    def test_dominance_partial_order(seed):
        check_dominance_partial_order(_points(seed))

    @pytest.mark.parametrize("seed", SEEDS)
    def test_front_minimal_and_complete(seed):
        check_front_minimal_and_complete(_points(seed))

    @pytest.mark.parametrize("seed", SEEDS)
    def test_archive_order_invariance(seed):
        check_archive_order_invariance(_points(seed), seed + 1)


# ----------------------------------------------------- concrete pins
def test_insert_reports_membership():
    a = ParetoArchive()
    assert a.insert([1.0, 2.0], payload="p0")
    assert not a.insert([1.0, 2.0])          # exact duplicate
    assert not a.insert([2.0, 3.0])          # dominated
    assert a.insert([0.5, 3.0], payload="p1")  # trades off → joins
    assert a.insert([0.0, 0.0], payload="p2")  # dominates all → prunes
    assert len(a) == 1
    assert a.payloads() == ["p2"]


def test_empty_archive_front_shape():
    assert ParetoArchive().front().shape == (0, 0)
    assert ParetoArchive().payloads() == []
