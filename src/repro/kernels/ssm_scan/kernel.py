"""Pallas TPU kernel for the Mamba-2 SSD chunk scan.

Grid (B·H, n_chunks) with chunks innermost: the (P, N) chunk state lives
in VMEM scratch and is carried across the sequential chunk steps of each
(batch, head) program — the TPU-native mapping of the paper-style
"SIMD-class sequential op": intra-chunk work is dense MXU matmuls, the
recurrence is the tiny VMEM-resident state update.

Inputs are pre-arranged head-major and the per-head decay increments
``da = dt·a`` are precomputed, so the kernel sees only 2-D tiles:
  x  (BH, L, P)    dt (BH, L, 1)    da (BH, L, 1)
  Bm (BH, L, N)    Cm (BH, L, N)    (KV groups pre-broadcast to heads)
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(x_ref, dt_ref, da_ref, b_ref, c_ref, o_ref, h_ref, *,
                n_chunks: int, Lc: int):
    ic = pl.program_id(1)

    @pl.when(ic == 0)
    def _init():
        h_ref[...] = jnp.zeros_like(h_ref)

    x = x_ref[0].astype(jnp.float32)        # (Lc, P)
    dt = dt_ref[0].astype(jnp.float32)      # (Lc, 1)
    da = da_ref[0].astype(jnp.float32)      # (Lc, 1)
    Bm = b_ref[0].astype(jnp.float32)       # (Lc, N)
    Cm = c_ref[0].astype(jnp.float32)       # (Lc, N)

    cum = jnp.cumsum(da, axis=0)            # (Lc, 1)
    # intra-chunk: y[i] = Σ_{j<=i} exp(cum_i - cum_j)·dt_j·(C_i·B_j)·x_j
    diff = cum - cum.T                      # (Lc, Lc)
    tri = (jax.lax.broadcasted_iota(jnp.int32, (Lc, Lc), 0)
           >= jax.lax.broadcasted_iota(jnp.int32, (Lc, Lc), 1))
    Lmat = jnp.where(tri, jnp.exp(diff), 0.0)
    CB = jnp.dot(Cm, Bm.T, preferred_element_type=jnp.float32)
    W = CB * Lmat * dt.T                    # (Lc, Lc), weight on x_j
    y = jnp.dot(W, x, preferred_element_type=jnp.float32)
    # inter-chunk: y[i] += (C_i·exp(cum_i)) @ h   (h: (N, P))
    y += jnp.dot(Cm * jnp.exp(cum), h_ref[...],
                 preferred_element_type=jnp.float32)
    # state update: h' = exp(cum_L)·h + Σ_j exp(cum_L - cum_j)·dt_j·B_j⊗x_j
    decay_end = jnp.exp(cum[-1:] - cum)     # (Lc, 1)
    dB = Bm * (dt * decay_end)              # (Lc, N)
    h_ref[...] = (h_ref[...] * jnp.exp(cum[-1])
                  + jnp.dot(dB.T, x, preferred_element_type=jnp.float32))
    o_ref[0, ...] = y.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssm_scan(x, dt, a, Bmat, Cmat, D, *, chunk: int = 128,
             interpret: bool = False):
    """Same contract as ``ref.ssm_scan_ref`` (returns y only — the final
    state stays device-side in serving, which uses the decode step)."""
    Bsz, S, H, P = x.shape
    G, N = Bmat.shape[2], Bmat.shape[3]
    rep = H // G
    Lc = min(chunk, S)
    pad = (-S) % Lc
    dt32 = dt.astype(jnp.float32)
    da = dt32 * a.astype(jnp.float32)[None, None, :]

    def head_major(t, feat):
        t = t.transpose(0, 2, 1, 3) if t.ndim == 4 else \
            t.transpose(0, 2, 1)[..., None]
        t = t.reshape(Bsz * H, S, feat)
        return jnp.pad(t, ((0, 0), (0, pad), (0, 0))) if pad else t

    xh = head_major(x, P)
    dth = head_major(dt32, 1)
    dah = head_major(da, 1)
    Bh = head_major(jnp.repeat(Bmat, rep, axis=2), N)
    Ch = head_major(jnp.repeat(Cmat, rep, axis=2), N)
    Sp = S + pad
    nc = Sp // Lc

    out = pl.pallas_call(
        functools.partial(_ssd_kernel, n_chunks=nc, Lc=Lc),
        grid=(Bsz * H, nc),
        in_specs=[
            pl.BlockSpec((1, Lc, P), lambda bh, ic: (bh, ic, 0)),
            pl.BlockSpec((1, Lc, 1), lambda bh, ic: (bh, ic, 0)),
            pl.BlockSpec((1, Lc, 1), lambda bh, ic: (bh, ic, 0)),
            pl.BlockSpec((1, Lc, N), lambda bh, ic: (bh, ic, 0)),
            pl.BlockSpec((1, Lc, N), lambda bh, ic: (bh, ic, 0)),
        ],
        out_specs=pl.BlockSpec((1, Lc, P), lambda bh, ic: (bh, ic, 0)),
        out_shape=jax.ShapeDtypeStruct((Bsz * H, Sp, P), x.dtype),
        scratch_shapes=[pltpu.VMEM((N, P), jnp.float32)],
        interpret=interpret,
    )(xh, dth, dah, Bh, Ch)
    y = out[:, :S].reshape(Bsz, H, S, P).transpose(0, 2, 1, 3)
    return y + x * D[None, None, :, None]
