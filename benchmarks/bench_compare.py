"""Verdict-regression gate: diff benchmark artifacts against committed
baselines.

Every perf cell writes an artifact (``benchmarks/artifacts/*.json``)
whose ``verdict`` string starts with ``confirmed``, ``refuted``, or
``skipped`` (smoke artifacts carry no verdict and are ignored). This
tool compares the artifacts in a directory against the committed
baseline verdicts (``benchmarks/baselines/verdicts.json``) and exits
nonzero exactly when a cell that the baseline records as *confirmed*
now reports *refuted* — the one transition that means a perf claim this
repo ships has regressed. Everything else (new cells, still-refuted
cells, confirmed→skipped on hosts that can't measure the claim, e.g.
``sweep_shard`` on a single-core container) is reported but does not
fail the build.

No jax import — the gate must run anywhere, including bare CI runners:

    PYTHONPATH=src python -m benchmarks.bench_compare
    PYTHONPATH=src python -m benchmarks.bench_compare --update  # rebase

Wired as ``make bench-compare`` and run after ``bench-smoke`` in CI.
"""
from __future__ import annotations

import argparse
import json
import os
import sys

ART = os.path.join(os.path.dirname(__file__), "artifacts")
BASELINE = os.path.join(os.path.dirname(__file__), "baselines",
                        "verdicts.json")

#: verdict classes, by string prefix (cells compose free-text detail
#: after the class word, e.g. ``"skipped (no physical parallelism: ...)"``)
CLASSES = ("confirmed", "refuted", "skipped")


def classify(verdict: str | None) -> str:
    """Map a cell's free-text verdict to its class by prefix;
    ``"unknown"`` for anything unclassifiable (missing, empty, or not
    starting with a class word)."""
    if not isinstance(verdict, str):
        return "unknown"
    for c in CLASSES:
        if verdict.startswith(c):
            return c
    return "unknown"


def collect(art_dir: str) -> dict[str, str]:
    """``{cell-name: verdict-string}`` for every non-smoke artifact in
    ``art_dir`` that carries a verdict. Smoke artifacts (``*_smoke``)
    never carry verdicts and are skipped by name; unreadable files are
    reported to stderr and skipped (a corrupt artifact must not mask a
    regression elsewhere)."""
    out: dict[str, str] = {}
    for fname in sorted(os.listdir(art_dir)):
        if not fname.endswith(".json") or fname.endswith("_smoke.json"):
            continue
        path = os.path.join(art_dir, fname)
        try:
            with open(path) as f:
                data = json.load(f)
        except (OSError, ValueError) as e:
            print(f"bench_compare: unreadable artifact {fname}: {e}",
                  file=sys.stderr)
            continue
        if isinstance(data, dict) and "verdict" in data:
            out[fname[:-len(".json")]] = data["verdict"]
    return out


def compare(baseline: dict[str, str], current: dict[str, str]
            ) -> tuple[list[str], list[str]]:
    """(regressions, notes): ``regressions`` lists confirmed→refuted
    transitions — the failing class; ``notes`` narrates every other
    difference (new cell, vanished cell, any other class change)."""
    regressions, notes = [], []
    for cell in sorted(set(baseline) | set(current)):
        b, c = baseline.get(cell), current.get(cell)
        bc, cc = classify(b), classify(c)
        if cell not in current:
            notes.append(f"{cell}: no artifact (baseline {bc})")
        elif cell not in baseline:
            notes.append(f"{cell}: new cell ({cc})")
        elif bc == "confirmed" and cc == "refuted":
            regressions.append(f"{cell}: confirmed -> refuted ({c!r})")
        elif bc != cc:
            notes.append(f"{cell}: {bc} -> {cc}")
    return regressions, notes


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--artifacts", default=ART,
                    help="artifact directory to audit (default: "
                         "benchmarks/artifacts)")
    ap.add_argument("--baseline", default=BASELINE,
                    help="committed verdict baseline (default: "
                         "benchmarks/baselines/verdicts.json)")
    ap.add_argument("--update", action="store_true",
                    help="rewrite the baseline from the current "
                         "artifacts instead of comparing")
    args = ap.parse_args(argv)

    current = collect(args.artifacts)
    if args.update:
        os.makedirs(os.path.dirname(args.baseline), exist_ok=True)
        with open(args.baseline, "w") as f:
            json.dump(current, f, indent=1, sort_keys=True)
            f.write("\n")
        print(f"bench_compare: baseline updated "
              f"({len(current)} cells) -> {args.baseline}")
        return 0

    try:
        with open(args.baseline) as f:
            baseline = json.load(f)
    except FileNotFoundError:
        print(f"bench_compare: no baseline at {args.baseline}; run "
              f"with --update to create one", file=sys.stderr)
        return 2

    regressions, notes = compare(baseline, current)
    for n in notes:
        print(f"bench_compare: note: {n}")
    if regressions:
        for r in regressions:
            print(f"bench_compare: REGRESSION: {r}", file=sys.stderr)
        return 1
    print(f"bench_compare: OK ({len(current)} artifacts vs "
          f"{len(baseline)} baseline cells, no confirmed->refuted)")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
