"""Fig. 3 reproduction: DRAM vs HBM congestion and memory placement on a
4×4 mesh (flow-level simulator standing in for ASTRA-sim)."""
from __future__ import annotations

from repro.core.netsim import fig3_case

from .common import emit, save_json, timed

GB = 1e9


def main():
    results = {}
    for mem in ("dram", "hbm"):
        for place in ("peripheral", "central"):
            for bw in (60 * GB, 120 * GB):
                out, us = timed(fig3_case, mem, place, bw_nop=bw)
                key = f"{mem}_{place}_nop{int(bw/GB)}"
                results[key] = out["latency"]
                emit(f"fig3/{key}", us,
                     f"latency_ms={out['latency']*1e3:.2f}")
    # headline claims
    nop_scale = results["hbm_peripheral_nop60"] / \
        results["hbm_peripheral_nop120"]
    dram_scale = results["dram_peripheral_nop60"] / \
        results["dram_peripheral_nop120"]
    placement = results["hbm_peripheral_nop60"] / \
        results["hbm_central_nop60"]
    emit("fig3/hbm_nop_scaling", 0.0,
         f"{nop_scale:.2f}x (paper: linear, 2.00x)")
    emit("fig3/dram_nop_scaling", 0.0,
         f"{dram_scale:.2f}x (paper: none, 1.00x)")
    emit("fig3/central_vs_peripheral", 0.0,
         f"{placement:.2f}x (paper: 1.53x)")
    save_json("fig3", results)


if __name__ == "__main__":
    main()
