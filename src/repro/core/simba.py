"""SIMBA-like heuristic baseline — paper Sec. 3.1 / Table 3.

SIMBA partitions each layer non-uniformly, *inversely proportional to the
communication distance* of a chiplet (row/column) from off-chip memory,
greedily per layer with no end-to-end view. The paper shows this is
slightly *worse* than uniform LS when the end-to-end implication matters
(far chiplets get starved and under-utilized on compute-bound layers).
"""
from __future__ import annotations

import numpy as np

from .hw import HWConfig
from .workload import Partition, Task, clamp_partition_to_domain

__all__ = ["simba_partition"]


def _inverse_distance_split(total: int, weights: np.ndarray, unit: int
                            ) -> np.ndarray:
    """Largest-remainder apportionment of ``total`` by ``weights``,
    snapped to multiples of ``unit`` where possible."""
    w = weights / weights.sum()
    raw = w * total
    base = np.floor(raw / unit).astype(np.int64) * unit
    resid = total - int(base.sum())
    # distribute the residual by largest fractional part, unit at a time
    order = np.argsort(-(raw - base))
    i = 0
    while resid >= unit:
        base[order[i % len(base)]] += unit
        resid -= unit
        i += 1
    base[order[0]] += resid  # sub-unit remainder
    return base


def simba_partition(task: Task, hw: HWConfig) -> Partition:
    top = hw.topology
    # Row/column distance = mean local distance of that grid row/col to its
    # entrance (generalizes the corner-memory case to types B/C/D).
    row_dist = top.x_local.mean(axis=1) + top.y_local.mean(axis=1) * 0.0
    col_dist = top.y_local.mean(axis=0)
    wx = 1.0 / (1.0 + row_dist)
    wy = 1.0 / (1.0 + col_dist)
    Px = np.stack(
        [_inverse_distance_split(op.M, wx, hw.R) for op in task.ops])
    Py = np.stack(
        [_inverse_distance_split(op.N, wy, hw.C) for op in task.ops])
    part = Partition(Px, Py, np.full(len(task), hw.Y // 2, dtype=np.int64))
    # SIMBA still respects systolic-utilization floors; project into the
    # same feasible domain the solvers use (slack chosen wide).
    part = clamp_partition_to_domain(part, task, hw.X, hw.Y, hw.R, hw.C,
                                     slack=2)
    part.validate(task)
    return part
