"""Loss-path tests: fused chunked CE vs materialized logits, masking,
vocab padding, softcap."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.loss import fused_lm_loss, lm_loss, masked_pred_loss

KEY = jax.random.PRNGKey(0)


def setup(B=2, S=33, D=16, V=50, Vp=64):
    ks = jax.random.split(KEY, 3)
    hidden = jax.random.normal(ks[0], (B, S, D))
    head = jax.random.normal(ks[1], (D, Vp)) * 0.2
    tokens = jax.random.randint(ks[2], (B, S), 0, V)
    return hidden, head, tokens


def _logits(hidden, head, V, Vp, softcap=None):
    lg = hidden @ head
    if softcap is not None:
        lg = softcap * jnp.tanh(lg / softcap)
    if V != Vp:
        lg = jnp.where(jnp.arange(Vp)[None, None] >= V, -1e9, lg)
    return lg


@pytest.mark.parametrize("chunk", [8, 16, 64])
def test_fused_matches_materialized(chunk):
    hidden, head, tokens = setup()
    want = lm_loss(_logits(hidden, head, 50, 64), tokens)
    got = fused_lm_loss(hidden, head, tokens, vocab_size=50, chunk=chunk)
    assert float(got) == pytest.approx(float(want), rel=1e-5)


def test_fused_with_softcap():
    hidden, head, tokens = setup()
    want = lm_loss(_logits(hidden, head, 50, 64, softcap=10.0), tokens)
    got = fused_lm_loss(hidden, head, tokens, vocab_size=50,
                        final_softcap=10.0, chunk=8)
    assert float(got) == pytest.approx(float(want), rel=1e-5)


def test_fused_mask_no_shift():
    hidden, head, tokens = setup()
    mask = jax.random.bernoulli(KEY, 0.4, tokens.shape)
    want = masked_pred_loss(_logits(hidden, head, 50, 64), tokens, mask)
    got = fused_lm_loss(hidden, head, tokens, mask=mask, vocab_size=50,
                        shift=False, chunk=8)
    assert float(got) == pytest.approx(float(want), rel=1e-5)


def test_fused_grads_match():
    hidden, head, tokens = setup(S=32)

    def f_fused(h, w):
        return fused_lm_loss(h, w, tokens, vocab_size=50, chunk=8)

    def f_mat(h, w):
        return lm_loss(_logits(h, w, 50, 64), tokens)

    g1 = jax.grad(f_fused, argnums=(0, 1))(hidden, head)
    g2 = jax.grad(f_mat, argnums=(0, 1))(hidden, head)
    for a, b in zip(g1, g2):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-4, atol=1e-6)


def test_padding_tokens_never_win():
    hidden, head, tokens = setup()
    lg = _logits(hidden, head, 50, 64)
    assert int(jnp.argmax(lg, -1).max()) < 50
